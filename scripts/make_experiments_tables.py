"""Render EXPERIMENTS.md tables from result artifacts.

Two sources:

* dry-run JSONs (§Dry-run / §Roofline):
      PYTHONPATH=src python scripts/make_experiments_tables.py \
          results/dryrun_final2 [results/dryrun_baseline]
* batched-sweep TLB results written by ``python -m benchmarks.run``
  (the sweep engine's results/benchmarks.json):
      PYTHONPATH=src python scripts/make_experiments_tables.py \
          --tlb results/benchmarks.json
"""
import argparse
import glob
import json


def load(d):
    out = {}
    for p in sorted(glob.glob(f"{d}/*.json")):
        with open(p) as f:
            r = json.load(f)
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def fmt_s(v):
    if v == 0:
        return "0"
    if v < 1e-3:
        return f"{v*1e6:.0f}µs"
    if v < 1:
        return f"{v*1e3:.1f}ms"
    return f"{v:.2f}s"


def render_dryrun(final_dir, base_dir=None):
    final = load(final_dir)
    base = load(base_dir) if base_dir else {}

    print("### §Dry-run — per-cell compile + memory (all 40 cells × 2 meshes)\n")
    print("| arch | shape | mesh | status | mem/dev raw | mem/dev TPU-adj "
          "| fits 16GB | compile |")
    print("|---|---|---|---|---|---|---|---|")
    for key in sorted(final):
        r = final[key]
        a, s, m = key
        if r["status"] == "SKIP":
            print(f"| {a} | {s} | {m} | SKIP — {r['reason']} | | | | |")
            continue
        mem = r["memory"]
        print(f"| {a} | {s} | {m} | OK | {mem['total_per_device']/1e9:.2f}GB "
              f"| {mem['total_adjusted_tpu']/1e9:.2f}GB "
              f"| {'✓' if mem['fits_16gb'] else '✗'} "
              f"| {r['time']['compile_s']}s |")

    print("\n### §Roofline — single-pod (16×16) terms per step\n")
    print("| arch | shape | compute | memory (analytic) | collective "
          "| dominant | MODEL_FLOPS/HLO | vs baseline coll |")
    print("|---|---|---|---|---|---|---|---|")
    for key in sorted(final):
        a, s, m = key
        if m != "16x16":
            continue
        r = final[key]
        if r["status"] != "OK":
            continue
        rf = r["roofline"]
        uf = r.get("useful_flops_frac")
        delta = ""
        b = base.get(key)
        if b and b.get("status") == "OK":
            c0 = b["roofline"]["collective_s"]
            c1 = rf["collective_s"]
            if c0 > 0:
                delta = f"{(c1/c0 - 1)*100:+.0f}%"
        if uf is not None:
            print(f"| {a} | {s} | {fmt_s(rf['compute_s'])} "
                  f"| {fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} "
                  f"| {rf['dominant']} | {uf:.2f} | {delta} |")

    print("\n### Multi-pod (2×16×16) — collective scaling\n")
    print("| arch | shape | coll sp | coll mp | mp/sp |")
    print("|---|---|---|---|---|")
    for key in sorted(final):
        a, s, m = key
        if m != "16x16":
            continue
        r_sp = final[key]
        r_mp = final.get((a, s, "2x16x16"))
        if (r_sp.get("status") != "OK" or not r_mp
                or r_mp.get("status") != "OK"):
            continue
        c_sp = r_sp["roofline"]["collective_s"]
        c_mp = r_mp["roofline"]["collective_s"]
        print(f"| {a} | {s} | {fmt_s(c_sp)} | {fmt_s(c_mp)} "
              f"| {c_mp/max(c_sp,1e-12):.2f}× |")


SCENARIO_SECTIONS = ("tlb_scenario_contiguity", "tlb_scenarios",
                     "tlb_dynamic", "tlb_multitenant", "tlb_nested",
                     "tlb_accelerator")


def _md_cell(v) -> str:
    # '|K|=2'-style labels must not break the GFM table structure
    return str(v).replace("|", "\\|")


def _md_table(rows):
    cols = list(rows[0].keys())
    print("| " + " | ".join(_md_cell(c) for c in cols) + " |")
    print("|" + "---|" * len(cols))
    for r in rows:
        print("| " + " | ".join(_md_cell(r.get(c, "")) for c in cols) + " |")
    print()


def render_tlb(path):
    """Markdown tables for the paper's TLB artifacts from the batched-sweep
    results/benchmarks.json (one section per table/figure), plus a dedicated
    per-scenario section pairing each workload-derived/adversarial
    scenario's contiguity histogram with its miss-rate comparison."""
    with open(path) as f:
        payload = json.load(f)
    # pre-sweep runs wrote the sections dict at top level
    sections = payload.get("sections", payload)
    tier = payload.get("tier", "?")
    total = payload.get("total_wall_s", "?")
    # pre-backend-knob runs did not record the engine backend
    backend = payload.get("backend", "auto")
    print(f"## TLB sweep results  (tier={tier}, backend={backend}, "
          f"total {total}s)\n")
    for name, sec in sections.items():
        if not name.startswith("tlb_") or name in SCENARIO_SECTIONS:
            continue
        rows = sec.get("rows") or []
        if not rows:
            continue
        print(f"### {name} — {sec.get('artifact', '')}\n")
        _md_table(rows)

    if any(sections.get(s, {}).get("rows") for s in SCENARIO_SECTIONS):
        print("## Scenario registry: workload-derived contiguity\n")
        print("Mappings and VPN traces recorded from the repo's own serving"
              " and training stacks (plus adversarial generators), swept"
              " through `run_sweep` like the paper benches — see"
              " `docs/scenarios.md` for each scenario's definition.\n")
        cont = sections.get("tlb_scenario_contiguity", {}).get("rows")
        if cont:
            print("### Contiguity histograms (the Figs 2–3 measurement on"
                  " our workloads)\n")
            _md_table(cont)
        sc = sections.get("tlb_scenarios", {}).get("rows")
        if sc:
            print("### Relative TLB misses per scenario (Base = 1.0)\n")
            _md_table(sc)

    dyn = sections.get("tlb_dynamic", {}).get("rows")
    if dyn:
        print("## Dynamic mapping worlds: mid-trace remaps\n")
        print("Live event streams (serving churn, incremental compaction,"
              " progressive THP splitting) instead of frozen snapshots:"
              " each epoch turnover invalidates every TLB entry covering a"
              " remapped page (translation coherence) and charges the"
              " shootdown.  `rel_misses` rows are walks relative to Base;"
              " `shootdowns` rows count invalidated entries per method —"
              " see `docs/scenarios.md` for the scenario definitions.\n")
        _md_table(dyn)

    mt = sections.get("tlb_multitenant", {}).get("rows")
    if mt:
        print("## Multi-tenant address spaces: ASID tags vs"
              " flush-on-switch\n")
        print("Several tenants — each with its own contiguity signature —"
              " time-share one TLB under a KVScheduler-derived"
              " context-switch schedule (ASIDs are batch slots, recycled"
              " on tenant departure).  Every scenario is swept under both"
              " context-switch policies: `flush` wipes all structures on"
              " a switch, `tag` keeps ASID-tagged entries resident and"
              " pays targeted invalidation only on ASID recycling."
              "  `rel_misses` rows are walks relative to Base under the"
              " SAME policy; `shootdowns` rows count flushed/invalidated"
              " entries — see `docs/scenarios.md`.\n")
        _md_table(mt)

    nest = sections.get("tlb_nested", {}).get("rows")
    if nest:
        print("## Nested guest→host translation: shootdown vs"
              " hw-coherence\n")
        print("Two-level worlds: per-VM guest page tables composed over a"
              " host layer the hypervisor rewrites mid-trace (migration,"
              " defragmentation, ballooning), with VM schedules from the"
              " serving stack's KVScheduler.  Every scenario is swept"
              " under both translation-coherence policies: `shootdown`"
              " charges the fixed IPI latency plus per-entry invalidation"
              " on each remap storm, `hw-coherence` drops the SAME entry"
              " set for only the per-entry cost.  `rel_misses` rows are"
              " walks relative to Base (policy-invariant by construction"
              " — both policies invalidate identically); `shootdowns`"
              " rows count invalidated entries; `stall_cycles` rows"
              " isolate the coherence tax — see `docs/scenarios.md` and"
              " `docs/methods.md`.\n")
        _md_table(nest)

    acc = sections.get("tlb_accelerator", {}).get("rows")
    if acc:
        print("## Accelerator-scale translation: beyond the paper's"
              " roster\n")
        print("The kv-gather DMA recording interleaved as 64/256/1024"
              " concurrent streams sharing one TLB (`accel-gather-x*`),"
              " swept with Base, |K|=3 Aligned and the three"
              " accelerator-lineage methods — Subregion (bitmap windows),"
              " Cache-TLB (cache-backed reach), Dead-Protect (dead-fill"
              " bypass); see `docs/methods.md` for the method semantics"
              " and `docs/scenarios.md` for the scenario family."
              "  `rel_misses` rows are walks relative to Base;"
              " `cycles_per_access` rows show the latency trade — a"
              " cache-backed hit is cheaper than a walk but slower than"
              " any on-chip hit, so the two metrics can disagree.\n")
        _md_table(acc)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("dryrun_dir", nargs="?",
                    help="directory of dry-run JSONs (results/dryrun_*)")
    ap.add_argument("baseline_dir", nargs="?",
                    help="optional baseline dry-run directory")
    ap.add_argument("--tlb", metavar="BENCHMARKS_JSON",
                    help="render TLB sweep tables from benchmarks.json")
    args = ap.parse_args()
    if not args.dryrun_dir and not args.tlb:
        ap.error("need a dry-run directory and/or --tlb results")
    if args.tlb:
        render_tlb(args.tlb)
    if args.dryrun_dir:
        render_dryrun(args.dryrun_dir, args.baseline_dir)


if __name__ == "__main__":
    main()
