"""Tier-1 wall-time budget guard: the fast test tier must stay fast.

Runs the tier-1 suite (``python -m pytest -q`` — the pyproject addopts
deselect ``@slow``), times it, appends one entry to the repo-root
``BENCH_tier1.json`` trajectory::

    {"git_sha": ..., "host": ..., "wall_s": ..., "pytest_args": [...]}

and with ``--check`` compares the fresh wall time against the **last
committed entry** (``git show HEAD:BENCH_tier1.json`` — local appends
never ratchet the baseline) of the same host signature, failing past
``--threshold`` (default 1.25×).  New tests are expected to ADD time;
the gate exists so they add it consciously: exceeding the budget means
either marking the heaviest new tests ``@pytest.mark.slow`` (with small
fast variants, the repo convention) or committing a new baseline entry
in the same PR and saying so.

Wall-clock baselines only compare within one machine class: until an
entry measured on the current host class is committed, the gate is NOT
armed — it prints the ready-to-commit entry (and a ``::warning``
annotation on GitHub Actions) instead of silently passing, exactly like
``scripts/perf_smoke.py``.

Extra arguments after ``--`` are passed through to pytest (CI appends
the pytest-cov flags there, so the committed baseline includes the
coverage overhead it gates under).

Usage::

    python scripts/check_tier_budget.py [--check] [--no-append]
                                        [--threshold 1.25] [-- PYTEST_ARGS]
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_FILE = os.path.join(REPO, "BENCH_tier1.json")


def _git_sha() -> str:
    try:
        return subprocess.run(["git", "describe", "--always", "--dirty"],
                              capture_output=True, text=True, cwd=REPO,
                              timeout=10).stdout.strip() or "nogit"
    except (OSError, subprocess.SubprocessError):
        return "nogit"


def _host_sig() -> str:
    return f"{platform.system().lower()}-{platform.machine()}-" \
           f"{os.cpu_count()}cpu"


def load_trajectory() -> list:
    if not os.path.exists(BENCH_FILE):
        return []
    with open(BENCH_FILE) as f:
        data = json.load(f)
    assert isinstance(data, list), "BENCH_tier1.json must hold a list"
    return data


def committed_trajectory() -> list:
    """The trajectory as of HEAD — the budget baseline (see perf_smoke)."""
    try:
        r = subprocess.run(["git", "show", "HEAD:BENCH_tier1.json"],
                           capture_output=True, text=True, cwd=REPO,
                           timeout=10)
    except (OSError, subprocess.SubprocessError):
        return load_trajectory()
    if r.returncode != 0:
        in_repo = subprocess.run(
            ["git", "rev-parse", "--is-inside-work-tree"],
            capture_output=True, text=True, cwd=REPO, timeout=10)
        return [] if in_repo.returncode == 0 else load_trajectory()
    data = json.loads(r.stdout)
    assert isinstance(data, list)
    return data


def _step_summary(entry: dict, baseline, status: str, failed: bool) -> None:
    """Append the measured entry (and, when the gate is unarmed, the
    ready-to-commit baseline JSON) to the GitHub Actions step summary."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    lines = ["## Tier-1 wall-time budget", "",
             f"- measured: **{entry['wall_s']}s** on `{entry['host']}` "
             f"at `{entry['git_sha']}`",
             f"- status: {'**BUDGET EXCEEDED** — ' if failed else ''}"
             f"{status}"]
    if baseline is None:
        lines += ["", "Gate **not armed** for this host class — commit "
                  "this entry to `BENCH_tier1.json` to arm it:", "",
                  "```json", json.dumps(entry), "```"]
    try:
        with open(path, "a", encoding="utf-8") as fh:
            fh.write("\n".join(lines) + "\n\n")
    except OSError:
        pass


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    pytest_args: list = []
    if "--" in argv:
        cut = argv.index("--")
        argv, pytest_args = argv[:cut], argv[cut + 1:]
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="fail when wall time exceeds threshold x the "
                         "committed same-host baseline")
    ap.add_argument("--threshold", type=float, default=1.25)
    ap.add_argument("--no-append", action="store_true",
                    help="leave BENCH_tier1.json untouched")
    args = ap.parse_args(argv)

    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(REPO, "src"), env.get("PYTHONPATH")) if p)
    env.setdefault("JAX_PLATFORMS", "cpu")
    cmd = [sys.executable, "-m", "pytest", "-q"] + pytest_args
    print("running:", " ".join(cmd), flush=True)
    t0 = time.time()
    r = subprocess.run(cmd, cwd=REPO, env=env)
    wall = round(time.time() - t0, 1)
    if r.returncode != 0:
        print(f"tier-1 suite FAILED (rc={r.returncode}) after {wall}s — "
              "budget not evaluated", file=sys.stderr)
        return r.returncode

    entry = {"git_sha": _git_sha(), "host": _host_sig(), "wall_s": wall,
             "pytest_args": pytest_args}
    # match on host AND pytest args: a coverage-instrumented CI run must
    # never be gated by (or arm) an uninstrumented local baseline
    baseline = next(
        (e for e in reversed(committed_trajectory())
         if e.get("host") == entry["host"]
         and e.get("pytest_args", []) == entry["pytest_args"]), None)
    status = "no baseline"
    failed = False
    if baseline is None and args.check:
        print(f"NOTE: no committed tier-1 baseline for host="
              f"{entry['host']} — the budget gate did NOT run.  Commit "
              f"this entry to BENCH_tier1.json to arm it:\n"
              f"  {json.dumps(entry)}", file=sys.stderr)
        if os.environ.get("GITHUB_ACTIONS"):
            print(f"::warning file=BENCH_tier1.json::tier-1 budget gate "
                  f"not armed for {entry['host']} — commit a baseline "
                  f"entry measured on this runner class (ready-to-commit "
                  f"JSON in the job log)")
    if baseline:
        ratio = wall / max(baseline["wall_s"], 1e-9)
        status = (f"{ratio:.2f}x vs baseline {baseline['wall_s']}s"
                  f"@{baseline['git_sha']}")
        if args.check and ratio > args.threshold:
            failed = True
            print(f"BUDGET EXCEEDED: tier-1 took {wall}s, "
                  f"{ratio:.2f}x the committed {baseline['wall_s']}s "
                  f"(> {args.threshold}x).  Mark the heaviest new tests "
                  f"@pytest.mark.slow (with fast variants) or commit a "
                  f"new BENCH_tier1.json entry in this PR.",
                  file=sys.stderr)
    print(f"tier-1 wall={wall}s [{status}]")
    _step_summary(entry, baseline, status, failed)

    if not args.no_append:
        traj = load_trajectory()
        traj.append(entry)
        with open(BENCH_FILE, "w") as f:
            json.dump(traj, f, indent=1)
            f.write("\n")
        print(f"appended to {os.path.relpath(BENCH_FILE)}")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
