#!/usr/bin/env python
"""Run the cross-executor contract checker (repro.analysis) on the tree.

Stdlib-only on purpose: the CI ``contracts`` job (like the docs job)
installs nothing, and the analysis package reads the executors' source
instead of importing it.  Exit codes: 0 clean (warnings allowed), 1
contract violations, 2 the checker itself could not run.

Findings are printed one per line as ``file:line: [rule] severity:
message (hint)``; when ``$GITHUB_STEP_SUMMARY`` is set (CI), a markdown
table of the findings is appended there too.  Accepted exceptions live
in ``.contracts-suppressions`` — see docs/analysis.md for the format.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro import analysis  # noqa: E402


def _github_summary(active, suppressed, passes) -> str:
    lines = ["## Contract checker", "",
             f"Passes run: {', '.join(p.RULE for p in passes)}", ""]
    if not active:
        lines.append(f"**Clean** — no findings "
                     f"({len(suppressed)} suppressed).")
    else:
        lines += ["| Location | Rule | Severity | Finding |",
                  "| --- | --- | --- | --- |"]
        for f in active:
            loc = f"{f.file}:{f.line}" if f.line else f.file
            msg = f.message.replace("|", "\\|")
            if f.hint:
                msg += f" — {f.hint}".replace("|", "\\|")
            lines.append(f"| `{loc}` | {f.rule} | {f.severity} | {msg} |")
        lines.append("")
        lines.append(f"{len(suppressed)} finding(s) suppressed via "
                     f"`.contracts-suppressions`.")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))),
        help="repository root to analyze (default: this checkout)")
    ap.add_argument("--pass", dest="passes", action="append",
                    choices=sorted(analysis.PASS_BY_RULE),
                    help="run only this pass (repeatable; default: all)")
    ap.add_argument("--list", action="store_true",
                    help="list available passes and exit")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print suppressed findings")
    args = ap.parse_args(argv)

    if args.list:
        for p in analysis.ALL_PASSES:
            doc = (p.__doc__ or "").strip().splitlines()
            print(f"{p.RULE}: {doc[0] if doc else ''}")
        return 0

    passes = ([analysis.PASS_BY_RULE[r] for r in args.passes]
              if args.passes else list(analysis.ALL_PASSES))
    repo = analysis.Repo(args.root)
    try:
        active, suppressed = analysis.run_passes(repo, passes)
    except Exception as e:  # checker bug, not a contract violation
        print(f"contract checker failed to run: {e}", file=sys.stderr)
        return 2

    for f in active:
        print(f.render())
    if args.show_suppressed:
        for f in suppressed:
            print(f"[suppressed] {f.render()}")

    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        try:
            with open(summary_path, "a", encoding="utf-8") as fh:
                fh.write(_github_summary(active, suppressed, passes))
        except OSError:
            pass

    errors = [f for f in active if f.severity == "error"]
    warnings = [f for f in active if f.severity != "error"]
    print(f"{len(passes)} pass(es): {len(errors)} error(s), "
          f"{len(warnings)} warning(s), {len(suppressed)} suppressed")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
