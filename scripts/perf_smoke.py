"""Perf-smoke: time the smoke-tier sweep per backend, track the trajectory.

For every requested backend this script runs the smoke benchmark suite
twice in a scratch directory — once with an empty sweep cache (``cold_s``:
world materialization + compile + simulate + cache store) and once again
over the populated cache (``cached_s``: the content-hash cache-hit path) —
and appends one entry per backend to the repo-root ``BENCH_sweep.json``
trajectory::

    {"git_sha": ..., "tier": ..., "backend": ..., "cold_s": ..., "cached_s": ...}

Scopes per backend:

* ``xla`` — the full smoke TLB suite (``python -m benchmarks.run --smoke``),
  tier ``smoke``; this is the default-backend number the CI regression gate
  watches.
* ``pallas`` — a micro sweep (tier ``smoke-micro``): all 8 method kinds ×
  one static + one dynamic world at test scale, through the same
  ``run_sweep`` path.  Off-TPU the kernel runs in *interpret* mode, where
  smoke-scale record blocks make wall time pure interpreter overhead — so
  this lane sizes the worlds down to keep the Pallas path exercised
  end-to-end (cold compile + simulate + cache, then the cached path) with
  a trajectory that is comparable run-over-run.

``--check`` compares each backend's fresh ``cold_s`` against the **last
committed entry** (read from ``git show HEAD:BENCH_sweep.json``, so local
appends never ratchet the baseline) of the same (tier, backend, host) in
``BENCH_sweep.json`` and exits non-zero past ``--threshold`` (default
1.3×) — the sweep engine must not quietly regress.  Entries carry a
``host`` signature (platform + cpu count): wall-clock only compares within
one machine class, so a CI runner is gated by CI-measured baselines, not
by numbers committed from a developer laptop — until a matching baseline
exists, the check reports "no baseline" and passes.

Usage::

    python scripts/perf_smoke.py [--backends xla,pallas] [--check]
                                 [--no-append] [--threshold 1.3]
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import subprocess
import sys
import tempfile
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_FILE = os.path.join(REPO, "BENCH_sweep.json")

TIERS = {"xla": "smoke", "pallas": "smoke-micro"}

_MICRO_SWEEP = r"""
import numpy as np
from repro.core import demand_mapping, generate_trace
from repro.core.baselines import (anchor_spec, base_spec, cache_tlb_spec,
                                  cluster_spec, colt_spec, dead_protect_spec,
                                  kaligned_spec, rmm_spec, subregion_spec,
                                  thp_spec)
from repro.core.page_table import MappingEvent, build_dynamic_mapping
from repro.core.sweep import SweepCell, run_sweep

m = demand_mapping(1 << 10, seed=11)
tr = generate_trace("multiscale", 0, 256, seed=4, mapping=m)
dyn = build_dynamic_mapping(
    np.arange(1 << 10, dtype=np.int64) + 7,
    [(80, [MappingEvent("remap", 0, 128, ppn=100_000)]),
     (150, [MappingEvent("unmap", 768, 32)])], name="perf-dyn")
dtr = np.random.default_rng(3).integers(0, 512, size=256).astype(np.int64)
specs = [base_spec(), thp_spec(), colt_spec(), cluster_spec(), rmm_spec(),
         anchor_spec(6), kaligned_spec([9, 6, 4]),
         kaligned_spec([6, 4], use_predictor=False, name="ka-nopred"),
         subregion_spec(), cache_tlb_spec(), dead_protect_spec()]
cells = [SweepCell(s, m, tr) for s in specs]
cells += [SweepCell(s, dyn, dtr) for s in specs]
sweep = run_sweep(cells, backend="pallas")
assert all(r is not None for r in sweep.results)
print("micro sweep ok", sweep.stats)
"""


def _run_cmd(backend: str):
    if backend == "pallas":
        return [sys.executable, "-c", _MICRO_SWEEP]
    return [sys.executable, "-m", "benchmarks.run", "--smoke",
            "--backend", backend]


def _git_sha() -> str:
    try:
        return subprocess.run(["git", "describe", "--always", "--dirty"],
                              capture_output=True, text=True, cwd=REPO,
                              timeout=10).stdout.strip() or "nogit"
    except (OSError, subprocess.SubprocessError):
        return "nogit"


def _host_sig() -> str:
    """Machine-class signature: wall-clock baselines only compare within
    one class (a 2-core dev container and a GitHub runner are different
    machines; comparing across them measures hardware, not the engine)."""
    return f"{platform.system().lower()}-{platform.machine()}-" \
           f"{os.cpu_count()}cpu"


def _run_once(backend: str, cwd: str) -> float:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(REPO, "src"), REPO,
                    env.get("PYTHONPATH")) if p)
    env.setdefault("JAX_PLATFORMS", "cpu")
    t0 = time.time()
    r = subprocess.run(_run_cmd(backend), cwd=cwd, env=env,
                       capture_output=True, text=True)
    dt = time.time() - t0
    if r.returncode != 0:
        sys.stderr.write(r.stdout[-2000:] + r.stderr[-4000:])
        raise SystemExit(f"perf-smoke run failed for backend={backend}")
    return dt


def measure(backend: str) -> dict:
    # a scratch cwd gives a fresh results/sweep_cache: run 1 is the cold
    # path (materialize + compile + simulate + store), run 2 the cached one
    with tempfile.TemporaryDirectory(prefix=f"perf_smoke_{backend}_") as tmp:
        cold = _run_once(backend, tmp)
        cached = _run_once(backend, tmp)
    return {"git_sha": _git_sha(), "tier": TIERS[backend],
            "backend": backend, "host": _host_sig(),
            "cold_s": round(cold, 1), "cached_s": round(cached, 1)}


def load_trajectory() -> list:
    if not os.path.exists(BENCH_FILE):
        return []
    with open(BENCH_FILE) as f:
        data = json.load(f)
    assert isinstance(data, list), "BENCH_sweep.json must hold a list"
    return data


def committed_trajectory() -> list:
    """The trajectory as of HEAD — the regression baseline.  Local
    (uncommitted) appends must never ratchet the gate: inside a git
    checkout where the file is absent from HEAD the baseline is empty, and
    only outside a git checkout (no HEAD to ask) does the working-tree
    file stand in."""
    try:
        r = subprocess.run(["git", "show", "HEAD:BENCH_sweep.json"],
                           capture_output=True, text=True, cwd=REPO,
                           timeout=10)
    except (OSError, subprocess.SubprocessError):
        return load_trajectory()
    if r.returncode != 0:
        in_repo = subprocess.run(
            ["git", "rev-parse", "--is-inside-work-tree"],
            capture_output=True, text=True, cwd=REPO, timeout=10)
        return [] if in_repo.returncode == 0 else load_trajectory()
    data = json.loads(r.stdout)
    assert isinstance(data, list)
    return data


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backends", default="xla,pallas",
                    help="comma list of backends to measure")
    ap.add_argument("--check", action="store_true",
                    help="fail on cold-time regression vs the committed "
                         "baseline")
    ap.add_argument("--threshold", type=float, default=1.3,
                    help="max allowed cold_s ratio vs baseline (default "
                         "1.3x)")
    ap.add_argument("--no-append", action="store_true",
                    help="measure and check only; leave BENCH_sweep.json "
                         "untouched")
    args = ap.parse_args(argv)

    trajectory = load_trajectory()
    committed = committed_trajectory()
    failures = []
    for backend in [b for b in args.backends.split(",") if b]:
        if backend not in TIERS:
            raise SystemExit(f"unknown backend {backend!r}")
        entry = measure(backend)
        baseline = next(
            (e for e in reversed(committed)
             if e.get("tier") == entry["tier"]
             and e.get("backend") == backend
             and e.get("host") == entry["host"]), None)
        status = "no baseline"
        if baseline is None and args.check:
            # the gate is inert until a baseline measured on THIS machine
            # class is committed — say so loudly and print the ready-to-
            # commit entry, so a green run can't be mistaken for a passed
            # regression check (e.g. a fresh CI runner class)
            print(f"NOTE: no committed baseline for "
                  f"(tier={entry['tier']}, backend={backend}, "
                  f"host={entry['host']}) — the regression gate did NOT "
                  f"run.  Commit this entry to BENCH_sweep.json to arm "
                  f"it:\n  {json.dumps(entry)}", file=sys.stderr)
            if os.environ.get("GITHUB_ACTIONS"):
                # surface it as an annotation: a green job with an unarmed
                # gate must be visible on the PR, not buried in the log
                print(f"::warning file=BENCH_sweep.json::perf-smoke gate "
                      f"not armed for {backend}@{entry['host']} — commit "
                      f"a baseline entry measured on this runner class "
                      f"(see the job log for the ready-to-commit JSON)")
        if baseline:
            ratio = entry["cold_s"] / max(baseline["cold_s"], 1e-9)
            status = (f"{ratio:.2f}x vs baseline "
                      f"{baseline['cold_s']}s@{baseline['git_sha']}")
            if args.check and ratio > args.threshold:
                failures.append(f"{backend}: cold {entry['cold_s']}s is "
                                f"{ratio:.2f}x baseline "
                                f"{baseline['cold_s']}s "
                                f"(> {args.threshold}x)")
        print(f"{backend:7s} tier={entry['tier']:15s} "
              f"cold={entry['cold_s']:7.1f}s cached={entry['cached_s']:6.1f}s "
              f"[{status}]")
        trajectory.append(entry)

    if not args.no_append:
        with open(BENCH_FILE, "w") as f:
            json.dump(trajectory, f, indent=1)
            f.write("\n")
        print(f"appended to {os.path.relpath(BENCH_FILE)}")
    for msg in failures:
        print(f"REGRESSION: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
