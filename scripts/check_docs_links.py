"""Markdown link + methods-reference checker for the docs CI job.

Walks every tracked ``*.md`` file and verifies that relative link targets
exist in the working tree.  ``http(s)``/``mailto`` links are skipped (CI
must not depend on the network); ``#Lnn``/anchor fragments are stripped
before the existence check, so ``file.py#L123``-style references stay
checkable as files.

Additionally enforces that ``docs/methods.md`` documents EVERY MethodSpec
kind registered in ``src/repro/core/simulator.py``.  The kind registry is
resolved through the shared static parser in ``repro.analysis.kinds``
(AST-based, no jax import) — the same one the contract checker uses, so
the two can never drift.  Adding a kind without documenting its entry
format and semantics fails CI.

Exit code 1 with a listing when any link is broken or any kind is
undocumented.

    python scripts/check_docs_links.py [root]
"""
from __future__ import annotations

import os
import re
import subprocess
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.analysis import kinds as _kinds  # noqa: E402
from repro.analysis.framework import Repo  # noqa: E402

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_SCHEMES = ("http://", "https://", "mailto:")
SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", ".ruff_cache",
             "results", ".github", ".venv", "venv", "node_modules",
             ".claude"}
# arxiv-scraped reference material ships with figure links we don't vendor
SKIP_FILES = {"PAPERS.md", "SNIPPETS.md"}


def md_files(root: str):
    try:
        out = subprocess.run(["git", "ls-files", "*.md"], cwd=root,
                             capture_output=True, text=True, check=True)
        names = out.stdout.splitlines()
    except (OSError, subprocess.CalledProcessError):
        # not a git checkout: walk, skipping virtualenvs and caches
        names = []
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
            names.extend(os.path.relpath(os.path.join(dirpath, f), root)
                         for f in filenames if f.endswith(".md"))
    for name in names:
        if os.path.basename(name) not in SKIP_FILES:
            yield os.path.join(root, name)


def registered_kinds(root: str):
    """The simulator's KINDS tuple, via the shared AST parser."""
    return _kinds.registered_kinds(Repo(root))


def check_methods_doc(root: str) -> list:
    """Every registered kind must appear as ``kind: `<name>``` in
    docs/methods.md — the complete-methods-reference contract."""
    if not os.path.exists(os.path.join(root, "docs", "methods.md")):
        return ["docs/methods.md missing"]
    return [f"docs/methods.md does not document kind `{k}`"
            for k in _kinds.undocumented_kinds(Repo(root))]


def check(root: str) -> int:
    broken = []
    n_links = 0
    for md in sorted(md_files(root)):
        with open(md, encoding="utf-8") as f:
            text = f.read()
        for target in LINK_RE.findall(text):
            if target.startswith(SKIP_SCHEMES):
                continue
            path = target.split("#", 1)[0]
            if not path:          # pure in-page anchor
                continue
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(md), path))
            n_links += 1
            if not os.path.exists(resolved):
                broken.append((md, target))
    rel = os.path.relpath
    for md, target in broken:
        print(f"BROKEN  {rel(md, root)} -> {target}", file=sys.stderr)
    undocumented = check_methods_doc(root)
    for msg in undocumented:
        print(f"UNDOCUMENTED  {msg}", file=sys.stderr)
    kinds = registered_kinds(root)
    print(f"checked {n_links} relative links in docs; "
          f"{len(broken)} broken; {len(kinds)} method kinds, "
          f"{len(undocumented)} undocumented")
    return 1 if broken or undocumented else 0


if __name__ == "__main__":
    sys.exit(check(sys.argv[1] if len(sys.argv) > 1 else os.getcwd()))
