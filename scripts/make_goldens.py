"""Regenerate the golden-trace oracle suite under tests/goldens/.

Each golden is a tiny hand-checkable world (<= 16 accesses) for one method
kind, plus one multi-tenant world per context-switch policy.  The JSON
records the world, the trace, the oracle's per-step
``(level, ppn, evict, probes, cycles)`` sequence, the segment-entry events
(switch/shootdown with invalidation counts), and the final counters —
``tests/test_goldens.py`` replays them so a parity failure localizes to a
step instead of an end-of-run counter diff.

The worlds are DESIGNED, not sampled: each one forces the interesting
transitions of its method kind (cold walk -> coalesced hit -> L1 hit ->
L2 eviction -> refault), small enough to verify by hand from the
docstrings below.  Regenerate after an intentional semantics change with::

    PYTHONPATH=src python scripts/make_goldens.py

and review the diff like any other golden update.
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.core.baselines import (anchor_spec, base_spec, cache_tlb_spec,  # noqa: E402
                                  cluster_spec, colt_spec, dead_protect_spec,
                                  kaligned_spec, rmm_spec, subregion_spec,
                                  thp_spec)
from repro.core.page_table import (MappingEvent,  # noqa: E402
                                   build_dynamic_mapping,
                                   build_multitenant_mapping,
                                   build_nested_mapping, make_mapping)
from repro.core.simulator import (run_method_dynamic,  # noqa: E402
                                  run_method_multitenant,
                                  run_method_nested)

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "tests", "goldens")

FINAL_FIELDS = ("accesses", "l1_hits", "l2_regular_hits",
                "l2_coalesced_hits", "walks", "aligned_probes",
                "pred_correct", "cycles", "shootdowns")


def _identity(n, off=7):
    """Fully contiguous mapping: vpn -> vpn + off."""
    return make_mapping(np.arange(n, dtype=np.int64) + off)


def _golden_worlds():
    """name -> (spec, world, trace, note).  Keep every trace <= 16 long."""
    out = {}

    # base: 9 conflicting fills walk set 0 full (8 ways), the 10th access
    # revisits vpn 0 — evicted from both L1 (4-way) and L2 -> walk + evict
    m = _identity(2048)
    tr = [128 * i for i in range(9)] + [0]
    out["base-evict-chain"] = (
        base_spec(), m, tr,
        "L2 set 0 (vpn & 127 == 0) receives 9 fills; the 10th access "
        "(vpn 0) must walk again and evict")

    # thp: vpns 0..511 are one PA-aligned huge run (2MB entry), 1024+ is
    # scattered 4KB; first touch walks, later touches hit the 2MB L1
    ppn = np.full(2048, -1, np.int64)
    ppn[:512] = np.arange(512) + 512          # 512-aligned base: huge-ok
    ppn[1024:1032] = [5000, 4000, 3000, 2000, 1000, 900, 800, 700]
    m = make_mapping(ppn)
    tr = [0, 100, 200, 300, 1024, 1025, 1024, 400]
    out["thp-huge-vs-4k"] = (
        thp_spec(), m, tr,
        "one walk installs the 2MB entry serving vpns 0..511 via the huge "
        "L1; the scattered 4KB pages walk individually")

    # colt: contiguity within each 8-page cache-line window; one walk
    # coalesces the window, the rest of the window hits it
    ppn = np.full(256, -1, np.int64)
    ppn[0:8] = np.arange(8) + 40              # one full window
    ppn[16:20] = np.arange(4) + 80            # partial window
    m = make_mapping(ppn)
    tr = [0, 1, 7, 2, 16, 17, 18, 19, 3]
    out["colt-window"] = (
        colt_spec(), m, tr,
        "walk at vpn 0 installs the coalesced 8-PTE window; vpns 1,7,2 "
        "hit it (L2 coalesced); the 4-page window behaves alike")

    # cluster: an 8-page VA window whose pages map into one aligned
    # physical cluster -> the side TLB's bitmap serves the window
    ppn = np.full(256, -1, np.int64)
    ppn[0:8] = [16, 17, 18, 19, 20, 21, 22, 23]   # same cluster (>>3 == 2)
    ppn[8:16] = [100, 31, 102, 33, 104, 35, 106, 37]  # mixed clusters
    m = make_mapping(ppn)
    tr = [0, 1, 2, 3, 8, 9, 10, 4]
    out["cluster-bitmap"] = (
        cluster_spec(), m, tr,
        "vpns 0..7 share one physical cluster: the first walk installs "
        "the clustered entry, later pages side-hit it")

    # rmm: one long run; the first walk installs the 64-page range, every
    # other page of the run range-hits (side) instead of walking
    m = _identity(256, off=100)
    tr = [10, 11, 12, 40, 60, 5, 200, 201]
    out["rmm-range"] = (
        rmm_spec(), m, tr,
        "walk at vpn 10 installs the full [0,256) range; every later "
        "first-touch range-hits the side TLB")

    # anchor(d=16): anchors at 16-aligned vpns; an access walks, installs
    # the anchor entry covering its 16-window, neighbours hit it
    m = _identity(512, off=3)
    tr = [5, 6, 15, 4, 33, 34, 47, 7]
    out["anchor-d16"] = (
        anchor_spec(4), m, tr,
        "walk at vpn 5 installs anchor 0 (contig 16); vpns 6,15,4 hit it; "
        "vpn 33 installs anchor 32")

    # kaligned with predictor: mixed contiguity (one 64-run, one 16-run);
    # the predictor starts at k=6, mispredicts on the 16-run until it
    # retrains (probes counted)
    ppn = np.full(256, -1, np.int64)
    ppn[0:64] = np.arange(64) + 300           # k=6-coverable run
    ppn[128:144] = np.arange(16) + 600        # k=4-coverable run
    m = make_mapping(ppn)
    tr = [0, 1, 63, 128, 129, 130, 2, 143]
    out["kaligned-pred"] = (
        kaligned_spec([6, 4]), m, tr,
        "walks at 0 and 128 install k=6 and k=4 entries; accesses under "
        "the wrong predicted class pay an extra probe")

    # kaligned without predictor: fixed probe order K descending
    out["kaligned-nopred"] = (
        kaligned_spec([6, 4], use_predictor=False, name="ka-nopred"),
        m, tr,
        "same world, static probe order: k=6 then k=4 every time")

    # subregion: one 16-page window holding TWO delta-runs plus a hole.
    # The walk at vpn 0 installs an entry whose bitmap covers only the
    # pages delta-equal with vpn 0 (0..9); vpn 12's different delta is a
    # bitmap MISS -> second walk, second way, same set/tag.  vpn 32 is a
    # singleton window (contig 1 -> classified as a regular hit on probe).
    ppn = np.full(64, -1, np.int64)
    ppn[0:10] = np.arange(10) + 40            # delta +40 run
    ppn[12:16] = np.arange(4) + 200           # delta +188 run, same window
    ppn[32] = 999                             # singleton window
    m = make_mapping(ppn)
    tr = [0, 1, 9, 12, 13, 32, 5, 15]
    out["subregion-bitmap"] = (
        subregion_spec(), m, tr,
        "walk at vpn 0 installs window-0 entry with bitmap over vpns 0..9; "
        "1,9,5 hit its bitmap; vpn 12's delta differs -> bitmap miss, "
        "second walk fills a second way of the same window; the singleton "
        "window at 32 probes as a regular (contig=1) hit")

    # cache-tlb: the base evict-chain world; the 9th conflicting fill
    # evicts vpn 0 from L2 INTO the cache-backed tier (Victima move), so
    # the refault at vpn 0 hits the cache tier instead of walking
    m = _identity(2048)
    tr = [128 * i for i in range(9)] + [0]
    out["cache-tlb-victima"] = (
        cache_tlb_spec(), m, tr,
        "same evict chain as base-evict-chain, but the L2 victim (vpn 0) "
        "drops into the cache-backed tier; the 10th access side-hits it "
        "at L2-cache latency instead of walking")

    # dead-protect: vpns 0,16,32,48,64 alias L1 set 0 (4-way) and all have
    # dead-predictor counter 0 -> every first touch walks AND BYPASSES the
    # L2 fill.  vpn 0's second touch (evicted from L1 by the chain) must
    # walk AGAIN — its first fill was bypassed — and this time (ctr=1)
    # fills; vpn 0's third touch hits the L1 refill.
    m = _identity(2048)
    tr = [0, 16, 32, 48, 64, 0, 16, 0]
    out["dead-protect-bypass"] = (
        dead_protect_spec(), m, tr,
        "5 cold walks all bypass their L2 fill (ctr=0); the refaults at "
        "vpns 0 and 16 walk a second time and fill under ctr=1; the final "
        "access hits the refill")

    # multi-tenant, both policies: tenants A (contiguous) and B (stride-2)
    # alternate, then tenant C RECYCLES tenant A's ASID.  Under flush every
    # switch wipes; under tag A's entries survive B's quantum but C's
    # takeover of ASID 0 must targeted-flush A's leftovers.
    ta = _identity(64, off=1000)
    tb = make_mapping(np.arange(64, dtype=np.int64) * 2 + 2000)
    tc = _identity(64, off=3000)
    mt = build_multitenant_mapping(
        [ta, tb, tc],
        [(0, 0, 0), (4, 1, 1), (8, 0, 0), (12, 2, 0)], name="mt-golden")
    tr = [0, 1, 2, 3] * 4
    for policy in ("flush", "tag"):
        out[f"multitenant-{policy}"] = (
            dataclasses.replace(base_spec(), ctx_policy=policy), mt, tr,
            "A,B,A,C quanta over vpns 0..3; C recycles A's ASID 0 — "
            f"ctx_policy={policy}: tag keeps A resident across B's "
            "quantum but must invalidate A's entries at C's takeover; "
            "flush refaults every quantum")

    # nested, both coherence policies: ONE guest whose OWN epoch at t=6
    # remaps vpns 16..19 (vpn 0's entry survives that turnover — the dirty
    # set misses it) and then a HOST remap at t=10 moves frames 0..3, which
    # kills vpn 0's composed entry even though the guest table never
    # changed it.  The two goldens share world AND trace, so their diff is
    # exactly the coh_policy cost model: identical walks/hits/shootdowns,
    # cycles apart by LAT_SHOOTDOWN per dirty turnover.
    guest = build_dynamic_mapping(
        np.arange(32, dtype=np.int64),
        [(6, [MappingEvent("remap", 16, 4, ppn=40)])], name="g")
    host = build_dynamic_mapping(
        np.arange(48, dtype=np.int64),
        [(10, [MappingEvent("remap", 0, 4, ppn=50)])], name="h")
    nw = build_nested_mapping([guest], host, [(0, 0, 0)], name="nested")
    tr = [0, 0, 1, 16, 0, 16, 0, 16, 0, 1, 0, 0, 1, 16]
    out["nested-host-remap"] = (
        base_spec(), nw, tr,
        "guest epoch at t=6 dirties only vpns 16..19, so vpn 0 hits "
        "across it; the host remap of frames 0..3 at t=10 then forces "
        "vpn 0 (and 1) to walk again to host frames 50/51 while vpn 16 "
        "(guest frame 40) survives untouched")
    out["nested-coherence-vs-shootdown"] = (
        dataclasses.replace(base_spec(), coh_policy="hw-coherence"), nw, tr,
        "same world and trace as nested-host-remap under hw-coherence: "
        "the SAME entries die at both turnovers (walk sequence and "
        "shootdown counts bit-equal) but no IPI latency is charged — "
        "cycles differ by exactly LAT_SHOOTDOWN per dirty turnover")

    # nested + multi-tenant combined: a host epoch lands INSIDE a VM
    # quantum.  Tagged entries survive the VM switches, but the host remap
    # at t=8 (during B's quantum) moves A's frames 0..3, dirtying guest
    # vpns 0..3 — and the shootdown is VPN-keyed and ASID-blind
    # (conservative), so B's resident entries for the same vpns die too
    # even though B's frames never moved.
    ga = make_mapping(np.arange(16, dtype=np.int64), name="ga")
    gb = make_mapping(np.arange(16, dtype=np.int64) + 16, name="gb")
    host = build_dynamic_mapping(
        np.arange(40, dtype=np.int64),
        [(8, [MappingEvent("remap", 0, 4, ppn=60)])], name="h2")
    nw2 = build_nested_mapping(
        [ga, gb], host, [(0, 0, 0), (4, 1, 1), (12, 0, 0)],
        name="nested-mt")
    tr = [0, 1, 0, 1, 0, 1, 2, 3, 0, 1, 2, 3, 0, 1, 0, 2]
    out["nested-mt-combined"] = (
        dataclasses.replace(base_spec(), ctx_policy="tag"), nw2, tr,
        "A runs vpns 0/1, B runs 0..3 (frames 16+); the host moves A's "
        "frames 0..3 at t=8 inside B's quantum, so the VPN-keyed "
        "shootdown refaults B's second quantum AND, back on A at t=12, "
        "A's tagged entries — vpns 0/1 walk to frames 60/61")
    return out


def _world_json(world):
    from repro.core.page_table import (Mapping, MultiTenantMapping,
                                      NestedMapping)

    def layer(d):
        return {"boundaries": list(d.boundaries),
                "epochs": [m.ppn.tolist() for m in d.epochs]}

    if isinstance(world, NestedMapping):
        return {"kind": "nested",
                "guests": [layer(g) for g in world.guests],
                "host": layer(world.host),
                "boundaries": list(world.boundaries),
                "guest_ids": list(world.guest_ids),
                "asids": list(world.asids)}
    if isinstance(world, MultiTenantMapping):
        return {"kind": "multitenant",
                "tenants": [t.ppn.tolist() for t in world.tenants],
                "boundaries": list(world.boundaries),
                "tenant_ids": list(world.tenant_ids),
                "asids": list(world.asids)}
    assert isinstance(world, Mapping)
    return {"kind": "static", "ppn": world.ppn.tolist()}


def _spec_json(spec):
    d = dataclasses.asdict(spec)
    d["K"] = list(d["K"])
    return d


def make_golden(name, spec, world, trace, note):
    from repro.core.page_table import MultiTenantMapping, NestedMapping
    trace = np.asarray(trace, np.int64)
    assert trace.shape[0] <= 16, f"{name}: goldens must stay hand-checkable"
    steps, events = [], []
    if isinstance(world, NestedMapping):
        runner = run_method_nested
    elif isinstance(world, MultiTenantMapping):
        runner = run_method_multitenant
    else:
        runner = run_method_dynamic
    r = runner(spec, world, trace, on_step=steps.append,
               on_event=events.append)
    return {
        "name": name,
        "note": note,
        "spec": _spec_json(spec),
        "world": _world_json(world),
        "trace": trace.tolist(),
        "steps": steps,
        "events": events,
        "final": {f: int(getattr(r, f)) for f in FINAL_FIELDS}
        | {"coverage_mean": float(r.coverage_mean)},
    }


def main():
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    for name, (spec, world, trace, note) in _golden_worlds().items():
        g = make_golden(name, spec, world, trace, note)
        path = os.path.join(GOLDEN_DIR, f"{name}.json")
        with open(path, "w") as f:
            json.dump(g, f, indent=1)
            f.write("\n")
        levels = [s["level"] for s in g["steps"]]
        print(f"{name:22s} walks={g['final']['walks']:2d} "
              f"shoot={g['final']['shootdowns']:3d} levels={levels}")


if __name__ == "__main__":
    main()
