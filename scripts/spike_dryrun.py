"""Spike: verify 512 fake CPU devices, mesh creation, AOT lower/compile,
cost_analysis / memory_analysis availability, compile wall-time."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import time
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

print("n_devices:", jax.device_count())

t0 = time.time()
mesh_mp = jax.make_mesh((2, 16, 16), ("pod", "data", "model"))
print("multi-pod mesh ok", time.time() - t0)

# Single-pod mesh must use a subset of devices.
devs = jax.devices()[:256]
import numpy as np
mesh_sp = jax.sharding.Mesh(np.array(devs).reshape(16, 16), ("data", "model"))
print("single-pod mesh ok")

D, F, V, L = 1024, 4096, 32000, 4
B, S = 64, 1024


def init_params():
    return {
        "emb": jnp.zeros((V, D), jnp.bfloat16),
        "layers": {
            "wqkv": jnp.zeros((L, D, 3 * D), jnp.bfloat16),
            "wo": jnp.zeros((L, D, D), jnp.bfloat16),
            "w1": jnp.zeros((L, D, F), jnp.bfloat16),
            "w2": jnp.zeros((L, F, D), jnp.bfloat16),
        },
        "out": jnp.zeros((D, V), jnp.bfloat16),
    }


def fwd(params, tokens):
    x = params["emb"][tokens]

    def layer(x, w):
        qkv = jnp.einsum("bsd,de->bse", x, w["wqkv"])
        q, k, v = jnp.split(qkv, 3, axis=-1)
        nh = 8
        q = q.reshape(B, S, nh, D // nh)
        k = k.reshape(B, S, nh, D // nh)
        v = v.reshape(B, S, nh, D // nh)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(D // nh)
        mask = jnp.tril(jnp.ones((S, S), bool))
        att = jnp.where(mask, att, -1e9)
        att = jax.nn.softmax(att.astype(jnp.float32), axis=-1).astype(x.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(B, S, D)
        x = x + jnp.einsum("bsd,de->bse", o, w["wo"])
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, w["w1"]))
        x = x + jnp.einsum("bsf,fd->bsd", h, w["w2"])
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(layer), x, params["layers"])
    return jnp.einsum("bsd,dv->bsv", x, params["out"])


def loss_fn(params, tokens, labels):
    logits = fwd(params, tokens).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[..., None], axis=-1))


def train_step(params, tokens, labels):
    loss, grads = jax.value_and_grad(loss_fn)(params, tokens, labels)
    params = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype), params, grads)
    return params, loss


def run(mesh, tag):
    axes = mesh.axis_names
    data_ax = tuple(a for a in axes if a in ("pod", "data"))
    data_ax = data_ax if len(data_ax) > 1 else data_ax[0]
    pspec_params = {
        "emb": P("model", None),
        "layers": {
            "wqkv": P(None, data_ax, "model"),
            "wo": P(None, "model", data_ax),
            "w1": P(None, data_ax, "model"),
            "w2": P(None, "model", data_ax),
        },
        "out": P(None, "model"),
    }
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec_params,
                             is_leaf=lambda x: isinstance(x, P))
    tok_sh = NamedSharding(mesh, P(data_ax, None))
    params_s = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        init_params(), shardings)
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=tok_sh)
    lab = jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=tok_sh)

    t0 = time.time()
    lowered = jax.jit(train_step).lower(params_s, tok, lab)
    t1 = time.time()
    print(f"[{tag}] lower: {t1-t0:.1f}s")
    compiled = lowered.compile()
    t2 = time.time()
    print(f"[{tag}] compile: {t2-t1:.1f}s")
    ca = compiled.cost_analysis()
    print(f"[{tag}] cost_analysis type={type(ca)}")
    if isinstance(ca, list):
        ca = ca[0] if ca else {}
    if hasattr(ca, "items"):
        items = {k: v for k, v in ca.items() if "flops" in k or "bytes" in k}
        print(f"[{tag}] cost keys sample:", dict(list(items.items())[:8]))
    ma = compiled.memory_analysis()
    print(f"[{tag}] memory_analysis:", ma)
    txt = compiled.as_text()
    import re
    colls = re.findall(r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)", txt)
    from collections import Counter
    print(f"[{tag}] collectives:", Counter(colls))
    print(f"[{tag}] hlo len: {len(txt)}")


with mesh_sp:
    run(mesh_sp, "single-pod-256")
with mesh_mp:
    run(mesh_mp, "multi-pod-512")
print("SPIKE OK")
