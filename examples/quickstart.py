"""Quickstart: the paper's technique in 60 lines.

1. Build a mixed-contiguity memory mapping (the paper's §2 observation).
2. Run Algorithm 3 to determine K.
3. Simulate Base vs Anchor vs K-bit Aligned TLB and compare misses.
4. Same idea on the TPU side: a fragmented KV pool, Algorithm-3-chosen DMA
   classes, and the descriptor reduction the coalesced kernel achieves.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (anchor_static, base_spec, contiguity_histogram,
                        determine_k, generate_trace, kaligned_for_mapping,
                        run_method, synthetic_mapping)
from repro.kvcache.allocator import PagedKVAllocator
from repro.kvcache.block_table import choose_kernel_classes, dma_descriptor_count

# --- 1. a mixed-contiguity mapping (0.4 small + 0.4 medium + 0.2 large) ---
m = synthetic_mapping("mixed", n_pages=1 << 18, seed=0)
hist = contiguity_histogram(m)
print(f"mapping: {m.n_pages} pages, {sum(hist.values())} contiguity chunks")

# --- 2. Algorithm 3 ---
K = determine_k(hist)
print(f"Algorithm 3 chose K = {K}")

# --- 3. TLB simulation ---
trace = generate_trace("multiscale", 0, 120_000, seed=1, mapping=m)
base = run_method(base_spec(), m, trace)
anchor = anchor_static(m, trace, grid=(6, 8, 10))
ka = run_method(kaligned_for_mapping(m, psi=3), m, trace)
print(f"TLB misses   Base: {base.walks}   Anchor-Static: {anchor.walks}   "
      f"K-Aligned: {ka.walks}")
print(f"K-Aligned reduces misses {1 - ka.walks / base.walks:.1%} vs Base, "
      f"{1 - ka.walks / anchor.walks:.1%} vs Anchor")

# --- 4. the TPU adaptation: coalesced KV-cache DMA ---
alloc = PagedKVAllocator(num_pages=1024)
for i in range(120):                      # serving churn → mixed contiguity
    alloc.allocate(i, int(np.random.default_rng(i).integers(2, 24)))
for i in range(0, 120, 3):
    alloc.free(i)
alloc.allocate(999, 64)
tables = np.stack([alloc.block_table(rid, 64)
                   for rid in alloc.seqs if rid >= 60])
Kc = choose_kernel_classes(alloc.contiguity_histogram(), psi=3)
st = dma_descriptor_count(tables, Kc)
print(f"\nKV pool: kernel classes K = {Kc}")
print(f"DMA descriptors: page-granular {st['descriptors_page_granular']} → "
      f"coalesced {st['descriptors_coalesced']} "
      f"({st['reduction']:.1%} fewer)")
