"""End-to-end serving driver: batched requests through the paged engine.

The paper's kind is memory-system efficiency at serving time, so this is the
flagship e2e driver: a small LM served with continuous batching, a buddy
paged KV cache, Algorithm-3-chosen coalescing classes and the coalesced
Pallas paged-attention kernel (interpret mode on CPU).

Run:  PYTHONPATH=src python examples/serve_paged.py [--requests 8]
"""
import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.models import Model, RunConfig
from repro.serve import EngineConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--arch", default="internlm2-1.8b")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    rc = RunConfig(attn_q_chunk=32, attn_kv_chunk=32, scan_chunk=16)
    model = Model(cfg, rc)
    params = model.init(0)

    ec = EngineConfig(page_size=8, num_pages=256, max_batch=4, max_seq=128,
                      interpret=True)
    engine = ServingEngine(model, params, ec)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        prompt = list(rng.integers(0, cfg.vocab,
                                   size=int(rng.integers(8, 48))))
        rid = engine.add_request(prompt, max_new_tokens=args.max_new)
        print(f"request {rid}: prompt len {len(prompt)}")

    metrics = engine.run_to_completion()
    dt = time.time() - t0

    print(f"\nserved {args.requests} requests in {metrics['steps']} engine "
          f"steps ({dt:.1f}s wall, interpret mode)")
    print(f"kernel classes K = {metrics['K']} (Algorithm 3 on the live "
          f"contiguity histogram)")
    print(f"DMA descriptors: {metrics['dma_descriptors']:.0f} coalesced vs "
          f"{metrics['dma_descriptors_page_granular']:.0f} page-granular "
          f"→ {metrics['descriptor_reduction']:.1%} reduction")
    for rid, req in sorted(engine.requests.items()):
        print(f"  req {rid}: {req.state}, generated {len(req.generated)} "
              f"tokens: {req.generated[:6]}…")


if __name__ == "__main__":
    main()
