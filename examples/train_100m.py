"""Train a ~100M-parameter dense LM for a few hundred steps (e2e driver).

Exercises the full training substrate: data pipeline, chunked-loss model,
AdamW, async checkpointing with auto-resume, straggler watchdog.

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 200]
(~100M params on CPU: expect a few seconds/step; use --steps 20 for a smoke.)
"""
import argparse
import time

from repro.data import DataPipeline, PipelineConfig
from repro.models import Model, ModelConfig, RunConfig
from repro.optim import OptConfig
from repro.train import Trainer, TrainerConfig

# ~126M params: 12L, d=768, 12H, ff=3072, vocab=16384 (tied embeddings)
CONFIG_100M = ModelConfig(
    name="lm-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=12, head_dim=64, d_ff=3072, vocab=16384, tie_embeddings=True,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    args = ap.parse_args()

    rc = RunConfig(attn_q_chunk=128, attn_kv_chunk=256)
    model = Model(CONFIG_100M, rc)
    n = CONFIG_100M.param_count()
    print(f"model: {CONFIG_100M.name}, {n/1e6:.1f}M params")

    oc = OptConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    tc = TrainerConfig(total_steps=args.steps, ckpt_every=50,
                       ckpt_dir=args.ckpt_dir, log_every=10)
    pipe = DataPipeline(CONFIG_100M, PipelineConfig(batch=args.batch,
                                                    seq=args.seq))
    trainer = Trainer(model, oc, tc, pipe)

    t0 = time.time()
    out = trainer.run()
    dt = time.time() - t0
    logs = out["metrics"]
    print(f"\ntrained {args.steps} steps in {dt/60:.1f} min "
          f"({args.batch * args.seq * args.steps / dt:.0f} tok/s)")
    print(f"loss: {logs[0]['loss']:.3f} → {logs[-1]['loss']:.3f}")
    if out["stragglers"]:
        print(f"straggler steps flagged: {len(out['stragglers'])}")


if __name__ == "__main__":
    main()
