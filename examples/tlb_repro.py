"""Reproduce the paper's main comparison (Figure 8 / Table 4) end to end.

Runs the full method suite (Base, THP, RMM, COLT, Cluster, Anchor-Static,
|K|=2/3/4 Aligned) over demand-paged and synthetic mappings and prints the
relative-miss tables next to the paper's published numbers.

Run:  PYTHONPATH=src python examples/tlb_repro.py [--quick]

With ``--scenario NAME`` it instead sweeps the full suite over any scenario
from the registry (``python -c "import repro.scenarios as s; print([x.name
for x in s.list_scenarios()])"`` lists them) and prints its contiguity
histogram next to the relative misses — e.g. ``--scenario kv-churn`` runs
the paper's comparison on the repo's own KV-cache serving workload.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))    # repo root, for the benchmarks package

from benchmarks.tlb_suite import (ANCHOR_GRID_QUICK, SweepPlan,  # noqa: E402
                                  _add_suite, bench_demand, bench_synthetic)
from repro.core.page_table import contiguity_histogram  # noqa: E402
from repro.scenarios import get_scenario, list_scenarios  # noqa: E402

PAPER_TABLE4 = {
    # mapping: {method: relative misses}  (paper Table 4)
    "small":  {"THP": 1.00, "RMM": 0.992, "COLT": 0.605, "Cluster": 0.55,
               "Anchor-Static": 0.453, "|K|=2": 0.359, "|K|=3": 0.334,
               "|K|=4": 0.312},
    "medium": {"THP": 1.00, "RMM": 0.993, "COLT": 0.561, "Cluster": 0.523,
               "Anchor-Static": 0.334, "|K|=2": 0.25, "|K|=3": 0.204,
               "|K|=4": 0.174},
    "large":  {"THP": 0.456, "RMM": 0.451, "COLT": 0.34, "Cluster": 0.382,
               "Anchor-Static": 0.103, "|K|=2": 0.064, "|K|=3": 0.043,
               "|K|=4": 0.039},
    "mixed":  {"THP": 0.812, "RMM": 0.724, "COLT": 0.563, "Cluster": 0.532,
               "Anchor-Static": 0.605, "|K|=2": 0.25, "|K|=3": 0.132,
               "|K|=4": 0.056},
}


def run_scenario(name: str, n_pages: int, trace_len: int) -> None:
    """Full method suite over one registered scenario."""
    sc = get_scenario(name)
    data = sc.materialize(n_pages=n_pages, trace_len=trace_len, trace_seed=8)
    print(f"=== scenario {name} ({sc.family}) ===")
    print(f"  {sc.description}")
    print(f"  expected contiguity: {sc.contiguity}")
    hist = data.meta.get("contiguity_histogram") or \
        contiguity_histogram(data.mapping)
    top = sorted(hist.items(), key=lambda kv: -kv[0] * kv[1])[:8]
    print("  contiguity histogram (size×count, by covered pages): "
          + "  ".join(f"{s}×{f}" for s, f in top))
    plan = SweepPlan()
    # dynamic scenarios sweep the live DynamicMapping (epoch-segmented
    # lanes with shootdowns); K is still chosen from the epoch-0 snapshot
    _add_suite(plan, data.world, data.trace, name, ANCHOR_GRID_QUICK,
               k_mapping=data.mapping)
    cols = plan.run()[name]
    base = max(cols["Base"].walks, 1)
    dynamic = data.dynamic is not None
    print("  relative misses vs Base:")
    for label, r in cols.items():
        extra = f"  shootdowns {r.shootdowns}" if dynamic else ""
        print(f"    {label:14s} {r.walks / base:6.3f}   "
              f"(cpi {r.cpi:.2f}){extra}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--scenario", metavar="NAME",
                    help="sweep one registered scenario instead of the "
                         "paper tables ('list' to enumerate)")
    args = ap.parse_args()
    n = 1 << 18 if args.quick else 1 << 19
    tl = 100_000 if args.quick else 200_000

    if args.scenario == "list":
        for sc in list_scenarios():
            print(f"{sc.name:18s} [{sc.family}] {sc.description}")
        return
    if args.scenario:
        run_scenario(args.scenario,
                     n_pages=1 << 16 if args.quick else 1 << 17,
                     trace_len=tl)
        return

    print("=== Table 4, synthetic mappings (ours vs paper) ===")
    rows = bench_synthetic(trace_len=tl, n_pages=n)
    for r in rows:
        kind = r["mapping"]
        print(f"\n[{kind}]")
        for meth, paper in PAPER_TABLE4[kind].items():
            ours = r.get(meth)
            print(f"  {meth:14s} ours={ours:6.3f}   paper={paper:6.3f}")

    print("\n=== Figure 8, demand mapping (benchmark analogues) ===")
    for r in bench_demand(trace_len=tl):
        print(f"  {r['benchmark']:12s} " + "  ".join(
            f"{k}={v}" for k, v in r.items() if k != "benchmark"))


if __name__ == "__main__":
    main()
