"""Reproduce the paper's main comparison (Figure 8 / Table 4) end to end.

Runs the full method suite (Base, THP, RMM, COLT, Cluster, Anchor-Static,
|K|=2/3/4 Aligned) over demand-paged and synthetic mappings and prints the
relative-miss tables next to the paper's published numbers.

Run:  PYTHONPATH=src python examples/tlb_repro.py [--quick]
"""
import argparse

from benchmarks.tlb_suite import bench_demand, bench_synthetic

PAPER_TABLE4 = {
    # mapping: {method: relative misses}  (paper Table 4)
    "small":  {"THP": 1.00, "RMM": 0.992, "COLT": 0.605, "Cluster": 0.55,
               "Anchor-Static": 0.453, "|K|=2": 0.359, "|K|=3": 0.334,
               "|K|=4": 0.312},
    "medium": {"THP": 1.00, "RMM": 0.993, "COLT": 0.561, "Cluster": 0.523,
               "Anchor-Static": 0.334, "|K|=2": 0.25, "|K|=3": 0.204,
               "|K|=4": 0.174},
    "large":  {"THP": 0.456, "RMM": 0.451, "COLT": 0.34, "Cluster": 0.382,
               "Anchor-Static": 0.103, "|K|=2": 0.064, "|K|=3": 0.043,
               "|K|=4": 0.039},
    "mixed":  {"THP": 0.812, "RMM": 0.724, "COLT": 0.563, "Cluster": 0.532,
               "Anchor-Static": 0.605, "|K|=2": 0.25, "|K|=3": 0.132,
               "|K|=4": 0.056},
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    n = 1 << 18 if args.quick else 1 << 19
    tl = 100_000 if args.quick else 200_000

    print("=== Table 4, synthetic mappings (ours vs paper) ===")
    rows = bench_synthetic(trace_len=tl, n_pages=n)
    for r in rows:
        kind = r["mapping"]
        print(f"\n[{kind}]")
        for meth, paper in PAPER_TABLE4[kind].items():
            ours = r.get(meth)
            print(f"  {meth:14s} ours={ours:6.3f}   paper={paper:6.3f}")

    print("\n=== Figure 8, demand mapping (benchmark analogues) ===")
    for r in bench_demand(trace_len=tl):
        print(f"  {r['benchmark']:12s} " + "  ".join(
            f"{k}={v}" for k, v in r.items() if k != "benchmark"))


if __name__ == "__main__":
    main()
