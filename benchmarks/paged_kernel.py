"""TPU-adaptation benchmarks: DMA-descriptor model for the coalesced kernel.

The paper's metric is TLB misses; the TPU analogue is HBM DMA descriptors
issued per decode step.  We measure (a) descriptor-count reduction as a
function of pool fragmentation, (b) the modeled decode-attention memory time
t = bytes/BW + n_desc * t_issue (v5e: 819 GB/s, ~1 µs effective per
descriptor chain on the sparse-core/DMA path), and (c) the serving engine's
end-to-end descriptor metrics with Algorithm-3-chosen K.

(b) is a cost model, not a wall-clock measurement — this container has no
TPU.  Kernel correctness is interpret-mode-validated in tests/test_kernels.
"""
from __future__ import annotations

import time

import numpy as np

from repro.kvcache.allocator import PagedKVAllocator
from repro.kvcache.block_table import choose_kernel_classes, dma_descriptor_count

HBM_BW = 819e9
T_DESC = 1e-6          # effective per-descriptor issue cost (conservative)


def _fragmented_tables(frag: float, B: int, pages_per_seq: int,
                       n_pages: int, seed: int = 0):
    """Pool with tunable fragmentation.

    Fill the pool with single-page holders, then free ~60% of it: a
    ``1-frag`` share as aligned 64-page runs (buddy-coalescible → large
    contiguity) and a ``frag`` share as every-other singles whose buddies
    stay in use (the paper's §2 fragmentation mechanism).  New sequences
    then allocate from whatever contiguity survives.
    """
    rng = np.random.default_rng(seed)
    alloc = PagedKVAllocator(n_pages)
    for i in range(n_pages):
        alloc.allocate(20_000 + i, 1)
    n_free = int(0.6 * n_pages)
    freed = 0
    run = 64
    # contiguous component at two scales (64-page and 16-page runs), so the
    # surviving contiguity is MIXED — the regime Algorithm 3 targets
    n64 = int((1 - frag) * n_free / 2 / 64)
    n16 = int((1 - frag) * n_free / 2 / 16)
    if n64 + n16:
        starts = rng.choice(n_pages // run, size=min(n64 + n16,
                                                     n_pages // run),
                            replace=False) * run
        for idx, s in enumerate(starts):
            span = 64 if idx < n64 else 16
            for j in range(span):
                alloc.free(20_000 + s + j)
            freed += span
    i = 0
    while freed < n_free and i < n_pages:
        rid = 20_000 + i
        if rid in alloc.seqs:
            alloc.free(rid)
            freed += 1
        i += 2
    tables = []
    for b in range(B):
        if alloc.allocate(b, pages_per_seq) is None:
            break
        tables.append(alloc.block_table(b, pages_per_seq))
    return np.stack(tables) if tables else np.zeros((0, 1), np.int64), alloc


def bench_dma_vs_fragmentation(B=24, pages_per_seq=64, page_size=64,
                               kv_bytes_per_page=64 * 8 * 128 * 2 * 2):
    """Descriptor reduction and modeled decode memory time vs fragmentation."""
    rows = []
    for frag in (0.0, 0.25, 0.5, 0.75, 1.0):
        bt, alloc = _fragmented_tables(frag, B, pages_per_seq, 4096,
                                       seed=int(frag * 10))
        if bt.shape[0] == 0:
            continue
        hist = alloc.contiguity_histogram()
        K = choose_kernel_classes(hist, psi=3)
        st = dma_descriptor_count(bt, K)
        bytes_total = st["pages"] * kv_bytes_per_page
        t_base = bytes_total / HBM_BW + st["descriptors_page_granular"] * T_DESC
        t_coal = bytes_total / HBM_BW + st["descriptors_coalesced"] * T_DESC
        rows.append({
            "fragmentation": frag, "K": str(K),
            "pages": st["pages"],
            "desc_base": st["descriptors_page_granular"],
            "desc_coalesced": st["descriptors_coalesced"],
            "desc_reduction": round(st["reduction"], 4),
            "t_model_base_us": round(t_base * 1e6, 1),
            "t_model_coalesced_us": round(t_coal * 1e6, 1),
            "speedup": round(t_base / t_coal, 3),
        })
    return rows


def bench_kernel_classes_ablation(B=24, pages_per_seq=64):
    """|K| ablation on a mixed pool (paper Fig 9, kernel edition)."""
    bt, alloc = _fragmented_tables(0.75, B, pages_per_seq, 4096, seed=3)
    hist = alloc.contiguity_histogram()
    rows = []
    for psi in (1, 2, 3, 4):
        K = choose_kernel_classes(hist, psi=psi, theta=1.0)
        st = dma_descriptor_count(bt, K)
        rows.append({"psi": psi, "K": str(K),
                     "desc_reduction": round(st["reduction"], 4)})
    return rows


def bench_engine_end_to_end(quick=True):
    """Serving engine: tokens/step metrics with the real model + kernel
    (interpret mode — correctness path timing, not TPU wall time)."""
    from repro.configs import get_config
    from repro.models import Model, RunConfig
    from repro.serve import EngineConfig, ServingEngine

    cfg = get_config("internlm2-1.8b", reduced=True)
    rc = RunConfig(attn_q_chunk=32, attn_kv_chunk=32, scan_chunk=16)
    model = Model(cfg, rc)
    params = model.init(0)
    rows = []
    for policy in ("buddy_best", "page"):
        ec = EngineConfig(page_size=8, num_pages=256, max_batch=4,
                          max_seq=128, interpret=True, alloc_policy=policy)
        eng = ServingEngine(model, params, ec)
        rng = np.random.default_rng(0)
        for i in range(6):
            eng.add_request(list(rng.integers(0, cfg.vocab, size=24)),
                            max_new_tokens=4)
        t0 = time.time()
        m = eng.run_to_completion()
        rows.append({"alloc_policy": policy, "K": str(m["K"]),
                     "tokens": m["tokens"],
                     "desc_reduction": round(m["descriptor_reduction"], 4),
                     "wall_s": round(time.time() - t0, 1)})
    return rows
