"""Benchmark harness entry point: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract) followed by
the full result tables; writes results/benchmarks.json.

    PYTHONPATH=src python -m benchmarks.run [--full | --smoke]

Tiers:

* default — the quick suite (8 benchmarks, 120-150k-access traces)
* ``--full`` — all 16 benchmarks, long traces
* ``--smoke`` — tiny traces and footprints, TLB benches only; exercises the
  whole batched-sweep path end-to-end in seconds (the CI tier).  With
  ``--budget-s N`` the run exits non-zero if it exceeds the time budget.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Any, Dict, List

from . import _env  # noqa: F401  (must precede jax-importing modules)
from . import chaos, paged_kernel, roofline_summary, tlb_suite
from repro.core.sweep import resolve_backend
from repro.scenarios import clear_materialized_cache

SMOKE_TRACE_LEN = 4096
SMOKE_MAX_PAGES = 1 << 15


def _fmt_table(rows: List[Dict[str, Any]]) -> str:
    if not rows:
        return "(empty)"
    cols = list(rows[0].keys())
    w = {c: max(len(str(c)), *(len(str(r.get(c, ""))) for r in rows))
         for c in cols}
    out = ["  ".join(str(c).ljust(w[c]) for c in cols)]
    for r in rows:
        out.append("  ".join(str(r.get(c, "")).ljust(w[c]) for c in cols))
    return "\n".join(out)


BENCHES: List = [
    # (name, paper artifact, fn)
    ("tlb_synthetic", "Table 4 (synth) / Fig 1", tlb_suite.bench_synthetic),
    ("tlb_demand", "Figure 8 / Table 4 (real)", tlb_suite.bench_demand),
    ("tlb_coverage", "Table 5", tlb_suite.bench_coverage),
    ("tlb_predictor", "Table 6", tlb_suite.bench_predictor),
    ("tlb_k_sweep", "Figure 9", tlb_suite.bench_k_sweep),
    ("tlb_cpi", "Figures 10/11", tlb_suite.bench_cpi),
    ("tlb_scenarios", "Workload-derived + adversarial scenarios (registry)",
     tlb_suite.bench_scenarios),
    ("tlb_scenario_contiguity", "Scenario contiguity (Figs 2-3 analogue)",
     tlb_suite.bench_scenario_contiguity),
    ("tlb_dynamic", "Dynamic mapping worlds: mid-trace remaps + shootdowns",
     tlb_suite.bench_dynamic),
    ("tlb_multitenant",
     "Multi-tenant address spaces: ASID tags vs flush-on-switch",
     tlb_suite.bench_multitenant),
    ("tlb_nested",
     "Nested guest→host worlds: shootdown vs hw-coherence",
     tlb_suite.bench_nested),
    ("tlb_accelerator",
     "Accelerator-scale methods: subregion / cache-TLB / dead-protect",
     tlb_suite.bench_accelerator),
    ("tlb_chaos",
     "Chaos harness: fault injection + recovery (recovered vs lost work)",
     chaos.bench_chaos),
    ("dma_fragmentation", "TPU adaptation: descriptor model",
     paged_kernel.bench_dma_vs_fragmentation),
    ("dma_k_ablation", "TPU adaptation: |K| ablation",
     paged_kernel.bench_kernel_classes_ablation),
    ("engine_end_to_end", "TPU adaptation: serving engine",
     paged_kernel.bench_engine_end_to_end),
    ("roofline_summary", "EXPERIMENTS §Roofline (from dry-run artifacts)",
     roofline_summary.bench_roofline_summary),
]


def _derived_metric(name: str, rows: List[Dict[str, Any]]) -> str:
    try:
        if name == "tlb_synthetic":
            mixed = next(r for r in rows if r["mapping"] == "mixed")
            return (f"mixed:|K|=3 rel={mixed.get('|K|=3', '')};"
                    f"anchor rel={mixed['Anchor-Static']}")
        if name == "tlb_demand":
            import numpy as np
            ks = [r["|K|=2"] for r in rows]
            an = [r["Anchor-Static"] for r in rows]
            return (f"mean |K|=2 rel={np.mean(ks):.3f};"
                    f"mean anchor rel={np.mean(an):.3f};"
                    f"reduction vs anchor="
                    f"{1 - np.mean(ks) / max(np.mean(an), 1e-9):.3f}")
        if name == "tlb_predictor":
            import numpy as np
            return "mean acc |K|=2 = {:.3f}".format(
                np.mean([r["|K|=2"] for r in rows]))
        if name == "dma_fragmentation":
            mid = rows[len(rows) // 2]
            return (f"frag=0.5: desc_red={mid['desc_reduction']},"
                    f"speedup={mid['speedup']}")
        if name == "tlb_scenarios":
            import numpy as np
            kv = next(r for r in rows if r["scenario"] == "kv-churn")
            ks = [r["|K|=2"] for r in rows]
            return (f"kv-churn:|K|=2 rel={kv.get('|K|=2', '')};"
                    f"mean |K|=2 rel={np.mean(ks):.3f} over {len(rows)}"
                    " scenarios")
        if name == "tlb_dynamic":
            rel = [r for r in rows if r["metric"] == "rel_misses"]
            sd = [r for r in rows if r["metric"] == "shootdowns"]
            import numpy as np
            return (f"mean |K|=2 rel={np.mean([r['|K|=2'] for r in rel]):.3f}"
                    f" over {len(rel)} dynamic scenarios;"
                    f" total shootdowns |K|=2="
                    f"{sum(r['|K|=2'] for r in sd)}")
        if name == "tlb_multitenant":
            import numpy as np
            rel = [r for r in rows if r["metric"] == "rel_misses"]
            tag = np.mean([r["|K|=3"] for r in rel if r["policy"] == "tag"])
            flush = np.mean([r["|K|=3"] for r in rel
                             if r["policy"] == "flush"])
            return (f"mean |K|=3 rel: tag={tag:.3f} vs flush={flush:.3f}"
                    f" over {len(rel) // 2} scenarios")
        if name == "tlb_nested":
            import numpy as np
            cyc = [r for r in rows if r["metric"] == "stall_cycles"]
            sd = np.mean([r["|K|=3"] for r in cyc
                          if r["policy"] == "shootdown"])
            hw = np.mean([r["|K|=3"] for r in cyc
                          if r["policy"] == "hw-coherence"])
            return (f"mean |K|=3 stall cycles: shootdown={sd:.0f} vs"
                    f" hw-coherence={hw:.0f}"
                    f" ({1 - hw / max(sd, 1e-9):.1%} saved)"
                    f" over {len(cyc) // 2} scenarios")
        if name == "tlb_accelerator":
            import numpy as np
            rel = [r for r in rows if r["metric"] == "rel_misses"]
            ka = np.mean([r["|K|=3"] for r in rel])
            best = min(("Subregion", "Cache-TLB", "Dead-Protect"),
                       key=lambda k: np.mean([r[k] for r in rel]))
            return (f"mean rel misses over {len(rel)} concurrencies:"
                    f" |K|=3={ka:.3f};"
                    f" best accel={best}="
                    f"{np.mean([r[best] for r in rel]):.3f}")
        if name == "engine_end_to_end":
            return f"buddy desc_red={rows[0]['desc_reduction']}"
    except Exception as e:    # derived metrics must never kill the run
        return f"derive-error:{e}"
    return ""


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    tier = ap.add_mutually_exclusive_group()
    tier.add_argument("--full", action="store_true",
                      help="all 16 benchmarks, long traces")
    tier.add_argument("--smoke", action="store_true",
                      help="tiny traces, TLB benches only (CI tier)")
    ap.add_argument("--only", help="comma list of bench names")
    ap.add_argument("--budget-s", type=float, default=None,
                    help="exit non-zero if total wall-clock exceeds this")
    ap.add_argument("--no-cache", action="store_true",
                    help="bypass the on-disk sweep cache")
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "xla", "pallas"),
                    help="sweep execution backend (results are bit-exact "
                         "across backends; 'auto' = pallas on TPU, xla "
                         "elsewhere)")
    args = ap.parse_args(argv)

    if args.no_cache:
        os.environ["REPRO_SWEEP_NO_CACHE"] = "1"
    only = set(args.only.split(",")) if args.only else None
    t_start = time.time()
    results: Dict[str, Any] = {}
    csv_lines = ["name,us_per_call,derived"]
    for name, artifact, fn in BENCHES:
        if only and name not in only:
            continue
        if args.smoke and not name.startswith("tlb_"):
            continue
        kwargs = {}
        varnames = fn.__code__.co_varnames[:fn.__code__.co_argcount]
        if "quick" in varnames:
            kwargs["quick"] = not args.full
        if "backend" in varnames:
            kwargs["backend"] = args.backend
        if args.smoke:
            if "trace_len" in varnames:
                kwargs["trace_len"] = SMOKE_TRACE_LEN
            if "max_pages" in varnames:
                kwargs["max_pages"] = SMOKE_MAX_PAGES
        t0 = time.time()
        rows = fn(**kwargs)
        dt = time.time() - t0
        # worlds are memoized per-process so one bench builds each once;
        # drop them between benches or --full retains every mapping+trace
        # (hundreds of MB) until exit.  Smoke worlds are tiny — keep them,
        # so benches sharing scenarios (tlb_scenarios /
        # tlb_scenario_contiguity) build each world once per process.
        if not args.smoke:
            clear_materialized_cache()
        results[name] = {"artifact": artifact, "rows": rows,
                         "wall_s": round(dt, 1)}
        n_calls = max(len(rows), 1)
        csv_lines.append(
            f"{name},{dt * 1e6 / n_calls:.0f},{_derived_metric(name, rows)}")
        print(f"\n=== {name}  [{artifact}]  ({dt:.1f}s) ===")
        print(_fmt_table(rows))

    total = time.time() - t_start
    print("\n--- CSV (name,us_per_call,derived) ---")
    for line in csv_lines:
        print(line)
    os.makedirs("results", exist_ok=True)
    tier_name = "smoke" if args.smoke else ("full" if args.full else "quick")
    payload = {"tier": tier_name,
               # record what actually ran ('auto' resolves per platform)
               "backend": resolve_backend(args.backend),
               "total_wall_s": round(total, 1), "sections": results}
    with open("results/benchmarks.json", "w") as f:
        json.dump(payload, f, indent=1)
    print(f"\nwrote results/benchmarks.json  (tier={tier_name}, "
          f"total {total:.1f}s)")
    if args.budget_s is not None and total > args.budget_s:
        print(f"ERROR: exceeded time budget: {total:.1f}s > "
              f"{args.budget_s:.0f}s", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
