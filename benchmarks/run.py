"""Benchmark harness entry point: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract) followed by
the full result tables; writes results/benchmarks.json.

    PYTHONPATH=src python -m benchmarks.run [--full]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any, Callable, Dict, List

from . import paged_kernel, roofline_summary, tlb_suite


def _fmt_table(rows: List[Dict[str, Any]]) -> str:
    if not rows:
        return "(empty)"
    cols = list(rows[0].keys())
    w = {c: max(len(str(c)), *(len(str(r.get(c, ""))) for r in rows))
         for c in cols}
    out = ["  ".join(str(c).ljust(w[c]) for c in cols)]
    for r in rows:
        out.append("  ".join(str(r.get(c, "")).ljust(w[c]) for c in cols))
    return "\n".join(out)


BENCHES: List = [
    # (name, paper artifact, fn)
    ("tlb_synthetic", "Table 4 (synth) / Fig 1", tlb_suite.bench_synthetic),
    ("tlb_demand", "Figure 8 / Table 4 (real)", tlb_suite.bench_demand),
    ("tlb_coverage", "Table 5", tlb_suite.bench_coverage),
    ("tlb_predictor", "Table 6", tlb_suite.bench_predictor),
    ("tlb_k_sweep", "Figure 9", tlb_suite.bench_k_sweep),
    ("tlb_cpi", "Figures 10/11", tlb_suite.bench_cpi),
    ("dma_fragmentation", "TPU adaptation: descriptor model",
     paged_kernel.bench_dma_vs_fragmentation),
    ("dma_k_ablation", "TPU adaptation: |K| ablation",
     paged_kernel.bench_kernel_classes_ablation),
    ("engine_end_to_end", "TPU adaptation: serving engine",
     paged_kernel.bench_engine_end_to_end),
    ("roofline_summary", "EXPERIMENTS §Roofline (from dry-run artifacts)",
     roofline_summary.bench_roofline_summary),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="all 16 benchmarks, long traces")
    ap.add_argument("--only", help="comma list of bench names")
    args = ap.parse_args(argv)

    only = set(args.only.split(",")) if args.only else None
    results: Dict[str, Any] = {}
    csv_lines = ["name,us_per_call,derived"]
    for name, artifact, fn in BENCHES:
        if only and name not in only:
            continue
        t0 = time.time()
        kwargs = {}
        if "quick" in fn.__code__.co_varnames:
            kwargs["quick"] = not args.full
        rows = fn(**kwargs)
        dt = time.time() - t0
        results[name] = {"artifact": artifact, "rows": rows,
                         "wall_s": round(dt, 1)}
        derived = ""
        try:
            if name == "tlb_synthetic":
                mixed = next(r for r in rows if r["mapping"] == "mixed")
                derived = (f"mixed:|K|=3 rel={mixed['|K|=3']};"
                           f"anchor rel={mixed['Anchor-Static']}")
            elif name == "tlb_demand":
                import numpy as np
                ks = [r["|K|=2"] for r in rows]
                an = [r["Anchor-Static"] for r in rows]
                derived = (f"mean |K|=2 rel={np.mean(ks):.3f};"
                           f"mean anchor rel={np.mean(an):.3f};"
                           f"reduction vs anchor="
                           f"{1 - np.mean(ks)/max(np.mean(an),1e-9):.3f}")
            elif name == "tlb_predictor":
                import numpy as np
                derived = "mean acc |K|=2 = {:.3f}".format(
                    np.mean([r["|K|=2"] for r in rows]))
            elif name == "dma_fragmentation":
                mid = rows[len(rows) // 2]
                derived = (f"frag=0.5: desc_red={mid['desc_reduction']},"
                           f"speedup={mid['speedup']}")
            elif name == "engine_end_to_end":
                derived = f"buddy desc_red={rows[0]['desc_reduction']}"
        except Exception as e:    # derived metrics must never kill the run
            derived = f"derive-error:{e}"
        n_calls = max(len(rows), 1)
        csv_lines.append(f"{name},{dt * 1e6 / n_calls:.0f},{derived}")
        print(f"\n=== {name}  [{artifact}]  ({dt:.1f}s) ===")
        print(_fmt_table(rows))

    print("\n--- CSV (name,us_per_call,derived) ---")
    for line in csv_lines:
        print(line)
    os.makedirs("results", exist_ok=True)
    with open("results/benchmarks.json", "w") as f:
        json.dump(results, f, indent=1)
    print("\nwrote results/benchmarks.json")


if __name__ == "__main__":
    main()
