"""Benchmark process environment: import BEFORE anything that imports jax.

Exposes one virtual XLA host device per CPU core so the sweep engine can
shard lanes across cores with ``pmap``.  This is benchmark-only: tests and
library users keep the default single device (see tests/conftest.py note).
"""
import os
import sys

_FLAG = "xla_force_host_platform_device_count"

if "jax" not in sys.modules and _FLAG not in os.environ.get("XLA_FLAGS", ""):
    _n = os.cpu_count() or 1
    if _n > 1:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + f" --{_FLAG}={_n}").strip()
