"""Chaos bench: seeded fault schedules over the serving + sweep runtimes.

Every row answers one question: did the runtime ABSORB this fault kind?
``status`` is ``recovered`` when the faulted run finished with results
bit-exact (sweeps) or token-exact (serving) against the fault-free run,
``lost`` otherwise — CI greps for at least one ``recovered`` row per fault
kind.  The ``tlb-parity`` rows additionally report the paper's
coalescing-vs-blast-radius trade: a |K|=k coalesced entry covers up to
2^k translations, so one parity flip invalidates more reach than a Base
entry loss (``detail`` shows the invalidated-entry and extra-walk bill of
detect-invalidate-rewalk recovery vs idealized ECC).
"""
from __future__ import annotations

import dataclasses
import os
import tempfile

import numpy as np

from repro.core.baselines import base_spec, colt_spec, kaligned_for_histogram
from repro.core.sweep import SweepCell, run_sweep
from repro.robustness import (FaultPlan, EngineCrash, KVCorruption, PageLoss,
                              backend_fault_injection, corrupt_cache_entry,
                              make_parity_world, run_engine_with_recovery)
from .tlb_suite import (MULTITENANT_MAX_PAGES, NESTED_MAX_PAGES,
                        _scenario_world)

_COUNTERS = ("accesses", "l1_hits", "l2_regular_hits", "l2_coalesced_hits",
             "walks", "aligned_probes", "pred_correct", "cycles",
             "shootdowns")

CHAOS_SEED = 1908


def _same(a, b) -> bool:
    return all(getattr(a, f) == getattr(b, f) for f in _COUNTERS)


def _suite(hist):
    return [base_spec(), colt_spec(),
            kaligned_for_histogram(hist, psi=3)]


def _parity_rows(trace_len, max_pages, backend):
    """tlb-parity: parity-flip faults over live scenario worlds, swept
    under both recovery policies on the batched backends."""
    rows = []
    for name, cap in (("mt-serve-mix", MULTITENANT_MAX_PAGES),
                      ("nested-vm-mix", NESTED_MAX_PAGES)):
        d = _scenario_world(name, trace_len, min(max_pages, cap))
        pw = make_parity_world(d.world, d.trace, seed=CHAOS_SEED, n_faults=3)
        if pw is None:
            continue
        specs = _suite(d.meta["contiguity_histogram"])
        cells = []
        for par in ("parity", "ecc"):
            cells += [SweepCell(dataclasses.replace(s, par_policy=par),
                                pw, d.trace) for s in specs]
        cells += [SweepCell(s, d.world, d.trace) for s in specs]  # fault-free
        res = run_sweep(cells, cache=False, backend=backend)
        n = len(specs)
        for j, s in enumerate(specs):
            flip, ecc, free = res[j], res[n + j], res[2 * n + j]
            ok = _same(ecc, free)        # ECC = fault-free by construction
            rows.append({
                "fault": "tlb-parity", "scenario": name, "cell": s.name,
                "status": "recovered" if ok else "lost",
                "detail": (f"inval={flip.shootdowns - free.shootdowns} "
                           f"extra_walks={flip.walks - free.walks} "
                           f"per {len(pw.faults)} flips")})
    return rows


def _backend_rows(trace_len, max_pages, backend):
    """backend-failure: a Pallas batch that raises recovers on XLA; a cell
    failing EVERY backend bisects down to the pure-python oracle."""
    d = _scenario_world("mt-serve-mix", trace_len,
                        min(max_pages, MULTITENANT_MAX_PAGES))
    specs = _suite(d.meta["contiguity_histogram"])
    cells = [SweepCell(s, d.world, d.trace) for s in specs]
    clean = run_sweep(cells, cache=False, backend=backend)

    rows = []
    with backend_fault_injection(n_failures=1, backends=("pallas",)):
        res = run_sweep(cells, cache=False, backend="pallas")
    ok = (res.stats["backend_fallbacks"] >= 1
          and all(_same(a, b) for a, b in zip(res, clean)))
    rows.append({"fault": "backend-failure", "scenario": "mt-serve-mix",
                 "cell": "pallas->xla fallback",
                 "status": "recovered" if ok else "lost",
                 "detail": f"fallbacks={res.stats['backend_fallbacks']}"})

    cursed = cells[0]
    with backend_fault_injection(
            n_failures=10_000, backends=("pallas", "xla"),
            predicate=lambda sub, bk: any(c is cursed for c in sub)):
        res = run_sweep(cells, cache=False, backend=backend)
    ok = (res.stats["bisections"] >= 1
          and all(_same(a, b) for a, b in zip(res, clean)))
    rows.append({"fault": "backend-failure", "scenario": "mt-serve-mix",
                 "cell": "bisect to oracle",
                 "status": "recovered" if ok else "lost",
                 "detail": (f"bisections={res.stats['bisections']} "
                            f"oracle={res.stats['oracle_fallbacks']}")})
    return rows


def _cache_rows(trace_len, max_pages, backend):
    """cache-corruption: damaged .npz entries are quarantined (surfaced in
    stats) and recomputed to identical results."""
    d = _scenario_world("mt-serve-mix", trace_len,
                        min(max_pages, MULTITENANT_MAX_PAGES))
    specs = _suite(d.meta["contiguity_histogram"])
    cells = [SweepCell(s, d.world, d.trace) for s in specs]
    rows = []
    # This row is ABOUT the cache path: exercise it in a private temp dir
    # even when the harness globally bypasses the shared sweep cache.
    no_cache = os.environ.pop("REPRO_SWEEP_NO_CACHE", None)
    try:
        rows += _cache_rows_cached(cells, backend)
    finally:
        if no_cache is not None:
            os.environ["REPRO_SWEEP_NO_CACHE"] = no_cache
    return rows


def _cache_rows_cached(cells, backend):
    rows = []
    with tempfile.TemporaryDirectory() as cdir:
        first = run_sweep(cells, cache=True, cache_dir=cdir, backend=backend)
        entries = sorted(p for p in os.listdir(cdir) if p.endswith(".npz"))
        for mode, entry in zip(("truncate", "garbage", "schema"), entries):
            corrupt_cache_entry(os.path.join(cdir, entry), mode)
        again = run_sweep(cells, cache=True, cache_dir=cdir, backend=backend)
        ok = (again.stats["cache_quarantined"] == 3
              and all(_same(a, b) for a, b in zip(again, first)))
        rows.append({"fault": "cache-corruption", "scenario": "mt-serve-mix",
                     "cell": "truncate+garbage+schema",
                     "status": "recovered" if ok else "lost",
                     "detail": (f"quarantined="
                                f"{again.stats['cache_quarantined']} "
                                f"hits={again.stats['cache_hits']}")})
    return rows


def _serve_rows():
    """engine-crash / kv-corruption / page-loss: a full serve under a
    seeded fault plan, token-exact against the fault-free run."""
    import time

    from repro.configs import get_config
    from repro.models import Model, RunConfig
    from repro.serve import EngineConfig, ServingEngine

    cfg = get_config("internlm2-1.8b", reduced=True)
    rc = RunConfig(attn_q_chunk=32, attn_kv_chunk=32, scan_chunk=16)
    model = Model(cfg, rc)
    params = model.init(0)
    ec = EngineConfig(page_size=8, num_pages=256, max_batch=3, max_seq=64,
                      interpret=True)

    def make_engine():
        return ServingEngine(model, params, ec)

    rng = np.random.default_rng(2024)
    requests = [(list(rng.integers(0, cfg.vocab, size=12)), 5)
                for _ in range(4)]
    rows = []
    t0 = time.time()
    with tempfile.TemporaryDirectory() as ck:
        baseline, _ = run_engine_with_recovery(
            make_engine, requests, None, ck, max_steps=64)
    plans = [
        ("engine-crash", FaultPlan(CHAOS_SEED, (EngineCrash(step=3),))),
        ("kv-corruption", FaultPlan(CHAOS_SEED,
                                    (KVCorruption(step=2, n_pages=2),))),
        ("page-loss", FaultPlan(CHAOS_SEED, (PageLoss(step=1, n_pages=3),))),
    ]
    for kind, plan in plans:
        with tempfile.TemporaryDirectory() as ck:
            out, rep = run_engine_with_recovery(
                make_engine, requests, plan, ck, max_steps=64,
                snapshot_every=2)
        ok = out == baseline
        rows.append({"fault": kind, "scenario": "serve-tiny",
                     "cell": f"{len(requests)} reqs",
                     "status": "recovered" if ok else "lost",
                     "detail": (f"crashes={rep['crashes']} "
                                f"preempted={rep['preempted']} "
                                f"pages_lost={rep['pages_lost']} "
                                f"wall={time.time() - t0:.0f}s")})
    return rows


def bench_chaos(trace_len=60_000, quick=True, max_pages=MULTITENANT_MAX_PAGES,
                backend="auto"):
    rows = []
    rows += _parity_rows(trace_len, max_pages, backend)
    rows += _backend_rows(trace_len, max_pages, backend)
    rows += _cache_rows(trace_len, max_pages, backend)
    rows += _serve_rows()
    return rows
