"""Roofline summary bench: renders §Roofline aggregates from dry-run JSONs.

Reads ``results/dryrun_final2`` (or ``--dir``); skips gracefully when the
dry-run hasn't been executed in this checkout.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DEFAULT_DIR = "results/dryrun_final2"


def bench_roofline_summary(quick: bool = True,
                           dirname: str = DEFAULT_DIR) -> List[Dict]:
    if not os.path.isdir(dirname):
        return [{"note": f"{dirname} missing - run repro.launch.dryrun first"}]
    rows = []
    for p in sorted(glob.glob(f"{dirname}/*__sp.json")):
        r = json.load(open(p))
        if r.get("status") != "OK":
            continue
        rf = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "compute_s": f"{rf['compute_s']:.2e}",
            "memory_s": f"{rf['memory_s']:.2e}",
            "collective_s": f"{rf['collective_s']:.2e}",
            "dominant": rf["dominant"],
            "useful_flops": (round(r["useful_flops_frac"], 2)
                             if r.get("useful_flops_frac") else None),
            "mem_adj_GB": round(r["memory"]["total_adjusted_tpu"] / 1e9, 2),
            "fits": r["memory"]["fits_16gb"],
        })
    return rows
