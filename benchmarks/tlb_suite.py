"""Faithful-reproduction benchmarks: one per paper table/figure.

* ``bench_synthetic``  — Table 4 (synthetic mappings) + Figure 1 structure
* ``bench_demand``     — Figure 8 / Table 4 "Real Mapping" row (demand-paged
                         mapping from the buddy-allocator OS model)
* ``bench_coverage``   — Table 5 (relative translation coverage)
* ``bench_predictor``  — Table 6 (alignment-predictor accuracy)
* ``bench_k_sweep``    — Figure 9 (|K| = 2/3/4 relative to Anchor)
* ``bench_cpi``        — Figures 10/11 (translation cycles per access)

All traces are synthetic access-pattern analogues of the paper's benchmarks
(no Pin offline); see repro.core.traces.BENCHMARKS and EXPERIMENTS.md for the
fidelity discussion.
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence

import numpy as np

from repro.core import (BENCHMARKS, anchor_static, base_spec, benchmark_trace,
                        cluster_spec, colt_spec, demand_mapping,
                        generate_trace, kaligned_for_mapping, rmm_spec,
                        run_method, synthetic_mapping, thp_spec)

QUICK_BENCHES = ("mcf", "bwaves", "gups", "graph500", "omnetpp", "gromacs",
                 "xalancbmk", "libquantum")
ANCHOR_GRID_QUICK = (4, 6, 8, 10)


def _mapping_for(name: str, n_pages: int, seed: int = 0):
    return demand_mapping(n_pages, seed=seed)


def _suite(m, tr, anchor_grid, psis=(2, 3, 4)) -> Dict[str, object]:
    out = {}
    out["Base"] = run_method(base_spec(), m, tr)
    out["THP"] = run_method(thp_spec(), m, tr)
    out["RMM"] = run_method(rmm_spec(), m, tr)
    out["COLT"] = run_method(colt_spec(), m, tr)
    out["Cluster"] = run_method(cluster_spec(), m, tr)
    out["Anchor-Static"] = anchor_static(m, tr, grid=anchor_grid)
    for psi in psis:
        out[f"|K|={psi}"] = run_method(
            kaligned_for_mapping(m, psi=psi, theta=1.0 if psi > 2 else 0.9),
            m, tr)
    return out


def bench_synthetic(trace_len=150_000, n_pages=1 << 19, quick=True):
    """Table 4 synthetic-mapping rows."""
    rows = []
    for kind in ("small", "medium", "large", "mixed"):
        m = synthetic_mapping(kind, n_pages, seed=1)
        tr = generate_trace("multiscale", 0, trace_len, seed=2, mapping=m)
        t0 = time.time()
        res = _suite(m, tr, ANCHOR_GRID_QUICK)
        base = res["Base"].walks
        row = {"mapping": kind,
               **{k: round(v.walks / max(base, 1), 4) for k, v in res.items()},
               "wall_s": round(time.time() - t0, 1)}
        rows.append(row)
    return rows


def bench_demand(trace_len=150_000, quick=True):
    """Figure 8: per-benchmark relative misses on the demand mapping."""
    rows = []
    benches = QUICK_BENCHES if quick else tuple(BENCHMARKS)
    for name in benches:
        pattern, n_pages = BENCHMARKS[name]
        n_pages = min(n_pages, 1 << 19) if quick else n_pages
        m = _mapping_for(name, n_pages, seed=hash(name) % 1000)
        tr = generate_trace(pattern, 0, trace_len, seed=3, mapping=m)
        res = _suite(m, tr, ANCHOR_GRID_QUICK, psis=(2,))
        base = res["Base"].walks
        rows.append({"benchmark": name,
                     **{k: round(v.walks / max(base, 1), 4)
                        for k, v in res.items()}})
    return rows


def bench_coverage(trace_len=120_000, quick=True):
    """Table 5: relative TLB translation coverage (covered PTEs / 1024)."""
    rows = []
    benches = QUICK_BENCHES[:6] if quick else tuple(BENCHMARKS)
    for name in benches:
        pattern, n_pages = BENCHMARKS[name]
        n_pages = min(n_pages, 1 << 19)
        m = _mapping_for(name, n_pages, seed=hash(name) % 1000)
        tr = generate_trace(pattern, 0, trace_len, seed=4, mapping=m)
        base = run_method(base_spec(), m, tr)
        colt = run_method(colt_spec(), m, tr)
        anch = anchor_static(m, tr, grid=(6, 8, 10))
        ka = run_method(kaligned_for_mapping(m, psi=2), m, tr)
        denom = max(base.coverage_mean, 1.0)
        rows.append({"benchmark": name, "Base": 1.0,
                     "COLT": round(colt.coverage_mean / denom, 2),
                     "Anchor-Static": round(anch.coverage_mean / denom, 2),
                     "|K|=2": round(ka.coverage_mean / denom, 2)})
    return rows


def bench_predictor(trace_len=120_000, quick=True):
    """Table 6: predictor accuracy per benchmark for |K| = 2, 3, 4."""
    rows = []
    benches = QUICK_BENCHES[:6] if quick else tuple(BENCHMARKS)
    for name in benches:
        pattern, n_pages = BENCHMARKS[name]
        n_pages = min(n_pages, 1 << 19)
        m = _mapping_for(name, n_pages, seed=hash(name) % 1000)
        tr = generate_trace(pattern, 0, trace_len, seed=5, mapping=m)
        row = {"benchmark": name}
        for psi in (2, 3, 4):
            r = run_method(kaligned_for_mapping(m, psi=psi, theta=1.0), m, tr)
            row[f"|K|={psi}"] = round(r.predictor_accuracy, 3)
        rows.append(row)
    return rows


def bench_k_sweep(trace_len=150_000, n_pages=1 << 19):
    """Figure 9: misses of |K| modes relative to Anchor-Static (mixed)."""
    m = synthetic_mapping("mixed", n_pages, seed=1)
    tr = generate_trace("multiscale", 0, trace_len, seed=6, mapping=m)
    anch = anchor_static(m, tr, grid=ANCHOR_GRID_QUICK)
    rows = []
    for psi in (1, 2, 3, 4):
        r = run_method(kaligned_for_mapping(m, psi=psi, theta=1.0), m, tr)
        rows.append({"|K|": psi,
                     "rel_misses_vs_anchor": round(
                         r.walks / max(anch.walks, 1), 4)})
    return rows


def bench_cpi(trace_len=120_000, quick=True):
    """Figures 10/11: translation cycles per access."""
    rows = []
    benches = ("gups", "mcf", "graph500") if quick else tuple(BENCHMARKS)
    for name in benches:
        pattern, n_pages = BENCHMARKS[name]
        n_pages = min(n_pages, 1 << 19)
        m = _mapping_for(name, n_pages, seed=hash(name) % 1000)
        tr = generate_trace(pattern, 0, trace_len, seed=7, mapping=m)
        row = {"benchmark": name}
        for label, spec in (("Base", base_spec()), ("THP", thp_spec()),
                            ("COLT", colt_spec())):
            row[label] = round(run_method(spec, m, tr).cpi, 3)
        row["Anchor-Static"] = round(
            anchor_static(m, tr, grid=(6, 8, 10)).cpi, 3)
        for psi in (2, 3):
            row[f"|K|={psi}"] = round(run_method(
                kaligned_for_mapping(m, psi=psi, theta=1.0), m, tr).cpi, 3)
        rows.append(row)
    return rows
