"""Faithful-reproduction benchmarks: one per paper table/figure.

* ``bench_synthetic``  — Table 4 (synthetic mappings) + Figure 1 structure
* ``bench_demand``     — Figure 8 / Table 4 "Real Mapping" row (demand-paged
                         mapping from the buddy-allocator OS model)
* ``bench_coverage``   — Table 5 (relative translation coverage)
* ``bench_predictor``  — Table 6 (alignment-predictor accuracy)
* ``bench_k_sweep``    — Figure 9 (|K| = 2/3/4 relative to Anchor)
* ``bench_cpi``        — Figures 10/11 (translation cycles per access)
* ``bench_accelerator``— Beyond the paper: accelerator-lineage methods
                         (subregion / cache-TLB / dead-protect) on the
                         concurrency-diluted ``accel-gather`` streams

All traces are synthetic access-pattern analogues of the paper's benchmarks
(no Pin offline); see repro.core.traces.BENCHMARKS and EXPERIMENTS.md for the
fidelity discussion.

Every bench routes through :func:`repro.core.sweep.run_sweep`: all of its
(method, mapping, trace) cells run as lanes of ONE batched vmapped simulation
compiled once per shape bucket, instead of one ``run_method`` compile+scan
per cell.  ``max_pages`` caps mapping footprints so the ``--smoke`` tier can
exercise the identical sweep path in seconds.

Mappings and traces come from the scenario registry
(:mod:`repro.scenarios`): the paper benches use the synthetic families
(``synth-*``, ``paper-*``); ``bench_scenarios``/``bench_scenario_contiguity``
additionally sweep the workload-derived and adversarial scenarios — the
repo's own serving/training stacks as translation workloads.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Sequence, Tuple

from repro.core import (BENCHMARKS, SimResult, base_spec, cluster_spec,
                        colt_spec, kaligned_for_mapping, rmm_spec, thp_spec)
from repro.core.baselines import (anchor_spec, cache_tlb_spec,
                                  dead_protect_spec, kaligned_for_histogram,
                                  subregion_spec)
from repro.core.page_table import contiguity_histogram
from repro.core.sweep import SweepCell, run_sweep
from repro.kvcache.block_table import choose_kernel_classes
from repro.scenarios import get_scenario, list_scenarios

QUICK_BENCHES = ("mcf", "bwaves", "gups", "graph500", "omnetpp", "gromacs",
                 "xalancbmk", "libquantum")
ANCHOR_GRID_QUICK = (4, 6, 8, 10)
MAX_PAGES_DEFAULT = 1 << 19

# scenario lanes swept by bench_scenarios; quick keeps the python-driven
# churn cheap, full runs every workload + adversarial scenario registered
SCENARIO_LANES_QUICK = ("kv-churn", "kv-churn-page", "kv-gather",
                        "train-pipeline", "adv-numa")
SCENARIO_SEEDS = dict(map_seed=0, trace_seed=8)

# dynamic worlds swept by bench_dynamic (every registered dynamic scenario)
DYNAMIC_MAX_PAGES = 1 << 16     # per-epoch records are E× the static cost

# multi-tenant worlds swept by bench_multitenant: per-tenant records are
# n_tenants× the static cost, and the python scheduling drivers cap cheap
MULTITENANT_MAX_PAGES = 1 << 15

# nested worlds swept by bench_nested: every (guest epoch × host epoch)
# union segment materializes a composed view, so records scale with the
# product of both event streams
NESTED_MAX_PAGES = 1 << 15


def _scenario_world(name: str, trace_len: int, max_pages: int):
    data = get_scenario(name).materialize(n_pages=max_pages,
                                          trace_len=trace_len,
                                          **SCENARIO_SEEDS)
    return data


def _paper_world(name: str, trace_len: int, cap, trace_seed: int):
    """(mapping, trace) of a paper-benchmark analogue via the registry."""
    n_pages = min(BENCHMARKS[name][1], cap) if cap else BENCHMARKS[name][1]
    d = get_scenario(f"paper-{name}").materialize(
        n_pages=n_pages, trace_len=trace_len, trace_seed=trace_seed)
    return d.mapping, d.trace


class SweepPlan:
    """Accumulates tagged sweep cells; one ``run_sweep`` serves all rows.

    ``group="anchor"`` cells are reduced to the best (fewest walks) result
    per (row, label) — the Anchor-Static exhaustive-grid policy of §4.1.
    ``backend`` selects the sweep execution backend (``auto``/``xla``/
    ``pallas``); results are bit-exact across backends.
    """

    def __init__(self):
        self.cells: List[SweepCell] = []
        self.tags: List[Tuple[str, str, str]] = []

    def add(self, spec, mapping, trace, row: str, label: str,
            group: str = "plain") -> None:
        self.cells.append(SweepCell(spec, mapping, trace))
        self.tags.append((row, label, group))

    def add_anchor_static(self, mapping, trace, row: str,
                          grid: Iterable[int],
                          label: str = "Anchor-Static") -> None:
        for d in grid:
            self.add(anchor_spec(d), mapping, trace, row, label,
                     group="anchor")

    def run(self, cache: bool = True, backend: str = "auto"
            ) -> Dict[str, Dict[str, SimResult]]:
        sweep = run_sweep(self.cells, cache=cache, backend=backend)
        out: Dict[str, Dict[str, SimResult]] = {}
        for (row, label, group), r in zip(self.tags, sweep.results):
            cols = out.setdefault(row, {})
            if group == "anchor" and label in cols:
                if r.walks < cols[label].walks:
                    cols[label] = r
            else:
                cols[label] = r
        return out


def _add_suite(plan: SweepPlan, m, tr, row: str, anchor_grid,
               psis: Sequence[int] = (2, 3, 4), k_mapping=None,
               k_hist=None, transform=None) -> None:
    """Add the full method suite over world ``m`` (static, dynamic or
    multi-tenant) — the ONE definition of the compared-method roster.

    ``k_mapping`` is the static mapping Algorithm 3 reads the contiguity
    histogram from; defaults to ``m`` (pass the epoch-0 snapshot when ``m``
    is a :class:`~repro.core.page_table.DynamicMapping`).  ``k_hist``
    supplies the histogram directly instead (e.g. the merged per-tenant
    histogram of a multi-tenant world).  ``transform`` post-processes every
    spec (e.g. setting ``ctx_policy``) without forking the roster.
    """
    tx = transform if transform is not None else (lambda s: s)
    k_src = k_mapping if k_mapping is not None else m
    plan.add(tx(base_spec()), m, tr, row, "Base")
    plan.add(tx(thp_spec()), m, tr, row, "THP")
    plan.add(tx(rmm_spec()), m, tr, row, "RMM")
    plan.add(tx(colt_spec()), m, tr, row, "COLT")
    plan.add(tx(cluster_spec()), m, tr, row, "Cluster")
    for d in anchor_grid:
        plan.add(tx(anchor_spec(d)), m, tr, row, "Anchor-Static",
                 group="anchor")
    for psi in psis:
        theta = 1.0 if psi > 2 else 0.9
        spec = (kaligned_for_histogram(k_hist, psi=psi, theta=theta)
                if k_hist is not None
                else kaligned_for_mapping(k_src, psi=psi, theta=theta))
        plan.add(tx(spec), m, tr, row, f"|K|={psi}")


def bench_synthetic(trace_len=150_000, n_pages=1 << 19, quick=True,
                    max_pages=MAX_PAGES_DEFAULT, backend="auto"):
    """Table 4 synthetic-mapping rows."""
    n_pages = min(n_pages, max_pages)
    plan = SweepPlan()
    order = []
    for kind in ("small", "medium", "large", "mixed"):
        d = get_scenario(f"synth-{kind}").materialize(
            n_pages=n_pages, trace_len=trace_len, map_seed=1, trace_seed=2)
        _add_suite(plan, d.mapping, d.trace, kind, ANCHOR_GRID_QUICK)
        order.append(kind)
    res = plan.run(backend=backend)
    rows = []
    for kind in order:
        cols = res[kind]
        base = cols["Base"].walks
        rows.append({"mapping": kind,
                     **{k: round(v.walks / max(base, 1), 4)
                        for k, v in cols.items()}})
    return rows


def bench_demand(trace_len=150_000, quick=True, max_pages=None,
                 backend="auto"):
    """Figure 8: per-benchmark relative misses on the demand mapping.

    Footprints are only capped in quick/smoke tiers; ``--full`` runs the
    declared paper-scale footprints (up to 4GB of virtual address space).
    """
    cap = max_pages if max_pages is not None else (
        MAX_PAGES_DEFAULT if quick else None)
    benches = QUICK_BENCHES if quick else tuple(BENCHMARKS)
    plan = SweepPlan()
    for name in benches:
        m, tr = _paper_world(name, trace_len, cap, trace_seed=3)
        _add_suite(plan, m, tr, name, ANCHOR_GRID_QUICK, psis=(2,))
    res = plan.run(backend=backend)
    rows = []
    for name in benches:
        cols = res[name]
        base = cols["Base"].walks
        rows.append({"benchmark": name,
                     **{k: round(v.walks / max(base, 1), 4)
                        for k, v in cols.items()}})
    return rows


def bench_coverage(trace_len=120_000, quick=True,
                   max_pages=MAX_PAGES_DEFAULT, backend="auto"):
    """Table 5: relative TLB translation coverage (covered PTEs / 1024)."""
    benches = QUICK_BENCHES[:6] if quick else tuple(BENCHMARKS)
    plan = SweepPlan()
    for name in benches:
        m, tr = _paper_world(name, trace_len, max_pages, trace_seed=4)
        plan.add(base_spec(), m, tr, name, "Base")
        plan.add(colt_spec(), m, tr, name, "COLT")
        plan.add_anchor_static(m, tr, name, grid=(6, 8, 10))
        plan.add(kaligned_for_mapping(m, psi=2), m, tr, name, "|K|=2")
    res = plan.run(backend=backend)
    rows = []
    for name in benches:
        cols = res[name]
        denom = max(cols["Base"].coverage_mean, 1.0)
        rows.append({"benchmark": name, "Base": 1.0,
                     **{k: round(cols[k].coverage_mean / denom, 2)
                        for k in ("COLT", "Anchor-Static", "|K|=2")}})
    return rows


def bench_predictor(trace_len=120_000, quick=True,
                    max_pages=MAX_PAGES_DEFAULT, backend="auto"):
    """Table 6: predictor accuracy per benchmark for |K| = 2, 3, 4."""
    benches = QUICK_BENCHES[:6] if quick else tuple(BENCHMARKS)
    plan = SweepPlan()
    for name in benches:
        m, tr = _paper_world(name, trace_len, max_pages, trace_seed=5)
        for psi in (2, 3, 4):
            plan.add(kaligned_for_mapping(m, psi=psi, theta=1.0), m, tr,
                     name, f"|K|={psi}")
    res = plan.run(backend=backend)
    return [{"benchmark": name,
             **{k: round(v.predictor_accuracy, 3)
                for k, v in res[name].items()}}
            for name in benches]


def bench_k_sweep(trace_len=150_000, n_pages=1 << 19,
                  max_pages=MAX_PAGES_DEFAULT, backend="auto"):
    """Figure 9: misses of |K| modes relative to Anchor-Static (mixed)."""
    d = get_scenario("synth-mixed").materialize(
        n_pages=min(n_pages, max_pages), trace_len=trace_len,
        map_seed=1, trace_seed=6)
    m, tr = d.mapping, d.trace
    plan = SweepPlan()
    plan.add_anchor_static(m, tr, "mixed", grid=ANCHOR_GRID_QUICK)
    for psi in (1, 2, 3, 4):
        plan.add(kaligned_for_mapping(m, psi=psi, theta=1.0), m, tr,
                 "mixed", f"|K|={psi}")
    res = plan.run(backend=backend)["mixed"]
    anch = res["Anchor-Static"]
    return [{"|K|": psi,
             "rel_misses_vs_anchor": round(
                 res[f"|K|={psi}"].walks / max(anch.walks, 1), 4)}
            for psi in (1, 2, 3, 4)]


def bench_cpi(trace_len=120_000, quick=True, max_pages=MAX_PAGES_DEFAULT,
              backend="auto"):
    """Figures 10/11: translation cycles per access."""
    benches = ("gups", "mcf", "graph500") if quick else tuple(BENCHMARKS)
    plan = SweepPlan()
    for name in benches:
        m, tr = _paper_world(name, trace_len, max_pages, trace_seed=7)
        plan.add(base_spec(), m, tr, name, "Base")
        plan.add(thp_spec(), m, tr, name, "THP")
        plan.add(colt_spec(), m, tr, name, "COLT")
        plan.add_anchor_static(m, tr, name, grid=(6, 8, 10))
        for psi in (2, 3):
            plan.add(kaligned_for_mapping(m, psi=psi, theta=1.0), m, tr,
                     name, f"|K|={psi}")
    res = plan.run(backend=backend)
    return [{"benchmark": name,
             **{k: round(v.cpi, 3) for k, v in res[name].items()}}
            for name in benches]


# ---------------------------------------------------------------------------
# Workload-derived / adversarial scenario sweeps (ROADMAP: "open a new
# workload") — the repo's own serving and training stacks as translation
# workloads, plus adversarial contiguity generators.
# ---------------------------------------------------------------------------


def _scenario_names(quick: bool) -> Tuple[str, ...]:
    if quick:
        return SCENARIO_LANES_QUICK
    return tuple(sc.name for sc in list_scenarios("workload")
                 ) + tuple(sc.name for sc in list_scenarios("adversarial"))


def bench_scenarios(trace_len=120_000, quick=True,
                    max_pages=MAX_PAGES_DEFAULT, backend="auto"):
    """Per-scenario relative misses, full method suite through run_sweep.

    Each row is one registered scenario (workload-derived or adversarial):
    mappings and traces recorded from the in-repo systems, swept exactly
    like the paper benches.
    """
    names = _scenario_names(quick)
    plan = SweepPlan()
    for name in names:
        d = _scenario_world(name, trace_len, max_pages)
        _add_suite(plan, d.mapping, d.trace, name, ANCHOR_GRID_QUICK,
                   psis=(2, 3))
    res = plan.run(backend=backend)
    rows = []
    for name in names:
        cols = res[name]
        base = cols["Base"].walks
        rows.append({"scenario": name,
                     **{k: round(v.walks / max(base, 1), 4)
                        for k, v in cols.items()}})
    return rows


def bench_dynamic(trace_len=120_000, quick=True, max_pages=MAX_PAGES_DEFAULT,
                  backend="auto"):
    """Dynamic mapping worlds: mid-trace remaps with shootdown-correct TLBs.

    Every registered ``dynamic`` scenario (live event streams instead of
    frozen snapshots) is swept with the full method suite through ONE
    ``run_sweep`` call: lanes are epoch-segmented, and each epoch turnover
    invalidates every cached entry covering a remapped page (translation
    coherence).  Two rows per scenario: relative misses (Base = 1.0) and
    the per-method invalidated-entry counts — time-varying reach is where
    large-reach designs pay for their coverage.
    """
    names = tuple(sc.name for sc in list_scenarios("dynamic"))
    plan = SweepPlan()
    for name in names:
        d = _scenario_world(name, trace_len, min(max_pages,
                                                 DYNAMIC_MAX_PAGES))
        # K is chosen by Algorithm 3 from the epoch-0 histogram — what the
        # OS saw at launch; the events then degrade it, which is the point
        _add_suite(plan, d.world, d.trace, name, ANCHOR_GRID_QUICK,
                   psis=(2, 3), k_mapping=d.mapping)
    res = plan.run(backend=backend)
    rows = []
    for name in names:
        cols = res[name]
        base = cols["Base"].walks
        rows.append({"scenario": name, "metric": "rel_misses",
                     **{k: round(v.walks / max(base, 1), 4)
                        for k, v in cols.items()}})
        rows.append({"scenario": name, "metric": "shootdowns",
                     **{k: v.shootdowns for k, v in cols.items()}})
    return rows


def bench_multitenant(trace_len=120_000, quick=True,
                      max_pages=MAX_PAGES_DEFAULT, backend="auto"):
    """Multi-tenant address spaces: ASID-tagged TLBs under context-switch
    pressure, each scenario swept under BOTH context-switch policies.

    Every registered ``multitenant`` scenario (tenants drawn from
    different contiguity families, scheduled by the serving stack's own
    KVScheduler; see :mod:`repro.scenarios.multitenant`) runs the full
    9-method suite twice — ``ctx_policy="flush"`` (untagged hardware wipes
    the TLB every switch) and ``"tag"`` (ASID-tagged entries survive;
    recycled ASIDs pay targeted invalidation) — through ONE ``run_sweep``
    call per policy set.  K for the K-bit Aligned rows comes from the
    *merged* per-tenant contiguity histogram (Algorithm 3 over what an OS
    aggregating per-process stats would see).  Rows: per (scenario,
    policy) relative misses (Base = 1.0) and invalidated-entry counts —
    switch-heavy schedules are where large-reach designs pay for their
    coverage twice, once per tenant.
    """
    names = tuple(sc.name for sc in list_scenarios("multitenant"))
    rows = []
    for name in names:
        d = _scenario_world(name, trace_len, min(max_pages,
                                                 MULTITENANT_MAX_PAGES))
        # one plan (= one run_sweep) PER world: MT lanes are segmented on
        # that world's switch schedule, so batching all scenarios together
        # would pad every lane to the union (n_segments, seg_len) grid —
        # the smoke tier paid ~3x padded steps for the mixed batch
        plan = SweepPlan()
        for policy in ("flush", "tag"):
            _add_suite(
                plan, d.world, d.trace, f"{name}::{policy}",
                ANCHOR_GRID_QUICK, psis=(2, 3, 4),
                k_hist=d.meta["contiguity_histogram"],
                transform=lambda s, p=policy: dataclasses.replace(
                    s, ctx_policy=p))
        res = plan.run(backend=backend)
        for policy in ("flush", "tag"):
            cols = res[f"{name}::{policy}"]
            base = cols["Base"].walks
            rows.append({"scenario": name, "policy": policy,
                         "metric": "rel_misses",
                         **{k: round(v.walks / max(base, 1), 4)
                            for k, v in cols.items()}})
            rows.append({"scenario": name, "policy": policy,
                         "metric": "shootdowns",
                         **{k: v.shootdowns for k, v in cols.items()}})
    return rows


def bench_nested(trace_len=120_000, quick=True,
                 max_pages=MAX_PAGES_DEFAULT, backend="auto"):
    """Nested guest→host translation worlds, each scenario swept under BOTH
    translation-coherence policies.

    Every registered ``nested`` scenario (per-VM guest page tables composed
    over a host layer the hypervisor rewrites mid-trace, VM schedules from
    the serving stack's KVScheduler; see :mod:`repro.scenarios.nested`)
    runs the full method suite twice — ``coh_policy="shootdown"`` (every
    host/guest remap storm pays the fixed IPI cost plus per-entry
    invalidation) and ``"hw-coherence"`` (a coherence-participating TLB
    drops the same entries for only the per-entry cost) — through ONE
    ``run_sweep`` call per world.  Both policies invalidate identical
    entry sets, so walks/hits are bit-identical and only cycles move: the
    ``rel_misses`` rows are policy-invariant by construction while the
    ``stall_cycles`` rows isolate exactly the coherence tax.  K for the
    K-bit Aligned rows comes from the merged *composed* contiguity
    histogram (what Algorithm 3 sees through both levels).  Rows: per
    (scenario, policy) relative misses (Base = 1.0), invalidated-entry
    counts, and total translation stall cycles.
    """
    names = tuple(sc.name for sc in list_scenarios("nested"))
    rows = []
    for name in names:
        d = _scenario_world(name, trace_len, min(max_pages,
                                                 NESTED_MAX_PAGES))
        # one plan (= one run_sweep) per world, as in bench_multitenant:
        # nested lanes segment on that world's union grid (guest epochs ∪
        # host epochs ∪ VM switches) and batching worlds would pad all
        # lanes to the union shape
        plan = SweepPlan()
        for policy in ("shootdown", "hw-coherence"):
            _add_suite(
                plan, d.world, d.trace, f"{name}::{policy}",
                ANCHOR_GRID_QUICK, psis=(2, 3),
                k_hist=d.meta["contiguity_histogram"],
                transform=lambda s, p=policy: dataclasses.replace(
                    s, coh_policy=p))
        res = plan.run(backend=backend)
        for policy in ("shootdown", "hw-coherence"):
            cols = res[f"{name}::{policy}"]
            base = cols["Base"].walks
            rows.append({"scenario": name, "policy": policy,
                         "metric": "rel_misses",
                         **{k: round(v.walks / max(base, 1), 4)
                            for k, v in cols.items()}})
            rows.append({"scenario": name, "policy": policy,
                         "metric": "shootdowns",
                         **{k: v.shootdowns for k, v in cols.items()}})
            rows.append({"scenario": name, "policy": policy,
                         "metric": "stall_cycles",
                         **{k: v.cycles for k, v in cols.items()}})
    return rows


def bench_accelerator(trace_len=120_000, quick=True,
                      max_pages=MAX_PAGES_DEFAULT, backend="auto"):
    """Accelerator-scale translation: the three accelerator-lineage kinds
    against the paper's best CPU-scale scheme on concurrency-diluted
    gather streams.

    Every registered ``accelerator`` scenario (the kv-gather DMA recording
    interleaved as 64/256/1024 concurrent streams; see
    :mod:`repro.scenarios.accelerator`) is swept with Base, |K|=3 Aligned
    (Algorithm 3 over the scenario's contiguity histogram — the histogram
    is concurrency-invariant, so K is identical across rows), and the
    three accelerator-lineage methods: Subregion (bitmap windows),
    Cache-TLB (cache-backed reach), Dead-Protect (dead-fill bypass).  Two
    rows per scenario: relative misses (Base = 1.0) and translation
    cycles per access — cache-backed reach trades walks for slower side
    hits, so the two metrics deliberately disagree.
    """
    names = tuple(sc.name for sc in list_scenarios("accelerator"))
    plan = SweepPlan()
    for name in names:
        d = _scenario_world(name, trace_len, max_pages)
        m, tr = d.mapping, d.trace
        plan.add(base_spec(), m, tr, name, "Base")
        plan.add(kaligned_for_histogram(d.meta["contiguity_histogram"],
                                        psi=3, theta=1.0),
                 m, tr, name, "|K|=3")
        plan.add(subregion_spec(), m, tr, name, "Subregion")
        plan.add(cache_tlb_spec(), m, tr, name, "Cache-TLB")
        plan.add(dead_protect_spec(), m, tr, name, "Dead-Protect")
    res = plan.run(backend=backend)
    rows = []
    for name in names:
        cols = res[name]
        base = cols["Base"].walks
        rows.append({"scenario": name, "metric": "rel_misses",
                     **{k: round(v.walks / max(base, 1), 4)
                        for k, v in cols.items()}})
        rows.append({"scenario": name, "metric": "cycles_per_access",
                     **{k: round(v.cpi, 3) for k, v in cols.items()}})
    return rows


_HIST_BUCKETS = ((1, 1), (2, 15), (16, 63), (64, 255), (256, 511),
                 (512, 100_000_000))


def bench_scenario_contiguity(trace_len=120_000, quick=True,
                              max_pages=MAX_PAGES_DEFAULT):
    """Per-scenario contiguity histograms (the Figs 2–3 measurement, run on
    our own workloads): % of mapped pages living in chunks of each size
    bucket, plus the K Algorithm 3 picks from the histogram."""
    names = _scenario_names(quick)
    rows = []
    for name in names:
        d = _scenario_world(name, trace_len, max_pages)
        hist = d.meta.get("contiguity_histogram") or \
            contiguity_histogram(d.mapping)
        total = sum(s * f for s, f in hist.items()) or 1
        row = {"scenario": name,
               "mapped_pages": int((d.mapping.ppn >= 0).sum()),
               "chunks": int(sum(hist.values()))}
        for lo, hi in _HIST_BUCKETS:
            pct = 100.0 * sum(s * f for s, f in hist.items()
                              if lo <= s <= hi) / total
            label = f"{lo}" if lo == hi else \
                (f"{lo}+" if hi >= 100_000_000 else f"{lo}-{hi}")
            row[f"pages in {label}"] = round(pct, 1)
        row["K (Alg 3)"] = str(choose_kernel_classes(hist, psi=3) or [0])
        rows.append(row)
    return rows
