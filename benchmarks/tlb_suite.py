"""Faithful-reproduction benchmarks: one per paper table/figure.

* ``bench_synthetic``  — Table 4 (synthetic mappings) + Figure 1 structure
* ``bench_demand``     — Figure 8 / Table 4 "Real Mapping" row (demand-paged
                         mapping from the buddy-allocator OS model)
* ``bench_coverage``   — Table 5 (relative translation coverage)
* ``bench_predictor``  — Table 6 (alignment-predictor accuracy)
* ``bench_k_sweep``    — Figure 9 (|K| = 2/3/4 relative to Anchor)
* ``bench_cpi``        — Figures 10/11 (translation cycles per access)

All traces are synthetic access-pattern analogues of the paper's benchmarks
(no Pin offline); see repro.core.traces.BENCHMARKS and EXPERIMENTS.md for the
fidelity discussion.

Every bench routes through :func:`repro.core.sweep.run_sweep`: all of its
(method, mapping, trace) cells run as lanes of ONE batched vmapped simulation
compiled once per shape bucket, instead of one ``run_method`` compile+scan
per cell.  ``max_pages`` caps mapping footprints so the ``--smoke`` tier can
exercise the identical sweep path in seconds.
"""
from __future__ import annotations

import zlib
from typing import Dict, Iterable, List, Sequence, Tuple

from repro.core import (BENCHMARKS, SimResult, base_spec, cluster_spec,
                        colt_spec, demand_mapping, generate_trace,
                        kaligned_for_mapping, rmm_spec, synthetic_mapping,
                        thp_spec)
from repro.core.baselines import anchor_spec
from repro.core.sweep import SweepCell, run_sweep

QUICK_BENCHES = ("mcf", "bwaves", "gups", "graph500", "omnetpp", "gromacs",
                 "xalancbmk", "libquantum")
ANCHOR_GRID_QUICK = (4, 6, 8, 10)
MAX_PAGES_DEFAULT = 1 << 19


def _bench_seed(name: str) -> int:
    """Stable per-benchmark mapping seed (process-independent, unlike
    ``hash(name)``, so the sweep cache works across runs)."""
    return zlib.crc32(name.encode()) % 1000


def _mapping_for(name: str, n_pages: int):
    return demand_mapping(n_pages, seed=_bench_seed(name))


class SweepPlan:
    """Accumulates tagged sweep cells; one ``run_sweep`` serves all rows.

    ``group="anchor"`` cells are reduced to the best (fewest walks) result
    per (row, label) — the Anchor-Static exhaustive-grid policy of §4.1.
    """

    def __init__(self):
        self.cells: List[SweepCell] = []
        self.tags: List[Tuple[str, str, str]] = []

    def add(self, spec, mapping, trace, row: str, label: str,
            group: str = "plain") -> None:
        self.cells.append(SweepCell(spec, mapping, trace))
        self.tags.append((row, label, group))

    def add_anchor_static(self, mapping, trace, row: str,
                          grid: Iterable[int],
                          label: str = "Anchor-Static") -> None:
        for d in grid:
            self.add(anchor_spec(d), mapping, trace, row, label,
                     group="anchor")

    def run(self, cache: bool = True) -> Dict[str, Dict[str, SimResult]]:
        sweep = run_sweep(self.cells, cache=cache)
        out: Dict[str, Dict[str, SimResult]] = {}
        for (row, label, group), r in zip(self.tags, sweep.results):
            cols = out.setdefault(row, {})
            if group == "anchor" and label in cols:
                if r.walks < cols[label].walks:
                    cols[label] = r
            else:
                cols[label] = r
        return out


def _add_suite(plan: SweepPlan, m, tr, row: str, anchor_grid,
               psis: Sequence[int] = (2, 3, 4)) -> None:
    plan.add(base_spec(), m, tr, row, "Base")
    plan.add(thp_spec(), m, tr, row, "THP")
    plan.add(rmm_spec(), m, tr, row, "RMM")
    plan.add(colt_spec(), m, tr, row, "COLT")
    plan.add(cluster_spec(), m, tr, row, "Cluster")
    plan.add_anchor_static(m, tr, row, anchor_grid)
    for psi in psis:
        spec = kaligned_for_mapping(m, psi=psi,
                                    theta=1.0 if psi > 2 else 0.9)
        plan.add(spec, m, tr, row, f"|K|={psi}")


def bench_synthetic(trace_len=150_000, n_pages=1 << 19, quick=True,
                    max_pages=MAX_PAGES_DEFAULT):
    """Table 4 synthetic-mapping rows."""
    n_pages = min(n_pages, max_pages)
    plan = SweepPlan()
    order = []
    for kind in ("small", "medium", "large", "mixed"):
        m = synthetic_mapping(kind, n_pages, seed=1)
        tr = generate_trace("multiscale", 0, trace_len, seed=2, mapping=m)
        _add_suite(plan, m, tr, kind, ANCHOR_GRID_QUICK)
        order.append(kind)
    res = plan.run()
    rows = []
    for kind in order:
        cols = res[kind]
        base = cols["Base"].walks
        rows.append({"mapping": kind,
                     **{k: round(v.walks / max(base, 1), 4)
                        for k, v in cols.items()}})
    return rows


def bench_demand(trace_len=150_000, quick=True, max_pages=None):
    """Figure 8: per-benchmark relative misses on the demand mapping.

    Footprints are only capped in quick/smoke tiers; ``--full`` runs the
    declared paper-scale footprints (up to 4GB of virtual address space).
    """
    cap = max_pages if max_pages is not None else (
        MAX_PAGES_DEFAULT if quick else None)
    benches = QUICK_BENCHES if quick else tuple(BENCHMARKS)
    plan = SweepPlan()
    for name in benches:
        pattern, n_pages = BENCHMARKS[name]
        m = _mapping_for(name, min(n_pages, cap) if cap else n_pages)
        tr = generate_trace(pattern, 0, trace_len, seed=3, mapping=m)
        _add_suite(plan, m, tr, name, ANCHOR_GRID_QUICK, psis=(2,))
    res = plan.run()
    rows = []
    for name in benches:
        cols = res[name]
        base = cols["Base"].walks
        rows.append({"benchmark": name,
                     **{k: round(v.walks / max(base, 1), 4)
                        for k, v in cols.items()}})
    return rows


def bench_coverage(trace_len=120_000, quick=True,
                   max_pages=MAX_PAGES_DEFAULT):
    """Table 5: relative TLB translation coverage (covered PTEs / 1024)."""
    benches = QUICK_BENCHES[:6] if quick else tuple(BENCHMARKS)
    plan = SweepPlan()
    for name in benches:
        pattern, n_pages = BENCHMARKS[name]
        m = _mapping_for(name, min(n_pages, max_pages))
        tr = generate_trace(pattern, 0, trace_len, seed=4, mapping=m)
        plan.add(base_spec(), m, tr, name, "Base")
        plan.add(colt_spec(), m, tr, name, "COLT")
        plan.add_anchor_static(m, tr, name, grid=(6, 8, 10))
        plan.add(kaligned_for_mapping(m, psi=2), m, tr, name, "|K|=2")
    res = plan.run()
    rows = []
    for name in benches:
        cols = res[name]
        denom = max(cols["Base"].coverage_mean, 1.0)
        rows.append({"benchmark": name, "Base": 1.0,
                     **{k: round(cols[k].coverage_mean / denom, 2)
                        for k in ("COLT", "Anchor-Static", "|K|=2")}})
    return rows


def bench_predictor(trace_len=120_000, quick=True,
                    max_pages=MAX_PAGES_DEFAULT):
    """Table 6: predictor accuracy per benchmark for |K| = 2, 3, 4."""
    benches = QUICK_BENCHES[:6] if quick else tuple(BENCHMARKS)
    plan = SweepPlan()
    for name in benches:
        pattern, n_pages = BENCHMARKS[name]
        m = _mapping_for(name, min(n_pages, max_pages))
        tr = generate_trace(pattern, 0, trace_len, seed=5, mapping=m)
        for psi in (2, 3, 4):
            plan.add(kaligned_for_mapping(m, psi=psi, theta=1.0), m, tr,
                     name, f"|K|={psi}")
    res = plan.run()
    return [{"benchmark": name,
             **{k: round(v.predictor_accuracy, 3)
                for k, v in res[name].items()}}
            for name in benches]


def bench_k_sweep(trace_len=150_000, n_pages=1 << 19,
                  max_pages=MAX_PAGES_DEFAULT):
    """Figure 9: misses of |K| modes relative to Anchor-Static (mixed)."""
    m = synthetic_mapping("mixed", min(n_pages, max_pages), seed=1)
    tr = generate_trace("multiscale", 0, trace_len, seed=6, mapping=m)
    plan = SweepPlan()
    plan.add_anchor_static(m, tr, "mixed", grid=ANCHOR_GRID_QUICK)
    for psi in (1, 2, 3, 4):
        plan.add(kaligned_for_mapping(m, psi=psi, theta=1.0), m, tr,
                 "mixed", f"|K|={psi}")
    res = plan.run()["mixed"]
    anch = res["Anchor-Static"]
    return [{"|K|": psi,
             "rel_misses_vs_anchor": round(
                 res[f"|K|={psi}"].walks / max(anch.walks, 1), 4)}
            for psi in (1, 2, 3, 4)]


def bench_cpi(trace_len=120_000, quick=True, max_pages=MAX_PAGES_DEFAULT):
    """Figures 10/11: translation cycles per access."""
    benches = ("gups", "mcf", "graph500") if quick else tuple(BENCHMARKS)
    plan = SweepPlan()
    for name in benches:
        pattern, n_pages = BENCHMARKS[name]
        m = _mapping_for(name, min(n_pages, max_pages))
        tr = generate_trace(pattern, 0, trace_len, seed=7, mapping=m)
        plan.add(base_spec(), m, tr, name, "Base")
        plan.add(thp_spec(), m, tr, name, "THP")
        plan.add(colt_spec(), m, tr, name, "COLT")
        plan.add_anchor_static(m, tr, name, grid=(6, 8, 10))
        for psi in (2, 3):
            plan.add(kaligned_for_mapping(m, psi=psi, theta=1.0), m, tr,
                     name, f"|K|={psi}")
    res = plan.run()
    return [{"benchmark": name,
             **{k: round(v.cpi, 3) for k, v in res[name].items()}}
            for name in benches]
