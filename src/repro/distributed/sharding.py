"""Logical-axis sharding rules (MaxText-style) for params and activations.

Params and activations use *logical* axis names; rule tables map them to mesh
axes.  Swapping a rule set re-shards the entire model — this is the main
lever the §Perf hillclimb turns.

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod.  ``pod`` joins the FSDP/data-parallel group by default (pipeline
parallelism over ``pod`` is available through ``repro.distributed.pipeline``).

Rule sets:
* ``default``      — FSDP over (pod×)data on the embed dim + Megatron TP over
                     model on heads/mlp/vocab; kv-heads replicated (GQA kv=8
                     does not divide a 16-way model axis).
* ``decode``       — decode caches: batch over (pod×)data, head_dim over
                     model (kv-head counts don't divide the model axis).
* ``decode_long``  — long-context decode: KV sequence sharded over data
                     (partial-softmax decode), batch replicated.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisVal = Union[None, str, Tuple[str, ...]]

# data-parallel super-axis: ("pod","data") on multi-pod meshes collapses to
# whatever subset exists on the current mesh (see _resolve).
DP = ("pod", "data")

PARAM_RULES: Dict[str, Dict[str, AxisVal]] = {
    "default": {
        "embed": DP,          # FSDP / ZeRO-3 shard dim
        "mlp": "model",
        "q_heads": "model",
        "kv_heads": None,     # kv=8 < model axis; replicate (small)
        "vocab": "model",
        "expert": DP,         # FSDP over experts (never the contraction dim)
        "layers": None,
    },
    # beyond-paper variant: shard experts over data too (less all-to-all,
    # more gather) — used in hillclimbing.
    "expert_dp": {
        "embed": DP, "mlp": "model", "q_heads": "model", "kv_heads": None,
        "vocab": "model", "expert": DP, "layers": None,
    },
    # 2D sharding for collective-bound cells: split embed over model too.
    "embed_2d": {
        "embed": "model", "mlp": DP, "q_heads": DP, "kv_heads": None,
        "vocab": "model", "expert": None, "layers": None,
    },
}

ACT_RULES: Dict[str, Dict[str, AxisVal]] = {
    "default": {
        "batch": DP,
        "seq": None,
        "embed": None,
        "q_heads": "model",
        "kv_heads": None,
        "head_dim": None,
        "vocab": "model",
        "mlp": "model",
        "kv_seq": None,
        "kv_head_dim": "model",
        "pages": None,
    },
    # decode: shard the KV cache along the sequence (flash-decoding split-K);
    # avoids the kv_heads/head_dim axis fights (GQA kv=8 vs 16-way model)
    # that made the partitioner replicate cache slices per layer.
    "decode": {
        "batch": DP,
        "seq": None,
        "embed": None,
        "q_heads": "model",
        "kv_heads": None,
        "head_dim": None,
        "vocab": "model",
        "mlp": "model",
        "kv_seq": "model",
        "kv_head_dim": None,
        "pages": None,
    },
    "decode_long": {
        "batch": None,          # batch 1
        "seq": None,
        "embed": None,
        "q_heads": "model",
        "kv_heads": None,
        "head_dim": None,
        "vocab": "model",
        "mlp": "model",
        "kv_seq": DP,           # sequence-parallel KV cache
        "kv_head_dim": "model",
        "pages": DP,
    },
    # sequence-parallel training activations (hillclimb option)
    "seq_parallel": {
        "batch": DP, "seq": "model", "embed": None, "q_heads": "model",
        "kv_heads": None, "head_dim": None, "vocab": "model",
        "mlp": "model",
        "kv_seq": None, "kv_head_dim": "model", "pages": None,
    },
}


def _resolve(axis: AxisVal, mesh: Mesh, dim_size: Optional[int] = None
             ) -> AxisVal:
    """Drop mesh axes that don't exist; drop sharding if not divisible."""
    if axis is None:
        return None
    names = axis if isinstance(axis, tuple) else (axis,)
    names = tuple(a for a in names if a in mesh.axis_names)
    if not names:
        return None
    if dim_size is not None:
        while names and dim_size % int(np.prod([mesh.shape[a] for a in names])) != 0:
            names = names[1:]   # drop outermost axis until divisible
        if not names:
            return None
    # preserve the declared form: tuple-valued rules stay tuples even when
    # axis dropping leaves a single mesh axis (("pod","data") -> ("data",))
    return names if (len(names) > 1 or isinstance(axis, tuple)) else names[0]


def logical_to_pspec(logical: Sequence[Optional[str]], mesh: Mesh,
                     rules: Dict[str, AxisVal],
                     shape: Optional[Sequence[int]] = None) -> P:
    used: set = set()
    out = []
    for i, name in enumerate(logical):
        ax = rules.get(name) if name else None
        ax = _resolve(ax, mesh, None if shape is None else shape[i])
        # a mesh axis may appear at most once in a PartitionSpec
        if ax is not None:
            was_tuple = isinstance(ax, tuple)
            names = ax if was_tuple else (ax,)
            names = tuple(a for a in names if a not in used)
            used.update(names)
            if not names:
                ax = None
            elif len(names) > 1 or was_tuple:
                ax = names
            else:
                ax = names[0]
        out.append(ax)
    return P(*out)


def param_sharding(logical_tree_: Any, shape_tree: Any, mesh: Mesh,
                   rule_set: str = "default") -> Any:
    rules = PARAM_RULES[rule_set]

    def make(logical, sds):
        return NamedSharding(mesh, logical_to_pspec(logical, mesh, rules,
                                                    sds.shape))
    return jax.tree.map(make, logical_tree_, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            a is None or isinstance(a, str) for a in x))


def act_pspec(logical: Sequence[Optional[str]], mesh: Mesh,
              rule_set: str = "default",
              shape: Optional[Sequence[int]] = None) -> P:
    return logical_to_pspec(logical, mesh, ACT_RULES[rule_set], shape)


def with_logical_constraint(x: jax.Array, logical: Sequence[Optional[str]],
                            mesh: Optional[Mesh], rule_set: str = "default"
                            ) -> jax.Array:
    if mesh is None:
        return x
    spec = act_pspec(logical, mesh, rule_set, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def dp_axis_names(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axis_names(mesh)]))
