"""Int8 error-feedback gradient reduction (distributed-optimization trick).

Replaces the fp32 grad all-reduce with: quantize local grads to int8 (per-
block scales) + error-feedback residual, ``all_gather`` the int8 payload over
the data axis, dequantize and mean locally.  Wire bytes drop ~3.5x vs an fp32
ring all-reduce; error feedback keeps the long-run update sequence unbiased
(EF-SGD / 1-bit Adam lineage).

Two entry points:

* :func:`ef_allreduce_inside` — for use *inside* an existing ``shard_map``
  over the data axis (the production path: grads are local per dp shard).
* :func:`ef_allreduce` — standalone wrapper over stacked per-shard grads
  ``[ndp, ...]`` (used by tests and the demo bench; it shard_maps the leading
  axis over dp).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

PyTree = Any
QBLOCK = 512


def _quant(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % QBLOCK
    blk = jnp.pad(flat, (0, pad)).reshape(-1, QBLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(blk), 1, keepdims=True), 1e-12) / 127.0
    q = jnp.clip(jnp.round(blk / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    return flat[: int(np.prod(shape))].reshape(shape)


def ef_allreduce_inside(g_local: jax.Array, residual: jax.Array,
                        axis_name) -> Tuple[jax.Array, jax.Array]:
    """Inside shard_map: returns (mean-of-shards grad, new residual)."""
    x = g_local.astype(jnp.float32) + residual
    q, s = _quant(x)
    new_resid = x - _dequant(q, s, x.shape)
    qg = jax.lax.all_gather(q, axis_name)        # [ndp, blocks, QBLOCK] int8
    sg = jax.lax.all_gather(s, axis_name)        # [ndp, blocks, 1]
    deq = qg.astype(jnp.float32) * sg
    mean = deq.mean(axis=0)
    out = mean.reshape(-1)[: int(np.prod(x.shape))].reshape(x.shape)
    return out, new_resid


def ef_allreduce(stacked: PyTree, residual: PyTree, mesh: Mesh,
                 dp_axis: str = "data") -> Tuple[PyTree, PyTree]:
    """stacked: pytree of ``[ndp, ...]`` arrays (per-shard local grads,
    leading axis sharded over ``dp_axis``).  Returns (mean grads broadcast to
    all shards ``[ndp, ...]``, new residuals ``[ndp, ...]``)."""

    def one(g, r):
        def inner(g_loc, r_loc):
            out, new_r = ef_allreduce_inside(g_loc[0], r_loc[0], dp_axis)
            return out[None], new_r[None]

        return shard_map(inner, mesh=mesh,
                         in_specs=(P(dp_axis), P(dp_axis)),
                         out_specs=(P(dp_axis), P(dp_axis)),
                         check_rep=False)(g, r)

    flat_g, td = jax.tree.flatten(stacked)
    flat_r = jax.tree.leaves(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(td, [o[0] for o in outs]),
            jax.tree.unflatten(td, [o[1] for o in outs]))


def init_residual_stacked(stacked: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), stacked)
