from .sharding import (ACT_RULES, PARAM_RULES, act_pspec, dp_axis_names,
                       dp_size, logical_to_pspec, param_sharding,
                       with_logical_constraint)
