"""GPipe-style pipeline parallelism over a mesh axis (e.g. "pod").

For multi-pod runs where cross-DCN data parallelism is bandwidth-starved,
the "pod" axis can instead carry pipeline stages: each pod owns a contiguous
block of layers; microbatches stream through with ``collective_permute``
between stages.  Implemented with ``shard_map`` so the schedule (and its
bubble) is explicit in the HLO for the §Roofline collective parser.

Schedule: plain GPipe (fill-drain).  Bubble fraction = (S-1)/(M+S-1) for S
stages and M microbatches — acceptable at M >= 4S, and the multi-pod mesh
only has S=2.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

PyTree = Any


def pipeline_forward(mesh: Mesh, stage_axis: str,
                     block_fn: Callable[[PyTree, jax.Array], jax.Array],
                     stage_params: PyTree, x_micro: jax.Array) -> jax.Array:
    """Run ``block_fn`` as a pipeline over ``stage_axis``.

    stage_params: pytree with leading dim n_stages (sharded over stage_axis);
    x_micro: [n_micro, Bm, ...] microbatched activations (replicated across
    the stage axis).  Returns outputs [n_micro, Bm, ...] from the last stage
    (broadcast to all stages for downstream use).
    """
    n_stages = mesh.shape[stage_axis]

    def body(params_local, xs):
        # params_local: [1, ...] this stage's params; xs: [n_micro, Bm, ...]
        params_me = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(stage_axis)
        n_micro = xs.shape[0]
        ticks = n_micro + n_stages - 1

        buf = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 injects microbatch t (when in range)
            inject = jnp.where(t < n_micro, t, n_micro - 1)
            x_in = jnp.where(stage == 0, xs[inject], buf)
            y = block_fn(params_me, x_in)
            # pass to the next stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf_next = jax.lax.ppermute(y, stage_axis, perm)
            # last stage emits microbatch t - (n_stages - 1)
            out_idx = t - (n_stages - 1)
            emit = (stage == n_stages - 1) & (out_idx >= 0)
            safe = jnp.clip(out_idx, 0, n_micro - 1)
            outs = jnp.where(emit, outs.at[safe].set(y), outs)
            return buf_next, outs

        buf, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
        # broadcast final outputs from the last stage to every stage
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            stage_axis)
        return outs

    in_specs = (jax.tree.map(lambda _: P(stage_axis), stage_params),
                P())
    return shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=P(),
                     check_rep=False)(stage_params, x_micro)
