"""Model-free scheduling core of the serving engine.

:class:`KVScheduler` owns everything about request scheduling that does NOT
require a model: the FCFS waiting queue, the running set, stable batch-slot
assignment, KV-capacity admission control against a
:class:`~repro.kvcache.allocator.PagedKVAllocator`, and vLLM-style
preempt-youngest-and-requeue under pool exhaustion.

It exists so the same policy code drives two consumers:

* :class:`repro.serve.engine.ServingEngine` — real decode: the engine keeps
  token state and kernels, the scheduler keeps queues/slots/pages;
* :mod:`repro.scenarios.workload` — scenario recording: the KV-churn
  scenarios replay admission/extend/preempt/free cycles against the buddy
  allocator to harvest mixed-contiguity block tables and access traces
  without instantiating a model.

Splitting it out also fixes a latent bug in the original inlined admission
loop: a preempted victim was pushed to the *front* of the waiting queue
before the admitted request was popped from it, so the ``popleft`` removed
the victim (losing it forever) and left the admitted request queued twice.
``admit`` now removes the admitted request by identity.
"""
from __future__ import annotations

from collections import deque
from typing import Callable, Deque, Dict, List, Optional

from ..kvcache.allocator import PagedKVAllocator

OnEvent = Optional[Callable[[int], None]]


class KVScheduler:
    """Queues, batch slots, and KV-capacity admission over an allocator.

    Requests are opaque integer ids; per-request page needs are supplied by
    the caller at admission time (``need_pages(rid)``), so the scheduler
    works for both token-level engines and page-level scenario drivers.
    """

    def __init__(self, allocator: PagedKVAllocator, max_batch: int,
                 event_tap: Optional[Callable[[str, int], None]] = None):
        self.allocator = allocator
        self.max_batch = max_batch
        self.waiting: Deque[int] = deque()
        self.running: List[int] = []
        self.slots: Dict[int, int] = {}            # rid → stable batch slot
        self._free_slots: List[int] = list(range(max_batch))
        self.preemptions = 0
        #: optional ``tap(kind, rid)`` observer fired on every scheduling
        #: action that changes the KV mapping ("admit" — after the slot is
        #: assigned and pages are held; "preempt"/"release" — after the
        #: pages are freed).  The dynamic-scenario recorder uses it to turn
        #: serving churn into a :class:`repro.core.page_table.MappingEvent`
        #: stream; the real engine runs untapped by default.
        self.event_tap = event_tap

    def _tap(self, kind: str, rid: int) -> None:
        if self.event_tap is not None:
            self.event_tap(kind, rid)

    # ------------------------------------------------------------------
    def enqueue(self, rid: int, front: bool = False) -> None:
        if front:
            self.waiting.appendleft(rid)
        else:
            self.waiting.append(rid)

    def admit(self, need_pages: Callable[[int], int],
              on_admit: OnEvent = None, on_preempt: OnEvent = None
              ) -> List[int]:
        """FCFS admission with KV-capacity control (ServingEngine policy).

        Walks the waiting queue head; when the pool cannot serve the head
        request, preempts the youngest running request (recompute-style) if
        more than one is running, then retries once.  ``on_admit(rid)`` fires
        after the slot is assigned; ``on_preempt(rid)`` while the victim
        still holds its pages (so callers can snapshot recompute state),
        before it is requeued at the front of the queue (a preempted request
        is re-admitted with priority).
        """
        admitted: List[int] = []
        preempted_now: set = set()
        while self.waiting and len(self.running) < self.max_batch:
            rid = self.waiting[0]
            if rid in preempted_now:
                break    # admitting it again would just thrash the pool
            if self.allocator.allocate(rid, need_pages(rid)) is None:
                # pool exhausted: preempt the youngest running request
                # (vLLM-style recompute preemption) if that frees enough
                if len(self.running) > 1:
                    victim = self.running[-1]
                    self.preempt(victim, on_preempt)
                    preempted_now.add(victim)
                    if self.allocator.allocate(rid, need_pages(rid)) is None:
                        break
                else:
                    break
            # the preempted victim now sits at waiting[0]; remove the
            # admitted request by identity, not by position
            self.waiting.remove(rid)
            self.running.append(rid)
            self.slots[rid] = self._free_slots.pop(0)
            admitted.append(rid)
            self._tap("admit", rid)
            if on_admit is not None:
                on_admit(rid)
        return admitted

    def preempt(self, rid: int, on_preempt: OnEvent = None) -> None:
        """Free ``rid``'s pages and requeue it at the front of the queue."""
        if on_preempt is not None:
            on_preempt(rid)          # rid still holds its pages here
        self.running.remove(rid)
        self._free_slots.insert(0, self.slots.pop(rid))
        self.allocator.free(rid)
        self.preemptions += 1
        self.waiting.appendleft(rid)
        self._tap("preempt", rid)

    def release(self, rid: int) -> None:
        """A finished request: recycle its slot and pages."""
        self.running.remove(rid)
        self._free_slots.append(self.slots.pop(rid))
        self.allocator.free(rid)
        self._tap("release", rid)

    # ------------------------------------------------------------------
    def snapshot_state(self) -> Dict:
        """Queues/slots/counters as a JSON-serializable dict (the allocator
        snapshots its own page state separately)."""
        return dict(waiting=list(self.waiting), running=list(self.running),
                    slots={str(r): s for r, s in self.slots.items()},
                    free_slots=list(self._free_slots),
                    preemptions=self.preemptions)

    def restore_state(self, snap: Dict) -> None:
        self.waiting = deque(int(r) for r in snap["waiting"])
        self.running = [int(r) for r in snap["running"]]
        self.slots = {int(r): int(s) for r, s in snap["slots"].items()}
        self._free_slots = [int(s) for s in snap["free_slots"]]
        self.preemptions = int(snap["preemptions"])

    # ------------------------------------------------------------------
    def slot_of(self, rid: int) -> int:
        return self.slots[rid]

    @property
    def has_work(self) -> bool:
        return bool(self.running or self.waiting)
