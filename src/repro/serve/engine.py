"""Serving engine: continuous batching over a coalesced paged KV cache.

This is where the paper's pieces meet end-to-end:

* the buddy :class:`PagedKVAllocator` produces mixed-contiguity block tables
  under admission/finish churn (the OS of §2);
* Algorithm 3 (``choose_kernel_classes``) picks the kernel classes K from the
  allocator's live contiguity histogram, re-evaluated when fragmentation
  drifts (the paper re-runs it every 5B instructions; we use a utilization
  delta trigger);
* each decode step runs the coalesced paged-attention kernel; descriptor
  tables are rebuilt only for sequences whose block tables changed
  (the paper's "aligned entries are filled by the OS after the walk");
* scheduler: FCFS admission with KV-capacity admission control, preempt-and-
  requeue on pool exhaustion (vLLM-style), per-step DMA-descriptor metrics
  (the TPU analogue of TLB-miss counts).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.paged_attention.ops import build_descriptors, dma_stats
from ..kvcache.allocator import PagedKVAllocator
from ..kvcache.block_table import choose_kernel_classes
from ..models.model import Model, block_period, n_superblocks, _mixer_kind
from .scheduler import KVScheduler


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: List[int]
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    state: str = "waiting"          # waiting | running | done | preempted


@dataclasses.dataclass
class EngineConfig:
    page_size: int = 16
    num_pages: int = 512
    max_batch: int = 4
    max_seq: int = 512              # logical pages per seq = max_seq/page_size
    psi: int = 3                    # |K| bound for Algorithm 3
    refresh_util_delta: float = 0.15
    alloc_policy: str = "buddy_best"
    interpret: bool = True
    greedy: bool = True


class ServingEngine:
    def __init__(self, model: Model, params: Any, ec: EngineConfig):
        cfg = model.cfg
        assert cfg.family != "encoder", "encoder models don't decode"
        self.model = model
        self.params = params
        self.ec = ec
        self.cfg = cfg
        self.nsb = n_superblocks(cfg)
        self.period = block_period(cfg)
        self.allocator = PagedKVAllocator(ec.num_pages,
                                          alloc_policy=ec.alloc_policy)
        self.sched = KVScheduler(self.allocator, ec.max_batch)
        self.K: List[int] = []
        self._k_util = 0.0
        self.requests: Dict[int, Request] = {}
        self._next_id = 0
        self.metrics: Dict[str, float] = {
            "steps": 0, "tokens": 0, "dma_descriptors": 0,
            "dma_descriptors_page_granular": 0, "preemptions": 0,
            "kv_quarantined_pages": 0}
        self._init_state()

    # ------------------------------------------------------------------
    def _init_state(self):
        cfg, ec = self.cfg, self.ec
        B = ec.max_batch
        dt = jnp.dtype(self.model.rc.compute_dtype)
        state: Dict[str, Any] = {}
        for j in range(self.period):
            mk = _mixer_kind(cfg, j)
            if mk == "attn":
                pool = jnp.zeros((self.nsb, ec.num_pages, ec.page_size,
                                  cfg.n_kv_heads, cfg.head_dim), dt)
                state[f"pos{j}"] = {"pool_k": pool, "pool_v": pool}
            else:
                from ..models.model import init_decode_state
                full = init_decode_state(cfg, self.model.rc, B, 8, dt)
                state[f"pos{j}"] = full[f"pos{j}"]
        self.state = state

    # ------------------------------------------------------------------
    def add_request(self, prompt: List[int], max_new_tokens: int = 16) -> int:
        # An oversized request can never be served: its block table would
        # silently truncate past max_seq pages, and one whose page need
        # exceeds the whole pool live-locks admission forever (the FCFS
        # head retries every step, preempting the rest of the batch).
        # Reject at the door instead.
        total = len(prompt) + max_new_tokens
        if total > self.ec.max_seq:
            raise ValueError(
                f"request needs {total} tokens (prompt {len(prompt)} + "
                f"max_new_tokens {max_new_tokens}) but max_seq is "
                f"{self.ec.max_seq}")
        need = -(-total // self.ec.page_size)
        if need > self.ec.num_pages:
            raise ValueError(
                f"request needs {need} KV pages but the pool only has "
                f"{self.ec.num_pages}: it could never be admitted")
        rid = self._next_id
        self._next_id += 1
        self.requests[rid] = Request(rid, list(prompt), max_new_tokens)
        self.sched.enqueue(rid)
        return rid

    # scheduling state lives in the model-free KVScheduler core (shared with
    # the scenario recorder in repro.scenarios.workload)
    @property
    def waiting(self):
        return self.sched.waiting

    @property
    def running(self) -> List[int]:
        return self.sched.running

    def _maybe_refresh_k(self):
        util = self.allocator.utilization()
        if not self.K or abs(util - self._k_util) > self.ec.refresh_util_delta:
            hist = self.allocator.contiguity_histogram()
            self.K = choose_kernel_classes(hist, psi=self.ec.psi) or [0]
            self._k_util = util

    def _need_pages(self, rid: int) -> int:
        req = self.requests[rid]
        return -(-(len(req.prompt) + req.max_new_tokens) // self.ec.page_size)

    def _admit(self):
        self.sched.admit(self._need_pages, on_admit=self._on_admit,
                         on_preempt=self._on_preempt)

    def _on_admit(self, rid: int) -> None:
        self.requests[rid].state = "running"
        self._prefill(rid)

    def _on_preempt(self, rid: int) -> None:
        """Recompute-style preemption bookkeeping.

        The victim keeps its ``generated`` list (the user must receive every
        token produced); on re-admission :meth:`_prefill` recomputes the KV
        for ``prompt + generated`` and decoding continues from there.  (An
        earlier version folded the generated tokens into ``prompt`` and
        cleared the list, silently dropping them from the final output.)
        """
        self.requests[rid].state = "preempted"
        self.metrics["preemptions"] += 1

    def _slot_of(self, rid: int) -> int:
        return self.sched.slot_of(rid)

    def _prefill(self, rid: int):
        """Run prompt (+ any recompute-preempted generation) through the
        model and write KV into the pages."""
        req = self.requests[rid]
        toks_list = req.prompt + req.generated
        toks = jnp.asarray(toks_list, jnp.int32)[None]
        logits, states = jax.jit(self.model.prefill, static_argnames=())(
            self.params, toks)
        bt = self.allocator.block_table(rid, self.max_pages)
        T = self.ec.page_size
        S = len(toks_list)
        n_full = -(-S // T)
        slot = self._slot_of(rid)
        for j in range(self.period):
            if _mixer_kind(self.cfg, j) != "attn":
                # recurrent states: copy into the batch slot
                st = states[f"pos{j}"]
                for key, val in st.items():
                    cur = self.state[f"pos{j}"][key]
                    upd = val[:, 0]
                    self.state[f"pos{j}"][key] = cur.at[:, slot].set(
                        upd.astype(cur.dtype))
                continue
            k = states[f"pos{j}"]["k"][:, 0]     # [nsb, maxS, KVH, D]
            v = states[f"pos{j}"]["v"][:, 0]
            pool_k = self.state[f"pos{j}"]["pool_k"]
            pool_v = self.state[f"pos{j}"]["pool_v"]
            pad = n_full * T - S
            kpad = jnp.pad(k[:, :S], ((0, 0), (0, pad), (0, 0), (0, 0)))
            vpad = jnp.pad(v[:, :S], ((0, 0), (0, pad), (0, 0), (0, 0)))
            pages = jnp.asarray(bt[:n_full], jnp.int32)
            kpages = kpad.reshape(self.nsb, n_full, T, *k.shape[2:])
            vpages = vpad.reshape(self.nsb, n_full, T, *v.shape[2:])
            self.state[f"pos{j}"]["pool_k"] = pool_k.at[:, pages].set(
                kpages.astype(pool_k.dtype))
            self.state[f"pos{j}"]["pool_v"] = pool_v.at[:, pages].set(
                vpages.astype(pool_v.dtype))
        # seed first generated token greedily from the last prompt position
        nxt = int(jnp.argmax(logits[0, S - 1, : self.cfg.vocab]))
        req.generated.append(nxt)

    @property
    def max_pages(self) -> int:
        return self.ec.max_seq // self.ec.page_size

    def _reap_finished(self) -> None:
        """Release running requests that hit their token budget (a
        re-admitted preemption victim may reach it at prefill, before any
        decode step — decoding it again would append an extra token)."""
        for rid in list(self.running):
            req = self.requests[rid]
            if len(req.generated) >= req.max_new_tokens:
                req.state = "done"
                self.sched.release(rid)

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One engine iteration: admit, decode one token for all running."""
        self._admit()
        self._reap_finished()
        if not self.running:
            return bool(self.waiting)
        self._maybe_refresh_k()
        ec = self.ec
        B = ec.max_batch
        toks = np.zeros((B, 1), np.int32)
        lens = np.zeros((B,), np.int32)
        tables = np.full((B, self.max_pages), -1, np.int32)
        active = np.zeros((B,), bool)
        for rid in self.running:
            slot = self._slot_of(rid)
            req = self.requests[rid]
            toks[slot, 0] = req.generated[-1]
            lens[slot] = len(req.prompt) + len(req.generated) - 1
            tables[slot] = self.allocator.block_table(rid, self.max_pages)
            active[slot] = True

        descriptors = build_descriptors(tables, self.K)
        st = dma_stats(tables, self.K)
        self.metrics["dma_descriptors"] += st["descriptors_coalesced"]
        self.metrics["dma_descriptors_page_granular"] += st["pages"]

        logits, self.state = self.model.decode_step_paged(
            self.params, self.state, jnp.asarray(toks), jnp.asarray(lens),
            tables, descriptors, page_size=ec.page_size,
            K_classes=tuple(self.K), interpret=ec.interpret)

        nxt = np.asarray(jnp.argmax(logits[:, 0, : self.cfg.vocab], axis=-1))
        finished = []
        for rid in list(self.running):
            slot = self._slot_of(rid)
            req = self.requests[rid]
            req.generated.append(int(nxt[slot]))
            self.metrics["tokens"] += 1
            if len(req.generated) >= req.max_new_tokens:
                req.state = "done"
                finished.append(rid)
        for rid in finished:
            self.sched.release(rid)
        self.metrics["steps"] += 1
        return bool(self.running or self.waiting)

    def run_to_completion(self, max_steps: int = 10_000) -> Dict[str, float]:
        for _ in range(max_steps):
            if not self.step():
                break
        m = dict(self.metrics)
        # max_steps exhaustion must never be silent: `stalled` counts the
        # requests still waiting/running when the loop gave up (0 = drained)
        m["stalled"] = len(self.waiting) + len(self.running)
        pg = m["dma_descriptors_page_granular"]
        m["descriptor_reduction"] = 1.0 - m["dma_descriptors"] / max(pg, 1)
        m["K"] = list(self.K)
        return m

    # ------------------------------------------------------------------
    # Robustness: crash-restart checkpoints and KV-page quarantine
    # ------------------------------------------------------------------
    def snapshot(self, ckpt_dir: str, step: int = None) -> int:
        """Checkpoint the complete engine state (KV pool pytree via the
        atomic :class:`~repro.checkpoint.checkpointer.Checkpointer`; the
        request/scheduler/allocator bookkeeping rides in ``extras``).  A
        fresh engine built from the same (model, params, config) that
        :meth:`restore`\\ s this checkpoint continues token-exactly —
        ``tests/test_robustness.py`` proves it against the fault-free run."""
        from ..checkpoint.checkpointer import Checkpointer
        extras = dict(
            requests={str(r): dict(prompt=[int(t) for t in q.prompt],
                                   max_new_tokens=int(q.max_new_tokens),
                                   generated=[int(t) for t in q.generated],
                                   state=q.state)
                      for r, q in self.requests.items()},
            scheduler=self.sched.snapshot_state(),
            allocator=self.allocator.snapshot_state(),
            K=list(self.K), k_util=self._k_util, next_id=self._next_id,
            metrics=dict(self.metrics))
        step = int(self.metrics["steps"]) if step is None else int(step)
        Checkpointer(ckpt_dir).save(step, self.state, extras, blocking=True)
        return step

    def restore(self, ckpt_dir: str, step: int = None) -> int:
        """Reload a :meth:`snapshot` into this engine (crash-restart)."""
        from ..checkpoint.checkpointer import Checkpointer
        tree, extras = Checkpointer(ckpt_dir).restore(step, target=self.state)
        self.state = jax.tree.map(jnp.asarray, tree)
        self.requests = {
            int(r): Request(int(r), [int(t) for t in d["prompt"]],
                            int(d["max_new_tokens"]),
                            [int(t) for t in d["generated"]], d["state"])
            for r, d in extras["requests"].items()}
        self.sched.restore_state(extras["scheduler"])
        self.allocator.restore_state(extras["allocator"])
        self.K = [int(k) for k in extras["K"]]
        self._k_util = float(extras["k_util"])
        self._next_id = int(extras["next_id"])
        self.metrics = dict(extras["metrics"])
        return int(extras["metrics"]["steps"])

    def quarantine_pages(self, pages) -> List[int]:
        """Corrupted-KV-page recovery: recompute-preempt every owning
        request (its ``generated`` tokens are kept, so re-prefill rebuilds
        the exact KV the corrupted pages held), then retire the poisoned
        physical pages from the pool permanently so re-admission cannot
        land on them.  Returns the preempted request ids."""
        owners = self.allocator.owners_of(pages)
        for rid in owners:
            self.sched.preempt(rid, self._on_preempt)
        retired = self.allocator.retire_pages(pages)
        self.metrics["kv_quarantined_pages"] += len(retired)
        return owners
