from .engine import EngineConfig, Request, ServingEngine
from .scheduler import KVScheduler
