"""Multi-tenant scenarios: many address spaces time-sharing one TLB.

The ROADMAP north star is a serving system under heavy traffic from many
users — which at the translation layer means many tenants context-switching
on one TLB, each bringing its *own* contiguity signature (the paper's
"mixed contiguity" taken to its serving-stack conclusion).  Each scenario
here produces a :class:`repro.core.page_table.MultiTenantMapping`: per-
tenant address spaces drawn from the Table-3 synthetic families, plus a
context-switch schedule **derived from the serving stack's own scheduling
core** — a :class:`repro.serve.scheduler.KVScheduler` runs decode rounds
over the tenants (admission, batch slots, preemption under pool pressure),
and every decode quantum of a running tenant becomes one schedule segment.
ASIDs are the scheduler's batch slots, so ASID *recycling* (a departed
tenant's slot re-assigned to a newcomer) falls out of slot reuse exactly
the way it does in the real engine.

* ``mt-serve-mix``    — four resident tenants drawn from the
  small/medium/large/mixed contiguity families, round-robin decode
  quanta: different tenants exhibit *different* contiguity types
  simultaneously.
* ``mt-churn``        — a stream of tenants arriving and departing under
  pool pressure (admission control + preemption), so batch slots — and
  with them ASIDs — are recycled to new tenants mid-trace.
* ``mt-flush-vs-tag`` — few small-footprint tenants under a deliberately
  switch-heavy schedule: the world where the ``ctx_policy`` knob
  (flush-on-switch vs ASID tags) separates most; sweep it under both.

All builders are deterministic in the request seeds.  ``meta`` reports the
schedule (segments, switches, recycles), the scheduler's event taps, and
the merged contiguity histogram Algorithm 3 should see.
"""
from __future__ import annotations

from collections import Counter
from typing import Dict, List, Tuple

import numpy as np

from ..core.page_table import Mapping, build_multitenant_mapping
from ..kvcache.allocator import PagedKVAllocator
from ..serve.scheduler import KVScheduler
from .base import ScenarioData, ScenarioRequest, scenario
from .synthetic import SYNTH_KINDS
from .workload import _episode_seed

#: decode rounds a tenant runs before completing (mt-churn keeps this small
#: so slots actually recycle within a smoke-length trace)
RESIDENT_ROUNDS = 1_000_000


def _tenant_worlds(kinds: List[str], req: ScenarioRequest,
                   tenant_pages: int) -> Tuple[List[Mapping],
                                               List[np.ndarray]]:
    """One synthetic (mapping, trace stream) per tenant, seeded per tenant
    so equal-kind tenants still get independent address spaces."""
    from .base import get_scenario
    maps: List[Mapping] = []
    streams: List[np.ndarray] = []
    for i, kind in enumerate(kinds):
        d = get_scenario(f"synth-{kind}").materialize(
            n_pages=tenant_pages, trace_len=req.trace_len,
            map_seed=req.map_seed * 17 + i + 1,
            trace_seed=req.trace_seed * 31 + i + 1)
        maps.append(d.mapping)
        streams.append(np.asarray(d.trace))
    return maps, streams


class _DecodeRoundScheduler:
    """Runs KVScheduler decode rounds over tenants; emits the segment list.

    Tenants are scheduler requests: admitted FCFS under KV-capacity
    control, preempted youngest-first under pool pressure, released after
    their round budget — the same policy code
    :class:`repro.serve.engine.ServingEngine` runs.  Each round, every
    running tenant decodes one quantum; the quantum is one schedule
    segment under the tenant's batch slot as ASID.
    """

    def __init__(self, pool_pages: int, max_batch: int):
        self.alloc = PagedKVAllocator(pool_pages, alloc_policy="buddy_best")
        self.sched = KVScheduler(self.alloc, max_batch)
        self.taps: Counter = Counter()
        self.sched.event_tap = lambda kind, rid: self.taps.update([kind])
        self.need: Dict[int, int] = {}
        self.rounds_left: Dict[int, int] = {}

    def enqueue(self, rid: int, need_pages: int, rounds: int) -> None:
        self.need[rid] = max(int(need_pages), 1)
        self.rounds_left[rid] = max(int(rounds), 1)
        self.sched.enqueue(rid)

    def run(self, quantum: int, total: int,
            arrivals=None) -> List[Tuple[int, int, int]]:
        """Emit ``(t, tenant_id, asid)`` segments until ``total`` steps.

        ``arrivals(round_idx)`` may enqueue more tenants (mt-churn)."""
        schedule: List[Tuple[int, int, int]] = []
        t = 0
        rnd = 0
        while t < total:
            if arrivals is not None:
                arrivals(rnd)
            self.sched.admit(lambda rid: self.need[rid])
            running = list(self.sched.running)
            if not running:
                break
            for rid in running:
                if t >= total:
                    break
                schedule.append((t, rid, self.sched.slot_of(rid)))
                t += quantum
                self.rounds_left[rid] -= 1
                if self.rounds_left[rid] <= 0:
                    self.sched.release(rid)
            rnd += 1
        return schedule


def _assemble(name: str, maps: List[Mapping], streams: List[np.ndarray],
              schedule: List[Tuple[int, int, int]], req: ScenarioRequest,
              drv: _DecodeRoundScheduler, kinds: List[str]) -> ScenarioData:
    """Stitch per-tenant trace streams along the schedule; build the world."""
    mt = build_multitenant_mapping(maps, schedule, name=name)
    bounds = list(mt.boundaries) + [req.trace_len]
    cursor = [0] * len(maps)
    parts: List[np.ndarray] = []
    for s in range(mt.n_segments):
        tid = mt.tenant_ids[s]
        n = bounds[s + 1] - bounds[s]
        stream = streams[tid]
        idx = (np.arange(cursor[tid], cursor[tid] + n)) % stream.shape[0]
        parts.append(stream[idx])
        cursor[tid] += n
    trace = np.concatenate(parts)[: req.trace_len]
    meta = {
        "tenant_kinds": list(kinds),
        "n_tenants": mt.n_tenants,
        "n_segments": mt.n_segments,
        "switches": mt.n_switches(),
        "recycles": int(sum(mt.recycled)),
        "asids": sorted(set(mt.asids)),
        "sched_events": dict(drv.taps),
        "preemptions": drv.sched.preemptions,
        "contiguity_histogram": mt.merged_contiguity_histogram(),
    }
    return ScenarioData(name, mt.tenants[0], trace, meta=meta,
                        multitenant=mt)


def _tenant_pages(req: ScenarioRequest, n_tenants: int) -> int:
    return int(max(req.n_pages // n_tenants, 256))


@scenario("mt-serve-mix", family="multitenant",
          description="four resident tenants (small/medium/large/mixed "
                      "contiguity families) round-robin decoding under the "
                      "KVScheduler; ASIDs are batch slots",
          contiguity="four different per-tenant signatures interleaved "
                     "through one TLB")
def _mt_serve_mix(req: ScenarioRequest) -> ScenarioData:
    kinds = list(SYNTH_KINDS)
    maps, streams = _tenant_worlds(kinds, req, _tenant_pages(req, 4))
    quantum = max(req.trace_len // 40, 8)
    # pool sized so all four tenants stay resident: switching pressure
    # comes from the round-robin quanta, not from churn
    drv = _DecodeRoundScheduler(pool_pages=1 << 10, max_batch=4)
    for i in range(4):
        drv.enqueue(i, need_pages=64, rounds=RESIDENT_ROUNDS)
    schedule = drv.run(quantum, req.trace_len)
    return _assemble("mt-serve-mix", maps, streams, schedule, req, drv,
                     kinds)


@scenario("mt-churn", family="multitenant",
          description="tenants arrive and depart under pool pressure "
                      "(KVScheduler admission + preemption); departed "
                      "tenants' batch slots — their ASIDs — are recycled "
                      "to newcomers",
          contiguity="rotating cast of per-tenant signatures; ASID "
                     "recycling forces targeted invalidation under tags")
def _mt_churn(req: ScenarioRequest) -> ScenarioData:
    n_tenants = 8
    kinds = [SYNTH_KINDS[i % len(SYNTH_KINDS)] for i in range(n_tenants)]
    maps, streams = _tenant_worlds(kinds, req, _tenant_pages(req, 4))
    quantum = max(req.trace_len // 56, 8)
    rng = np.random.default_rng(_episode_seed(req))
    # pool fits ~2 of 3 batch slots: admission control blocks some heads
    # and preempts the youngest running tenant for others, so slots (=
    # ASIDs) recycle and tenants bounce between slots
    drv = _DecodeRoundScheduler(pool_pages=512, max_batch=3)
    next_rid = [0]

    def arrivals(rnd: int) -> None:
        while next_rid[0] < n_tenants and len(drv.sched.waiting) < 2:
            rid = next_rid[0]
            next_rid[0] += 1
            drv.enqueue(rid, need_pages=int(rng.integers(160, 256)),
                        rounds=int(rng.integers(3, 7)))

    schedule = drv.run(quantum, req.trace_len, arrivals=arrivals)
    return _assemble("mt-churn", maps, streams, schedule, req, drv, kinds)


@scenario("mt-flush-vs-tag", family="multitenant",
          description="three small-footprint tenants under a deliberately "
                      "switch-heavy round-robin schedule — the world where "
                      "the flush-vs-tag ctx_policy knob separates most; "
                      "sweep it under both policies",
          contiguity="small per-tenant working sets that fit in the TLB: "
                     "tags retain them across switches, flushes refault")
def _mt_flush_vs_tag(req: ScenarioRequest) -> ScenarioData:
    kinds = ["small", "medium", "small"]
    maps, streams = _tenant_worlds(kinds, req,
                                   _tenant_pages(req, 16))
    quantum = max(req.trace_len // 96, 4)
    drv = _DecodeRoundScheduler(pool_pages=1 << 9, max_batch=3)
    for i in range(3):
        drv.enqueue(i, need_pages=32, rounds=RESIDENT_ROUNDS)
    schedule = drv.run(quantum, req.trace_len)
    return _assemble("mt-flush-vs-tag", maps, streams, schedule, req, drv,
                     kinds)
