"""Synthetic scenario families: the paper's own mapping/trace analogues.

These wrap :mod:`repro.core.mappings` and :mod:`repro.core.traces` behind the
registry with **exact parity**: for equal seeds, ``synth-*``/``demand*``
materialize the same arrays the old direct two-liner produced (enforced by
``tests/test_scenarios.py``), so sweep-cache keys are stable across the
refactor.

* ``synth-{small,medium,large,mixed}`` — Table 3 chunk-size families over a
  multiscale reuse trace (the Table 4 rows).
* ``demand`` / ``demand-thp``        — churned buddy-allocator demand paging
  (Fig 8 / Table 4 "Real Mapping").
* ``paper-<bench>``                  — one per paper benchmark (Figure 8):
  the benchmark's access-pattern analogue over a demand mapping whose seed is
  pinned per benchmark (``crc32(name) % 1000``, process-independent so the
  sweep cache works across runs).  ``map_seed`` is ignored; ``n_pages`` caps
  the declared paper footprint.
"""
from __future__ import annotations

import zlib

from ..core.mappings import demand_mapping, synthetic_mapping
from ..core.traces import BENCHMARKS, generate_trace
from .base import ScenarioData, ScenarioRequest, scenario

SYNTH_KINDS = ("small", "medium", "large", "mixed")


def _register_synth(kind: str) -> None:
    @scenario(f"synth-{kind}", family="synthetic",
              description=f"Table 3 '{kind}' chunk-size family, "
                          "multiscale reuse trace",
              contiguity={"small": "chunks of 1–63 pages",
                          "medium": "chunks of 64–511 pages",
                          "large": "chunks of 512–1024 pages",
                          "mixed": "0.4 small + 0.4 medium + 0.2 large",
                          }[kind])
    def _build(req: ScenarioRequest, kind: str = kind) -> ScenarioData:
        m = synthetic_mapping(kind, req.n_pages, seed=req.map_seed)
        tr = generate_trace("multiscale", 0, req.trace_len,
                            seed=req.trace_seed, mapping=m)
        return ScenarioData(f"synth-{kind}", m, tr)


for _kind in SYNTH_KINDS:
    _register_synth(_kind)


def _register_demand(thp: bool) -> None:
    name = "demand-thp" if thp else "demand"
    @scenario(name, family="synthetic",
              description="churned buddy-allocator demand paging"
                          + (" with THP-preferring order-9 requests"
                             if thp else ""),
              contiguity="power-of-two buddy runs, sizes mixed by churn"
                         + ("; mostly 512-page blocks" if thp else ""))
    def _build(req: ScenarioRequest, thp: bool = thp) -> ScenarioData:
        m = demand_mapping(req.n_pages, seed=req.map_seed, thp=thp)
        tr = generate_trace("multiscale", 0, req.trace_len,
                            seed=req.trace_seed, mapping=m)
        return ScenarioData(name, m, tr)


_register_demand(False)
_register_demand(True)


def paper_bench_seed(name: str) -> int:
    """Stable per-benchmark mapping seed (process-independent, unlike
    ``hash(name)``, so the sweep cache works across runs)."""
    return zlib.crc32(name.encode()) % 1000


def _register_paper_bench(bname: str) -> None:
    pattern, footprint = BENCHMARKS[bname]

    @scenario(f"paper-{bname}", family="synthetic",
              description=f"paper benchmark analogue '{bname}' "
                          f"({pattern} pattern) over a demand mapping",
              contiguity="demand-paged buddy runs over "
                         f"a {footprint}-page footprint")
    def _build(req: ScenarioRequest, bname: str = bname,
               pattern: str = pattern, footprint: int = footprint
               ) -> ScenarioData:
        n = min(footprint, req.n_pages)
        m = demand_mapping(n, seed=paper_bench_seed(bname))
        tr = generate_trace(pattern, 0, req.trace_len,
                            seed=req.trace_seed, mapping=m)
        return ScenarioData(f"paper-{bname}", m, tr,
                            meta={"pattern": pattern,
                                  "paper_footprint": footprint})


for _bname in BENCHMARKS:
    _register_paper_bench(_bname)
