"""Dynamic scenarios: live MappingEvent streams instead of frozen snapshots.

The workload and adversarial families record churn and then *flatten* it
into one static mapping — coalesced entries never face the event that
actually stresses them: a remap invalidating a range one aligned entry
covers.  Each scenario here re-emits its source's churn as a
:class:`repro.core.page_table.DynamicMapping`: epoch snapshots + the event
stream between them + the trace positions where each epoch begins.  The
static recordings (``kv-churn``, ``adv-compaction``, ``adv-thp-split``)
stay registered for parity.

* ``dyn-kv-churn``    — the paged KV cache with churn left ON between trace
  segments: the :class:`~repro.serve.scheduler.KVScheduler` event tap
  records admit/preempt/release while the buddy pool reassigns frames, so
  block-table entries cached by the TLB genuinely die mid-trace.
* ``dyn-compaction``  — incremental ``kcompactd`` passes: every epoch
  migrates a fresh fraction of the chunks into the dense region, shooting
  down whatever reach the TLBs built over the previous epoch.
* ``dyn-thp-split``   — progressive THP splitting: each epoch punches new
  holes into surviving huge runs (COW / ``MADV_DONTNEED``), the failure
  mode 2MB-entry schemes are most exposed to.

All builders are deterministic in the request seeds.  ``meta`` reports the
event mix, per-epoch dirty-page counts and epoch boundaries.
"""
from __future__ import annotations

from collections import Counter
from typing import List, Tuple

import numpy as np

from ..core.mappings import demand_mapping
from ..core.page_table import (MappingEvent, apply_event, build_dynamic_mapping,
                               contiguity_chunks, contiguity_histogram,
                               dynamic_from_snapshots, make_mapping,
                               next_pow2 as _next_pow2)
from ..core.traces import generate_trace
from .base import ScenarioData, ScenarioRequest, scenario
from .workload import _ChurnDriver, _episode_seed, _record_decode_sweep

N_EPOCHS = 4
INTER_EPOCH_CHURN = 8      # scheduler steps of live churn between segments


def _dyn_meta(dyn, extra=None):
    meta = {
        "n_epochs": dyn.n_epochs,
        "boundaries": list(dyn.boundaries),
        "events": dict(Counter(ev.kind for evs in dyn.events for ev in evs)),
        "dirty_pages": [dyn.dirty_count(e) for e in range(1, dyn.n_epochs)],
        "contiguity_histogram": contiguity_histogram(dyn.epochs[0]),
    }
    meta.update(extra or {})
    return meta


def _epoch_trace_segments(dyn, req: ScenarioRequest) -> np.ndarray:
    """Per-epoch multiscale traces over the epoch's own mapping, stitched at
    the boundaries (each access touches a page mapped in its epoch)."""
    bounds = dyn.boundaries + (req.trace_len,)
    parts = []
    for e in range(dyn.n_epochs):
        n = bounds[e + 1] - bounds[e]
        parts.append(generate_trace("multiscale", 0, n,
                                    seed=req.trace_seed * 131 + e,
                                    mapping=dyn.epochs[e]))
    return np.concatenate(parts)


# ---------------------------------------------------------------------------
# KV-cache serving churn, left live between trace segments
# ---------------------------------------------------------------------------


@scenario("dyn-kv-churn", family="dynamic",
          description="paged KV cache with serving churn ON between decode "
                      "segments: KVScheduler event tap + buddy frame "
                      "reassignment produce mid-trace remaps",
          contiguity="mixed buddy runs whose backing dies and reappears "
                     "across epochs")
def _dyn_kv_churn(req: ScenarioRequest) -> ScenarioData:
    pool = int(min(max(req.n_pages, 1 << 10), 1 << 14))
    drv = _ChurnDriver(pool, "buddy_best", _episode_seed(req))
    taps: Counter = Counter()
    drv.sched.event_tap = lambda kind, rid: taps.update([kind])
    drv.churn()
    # fixed per-slot VA stride for the whole episode (sequences never exceed
    # pool//2 prompt + 8 decode pages, see _ChurnDriver._draw_request)
    stride = _next_pow2(drv.pool // 2 + 9)
    seg = max(req.trace_len // N_EPOCHS, 1)

    snaps = []
    bounds: List[int] = []
    rec_all: List[Tuple[int, int]] = []
    for e in range(N_EPOCHS):
        if e:
            drv.churn(INTER_EPOCH_CHURN)
        rec = _record_decode_sweep(drv, seg)[:seg]
        if not rec:
            break
        bounds.append(len(rec_all))
        rec_all.extend(rec)
        # snapshot AFTER the segment: within a segment sequences only grow
        # (allow_churn=False), so every recorded access is mapped here and
        # no recorded translation changed since the segment began
        snaps.append(drv.snapshot_mapping(stride, name=f"dyn-kv-churn@{e}"))
    dyn = dynamic_from_snapshots(snaps, bounds, name="dyn-kv-churn")
    arr = np.asarray(rec_all, dtype=np.int64)
    trace = arr[:, 0] * stride + arr[:, 1]
    meta = _dyn_meta(dyn, {
        "pool_pages": drv.pool,
        "sched_events": dict(taps),
        "preemptions": drv.sched.preemptions,
        "extends": drv.extends,
        "completions": drv.completions,
    })
    return ScenarioData("dyn-kv-churn", dyn.epochs[0], trace, meta=meta,
                        dynamic=dyn)


# ---------------------------------------------------------------------------
# Incremental OS events over a demand mapping
# ---------------------------------------------------------------------------


@scenario("dyn-compaction", family="dynamic",
          description="kcompactd running live: each epoch migrates a fresh "
                      "fraction of the chunks into one dense region, "
                      "invalidating previously coalesced reach",
          contiguity="progressively bimodal: the compacted run grows every "
                     "epoch while the rest stays fragmented")
def _dyn_compaction(req: ScenarioRequest) -> ScenarioData:
    m0 = demand_mapping(req.n_pages, seed=req.map_seed)
    rng = np.random.default_rng(req.map_seed + 1)
    seg = max(req.trace_len // N_EPOCHS, 2)
    ppn = m0.ppn
    dest = int(ppn.max()) + 2
    schedule = []
    for e in range(1, N_EPOCHS):
        chunks = contiguity_chunks(make_mapping(ppn))
        picked = rng.random(len(chunks)) < 0.25
        evs = []
        for (start, size), take in zip(chunks, picked):
            if not take:
                continue
            evs.append(MappingEvent("compact", start, size, ppn=dest))
            dest += size           # contiguous with the previous migrant
        schedule.append((e * seg, evs))
        for ev in evs:
            ppn = apply_event(ppn, ev)
    dyn = build_dynamic_mapping(m0.ppn, schedule, name="dyn-compaction")
    trace = _epoch_trace_segments(dyn, req)
    return ScenarioData("dyn-compaction", dyn.epochs[0], trace,
                        meta=_dyn_meta(dyn), dynamic=dyn)


@scenario("dyn-thp-split", family="dynamic",
          description="progressive THP splitting: every epoch punches new "
                      "holes into surviving huge runs (COW / MADV_DONTNEED "
                      "analogue)",
          contiguity="512-page runs shattered a little further each epoch")
def _dyn_thp_split(req: ScenarioRequest) -> ScenarioData:
    m0 = demand_mapping(req.n_pages, seed=req.map_seed, thp=True)
    rng = np.random.default_rng(req.map_seed + 1)
    seg = max(req.trace_len // N_EPOCHS, 2)
    ppn = m0.ppn
    scatter = int(ppn.max()) + 2
    schedule = []
    for e in range(1, N_EPOCHS):
        evs = []
        for start, size in contiguity_chunks(make_mapping(ppn)):
            if size < 64 or rng.random() >= 0.4:
                continue
            holes = np.unique(rng.integers(1, size,
                                           size=int(rng.integers(1, 4))))
            for h in holes:
                evs.append(MappingEvent("split", start + int(h), 1,
                                        ppn=scatter))
                scatter += 2       # remapped far away: the run breaks
        schedule.append((e * seg, evs))
        for ev in evs:
            ppn = apply_event(ppn, ev)
    dyn = build_dynamic_mapping(m0.ppn, schedule, name="dyn-thp-split")
    trace = _epoch_trace_segments(dyn, req)
    return ScenarioData("dyn-thp-split", dyn.epochs[0], trace,
                        meta=_dyn_meta(dyn), dynamic=dyn)
