"""Accelerator-concurrency scenarios: the kv-gather recording replayed at
high-throughput-processor scale.

The accelerator-lineage translation schemes (subregion TLBs for
high-throughput processors, cache-backed reach extension, dead-entry
protection — see ``docs/methods.md``) were motivated by workloads where
hundreds to thousands of concurrent streams share one translation
structure, shredding the locality a CPU-scale TLB relies on.  The
``accel-gather`` family reproduces that pressure from the repo's own
serving stack: it records the SAME coalesced paged-attention DMA issue
order as ``kv-gather`` (one churned :class:`~repro.kvcache.allocator.\
PagedKVAllocator` episode, Algorithm-3 class passes), then splits the
recording into ``conc`` equal contiguous chunks — one per concurrent
gather stream — and interleaves them round-robin, page by page.  Each
chunk keeps its in-stream issue order, but consecutive *TLB* accesses now
come from ``conc`` different streams: per-stream spatial locality is
diluted by exactly the concurrency factor while the page working set and
its contiguity histogram stay identical to ``kv-gather``.

Determinism: the episode is seeded by ``(map_seed, trace_seed)`` exactly
like ``kv-gather`` (same seeds → bit-identical mapping AND recording),
and the chunk/interleave shuffle is a pure function of the recording
length and ``conc`` — no extra randomness.  The concurrency knob is the
scenario name (``accel-gather-x64/-x256/-x1024``); all variants of one
seed pair share one churn episode via the materialization memo.
"""
from __future__ import annotations

import numpy as np

from ..core.page_table import contiguity_histogram
from .base import ScenarioData, ScenarioRequest, scenario
from .workload import (_ChurnDriver, _episode_seed, _kv_pool,
                       _record_gather_order)


def _interleave_streams(trace: np.ndarray, conc: int) -> np.ndarray:
    """Round-robin interleave of ``conc`` contiguous chunks of ``trace``.

    Chunk ``s`` models stream ``s``'s issue queue; the interleave is the
    order a shared translation structure services them.  Ceil-division
    sizing pads the last chunks by wrapping (streams loop their gather),
    keeping the output the same length as the input.
    """
    n = trace.shape[0]
    conc = max(min(conc, n), 1)
    chunk = -(-n // conc)
    idx = np.arange(conc * chunk)
    # position j of the interleave reads chunk (j % conc) at offset
    # (j // conc); wrap offsets past a chunk's real end back onto it
    src = (idx % conc) * chunk + (idx // conc)
    return trace[src % n][:n]


def _accel_gather(req: ScenarioRequest, conc: int, name: str) -> ScenarioData:
    drv = _ChurnDriver(_kv_pool(req), "buddy_best", _episode_seed(req))
    drv.churn()
    stride = drv.slot_stride()
    rec, K = _record_gather_order(drv, req.trace_len, stride)
    m = drv.snapshot_mapping(stride, name=name)
    if not rec:                      # degenerate tiny pools
        rec = [(drv.sched.slot_of(r), 0)
               for r in drv.sched.running] or [(0, 0)]
    arr = np.asarray(rec[: req.trace_len], dtype=np.int64)
    flat = arr[:, 0] * stride + arr[:, 1]
    trace = _interleave_streams(flat, conc)
    meta = {"pool_pages": drv.pool,
            "live_seqs": len(drv.sched.running),
            "concurrency": conc,
            "K": K,
            "utilization": round(drv.alloc.utilization(), 3),
            "contiguity_histogram": contiguity_histogram(m)}
    return ScenarioData(name, m, trace, meta=meta)


def _register(conc: int):
    @scenario(f"accel-gather-x{conc}", family="accelerator",
              description=f"kv-gather DMA recording interleaved as {conc} "
                          "concurrent gather streams sharing one TLB",
              contiguity="kv-gather's mixed buddy runs; per-stream "
                         "locality diluted by the concurrency factor")
    def _build(req: ScenarioRequest, _conc=conc) -> ScenarioData:
        return _accel_gather(req, _conc, f"accel-gather-x{_conc}")
    return _build


for _conc in (64, 256, 1024):
    _register(_conc)
