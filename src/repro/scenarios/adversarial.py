"""Adversarial scenario generators: OS events that reshape contiguity.

Each starts from a demand-paged mapping and applies one contiguity-shifting
mechanism real kernels perform, producing distributions that stress specific
assumptions of the compared schemes:

* ``adv-compaction``  — memory compaction (``kcompactd``): a fraction of the
  chunks is migrated into one dense physical region in VA order, merging
  virtually-adjacent migrated chunks into very large runs while the rest
  stays fragmented.  Bimodal: a few huge chunks + many small ones — the
  regime where a single fixed anchor distance must sacrifice one mode.
* ``adv-thp-split``   — THP splitting: a THP-backed mapping (order-9 runs)
  whose huge pages are partially broken by hole-punching (COW faults,
  ``madvise(MADV_DONTNEED)``), shattering 512-runs into irregular fragments.
  Defeats the 2MB-only scheme while k<9 alignment classes still coalesce.
* ``adv-numa``        — NUMA interleave (``MPOL_INTERLEAVE``): consecutive
  16-page virtual granules round-robin across 4 node regions, so *every*
  chunk is exactly 16 pages.  A single-size distribution: Algorithm 3
  should collapse to K={4} (Table 1: size 2–16 → k=4) and anything assuming
  larger reach wastes its entries.

Traces are multiscale reuse sweeps (the locality family of the paper's SPEC
analogues), seeded by ``trace_seed``.
"""
from __future__ import annotations

import numpy as np

from ..core.mappings import demand_mapping
from ..core.page_table import (contiguity_chunks, contiguity_histogram,
                               make_mapping)
from ..core.traces import generate_trace
from .base import ScenarioData, ScenarioRequest, scenario


def _with_trace(name: str, ppn: np.ndarray, req: ScenarioRequest
                ) -> ScenarioData:
    m = make_mapping(ppn, name=name)
    tr = generate_trace("multiscale", 0, req.trace_len,
                        seed=req.trace_seed, mapping=m)
    return ScenarioData(name, m, tr,
                        meta={"contiguity_histogram":
                              contiguity_histogram(m)})


@scenario("adv-compaction", family="adversarial",
          description="demand mapping after a compaction pass migrated half "
                      "the chunks into one dense physical region",
          contiguity="bimodal: few very large compacted runs + untouched "
                     "small buddy chunks")
def _compaction(req: ScenarioRequest) -> ScenarioData:
    m0 = demand_mapping(req.n_pages, seed=req.map_seed)
    rng = np.random.default_rng(req.map_seed + 1)
    ppn = m0.ppn.copy()
    chunks = contiguity_chunks(m0)
    picked = rng.random(len(chunks)) < 0.5
    dest = int(ppn.max()) + 2          # fresh dense region, off by a guard
    for (start, size), take in zip(chunks, picked):
        if not take:
            continue
        ppn[start: start + size] = np.arange(dest, dest + size)
        dest += size                   # contiguous with the previous migrant
    return _with_trace("adv-compaction", ppn, req)


@scenario("adv-thp-split", family="adversarial",
          description="THP-backed mapping with huge pages partially split "
                      "by hole-punching (COW / MADV_DONTNEED analogue)",
          contiguity="shattered 512-page runs: irregular 60–250-page "
                     "fragments")
def _thp_split(req: ScenarioRequest) -> ScenarioData:
    m0 = demand_mapping(req.n_pages, seed=req.map_seed, thp=True)
    rng = np.random.default_rng(req.map_seed + 1)
    ppn = m0.ppn.copy()
    scatter = int(ppn.max()) + 2
    for start, size in contiguity_chunks(m0):
        if size < 64 or rng.random() >= 0.6:
            continue
        holes = rng.integers(1, size, size=int(rng.integers(1, 4)))
        for h in np.unique(holes):
            ppn[start + int(h)] = scatter   # remapped far away: run breaks
            scatter += 2
    return _with_trace("adv-thp-split", ppn, req)


@scenario("adv-numa", family="adversarial",
          description="NUMA-interleave analogue: 16-page virtual granules "
                      "round-robin across 4 node regions",
          contiguity="uniform: every chunk exactly 16 pages (Table 1 k=4)")
def _numa_interleave(req: ScenarioRequest) -> ScenarioData:
    nodes, gran = 4, 16
    n = (req.n_pages // (nodes * gran)) * nodes * gran
    n = max(n, nodes * gran)
    vpn = np.arange(n, dtype=np.int64)
    granule = vpn // gran
    node = granule % nodes
    # within its node, each granule lands after the node's earlier granules;
    # node regions are separated by a guard page so runs never merge
    node_region = (n // nodes) + 1
    within = (granule // nodes) * gran + (vpn % gran)
    ppn = node * node_region + within
    return _with_trace("adv-numa", ppn, req)
