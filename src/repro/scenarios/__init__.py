"""Scenario registry: every (mapping, trace) source behind one interface.

See :mod:`repro.scenarios.base` for the model and ``docs/scenarios.md`` for
the catalogue.  Importing this package registers all built-in families:
synthetic (Table-3 families, demand paging, paper-benchmark analogues),
workload-derived (KV-cache serving churn, paged-attention gather order,
training data pipeline, checkpoint shards), adversarial (compaction,
THP splitting, NUMA interleave), dynamic (live mapping-event streams),
multitenant (ASID-tagged address spaces under KVScheduler-derived
context-switch schedules), accelerator (the kv-gather recording
interleaved at accelerator concurrency), and nested (guest→host two-level
translation worlds with host-side remap storms).
"""
from . import (accelerator, adversarial, dynamic, multitenant,  # noqa: F401
               nested, synthetic, workload)
from .base import (FAMILIES, Scenario, ScenarioData, ScenarioRequest,
                   clear_materialized_cache, get_scenario, list_scenarios,
                   register, scenario)

__all__ = [
    "FAMILIES", "Scenario", "ScenarioData", "ScenarioRequest",
    "clear_materialized_cache", "get_scenario", "list_scenarios",
    "register", "scenario",
]
