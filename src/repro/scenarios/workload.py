"""Workload-derived scenarios: mappings/traces recorded from the repo's own
serving and training stacks.

Unlike the synthetic families, these do not *model* contiguity — they run the
in-repo systems and record what their allocators and access loops actually
produce, the methodology of workload-driven translation studies (Victima,
subregion-contiguity; PAPERS.md):

* ``kv-churn`` / ``kv-churn-page`` — the paged KV cache under serving churn:
  requests admitted, grown page-by-page (``PagedKVAllocator.extend``),
  preempted under pool pressure and freed on completion, driven by the same
  :class:`repro.serve.scheduler.KVScheduler` policy code the real
  ``ServingEngine`` uses.  The mapping is the live block tables (one
  power-of-two-aligned virtual region per batch slot, logical KV pages
  consecutive within it); the trace is the decode loop's per-step page sweep.
  ``-page`` uses the vLLM-style page-at-a-time policy (worst-case
  contiguity, the paper's Base analogue).
* ``kv-gather`` — same churned pool, but the trace follows the coalesced
  paged-attention kernel's DMA issue order: per class k (chosen by
  Algorithm 3 from the allocator's live histogram, descending) over that
  class's covered windows, then the class-0 leftovers — the gather order of
  ``repro.kernels.paged_attention``.
* ``train-pipeline`` — the prefetching data pipeline's host batch buffers
  (``repro.data.pipeline``): a rolling ring of ``prefetch+1`` step buffers
  carved from a churned heap, producer writes interleaved with consumer
  reads.
* ``ckpt-shards`` — checkpoint save/restore (``repro.checkpoint``): one
  buffer per pytree leaf (sizes derived from a real ``ModelConfig``),
  sequential save writes followed by elastic-restore reads where every leaf
  is read as ``n_devices`` interleaved shard streams (reshard-on-restore).

All builders are deterministic in the request seeds; churn statistics and
contiguity histograms are reported in ``ScenarioData.meta``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.page_table import (Mapping, contiguity_histogram, make_mapping,
                               next_pow2 as _next_pow2)
from ..kvcache.allocator import PagedKVAllocator
from ..kvcache.block_table import assign_classes, choose_kernel_classes
from ..serve.scheduler import KVScheduler
from .base import ScenarioData, ScenarioRequest, scenario

MAX_BATCH = 8          # batch slots of the churn driver
CHURN_STEPS = 96       # scheduler steps of warm-up churn before recording


def _episode_seed(req: ScenarioRequest) -> list:
    """Workload scenarios record ONE system episode: the mapping and the
    trace come out of the same run, so both seeds jointly seed it (a caller
    varying either gets an independent episode, never a silently identical
    one)."""
    return [req.map_seed, req.trace_seed]


# ---------------------------------------------------------------------------
# KV-cache serving churn
# ---------------------------------------------------------------------------


class _ChurnDriver:
    """Drives KVScheduler + PagedKVAllocator through serving churn.

    Requests are tracked in page units (tokens only matter to the allocator
    at page granularity): a request needs ``prompt`` pages at admission and
    grows by one page per step until ``target`` pages, then completes.
    Preemption is recompute-style: a victim re-enters the queue needing all
    pages it held (prompt absorbs generated state), mirroring
    ``ServingEngine._on_preempt``.
    """

    def __init__(self, pool_pages: int, alloc_policy: str, seed: int):
        self.rng = np.random.default_rng(seed)
        self.alloc = PagedKVAllocator(pool_pages, alloc_policy=alloc_policy)
        self.pool = self.alloc.buddy.n_frames
        self.sched = KVScheduler(self.alloc, MAX_BATCH)
        self.prompt: Dict[int, int] = {}
        self.target: Dict[int, int] = {}
        self._next_rid = 0
        self.extends = 0
        self.completions = 0

    def _draw_request(self) -> None:
        """Log-uniform prompt footprint (spans buddy orders → mixed
        contiguity) plus a short decode tail; one in four requests is a
        long-context outlier so the pool saturates and preemption fires."""
        cap = max(self.pool // 2, 8)
        if self.rng.random() < 0.25:
            p = int(self.rng.integers(cap // 2, cap + 1))
        else:
            p = int(2.0 ** self.rng.uniform(0.0, np.log2(cap // 2)))
        rid = self._next_rid
        self._next_rid += 1
        self.prompt[rid] = max(p, 1)
        self.target[rid] = self.prompt[rid] + int(self.rng.integers(1, 9))
        self.sched.enqueue(rid)

    def _preempt_cb(self, rid: int) -> None:
        self.prompt[rid] = max(len(self.alloc.seqs[rid].pages), 1)

    def step(self, allow_churn: bool = True) -> List[int]:
        """One scheduler iteration; returns the running set after admission.

        With ``allow_churn`` False (recording phase) nothing is preempted or
        freed: sequences at target simply stop growing, and extend failures
        cap growth instead of evicting a victim — the mapping only gains
        pages, so every recorded access exists in the final snapshot.
        """
        sched, alloc = self.sched, self.alloc
        if allow_churn:
            while len(sched.waiting) < 2:
                self._draw_request()
            sched.admit(lambda rid: self.prompt[rid],
                        on_preempt=self._preempt_cb)
        for rid in list(sched.running):
            if rid not in alloc.seqs:    # preempted by an earlier iteration
                continue
            held = len(alloc.seqs[rid].pages)
            if held >= self.target[rid]:
                if allow_churn:
                    sched.release(rid)
                    self.completions += 1
                continue
            if alloc.extend(rid, 1):
                self.extends += 1
                continue
            if allow_churn:
                others = [r for r in sched.running if r != rid]
                if others:
                    sched.preempt(others[-1], self._preempt_cb)
                    if alloc.extend(rid, 1):
                        self.extends += 1
                        continue
                # still no room: cap this sequence where it is
                self.target[rid] = held
            else:
                self.target[rid] = held
        return list(sched.running)

    def churn(self, steps: int = CHURN_STEPS) -> None:
        for _ in range(steps):
            self.step(allow_churn=True)
        # refill the batch so the recording phase always has live sequences
        # (the last churn step may have released everything it was running)
        while len(self.sched.waiting) < 2:
            self._draw_request()
        self.sched.admit(lambda rid: self.prompt[rid],
                         on_preempt=self._preempt_cb)

    # -- snapshotting -----------------------------------------------------
    def slot_stride(self) -> int:
        """Per-slot virtual region size: the next power of two of the
        largest live sequence (block tables are padded to a common shape in
        the engine; the pow-2 stride gives the natural VA alignment
        buddy/THP-style faulting would)."""
        longest = max((len(self.alloc.seqs[r].pages)
                       for r in self.sched.running), default=1)
        return _next_pow2(max(longest, 1))

    def snapshot_mapping(self, stride: int, name: str) -> Mapping:
        ppn = np.full(stride * MAX_BATCH, -1, dtype=np.int64)
        for rid in self.sched.running:
            s = self.sched.slot_of(rid)
            pages = np.asarray(self.alloc.seqs[rid].pages, dtype=np.int64)
            ppn[s * stride: s * stride + pages.shape[0]] = pages
        return make_mapping(ppn, name=name)


def _record_decode_sweep(drv: _ChurnDriver, trace_len: int
                         ) -> List[Tuple[int, int]]:
    """Decode-loop access order: per step, each running sequence reads its
    logical KV pages 0..len-1 in order (the block-table walk every decode
    step performs), while sequences keep growing page by page."""
    rec: List[Tuple[int, int]] = []
    guard = 0
    while len(rec) < trace_len and guard < 4 * trace_len + 64:
        for rid in drv.step(allow_churn=False):
            s = drv.sched.slot_of(rid)
            held = len(drv.alloc.seqs[rid].pages)
            rec.extend((s, j) for j in range(held))
            if len(rec) >= trace_len:
                break
        guard += max(sum(len(drv.alloc.seqs[r].pages)
                         for r in drv.sched.running), 1)
    return rec


def _record_gather_order(drv: _ChurnDriver, trace_len: int, stride: int
                         ) -> Tuple[List[Tuple[int, int]], List[int]]:
    """Kernel DMA issue order: Algorithm 3 picks K from the live histogram;
    each simulated decode step then visits, per class k descending, the
    class-k covered windows (whole 2^k-page superblock per descriptor) and
    finally the class-0 leftovers — the per-class pass structure of
    ``repro.kernels.paged_attention``."""
    K = choose_kernel_classes(drv.alloc.contiguity_histogram(), psi=3) or [0]
    per_slot: List[Tuple[int, List[int]]] = []
    for rid in drv.sched.running:
        s = drv.sched.slot_of(rid)
        pages = drv.alloc.seqs[rid].pages
        bt = np.full(stride, -1, dtype=np.int64)
        bt[: len(pages)] = pages
        asg = assign_classes(bt, K)
        order: List[int] = []
        for k in sorted(asg, reverse=True):
            w = 1 << k
            for widx in np.flatnonzero(asg[k]):
                base = int(widx) * w if k > 0 else int(widx)
                order.extend(range(base, base + w) if k > 0 else [base])
        per_slot.append((s, order))
    step_rec: List[Tuple[int, int]] = []
    for s, order in per_slot:
        step_rec.extend((s, j) for j in order)
    rec: List[Tuple[int, int]] = []
    while len(rec) < trace_len and step_rec:
        rec.extend(step_rec)
    return rec, K


def _finish_kv(drv: _ChurnDriver, rec: List[Tuple[int, int]], name: str,
               req: ScenarioRequest, extra_meta: Optional[dict] = None
               ) -> ScenarioData:
    stride = drv.slot_stride()
    m = drv.snapshot_mapping(stride, name=name)
    if not rec:                      # degenerate tiny pools
        rec = [(drv.sched.slot_of(r), 0) for r in drv.sched.running] or [(0, 0)]
    arr = np.asarray(rec[: req.trace_len], dtype=np.int64)
    trace = arr[:, 0] * stride + arr[:, 1]
    meta = {"pool_pages": drv.pool,
            "live_seqs": len(drv.sched.running),
            "preemptions": drv.sched.preemptions,
            "extends": drv.extends,
            "completions": drv.completions,
            "utilization": round(drv.alloc.utilization(), 3),
            "contiguity_histogram": contiguity_histogram(m)}
    meta.update(extra_meta or {})
    return ScenarioData(name, m, trace, meta=meta)


def _kv_pool(req: ScenarioRequest) -> int:
    # n_pages budgets the physical pool; clamp so the python churn loop
    # stays cheap at --full scale and meaningful at --smoke scale
    return int(min(max(req.n_pages, 1 << 10), 1 << 17))


@scenario("kv-churn", family="workload",
          description="paged KV cache under serving churn "
                      "(buddy_best allocation, KVScheduler policy)",
          contiguity="mixed power-of-two buddy runs, fragmented by "
                     "preempt/free cycles")
def _kv_churn(req: ScenarioRequest) -> ScenarioData:
    drv = _ChurnDriver(_kv_pool(req), "buddy_best", _episode_seed(req))
    drv.churn()
    rec = _record_decode_sweep(drv, req.trace_len)
    return _finish_kv(drv, rec, "kv-churn", req)


@scenario("kv-churn-page", family="workload",
          description="paged KV cache under serving churn with vLLM-style "
                      "page-at-a-time allocation",
          contiguity="page-granular blocks: mostly small chunks, longer "
                     "runs only where the churned free list happens to be "
                     "consecutive")
def _kv_churn_page(req: ScenarioRequest) -> ScenarioData:
    drv = _ChurnDriver(_kv_pool(req), "page", _episode_seed(req))
    drv.churn()
    rec = _record_decode_sweep(drv, req.trace_len)
    return _finish_kv(drv, rec, "kv-churn-page", req)


@scenario("kv-gather", family="workload",
          description="coalesced paged-attention DMA gather order over the "
                      "churned KV pool (Algorithm 3 classes, per-class "
                      "descriptor passes)",
          contiguity="same mixed buddy runs as kv-churn; access order "
                     "grouped by alignment class")
def _kv_gather(req: ScenarioRequest) -> ScenarioData:
    drv = _ChurnDriver(_kv_pool(req), "buddy_best", _episode_seed(req))
    drv.churn()
    stride = drv.slot_stride()
    rec, K = _record_gather_order(drv, req.trace_len, stride)
    return _finish_kv(drv, rec, "kv-gather", req, extra_meta={"K": K})


# ---------------------------------------------------------------------------
# Training stack: data pipeline and checkpoint shards
# ---------------------------------------------------------------------------


def _heap_alloc(alloc: PagedKVAllocator, rid: int, n_pages: int
                ) -> np.ndarray:
    """One host-heap buffer as a PagedKVAllocator sequence (the same
    largest-fit buddy policy the serving stack uses); freed via
    ``alloc.free(rid)``."""
    seq = alloc.allocate(rid, n_pages)
    if seq is None:
        raise RuntimeError("buddy pool exhausted")
    return np.asarray(seq.pages, dtype=np.int64)


@scenario("train-pipeline", family="workload",
          description="prefetching data pipeline's rolling ring of host "
                      "batch buffers (repro.data.pipeline, prefetch=2, "
                      "seq-length-bucketed batches)",
          contiguity="per-buffer buddy extents of several bucket sizes; "
                     "heap reuse across the ring mixes them")
def _train_pipeline(req: ScenarioRequest) -> ScenarioData:
    from ..data.pipeline import PipelineConfig
    pc = PipelineConfig(batch=8, seq=4096, seed=req.map_seed, prefetch=2)
    # one decoder batch = tokens + labels, int32 (see pipeline._batch_at);
    # batches are bucketed by padded sequence length, so buffer sizes vary
    full_pages = max((pc.batch * pc.seq * 2 * 4) // 4096, 4)
    buckets = [max(full_pages // d, 1) for d in (1, 2, 4, 3)]
    n_steps = max(req.n_pages // full_pages, pc.prefetch + 2)
    rng = np.random.default_rng(_episode_seed(req))
    heap = PagedKVAllocator(4 * full_pages * (pc.prefetch + 2), max_order=10)
    # heap warm-up: scattered small allocations fragment the pool the way a
    # long-running training process's host heap is
    n_warm = 4 * (pc.prefetch + 2)
    for i in range(n_warm):
        _heap_alloc(heap, -1 - i, int(rng.integers(1, 8)))
    for i in range(0, n_warm, 2):
        heap.free(-1 - i)

    ring: List[int] = []                        # live buffer rids, oldest 1st
    va_bases: List[int] = []
    sizes: List[int] = []
    phys: List[np.ndarray] = []
    va = 0
    rec: List[int] = []
    for step in range(n_steps):
        n = buckets[int(rng.integers(0, len(buckets)))]
        pages = _heap_alloc(heap, step, n)
        ring.append(step)
        a = _next_pow2(n)
        va = (va + a - 1) & ~(a - 1)
        va_bases.append(va)
        sizes.append(n)
        phys.append(pages)
        va += n
        # producer writes buffer `step`; consumer reads `step - prefetch`
        # concurrently (the host→device copy overlapping compute) —
        # interleave the two sequential streams
        writer = np.arange(n) + va_bases[step]
        if step >= pc.prefetch:
            prev = step - pc.prefetch
            reader = np.arange(sizes[prev]) + va_bases[prev]
            ln = max(writer.shape[0], reader.shape[0])
            inter = np.empty(2 * ln, dtype=np.int64)
            inter[0::2] = np.resize(writer, ln)
            inter[1::2] = np.resize(reader, ln)
            rec.extend(inter.tolist())
        else:
            rec.extend(writer.tolist())
        if len(ring) > pc.prefetch + 1:          # batch consumed: free it
            heap.free(ring.pop(0))
            # (physical pages recycled; the vpn keeps its last backing)
    ppn = np.full(va, -1, dtype=np.int64)
    for base, pages in zip(va_bases, phys):
        ppn[base: base + pages.shape[0]] = pages
    m = make_mapping(ppn, name="train-pipeline")
    trace = np.asarray(rec, dtype=np.int64)
    reps = -(-req.trace_len // max(trace.shape[0], 1))
    trace = np.tile(trace, reps)[: req.trace_len]
    return ScenarioData("train-pipeline", m, trace,
                        meta={"bucket_pages": buckets,
                              "steps": n_steps,
                              "contiguity_histogram":
                                  contiguity_histogram(m)})


def _model_leaf_pages(cap_pages: int) -> List[int]:
    """Per-leaf page counts of a real model's checkpoint (fp32), from the
    internlm2-1.8b ModelConfig, truncated to the ``cap_pages`` budget."""
    from ..configs import get_config
    cfg = get_config("internlm2-1.8b")
    d, dff, v = cfg.d_model, cfg.d_ff, cfg.vocab
    qh = cfg.n_heads * cfg.head_dim
    kvh = cfg.n_kv_heads * cfg.head_dim
    per_layer = [d * qh, d * kvh, d * kvh, qh * d,      # q k v o
                 d * dff, d * dff, dff * d,             # gate up down
                 d, d]                                  # norms
    elems = [v * d] + per_layer * cfg.n_layers + [d, v * d]
    pages = [max((e * 4) // 4096, 1) for e in elems]
    # scale the whole tree to the page budget so the structural mix (huge
    # matrices next to page-sized norm vectors) survives at any size
    scale = min(cap_pages / max(sum(pages), 1), 1.0)
    return [max(int(p * scale), 1) for p in pages]


@scenario("ckpt-shards", family="workload",
          description="checkpoint save + elastic restore: one buffer per "
                      "pytree leaf (repro.checkpoint layout), leaves read "
                      "back as interleaved per-device shard streams",
          contiguity="large per-leaf extents (weight matrices) next to "
                     "page-sized norm leaves")
def _ckpt_shards(req: ScenarioRequest) -> ScenarioData:
    n_devices = 8
    leaf_pages = _model_leaf_pages(req.n_pages)
    rng = np.random.default_rng(_episode_seed(req))
    cache = PagedKVAllocator(4 * max(sum(leaf_pages), 1024), max_order=11)
    # page-cache churn before the save lands
    warm = list(range(-64, 0))
    for i in warm:
        _heap_alloc(cache, i, int(rng.integers(1, 16)))
    rng.shuffle(warm)
    for i in warm[: len(warm) // 2]:
        cache.free(i)

    va = 0
    va_bases: List[int] = []
    phys: List[np.ndarray] = []
    meta_rids: List[int] = []
    for leaf, n in enumerate(leaf_pages):
        pages = _heap_alloc(cache, leaf, n)
        a = _next_pow2(n)
        va = (va + a - 1) & ~(a - 1)
        va_bases.append(va)
        phys.append(pages)
        # leaves are separate .npy files: a guard page keeps their extents
        # from merging in VA, and the writer's interleaved metadata I/O
        # (manifest, dirents) punches small allocations between leaf extents
        va += n + 1
        rid = 100_000 + leaf
        _heap_alloc(cache, rid, int(rng.integers(1, 4)))
        meta_rids.append(rid)
        if len(meta_rids) > 4:
            cache.free(meta_rids.pop(0))
    ppn = np.full(va, -1, dtype=np.int64)
    for base, pages in zip(va_bases, phys):
        ppn[base: base + pages.shape[0]] = pages
    m = make_mapping(ppn, name="ckpt-shards")

    rec: List[int] = []
    # save: the serialization thread writes each leaf sequentially
    for base, n in zip(va_bases, leaf_pages):
        rec.extend(range(base, base + n))
    # elastic restore: each leaf is split into n_devices contiguous shards
    # read concurrently (device_put against the target mesh) — round-robin
    # across the shard streams; ceil-division so tail pages are covered
    for base, n in zip(va_bases, leaf_pages):
        shard = -(-n // n_devices)
        offs = [base + d * shard for d in range(n_devices) if d * shard < n]
        lens = [min(shard, base + n - o) for o in offs]
        for i in range(max(lens)):
            rec.extend(o + i for o, ln in zip(offs, lens) if i < ln)
    trace = np.asarray(rec, dtype=np.int64)
    reps = -(-req.trace_len // max(trace.shape[0], 1))
    trace = np.tile(trace, reps)[: req.trace_len]
    return ScenarioData("ckpt-shards", m, trace,
                        meta={"n_leaves": len(leaf_pages),
                              "n_devices": n_devices,
                              "contiguity_histogram":
                                  contiguity_histogram(m)})
