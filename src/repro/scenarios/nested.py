"""Nested scenarios: guest→host two-level translation worlds (VMs).

Under virtualization the paper's mixed contiguity gets strictly harder: a
translation is guest-VPN → guest-PPN → host-PPN, and the contiguity K-bit
alignment exploits can fracture at *either* level.  Each scenario here
produces a :class:`repro.core.page_table.NestedMapping`: per-VM guest page
tables drawn from the Table-3 synthetic families, composed over one host
layer the hypervisor rewrites mid-trace, plus a VM schedule derived from
the serving stack's own :class:`~repro.serve.scheduler.KVScheduler` —
tenants-as-VMs, vCPU ASIDs as batch slots, exactly the multi-tenant
machinery one level up.

* ``nested-vm-mix``          — three resident VMs with different guest
  contiguity signatures round-robin decoding over one host layer; a
  single host migration event mid-trace dirties composed translations of
  VMs that never ran an OS event of their own.
* ``nested-host-compaction`` — the hypervisor's defragmenter runs live:
  every host epoch migrates scattered guest-frame ranges into one dense
  region.  Guests see nothing; every composed entry over a moved frame
  dies.  The world where the ``coh_policy`` knob separates most — sweep
  it under both ``shootdown`` and ``hw-coherence``.
* ``nested-balloon``         — a balloon driver inflates in one VM (its
  frames scatter page-by-page to reclaim contiguous host memory), then
  deflates and the host re-compacts them — composed contiguity shatters
  and returns while the *guest* table never changes.

All builders are deterministic in the request seeds.  ``meta`` reports the
VM schedule, host event mix, per-boundary composed dirty counts, and the
merged composed contiguity histogram Algorithm 3 should see.
"""
from __future__ import annotations

from collections import Counter
from typing import List, Sequence, Tuple

import numpy as np

from ..core.page_table import (MappingEvent, Mapping, NestedMapping,
                               build_dynamic_mapping, build_nested_mapping)
from .base import ScenarioData, ScenarioRequest, scenario
from .multitenant import (RESIDENT_ROUNDS, _DecodeRoundScheduler,
                          _tenant_worlds)


def _guest_pages(req: ScenarioRequest, n_guests: int) -> int:
    return int(max(req.n_pages // (2 * n_guests), 256))


def _host_identity(maps: Sequence[Mapping]) -> np.ndarray:
    """Identity host table covering every guest PPN (a fresh VM's frames
    are host-contiguous until the hypervisor starts moving them)."""
    hmax = max(int(np.max(np.asarray(m.ppn))) for m in maps) + 8
    return np.arange(hmax, dtype=np.int64)


def _assemble_nested(name: str, world: NestedMapping,
                     streams: List[np.ndarray], req: ScenarioRequest,
                     drv: _DecodeRoundScheduler, kinds: List[str],
                     host_events) -> ScenarioData:
    """Stitch per-VM trace streams along the VM schedule; build meta.

    Host events only *move frames* (no unmap), so a guest's mapped-VPN set
    is invariant across host epochs and each VM's synthetic stream stays
    valid in every composed view.
    """
    bounds = list(world.boundaries) + [req.trace_len]
    cursor = [0] * world.n_guests
    parts: List[np.ndarray] = []
    for s in range(world.n_segments):
        gid = world.guest_ids[s]
        n = bounds[s + 1] - bounds[s]
        stream = streams[gid]
        idx = np.arange(cursor[gid], cursor[gid] + n) % stream.shape[0]
        parts.append(stream[idx])
        cursor[gid] += n
    trace = np.concatenate(parts)[: req.trace_len]
    segs = world.plan_segments()
    meta = {
        "guest_kinds": list(kinds),
        "n_guests": world.n_guests,
        "n_schedule_segments": world.n_segments,
        "n_union_segments": len(segs),
        "switches": world.n_switches(),
        "recycles": int(sum(world.recycled)),
        "asids": sorted(set(world.asids)),
        "host_epochs": world.host.n_epochs,
        "host_events": dict(Counter(ev.kind for evs in host_events
                                    for ev in evs)),
        "dirty_pages": [int(s.dirty.sum()) for s in segs
                        if s.dirty is not None],
        "sched_events": dict(drv.taps),
        "contiguity_histogram": world.merged_contiguity_histogram(),
    }
    return ScenarioData(name, world.composed(world.guest_ids[0], 0, 0),
                        trace, meta=meta, nested=world)


def _host_layer(maps: Sequence[Mapping],
                schedule: List[Tuple[int, List[MappingEvent]]]):
    h0 = _host_identity(maps)
    return build_dynamic_mapping(h0, schedule, name="host"), h0


@scenario("nested-vm-mix", family="nested",
          description="three resident VMs (small/medium/large guest "
                      "contiguity) round-robin decoding under the "
                      "KVScheduler over one host layer; a mid-trace host "
                      "migration dirties composed entries of VMs that ran "
                      "no OS event of their own",
          contiguity="three per-VM signatures composed over one host "
                     "layer; one host event fractures them mid-trace")
def _nested_vm_mix(req: ScenarioRequest) -> ScenarioData:
    kinds = ["small", "medium", "large"]
    maps, streams = _tenant_worlds(kinds, req, _guest_pages(req, 3))
    quantum = max(req.trace_len // 36, 8)
    drv = _DecodeRoundScheduler(pool_pages=1 << 10, max_batch=3)
    for i in range(3):
        drv.enqueue(i, need_pages=64, rounds=RESIDENT_ROUNDS)
    schedule = drv.run(quantum, req.trace_len)
    # one NUMA-balancing-style host migration: a frame range VM 0 happens
    # to own moves; the guests' own tables never change
    rng = np.random.default_rng(req.map_seed + 7)
    hmax = _host_identity(maps).size
    live = np.asarray(maps[0].ppn)
    p0 = int(live[live >= 0][rng.integers(0, (live >= 0).sum())])
    p0 = min(p0, hmax - 64)
    h_evs = [MappingEvent("remap", p0, 64, ppn=hmax)]
    host, _ = _host_layer(maps, [(req.trace_len // 2, h_evs)])
    world = build_nested_mapping(maps, host, schedule, name="nested-vm-mix")
    return _assemble_nested("nested-vm-mix", world, streams, req, drv,
                            kinds, [h_evs])


@scenario("nested-host-compaction", family="nested",
          description="hypervisor defragmenter live: every host epoch "
                      "migrates scattered guest-frame ranges into one "
                      "dense region, killing composed entries guests "
                      "never touched — sweep under both coh_policy values",
          contiguity="composed chunks die in storms at host epochs; "
                     "host-side runs densify while guest views fracture")
def _nested_host_compaction(req: ScenarioRequest) -> ScenarioData:
    kinds = ["medium", "mixed"]
    maps, streams = _tenant_worlds(kinds, req, _guest_pages(req, 2))
    quantum = max(req.trace_len // 24, 8)
    drv = _DecodeRoundScheduler(pool_pages=1 << 10, max_batch=2)
    for i in range(2):
        drv.enqueue(i, need_pages=64, rounds=RESIDENT_ROUNDS)
    schedule = drv.run(quantum, req.trace_len)

    rng = np.random.default_rng(req.map_seed + 13)
    h0 = _host_identity(maps)
    dest = int(h0.size)
    live = np.unique(np.concatenate(
        [np.asarray(m.ppn)[np.asarray(m.ppn) >= 0] for m in maps]))
    n_epochs = 4
    seg = max(req.trace_len // n_epochs, 2)
    h_sched: List[Tuple[int, List[MappingEvent]]] = []
    for e in range(1, n_epochs):
        evs = []
        # migrate a handful of 32-frame windows around live guest frames
        for p in live[rng.integers(0, live.size, 6)]:
            start = int(min(p, h0.size - 32))
            evs.append(MappingEvent("compact", start, 32, ppn=dest))
            dest += 32             # contiguous with the previous migrant
        h_sched.append((e * seg, evs))
    host = build_dynamic_mapping(h0, h_sched, name="host-compaction")
    world = build_nested_mapping(maps, host, schedule,
                                 name="nested-host-compaction")
    return _assemble_nested("nested-host-compaction", world, streams, req,
                            drv, kinds, [evs for _, evs in h_sched])


@scenario("nested-balloon", family="nested",
          description="balloon driver: inflate scatters one VM's frames "
                      "page-by-page (host reclaims contiguous memory), "
                      "deflate re-compacts them — the guest table never "
                      "changes while composed contiguity shatters and "
                      "returns",
          contiguity="one VM's composed runs shatter to singletons at "
                     "inflate and re-densify at deflate")
def _nested_balloon(req: ScenarioRequest) -> ScenarioData:
    kinds = ["large", "small"]
    maps, streams = _tenant_worlds(kinds, req, _guest_pages(req, 2))
    quantum = max(req.trace_len // 24, 8)
    drv = _DecodeRoundScheduler(pool_pages=1 << 10, max_batch=2)
    for i in range(2):
        drv.enqueue(i, need_pages=64, rounds=RESIDENT_ROUNDS)
    schedule = drv.run(quantum, req.trace_len)

    rng = np.random.default_rng(req.map_seed + 29)
    h0 = _host_identity(maps)
    victim = np.asarray(maps[0].ppn)
    victim = np.unique(victim[victim >= 0])
    picked = victim[rng.integers(0, victim.size, 48)]
    scatter = int(h0.size)
    inflate = []
    for p in np.unique(picked):
        # page-by-page to far-apart frames: every composed run through p
        # breaks (the dyn-thp-split scatter pattern, one level down)
        inflate.append(MappingEvent("remap", int(p), 1, ppn=scatter))
        scatter += 2
    # deflate: the same frames come back contiguous (host re-compacted)
    deflate = [MappingEvent("compact", int(p), 1, ppn=scatter + i)
               for i, p in enumerate(np.unique(picked))]
    t1, t2 = max(req.trace_len // 3, 1), max(2 * req.trace_len // 3, 2)
    host = build_dynamic_mapping(h0, [(t1, inflate), (t2, deflate)],
                                 name="host-balloon")
    world = build_nested_mapping(maps, host, schedule,
                                 name="nested-balloon")
    return _assemble_nested("nested-balloon", world, streams, req, drv,
                            kinds, [inflate, deflate])
