"""Scenario registry: one interface over every (mapping, trace) source.

The paper's argument (§2, Figs 2–3) is that *real* applications produce
diverse, mixed contiguity that fixed-assumption coalescing schemes miss.
A :class:`Scenario` packages one source of that diversity — a synthetic
Table-3 family, a paper-benchmark analogue, a workload recorded from the
repo's own serving/training stack, or an adversarial generator — behind a
single call:

    from repro.scenarios import get_scenario
    data = get_scenario("kv-churn").materialize(n_pages=1 << 15,
                                                trace_len=50_000)
    data.mapping   # repro.core.page_table.Mapping (contiguity-annotated)
    data.trace     # int64[trace_len] VPN access trace
    data.meta      # scenario-specific provenance (histogram, churn stats…)

Materialization is **deterministic** in ``(name, n_pages, trace_len,
map_seed, trace_seed)``: two processes with the same arguments produce
bit-identical arrays, which is what makes the content-hash cache of
:func:`repro.core.sweep.run_sweep` hit across runs.  Results are memoized
per-process so a sweep bench and a histogram bench sharing a scenario build
it once.

Register a new scenario with the :func:`scenario` decorator::

    @scenario("my-workload", family="workload",
              description="what it models",
              contiguity="expected chunk-size signature")
    def _build(req: ScenarioRequest) -> ScenarioData:
        ...

Importing :mod:`repro.scenarios` registers all built-in families.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core.page_table import (DynamicMapping, Mapping, MultiTenantMapping,
                               NestedMapping)

FAMILIES = ("synthetic", "workload", "adversarial", "dynamic", "multitenant",
            "accelerator", "nested")


@dataclasses.dataclass(frozen=True)
class ScenarioRequest:
    """Size/seed knobs passed to a scenario builder.

    ``n_pages`` is a *target or cap* on the mapped footprint: synthetic
    builders hit it exactly; workload builders treat it as the physical pool
    budget (the mapped footprint follows from the recorded workload); some
    scenarios pin their own mapping seed (see each builder's docstring).
    """

    n_pages: int = 1 << 16
    trace_len: int = 100_000
    map_seed: int = 0
    trace_seed: int = 0


@dataclasses.dataclass(frozen=True)
class ScenarioData:
    """A materialized scenario: simulator-ready mapping + VPN trace.

    :meth:`Scenario.materialize` memoizes and returns ONE shared instance
    per parameter set (with a read-only trace array), so consumers must
    treat it — including ``meta`` — as immutable.

    ``dynamic`` scenarios additionally carry the full
    :class:`~repro.core.page_table.DynamicMapping` (epoch snapshots, event
    stream, trace-position boundaries); for them ``mapping`` is the
    epoch-0 snapshot (what the OS saw when it chose K), and each trace
    entry must be mapped in the epoch live at that step.  ``multitenant``
    scenarios carry a
    :class:`~repro.core.page_table.MultiTenantMapping` (tenant address
    spaces + context-switch schedule with ASID assignments); ``mapping``
    is tenant 0's space and each trace entry must be mapped in the tenant
    scheduled at that step.  ``nested`` scenarios carry a
    :class:`~repro.core.page_table.NestedMapping` (guest page tables
    composed over a host layer + a VM schedule); ``mapping`` is the first
    scheduled VM's initial composed view and each trace entry must be
    mapped in the composed view live at that step.  Sweep either by
    passing ``data.world`` (the segmented world when present, else the
    static mapping) to :class:`repro.core.sweep.SweepCell`.
    """

    scenario: str
    mapping: Mapping
    trace: np.ndarray
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    dynamic: Optional[DynamicMapping] = None
    multitenant: Optional[MultiTenantMapping] = None
    nested: Optional[NestedMapping] = None

    @property
    def world(self):
        """What to simulate: the segmented world when present, else static."""
        if self.dynamic is not None:
            return self.dynamic
        if self.multitenant is not None:
            return self.multitenant
        if self.nested is not None:
            return self.nested
        return self.mapping


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named, documented (mapping, trace) source."""

    name: str
    family: str               # one of FAMILIES
    description: str
    contiguity: str           # one-line expected contiguity signature
    builder: Callable[[ScenarioRequest], ScenarioData]

    def materialize(self, n_pages: int = 1 << 16, trace_len: int = 100_000,
                    map_seed: int = 0, trace_seed: int = 0) -> ScenarioData:
        """Build (memoized) the mapping and trace for these parameters."""
        req = ScenarioRequest(n_pages=int(n_pages), trace_len=int(trace_len),
                              map_seed=int(map_seed),
                              trace_seed=int(trace_seed))
        key = (self.name, req)
        hit = _MATERIALIZED.get(key)
        if hit is None:
            hit = self.builder(req)
            assert hit.trace.ndim == 1
            trace = np.ascontiguousarray(hit.trace, dtype=np.int64)
            trace.setflags(write=False)
            hit = dataclasses.replace(hit, trace=trace)
            _MATERIALIZED[key] = hit
        return hit


_REGISTRY: Dict[str, Scenario] = {}
_MATERIALIZED: Dict[Tuple[str, ScenarioRequest], ScenarioData] = {}


def register(sc: Scenario) -> Scenario:
    if sc.family not in FAMILIES:
        raise ValueError(f"unknown scenario family: {sc.family}")
    if sc.name in _REGISTRY:
        raise ValueError(f"scenario already registered: {sc.name}")
    _REGISTRY[sc.name] = sc
    return sc


def scenario(name: str, family: str, description: str, contiguity: str):
    """Decorator form of :func:`register` for builder functions."""
    def deco(fn: Callable[[ScenarioRequest], ScenarioData]):
        register(Scenario(name=name, family=family, description=description,
                          contiguity=contiguity, builder=fn))
        return fn
    return deco


def get_scenario(name: str) -> Scenario:
    try:
        return _REGISTRY[name]
    except KeyError:
        known = ", ".join(sorted(_REGISTRY))
        raise KeyError(f"unknown scenario {name!r}; registered: {known}") \
            from None


def list_scenarios(family: Optional[str] = None) -> List[Scenario]:
    """All registered scenarios (optionally one family), by name."""
    out = [sc for sc in _REGISTRY.values()
           if family is None or sc.family == family]
    return sorted(out, key=lambda sc: sc.name)


def clear_materialized_cache() -> None:
    """Drop the per-process memo (tests / memory pressure)."""
    _MATERIALIZED.clear()
