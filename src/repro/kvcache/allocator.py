"""Paged KV-cache physical allocator (the "OS" of the TPU adaptation).

A binary-buddy allocator over the physical KV page pool — deliberately the
same mechanism as :class:`repro.core.mappings.BuddyAllocator`, because the
paper's whole premise is that buddy allocation under churn produces *mixed
contiguity* (§2): fresh pools serve large aligned runs (large contiguity),
long-running serving workloads fragment them (small/medium contiguity).

Buddy blocks of order k are 2^k-aligned in the pool, which is exactly the
alignment the coalesced Pallas kernel needs for its class-k superblock loads
(a BlockSpec index is in units of the block shape — see
``repro.kernels.paged_attention``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from ..core.mappings import BuddyAllocator
from ..core.page_table import compute_runs


@dataclasses.dataclass
class SeqAlloc:
    """Block table of one sequence: logical KV page → physical page."""
    seq_id: int
    pages: List[int]                 # physical page ids, logical order
    blocks: List[tuple]              # (base, order) buddy blocks held


class PagedKVAllocator:
    """Allocates physical KV pages for sequences; tracks contiguity.

    ``alloc_policy``:
      * "buddy_best"  — largest buddy block ≤ remaining need (default; gives
        the large/mixed contiguity the coalesced kernel exploits)
      * "page"        — page-at-a-time (vLLM-style; worst-case contiguity,
        the baseline the paper compares against)
    """

    def __init__(self, num_pages: int, max_order: int = 8,
                 alloc_policy: str = "buddy_best"):
        self.num_pages = num_pages
        max_order = min(max_order, int(np.floor(np.log2(max(num_pages, 1)))))
        self.max_order = max_order
        self.policy = alloc_policy
        self.buddy = BuddyAllocator(num_pages, max_order=max_order)
        assert self.buddy.n_frames > 0, "pool smaller than one buddy block"
        self.seqs: Dict[int, SeqAlloc] = {}

    # ------------------------------------------------------------------
    def allocate(self, seq_id: int, n_pages: int) -> Optional[SeqAlloc]:
        if seq_id in self.seqs:
            raise KeyError(f"seq {seq_id} already allocated")
        alloc = SeqAlloc(seq_id, [], [])
        need = n_pages
        while need > 0:
            if self.policy == "page":
                order = 0
            else:
                order = min(int(np.floor(np.log2(max(need, 1)))),
                            self.max_order)
            base = None
            while base is None and order >= 0:
                base = self.buddy.alloc(order)
                if base is None:
                    order -= 1
            if base is None:
                # rollback: the seq is not registered yet, so return its
                # partial blocks to the buddy directly (self.free would be a
                # no-op here and leak them)
                for b, o in alloc.blocks:
                    self.buddy.free_block(b, o)
                return None
            take = min(1 << order, need)
            alloc.blocks.append((base, order))
            alloc.pages.extend(range(base, base + take))
            # unused tail of the block stays held (internal fragmentation,
            # as in real pools); freed with the sequence.
            need -= take
        self.seqs[seq_id] = alloc
        return alloc

    def extend(self, seq_id: int, n_pages: int) -> bool:
        """Append pages to a sequence (decode growth)."""
        alloc = self.seqs[seq_id]
        need = n_pages
        while need > 0:
            order = 0 if self.policy == "page" else min(
                int(np.floor(np.log2(max(need, 1)))), self.max_order)
            base = None
            while base is None and order >= 0:
                base = self.buddy.alloc(order)
                if base is None:
                    order -= 1
            if base is None:
                return False
            take = min(1 << order, need)
            alloc.blocks.append((base, order))
            alloc.pages.extend(range(base, base + take))
            need -= take
        return True

    def free(self, seq_id: int) -> None:
        alloc = self.seqs.pop(seq_id, None)
        if alloc is None:
            return
        for base, order in alloc.blocks:
            self.buddy.free_block(base, order)

    # ------------------------------------------------------------------
    def block_table(self, seq_id: int, max_pages: int) -> np.ndarray:
        """Padded block table (−1 beyond the sequence)."""
        pages = self.seqs[seq_id].pages
        out = np.full(max_pages, -1, dtype=np.int32)
        out[: len(pages)] = pages[:max_pages]
        return out

    def contiguity_histogram(self) -> Dict[int, int]:
        """Chunk-size histogram over all live block tables (input to
        Algorithm 3 for choosing the kernel's K classes)."""
        hist: Dict[int, int] = {}
        for alloc in self.seqs.values():
            phys = np.asarray(alloc.pages, dtype=np.int64)
            if len(phys) == 0:
                continue
            _, run_len = compute_runs(phys)
            starts = np.flatnonzero(np.diff(np.concatenate(
                [[-2], phys])) != 1)
            for s in starts:
                size = int(run_len[s])
                hist[size] = hist.get(size, 0) + 1
        return hist

    def utilization(self) -> float:
        free, _ = self.buddy.frag_stats()
        return 1.0 - free / max(self.buddy.n_frames, 1)

    # ------------------------------------------------------------------
    # Robustness: crash-restart snapshots and bad-page retirement
    # ------------------------------------------------------------------
    def snapshot_state(self) -> Dict:
        """Complete mutable state as a JSON-serializable dict (sequence
        block tables + buddy free lists) for engine checkpoints."""
        return dict(
            seqs={str(r): dict(pages=list(a.pages),
                               blocks=[[int(b), int(o)] for b, o in a.blocks])
                  for r, a in self.seqs.items()},
            free=self.buddy.snapshot())

    def restore_state(self, snap: Dict) -> None:
        self.buddy.restore(snap["free"])
        self.seqs = {
            int(r): SeqAlloc(int(r), [int(p) for p in d["pages"]],
                             [(int(b), int(o)) for b, o in d["blocks"]])
            for r, d in snap["seqs"].items()}

    def owners_of(self, pages) -> List[int]:
        """Sequence ids whose block tables touch any of ``pages``."""
        bad = set(int(p) for p in pages)
        return sorted(r for r, a in self.seqs.items() if bad & set(a.pages))

    def retire_pages(self, pages) -> List[int]:
        """Permanently remove FREE physical pages from the pool (corrupted
        KV backing store).  Owned pages are skipped — free the owning
        sequence first (quarantine-and-recompute does).  Returns the pages
        actually retired."""
        return [int(p) for p in pages if self.buddy.retire(int(p))]
