from .allocator import PagedKVAllocator, SeqAlloc
from .block_table import (assign_classes, choose_kernel_classes,
                          descriptor_tables, dma_descriptor_count,
                          window_coverage)
