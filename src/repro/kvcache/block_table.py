"""Block tables with K-bit aligned run descriptors — the paper on KV paging.

A block table maps logical KV pages → physical pool pages (the "page table").
This module computes, per 2^k-aligned logical window, whether the window is
*coverable by one class-k descriptor*:

    covered_k[b, j]  ⇔  pages [j·2^k, (j+1)·2^k) are all mapped,
                        physically consecutive, AND the physical start is
                        2^k-aligned

— the direct analogue of a k-bit aligned PTE whose contiguity spans its
window (paper §3.1), with the added physical-alignment condition because a
Pallas BlockSpec index is in units of the block shape (hardware pages and
buddy blocks are naturally aligned, so the condition is usually free).

``assign_classes`` implements Algorithm 1's rightward-compatible fill: each
page belongs to the *largest* covering class in K; pages covered by no class
fall back to class 0 (page-granular access = the "regular entry").
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np


def window_coverage(block_table: np.ndarray, k: int) -> np.ndarray:
    """bool[n_windows]: class-k coverage of each 2^k-page logical window.

    ``block_table``: int array [max_pages], -1 = unmapped.
    """
    w = 1 << k
    n = block_table.shape[0]
    nw = n // w
    bt = block_table[: nw * w].reshape(nw, w).astype(np.int64)
    mapped = (bt >= 0).all(axis=1)
    consec = (np.diff(bt, axis=1) == 1).all(axis=1) if w > 1 else \
        np.ones(nw, bool)
    aligned = (bt[:, 0] % w) == 0
    return mapped & consec & aligned


def assign_classes(block_table: np.ndarray, K: Sequence[int]
                   ) -> Dict[int, np.ndarray]:
    """Rightward-compatible class assignment (Algorithm 1 analogue).

    Returns {k: bool[n_windows_k]} where a window is marked for class k iff
    it is covered at k and NOT already claimed by a larger class in K.
    Class 0 (single pages) is always present as the fallback and marks every
    mapped page not claimed by any k in K.
    """
    n = block_table.shape[0]
    Kd = sorted(set(int(k) for k in K if k > 0), reverse=True)
    claimed = np.zeros(n, dtype=bool)
    out: Dict[int, np.ndarray] = {}
    for k in Kd:
        w = 1 << k
        cov = window_coverage(block_table, k)
        free = ~claimed[: (n // w) * w].reshape(-1, w).any(axis=1)
        take = cov & free
        out[k] = take
        claimed[: (n // w) * w] |= np.repeat(take, w)
    page_mapped = block_table >= 0
    out[0] = page_mapped & ~claimed
    return out


def descriptor_tables(block_tables: np.ndarray, K: Sequence[int]
                      ) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
    """Batched kernel inputs per class.

    ``block_tables``: [B, max_pages].  Returns, for each class k in K ∪ {0}:
    ``(window_index [B, n_w_k] int32, covered [B, n_w_k] int8)`` where
    ``window_index[b, j]`` is the PHYSICAL window index (phys_start >> k) the
    class-k Pallas pass loads for logical window j, or 0 when not covered
    (masked out by ``covered``).
    """
    B, n = block_tables.shape
    out: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
    Kall = sorted(set(list(K) + [0]), reverse=True)
    assigns = [assign_classes(block_tables[b], K) for b in range(B)]
    for k in Kall:
        w = 1 << k
        nw = n // w
        idx = np.zeros((B, nw), dtype=np.int32)
        cov = np.zeros((B, nw), dtype=np.int8)
        for b in range(B):
            take = assigns[b][k]
            if k == 0:
                take = take[: nw]
                phys = block_tables[b][: nw]
                idx[b] = np.where(take, np.maximum(phys, 0), 0)
            else:
                phys0 = block_tables[b][: nw * w: w]
                idx[b] = np.where(take, np.maximum(phys0, 0) >> k, 0)
            cov[b] = take.astype(np.int8)
        out[k] = (idx, cov)
    return out


def dma_descriptor_count(block_tables: np.ndarray, K: Sequence[int]
                         ) -> Dict[str, float]:
    """The paper's miss-count metric, TPU edition: DMA descriptors needed to
    read every mapped page once, with vs without coalescing."""
    B, n = block_tables.shape
    total_pages = int((block_tables >= 0).sum())
    coalesced = 0
    for b in range(B):
        asg = assign_classes(block_tables[b], K)
        for k, take in asg.items():
            coalesced += int(take.sum())
    return {
        "pages": total_pages,
        "descriptors_page_granular": total_pages,
        "descriptors_coalesced": coalesced,
        "reduction": 1.0 - coalesced / max(total_pages, 1),
    }


def choose_kernel_classes(contiguity_histogram: Dict[int, int],
                          psi: int = 3, theta: float = 0.9,
                          max_class: int = 6) -> List[int]:
    """Algorithm 3 with a DMA-appropriate size→class mapping.

    The paper's Table 1 assigns a chunk the smallest alignment whose window
    COVERS it (size 2–16 → k=4): a partially-filled aligned entry still
    translates its pages.  A Pallas class-k pass instead loads the whole
    2^k-page window in one DMA, so a chunk only benefits from classes with
    2^k ≤ size: f(size) = floor(log2(size)).  Same greedy coverage selection,
    θ and ψ as Algorithm 3.  ``max_class`` bounds the superblock so a class-k
    tile (2^k pages × page_size tokens × KVH × D) fits VMEM.
    """
    weights: Dict[int, int] = {}
    total = 0
    for size, freq in contiguity_histogram.items():
        if size < 2 or freq <= 0:
            continue
        k = min(int(np.floor(np.log2(size))), max_class)
        cov = size * freq
        total += cov
        weights[k] = weights.get(k, 0) + cov
    if not total:
        return []
    K: List[int] = []
    covered = 0
    for k, cov in sorted(weights.items(), key=lambda kv: (-kv[1], -kv[0])):
        K.append(k)
        covered += cov
        if covered > theta * total or len(K) >= psi:
            break
    return sorted(K, reverse=True)
