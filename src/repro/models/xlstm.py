"""xLSTM blocks (mLSTM + sLSTM) [Beck et al., arXiv:2405.04517] — pure JAX.

xlstm-350m interleaves mLSTM blocks (matrix memory C ∈ R^{dh×dh} per head,
parallelizable, no h-recurrence) with sLSTM blocks (scalar memory, true
hidden-state recurrence with block-diagonal per-head R).

Both use *exponential gating* with the max-stabilizer state m; training runs
the time recurrence under chunked ``jax.checkpoint`` (boundary states only),
decode carries O(1) state — hence xlstm runs ``long_500k`` trivially.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .common import Spec
from .config import ModelConfig


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_in = 2 * d
    H = cfg.n_heads
    return {
        "up_proj": Spec((d, 2 * d_in), ("embed", "mlp")),
        "conv_w": Spec((4, d_in), (None, "mlp")),
        "conv_b": Spec((d_in,), ("mlp",), init="zeros"),
        "wq": Spec((d_in, d_in), ("mlp", "q_heads")),
        "wk": Spec((d_in, d_in), ("mlp", "q_heads")),
        "wv": Spec((d_in, d_in), ("mlp", "q_heads")),
        "w_i": Spec((d_in, H), ("mlp", None)),
        "w_f": Spec((d_in, H), ("mlp", None)),
        "norm": Spec((d_in,), ("mlp",), init="ones"),
        "down_proj": Spec((d_in, d), ("mlp", "embed")),
    }


class MLSTMState(NamedTuple):
    conv: jax.Array   # [B, 3, d_in]
    C: jax.Array      # [B, H, dh, dh]
    n: jax.Array      # [B, H, dh]
    m: jax.Array      # [B, H]


def _mlstm_step(carry, qkvif):
    C, n, m = carry
    q, k, v, it, ft = qkvif           # q,k,v: [B,H,dh]; it,ft: [B,H]
    m_new = jnp.maximum(ft + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(ft + m - m_new)
    C = f_p[..., None, None] * C + i_p[..., None, None] * (
        v[..., :, None] * k[..., None, :])
    n = f_p[..., None] * n + i_p[..., None] * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)), 1.0)
    h = jnp.einsum("bhde,bhe->bhd", C, q) / denom[..., None]
    return (C, n, m_new), h


def mlstm_layer(cfg: ModelConfig, p: dict, x: jax.Array, *,
                scan_chunk: int = 128,
                state: Optional[MLSTMState] = None,
                return_state: bool = False):
    B, S, d = x.shape
    d_in = 2 * d
    H = cfg.n_heads
    dh = d_in // H

    xz = jnp.einsum("bsd,de->bse", x, p["up_proj"])
    xm, z = jnp.split(xz, 2, axis=-1)
    conv_state = state.conv if state is not None else \
        jnp.zeros((B, 3, d_in), x.dtype)
    xp = jnp.concatenate([conv_state.astype(xm.dtype), xm], axis=1)
    xc = sum(xp[:, i:i + S] * p["conv_w"][i] for i in range(4)) + p["conv_b"]
    xc = jax.nn.silu(xc)
    new_conv = xp[:, -3:]

    def heads(a):
        return a.reshape(B, S, H, dh).astype(jnp.float32)
    q = heads(jnp.einsum("bse,ef->bsf", xc, p["wq"])) / np.sqrt(dh)
    k = heads(jnp.einsum("bse,ef->bsf", xc, p["wk"])) / np.sqrt(dh)
    v = heads(jnp.einsum("bse,ef->bsf", xm, p["wv"]))
    it = jnp.einsum("bse,eh->bsh", xc, p["w_i"]).astype(jnp.float32)
    ft = jax.nn.log_sigmoid(
        jnp.einsum("bse,eh->bsh", xc, p["w_f"]).astype(jnp.float32))

    if state is not None:
        C0, n0, m0 = state.C.astype(jnp.float32), state.n.astype(jnp.float32), \
            state.m.astype(jnp.float32)
    else:
        C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)

    Q = min(scan_chunk, S)
    pad = (-S) % Q
    def padt(a):
        return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
    qs, ks, vs, its, fts = map(padt, (q.transpose(0, 1, 2, 3), k, v, it, ft))
    nC = qs.shape[1] // Q

    def chunk_fn(carry, inp):
        qs_, ks_, vs_, its_, fts_ = inp   # [B,Q,...]
        def t_step(c, tup):
            return _mlstm_step(c, tup)
        (C, n, m), hs = jax.lax.scan(
            t_step, carry,
            tuple(a.swapaxes(0, 1) for a in (qs_, ks_, vs_, its_, fts_)))
        return (C, n, m), hs.swapaxes(0, 1)   # [B,Q,H,dh]

    xs = tuple(a.reshape(B, nC, Q, *a.shape[2:]).swapaxes(0, 1)
               for a in (qs, ks, vs, its, fts))
    (Cf, nf, mf), hs = jax.lax.scan(jax.checkpoint(chunk_fn), (C0, n0, m0), xs)
    h = hs.swapaxes(0, 1).reshape(B, nC * Q, d_in)[:, :S]

    # group-norm per head (xLSTM uses multi-head layer norm) then gate
    hr = h.reshape(B, S, H, dh)
    mu = hr.mean(-1, keepdims=True)
    var = hr.var(-1, keepdims=True)
    hn = ((hr - mu) * jax.lax.rsqrt(var + 1e-6)).reshape(B, S, d_in)
    hn = hn * p["norm"].astype(jnp.float32)
    y = (hn * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["down_proj"])
    if return_state:
        return out, MLSTMState(new_conv, Cf, nf, mf)
    return out


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    gates = {}
    for g in ("i", "f", "z", "o"):
        gates[f"w_{g}"] = Spec((d, d), ("embed", "q_heads"))
        gates[f"r_{g}"] = Spec((H, dh, dh), (None, None, None), scale=0.5)
        gates[f"b_{g}"] = Spec((d,), (None,), init="zeros")
    gates["norm"] = Spec((d,), (None,), init="ones")
    gates["out_proj"] = Spec((d, d), ("q_heads", "embed"))
    return gates


class SLSTMState(NamedTuple):
    c: jax.Array   # [B, d]
    n: jax.Array   # [B, d]
    m: jax.Array   # [B, d]
    h: jax.Array   # [B, d]


def slstm_layer(cfg: ModelConfig, p: dict, x: jax.Array, *,
                scan_chunk: int = 128,
                state: Optional[SLSTMState] = None,
                return_state: bool = False):
    B, S, d = x.shape
    H = cfg.n_heads
    dh = d // H

    # input contributions for all gates (precomputed in parallel)
    pre = {g: jnp.einsum("bsd,de->bse", x, p[f"w_{g}"]).astype(jnp.float32)
           + p[f"b_{g}"].astype(jnp.float32) for g in ("i", "f", "z", "o")}

    if state is None:
        c0 = jnp.zeros((B, d), jnp.float32)
        n0 = jnp.zeros((B, d), jnp.float32)
        m0 = jnp.full((B, d), -1e30, jnp.float32)
        h0 = jnp.zeros((B, d), jnp.float32)
    else:
        c0, n0, m0, h0 = (a.astype(jnp.float32) for a in state)

    R = {g: p[f"r_{g}"].astype(jnp.float32) for g in ("i", "f", "z", "o")}

    def rec(hprev, g):
        hh = hprev.reshape(B, H, dh)
        return jnp.einsum("bhd,hde->bhe", hh, R[g]).reshape(B, d)

    def t_step(carry, inp):
        c, n, m, h = carry
        pi, pf, pz, po = inp
        it = pi + rec(h, "i")
        ft = jax.nn.log_sigmoid(pf + rec(h, "f"))
        zt = jnp.tanh(pz + rec(h, "z"))
        ot = jax.nn.sigmoid(po + rec(h, "o"))
        m_new = jnp.maximum(ft + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(ft + m - m_new)
        c_new = f_p * c + i_p * zt
        n_new = f_p * n + i_p
        h_new = ot * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, m_new, h_new), h_new

    Q = min(scan_chunk, S)
    pad = (-S) % Q
    xs_all = tuple(jnp.pad(pre[g], ((0, 0), (0, pad), (0, 0)))
                   for g in ("i", "f", "z", "o"))
    nC = xs_all[0].shape[1] // Q

    def chunk_fn(carry, inp):
        carry, hs = jax.lax.scan(
            t_step, carry, tuple(a.swapaxes(0, 1) for a in inp))
        return carry, hs.swapaxes(0, 1)

    xs = tuple(a.reshape(B, nC, Q, d).swapaxes(0, 1) for a in xs_all)
    carryF, hs = jax.lax.scan(jax.checkpoint(chunk_fn), (c0, n0, m0, h0), xs)
    h = hs.swapaxes(0, 1).reshape(B, nC * Q, d)[:, :S]

    hr = h.reshape(B, S, H, dh)
    mu = hr.mean(-1, keepdims=True)
    var = hr.var(-1, keepdims=True)
    hn = ((hr - mu) * jax.lax.rsqrt(var + 1e-6)).reshape(B, S, d)
    hn = hn * p["norm"].astype(jnp.float32)
    out = jnp.einsum("bse,ed->bsd", hn.astype(x.dtype), p["out_proj"])
    if return_state:
        return out, SLSTMState(*carryF)
    return out
