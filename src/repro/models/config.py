"""Model / run configuration dataclasses.

``ModelConfig`` describes an architecture (one per assigned arch in
``repro.configs``); ``RunConfig`` describes execution choices that the perf
hillclimb iterates on (dtypes, chunking, microbatching, sharding rule set).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0          # 0 → ceil(d_model/16)

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank or -(-d_model // 16)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | hybrid | xlstm | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0  # qwen2-moe style shared experts
    moe_every: int = 1         # a layer is MoE iff layer % moe_every == moe_offset
    moe_offset: int = 0
    # --- hybrid (jamba) ---
    attn_every: int = 1        # attention on layer i iff i % attn_every == attn_offset
    attn_offset: int = 0
    mamba: Optional[MambaConfig] = None
    # --- xlstm ---
    slstm_every: int = 0       # sLSTM on layer i iff slstm_every and i % slstm_every == 0
    # --- features ---
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    causal: bool = True        # False → encoder-only (hubert)
    tie_embeddings: bool = False
    # --- vlm ---
    n_patches: int = 0         # >0 → patch-embedding injection (llava stub)
    # --- norm ---
    rms_eps: float = 1e-6

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def is_moe_layer(self, i: int) -> bool:
        return (self.n_experts > 0
                and i % self.moe_every == self.moe_offset % self.moe_every)

    def is_attn_layer(self, i: int) -> bool:
        if self.family == "xlstm":
            return False
        return i % self.attn_every == self.attn_offset % self.attn_every

    def is_slstm_layer(self, i: int) -> bool:
        return bool(self.slstm_every) and i % self.slstm_every == 0

    @property
    def n_attn_layers(self) -> int:
        return sum(self.is_attn_layer(i) for i in range(self.n_layers))

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS and sanity checks)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        for i in range(self.n_layers):
            total += 2 * d                     # pre-norms (mixer + ffn)
            if self.family == "xlstm":
                total += _xlstm_layer_params(self, i)
                continue
            if self.is_attn_layer(i):
                total += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
                if self.qk_norm:
                    total += 2 * self.head_dim
            elif self.mamba is not None:
                total += _mamba_layer_params(self, self.mamba)
            if ff <= 0:
                continue
            if self.is_moe_layer(i):
                total += d * self.n_experts            # router
                total += self.n_experts * 3 * d * ff   # routed experts
                total += self.n_shared_experts * 3 * d * ff
                if self.n_shared_experts:
                    total += d                         # shared-expert gate
            else:
                total += 3 * d * ff
        total += d                                     # final norm
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.n_experts == 0:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        inactive_experts = self.n_experts - self.top_k
        n_moe_layers = sum(self.is_moe_layer(i) for i in range(self.n_layers))
        return self.param_count() - n_moe_layers * inactive_experts * 3 * d * ff


def _mamba_layer_params(cfg: ModelConfig, mc: MambaConfig) -> int:
    d = cfg.d_model
    d_in = mc.expand * d
    dtr = mc.resolved_dt_rank(d)
    return (d * 2 * d_in               # in_proj (x and z)
            + d_in * mc.d_conv         # depthwise conv
            + d_in * (dtr + 2 * mc.d_state)   # x_proj → dt, B, C
            + dtr * d_in + d_in        # dt_proj + bias
            + d_in * mc.d_state        # A_log
            + d_in                     # D
            + d_in * d)                # out_proj


def _xlstm_layer_params(cfg: ModelConfig, i: int) -> int:
    d = cfg.d_model
    if cfg.is_slstm_layer(i):
        # 4 gates × (input + recurrent block-diag per head) + out
        dh = d // cfg.n_heads
        return 4 * (d * d + cfg.n_heads * dh * dh) + d * d
    d_in = 2 * d
    return (d * 2 * d_in              # up-proj (x and z)
            + d_in * 4                # causal conv (k=4)
            + 3 * d_in * d_in // cfg.n_heads * 0  # (qkv are per-head proj below)
            + 3 * d_in * d_in         # q, k, v projections
            + 3 * d_in                # i, f gate projections (per unit) + o
            + d_in * d)               # down-proj


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Execution knobs — the surface the §Perf hillclimb iterates on."""

    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # attention chunking (memory-efficient attention block sizes)
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    # Sarathi-style chunked prefill (1 = single pass)
    prefill_seq_chunks: int = 1
    # mamba / xlstm recurrence chunk (checkpoint boundary)
    scan_chunk: int = 128
    # training
    microbatches: int = 1              # gradient-accumulation steps
    remat: str = "full"                # full | none
    optimizer: str = "adamw"           # adamw | adamw8bit | adafactor
    grad_dtype: str = "float32"        # grad-accumulator dtype (bf16 for
                                       # memory-extreme models, e.g. jamba)
    capacity_factor: float = 1.25
    # distribution
    expert_sharding: str = "tensor"    # tensor | expert
    moe_weight_gather: bool = False    # inference-only: gather small expert
                                       # stacks at use (FSDP semantics);
                                       # hurts training (full-size grad RS)
    rules: str = "default"             # sharding rule-set name
    seq_shard_decode: bool = False     # shard KV seq over data axis (long ctx)
    # kv cache / paging
    kv_page_size: int = 64             # pages of the paged KV cache (tokens)

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)
