"""Mixture-of-Experts FFN with sort-based top-k dispatch (TPU-idiomatic).

Dispatch is the MegaBlocks/GShard-style capacity-bounded gather:

  1. router logits → top-k experts per token,
  2. flatten (token, k) assignments, sort by expert id,
  3. rank within expert = position in sorted order − expert segment start,
  4. scatter tokens into an [E, C, d] buffer (assignments past capacity drop),
  5. batched expert GEMMs, 6. weighted scatter-add back.

This avoids the [T, E, C] one-hot dispatch tensor (which at 4k tokens × 60
experts would dominate memory) while staying fully differentiable: gradients
flow through gathered activations and router weights; indices are integers.

Shared experts (qwen2-moe) run as one dense SwiGLU with a sigmoid gate.

Expert sharding (RunConfig.expert_sharding):
* ``tensor`` — every expert's d_ff is sharded over "model" (works for any E,
  e.g. 60 or 40 experts on a 16-way axis);
* ``expert`` — experts sharded over "model" (E % axis == 0, e.g. jamba's 16),
  giving expert parallelism with all-to-all dispatch under SPMD.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import Spec
from .config import ModelConfig, RunConfig
from ..distributed.sharding import with_logical_constraint


def moe_specs(cfg: ModelConfig, rc: RunConfig) -> dict:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    # Two expert-weight layouts (§Perf iterations 4/6):
    # * E divisible by the 16-wide data axis (jamba 16e): FSDP over the
    #   EXPERT dim, d unsharded — avoids the batch-unsharding all-reduce of
    #   full-batch expert hiddens (16-32GB/layer on jamba prefill) that the
    #   d-on-data layout provokes.
    # * E not divisible (granite 40e, qwen2 60e): keep FSDP on d — the
    #   expert-dim layout degrades to dp-replicated experts there, and the
    #   partitioner then un-shards the dispatch scatter (u32 index planes,
    #   16GB all-gathers).  Their experts are small; d-on-data is proven.
    # (Replicating tiny expert stacks over dp was tried and REFUTED: the
    # backward pass then all-reduces activation-shaped [E,d,B,C] grad
    # intermediates, 332s of collectives on granite-moe — §Perf iteration 8.)
    if E % 16 == 0:
        wl = ("expert", None, "mlp")
        wl_down = ("expert", "mlp", None)
    else:
        wl = (None, "embed", "mlp")
        wl_down = (None, "mlp", "embed")
    s = {
        "router": Spec((d, E), ("embed", None)),
        "w_gate": Spec((E, d, ff), wl),
        "w_up": Spec((E, d, ff), wl),
        "w_down": Spec((E, ff, d), wl_down),
    }
    if cfg.n_shared_experts:
        sff = cfg.n_shared_experts * ff
        s["shared"] = {
            "w_gate": Spec((d, sff), ("embed", "mlp")),
            "w_up": Spec((d, sff), ("embed", "mlp")),
            "w_down": Spec((sff, d), ("mlp", "embed")),
            "gate": Spec((d, 1), ("embed", None)),
        }
    return s


def _dispatch_indices(expert_ids: jax.Array, E: int, capacity: int
                      ) -> Tuple[jax.Array, jax.Array]:
    """expert_ids: [A] flat assignments → (slot index in [E*C], keep mask)."""
    A = expert_ids.shape[0]
    order = jnp.argsort(expert_ids)                    # stable
    sorted_e = expert_ids[order]
    # rank within expert: position - start of this expert's segment
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    rank_sorted = jnp.arange(A) - seg_start[sorted_e]
    rank = jnp.zeros((A,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    keep = rank < capacity
    slot = expert_ids * capacity + jnp.minimum(rank, capacity - 1)
    return jnp.where(keep, slot, E * capacity), keep   # E*C = drop bucket


def moe_ffn(cfg: ModelConfig, rc: RunConfig, p: dict, x: jax.Array,
            mesh=None, act_rules: str = "default",
            ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] → (y: [B, S, d], aux_loss: scalar load-balance loss).

    Dispatch is per-GROUP (group = sequence), GShard-style: every gather /
    scatter carries the batch dim, so under data-parallel sharding the
    indices and buffers stay shard-local — no global index matrices, no
    all-gather of dispatch state (a global-index scatter made XLA
    materialize [T_global, d] u32 index planes: +70GB/device on jamba).

    Small expert stacks are constrained dp-replicated at USE (classic FSDP:
    the partitioner all-gathers the weight shards instead of resharding the
    multi-GB dispatch buffers — §Perf iteration 5).  Large stacks (jamba:
    19GB/layer) keep sharded weights: gathering activations is cheaper there.
    """
    B, S, d = x.shape
    E, k, ff = cfg.n_experts, cfg.top_k, cfg.d_ff
    T = B * S
    w_gate, w_up, w_down = p["w_gate"], p["w_up"], p["w_down"]
    expert_bytes = 3 * E * d * ff * 2
    if rc.moe_weight_gather and expert_bytes < 2e9:
        # inference: gather weights, keep batch sharded (training would
        # reduce-scatter a full-size weight grad per microbatch instead)
        w_gate = with_logical_constraint(w_gate, (None, None, "mlp"),
                                         mesh, act_rules)
        w_up = with_logical_constraint(w_up, (None, None, "mlp"),
                                       mesh, act_rules)
        w_down = with_logical_constraint(w_down, (None, "mlp", None),
                                         mesh, act_rules)

    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)       # [B, S, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros((B, E), jnp.float32)
    ce = jax.vmap(lambda c, i: c.at[i.reshape(-1)].add(1.0))(ce, expert_ids)
    aux = E * jnp.sum(me * (ce.sum(0) / (T * k)))

    A = S * k                                             # assignments/group
    capacity = int(np.ceil(A * rc.capacity_factor / E))
    capacity = max(capacity, 4)

    flat_e = expert_ids.reshape(B, A).astype(jnp.int32)
    slot, keep = jax.vmap(
        lambda e: _dispatch_indices(e, E, capacity))(flat_e)   # [B, A]
    tok_idx = jnp.repeat(jnp.arange(S, dtype=jnp.int32), k)    # [A]

    # scatter tokens into per-group expert buffers (+1 drop row)
    def scatter_group(xg, sl):
        buf = jnp.zeros((E * capacity + 1, d), xg.dtype)
        return buf.at[sl].set(xg[tok_idx])
    buf = jax.vmap(scatter_group)(x, slot)                # [B, E*C+1, d]
    eb = buf[:, : E * capacity].reshape(B, E, capacity, d)

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", eb, w_gate))
    h = h * jnp.einsum("becd,edf->becf", eb, w_up)
    out = jnp.einsum("becf,efd->becd", h, w_down)

    flat_out = out.reshape(B, E * capacity, d)

    def combine_group(fo, sl, kp, gv):
        g = jnp.where(kp[:, None], fo[jnp.minimum(sl, E * capacity - 1)], 0.0)
        w = gv.reshape(-1)[:, None].astype(g.dtype)
        return jnp.zeros((S, d), g.dtype).at[tok_idx].add(g * w)
    y = jax.vmap(combine_group)(flat_out, slot, keep, gate_vals)  # [B, S, d]

    if cfg.n_shared_experts:
        sp = p["shared"]
        hs = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, sp["w_gate"]))
        hs = hs * jnp.einsum("bsd,df->bsf", x, sp["w_up"])
        ys = jnp.einsum("bsf,fd->bsd", hs, sp["w_down"])
        g = jax.nn.sigmoid(jnp.einsum("bsd,do->bso", x, sp["gate"])
                           .astype(jnp.float32)).astype(ys.dtype)
        y = y + g * ys

    return y.astype(x.dtype), aux
