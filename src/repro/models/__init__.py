from .config import MambaConfig, ModelConfig, RunConfig
from .model import (Model, cross_entropy, decode_state_logical,
                    decode_state_shapes, init_decode_state, model_specs,
                    padded_vocab)
