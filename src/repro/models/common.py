"""Parameter-spec substrate: declare params once, get init + logical sharding.

No flax/haiku offline — this is a tiny pure-functional replacement:

* a model declares a *spec tree*: nested dicts of :class:`Spec` leaves, each
  carrying shape, logical axis names and an initializer;
* ``init_params``   materializes a param pytree (deterministic per path);
* ``logical_tree``  extracts the logical-axes pytree (same structure);
* ``abstract_params`` builds ShapeDtypeStructs with NamedShardings for the
  dry-run (no allocation).

Logical axis names are resolved to mesh axes through a rule table in
:mod:`repro.distributed.sharding` — changing a rule set re-shards the whole
model, which is the main lever of the §Perf hillclimb.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | scaled | conv
    scale: float = 1.0          # stddev multiplier (normal) / fan-in override
    dtype: Optional[str] = None  # overrides param_dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _path_seed(path: Tuple[str, ...], base: int) -> int:
    h = 2166136261
    for part in path:
        for ch in part.encode():
            h = ((h ^ ch) * 16777619) & 0xFFFFFFFF
    return (h ^ base) & 0xFFFFFFFF


def _init_leaf(spec: Spec, key, dtype) -> jax.Array:
    dt = jnp.dtype(spec.dtype or dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt)
    if spec.init == "normal":
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = spec.scale / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dt)
    if spec.init == "embed":
        std = spec.scale
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dt)
    raise ValueError(f"unknown init {spec.init}")


def _walk(tree: PyTree, fn: Callable[[Tuple[str, ...], Spec], Any],
          path: Tuple[str, ...] = ()) -> PyTree:
    if isinstance(tree, dict):
        return {k: _walk(v, fn, path + (str(k),)) for k, v in tree.items()}
    assert isinstance(tree, Spec), f"non-Spec leaf at {path}: {tree!r}"
    return fn(path, tree)


def init_params(specs: PyTree, seed: int = 0, dtype: str = "float32") -> PyTree:
    def make(path, spec):
        key = jax.random.PRNGKey(_path_seed(path, seed))
        return _init_leaf(spec, key, dtype)
    return _walk(specs, make)


def logical_tree(specs: PyTree) -> PyTree:
    return _walk(specs, lambda _, s: s.logical)


def spec_shapes(specs: PyTree, dtype: str = "float32") -> PyTree:
    return _walk(specs, lambda _, s: jax.ShapeDtypeStruct(
        s.shape, jnp.dtype(s.dtype or dtype)))


def count_params(specs: PyTree) -> int:
    total = 0

    def add(_, s):
        nonlocal total
        total += int(np.prod(s.shape))
        return None
    _walk(specs, add)
    return total
