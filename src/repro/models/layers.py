"""Transformer building blocks: RMSNorm, RoPE, GQA attention (chunked
online-softmax), SwiGLU MLP.

The attention here is the *portable jnp path* with flash-style blocking (no
S×S materialization — essential for 32k prefill); the Pallas TPU kernel in
``repro.kernels.flash_attention`` implements the same blocking for the MXU
and is validated against :func:`causal_attention` as its oracle.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import Spec
from .config import ModelConfig


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    freqs = rope_freqs(x.shape[-1], theta)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked online-softmax attention (flash-style, pure jnp)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _attn_block(q, k, v, mask, scale):
    """One (q-block, kv-block) tile: returns (m, l, o) online-softmax stats.

    q: [B, Q, H, D]; k, v: [B, S, H, D] (KV already expanded to H heads —
    the expansion is a LOCAL broadcast when kv-heads are replicated, which
    is what keeps prefill free of per-layer head resharding; a grouped
    [B,Q,KVH,G,D] layout was tried and REFUTED: with KVH=8 < the 16-way
    model axis it forced q/o resharding every layer, +1.5-15x prefill
    collectives — §Perf iteration 9).
    """
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)                         # [b,h,q]
    p = jnp.exp(s - m[..., None])
    lsum = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return m, lsum, o


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool,
                      q_offset: int | jax.Array = 0,
                      kv_len: Optional[jax.Array] = None,
                      q_chunk: int = 512, kv_chunk: int = 1024,
                      scale: Optional[float] = None) -> jax.Array:
    """Memory-efficient attention (train/prefill path).

    q: [B, Sq, Hq, D]; k, v: [B, Skv, Hkv, D] with Hq % Hkv == 0 (GQA kv
    heads broadcast to Hq — local under replicated-kv sharding).
    ``q_offset`` is the absolute position of q[0] (chunked prefill).
    Never materializes more than [B, Hq, q_chunk, kv_chunk] scores.
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    rep = Hq // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = scale if scale is not None else 1.0 / np.sqrt(D)

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    pq = (-Sq) % q_chunk
    pk = (-Skv) % kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // q_chunk, kp.shape[1] // kv_chunk

    kb = kp.reshape(B, nk, kv_chunk, Hq, D)
    vb = vp.reshape(B, nk, kv_chunk, Hq, D)

    def q_block(qi, qc):
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inputs):
            m_acc, l_acc, o_acc = carry
            ki, kc, vc = inputs
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if kv_len is not None:
                mask &= k_pos[None, :] < kv_len
            mask &= (k_pos < Skv)[None, :]
            mask &= (q_pos < q_offset + Sq)[:, None]
            m, l, o = _attn_block(qc, kc, vc, mask[None, None], scale)
            m_new = jnp.maximum(m_acc, m)
            alpha = jnp.exp(m_acc - m_new)
            beta = jnp.exp(m - m_new)
            l_new = l_acc * alpha + l * beta
            o_new = (o_acc * alpha.transpose(0, 2, 1)[..., None]
                     + o * beta.transpose(0, 2, 1)[..., None])
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, Hq, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hq, q_chunk), jnp.float32)
        o0 = jnp.zeros((B, q_chunk, Hq, D), jnp.float32)
        (m, lsum, o), _ = jax.lax.scan(
            kv_step, (m0, l0, o0),
            (jnp.arange(nk), kb.swapaxes(0, 1), vb.swapaxes(0, 1)))
        lsum = jnp.maximum(lsum, 1e-20)
        return o / lsum.transpose(0, 2, 1)[..., None]

    qb = qp.reshape(B, nq, q_chunk, Hq, D).swapaxes(0, 1)
    out = jax.lax.map(lambda args: q_block(*args), (jnp.arange(nq), qb))
    out = out.swapaxes(0, 1).reshape(B, nq * q_chunk, Hq, D)[:, :Sq]
    return out.astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_len: jax.Array, *, scale: Optional[float] = None
                     ) -> jax.Array:
    """Single-step decode attention (grouped GQA, cache never repeated).

    q: [B, 1, Hq, D]; caches: [B, S, Hkv, D]; kv_len: [B] valid lengths.
    """
    B, _, Hq, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bcgd,bscd->bcgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    mask = (jnp.arange(S)[None, :] < kv_len[:, None])[:, None, None, :]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bcgs,bscd->bcgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention layer (params + apply)
# ---------------------------------------------------------------------------

def attention_specs(cfg: ModelConfig) -> dict:
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    s = {
        "wq": Spec((d, qd), ("embed", "q_heads")),
        "wk": Spec((d, kvd), ("embed", "kv_heads")),
        "wv": Spec((d, kvd), ("embed", "kv_heads")),
        "wo": Spec((qd, d), ("q_heads", "embed")),
    }
    if cfg.qk_norm:
        s["q_norm"] = Spec((cfg.head_dim,), (None,), init="ones")
        s["k_norm"] = Spec((cfg.head_dim,), (None,), init="ones")
    return s


def attention_qkv(cfg: ModelConfig, p: dict, x: jax.Array,
                  positions: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, S, _ = x.shape
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(
        B, S, cfg.n_heads, cfg.head_dim)
    k = jnp.einsum("bsd,de->bse", x, p["wk"]).reshape(
        B, S, cfg.n_kv_heads, cfg.head_dim)
    v = jnp.einsum("bsd,de->bse", x, p["wv"]).reshape(
        B, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rms_eps)
        k = rms_norm(k, p["k_norm"], cfg.rms_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_layer(cfg: ModelConfig, p: dict, x: jax.Array,
                    positions: jax.Array, *, q_chunk: int, kv_chunk: int
                    ) -> jax.Array:
    q, k, v = attention_qkv(cfg, p, x, positions)
    o = chunked_attention(q, k, v, causal=cfg.causal,
                          q_chunk=q_chunk, kv_chunk=kv_chunk)
    B, S = x.shape[:2]
    return jnp.einsum("bse,ed->bsd", o.reshape(B, S, cfg.q_dim), p["wo"])


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    return {
        "w_gate": Spec((d, ff), ("embed", "mlp")),
        "w_up": Spec((d, ff), ("embed", "mlp")),
        "w_down": Spec((ff, d), ("mlp", "embed")),
    }


def mlp(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
    h = h * jnp.einsum("bsd,df->bsf", x, p["w_up"])
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])
