"""Model assembly: config → (specs, init, train/prefill/decode functions).

Layers are grouped into *superblocks* of ``period`` layers (the lcm of the
architecture's interleave periods: jamba = 8, xlstm = 6, homogeneous = 1) and
scanned with per-superblock ``jax.checkpoint`` — HLO stays O(period) and the
backward stores one activation per superblock boundary.

Decode threads per-layer state (KV caches / SSM states / xLSTM states)
through the same superblock scan as stacked pytrees.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from . import layers as L
from . import mamba as M
from . import moe as MoE
from . import xlstm as X
from .common import Spec, init_params, logical_tree, spec_shapes
from .config import ModelConfig, RunConfig
from ..distributed.sharding import with_logical_constraint

PyTree = Any


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def padded_vocab(cfg: ModelConfig) -> int:
    return _round_up(cfg.vocab, 256)


def block_period(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return int(np.lcm(cfg.attn_every, cfg.moe_every))
    if cfg.family == "xlstm" and cfg.slstm_every:
        return cfg.slstm_every
    return 1


def n_superblocks(cfg: ModelConfig) -> int:
    per = block_period(cfg)
    assert cfg.n_layers % per == 0, (cfg.n_layers, per)
    return cfg.n_layers // per


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------

def _mixer_kind(cfg: ModelConfig, j: int) -> str:
    if cfg.family == "xlstm":
        return "slstm" if cfg.is_slstm_layer(j) else "mlstm"
    if cfg.family == "hybrid" and not cfg.is_attn_layer(j):
        return "mamba"
    return "attn"


def _ffn_kind(cfg: ModelConfig, j: int) -> str:
    if cfg.d_ff <= 0:
        return "none"
    return "moe" if cfg.is_moe_layer(j) else "mlp"


def _position_specs(cfg: ModelConfig, rc: RunConfig, j: int) -> dict:
    d = cfg.d_model
    b: dict = {"ln1": Spec((d,), (None,), init="ones")}
    mk = _mixer_kind(cfg, j)
    if mk == "attn":
        b["attn"] = L.attention_specs(cfg)
    elif mk == "mamba":
        b["mamba"] = M.mamba_specs(cfg)
    elif mk == "mlstm":
        b["mlstm"] = X.mlstm_specs(cfg)
    elif mk == "slstm":
        b["slstm"] = X.slstm_specs(cfg)
    fk = _ffn_kind(cfg, j)
    if fk != "none":
        b["ln2"] = Spec((d,), (None,), init="ones")
        b["moe" if fk == "moe" else "mlp"] = (
            MoE.moe_specs(cfg, rc) if fk == "moe" else L.mlp_specs(cfg))
    return b


def _stack(tree: PyTree, n: int) -> PyTree:
    def f(s: Spec) -> Spec:
        return Spec((n,) + s.shape, ("layers",) + s.logical, init=s.init,
                    scale=s.scale, dtype=s.dtype)
    return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, Spec))


def model_specs(cfg: ModelConfig, rc: RunConfig) -> dict:
    d, V = cfg.d_model, padded_vocab(cfg)
    per = block_period(cfg)
    nsb = n_superblocks(cfg)
    blocks = {f"pos{j}": _position_specs(cfg, rc, j) for j in range(per)}
    s: dict = {
        "embed": Spec((V, d), ("vocab", "embed"), init="embed", scale=0.02),
        "blocks": _stack(blocks, nsb),
        "final_norm": Spec((d,), (None,), init="ones"),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = Spec((d, V), ("embed", "vocab"))
    return s


# ---------------------------------------------------------------------------
# decode-state (cache) structure
# ---------------------------------------------------------------------------

def decode_state_shapes(cfg: ModelConfig, rc: RunConfig, batch: int,
                        max_seq: int, dtype=jnp.bfloat16) -> dict:
    """Abstract decode state per superblock position, stacked over nsb."""
    per = block_period(cfg)
    nsb = n_superblocks(cfg)
    out: dict = {}
    for j in range(per):
        mk = _mixer_kind(cfg, j)
        if mk == "attn":
            kv = jax.ShapeDtypeStruct(
                (nsb, batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dtype)
            out[f"pos{j}"] = {"k": kv, "v": kv}
        elif mk == "mamba":
            mc = cfg.mamba
            d_in = mc.expand * cfg.d_model
            out[f"pos{j}"] = {
                "conv": jax.ShapeDtypeStruct((nsb, batch, mc.d_conv - 1, d_in),
                                             dtype),
                "ssm": jax.ShapeDtypeStruct((nsb, batch, d_in, mc.d_state),
                                            jnp.float32),
            }
        elif mk == "mlstm":
            d_in = 2 * cfg.d_model
            H = cfg.n_heads
            dh = d_in // H
            out[f"pos{j}"] = {
                "conv": jax.ShapeDtypeStruct((nsb, batch, 3, d_in), dtype),
                "C": jax.ShapeDtypeStruct((nsb, batch, H, dh, dh), jnp.float32),
                "n": jax.ShapeDtypeStruct((nsb, batch, H, dh), jnp.float32),
                "m": jax.ShapeDtypeStruct((nsb, batch, H), jnp.float32),
            }
        elif mk == "slstm":
            d = cfg.d_model
            out[f"pos{j}"] = {
                "c": jax.ShapeDtypeStruct((nsb, batch, d), jnp.float32),
                "n": jax.ShapeDtypeStruct((nsb, batch, d), jnp.float32),
                "m": jax.ShapeDtypeStruct((nsb, batch, d), jnp.float32),
                "h": jax.ShapeDtypeStruct((nsb, batch, d), jnp.float32),
            }
    return out


def decode_state_logical(cfg: ModelConfig) -> dict:
    """Logical axes for the decode state (for dry-run shardings)."""
    per = block_period(cfg)
    out: dict = {}
    for j in range(per):
        mk = _mixer_kind(cfg, j)
        if mk == "attn":
            kv = ("layers", "batch", "kv_seq", "kv_heads", "kv_head_dim")
            out[f"pos{j}"] = {"k": kv, "v": kv}
        elif mk == "mamba":
            out[f"pos{j}"] = {
                "conv": ("layers", "batch", None, "kv_head_dim"),
                "ssm": ("layers", "batch", "kv_head_dim", None)}
        elif mk == "mlstm":
            out[f"pos{j}"] = {
                "conv": ("layers", "batch", None, None),
                "C": ("layers", "batch", None, None, None),
                "n": ("layers", "batch", None, None),
                "m": ("layers", "batch", None)}
        elif mk == "slstm":
            out[f"pos{j}"] = {k: ("layers", "batch", None)
                              for k in ("c", "n", "m", "h")}
    return out


def init_decode_state(cfg: ModelConfig, rc: RunConfig, batch: int,
                      max_seq: int, dtype=jnp.bfloat16) -> dict:
    shapes = decode_state_shapes(cfg, rc, batch, max_seq, dtype)
    state = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
    # exponential-gating stabilizer states start at -inf, not 0 (xLSTM)
    for pos, st in state.items():
        if "m" in st and "C" in st or ("m" in st and "h" in st):
            st["m"] = jnp.full_like(st["m"], -1e30)
    return state


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    rc: RunConfig
    mesh: Optional[Mesh] = None
    act_rules: str = "default"

    # ---- params ----
    def specs(self) -> dict:
        return model_specs(self.cfg, self.rc)

    def init(self, seed: int = 0) -> PyTree:
        return init_params(self.specs(), seed=seed, dtype=self.rc.param_dtype)

    def logical(self) -> PyTree:
        return logical_tree(self.specs())

    def abstract_params(self) -> PyTree:
        return spec_shapes(self.specs(), dtype=self.rc.param_dtype)

    # ---- helpers ----
    def _constrain(self, x, logical):
        return with_logical_constraint(x, logical, self.mesh, self.act_rules)

    def _embed(self, params, tokens, patch_embeds=None):
        cdt = jnp.dtype(self.rc.compute_dtype)
        x = params["embed"].astype(cdt)[tokens]
        if self.cfg.n_patches and patch_embeds is not None:
            np_ = min(self.cfg.n_patches, x.shape[1])
            x = jax.lax.dynamic_update_slice(
                x, patch_embeds[:, :np_].astype(cdt), (0, 0, 0))
        return self._constrain(x, ("batch", "seq", "embed"))

    def _logits(self, params, x):
        head = params.get("lm_head")
        if head is None:
            head = params["embed"].T
        logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
        V = padded_vocab(self.cfg)
        if V != self.cfg.vocab:  # mask padding classes
            pad_mask = jnp.arange(V) >= self.cfg.vocab
            logits = jnp.where(pad_mask, -1e9, logits.astype(jnp.float32))
        return logits

    def _mixer(self, j, p, x, positions, *, state=None, return_state=False):
        cfg, rc = self.cfg, self.rc
        mk = _mixer_kind(cfg, j)
        if mk == "attn":
            if state is None:
                out = L.attention_layer(cfg, p["attn"], x, positions,
                                        q_chunk=rc.attn_q_chunk,
                                        kv_chunk=rc.attn_kv_chunk)
                return (out, None) if return_state else out
            return self._attn_decode(p["attn"], x, positions, state,
                                     return_state)
        if mk == "mamba":
            st = (state["conv"], state["ssm"]) if state is not None else None
            r = M.mamba_layer(cfg, p["mamba"], x, scan_chunk=rc.scan_chunk,
                              state=st, return_state=return_state)
            if return_state:
                out, (cs, ss) = r
                return out, {"conv": cs, "ssm": ss}
            return r
        if mk == "mlstm":
            st = X.MLSTMState(state["conv"], state["C"], state["n"],
                              state["m"]) if state is not None else None
            r = X.mlstm_layer(cfg, p["mlstm"], x, scan_chunk=rc.scan_chunk,
                              state=st, return_state=return_state)
            if return_state:
                out, s = r
                return out, {"conv": s.conv, "C": s.C, "n": s.n, "m": s.m}
            return r
        if mk == "slstm":
            st = X.SLSTMState(state["c"], state["n"], state["m"],
                              state["h"]) if state is not None else None
            r = X.slstm_layer(cfg, p["slstm"], x, scan_chunk=rc.scan_chunk,
                              state=st, return_state=return_state)
            if return_state:
                out, s = r
                return out, {"c": s.c, "n": s.n, "m": s.m, "h": s.h}
            return r
        raise ValueError(mk)

    def _attn_decode(self, p, x, positions, state, return_state):
        """Single-token attention against the dense KV cache."""
        cfg = self.cfg
        q, k, v = L.attention_qkv(cfg, p, x, positions)
        kv_len = positions[:, 0]                     # [B]
        B = x.shape[0]
        bidx = jnp.arange(B)
        k_cache = state["k"]
        v_cache = state["v"]
        k_cache = k_cache.at[bidx, kv_len].set(k[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[bidx, kv_len].set(v[:, 0].astype(v_cache.dtype))
        # NOTE: no sharding constraint here — the cache inherits the input
        # sharding through the aliased scan carry; a mid-scan constraint made
        # the SPMD partitioner insert per-layer "involuntary full remat"
        # copies of the whole cache slice (§Perf iteration 2).
        o = L.decode_attention(q, k_cache.astype(q.dtype),
                               v_cache.astype(q.dtype), kv_len + 1)
        out = jnp.einsum("bse,ed->bsd", o.reshape(B, 1, cfg.q_dim), p["wo"])
        if return_state:
            return out, {"k": k_cache, "v": v_cache}
        return out

    def _ffn(self, j, p, x):
        fk = _ffn_kind(self.cfg, j)
        if fk == "none":
            return x * 0.0, jnp.float32(0.0)
        if fk == "moe":
            return MoE.moe_ffn(self.cfg, self.rc, p["moe"], x,
                               mesh=self.mesh, act_rules=self.act_rules)
        return L.mlp(p["mlp"], x), jnp.float32(0.0)

    def _superblock(self, p_sb, x, positions, *, states=None,
                    return_states=False):
        cfg = self.cfg
        per = block_period(cfg)
        aux = jnp.float32(0.0)
        new_states: Dict[str, Any] = {}
        for j in range(per):
            p = p_sb[f"pos{j}"]
            st = states.get(f"pos{j}") if states is not None else None
            h = L.rms_norm(x, p["ln1"], cfg.rms_eps)
            r = self._mixer(j, p, h, positions, state=st,
                            return_state=return_states or st is not None)
            if isinstance(r, tuple):
                mix_out, new_st = r
                if return_states:
                    new_states[f"pos{j}"] = new_st
            else:
                mix_out = r
            x = x + mix_out
            if _ffn_kind(cfg, j) != "none":
                h = L.rms_norm(x, p["ln2"], cfg.rms_eps)
                f, a = self._ffn(j, p, h)
                x = x + f
                aux = aux + a
            x = self._constrain(x, ("batch", "seq", "embed"))
        return x, aux, new_states

    # ---- public passes ----
    def backbone(self, params, tokens, *, patch_embeds=None,
                 input_embeds=None, positions=None):
        """Full-sequence forward → (final hidden [B, S, d], moe aux loss)."""
        cfg, rc = self.cfg, self.rc
        cdt = jnp.dtype(rc.compute_dtype)
        if input_embeds is not None:
            x = input_embeds.astype(cdt)
            B, S = x.shape[:2]
        else:
            B, S = tokens.shape
            x = self._embed(params, tokens, patch_embeds)
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))

        def body(x, p_sb):
            x, aux, _ = self._superblock(
                jax.tree.map(lambda a: a.astype(cdt) if a.dtype in
                             (jnp.float32, jnp.bfloat16) else a, p_sb),
                x, positions)
            return x, aux

        body_fn = jax.checkpoint(body) if rc.remat == "full" else body
        x, auxs = jax.lax.scan(body_fn, x, params["blocks"])
        x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
        return x, jnp.sum(auxs)

    def forward(self, params, tokens, *, patch_embeds=None,
                input_embeds=None, positions=None):
        """Full-sequence forward → logits [B, S, V]. (inference / tests)"""
        x, aux = self.backbone(params, tokens, patch_embeds=patch_embeds,
                               input_embeds=input_embeds, positions=positions)
        return self._logits(params, x), aux

    def loss(self, params, tokens, labels, *, mask=None, patch_embeds=None,
             input_embeds=None, xent_chunk: int = 512):
        """Training loss with seq-chunked lm-head + cross-entropy: never
        materializes [B, S, V] (vocab 152k x seq 4k in fp32 would be ~5GB per
        device otherwise).  Returns (loss, moe_aux)."""
        cfg = self.cfg
        x, aux = self.backbone(params, tokens, patch_embeds=patch_embeds,
                               input_embeds=input_embeds)
        B, S, d = x.shape
        head = params.get("lm_head")
        if head is None:
            head = params["embed"].T
        head = head.astype(x.dtype)
        V = padded_vocab(cfg)
        pad_bias = jnp.where(jnp.arange(V) >= cfg.vocab, -1e9, 0.0
                             ).astype(jnp.float32)
        C = min(xent_chunk, S)
        padS = (-S) % C
        xs = jnp.pad(x, ((0, 0), (0, padS), (0, 0))).reshape(B, -1, C, d)
        ls = jnp.pad(labels, ((0, 0), (0, padS))).reshape(B, -1, C)
        if mask is None:
            mask = jnp.ones((B, S), jnp.float32)
        ms = jnp.pad(mask, ((0, 0), (0, padS))).reshape(B, -1, C)
        nc = xs.shape[1]

        def chunk(carry, idx):
            xc = xs[:, idx]
            lc = ls[:, idx]
            mc = ms[:, idx]
            logits = (jnp.einsum("bcd,dv->bcv", xc, head)
                      .astype(jnp.float32) + pad_bias)
            lse = jax.nn.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
            nll = ((lse - ll) * mc).sum()
            return carry + nll, None

        total, _ = jax.lax.scan(jax.checkpoint(chunk), jnp.float32(0.0),
                                jnp.arange(nc))
        denom = jnp.maximum(mask.sum(), 1.0)
        return total / denom, aux

    def prefill(self, params, tokens, *, patch_embeds=None,
                input_embeds=None, max_seq: Optional[int] = None):
        """Forward that also returns the decode state filled to S tokens."""
        cfg, rc = self.cfg, self.rc
        cdt = jnp.dtype(rc.compute_dtype)
        if input_embeds is not None:
            x = input_embeds.astype(cdt)
            B, S = x.shape[:2]
        else:
            B, S = tokens.shape
            x = self._embed(params, tokens, patch_embeds)
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        max_seq = max_seq or S

        per = block_period(cfg)

        def body(x, p_sb):
            p_sb = jax.tree.map(lambda a: a.astype(cdt) if a.dtype in
                                (jnp.float32, jnp.bfloat16) else a, p_sb)
            states: Dict[str, Any] = {}
            aux = jnp.float32(0.0)
            for j in range(per):
                p = p_sb[f"pos{j}"]
                h = L.rms_norm(x, p["ln1"], cfg.rms_eps)
                mk = _mixer_kind(cfg, j)
                if mk == "attn":
                    q, k, v = L.attention_qkv(cfg, p["attn"], h, positions)
                    o = L.chunked_attention(q, k, v, causal=cfg.causal,
                                            q_chunk=rc.attn_q_chunk,
                                            kv_chunk=rc.attn_kv_chunk)
                    mix = jnp.einsum("bse,ed->bsd",
                                     o.reshape(B, S, cfg.q_dim),
                                     p["attn"]["wo"])
                    pad = max_seq - S
                    kc = jnp.pad(k.astype(cdt), ((0, 0), (0, pad), (0, 0), (0, 0)))
                    vc = jnp.pad(v.astype(cdt), ((0, 0), (0, pad), (0, 0), (0, 0)))
                    states[f"pos{j}"] = {"k": kc, "v": vc}
                else:
                    mix, st = self._mixer(j, p, h, positions,
                                          return_state=True)
                    states[f"pos{j}"] = st
                x = x + mix
                if _ffn_kind(cfg, j) != "none":
                    hh = L.rms_norm(x, p["ln2"], cfg.rms_eps)
                    f, a = self._ffn(j, p, hh)
                    x = x + f
                    aux = aux + a
                x = self._constrain(x, ("batch", "seq", "embed"))
            return x, (aux, states)

        x, (auxs, states) = jax.lax.scan(body, x, params["blocks"])
        x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
        return self._logits(params, x), states

    def prefill_chunked(self, params, tokens, *, n_chunks: int,
                        patch_embeds=None, input_embeds=None,
                        max_seq: Optional[int] = None):
        """Sarathi-style chunked prefill: process the sequence in ``n_chunks``
        passes, each attending to the KV cache filled so far.

        Peak activation transients shrink ~n_chunks× (per-chunk MoE dispatch
        buffers, attention workspaces); compute is unchanged because each
        chunk attends only to the statically-sliced cache prefix.
        """
        cfg, rc = self.cfg, self.rc
        cdt = jnp.dtype(rc.compute_dtype)
        if input_embeds is not None:
            x_full = input_embeds.astype(cdt)
            B, S = x_full.shape[:2]
        else:
            B, S = tokens.shape
            x_full = self._embed(params, tokens, patch_embeds)
        assert S % n_chunks == 0, (S, n_chunks)
        Sc = S // n_chunks
        max_seq = max_seq or S
        state = init_decode_state(cfg, rc, B, max_seq, cdt)
        per = block_period(cfg)

        def attn_chunk(p, h, st, ci):
            off = ci * Sc
            positions = jnp.broadcast_to(off + jnp.arange(Sc), (B, Sc))
            q, k, v = L.attention_qkv(cfg, p, h, positions)
            kc = jax.lax.dynamic_update_slice(
                st["k"], k.astype(st["k"].dtype), (0, off, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                st["v"], v.astype(st["v"].dtype), (0, off, 0, 0))
            # static prefix slice: no wasted compute on unfilled cache
            o = L.chunked_attention(
                q, kc[:, :off + Sc].astype(q.dtype),
                vc[:, :off + Sc].astype(q.dtype),
                causal=cfg.causal, q_offset=off,
                q_chunk=rc.attn_q_chunk, kv_chunk=rc.attn_kv_chunk)
            out = jnp.einsum("bse,ed->bsd", o.reshape(B, Sc, cfg.q_dim),
                             p["wo"])
            return out, {"k": kc, "v": vc}

        hidden_chunks = []
        for ci in range(n_chunks):
            xc = jax.lax.dynamic_slice_in_dim(x_full, ci * Sc, Sc, axis=1)
            positions = jnp.broadcast_to(ci * Sc + jnp.arange(Sc), (B, Sc))

            def body(x, xs, _ci=ci):
                p_sb, st_sb = xs
                p_sb = jax.tree.map(lambda a: a.astype(cdt) if a.dtype in
                                    (jnp.float32, jnp.bfloat16) else a, p_sb)
                new_states: Dict[str, Any] = {}
                for j in range(per):
                    p = p_sb[f"pos{j}"]
                    st = st_sb[f"pos{j}"]
                    h = L.rms_norm(x, p["ln1"], cfg.rms_eps)
                    if _mixer_kind(cfg, j) == "attn":
                        mix, new_st = attn_chunk(p["attn"], h, st, _ci)
                    else:
                        mix, new_st = self._mixer(j, p, h, positions,
                                                  state=st, return_state=True)
                    new_states[f"pos{j}"] = new_st
                    x = x + mix
                    if _ffn_kind(cfg, j) != "none":
                        hh = L.rms_norm(x, p["ln2"], cfg.rms_eps)
                        f, _ = self._ffn(j, p, hh)
                        x = x + f
                    x = self._constrain(x, ("batch", "seq", "embed"))
                return x, new_states

            xc, state = jax.lax.scan(body, xc, (params["blocks"], state))
            hidden_chunks.append(xc)

        h = jnp.concatenate(hidden_chunks, axis=1)
        h = L.rms_norm(h, params["final_norm"], cfg.rms_eps)
        return self._logits(params, h), state

    def decode_step_paged(self, params, state, tokens, kv_len, block_tables,
                          descriptors, *, page_size: int,
                          K_classes: Tuple[int, ...], interpret: bool = True):
        """One decode step against the PAGED KV cache (the paper's path).

        ``state``: as ``decode_step`` but attention positions hold
        {"pool_k","pool_v"} of shape [nsb, n_pages, T, KVH, D];
        ``block_tables``: [B, max_pages] int32 (shared by all layers);
        ``descriptors``: per-class (win_idx, covered) arrays from
        ``repro.kernels.paged_attention.ops.build_descriptors``.
        """
        from ..kernels.paged_attention.ops import _paged_attention_jit
        cfg, rc = self.cfg, self.rc
        cdt = jnp.dtype(rc.compute_dtype)
        B = tokens.shape[0]
        x = self._embed(params, tokens)
        positions = kv_len[:, None]
        classes = tuple(sorted(set(list(K_classes) + [0]), reverse=True))
        desc_flat = []
        for k in classes:
            wi, cov = descriptors[k]
            desc_flat += [jnp.asarray(wi), jnp.asarray(cov)]
        bt = jnp.asarray(block_tables)
        bidx = jnp.arange(B)
        page_of = bt[bidx, kv_len // page_size]
        off_of = kv_len % page_size
        # inactive batch slots carry all -1 block tables; a raw scatter at
        # page -1 would wrap to the LAST pool page and corrupt whichever
        # live sequence owns it.  Route them to an out-of-range page and
        # drop: a clamped index would collide with an active lane writing
        # the same cell, and duplicate-index scatter order is unspecified.
        lane_ok = page_of >= 0

        def paged_attn(p, h, st):
            q, k, v = L.attention_qkv(cfg, p, h, positions)
            n_pool = st["pool_k"].shape[0]
            drop_page = jnp.where(lane_ok, page_of, n_pool)
            pk = st["pool_k"].at[drop_page, off_of].set(
                k[:, 0].astype(st["pool_k"].dtype), mode="drop")
            pv = st["pool_v"].at[drop_page, off_of].set(
                v[:, 0].astype(st["pool_v"].dtype), mode="drop")
            o = _paged_attention_jit(
                q[:, 0], pk, pv, kv_len + 1, tuple(desc_flat),
                page_size=page_size, classes=classes, interpret=interpret)
            out = jnp.einsum("bse,ed->bsd",
                             o[:, None].astype(h.dtype).reshape(B, 1, cfg.q_dim),
                             p["wo"])
            return out, {"pool_k": pk, "pool_v": pv}

        per = block_period(cfg)

        def body(x, xs):
            p_sb, st_sb = xs
            p_sb = jax.tree.map(lambda a: a.astype(cdt) if a.dtype in
                                (jnp.float32, jnp.bfloat16) else a, p_sb)
            new_states: Dict[str, Any] = {}
            for j in range(per):
                p = p_sb[f"pos{j}"]
                st = st_sb[f"pos{j}"]
                h = L.rms_norm(x, p["ln1"], cfg.rms_eps)
                if _mixer_kind(cfg, j) == "attn":
                    mix, new_st = paged_attn(p["attn"], h, st)
                else:
                    mix, new_st = self._mixer(j, p, h, positions, state=st,
                                              return_state=True)
                new_states[f"pos{j}"] = new_st
                x = x + mix
                if _ffn_kind(cfg, j) != "none":
                    hh = L.rms_norm(x, p["ln2"], cfg.rms_eps)
                    f, _ = self._ffn(j, p, hh)
                    x = x + f
            return x, new_states

        x, new_states = jax.lax.scan(body, x, (params["blocks"], state))
        x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
        return self._logits(params, x), new_states

    def decode_step(self, params, state, tokens, kv_len):
        """One decode step: tokens [B, 1], kv_len [B] → (logits, new state)."""
        cfg, rc = self.cfg, self.rc
        cdt = jnp.dtype(rc.compute_dtype)
        x = self._embed(params, tokens)
        positions = kv_len[:, None]

        def body(x, xs):
            p_sb, st_sb = xs
            p_sb = jax.tree.map(lambda a: a.astype(cdt) if a.dtype in
                                (jnp.float32, jnp.bfloat16) else a, p_sb)
            x, _, new_st = self._superblock(p_sb, x, positions,
                                            states=st_sb, return_states=True)
            return x, new_st

        x, new_states = jax.lax.scan(body, x, (params["blocks"], state))
        x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
        return self._logits(params, x), new_states


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is not None:
        return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return -ll.mean()
