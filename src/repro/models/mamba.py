"""Mamba-1 selective SSM block (for the Jamba hybrid) — pure JAX.

Training/prefill uses a *chunked* selective scan: the sequence is split into
``scan_chunk``-sized chunks processed by an outer ``lax.scan`` whose body is
``jax.checkpoint``-ed, so the backward pass stores only chunk-boundary states
([B, d_inner, d_state] per chunk) instead of the full [B, S, d_inner, d_state]
state trajectory — the standard memory shape for SSM training, and the reason
jamba can train at 4k×256 global batch.  Within a chunk the recurrence runs as
an associative scan (parallel on the MXU/VPU).

Decode keeps O(1) state per layer: (conv_state [B, d_conv-1, d_inner],
ssm_state [B, d_inner, d_state]) — this is why jamba runs the ``long_500k``
shape that pure-attention models cannot.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .common import Spec
from .config import ModelConfig


def mamba_specs(cfg: ModelConfig) -> dict:
    mc = cfg.mamba
    d = cfg.d_model
    d_in = mc.expand * d
    dtr = mc.resolved_dt_rank(d)
    return {
        "in_proj": Spec((d, 2 * d_in), ("embed", "mlp")),
        "conv_w": Spec((mc.d_conv, d_in), (None, "mlp")),
        "conv_b": Spec((d_in,), ("mlp",), init="zeros"),
        "x_proj": Spec((d_in, dtr + 2 * mc.d_state), ("mlp", None)),
        "dt_proj": Spec((dtr, d_in), (None, "mlp")),
        "dt_bias": Spec((d_in,), ("mlp",), init="zeros"),
        "A_log": Spec((d_in, mc.d_state), ("mlp", None), init="ones"),
        "D": Spec((d_in,), ("mlp",), init="ones"),
        "out_proj": Spec((d_in, d), ("mlp", "embed")),
    }


def _ssm_chunk(h0, abar, bx):
    """Associative scan of h_t = abar_t * h_{t-1} + bx_t over one chunk.

    abar, bx: [B, Q, d_in, N]; h0: [B, d_in, N].  Returns (hQ, y_states).
    """
    def combine(a, b):
        a1, b1 = a
        a2, b2 = b
        return a1 * a2, a2 * b1 + b2

    # fold h0 into the first element
    bx = bx.at[:, 0].add(abar[:, 0] * h0)
    acc_a, acc_b = jax.lax.associative_scan(combine, (abar, bx), axis=1)
    return acc_b[:, -1], acc_b


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv along time. x: [B, S, d_in]; w: [K, d_in]."""
    K = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else pad
    return out + b, new_state


def mamba_layer(cfg: ModelConfig, p: dict, x: jax.Array, *,
                scan_chunk: int = 128,
                state: Optional[Tuple[jax.Array, jax.Array]] = None,
                return_state: bool = False):
    """x: [B, S, d_model] → [B, S, d_model] (+ updated decode state)."""
    mc = cfg.mamba
    B, S, d = x.shape
    d_in = mc.expand * d
    dtr = mc.resolved_dt_rank(d)
    N = mc.d_state

    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xr, z = jnp.split(xz, 2, axis=-1)
    conv_state = state[0] if state is not None else None
    xr, new_conv_state = _causal_conv(xr, p["conv_w"], p["conv_b"], conv_state)
    xr = jax.nn.silu(xr)

    proj = jnp.einsum("bse,ef->bsf", xr, p["x_proj"])
    dt_r, Bc, Cc = jnp.split(proj, [dtr, dtr + N], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,re->bse", dt_r, p["dt_proj"])
                         + p["dt_bias"])                       # [B,S,d_in]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # [d_in, N]

    dt32 = dt.astype(jnp.float32)
    xr32 = xr.astype(jnp.float32)
    h0 = (state[1].astype(jnp.float32) if state is not None
          else jnp.zeros((B, d_in, N), jnp.float32))

    if S == 1:  # decode step: closed-form single update
        abar = jnp.exp(dt32[:, 0, :, None] * A)                # [B,d_in,N]
        bx = (dt32[:, 0, :, None] * Bc[:, 0, None, :].astype(jnp.float32)
              * xr32[:, 0, :, None])
        h = abar * h0 + bx
        y = jnp.einsum("ben,bn->be", h, Cc[:, 0].astype(jnp.float32))
        y = y + p["D"].astype(jnp.float32) * xr32[:, 0]
        y = y[:, None, :]
        states_h = h
    else:
        Q = min(scan_chunk, S)
        pad = (-S) % Q
        def padt(a):
            return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        dtp, xp_, Bp, Cp = map(padt, (dt32, xr32, Bc.astype(jnp.float32),
                                      Cc.astype(jnp.float32)))
        nC = dtp.shape[1] // Q

        def chunk_fn(h, inp):
            dtc, xc, Bc_, Cc_ = inp                            # [B,Q,...]
            abar = jnp.exp(dtc[..., None] * A)                 # [B,Q,d_in,N]
            bx = dtc[..., None] * Bc_[:, :, None, :] * xc[..., None]
            hQ, hs = _ssm_chunk(h, abar, bx)
            yc = jnp.einsum("bqen,bqn->bqe", hs, Cc_)
            return hQ, yc

        xs = tuple(a.reshape(B, nC, Q, *a.shape[2:]).swapaxes(0, 1)
                   for a in (dtp, xp_, Bp, Cp))
        hF, ys = jax.lax.scan(jax.checkpoint(chunk_fn), h0, xs)
        y = ys.swapaxes(0, 1).reshape(B, nC * Q, d_in)[:, :S]
        y = y + p["D"].astype(jnp.float32) * xr32
        states_h = hF

    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    if return_state:
        return out, (new_conv_state, states_h)
    return out
