"""Shared static parsers for the method-kind registry.

One parser, two consumers: ``scripts/check_docs_links.py`` (the docs CI
job, which installs nothing) and the kind-dispatch contract pass both
resolve the registered kinds through these functions, so the two can
never drift the way the old regex copy in the docs checker could.

Everything here reads source via :mod:`ast` — importing
``repro.core.simulator`` would drag in jax, which the consumers must not
require.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional

SIMULATOR = "src/repro/core/simulator.py"
BASELINES = "src/repro/core/baselines.py"
METHODS_DOC = "docs/methods.md"


def _tuple_assignments(tree: ast.AST) -> Dict[str, ast.expr]:
    out: Dict[str, ast.expr] = {}
    for node in ast.walk(tree):
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets = [node.target]
        for t in targets:
            if isinstance(t, ast.Name):
                out.setdefault(t.id, node.value)
    return out


def _eval_str_tuple(expr: ast.expr,
                    env: Dict[str, ast.expr]) -> Optional[List[str]]:
    """Evaluate a tuple-of-strings expression: literals, names bound to
    such tuples, and ``+`` concatenation (the shapes ``KINDS`` uses)."""
    if isinstance(expr, (ast.Tuple, ast.List)):
        vals: List[str] = []
        for e in expr.elts:
            if not (isinstance(e, ast.Constant) and isinstance(e.value, str)):
                return None
            vals.append(e.value)
        return vals
    if isinstance(expr, ast.Name):
        if expr.id not in env:
            return None
        return _eval_str_tuple(env[expr.id], env)
    if isinstance(expr, ast.BinOp) and isinstance(expr.op, ast.Add):
        left = _eval_str_tuple(expr.left, env)
        right = _eval_str_tuple(expr.right, env)
        if left is None or right is None:
            return None
        return left + right
    return None


def _kinds_from_tree(tree: ast.AST, name: str) -> List[str]:
    env = _tuple_assignments(tree)
    if name not in env:
        raise ValueError(f"no assignment to {name} found in simulator")
    vals = _eval_str_tuple(env[name], env)
    if vals is None:
        raise ValueError(f"{name} is not a static tuple of strings")
    seen, out = set(), []
    for v in vals:
        if v not in seen:
            seen.add(v)
            out.append(v)
    return out


def registered_kinds(repo) -> List[str]:
    """All method kinds (``simulator.KINDS``), parsed statically.

    ``repo`` is a :class:`repro.analysis.framework.Repo` (or anything
    with a compatible ``tree``/``text`` API) rooted at the repository.
    """
    tree = repo.tree(SIMULATOR)
    if tree is None:
        raise ValueError(f"cannot parse {SIMULATOR}")
    return _kinds_from_tree(tree, "KINDS")


def accel_kinds(repo) -> List[str]:
    """The accelerator-lineage subset (``simulator.ACCEL_KINDS``)."""
    tree = repo.tree(SIMULATOR)
    if tree is None:
        raise ValueError(f"cannot parse {SIMULATOR}")
    return _kinds_from_tree(tree, "ACCEL_KINDS")


def spec_factories(repo) -> Dict[str, List[str]]:
    """kind -> spec-factory function names, parsed from baselines.py.

    A factory is a module-level function whose body constructs a
    ``MethodSpec(kind="...")`` (directly or in a return expression).
    """
    tree = repo.tree(BASELINES)
    out: Dict[str, List[str]] = {}
    if tree is None:
        return out
    for node in getattr(tree, "body", []):
        if not isinstance(node, ast.FunctionDef):
            continue
        for call in ast.walk(node):
            if not (isinstance(call, ast.Call)
                    and isinstance(call.func, ast.Name)
                    and call.func.id == "MethodSpec"):
                continue
            for kw in call.keywords:
                if (kw.arg == "kind" and isinstance(kw.value, ast.Constant)
                        and isinstance(kw.value.value, str)):
                    out.setdefault(kw.value.value, []).append(node.name)
    return out


def undocumented_kinds(repo) -> List[str]:
    """Kinds missing a `` `kind` `` mention in docs/methods.md."""
    doc = repo.text(METHODS_DOC)
    if doc is None:
        return list(registered_kinds(repo))
    return [k for k in registered_kinds(repo) if f"`{k}`" not in doc]
