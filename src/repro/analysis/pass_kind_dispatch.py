"""Pass: every registered method kind is dispatched everywhere it must be.

The repo's correctness story is four executors of one semantics; the
fuzzer and goldens prove them bit-exact *dynamically*, but only for the
kinds they were told about.  This pass closes the registration loop
statically: for every kind in ``simulator.KINDS`` it demands

* **dispatch evidence** in the pure-python oracle (``simulator.py``) and
  in the shared lane program (``lane_program.py``) — the kind's selector
  literal inside the named function, per the contract table below;
* **flag plumbing** — kinds selected per lane by a boolean must carry it
  through ``STEP_KEYS`` (lane program), ``PARAM_KEYS`` and ``_lane_dict``
  (Pallas kernel), and the ``lanes`` dict built by ``pack_lanes``;
* **a golden** under ``tests/goldens/`` whose ``spec.kind`` matches;
* **test registration** — a spec factory for the kind in ``baselines.py``
  that appears in both ``tests/test_backends.py::ALL_KINDS`` and
  ``tests/test_fuzz_differential.py::SPECS``;
* **documentation** in ``docs/methods.md`` (shared with
  ``scripts/check_docs_links.py``).

A kind with no entry in ``KIND_CONTRACTS`` fails too: adding a kind means
declaring how it is dispatched, in this one table.
"""
from __future__ import annotations

import ast
import json
from typing import Dict, List, Optional, Set

from .framework import Finding, Repo, missing_file
from .kinds import (BASELINES, METHODS_DOC, SIMULATOR, registered_kinds,
                    spec_factories, undocumented_kinds)

RULE = "kind-dispatch"

LANE_PROGRAM = "src/repro/core/lane_program.py"
TLB_SWEEP = "src/repro/kernels/tlb_sweep/tlb_sweep.py"
BACKENDS_TEST = "tests/test_backends.py"
FUZZ_TEST = "tests/test_fuzz_differential.py"
GOLDEN_DIR = "tests/goldens"

# Per-kind dispatch contract.  ``oracle``/``lane``: (function, literal)
# pairs — the selector literal must occur inside that function of
# simulator.py / lane_program.py.  ``None`` means the kind rides the
# generic datapath there (no kind-specific selector to check).  ``flag``:
# the per-lane boolean that selects the kind's datapath in step_access,
# or None for kinds driven by generic lane data (K classes, predictor).
KIND_CONTRACTS: Dict[str, Dict] = {
    "base": dict(oracle=None, lane=None, flag=None),
    "thp": dict(oracle=[("_run_segments", "thp"), ("_simulate", "thp")],
                lane=[("pack_lanes", "thp"), ("_fill_profile_key", "thp")],
                flag="is_thp"),
    "colt": dict(oracle=[("_run_segments", "colt"), ("_simulate", "colt")],
                 lane=[("pack_lanes", "colt"),
                       ("_fill_profile_key", "colt")],
                 flag="is_colt"),
    "cluster": dict(oracle=[("_run_segments", "cluster"),
                            ("_simulate", "cluster")],
                    lane=[("pack_lanes", "cluster")],
                    flag="has_cluster"),
    "rmm": dict(oracle=[("_run_segments", "rmm"), ("_simulate", "rmm")],
                lane=[("pack_lanes", "rmm")],
                flag="has_rmm"),
    "anchor": dict(oracle=[("_simulate", "anchor"),
                           ("miss_chain_cycles", "anchor")],
                   lane=[("_fill_profile_key", "anchor")],
                   flag=None),
    "kaligned": dict(oracle=[("_simulate", "kaligned"),
                             ("miss_chain_cycles", "kaligned")],
                     lane=[("_fill_profile_key", "kaligned")],
                     flag=None),
    "subregion": dict(oracle=[("_run_segments", "subregion")],
                      lane=[("pack_lanes", "subregion"),
                            ("_fill_profile_key", "subregion")],
                      flag="is_subr"),
    "cache-tlb": dict(oracle=[("_run_segments", "cache-tlb")],
                      lane=[("pack_lanes", "cache-tlb")],
                      flag="has_ctlb"),
    "dead-protect": dict(oracle=[("_run_segments", "dead-protect")],
                         lane=[("pack_lanes", "dead-protect")],
                         flag="use_dead"),
}

# Per-policy dispatch contract, the ``*_policy`` analogue of the kind
# table: every ``MethodSpec`` field named ``*_policy`` is an orthogonal
# per-lane knob (context-switch handling, translation coherence,
# soft-error recovery) that both executors must branch on.  ``oracle``/
# ``lane``: (function, literal) pairs as in KIND_CONTRACTS.  A policy
# field with no entry fails — adding a policy (as ``par_policy`` was for
# the tlb-parity fault model) means declaring its dispatch evidence here.
POLICY_CONTRACTS: Dict[str, Dict] = {
    "ctx_policy": dict(
        oracle=[("_segs_multitenant", "flush"), ("_segs_multitenant", "tag"),
                ("_segs_nested", "flush"), ("_segs_nested", "tag")],
        lane=[("pack_lanes", "flush"), ("pack_lanes", "tag")]),
    "coh_policy": dict(
        oracle=[("_run_segments", "hw-coherence")],
        lane=[("pack_lanes", "hw-coherence")]),
    "par_policy": dict(
        oracle=[("run_method_parity", "parity")],
        lane=[("pack_lanes", "ecc")]),
}


def _function(tree: ast.AST, name: str) -> Optional[ast.FunctionDef]:
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


def _literals_in(fn: ast.FunctionDef) -> Set[str]:
    return {n.value for n in ast.walk(fn)
            if isinstance(n, ast.Constant) and isinstance(n.value, str)}


def _str_tuple(tree: ast.AST, name: str) -> Optional[List[str]]:
    for node in ast.walk(tree):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == name):
            try:
                val = ast.literal_eval(node.value)
            except ValueError:
                return None
            if isinstance(val, tuple) and all(isinstance(v, str)
                                              for v in val):
                return list(val)
    return None


def _dict_keys_built(fn: ast.FunctionDef, var: str) -> Set[str]:
    """Keys of the ``var = dict(...)`` literal plus ``var["k"] = ...`` and
    ``var["k"][i] = ...`` writes inside ``fn``."""
    keys: Set[str] = set()
    for node in ast.walk(fn):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == var
                and isinstance(node.value, ast.Call)
                and isinstance(node.value.func, ast.Name)
                and node.value.func.id == "dict"):
            keys.update(kw.arg for kw in node.value.keywords if kw.arg)
        if isinstance(node, ast.Subscript):
            tgt = node.value
            while isinstance(tgt, ast.Subscript):
                tgt = tgt.value
            if (isinstance(tgt, ast.Name) and tgt.id == var
                    and isinstance(node.slice, ast.Constant)
                    and isinstance(node.slice.value, str)):
                keys.add(node.slice.value)
    return keys


def _names_in(fn: ast.FunctionDef) -> Set[str]:
    return {n.id for n in ast.walk(fn) if isinstance(n, ast.Name)}


def _golden_kinds(repo: Repo) -> Set[str]:
    out: Set[str] = set()
    for name in repo.listdir(GOLDEN_DIR):
        if not name.endswith(".json"):
            continue
        text = repo.text(f"{GOLDEN_DIR}/{name}")
        try:
            data = json.loads(text or "")
        except json.JSONDecodeError:
            continue
        kind = (data.get("spec") or {}).get("kind")
        if kind:
            out.add(kind)
    return out


def _factory_calls(repo: Repo, rel: str, var: str) -> Optional[Set[str]]:
    tree = repo.tree(rel)
    if tree is None:
        return None
    for node in ast.walk(tree):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == var):
            return {c.func.id for c in ast.walk(node.value)
                    if isinstance(c, ast.Call)
                    and isinstance(c.func, ast.Name)}
    return None


def run(repo: Repo) -> List[Finding]:
    findings: List[Finding] = []
    sim_tree = repo.tree(SIMULATOR)
    lane_tree = repo.tree(LANE_PROGRAM)
    if sim_tree is None or lane_tree is None:
        bad = SIMULATOR if sim_tree is None else LANE_PROGRAM
        return [missing_file(bad, RULE, "file absent or unparseable")]
    try:
        kinds = registered_kinds(repo)
    except ValueError as e:
        return [missing_file(SIMULATOR, RULE, str(e))]

    def fn_literals(tree, rel, name) -> Optional[Set[str]]:
        fn = _function(tree, name)
        if fn is None:
            findings.append(Finding(
                file=rel, line=0, rule=RULE, severity="error",
                message=f"expected function {name}() not found",
                hint="the kind-dispatch contract table names it; update "
                     "KIND_CONTRACTS if it was renamed"))
            return None
        return _literals_in(fn)

    lit_cache: Dict = {}

    def check_evidence(kind, where, tree, rel):
        for fname, literal in where or []:
            key = (rel, fname)
            if key not in lit_cache:
                lit_cache[key] = fn_literals(tree, rel, fname)
            lits = lit_cache[key]
            if lits is not None and literal not in lits:
                findings.append(Finding(
                    file=rel, line=0, rule=RULE, severity="error",
                    message=f"kind {kind!r}: selector literal {literal!r} "
                            f"missing from {fname}()",
                    hint="the executor no longer dispatches this kind "
                         "here; restore the dispatch or update "
                         "KIND_CONTRACTS"))

    step_keys = _str_tuple(lane_tree, "STEP_KEYS")
    tlb_tree = repo.tree(TLB_SWEEP)
    param_keys = (_str_tuple(tlb_tree, "PARAM_KEYS")
                  if tlb_tree is not None else None)
    if step_keys is None:
        findings.append(missing_file(LANE_PROGRAM, RULE,
                                     "STEP_KEYS tuple not found"))
    if param_keys is None:
        findings.append(missing_file(TLB_SWEEP, RULE,
                                     "PARAM_KEYS tuple not found"))

    pack_fn = _function(lane_tree, "pack_lanes")
    step_fn = _function(lane_tree, "step_access")
    lanes_keys = (_dict_keys_built(pack_fn, "lanes")
                  if pack_fn is not None else set())
    step_names = _names_in(step_fn) if step_fn is not None else set()
    step_strings = _literals_in(step_fn) if step_fn is not None else set()
    lane_dict_fn = (_function(tlb_tree, "_lane_dict")
                    if tlb_tree is not None else None)
    lane_dict_keys: Set[str] = set()
    if lane_dict_fn is not None:
        for node in ast.walk(lane_dict_fn):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id == "dict"):
                lane_dict_keys.update(kw.arg for kw in node.keywords
                                      if kw.arg)

    golden_kinds = _golden_kinds(repo)
    factories = spec_factories(repo)
    backends_calls = _factory_calls(repo, BACKENDS_TEST, "ALL_KINDS")
    fuzz_calls = _factory_calls(repo, FUZZ_TEST, "SPECS")
    if backends_calls is None:
        findings.append(missing_file(BACKENDS_TEST, RULE,
                                     "ALL_KINDS list not found"))
    if fuzz_calls is None:
        findings.append(missing_file(FUZZ_TEST, RULE,
                                     "SPECS list not found"))

    for kind in kinds:
        contract = KIND_CONTRACTS.get(kind)
        if contract is None:
            findings.append(Finding(
                file=SIMULATOR, line=0, rule=RULE, severity="error",
                message=f"kind {kind!r} has no entry in the dispatch "
                        f"contract table",
                hint="declare its oracle/lane selectors and flag in "
                     "repro.analysis.pass_kind_dispatch.KIND_CONTRACTS"))
            continue
        check_evidence(kind, contract["oracle"], sim_tree, SIMULATOR)
        check_evidence(kind, contract["lane"], lane_tree, LANE_PROGRAM)

        flag = contract["flag"]
        if flag is not None:
            for keys, rel, what in (
                    (step_keys, LANE_PROGRAM, "STEP_KEYS"),
                    (param_keys, TLB_SWEEP, "PARAM_KEYS")):
                if keys is not None and flag not in keys:
                    findings.append(Finding(
                        file=rel, line=0, rule=RULE, severity="error",
                        message=f"kind {kind!r}: lane flag {flag!r} "
                                f"missing from {what}",
                        hint="the flag must flow through both backends' "
                             "per-lane scalar plumbing"))
            if pack_fn is not None and flag not in lanes_keys:
                findings.append(Finding(
                    file=LANE_PROGRAM, line=0, rule=RULE, severity="error",
                    message=f"kind {kind!r}: pack_lanes never sets "
                            f"lanes[{flag!r}]",
                    hint="every STEP_KEYS flag must be packed per lane"))
            if (step_fn is not None and flag not in step_names
                    and flag not in step_strings):
                findings.append(Finding(
                    file=LANE_PROGRAM, line=0, rule=RULE, severity="error",
                    message=f"kind {kind!r}: step_access never reads "
                            f"lane flag {flag!r}",
                    hint="the shared step is the only datapath; a flag "
                         "it ignores dispatches nothing"))
            if lane_dict_fn is not None and flag not in lane_dict_keys:
                findings.append(Finding(
                    file=TLB_SWEEP, line=0, rule=RULE, severity="error",
                    message=f"kind {kind!r}: _lane_dict omits flag "
                            f"{flag!r}",
                    hint="the Pallas kernel rebuilds the lane dict from "
                         "its params row; every STEP_KEYS flag belongs "
                         "there"))

        if kind not in golden_kinds:
            findings.append(Finding(
                file=GOLDEN_DIR, line=0, rule=RULE, severity="error",
                message=f"kind {kind!r} has no golden trace",
                hint="add one via scripts/make_goldens.py"))
        fnames = factories.get(kind, [])
        if not fnames:
            findings.append(Finding(
                file=BASELINES, line=0, rule=RULE, severity="error",
                message=f"kind {kind!r} has no spec factory",
                hint="add a *_spec() factory so tests can register it"))
        else:
            for calls, rel, what in ((backends_calls, BACKENDS_TEST,
                                      "ALL_KINDS"),
                                     (fuzz_calls, FUZZ_TEST, "SPECS")):
                if calls is not None and not set(fnames) & calls:
                    findings.append(Finding(
                        file=rel, line=0, rule=RULE, severity="error",
                        message=f"kind {kind!r}: no factory of "
                                f"{fnames} appears in {what}",
                        hint="register the kind so the differential "
                             "suites exercise it"))

    # -- MethodSpec *_policy knobs: declared and dispatched -------------
    policy_fields: Set[str] = set()
    for node in ast.walk(sim_tree):
        if isinstance(node, ast.ClassDef) and node.name == "MethodSpec":
            policy_fields = {
                n.target.id for n in node.body
                if isinstance(n, ast.AnnAssign)
                and isinstance(n.target, ast.Name)
                and n.target.id.endswith("_policy")}
    for field in sorted(policy_fields):
        contract = POLICY_CONTRACTS.get(field)
        if contract is None:
            findings.append(Finding(
                file=SIMULATOR, line=0, rule=RULE, severity="error",
                message=f"MethodSpec.{field} has no entry in the policy "
                        f"dispatch contract table",
                hint="declare its oracle/lane selector literals in "
                     "repro.analysis.pass_kind_dispatch.POLICY_CONTRACTS"))
            continue
        check_evidence(field, contract["oracle"], sim_tree, SIMULATOR)
        check_evidence(field, contract["lane"], lane_tree, LANE_PROGRAM)
    for field in POLICY_CONTRACTS:
        if field not in policy_fields:
            findings.append(Finding(
                file=SIMULATOR, line=0, rule=RULE, severity="warning",
                message=f"policy contract table lists unknown MethodSpec "
                        f"field {field!r}",
                hint="remove its POLICY_CONTRACTS entry"))

    for kind in undocumented_kinds(repo):
        findings.append(Finding(
            file=METHODS_DOC, line=0, rule=RULE, severity="error",
            message=f"kind {kind!r} is not documented",
            hint="add a `kind`-quoted section to docs/methods.md"))

    # Stale contract entries (kind removed from KINDS but not from the
    # table) — keep the table honest in both directions.
    for kind in KIND_CONTRACTS:
        if kind not in kinds:
            findings.append(Finding(
                file=SIMULATOR, line=0, rule=RULE, severity="warning",
                message=f"contract table lists unregistered kind "
                        f"{kind!r}",
                hint="remove its KIND_CONTRACTS entry"))

    # Scalar plumbing stays in sync: every step key except the kvals
    # vector must have a params-row slot, and _lane_dict must rebuild
    # exactly the STEP_KEYS dict.
    if step_keys is not None and param_keys is not None:
        for key in step_keys:
            if key != "kvals" and key not in param_keys:
                findings.append(Finding(
                    file=TLB_SWEEP, line=0, rule=RULE, severity="error",
                    message=f"STEP_KEYS entry {key!r} missing from "
                            f"PARAM_KEYS",
                    hint="the Pallas params row must carry every lane "
                         "scalar"))
    if step_keys is not None and lane_dict_fn is not None:
        missing = set(step_keys) - lane_dict_keys
        extra = lane_dict_keys - set(step_keys)
        for key in sorted(missing | extra):
            if key in missing:
                msg = f"_lane_dict omits STEP_KEYS entry {key!r}"
            else:
                msg = f"_lane_dict key {key!r} is not in STEP_KEYS"
            findings.append(Finding(
                file=TLB_SWEEP, line=0, rule=RULE, severity="error",
                message=msg,
                hint="_lane_dict must rebuild exactly the STEP_KEYS "
                     "lane dict"))
    if step_keys is not None and pack_fn is not None:
        for key in step_keys:
            if key not in lanes_keys:
                findings.append(Finding(
                    file=LANE_PROGRAM, line=0, rule=RULE, severity="error",
                    message=f"STEP_KEYS entry {key!r} is never packed by "
                            f"pack_lanes",
                    hint="add it to the lanes dict"))
    return findings
