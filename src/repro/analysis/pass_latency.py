"""Pass: latency model constants are single-sourced from ``LAT_*``.

The cycle model lives in the ``LAT_*`` constants of
``src/repro/core/simulator.py``; both backends import them.  An integer
literal in executor code that happens to equal one of those values is a
magic-number duplicate waiting to go stale when the model is retuned —
this pass flags it.

Only *distinctive* latency values are matched: ``LAT_*`` values below
``MIN_DISTINCTIVE`` (the 7/8-cycle probe costs) collide with way counts,
bit masks and geometry constants everywhere, so flagging them would be
pure noise.  The definition site itself (``LAT_X = <n>`` in
simulator.py) is exempt, as are docstrings (string constants never
match).
"""
from __future__ import annotations

import ast
from typing import Dict, List

from .framework import Finding, Repo, missing_file

RULE = "latency-constants"

SIMULATOR = "src/repro/core/simulator.py"
EXECUTOR_FILES = (
    SIMULATOR,
    "src/repro/core/lane_program.py",
    "src/repro/core/sweep.py",
    "src/repro/kernels/tlb_sweep/tlb_sweep.py",
    "src/repro/kernels/tlb_sweep/ops.py",
    "src/repro/kernels/tlb_sweep/ref.py",
)
MIN_DISTINCTIVE = 10


def lat_constants(repo: Repo) -> Dict[int, List[str]]:
    """value -> LAT_* names defined with that value in simulator.py."""
    tree = repo.tree(SIMULATOR)
    out: Dict[int, List[str]] = {}
    if tree is None:
        return out
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id.startswith("LAT_")):
            continue
        try:
            val = ast.literal_eval(node.value)
        except ValueError:
            continue
        if isinstance(val, int):
            out.setdefault(val, []).append(node.targets[0].id)
    return out


def _definition_lines(tree: ast.AST) -> set:
    lines = set()
    for node in ast.walk(tree):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id.startswith("LAT_")):
            lines.update(range(node.lineno, (node.end_lineno or
                                             node.lineno) + 1))
    return lines


def run(repo: Repo) -> List[Finding]:
    values = {v: names for v, names in lat_constants(repo).items()
              if v >= MIN_DISTINCTIVE}
    if not values:
        return [missing_file(SIMULATOR, RULE,
                             "no LAT_* constants found in simulator.py")]
    findings: List[Finding] = []
    for rel in EXECUTOR_FILES:
        tree = repo.tree(rel)
        if tree is None:
            continue
        skip = _definition_lines(tree) if rel == SIMULATOR else set()
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Constant)
                    and isinstance(node.value, int)
                    and not isinstance(node.value, bool)):
                continue
            if node.value not in values or node.lineno in skip:
                continue
            names = " or ".join(values[node.value])
            findings.append(Finding(
                file=rel, line=node.lineno, rule=RULE, severity="error",
                message=f"magic number {node.value} duplicates {names}",
                hint=f"import and use {names} so a retuned cycle model "
                     f"cannot go stale here"))
    return findings
