"""Static contract checker for the four-executor TLB simulation.

AST-based analyses that prove, at lint time, what the fuzzer and goldens
prove dynamically: the pure-python oracle, the step reference, the XLA
scan and the Pallas kernel stay registered, dispatched and cache-keyed
in sync.  Stdlib-only by design — see :mod:`repro.analysis.framework`.

Run via ``scripts/check_contracts.py``; passes are documented in
``docs/analysis.md``.
"""
from . import (pass_cache_key, pass_kind_dispatch, pass_latency,
               pass_plane_layout, pass_purity)
from .framework import (Finding, Repo, Suppression, has_errors,
                        load_suppressions, run_passes)
from .kinds import registered_kinds, spec_factories, undocumented_kinds

ALL_PASSES = (
    pass_kind_dispatch,
    pass_plane_layout,
    pass_latency,
    pass_purity,
    pass_cache_key,
)

PASS_BY_RULE = {p.RULE: p for p in ALL_PASSES}

__all__ = [
    "ALL_PASSES", "PASS_BY_RULE", "Finding", "Repo", "Suppression",
    "has_errors", "load_suppressions", "registered_kinds", "run_passes",
    "spec_factories", "undocumented_kinds",
]
