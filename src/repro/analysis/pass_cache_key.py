"""Pass: the on-disk result cache can never serve stale results.

``cell_key`` must fold every semantic input of a sweep cell; anything it
misses silently serves yesterday's counters after today's change.  The
contract, checked statically against ``sweep.py``:

* **spec**: ``cell_key`` hashes ``repr(cell.spec)``, which covers every
  ``MethodSpec`` dataclass field automatically — so the pass verifies the
  ``repr(...)`` fold is still there and that no field opts out with
  ``repr=False``.  (Adding a spec field therefore never needs a checker
  update; removing the repr fold turns this pass red.)
* **worlds**: each ``isinstance`` branch of ``cell_key`` must read the
  world attributes declared in ``WORLD_KEY_ATTRS`` — the semantic content
  of each mapping type.  ``_WorldPlan``'s fields in ``lane_program.py``
  are diffed against ``WORLDPLAN_FOLDS``: each field must be declared
  either folded (with the attribute evidence above) or derived from
  folded data; a new field fails until classified.
* **execution knobs**: ``run_sweep`` keyword parameters must stay within
  ``EXEC_KNOB_ALLOWLIST`` — knobs proven bit-exactness-neutral (any
  backend/block size may serve any cached cell).  A new parameter fails
  until it is either folded into ``cell_key`` or allowlisted here with
  that proof.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from .framework import Finding, Repo, missing_file

RULE = "cache-key"

SWEEP = "src/repro/core/sweep.py"
SIMULATOR = "src/repro/core/simulator.py"
LANE_PROGRAM = "src/repro/core/lane_program.py"

# Execution-only run_sweep parameters: bit-exactness across their values
# is enforced by tests/test_backends.py, so they are excluded from the
# key by design.
EXEC_KNOB_ALLOWLIST = {"cells", "cache", "cache_dir", "backend",
                       "block_size"}

# Attribute reads each cell_key world branch must make.  Keyed by the
# isinstance() class name of the branch; "" is the final else (static
# mapping) branch.
WORLD_KEY_ATTRS: Dict[str, Set[str]] = {
    "ParityWorld": {"faults", "base"},
    "DynamicMapping": {"boundaries", "epochs", "ppn"},
    "MultiTenantMapping": {"boundaries", "tenant_ids", "asids",
                           "recycled", "tenants", "ppn"},
    "NestedMapping": {"boundaries", "guest_ids", "asids", "recycled",
                      "guests", "host", "epochs", "ppn"},
    "": {"ppn"},
}

# _WorldPlan fields -> how the cache key covers them.  "folded" fields
# are hashed via the world attributes above; "derived" fields are
# computed at plan time purely from folded data, so hashing them again
# would be redundant.
WORLDPLAN_FOLDS: Dict[str, str] = {
    "sources": "folded: per-source ppn digests (epochs/tenants/"
               "guests/host)",
    "bounds": "folded: world boundaries tuples",
    "src_idx": "folded: tenant_ids/guest_ids schedule identity",
    "asids": "folded: asids tuples",
    "switch": "derived: recomputed from tenant_ids/boundaries",
    "recycled": "folded: recycled tuples",
    "dirty": "derived: recomputed from consecutive epoch ppn diffs",
    "parity": "derived: spliced from the ParityWorld faults tuple, which "
              "cell_key folds verbatim",
}


def _function(tree: ast.AST, name: str) -> Optional[ast.FunctionDef]:
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


def _dataclass_fields(tree: ast.AST, cls: str) -> List[ast.AnnAssign]:
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == cls:
            return [n for n in node.body if isinstance(n, ast.AnnAssign)]
    return []


def _branch_attrs(fn: ast.FunctionDef) -> Dict[str, Set[str]]:
    """isinstance-class-name -> attribute names read in that cell_key
    branch (the trailing else keyed "")."""
    out: Dict[str, Set[str]] = {}

    def attrs_in(body) -> Set[str]:
        got: Set[str] = set()
        for stmt in body:
            for node in ast.walk(stmt):
                if isinstance(node, ast.Attribute):
                    got.add(node.attr)
        return got

    def class_of(test: ast.expr) -> Optional[str]:
        if (isinstance(test, ast.Call) and isinstance(test.func, ast.Name)
                and test.func.id == "isinstance" and len(test.args) == 2):
            cls = test.args[1]
            if isinstance(cls, ast.Name):
                return cls.id
            if isinstance(cls, ast.Attribute):
                return cls.attr
        return None

    def walk_chain(stmt: ast.If):
        cls = class_of(stmt.test)
        if cls is not None:
            out[cls] = attrs_in(stmt.body)
        orelse = stmt.orelse
        if len(orelse) == 1 and isinstance(orelse[0], ast.If):
            walk_chain(orelse[0])
        elif orelse:
            out[""] = attrs_in(orelse)

    for stmt in fn.body:
        if isinstance(stmt, ast.If) and class_of(stmt.test) is not None:
            walk_chain(stmt)
    return out


def run(repo: Repo) -> List[Finding]:
    sweep_tree = repo.tree(SWEEP)
    sim_tree = repo.tree(SIMULATOR)
    lane_tree = repo.tree(LANE_PROGRAM)
    findings: List[Finding] = []
    if sweep_tree is None:
        return [missing_file(SWEEP, RULE, "file absent or unparseable")]

    key_fn = _function(sweep_tree, "cell_key")
    if key_fn is None:
        return [missing_file(SWEEP, RULE, "cell_key() not found")]

    # -- spec fold: repr(cell.spec) ------------------------------------
    has_spec_repr = any(
        isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
        and node.func.id == "repr" and node.args
        and isinstance(node.args[0], ast.Attribute)
        and node.args[0].attr == "spec"
        for node in ast.walk(key_fn))
    if not has_spec_repr:
        findings.append(Finding(
            file=SWEEP, line=key_fn.lineno, rule=RULE, severity="error",
            message="cell_key no longer folds repr(cell.spec)",
            hint="the dataclass repr is what makes every MethodSpec "
                 "field cache-relevant automatically"))

    if sim_tree is not None:
        for field in _dataclass_fields(sim_tree, "MethodSpec"):
            val = field.value
            if not isinstance(val, ast.Call):
                continue
            fname = val.func.attr if isinstance(val.func, ast.Attribute) \
                else getattr(val.func, "id", "")
            if fname != "field":
                continue
            for kw in val.keywords:
                if (kw.arg == "repr"
                        and isinstance(kw.value, ast.Constant)
                        and kw.value.value is False):
                    name = getattr(field.target, "id", "?")
                    findings.append(Finding(
                        file=SIMULATOR, line=field.lineno, rule=RULE,
                        severity="error",
                        message=f"MethodSpec.{name} sets repr=False and "
                                f"escapes the cache key",
                        hint="cell_key folds repr(spec); an unrepresented "
                             "field can serve stale results"))
    else:
        findings.append(missing_file(SIMULATOR, RULE,
                                     "file absent or unparseable"))

    # -- world folds per isinstance branch -----------------------------
    branches = _branch_attrs(key_fn)
    for cls, want in WORLD_KEY_ATTRS.items():
        got = branches.get(cls)
        label = cls or "<static else>"
        if got is None:
            findings.append(Finding(
                file=SWEEP, line=key_fn.lineno, rule=RULE,
                severity="error",
                message=f"cell_key has no {label} world branch",
                hint="every mapping type needs an explicit content "
                     "fold"))
            continue
        missing = want - got
        if missing:
            findings.append(Finding(
                file=SWEEP, line=key_fn.lineno, rule=RULE,
                severity="error",
                message=f"cell_key {label} branch no longer reads "
                        f"{sorted(missing)}",
                hint="these world attributes are semantic inputs; "
                     "dropping them from the key serves stale results"))
    for cls in branches:
        if cls not in WORLD_KEY_ATTRS:
            findings.append(Finding(
                file=SWEEP, line=key_fn.lineno, rule=RULE,
                severity="error",
                message=f"cell_key folds unknown world type {cls}",
                hint="declare its required attributes in "
                     "pass_cache_key.WORLD_KEY_ATTRS"))

    # -- _WorldPlan fields all classified ------------------------------
    if lane_tree is not None:
        plan_fields = [getattr(f.target, "id", "?")
                       for f in _dataclass_fields(lane_tree, "_WorldPlan")]
        if not plan_fields:
            findings.append(missing_file(LANE_PROGRAM, RULE,
                                         "_WorldPlan dataclass not found"))
        for name in plan_fields:
            if name not in WORLDPLAN_FOLDS:
                findings.append(Finding(
                    file=LANE_PROGRAM, line=0, rule=RULE,
                    severity="error",
                    message=f"_WorldPlan.{name} is not classified in the "
                            f"cache-key contract",
                    hint="declare it folded (and fold it in cell_key) or "
                         "derived in pass_cache_key.WORLDPLAN_FOLDS"))
        for name in WORLDPLAN_FOLDS:
            if name not in plan_fields:
                findings.append(Finding(
                    file=LANE_PROGRAM, line=0, rule=RULE,
                    severity="warning",
                    message=f"cache-key contract lists unknown "
                            f"_WorldPlan field {name!r}",
                    hint="remove its WORLDPLAN_FOLDS entry"))
    else:
        findings.append(missing_file(LANE_PROGRAM, RULE,
                                     "file absent or unparseable"))

    # -- run_sweep knobs stay allowlisted ------------------------------
    rs = _function(sweep_tree, "run_sweep")
    if rs is None:
        findings.append(missing_file(SWEEP, RULE, "run_sweep() not found"))
    else:
        params = [a.arg for a in rs.args.args + rs.args.kwonlyargs]
        for p in params:
            if p not in EXEC_KNOB_ALLOWLIST:
                findings.append(Finding(
                    file=SWEEP, line=rs.lineno, rule=RULE,
                    severity="error",
                    message=f"run_sweep parameter {p!r} is neither "
                            f"folded into cell_key nor allowlisted",
                    hint="if it can change results, fold it into the "
                         "key; if provably execution-only, add it to "
                         "EXEC_KNOB_ALLOWLIST with that proof"))
    return findings
