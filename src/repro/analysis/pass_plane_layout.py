"""Pass: packed-plane layouts come from the one table, nowhere else.

``src/repro/core/plane_layout.py`` is the single source of truth for the
trailing-axis field tuples of every packed TLB plane and per-vpn record.
This pass

* literal-evals the table and re-checks its own invariant — every plane
  carries ``asid``, and nothing but declared sidecar fields may follow
  it (probes match on ASID; the context-switch pass clears by it);
* flags any integer literal equal to a known plane/record width inside a
  shape tuple passed to an allocation call (``np.zeros``/``np.full``,
  ``pltpu.VMEM``/``SMEM``, ``pl.BlockSpec``, ``jax.ShapeDtypeStruct``,
  the local ``packed`` helper) in the executor sources — widths must be
  spelled ``PLANE_WIDTH[...]`` / ``*_REC_WIDTH`` so a table change
  propagates everywhere;
* checks the derived field-index unpacking in ``lane_program.py``
  (``TAG, ... = range(PLANE_WIDTH["l2"])``) binds exactly one name per
  L2 field;
* checks the arity of every packed row ``jnp.stack([...])`` built in
  ``step_access`` against its plane's width.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from .framework import Finding, Repo, missing_file

RULE = "plane-layout"

LAYOUT = "src/repro/core/plane_layout.py"
LANE_PROGRAM = "src/repro/core/lane_program.py"
USE_SITES = (
    LANE_PROGRAM,
    "src/repro/kernels/tlb_sweep/tlb_sweep.py",
    "src/repro/kernels/tlb_sweep/ops.py",
    "src/repro/kernels/tlb_sweep/ref.py",
)
ALLOC_FUNCS = {"zeros", "full", "ones", "empty", "VMEM", "SMEM",
               "BlockSpec", "ShapeDtypeStruct", "packed"}
# step_access row vectors -> the plane each must match
ROW_VECTORS = {"fill_vec": "l2", "l1_vec": "l1", "l1h_vec": "l1h",
               "rmm_vec": "rmm", "cl_vec": "clus", "ctlb_vec": "ctlb"}


def load_layout(repo: Repo) -> Optional[Dict[str, tuple]]:
    """The ``*_FIELDS`` literals of the layout module, by name."""
    tree = repo.tree(LAYOUT)
    if tree is None:
        return None
    out: Dict[str, tuple] = {}
    for node in getattr(tree, "body", []):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            continue
        name = node.targets[0].id
        if not name.endswith("_FIELDS"):
            continue
        try:
            out[name] = ast.literal_eval(node.value)
        except ValueError:
            continue
    return out


def _callee_name(func: ast.expr) -> Optional[str]:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


def run(repo: Repo) -> List[Finding]:
    layout = load_layout(repo)
    if layout is None or "PLANE_FIELDS" not in layout:
        return [missing_file(LAYOUT, RULE,
                             "layout table absent or not literal-evalable")]
    findings: List[Finding] = []
    planes: Dict[str, tuple] = layout["PLANE_FIELDS"]
    sidecar = set(layout.get("SIDECAR_FIELDS", ("aux",)))

    # -- table invariants ------------------------------------------------
    for plane, fields in planes.items():
        if "asid" not in fields:
            findings.append(Finding(
                file=LAYOUT, line=0, rule=RULE, severity="error",
                message=f"plane {plane!r} has no 'asid' field",
                hint="every plane must be ASID-tagged for multi-tenant "
                     "worlds"))
            continue
        trailing = fields[fields.index("asid") + 1:]
        bad = [f for f in trailing if f not in sidecar]
        if bad:
            findings.append(Finding(
                file=LAYOUT, line=0, rule=RULE, severity="error",
                message=f"plane {plane!r}: non-sidecar fields {bad} "
                        f"follow 'asid'",
                hint="asid must be the last field except declared "
                     "SIDECAR_FIELDS"))

    widths: Set[int] = {len(f) for f in planes.values()}
    for rec in ("MAP_REC_FIELDS", "FILL_REC_FIELDS", "MISC_FIELDS"):
        if rec in layout:
            widths.add(len(layout[rec]))

    # -- no hardcoded widths at allocation sites -------------------------
    for rel in USE_SITES:
        tree = repo.tree(rel)
        if tree is None:
            continue
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            if _callee_name(node.func) not in ALLOC_FUNCS:
                continue
            for arg in node.args:
                if not isinstance(arg, (ast.Tuple, ast.List)):
                    continue
                if len(arg.elts) < 2 and _callee_name(node.func) not in \
                        ("VMEM", "SMEM"):
                    continue
                for e in arg.elts:
                    if (isinstance(e, ast.Constant)
                            and isinstance(e.value, int)
                            and not isinstance(e.value, bool)
                            and e.value in widths
                            and e is arg.elts[-1]):
                        findings.append(Finding(
                            file=rel, line=e.lineno, rule=RULE,
                            severity="error",
                            message=f"hardcoded plane/record width "
                                    f"{e.value} in "
                                    f"{_callee_name(node.func)}() shape",
                            hint="spell it PLANE_WIDTH[...] / "
                                 "*_REC_WIDTH from "
                                 "repro.core.plane_layout"))

    # -- derived index unpacking matches the table -----------------------
    lane_tree = repo.tree(LANE_PROGRAM)
    if lane_tree is not None:
        l2_fields = planes.get("l2", ())
        unpack = None
        for node in ast.walk(lane_tree):
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Tuple)
                    and any(isinstance(t, ast.Name) and t.id == "TAG"
                            for t in node.targets[0].elts)):
                unpack = node
                break
        if unpack is None:
            findings.append(Finding(
                file=LANE_PROGRAM, line=0, rule=RULE, severity="error",
                message="L2 field-index unpacking (TAG, ... = ...) not "
                        "found",
                hint="derive the indices from "
                     "range(PLANE_WIDTH['l2'])"))
        elif len(unpack.targets[0].elts) != len(l2_fields):
            findings.append(Finding(
                file=LANE_PROGRAM, line=unpack.lineno, rule=RULE,
                severity="error",
                message=f"L2 index unpacking binds "
                        f"{len(unpack.targets[0].elts)} names but the "
                        f"table declares {len(l2_fields)} fields",
                hint="one name per PLANE_FIELDS['l2'] entry"))

        # -- packed-row stack arity ------------------------------------
        step_fn = None
        for node in ast.walk(lane_tree):
            if (isinstance(node, ast.FunctionDef)
                    and node.name == "step_access"):
                step_fn = node
                break
        seen_vecs: Set[str] = set()
        if step_fn is not None:
            for node in ast.walk(step_fn):
                if not (isinstance(node, ast.Assign)
                        and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)
                        and node.targets[0].id in ROW_VECTORS):
                    continue
                name = node.targets[0].id
                seen_vecs.add(name)
                call = node.value
                if not (isinstance(call, ast.Call)
                        and _callee_name(call.func) == "stack"
                        and call.args
                        and isinstance(call.args[0],
                                       (ast.List, ast.Tuple))):
                    continue
                plane = ROW_VECTORS[name]
                want = len(planes.get(plane, ()))
                got = len(call.args[0].elts)
                if got != want:
                    findings.append(Finding(
                        file=LANE_PROGRAM, line=node.lineno, rule=RULE,
                        severity="error",
                        message=f"{name} stacks {got} fields but plane "
                                f"{plane!r} is {want} wide",
                        hint="keep the packed row in lockstep with "
                             "PLANE_FIELDS"))
            for name in sorted(set(ROW_VECTORS) - seen_vecs):
                findings.append(Finding(
                    file=LANE_PROGRAM, line=0, rule=RULE,
                    severity="error",
                    message=f"step_access no longer builds {name}",
                    hint="renamed row vectors must be re-declared in "
                         "pass_plane_layout.ROW_VECTORS"))
    return findings
