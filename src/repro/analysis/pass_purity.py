"""Pass: traced code never concretizes tracers or calls host services.

A lightweight name-level taint lint over the executor sources.  *Traced
scopes* are functions that run under a jax trace: jitted entry points,
``lax.scan``/``vmap``/``pmap``/``lax.cond`` bodies, Pallas kernels and
their ``@pl.when``-gated regions, plus every function nested inside one.
They are found two ways — autodetection (``jax.jit``/``functools.partial
(jax.jit, ...)`` decorators and call sites, names passed to tracing
APIs) and the explicit ``TRACED_ENTRIES`` table for functions whose
tracing call site lives in *another* module (``step_access`` is vmapped
from ``sweep.py``; the Pallas kernel is partial-wrapped before
``pallas_call`` sees it).

Within a traced scope, positional parameters are tracer-tainted (minus
``static_argnums``/``static_argnames``; keyword-only parameters are
static by convention in this repo) and taint propagates through
assignments — except through the shape sanitizers (``.shape``,
``.ndim``, ``.dtype``, ``len()``), which yield trace-time constants.
Flagged on tainted values:

* python control flow: ``if``/``while``/``assert``/conditional
  expressions and ``and``/``or`` (these call ``__bool__`` and raise
  ``TracerBoolConversionError`` at trace time — or worse, silently
  specialize), and ``for`` directly over a traced array (a ``for`` over
  a *call* result is presumed the probe-chain idiom: a static-length
  python list of tracers, which unrolls legally);
* host concretization: ``float()``/``int()``/``bool()``, ``.item()``,
  ``.tolist()``;
* host numpy on tracers: ``np.*`` calls with a tainted argument
  (host-precomputing with numpy on *static* data is idiomatic and stays
  legal);
* and, taint-independent, any ``np.random``/``random``/``time`` call
  inside a traced scope (host RNG/clocks burn into the trace).
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .framework import Finding, Repo

RULE = "traced-purity"

EXECUTOR_FILES = (
    "src/repro/core/simulator.py",
    "src/repro/core/lane_program.py",
    "src/repro/core/sweep.py",
    "src/repro/kernels/tlb_sweep/tlb_sweep.py",
    "src/repro/kernels/tlb_sweep/ops.py",
    "src/repro/kernels/tlb_sweep/ref.py",
)

# Functions traced from another module (file -> function names).
TRACED_ENTRIES: Dict[str, Tuple[str, ...]] = {
    "src/repro/core/lane_program.py": ("step_access", "shoot_lane",
                                       "switch_lane"),
    "src/repro/kernels/tlb_sweep/tlb_sweep.py": ("_tlb_sweep_kernel",),
}

TRACING_CALLEES = {"jit", "vmap", "pmap", "scan", "cond", "while_loop",
                   "fori_loop", "pallas_call", "checkpoint", "remat",
                   "grad", "value_and_grad", "switch"}
SANITIZER_ATTRS = {"shape", "ndim", "dtype", "size", "nbytes"}
CONCRETIZE_CALLS = {"float", "int", "bool", "complex"}
CONCRETIZE_METHODS = {"item", "tolist", "__bool__", "__float__"}
HOST_SERVICE_ROOTS = ("np.random", "numpy.random", "random", "time")
NUMPY_ALIASES = {"np", "numpy"}


def _dotted(node: ast.expr) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def _static_positions(call: ast.Call) -> Tuple[Set[int], Set[str]]:
    """static_argnums / static_argnames literals of a jit-like call."""
    nums: Set[int] = set()
    names: Set[str] = set()
    for kw in call.keywords:
        if kw.arg in ("static_argnums", "static_broadcasted_argnums"):
            try:
                val = ast.literal_eval(kw.value)
            except ValueError:
                continue
            nums.update([val] if isinstance(val, int) else val)
        elif kw.arg == "static_argnames":
            try:
                val = ast.literal_eval(kw.value)
            except ValueError:
                continue
            names.update([val] if isinstance(val, str) else val)
    return nums, names


@dataclasses.dataclass
class _Entry:
    fn: ast.FunctionDef
    static_nums: Set[int]
    static_names: Set[str]


def _is_tracing_callee(func: ast.expr) -> bool:
    name = _dotted(func)
    if name is None:
        return False
    return name.split(".")[-1] in TRACING_CALLEES


def _collect_entries(tree: ast.AST, rel: str) -> List[_Entry]:
    funcs: Dict[str, ast.FunctionDef] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            funcs.setdefault(node.name, node)

    entries: Dict[str, _Entry] = {}

    def add(name: str, nums: Set[int], names: Set[str]):
        if name in funcs and name not in entries:
            entries[name] = _Entry(funcs[name], nums, names)

    for name in TRACED_ENTRIES.get(rel, ()):
        add(name, set(), set())

    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            for dec in node.decorator_list:
                if isinstance(dec, ast.Call):
                    inner = dec.args[0] if dec.args else None
                    if (_is_tracing_callee(dec.func)
                            or (inner is not None
                                and _is_tracing_callee(inner))):
                        nums, names = _static_positions(dec)
                        add(node.name, nums, names)
                elif (_dotted(dec) or "").split(".")[-1] in TRACING_CALLEES:
                    add(node.name, set(), set())
        if isinstance(node, ast.Call) and _is_tracing_callee(node.func):
            nums, names = _static_positions(node)
            for arg in ast.walk(node):
                if (isinstance(arg, ast.Name) and arg.id in funcs
                        and arg.id not in TRACING_CALLEES):
                    add(arg.id, nums, names)
    return list(entries.values())


class _Scope:
    """One traced function analyzed with a tainted-name set."""

    def __init__(self, rel: str, fn: ast.FunctionDef, tainted: Set[str],
                 findings: List[Finding]):
        self.rel = rel
        self.fn = fn
        self.tainted = set(tainted)
        self.findings = findings
        self.nested: List[ast.FunctionDef] = []

    # -- expression taint ------------------------------------------------
    def taint(self, node: Optional[ast.expr]) -> bool:
        if node is None or isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in SANITIZER_ATTRS:
                return False
            return self.taint(node.value)
        if isinstance(node, ast.Subscript):
            return self.taint(node.value) or self.taint(node.slice)
        if isinstance(node, ast.Call):
            name = _dotted(node.func) or ""
            if name == "len":
                return False
            args = list(node.args) + [kw.value for kw in node.keywords]
            if isinstance(node.func, ast.Attribute):
                args.append(node.func.value)
            return any(self.taint(a) for a in args)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return any(self.taint(e) for e in node.elts)
        if isinstance(node, ast.Dict):
            return any(self.taint(v) for v in node.values)
        if isinstance(node, ast.BinOp):
            return self.taint(node.left) or self.taint(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.taint(node.operand)
        if isinstance(node, ast.Compare):
            return self.taint(node.left) or any(self.taint(c)
                                                for c in node.comparators)
        if isinstance(node, ast.BoolOp):
            return any(self.taint(v) for v in node.values)
        if isinstance(node, ast.IfExp):
            return (self.taint(node.test) or self.taint(node.body)
                    or self.taint(node.orelse))
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
            return any(self.taint(g.iter) for g in node.generators) or \
                self.taint(node.elt)
        if isinstance(node, ast.Starred):
            return self.taint(node.value)
        return False

    def flag(self, node: ast.AST, message: str, hint: str):
        self.findings.append(Finding(
            file=self.rel, line=getattr(node, "lineno", 0), rule=RULE,
            severity="error", message=message, hint=hint))

    # -- violation scan over one expression ------------------------------
    def check_expr(self, node: Optional[ast.expr]):
        if node is None:
            return
        for sub in ast.walk(node):
            if isinstance(sub, ast.Lambda):
                continue
            if isinstance(sub, ast.IfExp) and self.taint(sub.test):
                self.flag(sub, "conditional expression on traced value",
                          "use jnp.where/lax.select")
            if isinstance(sub, ast.BoolOp) and any(self.taint(v)
                                                   for v in sub.values):
                self.flag(sub, "python and/or on traced value",
                          "use & / | on arrays")
            if not isinstance(sub, ast.Call):
                continue
            name = _dotted(sub.func) or ""
            root = name.split(".")[0]
            if any(name == r or name.startswith(r + ".")
                   for r in HOST_SERVICE_ROOTS):
                self.flag(sub, f"host service call {name}() in traced "
                               f"code",
                          "precompute outside the trace or use jax.random")
                continue
            args = list(sub.args) + [kw.value for kw in sub.keywords]
            any_tainted = any(self.taint(a) for a in args)
            if name in CONCRETIZE_CALLS and any_tainted:
                self.flag(sub, f"{name}() concretizes a traced value",
                          "keep it an array; cast with jnp astype")
            elif (isinstance(sub.func, ast.Attribute)
                    and sub.func.attr in CONCRETIZE_METHODS
                    and self.taint(sub.func.value)):
                self.flag(sub, f".{sub.func.attr}() concretizes a traced "
                               f"value",
                          "keep it an array")
            elif (root in NUMPY_ALIASES and len(name.split(".")) > 1
                    and any_tainted):
                self.flag(sub, f"host numpy call {name}() on traced "
                               f"value",
                          "use jnp.* inside traced code")

    # -- statement walk with taint propagation ---------------------------
    def assign_target(self, target: ast.expr, tainted: bool):
        if isinstance(target, ast.Name):
            if tainted:
                self.tainted.add(target.id)
            else:
                self.tainted.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for t in target.elts:
                self.assign_target(t, tainted)
        elif isinstance(target, ast.Starred):
            self.assign_target(target.value, tainted)
        # subscript/attribute writes mutate an existing binding: keep it

    def loop_targets(self, stmt: ast.For):
        it = stmt.iter
        if isinstance(it, ast.Call):
            callee = _dotted(it.func) or ""
            if callee == "range":
                self.assign_target(stmt.target, False)
                return
            if callee == "enumerate" and isinstance(stmt.target,
                                                    (ast.Tuple, ast.List)):
                inner = any(self.taint(a) for a in it.args)
                elts = stmt.target.elts
                self.assign_target(elts[0], False)
                for t in elts[1:]:
                    self.assign_target(t, inner)
                return
        self.assign_target(stmt.target, self.taint(it))

    def walk_block(self, body: Sequence[ast.stmt]):
        for stmt in body:
            if isinstance(stmt, ast.FunctionDef):
                self.nested.append(stmt)
                continue
            if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                value = stmt.value
                self.check_expr(value)
                tainted = self.taint(value)
                targets = (stmt.targets if isinstance(stmt, ast.Assign)
                           else [stmt.target])
                if isinstance(stmt, ast.AugAssign):
                    tainted = tainted or self.taint(stmt.target)
                for t in targets:
                    self.assign_target(t, tainted)
            elif isinstance(stmt, ast.If):
                self.check_expr(stmt.test)
                if self.taint(stmt.test):
                    self.flag(stmt, "python branch on traced value",
                              "use jnp.where/lax.cond; python `if` "
                              "concretizes the tracer")
                self.walk_block(stmt.body)
                self.walk_block(stmt.orelse)
            elif isinstance(stmt, ast.While):
                self.check_expr(stmt.test)
                if self.taint(stmt.test):
                    self.flag(stmt, "python while on traced value",
                              "use lax.while_loop")
                self.walk_block(stmt.body)
                self.walk_block(stmt.orelse)
            elif isinstance(stmt, ast.For):
                self.check_expr(stmt.iter)
                # a Call iter (probe_order(...), zip/enumerate of one) is
                # presumed to build a static-length python sequence of
                # tracers — the repo's probe-chain unroll idiom; direct
                # iteration over a traced array is the bug
                if self.taint(stmt.iter) and not isinstance(stmt.iter,
                                                            ast.Call):
                    self.flag(stmt, "python for over traced array",
                              "use lax.scan/fori_loop, or unroll over a "
                              "static python list")
                self.loop_targets(stmt)
                self.walk_block(stmt.body)
                self.walk_block(stmt.orelse)
            elif isinstance(stmt, ast.Assert):
                self.check_expr(stmt.test)
                if self.taint(stmt.test):
                    self.flag(stmt, "assert on traced value",
                              "use checkify or drop the assert")
            elif isinstance(stmt, ast.Return):
                self.check_expr(stmt.value)
            elif isinstance(stmt, ast.Expr):
                self.check_expr(stmt.value)
            elif isinstance(stmt, (ast.With,)):
                for item in stmt.items:
                    self.check_expr(item.context_expr)
                self.walk_block(stmt.body)
            elif isinstance(stmt, (ast.Try,)):
                self.walk_block(stmt.body)
                for h in stmt.handlers:
                    self.walk_block(h.body)
                self.walk_block(stmt.orelse)
                self.walk_block(stmt.finalbody)

    def run(self):
        # two sweeps so loop-carried taint stabilizes; findings only kept
        # from the second
        snapshot = set(self.tainted)
        sink: List[Finding] = []
        real, self.findings = self.findings, sink
        self.nested = []
        self.walk_block(self.fn.body)
        self.findings = real
        carried = set(self.tainted)
        self.tainted = snapshot | carried
        self.nested = []
        self.walk_block(self.fn.body)
        return self.nested


def _seed_params(fn: ast.FunctionDef, static_nums: Set[int],
                 static_names: Set[str]) -> Set[str]:
    tainted: Set[str] = set()
    for i, arg in enumerate(fn.args.args):
        if i in static_nums or arg.arg in static_names:
            continue
        tainted.add(arg.arg)
    # keyword-only params are static config by repo convention (tb,
    # with_switch, interpret, n_blocks)
    return tainted


def _analyze(rel: str, fn: ast.FunctionDef, closure: Set[str],
             static_nums: Set[int], static_names: Set[str],
             findings: List[Finding]):
    tainted = closure | _seed_params(fn, static_nums, static_names)
    scope = _Scope(rel, fn, tainted, findings)
    nested = scope.run()
    for sub in nested:
        _analyze(rel, sub, scope.tainted, set(), set(), findings)


def run(repo: Repo) -> List[Finding]:
    findings: List[Finding] = []
    for rel in EXECUTOR_FILES:
        tree = repo.tree(rel)
        if tree is None:
            continue
        for entry in _collect_entries(tree, rel):
            _analyze(rel, entry.fn, set(), entry.static_nums,
                     entry.static_names, findings)
    # dedup: nested defs reachable from two entries report once
    seen: Set[Tuple] = set()
    out: List[Finding] = []
    for f in findings:
        key = (f.file, f.line, f.message)
        if key not in seen:
            seen.add(key)
            out.append(f)
    return out
