"""Contract-checker framework: findings, suppressions, the pass manager.

The analysis package is **stdlib-only** — every pass works on source text
and :mod:`ast` trees, never by importing the executors — so the CI
``contracts`` job (and ``scripts/check_docs_links.py``) can run it on a
bare Python with no jax installed.  Keep it that way: a pass that needs a
fact about the executors parses it out of their source.

A *pass* is a module with a ``RULE`` string and a ``run(repo) ->
list[Finding]`` function; the registry lives in
:mod:`repro.analysis.__init__`.  Passes read files through :class:`Repo`,
which caches text and parsed trees and — crucially for the fixture tests —
can be pointed at any directory shaped like this repository, not just the
live checkout.

Suppressions: accepted exceptions live in ``.contracts-suppressions`` at
the repo root, one per line::

    rule | path-glob | message-substring | rationale

A finding is suppressed when its rule matches exactly, its file matches
the glob (:mod:`fnmatch` against the repo-relative posix path), and the
substring occurs in its message.  Suppressions that match nothing are
themselves reported as warnings, so the file cannot accumulate dead
entries.  Lines starting with ``#`` and blank lines are ignored.
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import os
from typing import Dict, List, Optional, Sequence, Tuple

SUPPRESSION_FILE = ".contracts-suppressions"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation, anchored to a source location."""

    file: str           # repo-relative posix path
    line: int           # 1-based; 0 when the finding is file-level
    rule: str           # the reporting pass's RULE id
    severity: str       # "error" fails the build; "warning" does not
    message: str        # what is wrong
    hint: str = ""      # how to fix it

    def render(self) -> str:
        loc = f"{self.file}:{self.line}" if self.line else self.file
        text = f"{loc}: [{self.rule}] {self.severity}: {self.message}"
        if self.hint:
            text += f"  ({self.hint})"
        return text


@dataclasses.dataclass(frozen=True)
class Suppression:
    rule: str
    path_glob: str
    substring: str
    rationale: str
    line: int           # line in the suppression file, for diagnostics

    def matches(self, f: Finding) -> bool:
        return (self.rule == f.rule
                and fnmatch.fnmatch(f.file, self.path_glob)
                and self.substring in f.message)


class Repo:
    """Read-only view of a repository tree with text/AST caches.

    ``root`` may be the live checkout or a fixture directory; passes must
    resolve every file through it so the seeded-violation tests can run
    them against synthetic trees.
    """

    def __init__(self, root: str):
        self.root = os.path.abspath(root)
        self._text: Dict[str, Optional[str]] = {}
        self._tree: Dict[str, Optional[ast.AST]] = {}

    def path(self, rel: str) -> str:
        return os.path.join(self.root, *rel.split("/"))

    def exists(self, rel: str) -> bool:
        return os.path.exists(self.path(rel))

    def text(self, rel: str) -> Optional[str]:
        """File contents, or None when the file is absent."""
        if rel not in self._text:
            try:
                with open(self.path(rel), encoding="utf-8") as f:
                    self._text[rel] = f.read()
            except OSError:
                self._text[rel] = None
        return self._text[rel]

    def tree(self, rel: str) -> Optional[ast.AST]:
        """Parsed AST, or None when the file is absent/unparseable."""
        if rel not in self._tree:
            src = self.text(rel)
            try:
                self._tree[rel] = None if src is None else ast.parse(src)
            except SyntaxError:
                self._tree[rel] = None
        return self._tree[rel]

    def listdir(self, rel: str) -> List[str]:
        try:
            return sorted(os.listdir(self.path(rel)))
        except OSError:
            return []


def missing_file(rel: str, rule: str, why: str) -> Finding:
    return Finding(file=rel, line=0, rule=rule, severity="error",
                   message=f"cannot analyze: {why}",
                   hint="the contract checker expects this file to exist "
                        "and parse")


def load_suppressions(repo: Repo,
                      rel: str = SUPPRESSION_FILE) -> List[Suppression]:
    src = repo.text(rel)
    if src is None:
        return []
    out: List[Suppression] = []
    for i, raw in enumerate(src.splitlines(), start=1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        parts = [p.strip() for p in line.split("|")]
        if len(parts) != 4:
            # malformed lines surface as findings via run_passes below
            out.append(Suppression(rule="<malformed>", path_glob="",
                                   substring=raw, rationale="", line=i))
            continue
        out.append(Suppression(rule=parts[0], path_glob=parts[1],
                               substring=parts[2], rationale=parts[3],
                               line=i))
    return out


def run_passes(repo: Repo, passes: Sequence,
               ) -> Tuple[List[Finding], List[Finding]]:
    """Run ``passes`` and apply suppressions.

    Returns ``(active, suppressed)``.  ``active`` includes warnings for
    malformed or unused suppression entries; callers fail on any active
    finding with severity ``error``.
    """
    findings: List[Finding] = []
    for mod in passes:
        findings.extend(mod.run(repo))

    sups = load_suppressions(repo)
    active: List[Finding] = []
    suppressed: List[Finding] = []
    used = [False] * len(sups)
    for f in findings:
        hit = None
        for i, s in enumerate(sups):
            if s.rule != "<malformed>" and s.matches(f):
                hit = i
                break
        if hit is None:
            active.append(f)
        else:
            used[hit] = True
            suppressed.append(f)
    for s, u in zip(sups, used):
        if s.rule == "<malformed>":
            active.append(Finding(
                file=SUPPRESSION_FILE, line=s.line, rule="suppressions",
                severity="error",
                message=f"malformed suppression line: {s.substring!r}",
                hint="expected 'rule | path-glob | substring | rationale'"))
        elif not u:
            active.append(Finding(
                file=SUPPRESSION_FILE, line=s.line, rule="suppressions",
                severity="warning",
                message=f"suppression matches no finding: "
                        f"{s.rule} | {s.path_glob} | {s.substring}",
                hint="delete stale entries so accepted exceptions stay "
                     "auditable"))
    return active, suppressed


def has_errors(findings: Sequence[Finding]) -> bool:
    return any(f.severity == "error" for f in findings)
