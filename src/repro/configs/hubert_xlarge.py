"""hubert-xlarge [arXiv:2106.07447; encoder-only audio, w2v2 arch].

48L d_model=1280 16H (MHA kv=16) d_ff=5120 vocab=504 (cluster targets),
head_dim=80.  The conv waveform frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings (B, S, d_model).
"""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="encoder",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab=504, causal=False, rope_theta=10_000.0,
)

REDUCED = dataclasses.replace(
    CONFIG, name="hubert-xlarge-reduced", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=4, head_dim=32, d_ff=256, vocab=64)
