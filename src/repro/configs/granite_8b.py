"""granite-8b [arXiv:2405.04324; llama-arch dense GQA, code model].

36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152, head_dim=128.
"""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=49152, rope_theta=10_000.0,
)

REDUCED = dataclasses.replace(
    CONFIG, name="granite-8b-reduced", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, head_dim=32, d_ff=256, vocab=512)
