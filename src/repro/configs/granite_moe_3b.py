"""granite-moe-3b-a800m [hf:ibm-granite family; 40 experts top-8].

32L d_model=1536 24H (GQA kv=8) d_ff=512 (per expert) vocab=49155,
head_dim=64.
"""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab=49155,
    n_experts=40, top_k=8,
)

REDUCED = dataclasses.replace(
    CONFIG, name="granite-moe-3b-a800m-reduced", n_layers=2, d_model=96,
    n_heads=4, n_kv_heads=2, head_dim=24, d_ff=64, vocab=512,
    n_experts=8, top_k=4)
