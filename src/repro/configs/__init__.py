from .registry import (ARCH_IDS, SHAPES, ShapeSpec, all_cells, cell_status,
                       get_config)
