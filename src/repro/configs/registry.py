"""Architecture registry: the 10 assigned archs + reduced smoke variants.

Each ``<arch>.py`` in this package defines ``CONFIG`` (exact published
config) and ``REDUCED`` (same family, tiny dims — used by CPU smoke tests).
``--arch <id>`` on every launcher resolves through :func:`get_config`.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

from ..models.config import ModelConfig

ARCH_IDS: Tuple[str, ...] = (
    "qwen3-32b",
    "internlm2-1.8b",
    "mistral-nemo-12b",
    "granite-8b",
    "xlstm-350m",
    "hubert-xlarge",
    "llava-next-34b",
    "jamba-1.5-large-398b",
    "qwen2-moe-a2.7b",
    "granite-moe-3b-a800m",
)

_MODULES = {
    "qwen3-32b": "qwen3_32b",
    "internlm2-1.8b": "internlm2_1_8b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "granite-8b": "granite_8b",
    "xlstm-350m": "xlstm_350m",
    "hubert-xlarge": "hubert_xlarge",
    "llava-next-34b": "llava_next_34b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "granite-moe-3b-a800m": "granite_moe_3b",
}


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.REDUCED if reduced else mod.CONFIG


# ---------------------------------------------------------------------------
# input shapes (assigned shape set for the LM family)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str           # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# archs with sub-quadratic paths that run long_500k
SUBQUADRATIC = {"xlstm-350m", "jamba-1.5-large-398b"}
ENCODER_ONLY = {"hubert-xlarge"}


def cell_status(arch: str, shape: str) -> Optional[str]:
    """None = runnable; otherwise the documented skip reason (DESIGN.md §4)."""
    s = SHAPES[shape]
    if arch in ENCODER_ONLY and s.kind == "decode":
        return "encoder-only: no decode step"
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return "pure full attention: quadratic at 500k (see DESIGN.md §4)"
    return None


def all_cells() -> List[Tuple[str, str, Optional[str]]]:
    return [(a, s, cell_status(a, s)) for a in ARCH_IDS for s in SHAPES]
