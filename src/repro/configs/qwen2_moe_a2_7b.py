"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B; 60 routed top-4 + 4 shared].

24L d_model=2048 16H (kv=16) d_ff=1408 (per expert) vocab=151936,
head_dim=128.  Shared-expert intermediate = 4x1408 = 5632, sigmoid-gated.
"""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab=151936,
    n_experts=60, top_k=4, n_shared_experts=4,
)

REDUCED = dataclasses.replace(
    CONFIG, name="qwen2-moe-a2.7b-reduced", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=4, head_dim=32, d_ff=64, vocab=512,
    n_experts=8, top_k=4, n_shared_experts=2)
