"""jamba-1.5-large-398b [arXiv:2403.19887; hybrid Mamba+attn 1:7, MoE 16e].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, head_dim=128.
Attention on 1 of every 8 layers (offset 4); MoE (16 experts, top-2) on every
other layer.  Mamba: d_state=16, d_conv=4, expand=2.
"""
import dataclasses

from ..models.config import MambaConfig, ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=65536,
    n_experts=16, top_k=2, moe_every=2, moe_offset=1,
    attn_every=8, attn_offset=4,
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
)

REDUCED = dataclasses.replace(
    CONFIG, name="jamba-1.5-large-398b-reduced", n_layers=8, d_model=128,
    n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256, vocab=512,
    n_experts=4, top_k=2)
