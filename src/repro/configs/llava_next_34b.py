"""llava-next-34b [hf:llava-hf/llava-v1.6 family; VLM, anyres tiling].

Backbone (Yi-34B-like): 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000, head_dim=128.  The vision tower is a STUB: ``input_specs``
provides precomputed patch embeddings (anyres: base + 4 tiles x 576 = 2880
patches) injected at the sequence prefix.
"""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab=64000, n_patches=2880, rope_theta=5_000_000.0,
)

REDUCED = dataclasses.replace(
    CONFIG, name="llava-next-34b-reduced", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, head_dim=32, d_ff=256, vocab=512, n_patches=16)
