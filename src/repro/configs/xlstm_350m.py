"""xlstm-350m [arXiv:2405.04517; sLSTM + mLSTM blocks].

24L d_model=1024 4H vocab=50304, d_ff=0 (mLSTM blocks carry their own 2x
up-projection).  sLSTM every 6th layer (the paper's [7:1]-style interleave).
"""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="xlstm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, head_dim=256,
    d_ff=0, vocab=50304, slstm_every=6,
)

REDUCED = dataclasses.replace(
    CONFIG, name="xlstm-350m-reduced", n_layers=6, d_model=64, n_heads=2,
    n_kv_heads=2, head_dim=32, vocab=512, slstm_every=6)
