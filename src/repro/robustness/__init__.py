"""Chaos harness: deterministic fault injection + recovery policies.

See ``docs/robustness.md`` for the fault taxonomy, the recovery ladder of
each runtime, and how to add a fault kind."""
from .faults import (BackendFailure, BackendFault, CacheCorruption,
                     EngineCrash, FAULT_KINDS, FaultPlan, KVCorruption,
                     PageLoss, TLBParity, backend_fault_injection,
                     corrupt_cache_entry, corrupt_kv_pages, kind_of,
                     make_parity_world)
from .recovery import RecoveryError, retry_with_backoff, \
    run_engine_with_recovery

__all__ = [
    "BackendFailure", "BackendFault", "CacheCorruption", "EngineCrash",
    "FAULT_KINDS", "FaultPlan", "KVCorruption", "PageLoss", "TLBParity",
    "backend_fault_injection", "corrupt_cache_entry", "corrupt_kv_pages",
    "kind_of", "make_parity_world", "RecoveryError", "retry_with_backoff",
    "run_engine_with_recovery",
]
