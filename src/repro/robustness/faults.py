"""Deterministic, seeded fault injection for the serving + sweep runtimes.

The chaos harness's contract is the inverse of a test suite's: instead of
asserting the system works on good inputs, it *schedules* failures — a
poisoned TLB entry, a corrupted KV page, a dead engine process, a backend
that refuses to compile, a rotted cache file — and asserts the runtimes
either recover to bit/token-exact results or fail loudly.  Everything here
is deterministic: a :class:`FaultPlan` is fully defined by its seed, so
every chaos run (benchmarks, the hypothesis fuzz in
``tests/test_robustness.py``) replays exactly.

Fault taxonomy (one frozen dataclass per kind; ``docs/robustness.md``):

* :class:`TLBParity`    — flip a live TLB entry mid-trace (the paper-grounded
  fault: a coalesced |K|=k entry covers up to 2^k translations, so one soft
  error has a multiplied blast radius; lowers to
  :class:`~repro.core.page_table.ParityWorld`).
* :class:`KVCorruption` — garbage written into live KV-pool pages mid-serve.
* :class:`PageLoss`     — physical pages permanently lost from the KV pool.
* :class:`EngineCrash`  — the engine process dies at step N (recovered by
  :meth:`~repro.serve.engine.ServingEngine.restore`).
* :class:`BackendFailure` — the sweep backend raises at compile/run time
  (recovered by ``run_sweep``'s fallback/bisection ladder).
* :class:`CacheCorruption` — sweep-cache ``.npz`` entries truncated /
  garbage / wrong-schema (quarantined + recomputed by ``run_sweep``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from ..core.page_table import ParityWorld

# --------------------------------------------------------------------------
# Typed fault events
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TLBParity:
    """Parity-flip a live TLB entry: the translation for ``vpn`` held at
    trace position ``step`` is poisoned.  ``spec.par_policy`` decides the
    recovery model (detect-invalidate-rewalk vs idealized ECC)."""
    step: int
    vpn: int


@dataclasses.dataclass(frozen=True)
class KVCorruption:
    """At engine step ``step``, ``n_pages`` live physical KV pages are
    overwritten with garbage (then quarantined-and-recomputed)."""
    step: int
    n_pages: int = 1


@dataclasses.dataclass(frozen=True)
class PageLoss:
    """At engine step ``step``, ``n_pages`` free physical pages vanish from
    the pool (bad DRAM): permanently retired, transparent to live work."""
    step: int
    n_pages: int = 1


@dataclasses.dataclass(frozen=True)
class EngineCrash:
    """The engine process dies right after step ``step``; the harness
    restarts from the latest checkpoint."""
    step: int


@dataclasses.dataclass(frozen=True)
class BackendFailure:
    """The next ``n_batches`` sweep batches raise on ``backends`` (compile
    or runtime failure), exercising the fallback/bisection ladder."""
    n_batches: int = 1
    backends: Tuple[str, ...] = ("pallas",)


@dataclasses.dataclass(frozen=True)
class CacheCorruption:
    """``n_entries`` sweep-cache files are damaged in ``mode``
    (``truncate`` | ``garbage`` | ``schema``)."""
    n_entries: int = 1
    mode: str = "truncate"


FAULT_KINDS = {
    "tlb-parity": TLBParity,
    "kv-corruption": KVCorruption,
    "page-loss": PageLoss,
    "engine-crash": EngineCrash,
    "backend-failure": BackendFailure,
    "cache-corruption": CacheCorruption,
}


def kind_of(event) -> str:
    for k, cls in FAULT_KINDS.items():
        if isinstance(event, cls):
            return k
    raise TypeError(f"unknown fault event {event!r}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded schedule of typed fault events.

    The plan is pure data: injectors below (and the recovery harness in
    :mod:`repro.robustness.recovery`) interpret it.  ``generate`` derives
    every event from ``seed`` alone, so a plan is reproducible from one
    integer."""

    seed: int
    events: Tuple = ()

    def of(self, cls) -> List:
        return [e for e in self.events if isinstance(e, cls)]

    def kinds(self) -> Tuple[str, ...]:
        return tuple(sorted({kind_of(e) for e in self.events}))

    @classmethod
    def generate(cls, seed: int, kinds: Sequence[str] = ("engine-crash",
                                                         "kv-corruption"),
                 max_step: int = 8, n_events: int = 2) -> "FaultPlan":
        """Deterministic plan: ``n_events`` events drawn per requested kind
        at steps in ``[1, max_step]`` (sweep-side kinds are step-free)."""
        rng = np.random.default_rng(seed)
        events: List = []
        for k in kinds:
            if k == "backend-failure":
                events.append(BackendFailure(n_batches=1))
                continue
            if k == "cache-corruption":
                modes = ("truncate", "garbage", "schema")
                events.append(CacheCorruption(
                    n_entries=1, mode=modes[int(rng.integers(3))]))
                continue
            steps = sorted(set(int(s) for s in rng.integers(
                1, max_step + 1, size=n_events)))
            for s in steps:
                if k == "engine-crash":
                    events.append(EngineCrash(step=s))
                elif k == "kv-corruption":
                    events.append(KVCorruption(step=s, n_pages=int(
                        rng.integers(1, 3))))
                elif k == "page-loss":
                    events.append(PageLoss(step=s, n_pages=int(
                        rng.integers(1, 4))))
                elif k == "tlb-parity":
                    # vpn resolved later against a concrete trace
                    events.append(TLBParity(step=s, vpn=-1))
                else:
                    raise ValueError(f"unknown fault kind {k!r}")
        return cls(seed=seed, events=tuple(events))


# --------------------------------------------------------------------------
# Injectors
# --------------------------------------------------------------------------


def make_parity_world(base, trace: np.ndarray, seed: int,
                      n_faults: int = 3) -> Optional[ParityWorld]:
    """Wrap any base world in a :class:`ParityWorld` with a seeded fault
    schedule that is valid by construction: fault steps avoid position 0
    and the base world's own segment boundaries, and each fault poisons
    the translation of ``trace[step]`` — a page guaranteed mapped in the
    segment live at that step.  Returns None when the trace is too short
    to place any fault."""
    probe = ParityWorld(base=base, faults=())
    forbidden = set(probe.base_boundaries()) | {0}
    rng = np.random.default_rng(seed)
    T = int(trace.shape[0])
    steps: List[int] = []
    for s in rng.integers(1, max(T, 2), size=8 * n_faults):
        s = int(s)
        if s < T and s not in forbidden and s not in steps:
            steps.append(s)
        if len(steps) == n_faults:
            break
    if not steps:
        return None
    faults = tuple((s, int(trace[s])) for s in sorted(steps))
    return ParityWorld(base=base, faults=faults)


class BackendFault(RuntimeError):
    """An injected sweep-backend compile/runtime failure."""


@contextlib.contextmanager
def backend_fault_injection(n_failures: int = 1,
                            backends: Tuple[str, ...] = ("pallas",),
                            predicate: Optional[Callable] = None):
    """Install a hook that makes the next ``n_failures`` matching sweep
    batches raise :class:`BackendFault`.

    ``backends`` scopes the failure (default: only the Pallas backend
    fails, so ``run_sweep``'s xla fallback recovers).  ``predicate(cells,
    backend)`` further narrows it — e.g. curse one specific cell so every
    batch containing it fails on EVERY backend, forcing bisection down to
    the oracle.  Yields a stats dict counting injected failures."""
    from ..core import sweep as _sweep

    stats = {"injected": 0}
    remaining = [n_failures]

    def hook(cells, backend):
        if backend not in backends:
            return
        if predicate is not None and not predicate(cells, backend):
            return
        if remaining[0] <= 0:
            return
        remaining[0] -= 1
        stats["injected"] += 1
        raise BackendFault(
            f"injected {backend} failure ({stats['injected']}/{n_failures})")

    prev = _sweep._BACKEND_FAULT_HOOK
    _sweep._BACKEND_FAULT_HOOK = hook
    try:
        yield stats
    finally:
        _sweep._BACKEND_FAULT_HOOK = prev


def corrupt_cache_entry(path: str, mode: str = "truncate") -> None:
    """Damage one sweep-cache ``.npz`` file in place.

    ``truncate`` — cut the file mid-stream (torn write / partial disk);
    ``garbage``  — overwrite with non-zip bytes (bit rot);
    ``schema``   — a VALID npz missing the expected keys (stale layout
    from an older code version)."""
    if mode == "truncate":
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(max(size // 2, 1))
    elif mode == "garbage":
        with open(path, "wb") as f:
            f.write(b"\x00corrupt!" * 16)
    elif mode == "schema":
        tmp = path + ".tmp.npz"          # .npz suffix: savez keeps the name
        np.savez_compressed(tmp, wrong_key=np.zeros(3))
        os.replace(tmp, path)
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")


def corrupt_kv_pages(engine, pages: Sequence[int], value: float = 1e4
                     ) -> None:
    """Overwrite the KV-pool contents of ``pages`` with garbage across
    every attention position — the physical damage a :class:`KVCorruption`
    event models.  Recovery is the engine's ``quarantine_pages``."""
    import jax.numpy as jnp
    idx = jnp.asarray(list(pages), jnp.int32)
    for key, st in engine.state.items():
        if isinstance(st, dict) and "pool_k" in st:
            for pool in ("pool_k", "pool_v"):
                p = st[pool]
                engine.state[key][pool] = p.at[:, idx].set(
                    jnp.asarray(value, p.dtype))
