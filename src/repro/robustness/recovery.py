"""Recovery policies: how the runtimes turn injected faults into finished
work.

Four policies, each owned by the layer that can act on it:

* **retry-with-backoff** (:func:`retry_with_backoff`) — transient faults;
  generic wrapper used by harnesses around flaky effectful calls.
* **backend fallback + batch bisection** — lives in
  :func:`repro.core.sweep._run_batch_resilient`: a failing Pallas batch
  reruns on XLA (bit-exact by construction, so the fallback result is
  identical), a batch failing every backend bisects until the poisoned
  cell runs on the pure-python oracle, and only the oracle raising
  propagates.
* **quarantine-and-recompute** —
  :meth:`repro.serve.engine.ServingEngine.quarantine_pages` preempts the
  owners of corrupted KV pages through the recompute path (generated
  tokens kept → token-exact) and retires the pages;
  ``run_sweep`` quarantines corrupt cache files and recomputes, surfacing
  ``cache_quarantined`` in its stats.
* **checkpoint-resume** — :meth:`ServingEngine.snapshot` / ``restore``;
  :func:`run_engine_with_recovery` below drives a full serve under a
  :class:`~repro.robustness.faults.FaultPlan`, restarting a "crashed"
  engine from its latest checkpoint and proving the output token-exact.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .faults import (EngineCrash, FaultPlan, KVCorruption, PageLoss,
                     corrupt_kv_pages)


class RecoveryError(RuntimeError):
    """A fault the recovery policies could NOT absorb.  Raised instead of
    returning partial results: the chaos contract is recover exactly or
    fail loudly, never diverge silently."""


def retry_with_backoff(fn: Callable, *, retries: int = 3,
                       base_delay: float = 0.0,
                       retry_on: Tuple = (Exception,),
                       sleep: Callable[[float], None] = time.sleep):
    """Call ``fn()`` up to ``retries + 1`` times with exponential backoff
    (``base_delay * 2^attempt``; 0 keeps tests instant).  The last failure
    propagates unchanged."""
    for attempt in range(retries + 1):
        try:
            return fn()
        except retry_on:
            if attempt == retries:
                raise
            if base_delay:
                sleep(base_delay * (2 ** attempt))


def run_engine_with_recovery(make_engine: Callable,
                             requests: Sequence[Tuple[List[int], int]],
                             plan: Optional[FaultPlan],
                             ckpt_dir: str,
                             max_steps: int = 256,
                             snapshot_every: int = 1) -> Tuple[Dict, Dict]:
    """Drive a :class:`ServingEngine` to completion under a fault plan.

    ``make_engine()`` builds a fresh engine from fixed (model, params,
    config) — the "process" that crash events kill.  Per engine step the
    harness fires the plan's events due at that step:

    * :class:`KVCorruption` — garbage live pages with
      :func:`~repro.robustness.faults.corrupt_kv_pages`, then recover via
      ``engine.quarantine_pages`` (owners recompute-preempted, pages
      retired);
    * :class:`PageLoss` — retire free pages directly (owned pages are
      skipped: losing them is the KVCorruption path);
    * :class:`EngineCrash` — discard the engine object and ``restore`` a
      fresh one from the latest snapshot; steps since that snapshot replay
      deterministically.

    Each event fires once.  Returns ``(outputs, report)`` where
    ``outputs[rid]`` is the full generated token list.  Raises
    :class:`RecoveryError` when ``max_steps`` expires with work still
    pending — a stall is a loud failure, never a truncated answer.
    """
    plan = plan or FaultPlan(seed=0)
    rng = np.random.default_rng(plan.seed)
    eng = make_engine()
    for prompt, max_new in requests:
        eng.add_request(list(prompt), max_new_tokens=max_new)

    crash_due = {e.step for e in plan.of(EngineCrash)}
    corrupt_due: Dict[int, List[KVCorruption]] = {}
    for e in plan.of(KVCorruption):
        corrupt_due.setdefault(e.step, []).append(e)
    loss_due: Dict[int, List[PageLoss]] = {}
    for e in plan.of(PageLoss):
        loss_due.setdefault(e.step, []).append(e)

    report = dict(crashes=0, restarts=0, kv_corrupted=0, preempted=0,
                  pages_lost=0, steps=0)
    eng.snapshot(ckpt_dir, step=0)
    for _ in range(max_steps):
        more = eng.step()
        step = int(eng.metrics["steps"])
        for e in corrupt_due.pop(step, []):
            live = sorted({p for a in eng.allocator.seqs.values()
                           for p in a.pages})
            if not live:
                continue
            k = min(e.n_pages, len(live))
            bad = [int(p) for p in rng.choice(live, size=k, replace=False)]
            corrupt_kv_pages(eng, bad)
            owners = eng.quarantine_pages(bad)
            report["kv_corrupted"] += len(bad)
            report["preempted"] += len(owners)
        for e in loss_due.pop(step, []):
            cand = [int(p) for p in rng.integers(0, eng.ec.num_pages,
                                                 size=e.n_pages)]
            report["pages_lost"] += len(eng.allocator.retire_pages(cand))
        if step % snapshot_every == 0:
            eng.snapshot(ckpt_dir, step=step)
        if step in crash_due:
            crash_due.discard(step)
            report["crashes"] += 1
            eng = make_engine()          # the old process is gone
            eng.restore(ckpt_dir)
            report["restarts"] += 1
            continue
        if not more and not eng.sched.has_work:
            break
    report["steps"] = int(eng.metrics["steps"])
    if eng.sched.has_work:
        raise RecoveryError(
            f"engine stalled after {max_steps} harness steps with "
            f"{len(eng.waiting)} waiting / {len(eng.running)} running")
    outputs = {rid: list(req.generated) for rid, req in eng.requests.items()}
    report["metrics"] = dict(eng.metrics)
    return outputs, report
