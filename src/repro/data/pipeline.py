"""Deterministic synthetic data pipeline with prefetch.

Stateless-by-construction: batch contents are a pure function of
(step, shard, seed), so the complete pipeline state in a checkpoint is one
integer — restart-safe on any host count (the property real frameworks get
from tfds/grain checkpointing, here by determinism).

A background thread keeps ``prefetch`` batches ahead; the host→device copy of
batch t overlaps the compute of batch t-1.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax
import numpy as np

from ..models.config import ModelConfig


@dataclasses.dataclass
class PipelineConfig:
    batch: int
    seq: int
    seed: int = 0
    prefetch: int = 2


def _batch_at(cfg: ModelConfig, pc: PipelineConfig, step: int
              ) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(np.uint64(pc.seed * 1_000_003 + step))
    B, S, V = pc.batch, pc.seq, cfg.vocab
    if cfg.family == "encoder":
        return {
            "input_embeds": rng.standard_normal(
                (B, S, cfg.d_model), dtype=np.float32) * 0.02,
            "labels": rng.integers(0, V, (B, S), dtype=np.int32),
            "mask": (rng.random((B, S)) < 0.08).astype(np.float32),
        }
    tokens = rng.integers(0, V, (B, S), dtype=np.int32)
    out = {"tokens": tokens,
           "labels": np.roll(tokens, -1, axis=1).astype(np.int32)}
    if cfg.family == "vlm":
        out["patch_embeds"] = rng.standard_normal(
            (B, cfg.n_patches, cfg.d_model), dtype=np.float32) * 0.02
    return out


class DataPipeline:
    """Iterator over device-ready batches with background prefetch."""

    def __init__(self, cfg: ModelConfig, pc: PipelineConfig,
                 shardings: Optional[Any] = None, start_step: int = 0):
        self.cfg, self.pc = cfg, pc
        self.shardings = shardings
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=max(pc.prefetch, 1))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        step = self.step
        while not self._stop.is_set():
            batch = _batch_at(self.cfg, self.pc, step)
            try:
                self._q.put((step, batch), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[Dict[str, Any]]:
        return self

    def __next__(self) -> Dict[str, Any]:
        while True:
            step, batch = self._q.get()
            if step == self.step:
                break
            # stale batch from before a restore(); drop it
        self.step += 1
        if self.shardings is not None:
            batch = {k: jax.device_put(v, self.shardings[k])
                     for k, v in batch.items()}
        return batch

    # --- checkpointable state -------------------------------------------
    def state(self) -> Dict[str, int]:
        return {"step": self.step}

    def restore(self, state: Dict[str, int]) -> None:
        self.step = int(state["step"])

    def close(self):
        self._stop.set()
