from .pipeline import DataPipeline, PipelineConfig
