"""Async, atomic, reshard-on-restore checkpointing.

Layout (one directory per step):

    <root>/step_000040.tmp-<nonce>/   # written here first
        manifest.json                  # tree-def, shapes, dtypes, extras
        leaf_00000.npy ...             # one file per pytree leaf
    <root>/step_000040/                # atomic rename when complete

* **atomic** — readers never see a partial checkpoint (tmp dir + rename);
  a crash mid-save leaves only a .tmp dir that is garbage-collected.
* **async**  — ``save`` returns immediately; the serialization thread
  device_gets and writes in the background (``wait()`` joins).
* **elastic restore** — leaves are restored with ``jax.device_put`` against
  the *target* mesh's shardings, so a checkpoint written on a 16x16 mesh
  restores onto 2x16x16 (or 4x8, or 1 device) unchanged: this is the
  node-failure / elastic-rescale path.
* keeps the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import uuid
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _leaf_paths(tree: PyTree) -> Tuple[List[Any], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class Checkpointer:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._gc_tmp()

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: PyTree,
             extras: Optional[Dict[str, Any]] = None,
             blocking: bool = False) -> None:
        self.wait()
        # device_get on the caller thread (cheap views for CPU arrays); the
        # file I/O happens on the background thread.
        leaves, treedef = _leaf_paths(tree)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
        import pickle
        manifest = {
            "step": step,
            "treedef": pickle.dumps(treedef).hex(),
            "leaves": [{"shape": list(l.shape), "dtype": str(l.dtype)}
                       for l in host_leaves],
            "extras": extras or {},
        }

        def work():
            tmp = os.path.join(self.root,
                               f"step_{step:08d}.tmp-{uuid.uuid4().hex[:8]}")
            os.makedirs(tmp, exist_ok=True)
            for i, arr in enumerate(host_leaves):
                if arr.dtype.kind not in "biufc":  # ml_dtypes (bf16, fp8, …)
                    arr = arr.view(np.uint8)
                np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr,
                        allow_pickle=False)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            final = os.path.join(self.root, f"step_{step:08d}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and ".tmp" not in name:
                steps.append(int(name.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, step: Optional[int] = None,
                target: Optional[PyTree] = None,
                shardings: Optional[PyTree] = None
                ) -> Tuple[PyTree, Dict[str, Any]]:
        """Load a checkpoint.

        ``target``: a pytree with the same structure (e.g. abstract params)
        used for tree reconstruction; if omitted, the saved treedef is used.
        ``shardings``: optional sharding pytree — leaves are device_put to it
        (reshard-on-restore, works across different meshes/device counts).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = os.path.join(self.root, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        import ml_dtypes  # noqa: F401  (registers bf16 etc. with numpy)
        leaves = []
        for i, meta in enumerate(manifest["leaves"]):
            arr = np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
            try:
                want = np.dtype(meta["dtype"])
            except TypeError:
                want = np.dtype(getattr(ml_dtypes, meta["dtype"]))
            if arr.dtype != want:
                arr = arr.view(want).reshape(meta["shape"])
            leaves.append(arr)
        if target is not None:
            treedef = jax.tree.structure(target)
        else:
            import pickle
            treedef = pickle.loads(bytes.fromhex(manifest["treedef"]))
        tree = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda arr, sh: jax.device_put(arr, sh), tree, shardings)
        return tree, manifest["extras"]

    # ------------------------------------------------------------------- gc
    def _gc(self) -> None:
        steps = sorted(s for s in (
            int(n.split("_")[1]) for n in os.listdir(self.root)
            if n.startswith("step_") and ".tmp" not in n))
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)

    def _gc_tmp(self) -> None:
        for name in os.listdir(self.root):
            if ".tmp-" in name:
                shutil.rmtree(os.path.join(self.root, name),
                              ignore_errors=True)
