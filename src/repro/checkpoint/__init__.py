from .checkpointer import Checkpointer
