"""Method configurations for all compared schemes (paper §4.1, Table 2).

Each returns a :class:`repro.core.simulator.MethodSpec` driving the unified
engine.  TLB geometries follow Table 2:

* common L1: 64-entry 4-way 4KB (+32-entry 4-way 2MB for THP)
* Base/THP/COLT/Anchor/K-Aligned L2: 1024 entries, 8-way (128 sets)
* Cluster: 768-entry 6-way regular + 320-entry 5-way clustered
* RMM: baseline L2 + 32-entry fully-associative range TLB
"""
from __future__ import annotations

from typing import Iterable, List, Sequence, Tuple

import numpy as np

from .determine_k import determine_k
from .page_table import Mapping, contiguity_histogram
from .simulator import SUBR_BITS, MethodSpec, SimResult, run_method

L2_SETS_8WAY = 128  # 1024 entries / 8 ways


def base_spec() -> MethodSpec:
    return MethodSpec(name="Base", kind="base")


def thp_spec() -> MethodSpec:
    return MethodSpec(name="THP", kind="thp")


def colt_spec() -> MethodSpec:
    # coalesced entries indexed by the 8-PTE window (index_shift=3)
    return MethodSpec(name="COLT", kind="colt", index_shift=3)


def cluster_spec() -> MethodSpec:
    # 768-entry 6-way regular TLB + clustered side TLB
    return MethodSpec(name="Cluster", kind="cluster", l2_sets=128, l2_ways=6,
                      side="cluster")


def rmm_spec() -> MethodSpec:
    return MethodSpec(name="RMM", kind="rmm", side="rmm")


def anchor_spec(distance_bits: int) -> MethodSpec:
    """Anchor with anchor distance 2**distance_bits [Park et al., ISCA'17]."""
    return MethodSpec(name=f"Anchor(d=2^{distance_bits})", kind="anchor",
                      K=(distance_bits,), index_shift=distance_bits)


def subregion_spec() -> MethodSpec:
    """Subregion TLB: large-reach entries covering an aligned 16-page
    memory subregion with a per-entry contiguity bitmap (the
    high-throughput-processor lineage, arXiv 2110.08613).  Sets are
    indexed by the subregion base, so one window maps to one set."""
    return MethodSpec(name="Subregion", kind="subregion",
                      index_shift=SUBR_BITS)


def cache_tlb_spec() -> MethodSpec:
    """Cache-backed TLB reach extension (Victima lineage, arXiv
    2310.04158): evicted L2 entries drop into a large cache-resident
    tier probed past an L1+L2 miss at L2-cache latency."""
    return MethodSpec(name="Cache-TLB", kind="cache-tlb")


def dead_protect_spec() -> MethodSpec:
    """Dead-entry protection (GPU TLB lineage, arXiv 2606.00486): a
    saturating-counter predictor bypasses L2 fills for pages never yet
    re-referenced, protecting live entries from dead-on-arrival fills."""
    return MethodSpec(name="Dead-Protect", kind="dead-protect")


def kaligned_spec(K: Sequence[int], use_predictor: bool = True,
                  name: str | None = None) -> MethodSpec:
    Kd = tuple(sorted(set(int(k) for k in K), reverse=True))
    return MethodSpec(
        name=name or f"|K|={len(Kd)} Aligned",
        kind="kaligned", K=Kd, index_shift=max(Kd) if Kd else 0,
        use_predictor=use_predictor)


def kaligned_for_histogram(hist, psi: int, theta: float = 0.9,
                           use_predictor: bool = True) -> MethodSpec:
    """K Aligned with K chosen by Algorithm 3 from a contiguity histogram.

    Use when the histogram is not derived from one mapping — e.g. the
    merged per-tenant histogram of a
    :class:`~repro.core.page_table.MultiTenantMapping`, the closest
    analogue of an OS aggregating per-process contiguity stats."""
    K = determine_k(hist, theta=theta, psi=psi)
    if not K:       # fully fragmented mapping: degenerate to smallest reach
        K = [4]
    return kaligned_spec(K[:psi], use_predictor=use_predictor,
                         name=f"|K|={min(len(K), psi)} Aligned")


def kaligned_for_mapping(m: Mapping, psi: int, theta: float = 0.9,
                         use_predictor: bool = True) -> MethodSpec:
    """K Aligned with K chosen by Algorithm 3 from the mapping's histogram."""
    return kaligned_for_histogram(contiguity_histogram(m), psi=psi,
                                  theta=theta, use_predictor=use_predictor)


ANCHOR_GRID: Tuple[int, ...] = (2, 3, 4, 5, 6, 7, 8, 9, 10, 11)


def anchor_static(m: Mapping, trace: np.ndarray,
                  grid: Iterable[int] = ANCHOR_GRID) -> SimResult:
    """Anchor-Static: exhaustively try all anchor distances, keep the best
    (paper §4.1: 'ends up with the optimal performance')."""
    best: SimResult | None = None
    for d in grid:
        r = run_method(anchor_spec(d), m, trace)
        if best is None or r.walks < best.walks:
            best = r
            best.name = f"Anchor-Static(best d=2^{d})"
    assert best is not None
    return best


def standard_suite(m: Mapping, trace: np.ndarray,
                   psis: Sequence[int] = (2, 3, 4),
                   anchor_grid: Iterable[int] = ANCHOR_GRID
                   ) -> List[SimResult]:
    """The paper's full comparison (Figs 1/8, Table 4): Base, THP, RMM, COLT,
    Cluster, Anchor-Static, |K|=2/3/4 Aligned."""
    out = [run_method(base_spec(), m, trace),
           run_method(thp_spec(), m, trace),
           run_method(rmm_spec(), m, trace),
           run_method(colt_spec(), m, trace),
           run_method(cluster_spec(), m, trace),
           anchor_static(m, trace, grid=anchor_grid)]
    for psi in psis:
        spec = kaligned_for_mapping(m, psi=psi)
        out.append(run_method(spec, m, trace))
    return out
