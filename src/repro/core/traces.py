"""Memory-access trace generators (paper §4: Pin traces of SPEC2006/graph500/gups).

We have no Pin traces offline, so each paper benchmark is represented by a
synthetic *access-pattern analogue* with the locality structure that drives
its TLB behaviour.  Trace entries are virtual page numbers (one entry per
memory access that reaches the TLB).

Patterns:

* ``sequential`` — streaming array sweeps (bwaves/zeusmp/wrf-like)
* ``strided``    — fixed-stride sweeps with several interleaved streams
* ``random``     — uniform random pages (gups: the worst case)
* ``zipf``       — skewed reuse (mcf/omnetpp/xalancbmk-like)
* ``bfs``        — frontier expansion with neighbourhood locality (graph500)
* ``blocked``    — tiled compute: dwell in a block, move on (gromacs/namd)
* ``mixed_phase``— phases alternating among the above (astar/sjeng-like)
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


def _seq(n_pages: int, length: int, rng: np.random.Generator) -> np.ndarray:
    starts = rng.integers(0, n_pages, size=max(1, length // 4096))
    out = (np.arange(length) % 4096)[None, :]
    segs = (starts[:, None] + out) % n_pages
    return segs.reshape(-1)[:length]


def _strided(n_pages: int, length: int, rng: np.random.Generator,
             stride: int = 7, streams: int = 4) -> np.ndarray:
    base = rng.integers(0, n_pages, size=streams)
    idx = np.arange(length)
    s = idx % streams
    step = idx // streams
    return (base[s] + step * stride) % n_pages


def _random(n_pages: int, length: int, rng: np.random.Generator) -> np.ndarray:
    return rng.integers(0, n_pages, size=length)


def _zipf(n_pages: int, length: int, rng: np.random.Generator,
          a: float = 1.2) -> np.ndarray:
    # zipf over a shuffled page id space so hot pages are scattered
    raw = rng.zipf(a, size=length)
    raw = np.minimum(raw - 1, n_pages - 1)
    perm = rng.permutation(n_pages)
    return perm[raw]


def _bfs(n_pages: int, length: int, rng: np.random.Generator,
         hood: int = 64, p_jump: float = 0.05) -> np.ndarray:
    jumps = rng.random(length) < p_jump
    targets = rng.integers(0, n_pages, size=length)
    offs = rng.integers(-hood, hood + 1, size=length)
    out = np.empty(length, dtype=np.int64)
    cur = int(rng.integers(0, n_pages))
    # vectorized-ish: segment between jumps shares a frontier centre
    centres = targets[np.searchsorted(np.flatnonzero(jumps), np.arange(length), side="right") - 1] \
        if jumps.any() else np.full(length, cur)
    centres[:int(np.argmax(jumps))] = cur if jumps.any() else cur
    out = (centres + offs) % n_pages
    return out.astype(np.int64)


def _blocked(n_pages: int, length: int, rng: np.random.Generator,
             block: int = 256, dwell: int = 2048) -> np.ndarray:
    n_blocks = max(1, -(-length // dwell))
    bases = rng.integers(0, max(1, n_pages - block), size=n_blocks)
    within = rng.integers(0, block, size=length)
    return (np.repeat(bases, dwell)[:length] + within) % n_pages


def _multiscale(n_pages: int, length: int, rng: np.random.Generator,
                seg: int = 2000, min_region: int = 256) -> np.ndarray:
    """Hierarchical working sets: dwell in a region whose size is drawn
    log-uniformly in [min_region, n_pages], then move on.

    Real programs exhibit reuse at many scales simultaneously (loop nests,
    data-structure traversals, phase behaviour); this is the pattern that
    makes TLB misses scale smoothly with translation *reach*, which is what
    the paper's SPEC-based traces show.
    """
    n_seg = max(1, length // seg)
    lo, hi = np.log2(min_region), np.log2(max(n_pages, min_region + 1))
    sizes = (2.0 ** rng.uniform(lo, hi, size=n_seg)).astype(np.int64)
    sizes = np.minimum(sizes, n_pages)
    bases = (rng.random(n_seg) * np.maximum(n_pages - sizes, 1)).astype(np.int64)
    offs = rng.random(length)
    seg_idx = np.minimum(np.arange(length) // seg, n_seg - 1)
    return bases[seg_idx] + (offs * sizes[seg_idx]).astype(np.int64)


def _mixed_phase(n_pages: int, length: int, rng: np.random.Generator) -> np.ndarray:
    gens = [_seq, _strided, _random, _zipf, _blocked]
    parts = []
    per = length // len(gens)
    for g in gens:
        parts.append(g(n_pages, per, rng))
    out = np.concatenate(parts)
    if out.shape[0] < length:
        out = np.concatenate([out, _seq(n_pages, length - out.shape[0], rng)])
    return out[:length]


PATTERNS = {
    "sequential": _seq,
    "strided": _strided,
    "random": _random,
    "zipf": _zipf,
    "bfs": _bfs,
    "blocked": _blocked,
    "multiscale": _multiscale,
    "mixed_phase": _mixed_phase,
}

# The paper's 16 benchmarks → access-pattern analogue + footprint (pages).
# Footprints are chosen so working sets well exceed the 1024-entry L2 reach
# (4MB), as for the paper's big-memory workloads.
BENCHMARKS: Dict[str, Tuple[str, int]] = {
    "astar": ("multiscale", 1 << 18),
    "bzip2": ("blocked", 1 << 17),
    "mcf": ("multiscale", 1 << 20),
    "omnetpp": ("zipf", 1 << 18),
    "povray": ("blocked", 1 << 16),
    "sjeng": ("mixed_phase", 1 << 17),
    "hmmer": ("strided", 1 << 16),
    "libquantum": ("sequential", 1 << 19),
    "bwaves": ("sequential", 1 << 19),
    "zeusmp": ("strided", 1 << 18),
    "gromacs": ("blocked", 1 << 17),
    "namd": ("multiscale", 1 << 17),
    "xalancbmk": ("zipf", 1 << 17),
    "wrf": ("multiscale", 1 << 19),
    "graph500": ("bfs", 1 << 20),
    "gups": ("random", 1 << 20),
}


def generate_trace(pattern: str, n_pages: int, length: int,
                   seed: int = 0, mapping=None) -> np.ndarray:
    """Generate a VPN trace.

    With ``mapping`` the pattern indexes the *mapped* pages only (VA-aligned
    mappings have unmapped alignment holes that a process never touches) and
    the returned trace contains true VPNs of that mapping.
    """
    rng = np.random.default_rng(seed)
    if mapping is not None:
        from .mappings import mapped_vpns
        mv = mapped_vpns(mapping)
        idx = PATTERNS[pattern](mv.shape[0], length, rng)
        return mv[np.asarray(idx, np.int64) % mv.shape[0]]
    vpns = PATTERNS[pattern](n_pages, length, rng)
    return np.asarray(vpns, dtype=np.int64) % n_pages


def benchmark_trace(name: str, length: int = 200_000, seed: int = 0,
                    mapping=None) -> Tuple[np.ndarray, int]:
    """Returns (trace, footprint_pages) for a named benchmark analogue."""
    pattern, n_pages = BENCHMARKS[name]
    return generate_trace(pattern, n_pages, length, seed=seed,
                          mapping=mapping), n_pages
