"""Trace-driven TLB simulator — unified JAX engine for all methods (paper §4).

Every compared method is a configuration of ONE set-associative engine, so the
paper's baselines and K-bit Aligned TLB differ only in declared policy, never
in simulation machinery:

* ``base``     — regular 4KB entries, standard index.
* ``thp``      — + 2MB huge-page entries (dual probe, separate L1 2MB array).
* ``colt``     — coalesced entries within 8-PTE cache-line windows [COLT'12].
* ``cluster``  — 768-entry regular + 320-entry clustered side TLB [HPCA'14].
* ``rmm``      — regular L2 + 32-entry fully-associative range TLB [RMM'15].
* ``anchor``   — single anchor distance d == K={log2 d} alignment [Anchor'17].
* ``kaligned`` — the paper: K-bit aligned entries, Fig-7 index scheme,
                 Algorithm 1 fill, Algorithm 2 lookup, 4-bit alignment
                 predictor.

The L2 set index follows the paper's modified scheme (Fig 7): bits
``[k_hat : k_hat+N)`` of the VPN, where ``k_hat = max(K)`` — every probe
(regular and all alignments) of one VPN lands in the same set, which is what
makes multi-alignment lookup a same-set tag compare.  The same property is
what lets :mod:`repro.core.sweep` batch *different* methods into one vmapped
engine: because the set index is always ``(vpn >> k_hat) & (l2_sets - 1)``
with per-method ``k_hat``/``l2_sets`` data, every method's L2 can live on one
padded ``(max_sets, max_ways)`` array layout — padded ways carry INVALID
k-classes (never hit, never chosen as victims) and unused alignment slots
carry inert ``K = -1`` classes whose probes are masked.  ``run_method`` below
stays as the per-call parity oracle for that batched engine.

Latency model (Table 2): L1 hit 0 (parallel with the cache access), L2
regular hit 7, coalesced/aligned/range/cluster hit 8 (+7 per extra aligned
probe), page walk 50, paid after the failed lookup chain (§3.5).

Implementation note: every conditional state write is expressed as an
*unconditional* one-element dynamic-update whose value falls back to the old
cell — XLA keeps the scan state in place; ``jnp.where(pred, scatter(arr),
arr)`` would copy the whole TLB every step.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .page_table import (DynamicMapping, Mapping, MultiTenantMapping,
                         NestedMapping, ParityWorld, cluster_bitmap,
                         huge_page_backed)

REGULAR = -1
HUGE = 9            # k-class used for 2MB entries (2^9 pages)
KSUBR = 10          # k-class used for subregion entries (bitmapped window)
INVALID = -2
NEG = -(2 ** 30)

# Subregion TLB (arXiv 2110.08613): one entry covers a fixed-size aligned
# memory subregion with a per-entry contiguity bitmap — bit j serves page
# ``base + j`` iff it is mapped with the same VA→PA delta as the fill page.
SUBR_BITS = 4
SUBR_PAGES = 1 << SUBR_BITS

# L2-cache-backed TLB tier (Victima, arXiv 2310.04158): evicted L2 TLB
# entries are victim-inserted into repurposed cache capacity — a much
# larger but slower tier probed after the on-chip structures miss.
CTLB_SETS, CTLB_WAYS = 256, 8
LAT_CTLB = 24

# Dead-entry protection (GPU TLB lineage, arXiv 2606.00486): a table of
# saturating reuse counters; a fill whose counter is still zero is
# predicted dead-on-arrival and bypasses the L2 (the walk is paid, the
# capacity is not).  Counters learn from repeated walks to the same index.
DP_TABLE = 256

# Latencies (Table 2)
LAT_L2_REG = 7
LAT_COAL = 8
LAT_EXTRA_PROBE = 7
LAT_WALK = 50

# Translation-coherence model (Yan et al., PAPERS.md): entering an epoch
# whose events dirtied >= 1 previously-mapped page costs one shootdown
# (IPI receipt + kernel entry), plus a per-entry invalidation port write
# for every TLB entry — in ANY structure — whose covered range contains a
# dirty vpn.  Charged once per epoch transition per TLB.
#
# WHICH entries die is fixed by correctness; what the turnover *stalls* is
# ``MethodSpec.coh_policy``: IPI-style ``"shootdown"`` pays LAT_SHOOTDOWN
# per turnover (broadcast receipt + kernel entry, even when nothing
# matches) plus LAT_INVALIDATE per killed entry, while directory-tracked
# ``"hw-coherence"`` pays only the per-entry port writes — the directory
# already knows which TLBs cache the dirty range, so there is no
# broadcast stall.  Counters and translations are bit-identical between
# the two policies; only cycles differ.
LAT_SHOOTDOWN = 200
LAT_INVALIDATE = 8

# Context-switch model (multi-tenant worlds): switching the running address
# space costs the kernel switch path once, whatever the TLB does about it.
# Under ``ctx_policy="flush"`` every structure is then bulk-cleared (valid
# bits drop in one go — no per-entry port writes; the real cost is the
# refill misses, which the simulation produces naturally).  Under
# ``ctx_policy="tag"`` entries survive and are screened by ASID compare;
# only a *recycled* ASID (see page_table.MultiTenantMapping) pays a
# targeted invalidation of its stale entries.  Entries invalidated by
# either flush are counted in ``SimResult.shootdowns``.
LAT_CTX_SWITCH = 150

N_COV_SAMPLES = 64

L1_SETS, L1_WAYS = 16, 4       # 64-entry 4-way (Table 2)
L1H_SETS, L1H_WAYS = 8, 4      # 32-entry 4-way 2MB array
RMM_ENTRIES = 32
CLUS_SETS, CLUS_WAYS = 64, 5   # 320-entry 5-way clustered TLB

# Accelerator-lineage kinds run through the segment oracle and the batched
# lane program only; ``run_method`` routes them past the legacy jitted
# ``_simulate`` (which covers the original paper roster).
ACCEL_KINDS = ("subregion", "cache-tlb", "dead-protect")

#: every registered MethodSpec kind — docs/methods.md must document each
#: one (enforced by scripts/check_docs_links.py).
KINDS = ("base", "thp", "colt", "cluster", "rmm", "anchor",
         "kaligned") + ACCEL_KINDS


@dataclasses.dataclass(frozen=True)
class MethodSpec:
    """Static (hashable) method configuration."""

    name: str
    kind: str                      # one of KINDS
    K: Tuple[int, ...] = ()        # alignment classes, descending
    l2_sets: int = 128
    l2_ways: int = 8
    index_shift: int = 0           # k_hat of Fig 7
    use_predictor: bool = False
    side: Optional[str] = None     # None | "rmm" | "cluster"
    #: context-switch policy on multi-tenant worlds: ``"flush"`` wipes every
    #: structure on a switch (untagged hardware), ``"tag"`` keeps entries
    #: ASID-tagged across switches (lookups only hit the live ASID; recycled
    #: ASIDs pay a targeted invalidation).  Irrelevant on single-address-
    #: space worlds: entries and probes then all carry ASID 0.
    ctx_policy: str = "flush"
    #: translation-coherence policy on remap turnovers (dynamic/nested
    #: worlds): ``"shootdown"`` is the IPI model — LAT_SHOOTDOWN broadcast
    #: stall per turnover plus LAT_INVALIDATE per killed entry —
    #: ``"hw-coherence"`` is the directory-tracked model (Yan et al.) —
    #: targeted per-entry invalidations only, no broadcast stall.  The
    #: invalidated-entry set (and so every counter and translation) is
    #: identical under both; only cycles differ.
    coh_policy: str = "shootdown"
    #: soft-error (parity-flip) policy on ParityWorld faults: ``"parity"``
    #: is detect-invalidate-rewalk — a flipped bit is caught by the parity
    #: check, EVERY entry whose covered range contains the poisoned vpn is
    #: invalidated (a |K|=k entry loses up to 2^k translations where Base
    #: loses one — the coalescing blast radius), and subsequent accesses
    #: re-walk and refill.  ``"ecc"`` is idealized in-place correction: the
    #: flip is repaired without losing any entry, so a run is bit-identical
    #: to the fault-free run by construction.  Irrelevant on worlds without
    #: parity faults.
    par_policy: str = "parity"

    def __post_init__(self):
        assert self.kind in KINDS, self.kind
        assert tuple(sorted(self.K, reverse=True)) == tuple(self.K)
        assert self.ctx_policy in ("flush", "tag"), self.ctx_policy
        assert self.coh_policy in ("shootdown", "hw-coherence"), \
            self.coh_policy
        assert self.par_policy in ("parity", "ecc"), self.par_policy


@dataclasses.dataclass
class SimResult:
    name: str
    accesses: int
    l1_hits: int
    l2_regular_hits: int
    l2_coalesced_hits: int
    walks: int
    aligned_probes: int
    pred_correct: int
    cycles: int
    coverage_mean: float           # Table 5 metric (covered PTEs in L2+side)
    ppn: np.ndarray                # translated PPNs (correctness oracle)
    shootdowns: int = 0            # entries invalidated by remap coherence

    @property
    def misses(self) -> int:       # "TLB misses" as plotted in Figs 1/8/9
        return self.walks

    @property
    def cpi(self) -> float:        # translation cycles per access (Fig 10/11)
        return self.cycles / max(self.accesses, 1)

    @property
    def predictor_accuracy(self) -> float:   # Table 6
        return self.pred_correct / max(self.l2_coalesced_hits, 1)


def miss_chain_cycles(spec: MethodSpec) -> int:
    """Cycles burned on the failed lookup chain before a walk (§3.5)."""
    if spec.K and spec.kind in ("kaligned", "anchor"):
        return LAT_COAL + LAT_EXTRA_PROBE * (len(spec.K) - 1)
    if spec.kind == "cache-tlb":
        return LAT_CTLB                  # the cache-backed tier probes last
    if spec.kind in ("colt", "subregion") or spec.side is not None:
        return LAT_COAL
    return LAT_L2_REG


def _full(shape, val):
    return jnp.full(shape, val, dtype=jnp.int32)


def _init_state(spec: MethodSpec):
    st = dict(
        t=jnp.int32(0),
        l1_tags=_full((L1_SETS, L1_WAYS), -1),
        l1_ppn=_full((L1_SETS, L1_WAYS), -1),
        l1_lru=_full((L1_SETS, L1_WAYS), 0),
        l2_tags=_full((spec.l2_sets, spec.l2_ways), -1),
        l2_k=_full((spec.l2_sets, spec.l2_ways), INVALID),
        l2_contig=_full((spec.l2_sets, spec.l2_ways), 0),
        l2_ppn=_full((spec.l2_sets, spec.l2_ways), -1),
        l2_lru=_full((spec.l2_sets, spec.l2_ways), 0),
        pred=jnp.int32(spec.K[0] if spec.K else 0),
        l1_hits=jnp.int32(0), reg_hits=jnp.int32(0), coal_hits=jnp.int32(0),
        walks=jnp.int32(0), probes=jnp.int32(0), pred_correct=jnp.int32(0),
        cycles=jnp.int32(0), cov=jnp.int32(0),
        cov_samples=_full((N_COV_SAMPLES,), 0),
    )
    if spec.kind == "thp":
        st.update(l1h_tags=_full((L1H_SETS, L1H_WAYS), -1),
                  l1h_ppn=_full((L1H_SETS, L1H_WAYS), -1),
                  l1h_lru=_full((L1H_SETS, L1H_WAYS), 0))
    if spec.side == "rmm":
        st.update(rmm_start=_full((RMM_ENTRIES,), -1),
                  rmm_len=_full((RMM_ENTRIES,), 0),
                  rmm_ppn=_full((RMM_ENTRIES,), -1),
                  rmm_lru=_full((RMM_ENTRIES,), 0))
    if spec.side == "cluster":
        st.update(cl_tags=_full((CLUS_SETS, CLUS_WAYS), -1),
                  cl_bm=_full((CLUS_SETS, CLUS_WAYS), 0),
                  cl_lru=_full((CLUS_SETS, CLUS_WAYS), 0))
    return st


def _cond_set(arr, idx, value, pred):
    """In-place conditional point write: arr[idx] = pred ? value : arr[idx]."""
    old = arr[idx]
    return arr.at[idx].set(jnp.where(pred, value, old))


@functools.partial(jax.jit, static_argnums=(0,))
def _simulate(spec: MethodSpec, ppn_map, run_start, run_len, huge_ok,
              clus_bm, trace):
    n_pages = ppn_map.shape[0]
    Ks = spec.K
    k_hat = spec.index_shift
    set_mask = jnp.int32(spec.l2_sets - 1)
    T = trace.shape[0]
    sample_every = max(T // N_COV_SAMPLES, 1)

    def contig_at(v):
        """Per-PTE contiguity field from the page table (0 = unmapped)."""
        v = jnp.clip(v, 0, n_pages - 1)
        mapped = ppn_map[v] >= 0
        return jnp.where(mapped, run_start[v] + run_len[v] - v, 0)

    def l2_set(vpn):
        return (vpn >> k_hat) & set_mask

    def probe_order(pred_k):
        """Traced list of |K| alignment values: predictor's k first, then the
        remaining K in descending order (§3.2 speculation)."""
        if not Ks:
            return []
        if not spec.use_predictor:
            return [jnp.int32(k) for k in Ks]
        kk = jnp.array(Ks, jnp.int32)
        order = [pred_k]
        not_pred = kk != pred_k
        csum = jnp.cumsum(not_pred.astype(jnp.int32))
        for pos in range(1, len(Ks)):
            sel = not_pred & (csum == pos)
            order.append(jnp.where(sel.any(), kk[jnp.argmax(sel)],
                                   jnp.int32(-1)))
        return order

    def step(st, vpn):
        t = st["t"]
        ppn_true = ppn_map[vpn]
        new = dict(st)

        # ---------------- L1 ------------------------------------------------
        s1 = vpn & jnp.int32(L1_SETS - 1)
        l1_ways_hit = st["l1_tags"][s1] == vpn
        l1_hit = l1_ways_hit.any()
        l1_way = jnp.argmax(l1_ways_hit)
        l1_ppn_val = st["l1_ppn"][s1, l1_way]
        if spec.kind == "thp":
            hv = vpn >> 9
            s1h = hv & jnp.int32(L1H_SETS - 1)
            h_ways_hit = st["l1h_tags"][s1h] == hv
            l1h_hit = h_ways_hit.any()
            l1h_way = jnp.argmax(h_ways_hit)
            l1h_ppn_val = st["l1h_ppn"][s1h, l1h_way] + (vpn & 511)
            l1_served = l1_hit | l1h_hit
            l1_out_ppn = jnp.where(l1_hit, l1_ppn_val, l1h_ppn_val)
        else:
            l1_served = l1_hit
            l1_out_ppn = l1_ppn_val

        # ---------------- L2 probes -----------------------------------------
        s2 = l2_set(vpn)
        tags = st["l2_tags"][s2]
        kcls = st["l2_k"][s2]
        contig = st["l2_contig"][s2]
        pbase = st["l2_ppn"][s2]
        valid = kcls != INVALID

        probes_used = jnp.int32(0)
        pred_ok = jnp.int32(0)
        hit_k = jnp.int32(-1)
        coal_hit = jnp.bool_(False)
        coal_ppn = jnp.int32(-1)
        coal_way = jnp.int32(0)

        if spec.kind == "colt":
            diff = vpn - tags
            cover = valid & (diff >= 0) & (diff < contig)
            l2_hit = cover.any()
            way = jnp.argmax(cover)
            reg_hit = l2_hit & (contig[way] == 1)
            coal_hit = l2_hit & (contig[way] > 1)
            l2_ppn_val = pbase[way] + (vpn - tags[way])
            touch_ways = cover
            touch_set = s2
        elif spec.kind == "thp":
            hv = vpn >> 9
            s2h = hv & set_mask
            tags_h = st["l2_tags"][s2h]
            kcls_h = st["l2_k"][s2h]
            huge_ways = (kcls_h == HUGE) & (tags_h == hv)
            reg_ways = (kcls == REGULAR) & (tags == vpn) & valid
            huge_hit = huge_ways.any()
            hw = jnp.argmax(huge_ways)
            rw = jnp.argmax(reg_ways)
            reg_hit = reg_ways.any() | huge_hit   # 2MB hit = plain L2 hit (7cyc)
            l2_hit = reg_hit
            l2_ppn_val = jnp.where(
                reg_ways.any(), pbase[rw],
                st["l2_ppn"][s2h, hw] + (vpn - (hv << 9)))
            touch_ways = jnp.where(reg_ways.any(), reg_ways, huge_ways)
            touch_set = jnp.where(reg_ways.any(), s2, s2h)
        else:
            reg_ways = (kcls == REGULAR) & (tags == vpn) & valid
            reg_hit = reg_ways.any()
            rw = jnp.argmax(reg_ways)
            first_probe_k = jnp.int32(-1)
            for pos, k_val in enumerate(probe_order(st["pred"])):
                vk = jnp.where(k_val >= 0,
                               vpn & ~((jnp.int32(1) << k_val) - 1),
                               jnp.int32(-10))
                m_ways = (kcls == k_val) & (tags == vk) & valid & \
                         (contig > (vpn - vk))
                m_hit = m_ways.any() & (k_val >= 0) & ~reg_hit & ~coal_hit
                probes_used = probes_used + jnp.where(
                    ~reg_hit & ~coal_hit & (k_val >= 0), 1, 0)
                coal_ppn = jnp.where(m_hit, pbase[jnp.argmax(m_ways)]
                                     + (vpn - vk), coal_ppn)
                coal_way = jnp.where(m_hit, jnp.argmax(m_ways), coal_way)
                hit_k = jnp.where(m_hit, k_val, hit_k)
                if pos == 0:
                    first_probe_k = k_val
                coal_hit = coal_hit | m_hit
            l2_hit = reg_hit | coal_hit
            l2_ppn_val = jnp.where(reg_hit, pbase[rw], coal_ppn)
            if spec.use_predictor:
                pred_ok = jnp.where(coal_hit & (hit_k == first_probe_k), 1, 0)
            touch_ways = jnp.zeros_like(reg_ways).at[
                jnp.where(reg_hit, rw, coal_way)].set(True)
            touch_set = s2

        # ---------------- side structures (probed with L2) ------------------
        side_hit = jnp.bool_(False)
        side_ppn = jnp.int32(-1)
        if spec.side == "rmm":
            d_r = vpn - st["rmm_start"]
            in_rng = (d_r >= 0) & (d_r < st["rmm_len"])
            side_hit = in_rng.any()
            sw = jnp.argmax(in_rng)
            side_ppn = st["rmm_ppn"][sw] + d_r[sw]
        if spec.side == "cluster":
            cwd = vpn >> 3
            sc = cwd & jnp.int32(CLUS_SETS - 1)
            crow = st["cl_tags"][sc]
            bit = (st["cl_bm"][sc] >> (vpn & 7)) & 1
            c_ways = (crow == cwd) & (bit == 1)
            side_hit = c_ways.any()
            # the clustered entry stores per-page offsets; by construction its
            # translation equals the page table's.
            side_ppn = ppn_true

        hit_any = l1_served | l2_hit | side_hit
        walk = ~hit_any

        # ---------------- latency (Table 2, §3.5) ---------------------------
        miss_chain = miss_chain_cycles(spec)
        cyc = jnp.where(
            l1_served, 0,
            jnp.where(reg_hit, LAT_L2_REG,
                      jnp.where(coal_hit,
                                LAT_COAL + LAT_EXTRA_PROBE *
                                jnp.maximum(probes_used - 1, 0),
                                jnp.where(side_hit, LAT_COAL,
                                          miss_chain + LAT_WALK))))

        # ---------------- fill selection (Algorithm 1) ----------------------
        if spec.kind in ("kaligned", "anchor"):
            fill_k = jnp.int32(REGULAR)
            fill_tag, fill_contig, fill_ppn = vpn, jnp.int32(1), ppn_true
            chosen = jnp.bool_(False)
            for k in Ks:                      # descending; first cover wins
                kk = jnp.int32(k)
                vk = vpn & ~((jnp.int32(1) << kk) - 1)
                sc_ = jnp.minimum(contig_at(vk), jnp.int32(1) << kk)
                take = (sc_ > (vpn - vk)) & ~chosen
                fill_k = jnp.where(take, kk, fill_k)
                fill_tag = jnp.where(take, vk, fill_tag)
                fill_contig = jnp.where(take, sc_, fill_contig)
                fill_ppn = jnp.where(
                    take, ppn_map[jnp.clip(vk, 0, n_pages - 1)], fill_ppn)
                chosen = chosen | take
            fill_set = s2
        elif spec.kind == "colt":
            w8 = vpn & ~jnp.int32(7)
            rs_ = run_start[vpn]
            re_ = rs_ + run_len[vpn]
            fill_tag = jnp.maximum(rs_, w8)
            fill_contig = jnp.maximum(jnp.minimum(re_, w8 + 8) - fill_tag, 1)
            fill_k = jnp.where(fill_contig > 1, jnp.int32(3),
                               jnp.int32(REGULAR))
            fill_ppn = ppn_map[jnp.clip(fill_tag, 0, n_pages - 1)]
            fill_set = s2
        elif spec.kind == "thp":
            is_huge = huge_ok[vpn]
            hv = vpn >> 9
            fill_tag = jnp.where(is_huge, hv, vpn)
            fill_k = jnp.where(is_huge, jnp.int32(HUGE), jnp.int32(REGULAR))
            fill_contig = jnp.where(is_huge, 512, 1)
            base_v = jnp.where(is_huge, hv << 9, vpn)
            fill_ppn = ppn_map[jnp.clip(base_v, 0, n_pages - 1)]
            fill_set = jnp.where(is_huge, hv & set_mask, s2)
        else:
            fill_tag, fill_contig, fill_ppn = vpn, jnp.int32(1), ppn_true
            fill_k = jnp.int32(REGULAR)
            fill_set = s2

        # ---------------- L2 fill (LRU victim) ------------------------------
        lru_row = st["l2_lru"][fill_set]
        valid_row = st["l2_k"][fill_set] != INVALID
        victim = jnp.argmin(jnp.where(valid_row, lru_row, jnp.int32(NEG)))
        evicted_contig = jnp.where(valid_row[victim],
                                   st["l2_contig"][fill_set, victim], 0)
        idx = (fill_set, victim)
        new["l2_tags"] = _cond_set(st["l2_tags"], idx, fill_tag, walk)
        new["l2_k"] = _cond_set(st["l2_k"], idx, fill_k, walk)
        new["l2_contig"] = _cond_set(st["l2_contig"], idx, fill_contig, walk)
        new["l2_ppn"] = _cond_set(st["l2_ppn"], idx, fill_ppn, walk)
        new["l2_lru"] = _cond_set(st["l2_lru"], idx, t, walk)
        cov_delta = jnp.where(walk, fill_contig - evicted_contig, 0)

        # LRU touch on the hitting way
        tw = jnp.argmax(touch_ways) if spec.kind in ("colt", "thp") else \
            jnp.argmax(touch_ways)
        new["l2_lru"] = _cond_set(new["l2_lru"], (touch_set, tw), t,
                                  l2_hit & ~walk & ~l1_served)

        # ---------------- side fills ----------------------------------------
        if spec.side == "rmm":
            victim_r = jnp.argmin(jnp.where(st["rmm_len"] > 0, st["rmm_lru"],
                                            jnp.int32(NEG)))
            ev_len = jnp.where(st["rmm_len"][victim_r] > 0,
                               st["rmm_len"][victim_r], 0)
            rs_, rl_ = run_start[vpn], run_len[vpn]
            new["rmm_start"] = _cond_set(st["rmm_start"], victim_r, rs_, walk)
            new["rmm_len"] = _cond_set(st["rmm_len"], victim_r, rl_, walk)
            new["rmm_ppn"] = _cond_set(
                st["rmm_ppn"], victim_r,
                ppn_map[jnp.clip(rs_, 0, n_pages - 1)], walk)
            lru1 = _cond_set(st["rmm_lru"], victim_r, t, walk)
            new["rmm_lru"] = _cond_set(lru1, sw if spec.side == "rmm" else 0,
                                       t, side_hit)
            cov_delta = cov_delta + jnp.where(walk, rl_ - ev_len, 0)
        if spec.side == "cluster":
            cwd = vpn >> 3
            sc = cwd & jnp.int32(CLUS_SETS - 1)
            bm = clus_bm[vpn]
            clusterable = bm != (jnp.int32(1) << (vpn & 7))
            fill_c = walk & clusterable
            vrow = st["cl_bm"][sc] != 0
            victim_c = jnp.argmin(jnp.where(vrow, st["cl_lru"][sc],
                                            jnp.int32(NEG)))
            cidx = (sc, victim_c)
            new["cl_tags"] = _cond_set(st["cl_tags"], cidx, cwd, fill_c)
            new["cl_bm"] = _cond_set(st["cl_bm"], cidx, bm, fill_c)
            lru1 = _cond_set(st["cl_lru"], cidx, t, fill_c)
            hit_cway = jnp.argmax((st["cl_tags"][sc] == cwd))
            new["cl_lru"] = _cond_set(lru1, (sc, hit_cway), t, side_hit)

        # ---------------- L1 fill --------------------------------------------
        if spec.kind == "thp":
            served_huge = huge_ok[vpn]
            hv = vpn >> 9
            s1h = hv & jnp.int32(L1H_SETS - 1)
            do1h = ~l1_served & served_huge
            vrh = st["l1h_tags"][s1h] >= 0
            vich = jnp.argmin(jnp.where(vrh, st["l1h_lru"][s1h],
                                        jnp.int32(NEG)))
            hidx = (s1h, vich)
            new["l1h_tags"] = _cond_set(st["l1h_tags"], hidx, hv, do1h)
            new["l1h_ppn"] = _cond_set(
                st["l1h_ppn"], hidx,
                ppn_map[jnp.clip(hv << 9, 0, n_pages - 1)], do1h)
            lru1 = _cond_set(st["l1h_lru"], hidx, t, do1h)
            new["l1h_lru"] = _cond_set(lru1, (s1h, l1h_way), t,
                                       l1_served & h_ways_hit.any() & ~l1_hit)
            do1 = ~l1_served & ~served_huge
        else:
            do1 = ~l1_served
        vr1 = st["l1_tags"][s1] >= 0
        vic1 = jnp.argmin(jnp.where(vr1, st["l1_lru"][s1], jnp.int32(NEG)))
        iidx = (s1, vic1)
        new["l1_tags"] = _cond_set(st["l1_tags"], iidx, vpn, do1)
        new["l1_ppn"] = _cond_set(st["l1_ppn"], iidx, ppn_true, do1)
        lru1 = _cond_set(st["l1_lru"], iidx, t, do1)
        new["l1_lru"] = _cond_set(lru1, (s1, l1_way), t, l1_hit)

        # ---------------- predictor update (§3.2) ---------------------------
        if spec.use_predictor and Ks:
            new["pred"] = jnp.where(
                coal_hit, hit_k,
                jnp.where(walk & (fill_k >= 0), fill_k, st["pred"]))

        # ---------------- accounting -----------------------------------------
        new["t"] = t + 1
        new["l1_hits"] = st["l1_hits"] + l1_served
        new["reg_hits"] = st["reg_hits"] + (reg_hit & ~l1_served)
        new["coal_hits"] = st["coal_hits"] + \
            ((coal_hit | side_hit) & ~reg_hit & ~l1_served)
        new["walks"] = st["walks"] + walk
        new["probes"] = st["probes"] + jnp.where(coal_hit & ~l1_served,
                                                 probes_used, 0)
        new["pred_correct"] = st["pred_correct"] + \
            jnp.where(~l1_served, pred_ok, 0)
        new["cycles"] = st["cycles"] + cyc
        new["cov"] = st["cov"] + cov_delta
        slot = jnp.minimum(t // sample_every, N_COV_SAMPLES - 1)
        new["cov_samples"] = _cond_set(new["cov_samples"], slot, new["cov"],
                                       t % sample_every == sample_every - 1)

        out_ppn = jnp.where(l1_served, l1_out_ppn,
                            jnp.where(l2_hit, l2_ppn_val,
                                      jnp.where(side_hit, side_ppn, ppn_true)))
        return new, out_ppn

    st0 = _init_state(spec)
    stF, ppns = jax.lax.scan(step, st0, trace)
    return stF, ppns


def run_method(spec: MethodSpec, m: Mapping, trace: np.ndarray) -> SimResult:
    """Simulate one method over (mapping, trace) and collect paper metrics."""
    if spec.kind in ACCEL_KINDS:
        # accelerator-lineage kinds live in the segment oracle (which treats
        # a static mapping as a single-segment world), not in ``_simulate``
        return run_method_dynamic(spec, m, trace)
    ppn_map = jnp.asarray(m.ppn, jnp.int32)
    rs = jnp.asarray(m.run_start, jnp.int32)
    rl = jnp.asarray(m.run_len, jnp.int32)
    huge = (jnp.asarray(huge_page_backed(m)) if spec.kind == "thp"
            else jnp.zeros((1,), bool))
    cbm = (jnp.asarray(cluster_bitmap(m), jnp.int32) if spec.side == "cluster"
           else jnp.zeros((1,), jnp.int32))
    tr = jnp.asarray(trace, jnp.int32)
    stF, ppns = _simulate(spec, ppn_map, rs, rl, huge, cbm, tr)
    stF = jax.device_get(stF)
    return SimResult(
        name=spec.name, accesses=int(tr.shape[0]),
        l1_hits=int(stF["l1_hits"]), l2_regular_hits=int(stF["reg_hits"]),
        l2_coalesced_hits=int(stF["coal_hits"]), walks=int(stF["walks"]),
        aligned_probes=int(stF["probes"]), pred_correct=int(stF["pred_correct"]),
        cycles=int(stF["cycles"]),
        coverage_mean=float(np.mean(np.asarray(stF["cov_samples"]))),
        ppn=np.asarray(jax.device_get(ppns)),
    )


# ---------------------------------------------------------------------------
# Segment-driven pure-python oracle (dynamic AND multi-tenant worlds)
# ---------------------------------------------------------------------------
#
# ``run_method_dynamic`` / ``run_method_multitenant`` are the correctness
# references for mid-trace remaps and for multi-tenant context switching: a
# plain numpy state machine with the exact semantics of the engine above,
# plus (a) paper-correct translation coherence — entering an epoch whose
# events dirtied pages, every structure (L1, 2MB L1, L2, RMM ranges,
# clustered side-TLB) drops every entry whose covered range contains a vpn
# whose translation died, and the shootdown cost is charged — and (b)
# ASID-correct context switching: every entry in every structure carries
# the ASID it was filled under, lookups only hit entries of the live ASID,
# and a switch either bulk-flushes (``ctx_policy="flush"``) or relies on
# the tags (``"tag"``, with targeted invalidation of recycled ASIDs).
# Both run over one shared segment loop (:func:`_run_segments`); the
# batched lanes of :mod:`repro.core.sweep` must match it bit for bit
# (tests/test_dynamic.py, tests/test_multitenant.py).  It is deliberately
# written without JAX so an engine bug cannot hide in shared machinery.


def _as_dynamic(world) -> DynamicMapping:
    if isinstance(world, DynamicMapping):
        return world
    return DynamicMapping((world,), (0,), name=world.name)


@dataclasses.dataclass
class _OracleSegment:
    """One schedule segment of the oracle: mapping + per-entry records live
    from trace step ``lo``, entered with optional coherence/switch work."""

    lo: int
    m: Mapping
    fill: np.ndarray                      # [n_pages, 4] fill profile
    clus: Optional[np.ndarray]            # [n_pages] cluster bitmap
    asid: int = 0
    switch: bool = False                  # address space changed: charge it
    flush_all: bool = False               # wipe every structure on entry
    flush_asid: bool = False              # wipe entries tagged asid (recycle)
    dirty: Optional[np.ndarray] = None    # bool[n_pages] shootdown set


def _segs_dynamic(spec: MethodSpec, world) -> list:
    from .lane_program import _fill_profile, _fill_profile_key  # lazy: no cycle

    dyn = _as_dynamic(world)
    fkey = _fill_profile_key(spec)
    has_clus = spec.side == "cluster"
    segs = []
    for e, m in enumerate(dyn.epochs):
        dirty = dyn.dirty(e) if e >= 1 else None
        if dirty is not None and not dirty.any():
            dirty = None
        segs.append(_OracleSegment(
            lo=dyn.boundaries[e], m=m,
            fill=_fill_profile(m, fkey, m.n_pages),
            clus=cluster_bitmap(m) if has_clus else None,
            dirty=dirty))
    return segs


def run_method_dynamic(spec: MethodSpec, world, trace: np.ndarray,
                       on_step=None, on_event=None) -> SimResult:
    """Simulate one method over a (possibly dynamic) world, pure python."""
    return _run_segments(spec, _segs_dynamic(spec, world), trace,
                         on_step=on_step, on_event=on_event)


def run_method_multitenant(spec: MethodSpec, world: MultiTenantMapping,
                           trace: np.ndarray, on_step=None, on_event=None
                           ) -> SimResult:
    """Simulate one method over a multi-tenant world, pure python.

    Every trace entry is a vpn of the tenant scheduled at that step
    (:meth:`~repro.core.page_table.MultiTenantMapping.tenant_at`); whether
    a context switch flushes or relies on ASID tags is
    ``spec.ctx_policy``.  The sweep engine's switch-segmented lanes must
    match this bit for bit (``tests/test_multitenant.py``)."""
    return _run_segments(spec, _segs_multitenant(spec, world), trace,
                         on_step=on_step, on_event=on_event)


def _segs_multitenant(spec: MethodSpec, world: MultiTenantMapping) -> list:
    from .lane_program import _fill_profile, _fill_profile_key  # lazy: no cycle

    assert isinstance(world, MultiTenantMapping)
    fkey = _fill_profile_key(spec)
    has_clus = spec.side == "cluster"
    fill_of: dict = {}
    clus_of: dict = {}
    segs = []
    for s in range(world.n_segments):
        tid = world.tenant_ids[s]
        m = world.tenants[tid]
        if tid not in fill_of:
            fill_of[tid] = _fill_profile(m, fkey, m.n_pages)
            clus_of[tid] = cluster_bitmap(m) if has_clus else None
        sw = world.switches(s)
        segs.append(_OracleSegment(
            lo=world.boundaries[s], m=m, fill=fill_of[tid],
            clus=clus_of[tid], asid=world.asids[s], switch=sw,
            flush_all=sw and spec.ctx_policy == "flush",
            flush_asid=world.recycled[s] and spec.ctx_policy == "tag"))
    return segs


def run_method_nested(spec: MethodSpec, world: NestedMapping,
                      trace: np.ndarray, on_step=None, on_event=None
                      ) -> SimResult:
    """Simulate one method over a nested (guest → host) world, pure python.

    Segments are the union grid of
    :meth:`~repro.core.page_table.NestedMapping.plan_segments` — VM
    schedule × guest epochs × host epochs — so one oracle loop discharges
    the dynamic × multi-tenant combination: a VM switch is a context
    switch under ``spec.ctx_policy``, and a guest- or host-level remap is
    a coherence turnover over the *composed* dirty set, charged under
    ``spec.coh_policy``.  The sweep engine's nested lanes must match this
    bit for bit (``tests/test_nested.py``, the extended fuzzer)."""
    return _run_segments(spec, _segs_nested(spec, world), trace,
                         on_step=on_step, on_event=on_event)


def _segs_nested(spec: MethodSpec, world: NestedMapping) -> list:
    from .lane_program import _fill_profile, _fill_profile_key  # lazy: no cycle

    assert isinstance(world, NestedMapping)
    fkey = _fill_profile_key(spec)
    has_clus = spec.side == "cluster"
    fill_of: dict = {}
    clus_of: dict = {}
    segs = []
    for ns in world.plan_segments():
        m = ns.mapping
        key = id(m)                      # composed views are memoized
        if key not in fill_of:
            fill_of[key] = _fill_profile(m, fkey, m.n_pages)
            clus_of[key] = cluster_bitmap(m) if has_clus else None
        segs.append(_OracleSegment(
            lo=ns.lo, m=m, fill=fill_of[key], clus=clus_of[key],
            asid=ns.asid, switch=ns.switch,
            flush_all=ns.switch and spec.ctx_policy == "flush",
            flush_asid=ns.recycled and spec.ctx_policy == "tag",
            dirty=ns.dirty))
    return segs


def _base_segments(spec: MethodSpec, base) -> list:
    """Oracle segment plan for any (non-parity) base world."""
    if isinstance(base, NestedMapping):
        return _segs_nested(spec, base)
    if isinstance(base, MultiTenantMapping):
        return _segs_multitenant(spec, base)
    return _segs_dynamic(spec, base)     # handles static too


def run_method_parity(spec: MethodSpec, world: ParityWorld,
                      trace: np.ndarray, on_step=None, on_event=None
                      ) -> SimResult:
    """Simulate one method over a parity-fault world, pure python.

    Each ``(step, vpn)`` fault is lowered to an extra segment boundary at
    ``step`` that keeps the live mapping, fill profile and ASID — so no
    context-switch work happens — and, under ``par_policy="parity"``,
    carries a single-vpn dirty set: entering it runs the standard
    detect-invalidate pass (every entry covering the vpn dies; a |K|=k
    entry loses up to ``2^k`` translations where Base loses one) charged
    like a coherence turnover under ``spec.coh_policy``, and subsequent
    accesses re-walk and refill — the detect-invalidate-rewalk recovery.
    Under ``par_policy="ecc"`` the fault segment carries no dirty set and
    the whole run is bit-identical to the fault-free run by construction.
    The sweep engine's parity-spliced lanes must match this bit for bit
    (``tests/test_robustness.py``)."""
    assert isinstance(world, ParityWorld)
    segs = _base_segments(spec, world.base)
    for t, vpn in world.faults:
        # the segment live at step t: the last one with lo <= t
        live_i = max(i for i, sg in enumerate(segs) if sg.lo <= t)
        live = segs[live_i]
        assert 0 <= vpn < live.m.n_pages, (t, vpn, live.m.n_pages)
        dirty = None
        if spec.par_policy == "parity":
            dirty = np.zeros(live.m.n_pages, bool)
            dirty[vpn] = True
        segs.insert(live_i + 1, _OracleSegment(
            lo=t, m=live.m, fill=live.fill, clus=live.clus,
            asid=live.asid, dirty=dirty))
    return _run_segments(spec, segs, trace, on_step=on_step,
                         on_event=on_event)


def _run_segments(spec: MethodSpec, segs, trace: np.ndarray,
                  on_step=None, on_event=None) -> SimResult:
    """The shared oracle loop: one TLB, a segment schedule, ASID tags.

    ``on_step(dict)`` (when given) receives one record per access —
    ``{t, vpn, asid, level, ppn, walk, evict, probes, cycles}`` with
    ``level`` in ``l1|l2reg|l2coal|side|walk`` — and ``on_event(dict)``
    one record per segment-entry action (``kind`` in ``switch|shootdown``
    with the invalidated-entry count): the golden-trace suite
    (``tests/goldens``) pins these step sequences so a parity failure
    localizes to a step instead of an end-of-run counter diff.
    """
    trace = np.asarray(trace, np.int64)
    T = int(trace.shape[0])
    Ks = spec.K
    k_hat = spec.index_shift
    set_mask = spec.l2_sets - 1
    miss_chain = miss_chain_cycles(spec)
    is_colt = spec.kind == "colt"
    is_thp = spec.kind == "thp"
    has_rmm = spec.side == "rmm"
    has_clus = spec.side == "cluster"
    is_subr = spec.kind == "subregion"
    has_ctlb = spec.kind == "cache-tlb"
    use_dead = spec.kind == "dead-protect"

    # -- state ------------------------------------------------------------
    l1_tag = np.full((L1_SETS, L1_WAYS), -1, np.int64)
    l1_ppn = np.full((L1_SETS, L1_WAYS), -1, np.int64)
    l1_lru = np.zeros((L1_SETS, L1_WAYS), np.int64)
    l1_asid = np.zeros((L1_SETS, L1_WAYS), np.int64)
    l1h_tag = np.full((L1H_SETS, L1H_WAYS), -1, np.int64)
    l1h_ppn = np.full((L1H_SETS, L1H_WAYS), -1, np.int64)
    l1h_lru = np.zeros((L1H_SETS, L1H_WAYS), np.int64)
    l1h_asid = np.zeros((L1H_SETS, L1H_WAYS), np.int64)
    l2_tag = np.full((spec.l2_sets, spec.l2_ways), -1, np.int64)
    l2_k = np.full((spec.l2_sets, spec.l2_ways), INVALID, np.int64)
    l2_contig = np.zeros((spec.l2_sets, spec.l2_ways), np.int64)
    l2_ppn = np.full((spec.l2_sets, spec.l2_ways), -1, np.int64)
    l2_lru = np.zeros((spec.l2_sets, spec.l2_ways), np.int64)
    l2_asid = np.zeros((spec.l2_sets, spec.l2_ways), np.int64)
    l2_aux = np.zeros((spec.l2_sets, spec.l2_ways), np.int64)
    rmm_start = np.full(RMM_ENTRIES, -1, np.int64)
    rmm_len = np.zeros(RMM_ENTRIES, np.int64)
    rmm_ppn = np.full(RMM_ENTRIES, -1, np.int64)
    rmm_lru = np.zeros(RMM_ENTRIES, np.int64)
    rmm_asid = np.zeros(RMM_ENTRIES, np.int64)
    cl_tag = np.full((CLUS_SETS, CLUS_WAYS), -1, np.int64)
    cl_bm = np.zeros((CLUS_SETS, CLUS_WAYS), np.int64)
    cl_lru = np.zeros((CLUS_SETS, CLUS_WAYS), np.int64)
    cl_asid = np.zeros((CLUS_SETS, CLUS_WAYS), np.int64)
    ctlb_tag = np.full((CTLB_SETS, CTLB_WAYS), -1, np.int64)
    ctlb_ppn = np.full((CTLB_SETS, CTLB_WAYS), -1, np.int64)
    ctlb_lru = np.zeros((CTLB_SETS, CTLB_WAYS), np.int64)
    ctlb_asid = np.zeros((CTLB_SETS, CTLB_WAYS), np.int64)
    dp_ctr = np.zeros(DP_TABLE, np.int64)
    pred = int(Ks[0]) if Ks else 0
    cur_asid = segs[0].asid

    n_l1 = n_reg = n_coal = n_walk = n_probe = n_pred = 0
    cycles = cov = n_shoot = 0
    sample_every = max(T // N_COV_SAMPLES, 1)
    cov_samples = np.zeros(N_COV_SAMPLES, np.int64)
    out = np.empty(T, np.int64)
    seg_i = 0

    def shootdown(t: int, dirty: np.ndarray, n_pages: int):
        """Invalidate every entry covering a dirty vpn; charge the cost.

        Coherence invalidation is ASID-blind: a translation died for
        whichever address space held it (in single-space worlds every
        entry carries ASID 0 anyway)."""
        nonlocal n_shoot, cycles, cov
        dcum = np.concatenate([[0], np.cumsum(dirty)])

        def rng_dirty(lo, ln):
            lo_ = np.clip(lo, 0, n_pages)
            hi_ = np.clip(lo + ln, 0, n_pages)
            return (dcum[hi_] - dcum[lo_]) > 0

        n_inv = 0
        cov_loss = 0
        valid2 = l2_k != INVALID
        # k == HUGE means "2MB entry, tag is vpn >> 9" only on THP lanes;
        # for K-bit Aligned, k = 9 is an ordinary alignment class whose tag
        # is the window base vpn.
        huge2 = is_thp & (l2_k == HUGE)
        lo2 = np.where(huge2, l2_tag << 9, l2_tag)
        # a subregion entry covers its whole aligned window: invalidation is
        # conservative over [tag, tag + SUBR_PAGES) (a cleared bitmap bit is
        # only ever a miss, never a stale translation, so over-invalidating
        # is safe and keeps the range query uniform)
        ln2 = np.where(huge2, 512,
                       np.where(is_subr & (l2_k == KSUBR), SUBR_PAGES,
                                np.where(l2_k == REGULAR, 1,
                                         np.maximum(l2_contig, 1))))
        stale2 = valid2 & rng_dirty(np.maximum(lo2, 0), ln2)
        n_inv += int(stale2.sum())
        cov_loss += int(l2_contig[stale2].sum())
        l2_k[stale2] = INVALID

        v1 = l1_tag >= 0
        stale1 = v1 & rng_dirty(np.maximum(l1_tag, 0), 1)
        n_inv += int(stale1.sum())
        l1_tag[stale1] = -1

        vh = l1h_tag >= 0
        staleh = vh & rng_dirty(np.maximum(l1h_tag, 0) << 9, 512)
        n_inv += int(staleh.sum())
        l1h_tag[staleh] = -1

        vr = rmm_len > 0
        staler = vr & rng_dirty(np.maximum(rmm_start, 0), rmm_len)
        n_inv += int(staler.sum())
        cov_loss += int(rmm_len[staler].sum())
        rmm_start[staler] = -1
        rmm_len[staler] = 0
        rmm_ppn[staler] = -1

        vc = cl_bm != 0
        stalec = vc & rng_dirty(np.maximum(cl_tag, 0) << 3, 8)
        n_inv += int(stalec.sum())
        cl_bm[stalec] = 0

        vt = ctlb_tag >= 0
        stalet = vt & rng_dirty(np.maximum(ctlb_tag, 0), 1)
        n_inv += int(stalet.sum())
        cov_loss += int(stalet.sum())
        ctlb_tag[stalet] = -1
        # the dead-entry counter table holds predictions, not translations:
        # nothing to invalidate

        n_shoot += n_inv
        if spec.coh_policy == "hw-coherence":
            # directory-tracked: targeted port writes only, no IPI stall
            cycles += LAT_INVALIDATE * n_inv
        else:
            cycles += LAT_SHOOTDOWN + LAT_INVALIDATE * n_inv
        cov -= cov_loss
        if on_event is not None:
            on_event(dict(t=t, kind="shootdown", invalidated=n_inv))

    def ctx_switch(t: int, seg: _OracleSegment):
        """Enter a schedule segment: set the live ASID, charge the switch,
        and flush — everything (``flush_all``) or the recycled ASID's stale
        entries (``flush_asid``).  Flushes are bulk valid-bit clears (no
        per-entry port writes); the refill misses are the real cost."""
        nonlocal cur_asid, n_shoot, cycles, cov
        cur_asid = seg.asid
        n_inv = 0
        if seg.flush_all or seg.flush_asid:
            def kill(valid, asid_arr):
                mask = np.asarray(valid)
                if not seg.flush_all:
                    mask = mask & (asid_arr == seg.asid)
                return mask

            k2 = kill(l2_k != INVALID, l2_asid)
            n_inv += int(k2.sum())
            cov -= int(l2_contig[k2].sum())
            l2_k[k2] = INVALID
            k1 = kill(l1_tag >= 0, l1_asid)
            n_inv += int(k1.sum())
            l1_tag[k1] = -1
            kh = kill(l1h_tag >= 0, l1h_asid)
            n_inv += int(kh.sum())
            l1h_tag[kh] = -1
            kr = kill(rmm_len > 0, rmm_asid)
            n_inv += int(kr.sum())
            cov -= int(rmm_len[kr].sum())
            rmm_start[kr] = -1
            rmm_len[kr] = 0
            rmm_ppn[kr] = -1
            kc = kill(cl_bm != 0, cl_asid)
            n_inv += int(kc.sum())
            cl_bm[kc] = 0
            kt = kill(ctlb_tag >= 0, ctlb_asid)
            n_inv += int(kt.sum())
            cov -= int(kt.sum())
            ctlb_tag[kt] = -1
            n_shoot += n_inv
        if seg.switch:
            cycles += LAT_CTX_SWITCH
        if on_event is not None and (seg.switch or n_inv):
            on_event(dict(t=t, kind="switch", asid=seg.asid,
                          invalidated=n_inv))

    for t in range(T):
        while seg_i + 1 < len(segs) and t == segs[seg_i + 1].lo:
            seg_i += 1
            seg = segs[seg_i]
            if seg.switch or seg.flush_all or seg.flush_asid \
                    or seg.asid != cur_asid:
                ctx_switch(t, seg)
            if seg.dirty is not None:
                # the dirty array fixes the vpn range it covers (nested
                # worlds union dirty sets over ALL guests, whose footprint
                # may exceed the scheduled guest's)
                shootdown(t, seg.dirty, int(seg.dirty.shape[0]))
        seg = segs[seg_i]
        m = seg.m
        n_pages = m.n_pages
        vpn = int(trace[t])
        ppn_true = int(m.ppn[vpn])
        frec = seg.fill[vpn]
        fill_tag, fill_k, fill_contig, fill_ppn = (int(frec[0]), int(frec[1]),
                                                   int(frec[2]), int(frec[3]))
        fill_aux = int(frec[4])

        # ---------------- L1 ---------------------------------------------
        s1 = vpn & (L1_SETS - 1)
        hits1 = (l1_tag[s1] == vpn) & (l1_asid[s1] == cur_asid)
        l1_hit = bool(hits1.any())
        l1_way = int(np.argmax(hits1))
        hv = vpn >> 9
        s1h = hv & (L1H_SETS - 1)
        hitsh = (l1h_tag[s1h] == hv) & (l1h_asid[s1h] == cur_asid)
        l1h_hit = is_thp and bool(hitsh.any())
        l1h_way = int(np.argmax(hitsh))
        l1_served = l1_hit or l1h_hit
        l1_out = (int(l1_ppn[s1, l1_way]) if l1_hit
                  else int(l1h_ppn[s1h, l1h_way]) + (vpn & 511))

        # ---------------- L2 probes --------------------------------------
        s2 = (vpn >> k_hat) & set_mask
        tags = l2_tag[s2]
        kcls = l2_k[s2]
        contig = l2_contig[s2]
        pbase = l2_ppn[s2]
        valid = (kcls != INVALID) & (l2_asid[s2] == cur_asid)
        probes_used = 0
        pred_ok = 0
        hit_k = -1
        coal_hit = False
        coal_ppn = -1
        s2h = hv & set_mask
        if is_colt:
            diff = vpn - tags
            cover = valid & (diff >= 0) & (diff < contig)
            l2h = bool(cover.any())
            way = int(np.argmax(cover))
            reg_hit = l2h and int(contig[way]) == 1
            coal_hit = l2h and int(contig[way]) > 1
            l2_ppn_val = int(pbase[way]) + (vpn - int(tags[way]))
            touch_set, tw = s2, way
        elif is_thp:
            huge_ways = (l2_k[s2h] == HUGE) & (l2_tag[s2h] == hv) & \
                (l2_asid[s2h] == cur_asid)
            reg_ways = (kcls == REGULAR) & (tags == vpn) & valid
            huge_hit = bool(huge_ways.any())
            hw = int(np.argmax(huge_ways))
            rw = int(np.argmax(reg_ways))
            any_reg = bool(reg_ways.any())
            reg_hit = any_reg or huge_hit
            l2h = reg_hit
            l2_ppn_val = (int(pbase[rw]) if any_reg
                          else int(l2_ppn[s2h, hw]) + (vpn - (hv << 9)))
            touch_set = s2 if any_reg else s2h
            tw = rw if any_reg else hw
        elif is_subr:
            # subregion entry: tag is the aligned window base; the per-entry
            # bitmap (AUX plane) says which window pages it serves
            base = vpn & ~(SUBR_PAGES - 1)
            off = vpn & (SUBR_PAGES - 1)
            cover = valid & (kcls == KSUBR) & (tags == base) & \
                (((l2_aux[s2] >> off) & 1) == 1)
            l2h = bool(cover.any())
            way = int(np.argmax(cover))
            reg_hit = l2h and int(contig[way]) == 1
            coal_hit = l2h and int(contig[way]) > 1
            l2_ppn_val = int(pbase[way]) + off
            touch_set, tw = s2, way
        else:
            reg_ways = (kcls == REGULAR) & (tags == vpn) & valid
            reg_hit = bool(reg_ways.any())
            rw = int(np.argmax(reg_ways))
            if Ks:
                if spec.use_predictor:
                    order = [pred] + [k for k in Ks if k != pred]
                else:
                    order = list(Ks)
            else:
                order = []
            first_probe_k = order[0] if order else -1
            coal_way = 0
            for k_val in order:
                if not reg_hit and not coal_hit:
                    probes_used += 1
                    vk = vpn & ~((1 << k_val) - 1)
                    m_ways = ((kcls == k_val) & (tags == vk) & valid
                              & (contig > (vpn - vk)))
                    if bool(m_ways.any()):
                        coal_way = int(np.argmax(m_ways))
                        coal_ppn = int(pbase[coal_way]) + (vpn - vk)
                        hit_k = k_val
                        coal_hit = True
            l2h = reg_hit or coal_hit
            l2_ppn_val = int(pbase[rw]) if reg_hit else coal_ppn
            if spec.use_predictor and coal_hit and hit_k == first_probe_k:
                pred_ok = 1
            touch_set = s2
            tw = rw if reg_hit else coal_way

        # ---------------- side structures --------------------------------
        side_hit = False
        side_ppn = -1
        if has_rmm:
            d_r = vpn - rmm_start
            in_rng = (d_r >= 0) & (d_r < rmm_len) & (rmm_asid == cur_asid)
            if bool(in_rng.any()):
                side_hit = True
                sw = int(np.argmax(in_rng))
                side_ppn = int(rmm_ppn[sw]) + int(d_r[sw])
        cwd = vpn >> 3
        sc = cwd & (CLUS_SETS - 1)
        if has_clus:
            bit = (cl_bm[sc] >> (vpn & 7)) & 1
            c_ways = (cl_tag[sc] == cwd) & (bit == 1) & \
                (cl_asid[sc] == cur_asid)
            if bool(c_ways.any()):
                side_hit = True
                side_ppn = ppn_true
        ctlb_hit = False
        sct = vpn & (CTLB_SETS - 1)
        ctlb_way = 0
        if has_ctlb and not (l1_served or l2h):
            t_ways = (ctlb_tag[sct] == vpn) & (ctlb_asid[sct] == cur_asid)
            if bool(t_ways.any()):
                side_hit = ctlb_hit = True
                ctlb_way = int(np.argmax(t_ways))
                side_ppn = int(ctlb_ppn[sct, ctlb_way])

        walk = not (l1_served or l2h or side_hit)

        # ---------------- latency ----------------------------------------
        if l1_served:
            cyc = 0
        elif reg_hit:
            cyc = LAT_L2_REG
        elif coal_hit:
            cyc = LAT_COAL + LAT_EXTRA_PROBE * max(probes_used - 1, 0)
        elif side_hit:
            cyc = LAT_CTLB if ctlb_hit else LAT_COAL
        else:
            cyc = miss_chain + LAT_WALK

        # ---------------- L2 fill ----------------------------------------
        served_huge = is_thp and fill_k == HUGE
        dp_bypass = False
        if use_dead and walk:
            dp_idx = vpn & (DP_TABLE - 1)
            dp_bypass = int(dp_ctr[dp_idx]) == 0   # never re-referenced yet
            dp_ctr[dp_idx] = min(int(dp_ctr[dp_idx]) + 1, 3)
        evict = False
        if walk and not dp_bypass:
            fill_set = s2h if served_huge else s2
            valid_row = l2_k[fill_set] != INVALID
            score = np.where(valid_row, l2_lru[fill_set], NEG)
            victim = int(np.argmin(score))
            evict = bool(valid_row[victim])
            evicted = int(l2_contig[fill_set, victim]) if evict else 0
            if has_ctlb and evict:
                # Victima move: the evicted on-chip entry drops into the
                # cache-backed tier instead of dying (its own LRU victim
                # within the tag-indexed set pays the 1-page coverage loss)
                ev_tag = int(l2_tag[fill_set, victim])
                ev_ppn = int(l2_ppn[fill_set, victim])
                ev_asid = int(l2_asid[fill_set, victim])
                sct_v = ev_tag & (CTLB_SETS - 1)
                vrow_t = ctlb_tag[sct_v] >= 0
                victim_t = int(np.argmin(np.where(vrow_t, ctlb_lru[sct_v],
                                                  NEG)))
                cov += 1 - (1 if vrow_t[victim_t] else 0)
                ctlb_tag[sct_v, victim_t] = ev_tag
                ctlb_ppn[sct_v, victim_t] = ev_ppn
                ctlb_lru[sct_v, victim_t] = t
                ctlb_asid[sct_v, victim_t] = ev_asid
            l2_tag[fill_set, victim] = fill_tag
            l2_k[fill_set, victim] = fill_k
            l2_contig[fill_set, victim] = fill_contig
            l2_ppn[fill_set, victim] = fill_ppn
            l2_lru[fill_set, victim] = t
            l2_asid[fill_set, victim] = cur_asid
            l2_aux[fill_set, victim] = fill_aux
            cov += fill_contig - evicted
        elif l2h and not l1_served:
            l2_lru[touch_set, tw] = t
        if ctlb_hit:
            ctlb_lru[sct, ctlb_way] = t

        # ---------------- side fills -------------------------------------
        if has_rmm:
            rs_v = int(m.run_start[vpn])
            rl_v = int(m.run_len[vpn])
            if walk:
                vrm = rmm_len > 0
                victim_r = int(np.argmin(np.where(vrm, rmm_lru, NEG)))
                ev_len = int(rmm_len[victim_r]) if vrm[victim_r] else 0
                rmm_start[victim_r] = rs_v
                rmm_len[victim_r] = rl_v
                rmm_ppn[victim_r] = int(
                    m.ppn[min(max(rs_v, 0), n_pages - 1)])
                rmm_lru[victim_r] = t
                rmm_asid[victim_r] = cur_asid
                cov += rl_v - ev_len
            elif side_hit:
                rmm_lru[sw] = t
        if has_clus:
            bm = int(seg.clus[vpn])
            if walk and bm != (1 << (vpn & 7)):
                vrow = cl_bm[sc] != 0
                victim_c = int(np.argmin(np.where(vrow, cl_lru[sc], NEG)))
                cl_tag[sc, victim_c] = cwd
                cl_bm[sc, victim_c] = bm
                cl_lru[sc, victim_c] = t
                cl_asid[sc, victim_c] = cur_asid
            elif side_hit:
                hit_cway = int(np.argmax((cl_tag[sc] == cwd)
                                         & (cl_asid[sc] == cur_asid)))
                cl_lru[sc, hit_cway] = t

        # ---------------- L1 fills ---------------------------------------
        if is_thp:
            if not l1_served and served_huge:
                vrh = l1h_tag[s1h] >= 0
                vich = int(np.argmin(np.where(vrh, l1h_lru[s1h], NEG)))
                l1h_tag[s1h, vich] = hv
                l1h_ppn[s1h, vich] = fill_ppn
                l1h_lru[s1h, vich] = t
                l1h_asid[s1h, vich] = cur_asid
            if l1_served and bool(hitsh.any()) and not l1_hit:
                l1h_lru[s1h, l1h_way] = t
            do1 = not l1_served and not served_huge
        else:
            do1 = not l1_served
        if do1:
            vr1 = l1_tag[s1] >= 0
            vic1 = int(np.argmin(np.where(vr1, l1_lru[s1], NEG)))
            l1_tag[s1, vic1] = vpn
            l1_ppn[s1, vic1] = ppn_true
            l1_lru[s1, vic1] = t
            l1_asid[s1, vic1] = cur_asid
        if l1_hit:
            l1_lru[s1, l1_way] = t

        # ---------------- predictor update -------------------------------
        if spec.use_predictor and Ks:
            if coal_hit:
                pred = hit_k
            elif walk and fill_k >= 0:
                pred = fill_k

        # ---------------- accounting -------------------------------------
        n_l1 += l1_served
        n_reg += reg_hit and not l1_served
        n_coal += (coal_hit or side_hit) and not reg_hit and not l1_served
        n_walk += walk
        if coal_hit and not l1_served:
            n_probe += probes_used
        if not l1_served:
            n_pred += pred_ok
        if dp_bypass:
            n_pred += 1            # dead-protect: bypassed fills ride C_PRED
        cycles += cyc
        slot = min(t // sample_every, N_COV_SAMPLES - 1)
        if t % sample_every == sample_every - 1:
            cov_samples[slot] = cov

        out[t] = (l1_out if l1_served
                  else l2_ppn_val if l2h
                  else side_ppn if side_hit
                  else ppn_true)
        if on_step is not None:
            level = ("l1" if l1_served else "l2reg" if reg_hit
                     else "l2coal" if coal_hit else "side" if side_hit
                     else "walk")
            on_step(dict(t=t, vpn=vpn, asid=cur_asid, level=level,
                         ppn=int(out[t]), walk=bool(walk),
                         evict=bool(evict), probes=int(probes_used),
                         cycles=int(cyc)))

    return SimResult(
        name=spec.name, accesses=T, l1_hits=int(n_l1),
        l2_regular_hits=int(n_reg), l2_coalesced_hits=int(n_coal),
        walks=int(n_walk), aligned_probes=int(n_probe),
        pred_correct=int(n_pred), cycles=int(cycles),
        coverage_mean=float(np.mean(cov_samples)), ppn=out,
        shootdowns=int(n_shoot))
