"""Algorithm 3 — determining K (paper §3.3, Table 1).

Given the OS contiguity histogram (chunk size → frequency), greedily choose
the alignment set K that covers the most contiguous pages, stopping once the
selected alignments cover ``theta`` (default 0.9) of the total contiguity or
``psi`` (default 4) alignments have been chosen.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Mapping, Tuple

# Table 1: contiguity-chunk size range → matching alignment (k bits).
SIZE_RANGE_TABLE: Tuple[Tuple[int, int, int], ...] = (
    (2, 16, 4),
    (17, 64, 6),
    (65, 128, 7),
    (129, 256, 8),
    (257, 512, 9),
    (513, 1024, 10),
    (1025, 1 << 62, 11),
)

THETA_DEFAULT = 0.9
PSI_DEFAULT = 4


def f_alignment(size: int) -> int:
    """Table 1 mapping function f(): chunk size → alignment k.

    Chunks of size < 2 have no matching alignment (nothing to coalesce) and
    return -1; Algorithm 3 skips them.
    """
    if size < 2:
        return -1
    for lo, hi, k in SIZE_RANGE_TABLE:
        if lo <= size <= hi:
            return k
    raise AssertionError("unreachable")


def determine_k(contiguity_histogram: Mapping[int, int] | Iterable[Tuple[int, int]],
                theta: float = THETA_DEFAULT,
                psi: int = PSI_DEFAULT) -> List[int]:
    """Algorithm 3.

    ``contiguity_histogram``: (size, freq) pairs — e.g. ``{16: 33}`` means a
    contiguity chunk of 16 pages occurs 33 times in the mapping.

    Returns K sorted descending (the probe order of Algorithms 1–2).

    Coverage of alignment k accumulates ``size * freq`` over all chunks whose
    matching alignment (Table 1) is k.  Size-1 chunks have nothing to coalesce
    and are excluded from both the weights and the total (the paper's
    pseudo-code leaves f(1) undefined; counting uncoalescible pages in the
    total would make theta unreachable on fragmented mappings).
    """
    items = (contiguity_histogram.items()
             if hasattr(contiguity_histogram, "items")
             else contiguity_histogram)
    alignment_weight: Dict[int, int] = {}
    total_contiguity = 0
    for size, freq in items:
        if size < 2 or freq <= 0:
            continue
        coverage = size * freq
        total_contiguity += coverage
        k = f_alignment(size)
        alignment_weight[k] = alignment_weight.get(k, 0) + coverage

    K: List[int] = []
    if total_contiguity == 0:
        return K
    sum_coverage = 0
    # descending by coverage; ties broken toward larger k (more reach)
    ranked = sorted(alignment_weight.items(), key=lambda kv: (-kv[1], -kv[0]))
    # Algorithm 3 stops once the selected alignments cover >= theta of the
    # total contiguity (the paper's "covers more than 90%" is inclusive at
    # the boundary: reaching exactly theta is enough).  The epsilon keeps
    # a histogram whose coverage is *exactly* theta from being pushed past
    # the boundary by the floating-point rounding of ``total * theta``.
    threshold = total_contiguity * theta * (1.0 - 1e-12)
    for k, coverage in ranked:
        K.append(k)
        sum_coverage += coverage
        if sum_coverage >= threshold:
            break
        if len(K) >= psi:
            break
    return sorted(K, reverse=True)
