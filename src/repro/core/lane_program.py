"""The shared per-lane TLB program: one definition, two backends.

The batched sweep engine runs every ``(method, mapping, trace)`` cell as a
*lane* of one compiled program.  This module is the single source of truth
for what a lane **is**, consumed by both execution backends:

* the XLA backend (:mod:`repro.core.sweep`) — a time-blocked
  ``jax.lax.scan`` whose body advances every lane by ``TB`` trace steps;
* the Pallas backend (:mod:`repro.kernels.tlb_sweep`) — a kernel whose grid
  maps lanes to program instances and keeps all TLB state in scratch for
  the whole trace.

Three layers live here:

1. **Packing** (:func:`pack_lanes`, :func:`init_batched_state`): dedup
   worlds/traces, precompute the per-``(world, epoch)`` map/fill/cluster
   records, pad every method onto one array layout, and bucket shapes
   (power-of-two trace lengths with a small floor, lane counts padded to a
   shared bucket and to a device multiple) so distinct sweeps reuse
   compiled executables.
2. **The step** (:func:`step_access`): one translation of one lane — the
   union of every method kind's datapath (L1, dual-probe THP, COLT window
   cover, the K-aligned probe chain with predictor, RMM ranges, clustered
   side-TLB, Algorithm-1 fills, LRU, latency and counters), selected per
   lane by data.  :func:`shoot_lane` is the epoch-turnover translation
   coherence pass.  Both operate on a plain dict of arrays for ONE lane;
   backends decide where that state lives (scan carry vs kernel scratch).
3. **The block plan** (:func:`build_block_plan`): the static timeline both
   backends execute — every epoch segment padded to a multiple of the block
   size, one shootdown flag per segment-entry block.  Block boundaries are
   an execution detail: results are bit-exact for every block size
   (enforced by ``tests/test_backends.py``).

Bit-exactness contract: for any packing, any block size and either backend,
every lane must match :func:`repro.core.simulator.run_method` /
:func:`~repro.core.simulator.run_method_dynamic` counter-for-counter.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from .page_table import (DynamicMapping, Mapping, MultiTenantMapping,
                         NestedMapping, ParityWorld, cluster_bitmap,
                         huge_page_backed, next_pow2 as _next_pow2)
from .plane_layout import (FILL_REC_WIDTH, MAP_REC_WIDTH, PLANE_FIELDS,
                           PLANE_WIDTH)
from .simulator import (CLUS_SETS, CLUS_WAYS, CTLB_SETS, CTLB_WAYS, DP_TABLE,
                        HUGE, INVALID, KSUBR, L1_SETS, L1_WAYS,
                        L1H_SETS, L1H_WAYS, LAT_COAL, LAT_CTLB,
                        LAT_CTX_SWITCH, LAT_EXTRA_PROBE, LAT_INVALIDATE,
                        LAT_L2_REG, LAT_SHOOTDOWN, LAT_WALK, N_COV_SAMPLES,
                        NEG, REGULAR, RMM_ENTRIES, SUBR_PAGES, MethodSpec,
                        miss_chain_cycles)

BIG = 2**30  # victim score for padded ways: never evictable

# Shape buckets: pad so repeated sweeps of similar size reuse the same
# compiled executable instead of specializing on exact lane/trace/page
# counts.  Traces are padded to the next power of two with a small floor
# (a ~200-step smoke trace costs a 256-step scan, not a 4096-step one);
# lane counts are padded to the next power of two up to LANE_SHARE_MAX and
# to multiples of LANE_BUCKET beyond it, then to a device multiple so the
# pmap path always shards.  K slots are padded to a fixed minimum so
# sweeps with |K| = 1..KMIN_SLOTS share one executable (inert ``-1``
# classes probe inertly).
TRACE_FLOOR = 256
LANE_FLOOR = 32
LANE_BUCKET = 32
LANE_SHARE_MAX = 64
KMIN_SLOTS = 4
# fill-record counts vary the most across suites (one record per distinct
# (world, epoch, fill profile)); a higher floor folds the common bench
# sizes onto {32, 64}
FILL_REC_FLOOR = 32

# packed-field indices, derived from the one layout table
# (:mod:`repro.core.plane_layout`).  Every structure carries the ASID its
# entry was filled under as its last non-sidecar field: probes require an
# ASID match (trivially true on single-address-space worlds, where
# everything is ASID 0), and the context-switch pass
# (:func:`switch_lane`) clears by it.  L2 AUX holds per-kind sidecar
# data: the subregion contiguity bitmap (bit j = page tag+j shares the
# entry's VA->PA delta); 0 for other kinds.
TAG, KCLS, CONTIG, PPN, LRU, L2_ASID, AUX = range(PLANE_WIDTH["l2"])
assert PLANE_FIELDS["l2"] == ("tag", "kcls", "contig", "ppn", "lru",
                              "asid", "aux")
# dirty record: [P+1] = prefix sum of the epoch's dirty-vpn bitmap
# counters: [9] = l1_hits, reg_hits, coal_hits, walks, probes, pred_correct,
#                 cycles, cov, shootdowns
N_COUNTERS = 9
(C_L1, C_REG, C_COAL, C_WALK, C_PROBE, C_PRED, C_CYC, C_COV,
 C_SHOOT) = range(9)

# The per-lane scalars consumed by step_access/shoot_lane (plus the
# ``kvals`` vector).  Both backends build their lane dicts from this ONE
# tuple — sweep.py slices the packed lanes with it, the Pallas ops pack
# their params row from it — so adding a lane parameter is a one-list
# change.
STEP_KEYS = ("kvals", "use_pred", "is_colt", "is_thp", "has_rmm",
             "has_cluster", "set_mask", "n_ways", "k_hat", "miss_chain",
             "sample_every", "is_subr", "has_ctlb", "use_dead", "coh_hw")


TRACE_LINEAR_BUCKET = 1 << 14


def bucket_trace_len(n: int) -> int:
    """Trace-length bucket: power of two with a small floor up to 16k (a
    ~200-step smoke trace pays a 256-step scan, not a 4096-step one), then
    multiples of 16k — pow2 padding would cost up to +100% inert steps on
    the 120–150k-access paper traces, where run time dominates."""
    if n <= TRACE_LINEAR_BUCKET:
        return max(TRACE_FLOOR, _next_pow2(n))
    return -(-n // TRACE_LINEAR_BUCKET) * TRACE_LINEAR_BUCKET


def bucket_lane_count(n: int, device_count: int = 1) -> int:
    """Lane-count bucket, always a multiple of the device count (so the
    pmap path shards every batch).  Bench-sized batches (>= 8 cells) pad to
    {LANE_FLOOR, LANE_SHARE_MAX} power-of-two buckets so the common suite
    sizes share one compiled executable; beyond LANE_SHARE_MAX they are
    chunked by run_sweep, and the remainder chunks land back in these
    buckets.  Tiny batches (a user comparing a handful of specs) stay
    near-exact — inert pad lanes are cheap per step but not free over a
    100k-step trace."""
    if n >= 8:
        L = max(_next_pow2(n), LANE_FLOOR) if n <= LANE_SHARE_MAX \
            else -(-n // LANE_BUCKET) * LANE_BUCKET
    else:
        L = max(_next_pow2(n), 4)
    if device_count > 1:
        L = -(-L // device_count) * device_count
    return L


# Record-count padding budget: stacks are padded to power-of-two record
# counts (with a floor) so sweeps of similar shape share one compiled
# executable — the big cold-time lever for smoke/CI tiers — but never at
# more than this many padded bytes per stack, so paper-scale footprints
# (where run time dominates anyway) degrade gracefully to exact counts.
REC_FLOOR = 8
REC_PAD_BUDGET = 64 << 20


def _pad_stack(recs: List[np.ndarray], floor: int = REC_FLOOR,
               budget: int = REC_PAD_BUDGET) -> np.ndarray:
    """Stack ``recs`` padded with zero records to a shared count bucket."""
    n = len(recs)
    b = max(floor, _next_pow2(n))
    rec_bytes = recs[0].nbytes
    while b > n and b * rec_bytes > budget:
        b //= 2
    b = max(b, n)
    pad = [np.zeros_like(recs[0])] * (b - n)
    return np.stack(recs + pad)


# ---------------------------------------------------------------------------
# Precomputed per-vpn records (fill policy is trace-independent)
# ---------------------------------------------------------------------------


def _map_record(m: Mapping, P: int) -> np.ndarray:
    """[P, 4] int32: ppn, run_start, run_len, ppn[run_start] (RMM fill)."""
    n = m.n_pages
    rec = np.zeros((P, MAP_REC_WIDTH), np.int32)
    rec[:, 0] = -1
    rec[:n, 0] = m.ppn
    rec[:n, 1] = m.run_start
    rec[:n, 2] = m.run_len
    rec[:n, 3] = m.ppn[np.clip(m.run_start, 0, n - 1)]
    return rec


def _fill_profile_key(spec: MethodSpec):
    if spec.kind in ("kaligned", "anchor"):
        return ("ka", spec.K)
    if spec.kind in ("colt", "thp"):
        return (spec.kind,)
    if spec.kind == "subregion":
        return ("subr",)
    return ("reg",)


def _fill_profile(m: Mapping, key, P: int) -> np.ndarray:
    """[P, 5] int32 fill record (tag, k, contig, ppn, aux): what
    Algorithm 1 / COLT / THP / the subregion policy / the regular policy
    would install on a walk at each vpn."""
    n = m.n_pages
    vpn = np.arange(n, dtype=np.int64)
    ppn = m.ppn
    rs, rl = m.run_start, m.run_len

    def contig_at(v):
        v = np.clip(v, 0, n - 1)
        return np.where(ppn[v] >= 0, rs[v] + rl[v] - v, 0)

    tag = vpn.copy()
    kcls = np.full(n, REGULAR, np.int64)
    contig = np.ones(n, np.int64)
    fppn = ppn.copy()
    aux = np.zeros(n, np.int64)
    if key[0] == "ka":
        chosen = np.zeros(n, bool)
        for k in key[1]:                    # descending; first cover wins
            vk = vpn & ~((1 << k) - 1)
            sc = np.minimum(contig_at(vk), 1 << k)
            take = (sc > (vpn - vk)) & ~chosen
            tag = np.where(take, vk, tag)
            kcls = np.where(take, k, kcls)
            contig = np.where(take, sc, contig)
            fppn = np.where(take, ppn[np.clip(vk, 0, n - 1)], fppn)
            chosen |= take
    elif key[0] == "colt":
        w8 = vpn & ~np.int64(7)
        re = rs + rl
        tag = np.maximum(rs, w8)
        contig = np.maximum(np.minimum(re, w8 + 8) - tag, 1)
        kcls = np.where(contig > 1, 3, REGULAR)
        fppn = ppn[np.clip(tag, 0, n - 1)]
    elif key[0] == "thp":
        huge = huge_page_backed(m)
        hv = vpn >> 9
        tag = np.where(huge, hv, vpn)
        kcls = np.where(huge, HUGE, REGULAR)
        contig = np.where(huge, 512, 1)
        fppn = ppn[np.clip(np.where(huge, hv << 9, vpn), 0, n - 1)]
    elif key[0] == "subr":
        # subregion entries: one entry covers the aligned SUBR_PAGES
        # window around vpn; bit j of the bitmap says page base+j shares
        # this vpn's VA->PA delta (so base_ppn + j translates it).
        base = vpn & ~np.int64(SUBR_PAGES - 1)
        delta = ppn - vpn
        bitmap = np.zeros(n, np.int64)
        for j in range(SUBR_PAGES):
            pj = np.clip(base + j, 0, n - 1)
            ok = (base + j < n) & (ppn[pj] >= 0) & (ppn[pj] - pj == delta)
            bitmap |= ok.astype(np.int64) << j
        mapped = ppn >= 0
        popc = sum((bitmap >> j) & 1 for j in range(SUBR_PAGES))
        tag = np.where(mapped, base, tag)
        kcls = np.where(mapped, KSUBR, kcls)
        contig = np.where(mapped, popc, contig)
        fppn = np.where(mapped, ppn - (vpn - base), fppn)
        aux = np.where(mapped, bitmap, 0)

    rec = np.zeros((P, FILL_REC_WIDTH), np.int32)
    rec[:n, 0] = tag
    rec[:n, 1] = kcls
    rec[:n, 2] = contig
    rec[:n, 3] = fppn
    rec[:n, 4] = aux
    rec[n:, 1] = REGULAR
    return rec


# ---------------------------------------------------------------------------
# Lane packing
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _WorldPlan:
    """One world decomposed into its schedule-segment sequence.

    ``sources`` are the distinct Mappings records are built from (epoch
    snapshots of a dynamic world; tenant address spaces of a multi-tenant
    one; deduped composed guest-over-host views of a nested one; the
    single mapping of a static one).  Per schedule segment ``i``:
    ``src_idx[i]`` is the live source, ``asids[i]`` the live ASID,
    ``switch[i]`` whether entering it changes the address space,
    ``recycled[i]`` whether its ASID was last held by a different tenant,
    and ``dirty[i]`` the vpn dirty bitmap the coherence pass must sweep on
    entering it (``None`` when nothing turned stale — dynamic worlds dirty
    by guest vpn, nested worlds by composed diff so host-level remaps
    surface too).  ``parity[i]`` marks segments spliced in by a
    :class:`~repro.core.page_table.ParityWorld` fault: their dirty set is
    a soft error, not a remap, so lanes whose spec runs ``par_policy=
    "ecc"`` (in-place correction) skip the invalidation pass for exactly
    those segments while remap coherence stays untouched.
    """

    sources: Tuple[Mapping, ...]
    bounds: Tuple[int, ...]
    src_idx: Tuple[int, ...]
    asids: Tuple[int, ...]
    switch: Tuple[bool, ...]
    recycled: Tuple[bool, ...]
    dirty: Tuple[Optional[np.ndarray], ...]
    parity: Tuple[bool, ...]


def _world_plan(world) -> _WorldPlan:
    if isinstance(world, ParityWorld):
        p = _world_plan(world.base)
        bounds = list(p.bounds)
        src_idx = list(p.src_idx)
        asids = list(p.asids)
        switch = list(p.switch)
        recycled = list(p.recycled)
        dirty = list(p.dirty)
        parity = [False] * len(bounds)
        for t, vpn in world.faults:
            # the segment live at fault time; collisions with base bounds
            # are excluded by the ParityWorld constructor
            i = int(np.searchsorted(np.asarray(bounds), t,
                                    side="right") - 1)
            d = np.zeros(p.sources[src_idx[i]].n_pages, bool)
            d[vpn] = True
            bounds.insert(i + 1, t)
            src_idx.insert(i + 1, src_idx[i])
            asids.insert(i + 1, asids[i])
            switch.insert(i + 1, False)
            recycled.insert(i + 1, False)
            dirty.insert(i + 1, d)
            parity.insert(i + 1, True)
        return _WorldPlan(p.sources, tuple(bounds), tuple(src_idx),
                          tuple(asids), tuple(switch), tuple(recycled),
                          tuple(dirty), tuple(parity))
    if isinstance(world, DynamicMapping):
        n = world.n_epochs
        dirty = (None,) + tuple(
            world.dirty(e) if world.dirty_count(e) else None
            for e in range(1, n))
        return _WorldPlan(world.epochs, world.boundaries, tuple(range(n)),
                          (0,) * n, (False,) * n, (False,) * n, dirty,
                          (False,) * n)
    if isinstance(world, MultiTenantMapping):
        n = world.n_segments
        return _WorldPlan(world.tenants, world.boundaries, world.tenant_ids,
                          world.asids,
                          tuple(world.switches(s) for s in range(n)),
                          world.recycled, (None,) * n, (False,) * n)
    if isinstance(world, NestedMapping):
        segs = world.plan_segments()
        sources: List[Mapping] = []
        src_of: Dict[int, int] = {}
        src_idx: List[int] = []
        for ns in segs:
            if id(ns.mapping) not in src_of:      # composed views memoized
                src_of[id(ns.mapping)] = len(sources)
                sources.append(ns.mapping)
            src_idx.append(src_of[id(ns.mapping)])
        n = len(segs)
        return _WorldPlan(tuple(sources), tuple(ns.lo for ns in segs),
                          tuple(src_idx), tuple(ns.asid for ns in segs),
                          tuple(ns.switch for ns in segs),
                          tuple(ns.recycled for ns in segs),
                          tuple(ns.dirty for ns in segs), (False,) * n)
    return _WorldPlan((world,), (0,), (0,), (0,), (False,), (False,),
                      (None,), (False,))


def pack_lanes(cells: Sequence["SweepCellLike"], device_count: int = 1):
    """Dedup worlds/traces/fill-profiles; pack per-lane params to arrays.

    Every world is a schedule-segment *sequence* (a static ``Mapping`` is
    one segment; a :class:`~repro.core.page_table.DynamicMapping` one per
    epoch; a :class:`~repro.core.page_table.MultiTenantMapping` one per
    scheduling quantum); map/fill/cluster records are built per ``(world,
    source mapping)`` and lanes carry a per-segment record index, so
    static, dynamic and multi-tenant lanes share one compiled program (a
    tenant scheduled many times reuses ONE record set).  The segment
    grid — the sorted union of every lane's boundaries — is returned as a
    static tuple; a batch with no segmented lane collapses to one segment
    and never runs the shootdown/switch pass.  Returns ``(lanes, stacks,
    (L, max_sets, max_ways), seg_bounds)``.
    """
    worlds: List = []
    world_index: Dict[int, int] = {}
    traces: List[np.ndarray] = []
    trace_index: Dict[int, int] = {}
    for c in cells:
        if id(c.mapping) not in world_index:
            world_index[id(c.mapping)] = len(worlds)
            worlds.append(c.mapping)
        if id(c.trace) not in trace_index:
            trace_index[id(c.trace)] = len(traces)
            traces.append(c.trace)

    plans: Dict[int, _WorldPlan] = {w: _world_plan(m)
                                    for w, m in enumerate(worlds)}

    P = _next_pow2(max(m.n_pages for p in plans.values()
                       for m in p.sources))
    T = bucket_trace_len(max(t.shape[0] for t in traces))

    # map records: one per (world, source mapping)
    map_recs: List[np.ndarray] = []
    map_rec_id: Dict[Tuple[int, int], int] = {}
    for w, p in plans.items():
        for e, m in enumerate(p.sources):
            map_rec_id[(w, e)] = len(map_recs)
            map_recs.append(_map_record(m, P))

    # fill records: one per (world, source, fill profile)
    fill_recs: List[np.ndarray] = []
    fill_rec_id: Dict[Tuple[int, int, tuple], int] = {}
    for c in cells:
        w = world_index[id(c.mapping)]
        key = _fill_profile_key(c.spec)
        for e, m in enumerate(plans[w].sources):
            fk = (w, e, key)
            if fk not in fill_rec_id:
                fill_rec_id[fk] = len(fill_recs)
                fill_recs.append(_fill_profile(m, key, P))

    # cluster bitmaps: one per (world, source).  The stack is always P wide
    # (not 1) so suites with and without cluster lanes share an executable;
    # the budget guard below shrinks it back for paper-scale footprints.
    need_clus = any(c.spec.side == "cluster" for c in cells)
    clus_wide = need_clus or P * 4 * REC_FLOOR <= REC_PAD_BUDGET
    clus_recs: List[np.ndarray] = [np.zeros(P if clus_wide else 1, np.int32)]
    clus_rec_id: Dict[Tuple[int, int], int] = {}
    if need_clus:
        for c in cells:
            if c.spec.side != "cluster":
                continue
            w = world_index[id(c.mapping)]
            for e, m in enumerate(plans[w].sources):
                if (w, e) not in clus_rec_id:
                    rec = np.zeros(P, np.int32)
                    rec[: m.n_pages] = cluster_bitmap(m)
                    clus_rec_id[(w, e)] = len(clus_recs)
                    clus_recs.append(rec)

    # dirty records (prefix sums): one per (world, segment) whose plan
    # carries a dirty bitmap (dynamic epochs e >= 1 with churn; nested
    # segments whose composed view diverged at either level)
    dirty_recs: List[np.ndarray] = [np.zeros(P + 1, np.int32)]
    dirty_rec_id: Dict[Tuple[int, int], int] = {}
    for w, p in plans.items():
        for e, d in enumerate(p.dirty):
            if d is None:
                continue
            dc = np.zeros(P + 1, np.int32)
            nd = min(int(d.shape[0]), P)   # beyond P no entry can cover
            np.cumsum(d[:nd], out=dc[1: nd + 1])
            dc[nd + 1:] = dc[nd]
            dirty_rec_id[(w, e)] = len(dirty_recs)
            dirty_recs.append(dc)

    n_tr = len(traces)
    if n_tr * T * 4 * 2 <= REC_PAD_BUDGET:
        n_tr = max(REC_FLOOR, _next_pow2(n_tr))
    trace_stack = np.zeros((n_tr, T), np.int32)
    for i, t in enumerate(traces):
        trace_stack[i, : t.shape[0]] = t

    # segment grid: union of all schedule boundaries, static per compile
    grid = sorted({int(b) for w in range(len(worlds))
                   for b in plans[w].bounds[1:]})
    seg_bounds = tuple([0] + grid + [T])
    n_segs = len(seg_bounds) - 1

    L = bucket_lane_count(len(cells), device_count)
    max_sets = max(c.spec.l2_sets for c in cells)
    max_ways = max(c.spec.l2_ways for c in cells)
    maxk = max([len(c.spec.K) for c in cells] + [KMIN_SLOTS])

    lanes = dict(
        is_colt=np.zeros(L, bool), is_thp=np.zeros(L, bool),
        is_subr=np.zeros(L, bool), has_ctlb=np.zeros(L, bool),
        use_dead=np.zeros(L, bool), coh_hw=np.zeros(L, bool),
        has_rmm=np.zeros(L, bool),
        has_cluster=np.zeros(L, bool), use_pred=np.zeros(L, bool),
        kvals=np.full((L, maxk), -1, np.int32),
        set_mask=np.zeros(L, np.int32), n_ways=np.ones(L, np.int32),
        k_hat=np.zeros(L, np.int32), miss_chain=np.zeros(L, np.int32),
        pred0=np.zeros(L, np.int32), asid0=np.zeros(L, np.int32),
        seg_map=np.zeros((L, n_segs), np.int32),
        seg_fill=np.zeros((L, n_segs), np.int32),
        seg_clus=np.zeros((L, n_segs), np.int32),
        seg_shoot=np.zeros((L, n_segs), bool),
        seg_dirty=np.zeros((L, n_segs), np.int32),
        seg_asid=np.zeros((L, n_segs), np.int32),
        seg_switch=np.zeros((L, n_segs), bool),
        seg_fall=np.zeros((L, n_segs), bool),
        seg_fasid=np.zeros((L, n_segs), bool),
        trace_id=np.zeros(L, np.int32), t_real=np.zeros(L, np.int32),
        sample_every=np.ones(L, np.int32),
    )
    for i, c in enumerate(cells):
        s = c.spec
        w = world_index[id(c.mapping)]
        p = plans[w]
        key = _fill_profile_key(s)
        lanes["is_colt"][i] = s.kind == "colt"
        lanes["is_thp"][i] = s.kind == "thp"
        lanes["is_subr"][i] = s.kind == "subregion"
        lanes["has_ctlb"][i] = s.kind == "cache-tlb"
        lanes["use_dead"][i] = s.kind == "dead-protect"
        lanes["coh_hw"][i] = s.coh_policy == "hw-coherence"
        lanes["has_rmm"][i] = s.side == "rmm"
        lanes["has_cluster"][i] = s.side == "cluster"
        lanes["use_pred"][i] = s.use_predictor
        lanes["kvals"][i, : len(s.K)] = s.K
        lanes["set_mask"][i] = s.l2_sets - 1
        lanes["n_ways"][i] = s.l2_ways
        lanes["k_hat"][i] = s.index_shift
        lanes["miss_chain"][i] = miss_chain_cycles(s)
        lanes["pred0"][i] = s.K[0] if s.K else 0
        lanes["asid0"][i] = p.asids[0]
        lanes["trace_id"][i] = trace_index[id(c.trace)]
        lanes["t_real"][i] = c.trace.shape[0]
        lanes["sample_every"][i] = max(c.trace.shape[0] // N_COV_SAMPLES, 1)
        for seg in range(n_segs):
            lo = seg_bounds[seg]
            e = int(np.searchsorted(p.bounds, lo, side="right") - 1)
            src = p.src_idx[e]
            lanes["seg_map"][i, seg] = map_rec_id[(w, src)]
            lanes["seg_fill"][i, seg] = fill_rec_id[(w, src, key)]
            lanes["seg_clus"][i, seg] = clus_rec_id.get((w, src), 0)
            lanes["seg_asid"][i, seg] = p.asids[e]
            # `turned` = this grid segment starts at one of the LANE's own
            # boundaries (the union grid also cuts at other lanes')
            turned = seg > 0 and e >= 1 and lo == p.bounds[e]
            # a parity-fault dirty set is a soft error, not a remap: ecc
            # lanes correct it in place and skip the invalidation pass
            ecc_skip = p.parity[e] and s.par_policy == "ecc"
            if turned and (w, e) in dirty_rec_id and not ecc_skip:
                lanes["seg_shoot"][i, seg] = True
                lanes["seg_dirty"][i, seg] = dirty_rec_id[(w, e)]
            if turned:
                lanes["seg_switch"][i, seg] = p.switch[e]
                lanes["seg_fall"][i, seg] = (p.switch[e]
                                             and s.ctx_policy == "flush")
                lanes["seg_fasid"][i, seg] = (p.recycled[e]
                                              and s.ctx_policy == "tag")
    stacks = dict(maps=_pad_stack(map_recs),
                  fills=_pad_stack(fill_recs, floor=FILL_REC_FLOOR),
                  clus=_pad_stack(clus_recs), dirty=_pad_stack(dirty_recs),
                  trace=trace_stack)
    return lanes, stacks, (L, max_sets, max_ways), seg_bounds


def needs_switch_pass(lanes) -> bool:
    """True when some lane's schedule actually switches, flushes or
    relabels an ASID — knowable statically at pack time.  Backends compile
    the segment-entry switch pass only then, so static and dynamic-only
    batches (whose flags are all False by construction) pay nothing for
    the multi-tenant machinery."""
    return bool(np.asarray(lanes["seg_switch"]).any()
                or np.asarray(lanes["seg_fall"]).any()
                or np.asarray(lanes["seg_fasid"]).any()
                or (np.asarray(lanes["seg_asid"])
                    != np.asarray(lanes["asid0"])[:, None]).any())


def init_batched_state(L: int, max_sets: int, max_ways: int, pred0,
                       asid0=None, *, with_ctlb: bool = False,
                       with_dp: bool = False):
    """``with_ctlb``/``with_dp`` size the cache-backed tier and the
    dead-entry counter table: full geometry when some lane in the batch
    is ``cache-tlb``/``dead-protect``, degenerate ``(1, 1)``-style arrays
    otherwise (the step indexes them shape-generically and its lane flags
    gate every read/write, so absent kinds pay one inert element)."""
    def packed(shape, init_tag):
        a = np.zeros(shape, np.int32)
        a[..., 0] = init_tag
        return a

    l2 = np.zeros((L, max_sets, max_ways, PLANE_WIDTH["l2"]), np.int32)
    l2[..., TAG] = -1
    l2[..., KCLS] = INVALID
    l2[..., PPN] = -1
    cs, cw = (CTLB_SETS, CTLB_WAYS) if with_ctlb else (1, 1)
    return dict(
        t=np.zeros(L, np.int32),
        l1=packed((L, L1_SETS, L1_WAYS, PLANE_WIDTH["l1"]), -1),
        l1h=packed((L, L1H_SETS, L1H_WAYS, PLANE_WIDTH["l1h"]), -1),
        l2=l2,
        rmm=packed((L, RMM_ENTRIES, PLANE_WIDTH["rmm"]), -1),
        clus=packed((L, CLUS_SETS, CLUS_WAYS, PLANE_WIDTH["clus"]), -1),
        ctlb=packed((L, cs, cw, PLANE_WIDTH["ctlb"]), -1),
        dp=np.zeros((L, DP_TABLE if with_dp else 1), np.int32),
        pred=np.asarray(pred0, np.int32).copy(),
        asid=(np.zeros(L, np.int32) if asid0 is None
              else np.asarray(asid0, np.int32).copy()),
        counters=np.zeros((L, N_COUNTERS), np.int32),
        cov_samples=np.zeros((L, N_COV_SAMPLES), np.int32),
    )


def _cond_set(arr, idx, value, pred):
    """In-place conditional point/row write (same trick as the oracle)."""
    old = arr[idx]
    return arr.at[idx].set(jnp.where(pred, value, old))


# ---------------------------------------------------------------------------
# The per-access step: the union of every kind's datapath, selected per lane
# ---------------------------------------------------------------------------


def step_access(lane, st, vpn, mrec, frec, bm, active):
    """One translation of ONE lane; returns ``(new_state, out_ppn)``.

    * ``lane`` — dict of per-lane scalars (+ the ``kvals`` vector);
    * ``st`` — the lane's state dict (packed L1/L1H/L2/RMM/CLUS arrays,
      ``t``, ``pred``, ``counters``, ``cov_samples``);
    * ``vpn`` — the accessed virtual page;
    * ``mrec``/``frec`` — the 4-wide map/fill records at ``vpn`` (gathered
      by the caller from the live epoch's record stack);
    * ``bm`` — the cluster bitmap word at ``vpn``;
    * ``active`` — False for padded steps: no state writes, no counters.

    The caller owns all gathers from the big record stacks — that is what
    lets the time-blocked backend hoist them to one bulk gather per block
    and the Pallas backend serve them from VMEM-resident per-segment
    blocks.
    """
    maxk = lane["kvals"].shape[0]
    kvals = lane["kvals"]
    use_pred = lane["use_pred"]
    is_colt, is_thp = lane["is_colt"], lane["is_thp"]
    is_subr = lane["is_subr"]
    is_generic = ~is_colt & ~is_thp & ~is_subr
    has_rmm, has_cluster = lane["has_rmm"], lane["has_cluster"]
    has_ctlb, use_dead = lane["has_ctlb"], lane["use_dead"]
    set_mask = lane["set_mask"]
    k_hat = lane["k_hat"]
    n_ways_total = st["l2"].shape[1]
    way_idx = jnp.arange(n_ways_total, dtype=jnp.int32)
    way_ok = way_idx < lane["n_ways"]

    def probe_order(pred_k):
        """[pred_k, remaining K desc] when predicting, else K as packed
        (padded positions stay -1 and probe inertly)."""
        order = [jnp.where(use_pred, pred_k, kvals[0])]
        not_pred = kvals != pred_k
        csum = jnp.cumsum(not_pred.astype(jnp.int32))
        for pos in range(1, maxk):
            sel = not_pred & (csum == pos)
            spec_k = jnp.where(sel.any(), kvals[jnp.argmax(sel)],
                               jnp.int32(-1))
            order.append(jnp.where(use_pred, spec_k, kvals[pos]))
        return order

    t = st["t"]
    ppn_true, rs_v, rl_v, rmm_fill_ppn = (mrec[0], mrec[1], mrec[2], mrec[3])
    fill_tag, fill_k, fill_contig, fill_ppn, fill_aux = (
        frec[0], frec[1], frec[2], frec[3], frec[4])
    new = dict(st)

    cur = st["asid"]

    # ---------------- L1 (regular + gated 2MB array) ----------------
    s1 = vpn & jnp.int32(L1_SETS - 1)
    l1row = st["l1"][s1]
    l1_ways_hit = (l1row[:, 0] == vpn) & (l1row[:, 3] == cur)
    l1_hit = l1_ways_hit.any()
    l1_way = jnp.argmax(l1_ways_hit)
    hv = vpn >> 9
    s1h = hv & jnp.int32(L1H_SETS - 1)
    l1hrow = st["l1h"][s1h]
    h_ways_hit = (l1hrow[:, 0] == hv) & (l1hrow[:, 3] == cur)
    l1h_hit = is_thp & h_ways_hit.any()
    l1h_way = jnp.argmax(h_ways_hit)
    l1_served = l1_hit | l1h_hit
    l1_out_ppn = jnp.where(l1_hit, l1row[l1_way, 1],
                           l1hrow[l1h_way, 1] + (vpn & 511))

    # ---------------- L2 probes (all kinds, selected) ---------------
    s2 = (vpn >> k_hat) & set_mask
    row = st["l2"][s2]                  # [W, 7]
    tags, kcls, contig, pbase = (row[:, TAG], row[:, KCLS],
                                 row[:, CONTIG], row[:, PPN])
    valid = (kcls != INVALID) & (row[:, L2_ASID] == cur)

    # colt branch
    diff = vpn - tags
    cover = valid & (diff >= 0) & (diff < contig)
    colt_hit = cover.any()
    colt_way = jnp.argmax(cover)
    colt_reg = colt_hit & (contig[colt_way] == 1)
    colt_coal = colt_hit & (contig[colt_way] > 1)
    colt_ppn = pbase[colt_way] + (vpn - tags[colt_way])

    # thp branch (dual-set probe on the same packed array)
    s2h = hv & set_mask
    row_h = st["l2"][s2h]
    huge_ways = (row_h[:, KCLS] == HUGE) & (row_h[:, TAG] == hv) & \
        (row_h[:, L2_ASID] == cur)
    reg_ways = (kcls == REGULAR) & (tags == vpn) & valid
    huge_hit = huge_ways.any()
    hw = jnp.argmax(huge_ways)
    rw = jnp.argmax(reg_ways)
    thp_reg = reg_ways.any() | huge_hit
    thp_ppn = jnp.where(reg_ways.any(), pbase[rw],
                        row_h[hw, PPN] + (vpn - (hv << 9)))
    thp_touch_ways = jnp.where(reg_ways.any(), reg_ways, huge_ways)
    thp_touch_set = jnp.where(reg_ways.any(), s2, s2h)

    # subregion branch: one entry covers the aligned SUBR_PAGES window;
    # the AUX bitmap says which offsets share the entry's VA->PA delta
    sub_base = vpn & ~jnp.int32(SUBR_PAGES - 1)
    sub_off = vpn & jnp.int32(SUBR_PAGES - 1)
    sub_cover = valid & (kcls == KSUBR) & (tags == sub_base) & \
        (((row[:, AUX] >> sub_off) & 1) == 1)
    subr_hit = sub_cover.any()
    subr_way = jnp.argmax(sub_cover)
    subr_reg = subr_hit & (contig[subr_way] == 1)
    subr_coal = subr_hit & (contig[subr_way] > 1)
    subr_ppn = pbase[subr_way] + sub_off

    # generic branch: regular probe + padded aligned-probe chain
    gen_reg = reg_ways.any()
    probes_used = jnp.int32(0)
    hit_k = jnp.int32(-1)
    gen_coal = jnp.bool_(False)
    coal_ppn = jnp.int32(-1)
    coal_way = jnp.int32(0)
    first_probe_k = jnp.int32(-1)
    for pos, k_val in enumerate(probe_order(st["pred"])):
        sh = jnp.maximum(k_val, 0)
        vk = jnp.where(k_val >= 0,
                       vpn & ~((jnp.int32(1) << sh) - 1),
                       jnp.int32(-10))
        m_ways = (kcls == k_val) & (tags == vk) & valid & \
                 (contig > (vpn - vk))
        m_hit = m_ways.any() & (k_val >= 0) & ~gen_reg & ~gen_coal
        probes_used = probes_used + jnp.where(
            ~gen_reg & ~gen_coal & (k_val >= 0), 1, 0)
        coal_ppn = jnp.where(m_hit, pbase[jnp.argmax(m_ways)]
                             + (vpn - vk), coal_ppn)
        coal_way = jnp.where(m_hit, jnp.argmax(m_ways), coal_way)
        hit_k = jnp.where(m_hit, k_val, hit_k)
        if pos == 0:
            first_probe_k = k_val
        gen_coal = gen_coal | m_hit

    # per-lane branch selection
    reg_hit = jnp.where(is_colt, colt_reg,
                        jnp.where(is_thp, thp_reg,
                                  jnp.where(is_subr, subr_reg, gen_reg)))
    coal_hit = jnp.where(is_generic, gen_coal,
                         (colt_coal & is_colt) | (subr_coal & is_subr))
    l2_hit = reg_hit | coal_hit
    l2_ppn_val = jnp.where(
        is_colt, colt_ppn,
        jnp.where(is_thp, thp_ppn,
                  jnp.where(is_subr, subr_ppn,
                            jnp.where(gen_reg, pbase[rw], coal_ppn))))
    pred_ok = jnp.where(use_pred & gen_coal
                        & (hit_k == first_probe_k), 1, 0)
    touch_set = jnp.where(is_thp, thp_touch_set, s2)
    tw = jnp.where(
        is_colt, colt_way,
        jnp.where(is_thp, jnp.argmax(thp_touch_ways),
                  jnp.where(is_subr, subr_way,
                            jnp.where(gen_reg, rw, coal_way))))
    probes_used = jnp.where(is_generic, probes_used, 0)

    # ---------------- side structures (gated) -----------------------
    d_r = vpn - st["rmm"][:, 0]
    in_rng = (d_r >= 0) & (d_r < st["rmm"][:, 1]) & \
        (st["rmm"][:, 4] == cur)
    rmm_hit = has_rmm & in_rng.any()
    sw = jnp.argmax(in_rng)
    rmm_ppn_val = st["rmm"][sw, 2] + d_r[sw]

    cwd = vpn >> 3
    sc = cwd & jnp.int32(CLUS_SETS - 1)
    crow = st["clus"][sc]               # [5, 4]
    bit = (crow[:, 1] >> (vpn & 7)) & 1
    c_ways = (crow[:, 0] == cwd) & (bit == 1) & (crow[:, 3] == cur)
    cl_hit = has_cluster & c_ways.any()

    # cache-backed tier (Victima lineage): probed only past an L1+L2 miss
    ctlb_sets = st["ctlb"].shape[0]     # degenerate (1, 1) when unused
    sct = vpn & jnp.int32(ctlb_sets - 1)
    trow = st["ctlb"][sct]
    t_ways = (trow[:, 0] == vpn) & (trow[:, 3] == cur)
    ctlb_hit = has_ctlb & ~l1_served & ~l2_hit & t_ways.any()
    ctlb_way = jnp.argmax(t_ways)

    side_hit = rmm_hit | cl_hit | ctlb_hit
    side_ppn = jnp.where(rmm_hit, rmm_ppn_val,
                         jnp.where(ctlb_hit, trow[ctlb_way, 1], ppn_true))

    hit_any = l1_served | l2_hit | side_hit
    walk = ~hit_any
    wr = walk & active  # gate for every state write below

    # ---------------- latency (per-lane miss chain) -----------------
    cyc = jnp.where(
        l1_served, 0,
        jnp.where(reg_hit, LAT_L2_REG,
                  jnp.where(coal_hit,
                            LAT_COAL + LAT_EXTRA_PROBE *
                            jnp.maximum(probes_used - 1, 0),
                            jnp.where(side_hit,
                                      jnp.where(ctlb_hit, LAT_CTLB,
                                                LAT_COAL),
                                      lane["miss_chain"]
                                      + LAT_WALK))))

    # ---------------- L2 fill (precomputed record; LRU victim) ------
    # dead-protect: a walk whose vpn's counter is still 0 (never
    # re-referenced) bypasses the L2 fill; the counter saturates at 3
    dp_n = st["dp"].shape[0]            # degenerate (1,) when unused
    dp_idx = vpn & jnp.int32(dp_n - 1)
    dp_ctr = st["dp"][dp_idx]
    dp_bypass = use_dead & walk & (dp_ctr == 0)
    new["dp"] = _cond_set(st["dp"], dp_idx, jnp.minimum(dp_ctr + 1, 3),
                          use_dead & wr)

    served_huge = is_thp & (fill_k == HUGE)
    fill_set = jnp.where(served_huge, s2h, s2)
    frow = st["l2"][fill_set]
    valid_row = frow[:, KCLS] != INVALID
    score = jnp.where(way_ok,
                      jnp.where(valid_row, frow[:, LRU],
                                jnp.int32(NEG)),
                      jnp.int32(BIG))
    victim = jnp.argmin(score)
    fill_wr = wr & ~dp_bypass
    evicted_contig = jnp.where(valid_row[victim],
                               frow[victim, CONTIG], 0)
    fill_vec = jnp.stack([fill_tag, fill_k, fill_contig, fill_ppn, t, cur,
                          fill_aux])
    l2n = _cond_set(st["l2"], (fill_set, victim), fill_vec, fill_wr)
    new["l2"] = _cond_set(l2n, (touch_set, tw, LRU), t,
                          l2_hit & ~walk & ~l1_served & active)
    cov_delta = jnp.where(fill_wr, fill_contig - evicted_contig, 0)

    # Victima move: a valid L2 victim drops into the cache-backed tier
    mv = fill_wr & has_ctlb & valid_row[victim]
    ev_tag = frow[victim, TAG]
    sct_v = ev_tag & jnp.int32(ctlb_sets - 1)
    vrow_t = st["ctlb"][sct_v][:, 0] >= 0
    victim_t = jnp.argmin(jnp.where(vrow_t, st["ctlb"][sct_v][:, 2],
                                    jnp.int32(NEG)))
    ctlb_vec = jnp.stack([ev_tag, frow[victim, PPN], t,
                          frow[victim, L2_ASID]])
    ctn = _cond_set(st["ctlb"], (sct_v, victim_t), ctlb_vec, mv)
    new["ctlb"] = _cond_set(ctn, (sct, ctlb_way, 2), t,
                            ctlb_hit & active)
    cov_delta = cov_delta + jnp.where(
        mv, 1 - vrow_t[victim_t].astype(jnp.int32), 0)

    # ---------------- side fills (gated) ----------------------------
    rmm_len = st["rmm"][:, 1]
    victim_r = jnp.argmin(jnp.where(rmm_len > 0, st["rmm"][:, 3],
                                    jnp.int32(NEG)))
    ev_len = jnp.where(rmm_len[victim_r] > 0, rmm_len[victim_r], 0)
    rmm_wr = wr & has_rmm
    rmm_vec = jnp.stack([rs_v, rl_v, rmm_fill_ppn, t, cur])
    rmmn = _cond_set(st["rmm"], victim_r, rmm_vec, rmm_wr)
    new["rmm"] = _cond_set(rmmn, (sw, 3), t, rmm_hit & active)
    cov_delta = cov_delta + jnp.where(rmm_wr, rl_v - ev_len, 0)

    clusterable = bm != (jnp.int32(1) << (vpn & 7))
    fill_c = wr & clusterable & has_cluster
    vrow = crow[:, 1] != 0
    victim_c = jnp.argmin(jnp.where(vrow, crow[:, 2],
                                    jnp.int32(NEG)))
    cl_vec = jnp.stack([cwd, bm, t, cur])
    cln = _cond_set(st["clus"], (sc, victim_c), cl_vec, fill_c)
    hit_cway = jnp.argmax((crow[:, 0] == cwd) & (crow[:, 3] == cur))
    new["clus"] = _cond_set(cln, (sc, hit_cway, 2), t,
                            cl_hit & active)

    # ---------------- L1 fills --------------------------------------
    do1h = ~l1_served & served_huge & active
    vrh = l1hrow[:, 0] >= 0
    vich = jnp.argmin(jnp.where(vrh, l1hrow[:, 2], jnp.int32(NEG)))
    l1h_vec = jnp.stack([hv, fill_ppn, t, cur])
    l1hn = _cond_set(st["l1h"], (s1h, vich), l1h_vec, do1h)
    new["l1h"] = _cond_set(
        l1hn, (s1h, l1h_way, 2), t,
        is_thp & l1_served & h_ways_hit.any() & ~l1_hit & active)

    do1 = ~l1_served & ~served_huge & active
    vr1 = l1row[:, 0] >= 0
    vic1 = jnp.argmin(jnp.where(vr1, l1row[:, 2], jnp.int32(NEG)))
    l1_vec = jnp.stack([vpn, ppn_true, t, cur])
    l1n = _cond_set(st["l1"], (s1, vic1), l1_vec, do1)
    new["l1"] = _cond_set(l1n, (s1, l1_way, 2), t, l1_hit & active)

    # ---------------- predictor update (gated) ----------------------
    upd = use_pred & active
    new["pred"] = jnp.where(
        upd & gen_coal, hit_k,
        jnp.where(upd & walk & (fill_k >= 0), fill_k, st["pred"]))

    # ---------------- accounting (one packed add) -------------------
    act = active
    delta = jnp.stack([
        (l1_served & act).astype(jnp.int32),
        (reg_hit & ~l1_served & act).astype(jnp.int32),
        ((coal_hit | side_hit) & ~reg_hit & ~l1_served
         & act).astype(jnp.int32),
        (walk & act).astype(jnp.int32),
        jnp.where(coal_hit & ~l1_served & act, probes_used, 0),
        # dead-protect rides C_PRED: bypassed fills count as predictions
        jnp.where(~l1_served & act, pred_ok, 0)
        + (dp_bypass & act).astype(jnp.int32),
        jnp.where(act, cyc, 0),
        cov_delta,
        jnp.int32(0),
    ])
    new["counters"] = st["counters"] + delta
    new["t"] = t + act.astype(jnp.int32)
    se = lane["sample_every"]
    slot = jnp.minimum(t // se, N_COV_SAMPLES - 1)
    new["cov_samples"] = _cond_set(st["cov_samples"], slot,
                                   new["counters"][C_COV],
                                   (t % se == se - 1) & active)

    out_ppn = jnp.where(
        l1_served, l1_out_ppn,
        jnp.where(l2_hit, l2_ppn_val,
                  jnp.where(side_hit, side_ppn, ppn_true)))
    return new, out_ppn


def shoot_lane(lane, st, dc, do):
    """Translation coherence on epoch turnover (gated by ``do``): drop
    every entry — in every structure — whose covered vpn range contains a
    dirty vpn of the entered epoch (``dc`` = the epoch's dirty-bitmap
    prefix sums, ``[P+1]``), charge the coherence cost, and release the
    dropped reach.  Both ``coh_policy`` values drop the identical entry
    set; they differ only in cycles — IPI-style ``shootdown`` pays the
    ``LAT_SHOOTDOWN`` broadcast stall plus ``LAT_INVALIDATE`` per entry,
    directory-tracked ``hw-coherence`` (``lane['coh_hw']``) pays only the
    targeted per-entry invalidations."""
    is_thp, is_subr = lane["is_thp"], lane["is_subr"]
    Pn = dc.shape[0] - 1

    def rng_dirty(lo, ln):
        lo_ = jnp.clip(lo, 0, Pn)
        hi_ = jnp.clip(lo + ln, 0, Pn)
        return (dc[hi_] - dc[lo_]) > 0

    new = dict(st)
    l2 = st["l2"]
    tagv, kv, cgv = l2[..., TAG], l2[..., KCLS], l2[..., CONTIG]
    # k == HUGE is a 2MB entry (tag = vpn >> 9) only on THP lanes;
    # K-bit Aligned lanes use k = 9 as a plain alignment class.
    # Subregion entries cover their whole SUBR_PAGES window (conservative:
    # a dirty page under a cleared bitmap bit still drops the entry — a
    # cleared bit can only miss, never serve stale).
    huge2 = is_thp & (kv == HUGE)
    subr2 = is_subr & (kv == KSUBR)
    stale2 = (kv != INVALID) & do & rng_dirty(
        jnp.maximum(jnp.where(huge2, tagv << 9, tagv), 0),
        jnp.where(huge2, 512,
                  jnp.where(subr2, SUBR_PAGES,
                            jnp.where(kv == REGULAR, 1,
                                      jnp.maximum(cgv, 1)))))
    new["l2"] = l2.at[..., KCLS].set(jnp.where(stale2, INVALID, kv))
    n_inv = stale2.sum(dtype=jnp.int32)
    cov_loss = jnp.where(stale2, cgv, 0).sum(dtype=jnp.int32)

    l1 = st["l1"]
    t1 = l1[..., 0]
    stale1 = (t1 >= 0) & do & rng_dirty(jnp.maximum(t1, 0), 1)
    new["l1"] = l1.at[..., 0].set(jnp.where(stale1, -1, t1))
    n_inv = n_inv + stale1.sum(dtype=jnp.int32)

    l1h = st["l1h"]
    th = l1h[..., 0]
    staleh = (th >= 0) & do & rng_dirty(jnp.maximum(th, 0) << 9, 512)
    new["l1h"] = l1h.at[..., 0].set(jnp.where(staleh, -1, th))
    n_inv = n_inv + staleh.sum(dtype=jnp.int32)

    rmm = st["rmm"]
    rs0, rl0 = rmm[:, 0], rmm[:, 1]
    staler = (rl0 > 0) & do & rng_dirty(jnp.maximum(rs0, 0), rl0)
    rmm2 = rmm.at[:, 0].set(jnp.where(staler, -1, rs0))
    rmm2 = rmm2.at[:, 1].set(jnp.where(staler, 0, rl0))
    new["rmm"] = rmm2.at[:, 2].set(jnp.where(staler, -1, rmm[:, 2]))
    n_inv = n_inv + staler.sum(dtype=jnp.int32)
    cov_loss = cov_loss + jnp.where(staler, rl0, 0).sum(
        dtype=jnp.int32)

    cl = st["clus"]
    ct, cb = cl[..., 0], cl[..., 1]
    stalec = (cb != 0) & do & rng_dirty(jnp.maximum(ct, 0) << 3, 8)
    new["clus"] = cl.at[..., 1].set(jnp.where(stalec, 0, cb))
    n_inv = n_inv + stalec.sum(dtype=jnp.int32)

    # cache-backed tier holds 4KB translations: tag-range-1 stale pass
    # (the dead-entry counter table holds predictions, nothing to drop)
    ctb = st["ctlb"]
    tt = ctb[..., 0]
    stalet = (tt >= 0) & do & rng_dirty(jnp.maximum(tt, 0), 1)
    new["ctlb"] = ctb.at[..., 0].set(jnp.where(stalet, -1, tt))
    n_inv = n_inv + stalet.sum(dtype=jnp.int32)
    cov_loss = cov_loss + stalet.sum(dtype=jnp.int32)

    cnt = st["counters"]
    add = (jnp.zeros_like(cnt)
           .at[C_SHOOT].set(n_inv)
           .at[C_CYC].set(jnp.where(do & ~lane["coh_hw"],
                                    LAT_SHOOTDOWN, 0)
                          + n_inv * LAT_INVALIDATE)
           .at[C_COV].set(-cov_loss))
    new["counters"] = cnt + add
    return new


def switch_lane(st, new_asid, do_switch, flush_all, flush_asid):
    """Context switch at segment entry (multi-tenant worlds).

    Sets the live ASID from per-``(lane, segment)`` data (``new_asid``
    equals the current ASID when this lane has no boundary here, so the
    unconditional write is a no-op), charges ``LAT_CTX_SWITCH`` when the
    address space changed (``do_switch``), and bulk-clears entries —
    every structure under ``flush_all`` (the untagged-hardware policy),
    or only entries tagged ``new_asid`` under ``flush_asid`` (an ASID
    recycled from a departed tenant: its stale entries must not serve
    the newcomer).  Flushes drop valid bits in bulk — no per-entry
    invalidation-port cycles, unlike coherence shootdowns — and the
    dropped entries are counted in the shootdown counter; the real cost
    surfaces as refill walks.  Static/dynamic lanes carry all-False
    flags and ASID 0 everywhere, making this pass a no-op for them."""
    new = dict(st)

    def kill(valid, asid_col):
        return valid & (flush_all | (flush_asid & (asid_col == new_asid)))

    l2 = st["l2"]
    kv = l2[..., KCLS]
    k2 = kill(kv != INVALID, l2[..., L2_ASID])
    new["l2"] = l2.at[..., KCLS].set(jnp.where(k2, INVALID, kv))
    n_inv = k2.sum(dtype=jnp.int32)
    cov_loss = jnp.where(k2, l2[..., CONTIG], 0).sum(dtype=jnp.int32)

    l1 = st["l1"]
    t1 = l1[..., 0]
    k1 = kill(t1 >= 0, l1[..., 3])
    new["l1"] = l1.at[..., 0].set(jnp.where(k1, -1, t1))
    n_inv = n_inv + k1.sum(dtype=jnp.int32)

    l1h = st["l1h"]
    th = l1h[..., 0]
    kh = kill(th >= 0, l1h[..., 3])
    new["l1h"] = l1h.at[..., 0].set(jnp.where(kh, -1, th))
    n_inv = n_inv + kh.sum(dtype=jnp.int32)

    rmm = st["rmm"]
    rl0 = rmm[:, 1]
    kr = kill(rl0 > 0, rmm[:, 4])
    rmm2 = rmm.at[:, 0].set(jnp.where(kr, -1, rmm[:, 0]))
    rmm2 = rmm2.at[:, 1].set(jnp.where(kr, 0, rl0))
    new["rmm"] = rmm2.at[:, 2].set(jnp.where(kr, -1, rmm[:, 2]))
    n_inv = n_inv + kr.sum(dtype=jnp.int32)
    cov_loss = cov_loss + jnp.where(kr, rl0, 0).sum(dtype=jnp.int32)

    cl = st["clus"]
    cb = cl[..., 1]
    kc = kill(cb != 0, cl[..., 3])
    new["clus"] = cl.at[..., 1].set(jnp.where(kc, 0, cb))
    n_inv = n_inv + kc.sum(dtype=jnp.int32)

    # cache-backed tier is ASID-tagged like everything else; the
    # dead-entry counter table is a predictor and survives switches
    ctb = st["ctlb"]
    tt = ctb[..., 0]
    kt = kill(tt >= 0, ctb[..., 3])
    new["ctlb"] = ctb.at[..., 0].set(jnp.where(kt, -1, tt))
    n_inv = n_inv + kt.sum(dtype=jnp.int32)
    cov_loss = cov_loss + kt.sum(dtype=jnp.int32)

    new["asid"] = new_asid
    cnt = st["counters"]
    add = (jnp.zeros_like(cnt)
           .at[C_SHOOT].set(n_inv)
           .at[C_CYC].set(jnp.where(do_switch, LAT_CTX_SWITCH, 0))
           .at[C_COV].set(-cov_loss))
    new["counters"] = cnt + add
    return new


# ---------------------------------------------------------------------------
# The block plan: the static time-blocked timeline both backends execute
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BlockPlan:
    """Static execution timeline for one packed batch.

    Every epoch segment ``[seg_bounds[s], seg_bounds[s+1])`` is padded to a
    whole number of ``tb``-step blocks, so a block never straddles a
    segment boundary and the per-segment record ids stay constant within a
    block.  Padded slots (``tpos >= blk_hi``) are fully inert.  The first
    block of every segment ``s > 0`` carries the shootdown flag; whether a
    given lane actually shoots there stays per-lane data
    (``lanes['seg_shoot']``).
    """

    tb: int                   # block size (trace steps per block)
    n_blocks: int             # total blocks across all segments
    blk_seg: np.ndarray       # [NB]    segment id of each block
    blk_shoot: np.ndarray     # [NB]    block enters a segment with s > 0
    blk_hi: np.ndarray        # [NB]    end bound of the block's segment
    tpos: np.ndarray          # [NB*TB] original t per padded slot
    slot_of_t: np.ndarray     # [T]     padded slot per original t


def build_block_plan(seg_bounds: Tuple[int, ...], tb: int) -> BlockPlan:
    T = seg_bounds[-1]
    blk_seg, blk_shoot, blk_hi, tpos = [], [], [], []
    slot_of_t = np.zeros(T, np.int32)
    for s, (lo, hi) in enumerate(zip(seg_bounds, seg_bounds[1:])):
        nb = -(-(hi - lo) // tb)
        for b in range(nb):
            blk_seg.append(s)
            blk_shoot.append(b == 0 and s > 0)
            blk_hi.append(hi)
            for j in range(tb):
                t = lo + b * tb + j
                if t < hi:
                    slot_of_t[t] = len(tpos)
                tpos.append(t)
    return BlockPlan(
        tb=tb, n_blocks=len(blk_seg),
        blk_seg=np.asarray(blk_seg, np.int32),
        blk_shoot=np.asarray(blk_shoot, bool),
        blk_hi=np.asarray(blk_hi, np.int32),
        tpos=np.asarray(tpos, np.int32),
        slot_of_t=slot_of_t)


class SweepCellLike:  # pragma: no cover - typing aid only
    """Anything with ``.spec``, ``.mapping``, ``.trace`` (see SweepCell)."""

    spec: MethodSpec
    mapping: object
    trace: np.ndarray
