"""Contiguity-annotated page table (paper §2, Definition 1 and §3.1).

A memory mapping for a process is modelled as a dense array ``ppn`` over a
virtual footprint of ``n_pages`` pages: ``ppn[vpn]`` is the physical page
number backing virtual page ``vpn`` (``-1`` = unmapped).

From ``ppn`` we derive, exactly as the paper's OS would by scanning the page
table:

* ``run_start[vpn]`` / ``run_len[vpn]``: the *contiguity chunk* (Def. 1)
  containing ``vpn`` — the maximal range of pages contiguous in both VA and
  PA.  The per-PTE ``contiguity`` field of §3.1 is
  ``run_start[vpn] + run_len[vpn] - vpn``.
* the contiguity-chunk list and the contiguity histogram used by Algorithm 3.

Mappings are not static: demand paging, compaction, THP promotion/splitting
and allocation churn — the very mechanisms the paper credits for *producing*
mixed contiguity — rewrite translations mid-run.  :class:`MappingEvent`
models one such OS action, and :class:`DynamicMapping` is an epoch sequence:
``epochs[e]`` is the live mapping for trace steps in
``[boundaries[e], boundaries[e+1])``, with ``events[e]`` the event batch
applied on entering epoch ``e``.  Translation coherence (the shootdown
semantics of Yan et al., "Hardware Translation Coherence for Virtualized
Systems") is derived from the *snapshot diff*: entering epoch ``e``, every
vpn in :meth:`DynamicMapping.dirty` lost its old translation, and any TLB
structure holding an entry that covers a dirty vpn must invalidate it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

UNMAPPED = -1


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (1 for n <= 1)."""
    return 1 if n <= 1 else 1 << int(n - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class Mapping:
    """A virtual→physical page mapping with derived contiguity metadata."""

    ppn: np.ndarray        # int64[n_pages], -1 where unmapped
    run_start: np.ndarray  # int64[n_pages], start vpn of containing chunk
    run_len: np.ndarray    # int64[n_pages], size of containing chunk
    name: str = "mapping"

    @property
    def n_pages(self) -> int:
        return int(self.ppn.shape[0])

    def contiguity(self, vpn) -> np.ndarray:
        """Per-PTE contiguity field (§3.1): pages contiguously mapped starting
        at ``vpn``, *including* ``vpn`` itself.  0 for unmapped pages."""
        vpn = np.asarray(vpn)
        mapped = self.ppn[vpn] != UNMAPPED
        return np.where(mapped, self.run_start[vpn] + self.run_len[vpn] - vpn, 0)


def compute_runs(ppn: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized contiguity-chunk extraction.

    A chunk boundary occurs at ``i`` when ``ppn[i] != ppn[i-1] + 1`` or when
    either side is unmapped.
    """
    ppn = np.asarray(ppn, dtype=np.int64)
    n = ppn.shape[0]
    if n == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    mapped = ppn != UNMAPPED
    cont_with_prev = np.zeros(n, dtype=bool)
    cont_with_prev[1:] = mapped[1:] & mapped[:-1] & (ppn[1:] == ppn[:-1] + 1)
    # run id increments where a new run starts
    new_run = ~cont_with_prev
    run_id = np.cumsum(new_run) - 1
    starts = np.flatnonzero(new_run)
    run_start = starts[run_id]
    counts = np.bincount(run_id)
    run_len = counts[run_id]
    # unmapped pages belong to no chunk
    run_len = np.where(mapped, run_len, 0)
    run_start = np.where(mapped, run_start, np.arange(n))
    return run_start.astype(np.int64), run_len.astype(np.int64)


def make_mapping(ppn: np.ndarray, name: str = "mapping") -> Mapping:
    run_start, run_len = compute_runs(ppn)
    return Mapping(ppn=np.asarray(ppn, np.int64), run_start=run_start,
                   run_len=run_len, name=name)


def contiguity_chunks(m: Mapping) -> List[Tuple[int, int]]:
    """All contiguity chunks as ``(start_vpn, size)`` (Definition 1)."""
    mapped = m.ppn != UNMAPPED
    starts = np.unique(m.run_start[mapped])
    return [(int(s), int(m.run_len[s])) for s in starts]


def contiguity_histogram(m: Mapping) -> Dict[int, int]:
    """The OS-maintained contiguity histogram (paper §3.3): chunk size → count.

    Mirrors the structure consumed by Algorithm 3: a list of (size, freq).
    """
    chunks = contiguity_chunks(m)
    hist: Dict[int, int] = {}
    for _, size in chunks:
        hist[size] = hist.get(size, 0) + 1
    return hist


def huge_page_backed(m: Mapping) -> np.ndarray:
    """bool[n_pages]: vpn lies inside a promotable 2MB huge page.

    THP can promote a 512-page window when (a) the window is fully contiguous
    and (b) the physical base is itself 512-aligned (x86 2MB pages require
    PA alignment).
    """
    n = m.n_pages
    base = np.arange(n, dtype=np.int64) & ~np.int64(511)
    ok = base + 512 <= n
    b = np.minimum(base, n - 1)
    contig_at_base = np.where(m.ppn[b] != UNMAPPED,
                              m.run_start[b] + m.run_len[b] - b, 0)
    aligned_pa = (m.ppn[b] & 511) == 0
    return ok & (contig_at_base >= 512) & aligned_pa


# ---------------------------------------------------------------------------
# Dynamic mappings: OS events that rewrite translations mid-trace
# ---------------------------------------------------------------------------

EVENT_KINDS = ("map", "unmap", "remap", "promote", "split", "compact")


@dataclasses.dataclass(frozen=True)
class MappingEvent:
    """One OS action on a virtual range ``[vpn, vpn + n)``.

    ``kind`` is a semantic label (all kinds except ``unmap`` are writes of a
    new backing):

    * ``map``     — demand-fault new pages in (previously unmapped);
    * ``unmap``   — release pages (``MADV_DONTNEED`` / free);
    * ``remap``   — migrate pages to new frames (NUMA balancing, swap);
    * ``promote`` — THP promotion: re-back a 512-window contiguously;
    * ``split``   — THP split: scatter pages out of a huge run;
    * ``compact`` — kcompactd migration into a dense region.

    ``ppn`` is the new physical backing: an ``int`` base of a contiguous
    frame range, an explicit array of ``n`` frames, or ``None`` for
    ``unmap``.
    """

    kind: str
    vpn: int
    n: int = 1
    ppn: Union[int, np.ndarray, None] = None

    def __post_init__(self):
        assert self.kind in EVENT_KINDS, self.kind
        assert self.n > 0 and self.vpn >= 0
        if self.kind == "unmap":
            assert self.ppn is None
        else:
            assert self.ppn is not None

    def new_ppns(self) -> np.ndarray:
        """The ``n`` frames this event installs (-1s for ``unmap``)."""
        if self.kind == "unmap":
            return np.full(self.n, UNMAPPED, np.int64)
        if isinstance(self.ppn, np.ndarray):
            assert self.ppn.shape[0] == self.n
            return np.asarray(self.ppn, np.int64)
        return np.arange(self.ppn, self.ppn + self.n, dtype=np.int64)


def apply_event(ppn: np.ndarray, ev: MappingEvent) -> np.ndarray:
    """Functionally apply one event to a ``ppn`` array (returns a copy)."""
    out = np.asarray(ppn, np.int64).copy()
    assert ev.vpn + ev.n <= out.shape[0], "event outside the virtual footprint"
    out[ev.vpn: ev.vpn + ev.n] = ev.new_ppns()
    return out


def events_from_diff(prev: np.ndarray, cur: np.ndarray
                     ) -> List[MappingEvent]:
    """Derive the run-grouped event list that turns ``prev`` into ``cur``.

    Used by recorders that snapshot a live system (the KV-churn driver)
    instead of logging semantic events: consecutive differing vpns of the
    same category become one ``map``/``unmap``/``remap`` event.
    """
    prev = np.asarray(prev, np.int64)
    cur = np.asarray(cur, np.int64)
    assert prev.shape == cur.shape
    diff = prev != cur
    cat = np.where(~diff, 0,
                   np.where(prev == UNMAPPED, 1,           # map
                            np.where(cur == UNMAPPED, 2,   # unmap
                                     3)))                  # remap
    out: List[MappingEvent] = []
    n = prev.shape[0]
    boundaries = np.flatnonzero(np.diff(cat) != 0) + 1
    starts = np.concatenate([[0], boundaries])
    ends = np.concatenate([boundaries, [n]])
    kinds = {1: "map", 2: "unmap", 3: "remap"}
    for s, e in zip(starts, ends):
        c = int(cat[s])
        if c == 0:
            continue
        if c == 2:
            out.append(MappingEvent("unmap", int(s), int(e - s)))
        else:
            out.append(MappingEvent(kinds[c], int(s), int(e - s),
                                    ppn=cur[s:e].copy()))
    return out


@dataclasses.dataclass(frozen=True)
class DynamicMapping:
    """An epoch sequence: ``epochs[e]`` is live for trace steps in
    ``[boundaries[e], boundaries[e+1])``; ``events[e]`` is the event batch
    applied on entering epoch ``e`` (``events[0]`` is empty).

    All epochs share one virtual footprint (``n_pages``).  The *dirty set*
    of epoch ``e`` — vpns whose old translation died — is derived from the
    snapshot diff, so invalidation correctness never depends on the event
    log being complete.
    """

    epochs: Tuple[Mapping, ...]
    boundaries: Tuple[int, ...]
    events: Tuple[Tuple[MappingEvent, ...], ...] = ()
    name: str = "dynamic"

    def __post_init__(self):
        assert len(self.epochs) >= 1
        assert len(self.boundaries) == len(self.epochs)
        assert self.boundaries[0] == 0
        assert all(a < b for a, b in zip(self.boundaries,
                                         self.boundaries[1:])), \
            "epoch boundaries must be strictly ascending"
        if not self.events:
            object.__setattr__(
                self, "events", tuple(() for _ in self.epochs))
        assert len(self.events) == len(self.epochs)
        n = self.epochs[0].n_pages
        assert all(m.n_pages == n for m in self.epochs), \
            "all epochs must share one virtual footprint"

    @property
    def n_pages(self) -> int:
        return self.epochs[0].n_pages

    @property
    def n_epochs(self) -> int:
        return len(self.epochs)

    def epoch_at(self, t: int) -> int:
        """Index of the epoch live at trace step ``t``."""
        return int(np.searchsorted(self.boundaries, t, side="right") - 1)

    def dirty(self, e: int) -> np.ndarray:
        """bool[n_pages]: vpns whose translation died entering epoch ``e``
        (previously mapped, now unmapped or re-backed) — the shootdown set."""
        assert 1 <= e < self.n_epochs
        prev, cur = self.epochs[e - 1].ppn, self.epochs[e].ppn
        return (prev != UNMAPPED) & (prev != cur)

    def dirty_count(self, e: int) -> int:
        return int(self.dirty(e).sum())


def build_dynamic_mapping(initial_ppn: np.ndarray,
                          schedule: Sequence[
                              Tuple[int, Sequence[MappingEvent]]],
                          name: str = "dynamic") -> DynamicMapping:
    """Replay an event schedule into a :class:`DynamicMapping`.

    ``schedule`` is ``[(boundary_t, events), ...]`` with strictly ascending
    ``boundary_t > 0``: at trace step ``boundary_t`` the events are applied
    (in order) and a new epoch begins.
    """
    ppn = np.asarray(initial_ppn, np.int64)
    epochs = [make_mapping(ppn, name=f"{name}@0")]
    boundaries = [0]
    events: List[Tuple[MappingEvent, ...]] = [()]
    for t, evs in schedule:
        cur = epochs[-1].ppn
        for ev in evs:
            cur = apply_event(cur, ev)
        epochs.append(make_mapping(cur, name=f"{name}@{int(t)}"))
        boundaries.append(int(t))
        events.append(tuple(evs))
    return DynamicMapping(tuple(epochs), tuple(boundaries), tuple(events),
                          name=name)


def dynamic_from_snapshots(snaps: Sequence[Mapping],
                           boundaries: Sequence[int],
                           name: str = "dynamic") -> DynamicMapping:
    """Wrap recorded snapshots; events are derived per epoch by diffing."""
    events = [()] + [tuple(events_from_diff(a.ppn, b.ppn))
                     for a, b in zip(snaps, snaps[1:])]
    return DynamicMapping(tuple(snaps), tuple(int(b) for b in boundaries),
                          tuple(events), name=name)


# ---------------------------------------------------------------------------
# Multi-tenant address spaces: many processes time-sharing one TLB
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MultiTenantMapping:
    """Several address spaces time-sharing one TLB under a context-switch
    schedule (the serving-stack conclusion of the paper's "diverse
    contiguity": every tenant brings its *own* contiguity signature).

    ``tenants[i]`` is tenant ``i``'s full address space (VPNs are
    per-tenant: the same vpn means different translations in different
    tenants).  The schedule is a segment sequence: during trace steps
    ``[boundaries[s], boundaries[s+1])`` tenant ``tenant_ids[s]`` runs under
    ASID ``asids[s]``.  The ASID is the *hardware tag* the OS assigned for
    that scheduling quantum — a finite resource, so departing tenants'
    ASIDs get recycled (``recycled[s]`` is True when segment ``s`` reuses
    an ASID whose previous holder was a *different* tenant; correctness
    then requires the OS to invalidate that ASID's stale entries before
    the segment runs, exactly like a Linux ASID-generation rollover).

    How a context switch treats the TLB is NOT a property of the world but
    of the hardware policy under test —
    :attr:`repro.core.simulator.MethodSpec.ctx_policy`:

    * ``"flush"`` — switching flushes every structure (untagged hardware);
    * ``"tag"``   — entries are ASID-tagged and survive switches; lookups
      only hit entries whose tag matches the live ASID, and only recycled
      ASIDs pay a targeted invalidation.
    """

    tenants: Tuple[Mapping, ...]
    boundaries: Tuple[int, ...]      # strictly ascending, [0] == 0
    tenant_ids: Tuple[int, ...]      # per segment: index into tenants
    asids: Tuple[int, ...]           # per segment: ASID label assigned
    name: str = "multitenant"
    recycled: Tuple[bool, ...] = ()  # derived: segment reuses a dead ASID

    def __post_init__(self):
        assert len(self.tenants) >= 1
        ns = len(self.boundaries)
        assert len(self.tenant_ids) == ns and len(self.asids) == ns
        assert ns >= 1 and self.boundaries[0] == 0
        assert all(a < b for a, b in zip(self.boundaries,
                                         self.boundaries[1:])), \
            "segment boundaries must be strictly ascending"
        assert all(0 <= t < len(self.tenants) for t in self.tenant_ids)
        assert all(a >= 0 for a in self.asids)
        # a resident tenant keeps its ASID until it is descheduled: adjacent
        # same-tenant segments must share one ASID.  Allowing a silent
        # relabel would make every resident entry unhittable through the
        # ASID compare with no flush charged — a free, invisible TLB wipe
        # no hardware policy exhibits.
        assert all(self.asids[s] == self.asids[s - 1]
                   for s in range(1, ns)
                   if self.tenant_ids[s] == self.tenant_ids[s - 1]), \
            "adjacent same-tenant segments must share one ASID"
        if not self.recycled:
            holder: Dict[int, int] = {}
            rec = []
            for s in range(ns):
                a, t = self.asids[s], self.tenant_ids[s]
                rec.append(a in holder and holder[a] != t)
                holder[a] = t
            object.__setattr__(self, "recycled", tuple(rec))
        assert len(self.recycled) == ns

    @property
    def n_pages(self) -> int:
        """Largest tenant footprint (engines pad every record to it)."""
        return max(m.n_pages for m in self.tenants)

    @property
    def n_tenants(self) -> int:
        return len(self.tenants)

    @property
    def n_segments(self) -> int:
        return len(self.boundaries)

    def segment_at(self, t: int) -> int:
        """Index of the schedule segment live at trace step ``t``."""
        return int(np.searchsorted(self.boundaries, t, side="right") - 1)

    def tenant_at(self, t: int) -> Mapping:
        return self.tenants[self.tenant_ids[self.segment_at(t)]]

    def switches(self, s: int) -> bool:
        """True when entering segment ``s`` changes the running address
        space (a context switch is charged; under ``flush`` the TLB is
        wiped)."""
        return s > 0 and self.tenant_ids[s] != self.tenant_ids[s - 1]

    def n_switches(self) -> int:
        return sum(self.switches(s) for s in range(self.n_segments))

    def merged_contiguity_histogram(self) -> Dict[int, int]:
        """Union histogram over all tenants — what an OS aggregating
        per-process contiguity stats would feed Algorithm 3."""
        hist: Dict[int, int] = {}
        for m in self.tenants:
            for size, freq in contiguity_histogram(m).items():
                hist[size] = hist.get(size, 0) + freq
        return hist


def build_multitenant_mapping(tenants: Sequence[Mapping],
                              schedule: Sequence[Tuple[int, int, int]],
                              name: str = "multitenant"
                              ) -> MultiTenantMapping:
    """Build a :class:`MultiTenantMapping` from ``(t, tenant_id, asid)``
    triples (strictly ascending ``t``, first at 0).  Consecutive segments
    with identical ``(tenant_id, asid)`` are merged — schedulers emit one
    entry per quantum and a tenant may run back-to-back quanta.  Adjacent
    same-tenant segments with *different* ASIDs are rejected by the
    constructor: a resident tenant keeps its ASID until descheduled."""
    assert schedule and schedule[0][0] == 0
    bounds: List[int] = []
    tids: List[int] = []
    asids: List[int] = []
    for t, tid, asid in schedule:
        if bounds and tids[-1] == tid and asids[-1] == asid:
            continue
        bounds.append(int(t))
        tids.append(int(tid))
        asids.append(int(asid))
    return MultiTenantMapping(tuple(tenants), tuple(bounds), tuple(tids),
                              tuple(asids), name=name)


# ---------------------------------------------------------------------------
# Nested (guest → host) translation: two-level worlds under virtualization
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NestedSegment:
    """One union-grid segment of a nested world.

    The union grid is the merge of the VM schedule boundaries, every
    guest's epoch boundaries, and the host's epoch boundaries: within one
    segment nothing about the composed translation or the running VM
    changes.  ``mapping`` is the *composed* guest-VPN → host-PPN view of
    the scheduled guest, and ``dirty`` (when not ``None``) is the set of
    guest VPNs — unioned over ALL guests, coherence is ASID-blind — whose
    composed translation died entering this segment.
    """

    lo: int
    guest_id: int
    asid: int
    switch: bool
    recycled: bool
    mapping: Mapping
    dirty: Optional[np.ndarray]


@dataclasses.dataclass(frozen=True)
class NestedMapping:
    """Two-level (guest → host) translation worlds: each tenant is a VM.

    ``guests[i]`` is VM ``i``'s guest page table as a
    :class:`DynamicMapping` over guest VPNs: ``guests[i].epochs[e].ppn[v]``
    is a *guest* PPN.  ``host`` is the hypervisor's table mapping guest
    PPNs to host PPNs, itself a :class:`DynamicMapping` — host-level
    remap/compaction/balloon events rewrite frames the guests never
    touched.  A translation the TLB may cache is the *composition*
    ``host.ppn[guest.ppn[v]]``, so contiguity (what K-bit alignment
    exploits) can fracture at either level, and a host event dirties
    composed translations **by host-side position** — every guest VPN
    whose backing guest PPN the host moved, across every VM.

    The VM schedule mirrors :class:`MultiTenantMapping`: during trace
    steps ``[boundaries[s], boundaries[s+1])`` guest ``guest_ids[s]`` runs
    under ASID ``asids[s]`` (vCPU tags), with ``recycled`` derived the
    same way.  :meth:`plan_segments` flattens all three time axes into one
    union grid consumed by both the oracle
    (:func:`repro.core.simulator.run_method_nested`) and the batched lane
    engine — the composed dirty sets are computed HERE, once, so every
    executor invalidates identically.

    *How* an invalidation is paid is not a property of the world but of
    :attr:`repro.core.simulator.MethodSpec.coh_policy`: IPI-style
    ``"shootdown"`` or directory-tracked ``"hw-coherence"``.
    """

    guests: Tuple[DynamicMapping, ...]
    host: DynamicMapping
    boundaries: Tuple[int, ...]      # strictly ascending, [0] == 0
    guest_ids: Tuple[int, ...]       # per segment: index into guests
    asids: Tuple[int, ...]           # per segment: ASID (vCPU tag)
    name: str = "nested"
    recycled: Tuple[bool, ...] = ()  # derived: segment reuses a dead ASID

    def __post_init__(self):
        assert len(self.guests) >= 1
        ns = len(self.boundaries)
        assert len(self.guest_ids) == ns and len(self.asids) == ns
        assert ns >= 1 and self.boundaries[0] == 0
        assert all(a < b for a, b in zip(self.boundaries,
                                         self.boundaries[1:])), \
            "schedule boundaries must be strictly ascending"
        assert all(0 <= g < len(self.guests) for g in self.guest_ids)
        assert all(a >= 0 for a in self.asids)
        # same invariant as MultiTenantMapping: a resident VM keeps its
        # ASID until descheduled
        assert all(self.asids[s] == self.asids[s - 1]
                   for s in range(1, ns)
                   if self.guest_ids[s] == self.guest_ids[s - 1]), \
            "adjacent same-guest segments must share one ASID"
        if not self.recycled:
            holder: Dict[int, int] = {}
            rec = []
            for s in range(ns):
                a, g = self.asids[s], self.guest_ids[s]
                rec.append(a in holder and holder[a] != g)
                holder[a] = g
            object.__setattr__(self, "recycled", tuple(rec))
        assert len(self.recycled) == ns
        object.__setattr__(self, "_composed_cache", {})
        object.__setattr__(self, "_segments_cache", None)

    @property
    def n_pages(self) -> int:
        """Largest guest footprint (engines pad every record to it)."""
        return max(g.n_pages for g in self.guests)

    @property
    def n_guests(self) -> int:
        return len(self.guests)

    @property
    def n_segments(self) -> int:
        return len(self.boundaries)

    def segment_at(self, t: int) -> int:
        """Index of the schedule segment live at trace step ``t``."""
        return int(np.searchsorted(self.boundaries, t, side="right") - 1)

    def switches(self, s: int) -> bool:
        return s > 0 and self.guest_ids[s] != self.guest_ids[s - 1]

    def n_switches(self) -> int:
        return sum(self.switches(s) for s in range(self.n_segments))

    def composed(self, guest_id: int, g_epoch: int, h_epoch: int) -> Mapping:
        """The composed guest-VPN → host-PPN :class:`Mapping` (memoized).

        A guest VPN is mapped iff the guest maps it AND its guest PPN
        falls inside the host table AND the host maps that frame;
        contiguity runs are recomputed on the composition, so a
        host-level fracture breaks a composed chunk even where the guest
        side stayed perfectly contiguous.
        """
        key = (guest_id, g_epoch, h_epoch)
        hit = self._composed_cache.get(key)
        if hit is None:
            g = self.guests[guest_id].epochs[g_epoch].ppn
            h = self.host.epochs[h_epoch].ppn
            gp = np.clip(g, 0, h.shape[0] - 1)
            ok = (g != UNMAPPED) & (g < h.shape[0])
            hit = make_mapping(
                np.where(ok, h[gp], UNMAPPED),
                name=f"{self.name}:g{guest_id}e{g_epoch}h{h_epoch}")
            self._composed_cache[key] = hit
        return hit

    def composed_at(self, t: int) -> Mapping:
        """The scheduled guest's composed view live at trace step ``t``."""
        gid = self.guest_ids[self.segment_at(t)]
        return self.composed(gid, self.guests[gid].epoch_at(t),
                             self.host.epoch_at(t))

    def _dirty_at(self, lo: int) -> Optional[np.ndarray]:
        """Union composed dirty set entering the union-grid boundary ``lo``
        (``None`` when no composed translation died).  ASID-blind by
        design: a shootdown invalidates a stale range for whichever VM
        cached it, exactly like the single-space dynamic worlds."""
        he0, he1 = self.host.epoch_at(lo - 1), self.host.epoch_at(lo)
        dirty = np.zeros(self.n_pages, bool)
        hit = False
        for gid, g in enumerate(self.guests):
            ge0, ge1 = g.epoch_at(lo - 1), g.epoch_at(lo)
            if ge0 == ge1 and he0 == he1:
                continue
            prev = self.composed(gid, ge0, he0).ppn
            cur = self.composed(gid, ge1, he1).ppn
            d = (prev != UNMAPPED) & (prev != cur)
            if d.any():
                dirty[: d.shape[0]] |= d
                hit = True
        return dirty if hit else None

    def plan_segments(self) -> Tuple[NestedSegment, ...]:
        """Flatten schedule × guest epochs × host epochs into the union
        grid (memoized).  Both the oracle and the lane engine consume
        exactly this plan, so a dirty set or a switch can never differ
        between executors."""
        if self._segments_cache is not None:
            return self._segments_cache
        grid = set(self.boundaries) | set(self.host.boundaries)
        for g in self.guests:
            grid.update(g.boundaries)
        segs = []
        prev_gid = None
        for lo in sorted(grid):
            s = self.segment_at(lo)
            gid = self.guest_ids[s]
            comp = self.composed(gid, self.guests[gid].epoch_at(lo),
                                 self.host.epoch_at(lo))
            segs.append(NestedSegment(
                lo=int(lo), guest_id=gid, asid=self.asids[s],
                switch=prev_gid is not None and gid != prev_gid,
                recycled=self.recycled[s] and lo == self.boundaries[s],
                mapping=comp,
                dirty=self._dirty_at(lo) if lo > 0 else None))
            prev_gid = gid
        out = tuple(segs)
        object.__setattr__(self, "_segments_cache", out)
        return out

    def merged_contiguity_histogram(self) -> Dict[int, int]:
        """Union histogram over the initial composed views — what a
        hypervisor aggregating per-VM contiguity stats feeds Algorithm 3."""
        hist: Dict[int, int] = {}
        for gid in range(self.n_guests):
            for size, freq in contiguity_histogram(
                    self.composed(gid, 0, 0)).items():
                hist[size] = hist.get(size, 0) + freq
        return hist


def _as_dynamic_layer(m) -> DynamicMapping:
    if isinstance(m, DynamicMapping):
        return m
    return DynamicMapping((m,), (0,), name=m.name)


def build_nested_mapping(guests, host,
                         schedule: Sequence[Tuple[int, int, int]],
                         name: str = "nested") -> NestedMapping:
    """Build a :class:`NestedMapping` from ``(t, guest_id, asid)`` triples
    (strictly ascending ``t``, first at 0; consecutive identical segments
    merged like :func:`build_multitenant_mapping`).  ``guests`` entries and
    ``host`` may be plain :class:`Mapping`\\ s — each is wrapped as a
    single-epoch :class:`DynamicMapping` layer."""
    assert schedule and schedule[0][0] == 0
    bounds: List[int] = []
    gids: List[int] = []
    asids: List[int] = []
    for t, gid, asid in schedule:
        if bounds and gids[-1] == gid and asids[-1] == asid:
            continue
        bounds.append(int(t))
        gids.append(int(gid))
        asids.append(int(asid))
    return NestedMapping(tuple(_as_dynamic_layer(g) for g in guests),
                         _as_dynamic_layer(host), tuple(bounds),
                         tuple(gids), tuple(asids), name=name)


@dataclasses.dataclass(frozen=True)
class ParityWorld:
    """A base world plus a schedule of mid-trace TLB parity-flip faults.

    Soft errors poison *live TLB state*, not the page table: at trace step
    ``t`` a parity fault flips a bit in whatever entry currently covers
    ``vpn``.  The mapping itself stays correct, so the world wraps any
    existing base world — static :class:`Mapping`, :class:`DynamicMapping`,
    :class:`MultiTenantMapping` or :class:`NestedMapping` — unchanged, and
    only adds the fault schedule.  What a fault *costs* is the method's
    :attr:`~repro.core.simulator.MethodSpec.par_policy`:

    * ``"parity"`` — detect-invalidate-rewalk.  The flipped entry (and any
      other entry covering the vpn) is invalidated; a coalesced |K|=k
      entry thereby loses up to ``2^k`` translations where Base loses one.
      That multiplied blast radius is the paper-grounded robustness trade
      of coalescing.
    * ``"ecc"`` — idealized in-place correction: no entry is lost and the
      run is bit-identical to the fault-free run by construction.

    ``faults`` is a tuple of ``(step, vpn)`` pairs with strictly ascending
    steps.  Steps must be positive and must not collide with the base
    world's own segment boundaries — a fault step becomes an extra segment
    boundary when lowered, and a collision would silently merge the fault
    with an epoch turnover or context switch.
    """

    base: object                   # Mapping | Dynamic/MultiTenant/Nested
    faults: Tuple[Tuple[int, int], ...]
    name: str = "parity"

    def __post_init__(self):
        assert not isinstance(self.base, ParityWorld), "no nesting"
        faults = tuple((int(t), int(v)) for t, v in self.faults)
        object.__setattr__(self, "faults", faults)
        steps = [t for t, _ in faults]
        assert steps == sorted(set(steps)), \
            f"fault steps must be strictly ascending: {steps}"
        assert all(t > 0 for t in steps), f"fault steps must be > 0: {steps}"
        assert all(v >= 0 for _, v in faults), "fault vpns must be mapped"
        clash = set(steps) & set(self.base_boundaries())
        assert not clash, \
            f"fault steps collide with base segment boundaries: {clash}"

    def base_boundaries(self) -> Tuple[int, ...]:
        """Trace positions where the BASE world already turns a segment."""
        if isinstance(self.base, (DynamicMapping, MultiTenantMapping)):
            return tuple(self.base.boundaries)
        if isinstance(self.base, NestedMapping):
            return tuple(sg.lo for sg in self.base.plan_segments())
        return (0,)


def cluster_bitmap(m: Mapping, cluster_bits: int = 3) -> np.ndarray:
    """Per-vpn bitmap for the Cluster TLB [Pham et al., HPCA'14].

    For each vpn, bit ``j`` of ``bitmap[vpn]`` is set when page ``j`` of the
    8-page virtual window containing ``vpn`` maps into the *same* aligned
    physical cluster as ``vpn`` itself (ppn >> cluster_bits equal).
    """
    n = m.n_pages
    w = 1 << cluster_bits
    pad = (-n) % w
    ppn = np.concatenate([m.ppn, np.full(pad, UNMAPPED, np.int64)])
    win = ppn.reshape(-1, w)                      # [n_win, w]
    pclus = np.where(win != UNMAPPED, win >> cluster_bits, -2)
    # bitmap from the perspective of each page in the window
    same = pclus[:, :, None] == pclus[:, None, :]   # [n_win, w(self), w(other)]
    bits = (same & (pclus[:, None, :] >= 0)) << np.arange(w)[None, None, :]
    bm = bits.sum(axis=2).astype(np.int64).reshape(-1)[:n]
    return np.where(m.ppn != UNMAPPED, bm, 0)
