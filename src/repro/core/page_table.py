"""Contiguity-annotated page table (paper §2, Definition 1 and §3.1).

A memory mapping for a process is modelled as a dense array ``ppn`` over a
virtual footprint of ``n_pages`` pages: ``ppn[vpn]`` is the physical page
number backing virtual page ``vpn`` (``-1`` = unmapped).

From ``ppn`` we derive, exactly as the paper's OS would by scanning the page
table:

* ``run_start[vpn]`` / ``run_len[vpn]``: the *contiguity chunk* (Def. 1)
  containing ``vpn`` — the maximal range of pages contiguous in both VA and
  PA.  The per-PTE ``contiguity`` field of §3.1 is
  ``run_start[vpn] + run_len[vpn] - vpn``.
* the contiguity-chunk list and the contiguity histogram used by Algorithm 3.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

UNMAPPED = -1


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (1 for n <= 1)."""
    return 1 if n <= 1 else 1 << int(n - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class Mapping:
    """A virtual→physical page mapping with derived contiguity metadata."""

    ppn: np.ndarray        # int64[n_pages], -1 where unmapped
    run_start: np.ndarray  # int64[n_pages], start vpn of containing chunk
    run_len: np.ndarray    # int64[n_pages], size of containing chunk
    name: str = "mapping"

    @property
    def n_pages(self) -> int:
        return int(self.ppn.shape[0])

    def contiguity(self, vpn) -> np.ndarray:
        """Per-PTE contiguity field (§3.1): pages contiguously mapped starting
        at ``vpn``, *including* ``vpn`` itself.  0 for unmapped pages."""
        vpn = np.asarray(vpn)
        mapped = self.ppn[vpn] != UNMAPPED
        return np.where(mapped, self.run_start[vpn] + self.run_len[vpn] - vpn, 0)


def compute_runs(ppn: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized contiguity-chunk extraction.

    A chunk boundary occurs at ``i`` when ``ppn[i] != ppn[i-1] + 1`` or when
    either side is unmapped.
    """
    ppn = np.asarray(ppn, dtype=np.int64)
    n = ppn.shape[0]
    if n == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    mapped = ppn != UNMAPPED
    cont_with_prev = np.zeros(n, dtype=bool)
    cont_with_prev[1:] = mapped[1:] & mapped[:-1] & (ppn[1:] == ppn[:-1] + 1)
    # run id increments where a new run starts
    new_run = ~cont_with_prev
    run_id = np.cumsum(new_run) - 1
    starts = np.flatnonzero(new_run)
    run_start = starts[run_id]
    counts = np.bincount(run_id)
    run_len = counts[run_id]
    # unmapped pages belong to no chunk
    run_len = np.where(mapped, run_len, 0)
    run_start = np.where(mapped, run_start, np.arange(n))
    return run_start.astype(np.int64), run_len.astype(np.int64)


def make_mapping(ppn: np.ndarray, name: str = "mapping") -> Mapping:
    run_start, run_len = compute_runs(ppn)
    return Mapping(ppn=np.asarray(ppn, np.int64), run_start=run_start,
                   run_len=run_len, name=name)


def contiguity_chunks(m: Mapping) -> List[Tuple[int, int]]:
    """All contiguity chunks as ``(start_vpn, size)`` (Definition 1)."""
    mapped = m.ppn != UNMAPPED
    starts = np.unique(m.run_start[mapped])
    return [(int(s), int(m.run_len[s])) for s in starts]


def contiguity_histogram(m: Mapping) -> Dict[int, int]:
    """The OS-maintained contiguity histogram (paper §3.3): chunk size → count.

    Mirrors the structure consumed by Algorithm 3: a list of (size, freq).
    """
    chunks = contiguity_chunks(m)
    hist: Dict[int, int] = {}
    for _, size in chunks:
        hist[size] = hist.get(size, 0) + 1
    return hist


def huge_page_backed(m: Mapping) -> np.ndarray:
    """bool[n_pages]: vpn lies inside a promotable 2MB huge page.

    THP can promote a 512-page window when (a) the window is fully contiguous
    and (b) the physical base is itself 512-aligned (x86 2MB pages require
    PA alignment).
    """
    n = m.n_pages
    base = np.arange(n, dtype=np.int64) & ~np.int64(511)
    ok = base + 512 <= n
    b = np.minimum(base, n - 1)
    contig_at_base = np.where(m.ppn[b] != UNMAPPED,
                              m.run_start[b] + m.run_len[b] - b, 0)
    aligned_pa = (m.ppn[b] & 511) == 0
    return ok & (contig_at_base >= 512) & aligned_pa


def cluster_bitmap(m: Mapping, cluster_bits: int = 3) -> np.ndarray:
    """Per-vpn bitmap for the Cluster TLB [Pham et al., HPCA'14].

    For each vpn, bit ``j`` of ``bitmap[vpn]`` is set when page ``j`` of the
    8-page virtual window containing ``vpn`` maps into the *same* aligned
    physical cluster as ``vpn`` itself (ppn >> cluster_bits equal).
    """
    n = m.n_pages
    w = 1 << cluster_bits
    pad = (-n) % w
    ppn = np.concatenate([m.ppn, np.full(pad, UNMAPPED, np.int64)])
    win = ppn.reshape(-1, w)                      # [n_win, w]
    pclus = np.where(win != UNMAPPED, win >> cluster_bits, -2)
    # bitmap from the perspective of each page in the window
    same = pclus[:, :, None] == pclus[:, None, :]   # [n_win, w(self), w(other)]
    bits = (same & (pclus[:, None, :] >= 0)) << np.arange(w)[None, None, :]
    bm = bits.sum(axis=2).astype(np.int64).reshape(-1)[:n]
    return np.where(m.ppn != UNMAPPED, bm, 0)
