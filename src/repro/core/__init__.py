"""Core library: the paper's contribution — K-bit Aligned TLB coalescing.

Faithful reproduction layer:
  * :mod:`repro.core.page_table`   — contiguity-annotated page tables (Def. 1)
  * :mod:`repro.core.aligned`      — K-bit aligned entries, Algorithms 1-2
  * :mod:`repro.core.determine_k`  — Algorithm 3 (Table 1 size ranges)
  * :mod:`repro.core.simulator`    — unified trace-driven TLB engine
  * :mod:`repro.core.sweep`        — batched methods×traces sweep engine
  * :mod:`repro.core.baselines`    — Base/THP/COLT/Cluster/RMM/Anchor specs
  * :mod:`repro.core.mappings`     — Table-3 synthetic + demand mappings
  * :mod:`repro.core.traces`       — benchmark access-pattern analogues
"""
from .aligned import (Entry, ReferenceTLB, aligned_lookup, aligned_vpn,
                      alignment_class, covers, fill_select,
                      simulate_reference, stored_contiguity)
from .baselines import (anchor_spec, anchor_static, base_spec, cluster_spec,
                        colt_spec, kaligned_for_mapping, kaligned_spec,
                        rmm_spec, standard_suite, thp_spec)
from .determine_k import SIZE_RANGE_TABLE, determine_k, f_alignment
from .mappings import BuddyAllocator, demand_mapping, synthetic_mapping
from .page_table import (DynamicMapping, Mapping, MappingEvent,
                         MultiTenantMapping, apply_event,
                         build_dynamic_mapping, build_multitenant_mapping,
                         compute_runs, contiguity_chunks,
                         contiguity_histogram, dynamic_from_snapshots,
                         events_from_diff, huge_page_backed, make_mapping)
from .simulator import (MethodSpec, SimResult, miss_chain_cycles, run_method,
                        run_method_dynamic, run_method_multitenant)
from .sweep import SweepCell, SweepResult, run_sweep
from .traces import BENCHMARKS, benchmark_trace, generate_trace
