"""Memory-mapping generators (paper §2.2, §4.1 and Table 3).

Synthetic mappings restrict chunk sizes to a range (Table 3):

* small   — 1..63 pages
* medium  — 64..511 pages
* large   — 512..1024 pages
* mixed   — 0.4 small + 0.4 medium + 0.2 large (by chunk count)

``demand_mapping`` emulates Linux demand paging through a buddy allocator with
churn, producing the *mixed contiguity* the paper measures on real machines
(Figs 2–3): a long-running buddy system serves allocations from power-of-two
free lists, so a warmed-up process sees chunks of many coexisting sizes.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from .determine_k import f_alignment
from .page_table import Mapping, make_mapping

SYNTH_RANGES = {
    "small": (1, 63),
    "medium": (64, 511),
    "large": (512, 1024),
}
MIXED_WEIGHTS = (("small", 0.4), ("medium", 0.4), ("large", 0.2))


def _va_alignment_of(size: int, cap_bits: int = 11) -> int:
    """VA alignment (pages) a chunk of ``size`` naturally lands on.

    OS allocators place extents at boundaries of their covering power of two
    (buddy blocks are order-aligned; THP-aware faulting aligns VMAs): the
    paper's own examples (Fig 4: size-6 chunk at VPN 8, size-3 at VPN 4) all
    assume this.  We align to the Table-1 matching alignment so a chunk is
    coverable by a single k-bit aligned entry — the regime the paper's §3.3
    ("every contiguity chunk covered by its matching aligned entry") targets.
    """
    k = f_alignment(size)
    if k < 0:
        return 1
    return 1 << min(k, cap_bits)


def _layout(chunks: List[int], rng: np.random.Generator,
            pa_align: bool = False, va_align: bool = True) -> np.ndarray:
    """Place chunks at (aligned) VA offsets, scattered in PA.

    Each chunk gets a physical base; chunk order is shuffled in PA and a
    one-page guard gap inserted so virtually-adjacent chunks are never
    physically adjacent (otherwise they would merge into one chunk).
    With ``pa_align`` the PA base of each chunk is rounded up to the chunk's
    power-of-two (gives THP/huge-page-promotable layouts).  With ``va_align``
    each chunk's VA base is aligned per ``_va_alignment_of`` (padding pages
    stay unmapped).
    """
    order = rng.permutation(len(chunks))
    pa_base = np.zeros(len(chunks), dtype=np.int64)
    cursor = np.int64(rng.integers(0, 512))
    for idx in order:
        size = chunks[idx]
        if pa_align:
            align = 1 << int(np.ceil(np.log2(max(size, 1))))
            cursor = (cursor + align - 1) & ~np.int64(align - 1)
        pa_base[idx] = cursor
        cursor += size + 1  # guard page: forces PA discontiguity at boundary

    va_base = np.zeros(len(chunks), dtype=np.int64)
    vp = np.int64(0)
    for idx, size in enumerate(chunks):
        if va_align:
            a = _va_alignment_of(size)
            vp = (vp + a - 1) & ~np.int64(a - 1)
        va_base[idx] = vp
        vp += size
    ppn = np.full(int(vp), -1, dtype=np.int64)
    for idx, size in enumerate(chunks):
        v = va_base[idx]
        ppn[v:v + size] = pa_base[idx] + np.arange(size)
    return ppn


def _draw_sizes(kind: str, n_pages: int, rng: np.random.Generator) -> List[int]:
    sizes: List[int] = []
    total = 0
    names = [k for k, _ in MIXED_WEIGHTS]
    probs = np.array([w for _, w in MIXED_WEIGHTS])
    while total < n_pages:
        k = kind if kind != "mixed" else names[rng.choice(len(names), p=probs)]
        lo, hi = SYNTH_RANGES[k]
        s = int(rng.integers(lo, hi + 1))
        s = min(s, n_pages - total)
        sizes.append(s)
        total += s
    return sizes


def synthetic_mapping(kind: str, n_pages: int, seed: int = 0,
                      pa_align: bool = True, va_align: bool = True) -> Mapping:
    """Table 3 synthetic mapping with chunk sizes drawn from ``kind``.

    ``n_pages`` counts *mapped* pages; with ``va_align`` the virtual footprint
    is slightly larger (alignment holes are unmapped).
    """
    if kind not in ("small", "medium", "large", "mixed"):
        raise ValueError(f"unknown synthetic mapping kind: {kind}")
    rng = np.random.default_rng(seed)
    sizes = _draw_sizes(kind, n_pages, rng)
    ppn = _layout(sizes, rng, pa_align=pa_align, va_align=va_align)
    return make_mapping(ppn, name=f"synth-{kind}")


def mapped_vpns(m: Mapping) -> np.ndarray:
    """VPNs of mapped pages, for trace generation over sparse footprints."""
    return np.flatnonzero(m.ppn >= 0).astype(np.int64)


class BuddyAllocator:
    """Minimal binary-buddy physical allocator (order 0..max_order).

    Used both by ``demand_mapping`` (to emulate the OS) and by the paged
    KV-cache allocator in :mod:`repro.kvcache.allocator` (the TPU adaptation).
    """

    def __init__(self, n_frames: int, max_order: int = 10):
        self.max_order = max_order
        block = 1 << max_order
        n_frames = (n_frames // block) * block
        self.n_frames = n_frames
        self.free: List[set] = [set() for _ in range(max_order + 1)]
        for base in range(0, n_frames, block):
            self.free[max_order].add(base)

    def alloc(self, order: int) -> Optional[int]:
        for o in range(order, self.max_order + 1):
            if self.free[o]:
                base = min(self.free[o])
                self.free[o].discard(base)
                # split down to requested order
                while o > order:
                    o -= 1
                    self.free[o].add(base + (1 << o))
                return base
        return None

    def free_block(self, base: int, order: int) -> None:
        # coalesce with buddy while possible
        while order < self.max_order:
            buddy = base ^ (1 << order)
            if buddy in self.free[order]:
                self.free[order].discard(buddy)
                base = min(base, buddy)
                order += 1
            else:
                break
        self.free[order].add(base)

    def frag_stats(self) -> Tuple[int, int]:
        free_frames = sum(len(s) << o for o, s in enumerate(self.free))
        largest = max((o for o, s in enumerate(self.free) if s), default=-1)
        return free_frames, largest

    # ---------------------------------------------------------- robustness
    def snapshot(self) -> List[List[int]]:
        """Free lists as plain sorted lists (JSON-serializable), one per
        order — the allocator's complete mutable state."""
        return [sorted(s) for s in self.free]

    def restore(self, freelists: List[List[int]]) -> None:
        assert len(freelists) == len(self.free)
        self.free = [set(int(b) for b in fl) for fl in freelists]

    def retire(self, frame: int) -> bool:
        """Permanently remove one FREE frame from the pool (bad page).

        Splits the free block containing ``frame`` down to order 0 and
        drops the poisoned frame; its buddies stay allocatable.  Returns
        False when the frame is currently allocated (or already retired) —
        the caller must free its owner first.  ``n_frames`` is unchanged,
        so a retired frame counts as permanently in-use."""
        for o in range(self.max_order + 1):
            base = (frame >> o) << o       # buddy blocks are size-aligned
            if base in self.free[o]:
                self.free[o].discard(base)
                while o > 0:
                    o -= 1
                    half = 1 << o
                    if frame < base + half:
                        self.free[o].add(base + half)
                    else:
                        self.free[o].add(base)
                        base += half
                return True
        return False


def demand_mapping(n_pages: int, seed: int = 0, churn: float = 0.3,
                   thp: bool = False) -> Mapping:
    """Emulated demand-paged mapping from a churned buddy allocator.

    ``churn`` controls fragmentation: fraction of interleaved alloc/free
    traffic before the process' own allocations, mirroring a long-running
    system (paper §2.1).  With ``thp`` the allocator prefers order-9 (2MB)
    blocks when the requested span is large, as Linux THP would.
    """
    rng = np.random.default_rng(seed)
    buddy = BuddyAllocator(n_frames=4 * n_pages, max_order=11)

    # Warm-up churn: scatter small in-use allocations, free a random subset.
    held: List[Tuple[int, int]] = []
    n_churn = int(churn * n_pages / 8)
    for _ in range(n_churn):
        order = int(rng.choice([0, 1, 2, 3], p=[0.5, 0.25, 0.15, 0.1]))
        base = buddy.alloc(order)
        if base is not None:
            held.append((base, order))
    rng.shuffle(held)
    for base, order in held[: len(held) // 2]:
        buddy.free_block(base, order)

    # The process' allocations: VA is filled left to right, each extent at its
    # order-aligned VA boundary (buddy/THP-style aligned faulting); the OS
    # serves each request with the largest available buddy block.
    blocks: List[Tuple[int, int]] = []   # (pa_base, n)
    mapped = 0
    while mapped < n_pages:
        want = n_pages - mapped
        max_req_order = 9 if thp else 11
        order = min(int(np.log2(max(want, 1))), max_req_order)
        # demand paging rarely asks for one giant block; mix request sizes
        order = int(rng.integers(0, order + 1)) if not thp else order
        base = None
        while base is None and order >= 0:
            base = buddy.alloc(order)
            if base is None:
                order -= 1
        if base is None:
            raise RuntimeError("buddy allocator exhausted")
        n = min(1 << order, want)
        blocks.append((base, n))
        mapped += n
    vp = np.int64(0)
    spans = []
    for base, n in blocks:
        a = 1 << int(np.ceil(np.log2(n))) if n > 1 else 1
        vp = (vp + a - 1) & ~np.int64(a - 1)
        spans.append((int(vp), base, n))
        vp += n
    ppn = np.full(int(vp), -1, dtype=np.int64)
    for v, base, n in spans:
        ppn[v:v + n] = base + np.arange(n)
    return make_mapping(ppn, name=f"demand{'-thp' if thp else ''}")
