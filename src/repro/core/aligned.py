"""K-bit aligned page-table entries — reference semantics (paper §3.1–3.2).

This module is the *pure-python oracle* for the vectorized/JAX simulator in
:mod:`repro.core.simulator` and for the device-side translation used by the
paged KV cache.  Every rule here is deliberately written as close to the
paper's prose as possible.

Notes on fidelity:

* **Rightward Compatible Rule** — an entry aligned for several k ∈ K is
  labelled with the maximum such k (`alignment_class`).
* **Stored contiguity** — a k-bit aligned entry records the number of pages
  contiguously mapped in the following 2^k pages *including itself*
  (`stored_contiguity`), i.e. ``min(contiguity(vpn_k), 2**k)``.
* **Coverage test** — the paper's Algorithms 1–2 write
  ``Entry.contiguity >= (VPN - VPN_k)``; with contiguity *including* the
  aligned page itself (Fig. 4/5: VPN 8 covers VPN 13 with contiguity 6,
  diff 5) the consistent test is ``contiguity > diff``.  We implement
  ``contiguity > diff`` and treat the paper's ``>=`` as an off-by-one in the
  pseudo-code; all of the paper's worked examples agree with ``>``.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import List, Optional, Sequence, Tuple

from .page_table import Mapping, UNMAPPED

REGULAR = -1  # k-class tag for a non-coalesced entry


def aligned_vpn(vpn: int, k: int) -> int:
    """Clear the k LSBs of vpn (the k-bit aligned VPN)."""
    return vpn & ~((1 << k) - 1)


def alignment_class(vpn: int, K: Sequence[int]) -> int:
    """Rightward Compatible Rule: the max k in K for which vpn is k-aligned;
    REGULAR (-1) if none."""
    best = REGULAR
    for k in K:
        if vpn & ((1 << k) - 1) == 0 and k > best:
            best = k
    return best


def stored_contiguity(m: Mapping, vpn_k: int, k: int) -> int:
    """Contiguity recorded by the k-bit aligned entry at vpn_k (§3.1)."""
    if vpn_k >= m.n_pages or m.ppn[vpn_k] == UNMAPPED:
        return 0
    return int(min(m.contiguity(vpn_k), 1 << k))


def covers(m: Mapping, vpn: int, vpn_k: int, k: int) -> bool:
    """Does the aligned entry at (vpn_k, k) translate vpn?"""
    return stored_contiguity(m, vpn_k, k) > (vpn - vpn_k)


@dataclasses.dataclass(frozen=True)
class Entry:
    """A (possibly coalesced) translation entry as held in the L2 TLB."""

    tag: int          # vpn of the entry (aligned vpn for k >= 0)
    kcls: int         # alignment class; REGULAR for a plain 4KB entry
    contiguity: int   # pages covered starting at tag (1 for regular)
    ppn: int          # physical page of `tag`

    def translate(self, vpn: int) -> Optional[int]:
        diff = vpn - self.tag
        if 0 <= diff < self.contiguity:
            return self.ppn + diff
        return None


def fill_select(m: Mapping, vpn: int, K: Sequence[int]) -> Entry:
    """Algorithm 1 — choose the entry inserted into L2 after a page walk.

    Probes aligned entries in descending k and returns the first whose stored
    contiguity covers ``vpn``; otherwise the regular entry for ``vpn``.
    """
    for k in sorted(K, reverse=True):
        vk = aligned_vpn(vpn, k)
        if covers(m, vpn, vk, k):
            return Entry(tag=vk, kcls=k,
                         contiguity=stored_contiguity(m, vk, k),
                         ppn=int(m.ppn[vk]))
    return Entry(tag=vpn, kcls=REGULAR, contiguity=1, ppn=int(m.ppn[vpn]))


def aligned_lookup(entries: Sequence[Entry], vpn: int, K: Sequence[int],
                   first_k: Optional[int] = None) -> Tuple[Optional[int], int, Optional[int]]:
    """Algorithm 2 — aligned lookup over a set of resident entries.

    Probes alignments ``first_k`` (the predictor's guess, §3.2) then the rest
    of K in descending order.  Returns ``(ppn | None, n_probes, hit_k)``.
    """
    order: List[int] = []
    if first_k is not None and first_k in K:
        order.append(first_k)
    order += [k for k in sorted(K, reverse=True) if k not in order]
    probes = 0
    for k in order:
        probes += 1
        vk = aligned_vpn(vpn, k)
        for e in entries:
            if e.kcls == k and e.tag == vk and e.contiguity > (vpn - vk):
                return e.ppn + (vpn - vk), probes, k
    return None, probes, None


class ReferenceTLB:
    """Fully-associative LRU TLB over :class:`Entry` — the miss-count oracle.

    Set-associativity is modelled by the JAX engine; this reference uses full
    associativity so property tests can check *translation correctness* and
    upper-bound behaviour of the engine independent of set-index choices.
    """

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.entries: "OrderedDict[Tuple[int, int], Entry]" = OrderedDict()

    def probe_regular(self, vpn: int) -> Optional[Entry]:
        e = self.entries.get((vpn, REGULAR))
        if e is not None:
            self.entries.move_to_end((vpn, REGULAR))
        return e

    def probe_aligned(self, vpn: int, K: Sequence[int],
                      first_k: Optional[int] = None) -> Tuple[Optional[int], int]:
        order: List[int] = []
        if first_k is not None and first_k in K:
            order.append(first_k)
        order += [k for k in sorted(K, reverse=True) if k not in order]
        probes = 0
        for k in order:
            probes += 1
            vk = aligned_vpn(vpn, k)
            e = self.entries.get((vk, k))
            if e is not None and e.contiguity > (vpn - vk):
                self.entries.move_to_end((vk, k))
                return e.ppn + (vpn - vk), probes
        return None, probes

    def insert(self, e: Entry) -> None:
        key = (e.tag, e.kcls)
        if key in self.entries:
            self.entries.move_to_end(key)
        self.entries[key] = e
        while len(self.entries) > self.capacity:
            self.entries.popitem(last=False)

    def coverage(self) -> int:
        """Table 5 metric: entries + extra pages covered by coalescing."""
        return sum(e.contiguity for e in self.entries.values())


def simulate_reference(m: Mapping, trace: Sequence[int], K: Sequence[int],
                       capacity: int = 1024) -> dict:
    """End-to-end reference simulation (no L1, fully-associative L2).

    Used by property tests as the oracle for the JAX engine and by unit tests
    to sanity-check Algorithms 1–3 against the paper's worked examples.
    """
    tlb = ReferenceTLB(capacity)
    walks = reg_hits = al_hits = probes_total = pred_correct = 0
    pred_k: Optional[int] = None
    for vpn in trace:
        vpn = int(vpn)
        e = tlb.probe_regular(vpn)
        if e is not None:
            reg_hits += 1
            continue
        ppn, probes = tlb.probe_aligned(vpn, K, first_k=pred_k)
        if ppn is not None:
            al_hits += 1
            probes_total += probes
            if probes == 1:
                pred_correct += 1
            # record the alignment that hit, for the 4-bit predictor
            for k in ([pred_k] if pred_k is not None else []) + sorted(K, reverse=True):
                if k is not None and covers(m, vpn, aligned_vpn(vpn, k), k):
                    pred_k = k
                    break
            assert ppn == int(m.ppn[vpn]), "aligned translation must be exact"
            continue
        walks += 1
        ins = fill_select(m, vpn, K)
        if ins.kcls != REGULAR:
            pred_k = ins.kcls
        tlb.insert(ins)
    return dict(walks=walks, regular_hits=reg_hits, aligned_hits=al_hits,
                probes=probes_total, pred_correct=pred_correct,
                coverage=tlb.coverage())
