"""Batched sweep engine: every method × trace as one vmapped simulation.

:func:`repro.core.simulator.run_method` simulates one ``(spec, mapping,
trace)`` triple per call and re-compiles for every distinct ``MethodSpec``
and every distinct array shape.  A paper-scale sweep (7+ methods × 16
benchmarks × several |K| / seed settings) pays that compile cost hundreds of
times.  This module instead *pads every method onto one common array layout*
so that all of ``base/thp/colt/cluster/rmm/anchor/kaligned`` run as rows of a
single ``jax.vmap``-ed set-associative scan, compiled once per shape bucket
and reused across traces and seeds:

* L2 arrays are padded to the max ``(l2_sets, l2_ways)`` of the batch; padded
  ways carry ``INVALID`` k-classes and a ``+BIG`` victim score so they can
  neither hit nor be chosen for fill.
* ``K`` is padded to the max ``|K|`` with inert ``-1`` alignment classes
  whose probes are masked out.
* The THP 2MB L1 array, the RMM range TLB, and the clustered side TLB are
  always present in the carried state but gated per lane by ``has_*`` flags
  (they are tiny next to L2, so inert lanes cost almost nothing).
* Traces are stacked and padded to a common length; padded steps are fully
  masked (no state writes, no counter increments), which keeps every lane
  bit-exact with its per-call :func:`run_method` equivalent.

Every per-method *static* attribute of the specialized engine (kind, side,
predictor, miss-chain latency, set mask, index shift) becomes per-lane
*data*, so one compiled program serves the whole sweep.

Two structural optimizations make the batched step fast on CPU (where each
vmapped point-scatter is a per-lane loop):

* each TLB structure lives in ONE packed array with a trailing field axis
  (L2 is ``[sets, ways, 5]`` = tag/k/contig/ppn/lru), so a fill is a single
  row scatter instead of five;
* fill selection (Algorithm 1, the COLT window clip, THP promotion) depends
  only on ``(mapping, fill policy, vpn)`` — it is precomputed *outside* the
  scan as a per-vpn record and becomes one gather inside the step.

Dynamic worlds (:class:`~repro.core.page_table.DynamicMapping`) run as
**epoch-segmented lanes** of the same program: map/fill/cluster records are
precomputed per ``(world, epoch)``, the scan is split at the static union
of all lanes' epoch boundaries, and between segments a vectorized shootdown
pass — gated per lane by whether its epoch turned over — invalidates every
entry (in L1, the 2MB L1, L2, the RMM range TLB and the clustered side-TLB)
whose covered vpn range contains a page whose translation died, via a range
query against the epoch's dirty-bitmap prefix sums.  Static cells are
1-epoch worlds, so mixed sweeps still compile once; every dynamic lane is
bit-exact against the pure-python epoch-aware oracle
:func:`repro.core.simulator.run_method_dynamic`.

When JAX exposes several (virtual) host devices, lanes are additionally
sharded across them with ``pmap`` — ``benchmarks/_env.py`` turns that on for
benchmark runs.

:func:`run_sweep` is the orchestrator: it dedups mappings/traces, packs
lanes, consults an on-disk result cache under ``results/sweep_cache`` keyed
by ``(spec, mapping hash, trace hash, git describe)``, simulates only the
missing cells, and returns per-cell :class:`~repro.core.simulator.SimResult`
objects bit-identical to the per-call oracle.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import subprocess
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .page_table import (DynamicMapping, Mapping, cluster_bitmap,
                         huge_page_backed, next_pow2 as _next_pow2)
from .simulator import (CLUS_SETS, CLUS_WAYS, HUGE, INVALID, L1_SETS, L1_WAYS,
                        L1H_SETS, L1H_WAYS, LAT_COAL, LAT_EXTRA_PROBE,
                        LAT_INVALIDATE, LAT_L2_REG, LAT_SHOOTDOWN, LAT_WALK,
                        N_COV_SAMPLES, NEG, REGULAR, RMM_ENTRIES, MethodSpec,
                        SimResult, miss_chain_cycles)

BIG = 2**30  # victim score for padded ways: never evictable

# Shape buckets: pad so repeated sweeps of similar size reuse the same
# compiled executable instead of specializing on exact lane/trace/page counts.
LANE_BUCKET = 8
TRACE_BUCKET = 4096

# packed-field indices
TAG, KCLS, CONTIG, PPN, LRU = 0, 1, 2, 3, 4          # L2: [S, W, 5]
# L1/L1H: [sets, ways, 3] = tag, ppn, lru
# RMM:    [32, 4]         = start, len, ppn, lru
# CLUS:   [64, 5, 3]      = tag, bitmap, lru
# fill record: [P, 4]     = tag, k, contig, ppn      (one per world epoch)
# map record:  [P, 4]     = ppn, run_start, run_len, ppn[run_start]  (ditto)
# dirty record: [P+1]     = prefix sum of the epoch's dirty-vpn bitmap
# counters: [9] = l1_hits, reg_hits, coal_hits, walks, probes, pred_correct,
#                 cycles, cov, shootdowns
N_COUNTERS = 9
(C_L1, C_REG, C_COAL, C_WALK, C_PROBE, C_PRED, C_CYC, C_COV,
 C_SHOOT) = range(9)


@dataclasses.dataclass(frozen=True)
class SweepCell:
    """One cell of a sweep: simulate ``spec`` over ``(mapping, trace)``.

    * ``spec``    — a :class:`~repro.core.simulator.MethodSpec` (build one
      with the factories in :mod:`repro.core.baselines`); its static config
      becomes per-lane *data* in the batched engine, so cells with different
      specs still share one compiled program.
    * ``mapping`` — a contiguity-annotated
      :class:`~repro.core.page_table.Mapping`, **or** a
      :class:`~repro.core.page_table.DynamicMapping` whose epoch boundaries
      segment the trace (mid-trace remaps with shootdown-correct
      invalidation); get one from a registered scenario
      (:mod:`repro.scenarios`) or the generators in
      :mod:`repro.core.mappings`.
    * ``trace``   — 1-D integer array of VPNs (every entry must be a mapped
      page of the epoch live at that step).

    Mappings/traces shared between cells (by object identity) are packed and
    hashed once, so build each world once and reuse it across specs.
    """

    spec: MethodSpec
    mapping: "Mapping | DynamicMapping"
    trace: np.ndarray

    def __post_init__(self):
        assert self.trace.ndim == 1
        if isinstance(self.mapping, DynamicMapping):
            assert all(0 < b < self.trace.shape[0]
                       for b in self.mapping.boundaries[1:]), \
                "epoch boundaries must fall inside the trace"

    @property
    def epochs(self) -> Tuple[Mapping, ...]:
        if isinstance(self.mapping, DynamicMapping):
            return self.mapping.epochs
        return (self.mapping,)

    @property
    def boundaries(self) -> Tuple[int, ...]:
        if isinstance(self.mapping, DynamicMapping):
            return self.mapping.boundaries
        return (0,)


@dataclasses.dataclass
class SweepResult:
    """Per-cell results (aligned with the request list) plus run stats."""

    results: List[SimResult]
    stats: Dict[str, float]

    def __iter__(self):
        return iter(self.results)

    def __getitem__(self, i):
        return self.results[i]


# ---------------------------------------------------------------------------
# Precomputed per-vpn records (fill policy is trace-independent)
# ---------------------------------------------------------------------------


def _map_record(m: Mapping, P: int) -> np.ndarray:
    """[P, 4] int32: ppn, run_start, run_len, ppn[run_start] (RMM fill)."""
    n = m.n_pages
    rec = np.zeros((P, 4), np.int32)
    rec[:, 0] = -1
    rec[:n, 0] = m.ppn
    rec[:n, 1] = m.run_start
    rec[:n, 2] = m.run_len
    rec[:n, 3] = m.ppn[np.clip(m.run_start, 0, n - 1)]
    return rec


def _fill_profile_key(spec: MethodSpec):
    if spec.kind in ("kaligned", "anchor"):
        return ("ka", spec.K)
    if spec.kind in ("colt", "thp"):
        return (spec.kind,)
    return ("reg",)


def _fill_profile(m: Mapping, key, P: int) -> np.ndarray:
    """[P, 4] int32 fill record (tag, k, contig, ppn): what Algorithm 1 /
    COLT / THP / the regular policy would install on a walk at each vpn."""
    n = m.n_pages
    vpn = np.arange(n, dtype=np.int64)
    ppn = m.ppn
    rs, rl = m.run_start, m.run_len

    def contig_at(v):
        v = np.clip(v, 0, n - 1)
        return np.where(ppn[v] >= 0, rs[v] + rl[v] - v, 0)

    tag = vpn.copy()
    kcls = np.full(n, REGULAR, np.int64)
    contig = np.ones(n, np.int64)
    fppn = ppn.copy()
    if key[0] == "ka":
        chosen = np.zeros(n, bool)
        for k in key[1]:                    # descending; first cover wins
            vk = vpn & ~((1 << k) - 1)
            sc = np.minimum(contig_at(vk), 1 << k)
            take = (sc > (vpn - vk)) & ~chosen
            tag = np.where(take, vk, tag)
            kcls = np.where(take, k, kcls)
            contig = np.where(take, sc, contig)
            fppn = np.where(take, ppn[np.clip(vk, 0, n - 1)], fppn)
            chosen |= take
    elif key[0] == "colt":
        w8 = vpn & ~np.int64(7)
        re = rs + rl
        tag = np.maximum(rs, w8)
        contig = np.maximum(np.minimum(re, w8 + 8) - tag, 1)
        kcls = np.where(contig > 1, 3, REGULAR)
        fppn = ppn[np.clip(tag, 0, n - 1)]
    elif key[0] == "thp":
        huge = huge_page_backed(m)
        hv = vpn >> 9
        tag = np.where(huge, hv, vpn)
        kcls = np.where(huge, HUGE, REGULAR)
        contig = np.where(huge, 512, 1)
        fppn = ppn[np.clip(np.where(huge, hv << 9, vpn), 0, n - 1)]

    rec = np.zeros((P, 4), np.int32)
    rec[:n, 0] = tag
    rec[:n, 1] = kcls
    rec[:n, 2] = contig
    rec[:n, 3] = fppn
    rec[n:, 1] = REGULAR
    return rec


# ---------------------------------------------------------------------------
# Lane packing
# ---------------------------------------------------------------------------


def _pack_lanes(cells: Sequence[SweepCell]):
    """Dedup worlds/traces/fill-profiles; pack per-lane params to arrays.

    Every world is an epoch *sequence* (a static ``Mapping`` is one epoch);
    map/fill/cluster records are built per ``(world, epoch)`` and lanes carry
    a per-segment record index, so dynamic and static lanes share one
    compiled program.  The segment grid — the sorted union of every lane's
    epoch boundaries — is returned as a static tuple; between segments the
    engine runs the shootdown pass for lanes whose epoch turned over.
    """
    worlds: List = []
    world_index: Dict[int, int] = {}
    traces: List[np.ndarray] = []
    trace_index: Dict[int, int] = {}
    for c in cells:
        if id(c.mapping) not in world_index:
            world_index[id(c.mapping)] = len(worlds)
            worlds.append(c.mapping)
        if id(c.trace) not in trace_index:
            trace_index[id(c.trace)] = len(traces)
            traces.append(c.trace)

    all_epochs: Dict[int, Tuple[Mapping, ...]] = {
        w: (m.epochs if isinstance(m, DynamicMapping) else (m,))
        for w, m in enumerate(worlds)}
    all_bounds: Dict[int, Tuple[int, ...]] = {
        w: (m.boundaries if isinstance(m, DynamicMapping) else (0,))
        for w, m in enumerate(worlds)}

    P = _next_pow2(max(m.n_pages for ms in all_epochs.values() for m in ms))
    T = -(-max(t.shape[0] for t in traces) // TRACE_BUCKET) * TRACE_BUCKET

    # map records: one per (world, epoch)
    map_recs: List[np.ndarray] = []
    map_rec_id: Dict[Tuple[int, int], int] = {}
    for w, ms in all_epochs.items():
        for e, m in enumerate(ms):
            map_rec_id[(w, e)] = len(map_recs)
            map_recs.append(_map_record(m, P))

    # fill records: one per (world, epoch, fill profile)
    fill_recs: List[np.ndarray] = []
    fill_rec_id: Dict[Tuple[int, int, tuple], int] = {}
    for c in cells:
        w = world_index[id(c.mapping)]
        key = _fill_profile_key(c.spec)
        for e, m in enumerate(all_epochs[w]):
            fk = (w, e, key)
            if fk not in fill_rec_id:
                fill_rec_id[fk] = len(fill_recs)
                fill_recs.append(_fill_profile(m, key, P))

    # cluster bitmaps: one per (world, epoch), only if any lane needs them
    need_clus = any(c.spec.side == "cluster" for c in cells)
    clus_recs: List[np.ndarray] = [np.zeros(P if need_clus else 1, np.int32)]
    clus_rec_id: Dict[Tuple[int, int], int] = {}
    if need_clus:
        for c in cells:
            if c.spec.side != "cluster":
                continue
            w = world_index[id(c.mapping)]
            for e, m in enumerate(all_epochs[w]):
                if (w, e) not in clus_rec_id:
                    rec = np.zeros(P, np.int32)
                    rec[: m.n_pages] = cluster_bitmap(m)
                    clus_rec_id[(w, e)] = len(clus_recs)
                    clus_recs.append(rec)

    # dirty records (prefix sums): one per (world, epoch >= 1) with >=1 dirty
    dirty_recs: List[np.ndarray] = [np.zeros(P + 1, np.int32)]
    dirty_rec_id: Dict[Tuple[int, int], int] = {}
    for w, m in enumerate(worlds):
        if not isinstance(m, DynamicMapping):
            continue
        for e in range(1, m.n_epochs):
            if m.dirty_count(e) == 0:
                continue
            dc = np.zeros(P + 1, np.int32)
            np.cumsum(m.dirty(e), out=dc[1: m.n_pages + 1])
            dc[m.n_pages + 1:] = dc[m.n_pages]
            dirty_rec_id[(w, e)] = len(dirty_recs)
            dirty_recs.append(dc)

    trace_stack = np.zeros((len(traces), T), np.int32)
    for i, t in enumerate(traces):
        trace_stack[i, : t.shape[0]] = t

    # segment grid: union of all epoch boundaries, static per compile
    grid = sorted({int(b) for w in range(len(worlds))
                   for b in all_bounds[w][1:]})
    seg_bounds = tuple([0] + grid + [T])
    n_segs = len(seg_bounds) - 1

    L = -(-len(cells) // LANE_BUCKET) * LANE_BUCKET
    max_sets = max(c.spec.l2_sets for c in cells)
    max_ways = max(c.spec.l2_ways for c in cells)
    maxk = max([len(c.spec.K) for c in cells] + [1])

    lanes = dict(
        is_colt=np.zeros(L, bool), is_thp=np.zeros(L, bool),
        has_rmm=np.zeros(L, bool),
        has_cluster=np.zeros(L, bool), use_pred=np.zeros(L, bool),
        kvals=np.full((L, maxk), -1, np.int32),
        set_mask=np.zeros(L, np.int32), n_ways=np.ones(L, np.int32),
        k_hat=np.zeros(L, np.int32), miss_chain=np.zeros(L, np.int32),
        pred0=np.zeros(L, np.int32),
        seg_map=np.zeros((L, n_segs), np.int32),
        seg_fill=np.zeros((L, n_segs), np.int32),
        seg_clus=np.zeros((L, n_segs), np.int32),
        seg_shoot=np.zeros((L, n_segs), bool),
        seg_dirty=np.zeros((L, n_segs), np.int32),
        trace_id=np.zeros(L, np.int32), t_real=np.zeros(L, np.int32),
        sample_every=np.ones(L, np.int32),
    )
    for i, c in enumerate(cells):
        s = c.spec
        w = world_index[id(c.mapping)]
        bounds = all_bounds[w]
        key = _fill_profile_key(s)
        lanes["is_colt"][i] = s.kind == "colt"
        lanes["is_thp"][i] = s.kind == "thp"
        lanes["has_rmm"][i] = s.side == "rmm"
        lanes["has_cluster"][i] = s.side == "cluster"
        lanes["use_pred"][i] = s.use_predictor
        lanes["kvals"][i, : len(s.K)] = s.K
        lanes["set_mask"][i] = s.l2_sets - 1
        lanes["n_ways"][i] = s.l2_ways
        lanes["k_hat"][i] = s.index_shift
        lanes["miss_chain"][i] = miss_chain_cycles(s)
        lanes["pred0"][i] = s.K[0] if s.K else 0
        lanes["trace_id"][i] = trace_index[id(c.trace)]
        lanes["t_real"][i] = c.trace.shape[0]
        lanes["sample_every"][i] = max(c.trace.shape[0] // N_COV_SAMPLES, 1)
        for seg in range(n_segs):
            lo = seg_bounds[seg]
            e = int(np.searchsorted(bounds, lo, side="right") - 1)
            lanes["seg_map"][i, seg] = map_rec_id[(w, e)]
            lanes["seg_fill"][i, seg] = fill_rec_id[(w, e, key)]
            lanes["seg_clus"][i, seg] = clus_rec_id.get((w, e), 0)
            turned = seg > 0 and e >= 1 and lo == bounds[e]
            if turned and (w, e) in dirty_rec_id:
                lanes["seg_shoot"][i, seg] = True
                lanes["seg_dirty"][i, seg] = dirty_rec_id[(w, e)]
    stacks = dict(maps=np.stack(map_recs), fills=np.stack(fill_recs),
                  clus=np.stack(clus_recs), dirty=np.stack(dirty_recs),
                  trace=trace_stack)
    return lanes, stacks, (L, max_sets, max_ways), seg_bounds


def _init_batched_state(L: int, max_sets: int, max_ways: int, pred0):
    def packed(shape, init_tag):
        a = np.zeros(shape, np.int32)
        a[..., 0] = init_tag
        return a

    l2 = np.zeros((L, max_sets, max_ways, 5), np.int32)
    l2[..., TAG] = -1
    l2[..., KCLS] = INVALID
    l2[..., PPN] = -1
    return dict(
        t=np.zeros(L, np.int32),
        l1=packed((L, L1_SETS, L1_WAYS, 3), -1),
        l1h=packed((L, L1H_SETS, L1H_WAYS, 3), -1),
        l2=l2,
        rmm=packed((L, RMM_ENTRIES, 4), -1),
        clus=packed((L, CLUS_SETS, CLUS_WAYS, 3), -1),
        pred=np.asarray(pred0, np.int32).copy(),
        counters=np.zeros((L, N_COUNTERS), np.int32),
        cov_samples=np.zeros((L, N_COV_SAMPLES), np.int32),
    )


def _cond_set(arr, idx, value, pred):
    """In-place conditional point/row write (same trick as the oracle)."""
    old = arr[idx]
    return arr.at[idx].set(jnp.where(pred, value, old))


# ---------------------------------------------------------------------------
# The batched step: the union of every kind's datapath, selected per lane
# ---------------------------------------------------------------------------


def _run_lanes_impl(lanes, stacks, st0, seg_bounds):
    map_stack = stacks["maps"]
    fill_stack = stacks["fills"]
    clus_map = stacks["clus"]
    dirty_stack = stacks["dirty"]
    trace_stack = stacks["trace"]
    maxk = lanes["kvals"].shape[1]
    n_ways_total = st0["l2"].shape[2]
    way_idx = jnp.arange(n_ways_total, dtype=jnp.int32)
    Pn = dirty_stack.shape[1] - 1

    def one_lane(lane, st_init):
        set_mask = lane["set_mask"]
        k_hat = lane["k_hat"]
        kvals = lane["kvals"]
        is_colt, is_thp = lane["is_colt"], lane["is_thp"]
        is_generic = ~is_colt & ~is_thp
        has_rmm, has_cluster = lane["has_rmm"], lane["has_cluster"]
        use_pred = lane["use_pred"]
        way_ok = way_idx < lane["n_ways"]

        def probe_order(pred_k):
            """[pred_k, remaining K desc] when predicting, else K as packed
            (padded positions stay -1 and probe inertly)."""
            order = [jnp.where(use_pred, pred_k, kvals[0])]
            not_pred = kvals != pred_k
            csum = jnp.cumsum(not_pred.astype(jnp.int32))
            for pos in range(1, maxk):
                sel = not_pred & (csum == pos)
                spec_k = jnp.where(sel.any(), kvals[jnp.argmax(sel)],
                                   jnp.int32(-1))
                order.append(jnp.where(use_pred, spec_k, kvals[pos]))
            return order

        def make_step(mid, fid, cid):
            """Step closure for one segment: record ids are per-lane traced
            scalars selecting the live epoch's map/fill/cluster records."""
            def step(st, t_idx):
                return _step(st, t_idx, mid, fid, cid)
            return step

        def _step(st, t_idx, mid, fid, cid):
            t = st["t"]
            vpn = trace_stack[lane["trace_id"], t_idx]
            active = t_idx < lane["t_real"]
            mrec = map_stack[mid, vpn]          # ppn, rs, rl, ppn[rs]
            ppn_true, rs_v, rl_v, rmm_fill_ppn = (mrec[0], mrec[1], mrec[2],
                                                  mrec[3])
            frec = fill_stack[fid, vpn]         # tag, k, contig, ppn
            fill_tag, fill_k, fill_contig, fill_ppn = (frec[0], frec[1],
                                                       frec[2], frec[3])
            new = dict(st)

            # ---------------- L1 (regular + gated 2MB array) ----------------
            s1 = vpn & jnp.int32(L1_SETS - 1)
            l1row = st["l1"][s1]
            l1_ways_hit = l1row[:, 0] == vpn
            l1_hit = l1_ways_hit.any()
            l1_way = jnp.argmax(l1_ways_hit)
            hv = vpn >> 9
            s1h = hv & jnp.int32(L1H_SETS - 1)
            l1hrow = st["l1h"][s1h]
            h_ways_hit = l1hrow[:, 0] == hv
            l1h_hit = is_thp & h_ways_hit.any()
            l1h_way = jnp.argmax(h_ways_hit)
            l1_served = l1_hit | l1h_hit
            l1_out_ppn = jnp.where(l1_hit, l1row[l1_way, 1],
                                   l1hrow[l1h_way, 1] + (vpn & 511))

            # ---------------- L2 probes (all kinds, selected) ---------------
            s2 = (vpn >> k_hat) & set_mask
            row = st["l2"][s2]                  # [W, 5]
            tags, kcls, contig, pbase = (row[:, TAG], row[:, KCLS],
                                         row[:, CONTIG], row[:, PPN])
            valid = kcls != INVALID

            # colt branch
            diff = vpn - tags
            cover = valid & (diff >= 0) & (diff < contig)
            colt_hit = cover.any()
            colt_way = jnp.argmax(cover)
            colt_reg = colt_hit & (contig[colt_way] == 1)
            colt_coal = colt_hit & (contig[colt_way] > 1)
            colt_ppn = pbase[colt_way] + (vpn - tags[colt_way])

            # thp branch (dual-set probe on the same packed array)
            s2h = hv & set_mask
            row_h = st["l2"][s2h]
            huge_ways = (row_h[:, KCLS] == HUGE) & (row_h[:, TAG] == hv)
            reg_ways = (kcls == REGULAR) & (tags == vpn) & valid
            huge_hit = huge_ways.any()
            hw = jnp.argmax(huge_ways)
            rw = jnp.argmax(reg_ways)
            thp_reg = reg_ways.any() | huge_hit
            thp_ppn = jnp.where(reg_ways.any(), pbase[rw],
                                row_h[hw, PPN] + (vpn - (hv << 9)))
            thp_touch_ways = jnp.where(reg_ways.any(), reg_ways, huge_ways)
            thp_touch_set = jnp.where(reg_ways.any(), s2, s2h)

            # generic branch: regular probe + padded aligned-probe chain
            gen_reg = reg_ways.any()
            probes_used = jnp.int32(0)
            hit_k = jnp.int32(-1)
            gen_coal = jnp.bool_(False)
            coal_ppn = jnp.int32(-1)
            coal_way = jnp.int32(0)
            first_probe_k = jnp.int32(-1)
            for pos, k_val in enumerate(probe_order(st["pred"])):
                sh = jnp.maximum(k_val, 0)
                vk = jnp.where(k_val >= 0,
                               vpn & ~((jnp.int32(1) << sh) - 1),
                               jnp.int32(-10))
                m_ways = (kcls == k_val) & (tags == vk) & valid & \
                         (contig > (vpn - vk))
                m_hit = m_ways.any() & (k_val >= 0) & ~gen_reg & ~gen_coal
                probes_used = probes_used + jnp.where(
                    ~gen_reg & ~gen_coal & (k_val >= 0), 1, 0)
                coal_ppn = jnp.where(m_hit, pbase[jnp.argmax(m_ways)]
                                     + (vpn - vk), coal_ppn)
                coal_way = jnp.where(m_hit, jnp.argmax(m_ways), coal_way)
                hit_k = jnp.where(m_hit, k_val, hit_k)
                if pos == 0:
                    first_probe_k = k_val
                gen_coal = gen_coal | m_hit

            # per-lane branch selection
            reg_hit = jnp.where(is_colt, colt_reg,
                                jnp.where(is_thp, thp_reg, gen_reg))
            coal_hit = jnp.where(is_generic, gen_coal, colt_coal & is_colt)
            l2_hit = reg_hit | coal_hit
            l2_ppn_val = jnp.where(
                is_colt, colt_ppn,
                jnp.where(is_thp, thp_ppn,
                          jnp.where(gen_reg, pbase[rw], coal_ppn)))
            pred_ok = jnp.where(use_pred & gen_coal
                                & (hit_k == first_probe_k), 1, 0)
            touch_set = jnp.where(is_thp, thp_touch_set, s2)
            tw = jnp.where(
                is_colt, colt_way,
                jnp.where(is_thp, jnp.argmax(thp_touch_ways),
                          jnp.where(gen_reg, rw, coal_way)))
            probes_used = jnp.where(is_generic, probes_used, 0)

            # ---------------- side structures (gated) -----------------------
            d_r = vpn - st["rmm"][:, 0]
            in_rng = (d_r >= 0) & (d_r < st["rmm"][:, 1])
            rmm_hit = has_rmm & in_rng.any()
            sw = jnp.argmax(in_rng)
            rmm_ppn_val = st["rmm"][sw, 2] + d_r[sw]

            cwd = vpn >> 3
            sc = cwd & jnp.int32(CLUS_SETS - 1)
            crow = st["clus"][sc]               # [5, 3]
            bit = (crow[:, 1] >> (vpn & 7)) & 1
            c_ways = (crow[:, 0] == cwd) & (bit == 1)
            cl_hit = has_cluster & c_ways.any()

            side_hit = rmm_hit | cl_hit
            side_ppn = jnp.where(rmm_hit, rmm_ppn_val, ppn_true)

            hit_any = l1_served | l2_hit | side_hit
            walk = ~hit_any
            wr = walk & active  # gate for every state write below

            # ---------------- latency (per-lane miss chain) -----------------
            cyc = jnp.where(
                l1_served, 0,
                jnp.where(reg_hit, LAT_L2_REG,
                          jnp.where(coal_hit,
                                    LAT_COAL + LAT_EXTRA_PROBE *
                                    jnp.maximum(probes_used - 1, 0),
                                    jnp.where(side_hit, LAT_COAL,
                                              lane["miss_chain"]
                                              + LAT_WALK))))

            # ---------------- L2 fill (precomputed record; LRU victim) ------
            served_huge = is_thp & (fill_k == HUGE)
            fill_set = jnp.where(served_huge, s2h, s2)
            frow = st["l2"][fill_set]
            valid_row = frow[:, KCLS] != INVALID
            score = jnp.where(way_ok,
                              jnp.where(valid_row, frow[:, LRU],
                                        jnp.int32(NEG)),
                              jnp.int32(BIG))
            victim = jnp.argmin(score)
            evicted_contig = jnp.where(valid_row[victim],
                                       frow[victim, CONTIG], 0)
            fill_vec = jnp.stack([fill_tag, fill_k, fill_contig, fill_ppn, t])
            l2n = _cond_set(st["l2"], (fill_set, victim), fill_vec, wr)
            new["l2"] = _cond_set(l2n, (touch_set, tw, LRU), t,
                                  l2_hit & ~walk & ~l1_served & active)
            cov_delta = jnp.where(wr, fill_contig - evicted_contig, 0)

            # ---------------- side fills (gated) ----------------------------
            rmm_len = st["rmm"][:, 1]
            victim_r = jnp.argmin(jnp.where(rmm_len > 0, st["rmm"][:, 3],
                                            jnp.int32(NEG)))
            ev_len = jnp.where(rmm_len[victim_r] > 0, rmm_len[victim_r], 0)
            rmm_wr = wr & has_rmm
            rmm_vec = jnp.stack([rs_v, rl_v, rmm_fill_ppn, t])
            rmmn = _cond_set(st["rmm"], victim_r, rmm_vec, rmm_wr)
            new["rmm"] = _cond_set(rmmn, (sw, 3), t, rmm_hit & active)
            cov_delta = cov_delta + jnp.where(rmm_wr, rl_v - ev_len, 0)

            bm = clus_map[cid, jnp.clip(vpn, 0, clus_map.shape[1] - 1)]
            clusterable = bm != (jnp.int32(1) << (vpn & 7))
            fill_c = wr & clusterable & has_cluster
            vrow = crow[:, 1] != 0
            victim_c = jnp.argmin(jnp.where(vrow, crow[:, 2],
                                            jnp.int32(NEG)))
            cl_vec = jnp.stack([cwd, bm, t])
            cln = _cond_set(st["clus"], (sc, victim_c), cl_vec, fill_c)
            hit_cway = jnp.argmax(crow[:, 0] == cwd)
            new["clus"] = _cond_set(cln, (sc, hit_cway, 2), t,
                                    cl_hit & active)

            # ---------------- L1 fills --------------------------------------
            do1h = ~l1_served & served_huge & active
            vrh = l1hrow[:, 0] >= 0
            vich = jnp.argmin(jnp.where(vrh, l1hrow[:, 2], jnp.int32(NEG)))
            l1h_vec = jnp.stack([hv, fill_ppn, t])
            l1hn = _cond_set(st["l1h"], (s1h, vich), l1h_vec, do1h)
            new["l1h"] = _cond_set(
                l1hn, (s1h, l1h_way, 2), t,
                is_thp & l1_served & h_ways_hit.any() & ~l1_hit & active)

            do1 = ~l1_served & ~served_huge & active
            vr1 = l1row[:, 0] >= 0
            vic1 = jnp.argmin(jnp.where(vr1, l1row[:, 2], jnp.int32(NEG)))
            l1_vec = jnp.stack([vpn, ppn_true, t])
            l1n = _cond_set(st["l1"], (s1, vic1), l1_vec, do1)
            new["l1"] = _cond_set(l1n, (s1, l1_way, 2), t, l1_hit & active)

            # ---------------- predictor update (gated) ----------------------
            upd = use_pred & active
            new["pred"] = jnp.where(
                upd & gen_coal, hit_k,
                jnp.where(upd & walk & (fill_k >= 0), fill_k, st["pred"]))

            # ---------------- accounting (one packed add) -------------------
            act = active
            delta = jnp.stack([
                (l1_served & act).astype(jnp.int32),
                (reg_hit & ~l1_served & act).astype(jnp.int32),
                ((coal_hit | side_hit) & ~reg_hit & ~l1_served
                 & act).astype(jnp.int32),
                (walk & act).astype(jnp.int32),
                jnp.where(coal_hit & ~l1_served & act, probes_used, 0),
                jnp.where(~l1_served & act, pred_ok, 0),
                jnp.where(act, cyc, 0),
                cov_delta,
                jnp.int32(0),
            ])
            new["counters"] = st["counters"] + delta
            new["t"] = t + act.astype(jnp.int32)
            se = lane["sample_every"]
            slot = jnp.minimum(t // se, N_COV_SAMPLES - 1)
            new["cov_samples"] = _cond_set(st["cov_samples"], slot,
                                           new["counters"][C_COV],
                                           (t % se == se - 1) & active)

            out_ppn = jnp.where(
                l1_served, l1_out_ppn,
                jnp.where(l2_hit, l2_ppn_val,
                          jnp.where(side_hit, side_ppn, ppn_true)))
            return new, out_ppn

        def shoot(st, seg):
            """Translation coherence on epoch turnover (gated per lane):
            drop every entry — in every structure — whose covered vpn range
            contains a dirty vpn of the entered epoch, charge one shootdown
            plus a per-entry invalidation, and release the dropped reach."""
            do = lane["seg_shoot"][seg]
            dc = dirty_stack[lane["seg_dirty"][seg]]     # [P+1] prefix sums

            def rng_dirty(lo, ln):
                lo_ = jnp.clip(lo, 0, Pn)
                hi_ = jnp.clip(lo + ln, 0, Pn)
                return (dc[hi_] - dc[lo_]) > 0

            new = dict(st)
            l2 = st["l2"]
            tagv, kv, cgv = l2[..., TAG], l2[..., KCLS], l2[..., CONTIG]
            # k == HUGE is a 2MB entry (tag = vpn >> 9) only on THP lanes;
            # K-bit Aligned lanes use k = 9 as a plain alignment class.
            huge2 = is_thp & (kv == HUGE)
            stale2 = (kv != INVALID) & do & rng_dirty(
                jnp.maximum(jnp.where(huge2, tagv << 9, tagv), 0),
                jnp.where(huge2, 512,
                          jnp.where(kv == REGULAR, 1, jnp.maximum(cgv, 1))))
            new["l2"] = l2.at[..., KCLS].set(jnp.where(stale2, INVALID, kv))
            n_inv = stale2.sum(dtype=jnp.int32)
            cov_loss = jnp.where(stale2, cgv, 0).sum(dtype=jnp.int32)

            l1 = st["l1"]
            t1 = l1[..., 0]
            stale1 = (t1 >= 0) & do & rng_dirty(jnp.maximum(t1, 0), 1)
            new["l1"] = l1.at[..., 0].set(jnp.where(stale1, -1, t1))
            n_inv = n_inv + stale1.sum(dtype=jnp.int32)

            l1h = st["l1h"]
            th = l1h[..., 0]
            staleh = (th >= 0) & do & rng_dirty(jnp.maximum(th, 0) << 9, 512)
            new["l1h"] = l1h.at[..., 0].set(jnp.where(staleh, -1, th))
            n_inv = n_inv + staleh.sum(dtype=jnp.int32)

            rmm = st["rmm"]
            rs0, rl0 = rmm[:, 0], rmm[:, 1]
            staler = (rl0 > 0) & do & rng_dirty(jnp.maximum(rs0, 0), rl0)
            rmm2 = rmm.at[:, 0].set(jnp.where(staler, -1, rs0))
            rmm2 = rmm2.at[:, 1].set(jnp.where(staler, 0, rl0))
            new["rmm"] = rmm2.at[:, 2].set(jnp.where(staler, -1, rmm[:, 2]))
            n_inv = n_inv + staler.sum(dtype=jnp.int32)
            cov_loss = cov_loss + jnp.where(staler, rl0, 0).sum(
                dtype=jnp.int32)

            cl = st["clus"]
            ct, cb = cl[..., 0], cl[..., 1]
            stalec = (cb != 0) & do & rng_dirty(jnp.maximum(ct, 0) << 3, 8)
            new["clus"] = cl.at[..., 1].set(jnp.where(stalec, 0, cb))
            n_inv = n_inv + stalec.sum(dtype=jnp.int32)

            cnt = st["counters"]
            add = (jnp.zeros_like(cnt)
                   .at[C_SHOOT].set(n_inv)
                   .at[C_CYC].set(jnp.where(do, LAT_SHOOTDOWN, 0)
                                  + n_inv * LAT_INVALIDATE)
                   .at[C_COV].set(-cov_loss))
            new["counters"] = cnt + add
            return new

        st = st_init
        outs = []
        for seg, (lo, hi) in enumerate(zip(seg_bounds, seg_bounds[1:])):
            if seg > 0:
                st = shoot(st, seg)
            step = make_step(lane["seg_map"][seg], lane["seg_fill"][seg],
                             lane["seg_clus"][seg])
            st, pp = jax.lax.scan(step, st,
                                  jnp.arange(lo, hi, dtype=jnp.int32))
            outs.append(pp)
        return st, (outs[0] if len(outs) == 1 else jnp.concatenate(outs))

    return jax.vmap(one_lane)(lanes, st0)


_run_lanes_jit = jax.jit(_run_lanes_impl, static_argnums=(3,))
_run_lanes_pmap = jax.pmap(_run_lanes_impl, in_axes=(0, None, 0),
                           static_broadcasted_argnums=(3,))


def _simulate_lanes(lanes, stacks, st0, seg_bounds):
    """Dispatch to pmap over virtual host devices when available (lanes are
    sharded across devices), else a single jitted vmap."""
    dev = jax.local_device_count()
    L = lanes["t_real"].shape[0]
    if dev > 1 and L % dev == 0:
        def shard(x):
            return x.reshape((dev, L // dev) + x.shape[1:])

        stF, ppns = _run_lanes_pmap(
            {k: shard(v) for k, v in lanes.items()}, stacks,
            {k: shard(v) for k, v in st0.items()}, seg_bounds)
        unshard = lambda x: np.asarray(x).reshape((L,) + x.shape[2:])  # noqa: E731
        return ({k: unshard(v) for k, v in jax.device_get(stF).items()},
                unshard(jax.device_get(ppns)))
    stF, ppns = _run_lanes_jit(lanes, stacks, st0, seg_bounds)
    return jax.device_get(stF), np.asarray(jax.device_get(ppns))


# ---------------------------------------------------------------------------
# On-disk result cache
# ---------------------------------------------------------------------------

_GIT_DESCRIBE: Optional[str] = None
_CODE_FINGERPRINT: Optional[str] = None


def _git_describe() -> str:
    global _GIT_DESCRIBE
    if _GIT_DESCRIBE is None:
        try:
            _GIT_DESCRIBE = subprocess.run(
                ["git", "describe", "--always", "--dirty"],
                capture_output=True, text=True, timeout=10,
                cwd=os.path.dirname(os.path.abspath(__file__)),
            ).stdout.strip() or "nogit"
        except (OSError, subprocess.SubprocessError):
            _GIT_DESCRIBE = "nogit"
    return _GIT_DESCRIBE


def _code_fingerprint() -> str:
    """git describe + a content hash of the engine sources, so uncommitted
    edits to the simulation semantics invalidate the cache too (a dirty
    tree always yields the same '<sha>-dirty' describe string)."""
    global _CODE_FINGERPRINT
    if _CODE_FINGERPRINT is None:
        h = hashlib.sha256(_git_describe().encode())
        here = os.path.dirname(os.path.abspath(__file__))
        for fname in ("simulator.py", "sweep.py", "page_table.py"):
            try:
                with open(os.path.join(here, fname), "rb") as f:
                    h.update(f.read())
            except OSError:
                h.update(b"?")
        _CODE_FINGERPRINT = h.hexdigest()
    return _CODE_FINGERPRINT


def _array_digest(a: np.ndarray) -> str:
    h = hashlib.sha256()
    h.update(str(a.dtype).encode())
    h.update(str(a.shape).encode())
    h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


def cell_key(cell: SweepCell, _digests: Optional[Dict[int, str]] = None
             ) -> str:
    """Stable cache key: spec config + world/trace content + code version.

    The key is a SHA-256 over (a) ``repr(spec)`` — every static knob of the
    method, (b) the *content* of the world and ``trace`` (dtype, shape,
    bytes — not object identity, so deterministically regenerated worlds hit
    the cache across processes), and (c) :func:`_code_fingerprint` — git
    describe plus a hash of the engine sources, so editing the simulation
    semantics invalidates stale results even in a dirty tree.  For a
    :class:`~repro.core.page_table.DynamicMapping` world, (b) folds in the
    event stream: every epoch snapshot's ``ppn`` plus the boundary
    positions, so two worlds differing only in when (or what) they remap
    never collide.

    ``_digests`` is an id-keyed memo so sweeps that share one mapping/trace
    across many specs hash each array once (valid while the arrays are kept
    alive by the caller, as run_sweep does).
    """
    def digest(a: np.ndarray) -> str:
        if _digests is None:
            return _array_digest(a)
        d = _digests.get(id(a))
        if d is None:
            d = _digests[id(a)] = _array_digest(a)
        return d

    h = hashlib.sha256()
    h.update(repr(cell.spec).encode())
    if isinstance(cell.mapping, DynamicMapping):
        h.update(repr(tuple(cell.mapping.boundaries)).encode())
        for m in cell.mapping.epochs:
            h.update(digest(m.ppn).encode())
    else:
        h.update(digest(cell.mapping.ppn).encode())
    h.update(digest(cell.trace).encode())
    h.update(_code_fingerprint().encode())
    return h.hexdigest()[:32]


_COUNTER_FIELDS = ("accesses", "l1_hits", "l2_regular_hits",
                   "l2_coalesced_hits", "walks", "aligned_probes",
                   "pred_correct", "cycles", "shootdowns")


def _cache_load(path: str) -> Optional[SimResult]:
    if not os.path.exists(path):
        return None
    try:
        with np.load(path, allow_pickle=False) as z:
            counters = z["counters"]
            return SimResult(
                name=str(z["name"]),
                **{f: int(counters[i]) for i, f in enumerate(_COUNTER_FIELDS)},
                coverage_mean=float(z["coverage_mean"]),
                ppn=z["ppn"],
            )
    except (OSError, KeyError, ValueError, IndexError):
        return None


def _cache_store(path: str, r: SimResult) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}.npz"
    np.savez_compressed(
        tmp, name=np.str_(r.name),
        counters=np.array([getattr(r, f) for f in _COUNTER_FIELDS], np.int64),
        coverage_mean=np.float64(r.coverage_mean), ppn=r.ppn)
    os.replace(tmp, path)


# ---------------------------------------------------------------------------
# Orchestrator
# ---------------------------------------------------------------------------

DEFAULT_CACHE_DIR = os.path.join("results", "sweep_cache")


def run_sweep(cells: Sequence[SweepCell], *, cache: bool = True,
              cache_dir: str = DEFAULT_CACHE_DIR) -> SweepResult:
    """Simulate every cell, batched into one compiled vmapped scan.

    Results are bit-identical to per-cell :func:`run_method` calls (enforced
    by ``tests/test_sweep.py``).  With ``cache`` enabled, previously
    simulated cells (same spec, mapping/trace *content* and code version —
    see :func:`cell_key`) are loaded from ``cache_dir`` and skipped; set the
    ``REPRO_SWEEP_NO_CACHE`` env var or ``cache=False`` to bypass.

    Usage — compare two methods on a workload-derived scenario::

        from repro.core.baselines import base_spec, kaligned_for_mapping
        from repro.core.sweep import SweepCell, run_sweep
        from repro.scenarios import get_scenario

        d = get_scenario("kv-churn").materialize(n_pages=1 << 15,
                                                 trace_len=100_000)
        specs = [base_spec(), kaligned_for_mapping(d.mapping, psi=3)]
        sweep = run_sweep([SweepCell(s, d.mapping, d.trace) for s in specs])
        for r in sweep:                      # SimResult per cell, in order
            print(r.name, r.misses, r.cpi)
        print(sweep.stats)                   # n_cells / cache_hits / wall_s

    Lanes are padded onto one array layout (max L2 geometry of the batch,
    inert ``K=-1`` alignment slots, ``LANE_BUCKET``/``TRACE_BUCKET`` shape
    buckets), so heterogeneous specs, footprints and trace lengths all reuse
    one compiled executable per shape bucket — see the module docstring for
    the padding rules.
    """
    t0 = time.time()
    cache = cache and not os.environ.get("REPRO_SWEEP_NO_CACHE")
    cells = list(cells)
    results: List[Optional[SimResult]] = [None] * len(cells)
    todo: List[int] = []
    hits = 0
    digests: Dict[int, str] = {}   # id-keyed; cells keep the arrays alive
    keys = [cell_key(c, digests) if cache else "" for c in cells]
    for i, c in enumerate(cells):
        if cache:
            r = _cache_load(os.path.join(cache_dir, keys[i] + ".npz"))
            if r is not None:
                results[i] = r
                hits += 1
                continue
        todo.append(i)

    if todo:
        sub = [cells[i] for i in todo]
        lanes, stacks, (L, max_sets, max_ways), seg_bounds = _pack_lanes(sub)
        st0 = _init_batched_state(L, max_sets, max_ways, lanes["pred0"])
        stF, ppns = _simulate_lanes(
            {k: jnp.asarray(v) for k, v in lanes.items()},
            {k: jnp.asarray(v) for k, v in stacks.items()},
            {k: jnp.asarray(v) for k, v in st0.items()}, seg_bounds)
        counters = np.asarray(stF["counters"])
        cov_samples = np.asarray(stF["cov_samples"])
        for j, i in enumerate(todo):
            c = cells[i]
            t_real = c.trace.shape[0]
            cnt = counters[j]
            r = SimResult(
                name=c.spec.name, accesses=t_real,
                l1_hits=int(cnt[C_L1]),
                l2_regular_hits=int(cnt[C_REG]),
                l2_coalesced_hits=int(cnt[C_COAL]),
                walks=int(cnt[C_WALK]),
                aligned_probes=int(cnt[C_PROBE]),
                pred_correct=int(cnt[C_PRED]),
                cycles=int(cnt[C_CYC]),
                coverage_mean=float(np.mean(cov_samples[j])),
                ppn=ppns[j, :t_real],
                shootdowns=int(cnt[C_SHOOT]),
            )
            results[i] = r
            if cache:
                _cache_store(os.path.join(cache_dir, keys[i] + ".npz"), r)

    stats = dict(n_cells=len(cells), cache_hits=hits,
                 simulated=len(todo), wall_s=round(time.time() - t0, 3))
    return SweepResult(results=results, stats=stats)  # type: ignore[arg-type]
