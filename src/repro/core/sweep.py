"""Batched sweep engine: every method × trace as one compiled program.

:func:`repro.core.simulator.run_method` simulates one ``(spec, mapping,
trace)`` triple per call and re-compiles for every distinct ``MethodSpec``
and every distinct array shape.  A paper-scale sweep (7+ methods × 16
benchmarks × several |K| / seed settings) pays that compile cost hundreds of
times.  This module instead *pads every method onto one common array layout*
so that all of ``base/thp/colt/cluster/rmm/anchor/kaligned`` run as lanes of
a single program, compiled once per shape bucket and reused across traces
and seeds.  The per-lane program itself — packing rules, the union step
datapath, the shootdown pass, the time-blocked execution plan — lives in
:mod:`repro.core.lane_program`; this module executes it and orchestrates
caching.  Two backends consume that one definition:

* ``backend='xla'`` (the CPU/GPU fast path): one ``jax.lax.scan`` whose
  carry is the packed state of ALL lanes and whose body advances every lane
  by a **block** of ``TB`` trace steps — the per-step map/fill/cluster/trace
  gathers are hoisted into one bulk gather per block and the intra-block
  dependency chain is unrolled, so a block costs a handful of fused memory
  ops instead of ``TB × (~10 gathers + ~5 scatters)`` of vmapped
  point-scatter dispatches.  Epoch-turnover shootdowns run under a
  ``lax.cond`` on the (static-timeline) segment-entry blocks, so static
  batches never pay them.
* ``backend='pallas'`` (:mod:`repro.kernels.tlb_sweep`): a Pallas kernel
  whose grid maps lanes to program instances, keeps all TLB state in
  scratch for the whole trace, and streams trace blocks in — eliminating
  the HBM state round-trip per step on real accelerators (``interpret=True``
  on CPU).

``backend='auto'`` picks ``pallas`` on TPU and ``xla`` elsewhere.  Both
backends are bit-exact against the pure-python oracles
:func:`~repro.core.simulator.run_method` /
:func:`~repro.core.simulator.run_method_dynamic` for every block size
(``tests/test_backends.py``), so results and cache entries never depend on
the execution strategy.

Dynamic worlds (:class:`~repro.core.page_table.DynamicMapping`) run as
**epoch-segmented lanes**: records are precomputed per ``(world, epoch)``,
the block timeline is split at the static union of all lanes' epoch
boundaries, and the first block of every segment runs a vectorized
shootdown pass — gated per lane by whether its epoch turned over — that
invalidates every entry whose covered vpn range contains a page whose
translation died.  ``run_sweep`` partitions each batch so purely-static
cells never ride a multi-segment timeline.

When JAX exposes several (virtual) host devices, lanes are sharded across
them with ``pmap`` — lane batches are padded to a device multiple so every
run shards (``benchmarks/_env.py`` turns the devices on for benchmarks).

:func:`run_sweep` is the orchestrator: it dedups mappings/traces, packs
lanes, consults an on-disk result cache under ``results/sweep_cache`` keyed
by ``(spec, mapping hash, trace hash, code fingerprint)``, simulates only
the missing cells, and returns per-cell
:class:`~repro.core.simulator.SimResult` objects bit-identical to the
per-call oracle.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import subprocess
import time
import zipfile
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .lane_program import (
    C_COAL, C_CYC, C_L1, C_PRED, C_PROBE, C_REG, C_SHOOT, C_WALK,
    LANE_SHARE_MAX, STEP_KEYS, build_block_plan,
    init_batched_state as _init_batched_state, needs_switch_pass,
    pack_lanes as _pack_lanes, shoot_lane, step_access, switch_lane)
from .page_table import (DynamicMapping, Mapping, MultiTenantMapping,
                         NestedMapping, ParityWorld)
from .simulator import MethodSpec, SimResult

# Default trace-steps-per-block of the time-blocked XLA backend.  Override
# per call with ``run_sweep(..., block_size=...)`` or globally with the
# ``REPRO_SWEEP_BLOCK`` env var.  Measured on CPU: run time keeps improving
# up to ~32 steps per block (the per-block record gathers amortize), while
# the inner-scan block body keeps compile time flat in the block size.
DEFAULT_BLOCK = 32


def _block_size(block_size: Optional[int]) -> int:
    if block_size is None:
        block_size = int(os.environ.get("REPRO_SWEEP_BLOCK", DEFAULT_BLOCK))
    if block_size < 1:
        raise ValueError(f"block_size must be >= 1, got {block_size}")
    return block_size


def resolve_backend(backend: Optional[str]) -> str:
    """Resolve the ``backend`` knob to the backend that actually runs:
    ``'auto'``/``None`` picks ``pallas`` on TPU and ``xla`` elsewhere.
    Public so harnesses recording what ran (``benchmarks/run.py``) resolve
    it the same way ``run_sweep`` does."""
    if backend in (None, "auto"):
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend not in ("xla", "pallas"):
        raise ValueError(f"unknown sweep backend {backend!r} "
                         "(want 'auto', 'xla' or 'pallas')")
    return backend


@dataclasses.dataclass(frozen=True)
class SweepCell:
    """One cell of a sweep: simulate ``spec`` over ``(mapping, trace)``.

    * ``spec``    — a :class:`~repro.core.simulator.MethodSpec` (build one
      with the factories in :mod:`repro.core.baselines`); its static config
      becomes per-lane *data* in the batched engine, so cells with different
      specs still share one compiled program.
    * ``mapping`` — a contiguity-annotated
      :class:`~repro.core.page_table.Mapping`, a
      :class:`~repro.core.page_table.DynamicMapping` whose epoch boundaries
      segment the trace (mid-trace remaps with shootdown-correct
      invalidation), **or** a
      :class:`~repro.core.page_table.MultiTenantMapping` whose schedule
      segments it (ASID-tagged context switching; the flush-vs-tag policy
      is ``spec.ctx_policy``), **or** a
      :class:`~repro.core.page_table.NestedMapping` whose segment grid is
      the union of its VM schedule, guest epochs and host epochs (two-level
      translation; the shootdown-vs-hw-coherence knob is
      ``spec.coh_policy``), **or** a
      :class:`~repro.core.page_table.ParityWorld` wrapping any of those
      plus a schedule of mid-trace TLB parity-flip faults (soft-error
      recovery; the detect-invalidate-rewalk vs in-place-correction knob
      is ``spec.par_policy``); get one from a registered scenario
      (:mod:`repro.scenarios`) or the generators in
      :mod:`repro.core.mappings`.
    * ``trace``   — 1-D integer array of VPNs (every entry must be a mapped
      page of the epoch/tenant live at that step).

    Mappings/traces shared between cells (by object identity) are packed and
    hashed once, so build each world once and reuse it across specs.
    """

    spec: MethodSpec
    mapping: ("Mapping | DynamicMapping | MultiTenantMapping | "
              "NestedMapping | ParityWorld")
    trace: np.ndarray

    def __post_init__(self):
        assert self.trace.ndim == 1
        world = self.mapping
        if isinstance(world, ParityWorld):
            assert all(0 < t < self.trace.shape[0]
                       for t, _ in world.faults), \
                "fault steps must fall inside the trace"
            world = world.base
        if isinstance(world, (DynamicMapping, MultiTenantMapping)):
            assert all(0 < b < self.trace.shape[0]
                       for b in world.boundaries[1:]), \
                "segment boundaries must fall inside the trace"
        elif isinstance(world, NestedMapping):
            assert all(0 < ns.lo < self.trace.shape[0]
                       for ns in world.plan_segments()[1:]), \
                "segment boundaries must fall inside the trace"

    @property
    def epochs(self) -> Tuple[Mapping, ...]:
        world = self.mapping
        if isinstance(world, ParityWorld):
            world = world.base
        if isinstance(world, DynamicMapping):
            return world.epochs
        if isinstance(world, MultiTenantMapping):
            return world.tenants
        if isinstance(world, NestedMapping):
            # distinct composed guest-over-host views, schedule order
            seen, out = set(), []
            for ns in world.plan_segments():
                if id(ns.mapping) not in seen:
                    seen.add(id(ns.mapping))
                    out.append(ns.mapping)
            return tuple(out)
        return (world,)

    @property
    def boundaries(self) -> Tuple[int, ...]:
        world, faults = self.mapping, ()
        if isinstance(world, ParityWorld):
            faults = tuple(t for t, _ in world.faults)
            world = world.base
        if isinstance(world, (DynamicMapping, MultiTenantMapping)):
            base = world.boundaries
        elif isinstance(world, NestedMapping):
            base = tuple(ns.lo for ns in world.plan_segments())
        else:
            base = (0,)
        return tuple(sorted(set(base) | set(faults)))

    @property
    def is_segmented(self) -> bool:
        """True when the lane rides a multi-segment timeline (mid-trace
        remap epochs, multi-tenant scheduling quanta, or the union grid
        of a nested guest/host world)."""
        return len(self.boundaries) > 1


@dataclasses.dataclass
class SweepResult:
    """Per-cell results (aligned with the request list) plus run stats."""

    results: List[SimResult]
    stats: Dict[str, float]

    def __iter__(self):
        return iter(self.results)

    def __getitem__(self, i):
        return self.results[i]


# ---------------------------------------------------------------------------
# The XLA backend: one scan over TB-step blocks, body vmapped over lanes
# ---------------------------------------------------------------------------

def _run_lanes_impl(lanes, stacks, st0, seg_bounds, tb, with_switch):
    """Time-blocked batched simulation of every lane.

    One ``lax.scan`` over the :class:`~repro.core.lane_program.BlockPlan`
    timeline: the body gathers the block's trace/map/fill/cluster records
    for ALL lanes in bulk, then advances the ``tb`` sequentially-dependent
    accesses with the shared :func:`~repro.core.lane_program.step_access`.
    Segment-entry blocks run the vectorized shootdown under ``lax.cond`` —
    skipped entirely at runtime on non-boundary blocks (and absent from the
    timeline of static batches)."""
    plan = build_block_plan(seg_bounds, tb)
    map_stack = stacks["maps"]
    fill_stack = stacks["fills"]
    clus_map = stacks["clus"]
    dirty_stack = stacks["dirty"]
    trace_stack = stacks["trace"]
    Pc = clus_map.shape[1]
    NB = plan.n_blocks
    L = lanes["t_real"].shape[0]
    lane_params = {k: lanes[k] for k in STEP_KEYS}

    xs = dict(tt=jnp.asarray(plan.tpos.reshape(NB, tb)),
              seg=jnp.asarray(plan.blk_seg),
              shoot=jnp.asarray(plan.blk_shoot),
              hi=jnp.asarray(plan.blk_hi))

    def lane_blk(lane, st, vpn_b, mrec_b, frec_b, bm_b, act_b):
        # the tb accesses are a sequential dependency chain over the
        # pre-gathered records; an inner scan keeps the compiled body one
        # step wide (unrolling it multiplies compile time for no run-time
        # gain on XLA — the win is the hoisted per-block gathers)
        def inner(st, x):
            return step_access(lane, st, *x)

        return jax.lax.scan(inner, st, (vpn_b, mrec_b, frec_b, bm_b, act_b))

    def blk_body(st_all, x):
        seg = x["seg"]

        def do_entry(s):
            # context switch first (set ASID, charge, policy flush), then
            # the translation-coherence shootdown — the oracle's order.
            # ``with_switch`` is static: batches with no multi-tenant lane
            # (all switch flags False by construction) never compile the
            # switch pass at all.
            if with_switch:
                s = jax.vmap(switch_lane)(
                    s, lanes["seg_asid"][:, seg],
                    lanes["seg_switch"][:, seg],
                    lanes["seg_fall"][:, seg], lanes["seg_fasid"][:, seg])
            do = lanes["seg_shoot"][:, seg]
            dcs = dirty_stack[lanes["seg_dirty"][:, seg]]
            return jax.vmap(shoot_lane)(lane_params, s, dcs, do)

        st_all = jax.lax.cond(x["shoot"], do_entry, lambda s: s, st_all)

        vpns = trace_stack[lanes["trace_id"][:, None], x["tt"][None, :]]
        mrecs = map_stack[lanes["seg_map"][:, seg, None], vpns]
        frecs = fill_stack[lanes["seg_fill"][:, seg, None], vpns]
        bms = clus_map[lanes["seg_clus"][:, seg, None],
                       jnp.clip(vpns, 0, Pc - 1)]
        act = (x["tt"][None, :] < x["hi"]) & \
              (x["tt"][None, :] < lanes["t_real"][:, None])
        return jax.vmap(lane_blk)(lane_params, st_all, vpns, mrecs, frecs,
                                  bms, act)

    stF, pp = jax.lax.scan(blk_body, st0, xs)        # pp: [NB, L, tb]
    pp = jnp.moveaxis(pp, 1, 0).reshape(L, NB * tb)
    return stF, pp[:, plan.slot_of_t]


_run_lanes_jit = jax.jit(_run_lanes_impl, static_argnums=(3, 4, 5))
_run_lanes_pmap = jax.pmap(_run_lanes_impl, in_axes=(0, None, 0),
                           static_broadcasted_argnums=(3, 4, 5))


def _simulate_lanes(lanes, stacks, st0, seg_bounds, backend="xla",
                    tb=DEFAULT_BLOCK):
    """Run one packed batch on the selected backend.

    ``xla``: dispatch to ``pmap`` over virtual host devices when available
    (lane batches are padded to a device multiple by
    :func:`~repro.core.lane_program.bucket_lane_count`, so benchmark runs
    always shard), else a single jitted scan.  ``pallas``: the
    :mod:`repro.kernels.tlb_sweep` kernel (interpret mode off-TPU).
    Returns ``(final_state, ppns)`` with at least ``counters`` and
    ``cov_samples`` in the state dict."""
    if backend == "pallas":
        from ..kernels.tlb_sweep import run_lanes_pallas
        stF, ppns = run_lanes_pallas(lanes, stacks, st0, seg_bounds, tb)
        return jax.device_get(stF), np.asarray(jax.device_get(ppns))
    with_switch = needs_switch_pass(lanes)
    dev = jax.local_device_count()
    L = lanes["t_real"].shape[0]
    if dev > 1 and L % dev == 0:
        def shard(x):
            return x.reshape((dev, L // dev) + x.shape[1:])

        stF, ppns = _run_lanes_pmap(
            {k: shard(v) for k, v in lanes.items()}, stacks,
            {k: shard(v) for k, v in st0.items()}, seg_bounds, tb,
            with_switch)
        unshard = lambda x: np.asarray(x).reshape((L,) + x.shape[2:])  # noqa: E731
        return ({k: unshard(v) for k, v in jax.device_get(stF).items()},
                unshard(jax.device_get(ppns)))
    stF, ppns = _run_lanes_jit(lanes, stacks, st0, seg_bounds, tb,
                               with_switch)
    return jax.device_get(stF), np.asarray(jax.device_get(ppns))


# ---------------------------------------------------------------------------
# On-disk result cache
# ---------------------------------------------------------------------------

_GIT_DESCRIBE: Optional[str] = None
_CODE_FINGERPRINT: Optional[str] = None

# Everything that defines the simulation semantics: the engine sources AND
# both backend implementations.  Paths are relative to src/repro/.
_FINGERPRINT_SOURCES = (
    "core/simulator.py",
    "core/sweep.py",
    "core/lane_program.py",
    "core/page_table.py",
    "core/plane_layout.py",
    "kernels/tlb_sweep/tlb_sweep.py",
    "kernels/tlb_sweep/ops.py",
)


def _git_describe() -> str:
    global _GIT_DESCRIBE
    if _GIT_DESCRIBE is None:
        try:
            _GIT_DESCRIBE = subprocess.run(
                ["git", "describe", "--always", "--dirty"],
                capture_output=True, text=True, timeout=10,
                cwd=os.path.dirname(os.path.abspath(__file__)),
            ).stdout.strip() or "nogit"
        except (OSError, subprocess.SubprocessError):
            _GIT_DESCRIBE = "nogit"
    return _GIT_DESCRIBE


def _code_fingerprint() -> str:
    """git describe + a content hash of the engine AND kernel sources, so
    uncommitted edits to the simulation semantics — including the Pallas
    TLB-sweep kernel — invalidate the cache too (a dirty tree always yields
    the same '<sha>-dirty' describe string)."""
    global _CODE_FINGERPRINT
    if _CODE_FINGERPRINT is None:
        h = hashlib.sha256(_git_describe().encode())
        pkg = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        for fname in _FINGERPRINT_SOURCES:
            try:
                with open(os.path.join(pkg, fname), "rb") as f:
                    h.update(f.read())
            except OSError:
                h.update(b"?")
        _CODE_FINGERPRINT = h.hexdigest()
    return _CODE_FINGERPRINT


def _array_digest(a: np.ndarray) -> str:
    h = hashlib.sha256()
    h.update(str(a.dtype).encode())
    h.update(str(a.shape).encode())
    h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


def cell_key(cell: SweepCell, _digests: Optional[Dict[int, str]] = None
             ) -> str:
    """Stable cache key: spec config + world/trace content + code version.

    The key is a SHA-256 over (a) ``repr(spec)`` — every static knob of the
    method, (b) the *content* of the world and ``trace`` (dtype, shape,
    bytes — not object identity, so deterministically regenerated worlds hit
    the cache across processes), and (c) :func:`_code_fingerprint` — git
    describe plus a hash of the engine sources, so editing the simulation
    semantics invalidates stale results even in a dirty tree.  For a
    :class:`~repro.core.page_table.DynamicMapping` world, (b) folds in the
    event stream: every epoch snapshot's ``ppn`` plus the boundary
    positions, so two worlds differing only in when (or what) they remap
    never collide.  Execution knobs (backend, block size, lane/trace
    padding) are deliberately NOT part of the key: results are bit-exact
    across all of them, so any backend may serve any cached cell.

    ``_digests`` is an id-keyed memo so sweeps that share one mapping/trace
    across many specs hash each array once (valid while the arrays are kept
    alive by the caller, as run_sweep does).
    """
    def digest(a: np.ndarray) -> str:
        if _digests is None:
            return _array_digest(a)
        d = _digests.get(id(a))
        if d is None:
            d = _digests[id(a)] = _array_digest(a)
        return d

    h = hashlib.sha256()
    h.update(repr(cell.spec).encode())
    world = cell.mapping
    if isinstance(world, ParityWorld):
        # the fault schedule is semantic content: when and which vpn flips
        # decides which entries die — then fold the wrapped base world
        # exactly as if it were the cell's mapping
        h.update(repr(("parity", tuple(world.faults))).encode())
        world = world.base
    if isinstance(world, DynamicMapping):
        h.update(repr(tuple(world.boundaries)).encode())
        for m in world.epochs:
            h.update(digest(m.ppn).encode())
    elif isinstance(world, MultiTenantMapping):
        mt = world
        # the full schedule: when, who, under which ASID — and the recycle
        # flags explicitly (normally derived from the former, but the
        # constructor accepts an override, which must not collide)
        h.update(repr((tuple(mt.boundaries), tuple(mt.tenant_ids),
                       tuple(mt.asids), tuple(mt.recycled))).encode())
        for m in mt.tenants:
            h.update(digest(m.ppn).encode())
    elif isinstance(world, NestedMapping):
        nm = world
        # both levels fold in: the VM schedule, every guest's event stream
        # AND the host's — two worlds differing only in a host-side remap
        # (which guests never observe directly) must never collide
        h.update(repr((tuple(nm.boundaries), tuple(nm.guest_ids),
                       tuple(nm.asids), tuple(nm.recycled))).encode())
        for g in nm.guests:
            h.update(repr(tuple(g.boundaries)).encode())
            for m in g.epochs:
                h.update(digest(m.ppn).encode())
        h.update(repr(tuple(nm.host.boundaries)).encode())
        for m in nm.host.epochs:
            h.update(digest(m.ppn).encode())
    else:
        h.update(digest(world.ppn).encode())
    h.update(digest(cell.trace).encode())
    h.update(_code_fingerprint().encode())
    return h.hexdigest()[:32]


_COUNTER_FIELDS = ("accesses", "l1_hits", "l2_regular_hits",
                   "l2_coalesced_hits", "walks", "aligned_probes",
                   "pred_correct", "cycles", "shootdowns")


def _cache_load(path: str) -> Tuple[Optional[SimResult], bool]:
    """Load one cache entry: ``(result, corrupt)``.

    A *missing* entry is the normal cold-cache case — ``(None, False)``.
    An entry that exists but fails to parse (truncated write, bit rot,
    wrong schema from an older layout) is CORRUPT — ``(None, True)`` — and
    the caller must quarantine it and surface the count: silently
    recomputing would hide an integrity problem in the cache directory.
    """
    if not os.path.exists(path):
        return None, False
    try:
        with np.load(path, allow_pickle=False) as z:
            counters = z["counters"]
            return SimResult(
                name=str(z["name"]),
                **{f: int(counters[i]) for i, f in enumerate(_COUNTER_FIELDS)},
                coverage_mean=float(z["coverage_mean"]),
                ppn=z["ppn"],
            ), False
    except (OSError, KeyError, ValueError, IndexError, EOFError,
            zipfile.BadZipFile):
        return None, True


def _quarantine_cache_entry(path: str) -> None:
    """Move a corrupt entry aside (never delete: keep it inspectable)."""
    try:
        os.replace(path, path + ".quarantined")
    except OSError:
        pass                         # raced away or unwritable: recompute


def _cache_store(path: str, r: SimResult) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}.npz"
    np.savez_compressed(
        tmp, name=np.str_(r.name),
        counters=np.array([getattr(r, f) for f in _COUNTER_FIELDS], np.int64),
        coverage_mean=np.float64(r.coverage_mean), ppn=r.ppn)
    os.replace(tmp, path)


# ---------------------------------------------------------------------------
# Orchestrator
# ---------------------------------------------------------------------------

DEFAULT_CACHE_DIR = os.path.join("results", "sweep_cache")

#: Chaos hook: :mod:`repro.robustness.faults` installs a callable here to
#: inject deterministic backend compile/runtime failures —
#: ``hook(cells, backend)`` raising makes the batch fail exactly as a real
#: backend fault would, upstream of any recovery.  ``None`` in production.
_BACKEND_FAULT_HOOK = None


def _oracle_result(cell: SweepCell) -> SimResult:
    """Pure-python oracle for one cell — the last-resort executor a failing
    lane is bisected down to (bit-exact with the batched backends by the
    parity suite, so recovery never changes results)."""
    from .simulator import (run_method_dynamic, run_method_multitenant,
                            run_method_nested, run_method_parity)
    w = cell.mapping
    if isinstance(w, ParityWorld):
        return run_method_parity(cell.spec, w, cell.trace)
    if isinstance(w, NestedMapping):
        return run_method_nested(cell.spec, w, cell.trace)
    if isinstance(w, MultiTenantMapping):
        return run_method_multitenant(cell.spec, w, cell.trace)
    return run_method_dynamic(cell.spec, w, cell.trace)


def _run_batch(sub: List[SweepCell], backend: str, tb: int
               ) -> List[SimResult]:
    """Pack and simulate one batch; per-cell results in ``sub`` order."""
    if _BACKEND_FAULT_HOOK is not None:
        _BACKEND_FAULT_HOOK(sub, backend)
    lanes, stacks, (L, max_sets, max_ways), seg_bounds = _pack_lanes(
        sub, device_count=jax.local_device_count())
    st0 = _init_batched_state(
        L, max_sets, max_ways, lanes["pred0"], lanes["asid0"],
        with_ctlb=any(c.spec.kind == "cache-tlb" for c in sub),
        with_dp=any(c.spec.kind == "dead-protect" for c in sub))
    stF, ppns = _simulate_lanes(lanes, stacks, st0, seg_bounds,
                                backend=backend, tb=tb)
    counters = np.asarray(stF["counters"])
    cov_samples = np.asarray(stF["cov_samples"])
    out = []
    for j, c in enumerate(sub):
        t_real = c.trace.shape[0]
        cnt = counters[j]
        out.append(SimResult(
            name=c.spec.name, accesses=t_real,
            l1_hits=int(cnt[C_L1]),
            l2_regular_hits=int(cnt[C_REG]),
            l2_coalesced_hits=int(cnt[C_COAL]),
            walks=int(cnt[C_WALK]),
            aligned_probes=int(cnt[C_PROBE]),
            pred_correct=int(cnt[C_PRED]),
            cycles=int(cnt[C_CYC]),
            coverage_mean=float(np.mean(cov_samples[j])),
            ppn=ppns[j, :t_real],
            shootdowns=int(cnt[C_SHOOT]),
        ))
    return out


def _run_batch_resilient(sub: List[SweepCell], backend: str, tb: int,
                         fstats: Dict[str, int]) -> List[SimResult]:
    """One batch with the recovery ladder: backend → xla fallback →
    bisection → per-cell oracle.

    A failing Pallas compile/run retries the WHOLE batch on the XLA
    backend first (bit-exact by construction, so the fallback result is
    identical).  A batch that still fails is bisected so one poisoned
    lane cannot take its batchmates down; a single cell that fails every
    backend is handed to the pure-python oracle.  Only the oracle itself
    raising propagates — the run then fails loudly rather than returning
    partial results.  Recovery counts surface in ``fstats``.
    """
    try:
        return _run_batch(sub, backend, tb)
    except Exception:
        if backend == "pallas":
            fstats["backend_fallbacks"] += 1
            try:
                return _run_batch(sub, "xla", tb)
            except Exception:
                pass
        if len(sub) == 1:
            fstats["oracle_fallbacks"] += 1
            return [_oracle_result(sub[0])]
        fstats["bisections"] += 1
        mid = len(sub) // 2
        return (_run_batch_resilient(sub[:mid], backend, tb, fstats)
                + _run_batch_resilient(sub[mid:], backend, tb, fstats))


def run_sweep(cells: Sequence[SweepCell], *, cache: bool = True,
              cache_dir: str = DEFAULT_CACHE_DIR,
              backend: str = "auto",
              block_size: Optional[int] = None) -> SweepResult:
    """Simulate every cell, batched into one compiled time-blocked program.

    Results are bit-identical to per-cell :func:`run_method` /
    :func:`run_method_dynamic` calls (enforced by ``tests/test_sweep.py``
    and ``tests/test_backends.py``) regardless of ``backend`` and
    ``block_size``.  With ``cache`` enabled, previously simulated cells
    (same spec, mapping/trace *content* and code version — see
    :func:`cell_key`) are loaded from ``cache_dir`` and skipped; set the
    ``REPRO_SWEEP_NO_CACHE`` env var or ``cache=False`` to bypass.

    * ``backend`` — ``'auto'`` (pallas on TPU, xla elsewhere), ``'xla'``
      (time-blocked vmapped scan; the CPU fast path), or ``'pallas'``
      (the :mod:`repro.kernels.tlb_sweep` kernel; interpret mode off-TPU).
    * ``block_size`` — trace steps per block (default ``DEFAULT_BLOCK``,
      or the ``REPRO_SWEEP_BLOCK`` env var).  Execution detail only: block
      boundaries never change results.

    Usage — compare two methods on a workload-derived scenario::

        from repro.core.baselines import base_spec, kaligned_for_mapping
        from repro.core.sweep import SweepCell, run_sweep
        from repro.scenarios import get_scenario

        d = get_scenario("kv-churn").materialize(n_pages=1 << 15,
                                                 trace_len=100_000)
        specs = [base_spec(), kaligned_for_mapping(d.mapping, psi=3)]
        sweep = run_sweep([SweepCell(s, d.mapping, d.trace) for s in specs])
        for r in sweep:                      # SimResult per cell, in order
            print(r.name, r.misses, r.cpi)
        print(sweep.stats)                   # n_cells / cache_hits / wall_s

    Lanes are padded onto one array layout (max L2 geometry of the batch,
    inert ``K=-1`` alignment slots, power-of-two lane/trace shape buckets),
    so heterogeneous specs, footprints and trace lengths all reuse one
    compiled executable per shape bucket — see
    :mod:`repro.core.lane_program` for the padding rules.  Batches mixing
    static and dynamic worlds are partitioned so purely-static cells never
    execute the epoch-segmented machinery.
    """
    t0 = time.time()
    backend = resolve_backend(backend)
    tb = _block_size(block_size)
    cache = cache and not os.environ.get("REPRO_SWEEP_NO_CACHE")
    cells = list(cells)
    results: List[Optional[SimResult]] = [None] * len(cells)
    todo: List[int] = []
    hits = 0
    digests: Dict[int, str] = {}   # id-keyed; cells keep the arrays alive
    keys = [cell_key(c, digests) if cache else "" for c in cells]
    fstats = dict(cache_quarantined=0, backend_fallbacks=0,
                  bisections=0, oracle_fallbacks=0)
    for i, c in enumerate(cells):
        if cache:
            path = os.path.join(cache_dir, keys[i] + ".npz")
            r, corrupt = _cache_load(path)
            if corrupt:
                _quarantine_cache_entry(path)
                fstats["cache_quarantined"] += 1
            if r is not None:
                results[i] = r
                hits += 1
                continue
        todo.append(i)

    # Partition: static cells never ride a multi-segment timeline installed
    # by segmented (dynamic/multi-tenant) cells sharing the sweep (and vice
    # versa the segmented batch stays small).  Groups larger than the
    # lane-sharing bucket are chunked at its size, so a 5-row and an 8-row
    # suite execute the SAME compiled programs instead of specializing on
    # their exact lane counts.  Each chunk is one packed batch.
    groups = [[i for i in todo if not cells[i].is_segmented],
              [i for i in todo if cells[i].is_segmented]]
    batches = [g[k: k + LANE_SHARE_MAX]
               for g in groups if g
               for k in range(0, len(g), LANE_SHARE_MAX)]
    for group in batches:
        sub = [cells[i] for i in group]
        for j, r in enumerate(_run_batch_resilient(sub, backend, tb, fstats)):
            i = group[j]
            results[i] = r
            if cache:
                _cache_store(os.path.join(cache_dir, keys[i] + ".npz"), r)

    tb_eff = tb
    if backend == "pallas":
        # the kernel caps its own block size (its body is unrolled); report
        # what actually ran, not what was requested
        from ..kernels.tlb_sweep.ops import effective_block
        tb_eff = effective_block(tb)
    stats = dict(n_cells=len(cells), cache_hits=hits,
                 simulated=len(todo), n_batches=len(batches),
                 backend=backend, block=tb_eff,
                 wall_s=round(time.time() - t0, 3), **fstats)
    return SweepResult(results=results, stats=stats)  # type: ignore[arg-type]
