"""Single source of truth for every packed plane / record layout.

Every TLB structure the batched executors carry is a packed int32 array
whose trailing axis is a fixed field tuple.  Those widths and field
orders used to be duplicated as comments and bare literals across
:mod:`repro.core.lane_program` and the Pallas kernel
(``kernels/tlb_sweep``); they live here now, and both backends derive
their allocation widths and field indices from this table.  The
contract checker (``repro.analysis.pass_plane_layout``) parses this
module with :func:`ast.literal_eval` — keep the ``*_FIELDS`` constants
pure literals (no imports, no computed values feeding them) so the
analyzer never needs jax to read them.

Layout invariant: every plane carries the ASID its entry was filled
under, and ``asid`` is the LAST field except for declared sidecar
fields (see ``SIDECAR_FIELDS``) — probes require an ASID match and the
context-switch pass clears by it, so a plane without a trailing ASID
cannot participate in multi-tenant worlds.
"""

# Packed planes: name -> trailing-axis field tuple.
PLANE_FIELDS = {
    # L1 / gated 2MB L1 array: 4KB (resp. 2MB) translations.
    "l1": ("tag", "ppn", "lru", "asid"),
    "l1h": ("tag", "ppn", "lru", "asid"),
    # Unified L2: every kind's entries share this layout.  ``aux`` is a
    # per-kind sidecar (subregion contiguity bitmap; 0 for other kinds).
    "l2": ("tag", "kcls", "contig", "ppn", "lru", "asid", "aux"),
    # RMM range table.
    "rmm": ("start", "len", "ppn", "lru", "asid"),
    # Clustered side-TLB.
    "clus": ("tag", "bitmap", "lru", "asid"),
    # Cache-backed tier (Victima lineage).
    "ctlb": ("tag", "ppn", "lru", "asid"),
}

# Fields allowed to follow ``asid`` (per-kind sidecar data).
SIDECAR_FIELDS = ("aux",)

# Precomputed per-vpn records gathered by the step (one row per page).
MAP_REC_FIELDS = ("ppn", "run_start", "run_len", "run_start_ppn")
FILL_REC_FIELDS = ("tag", "k", "contig", "ppn", "aux")

# Pallas kernel SMEM misc scalars.
MISC_FIELDS = ("t", "pred", "asid")

# Derived widths (everything below is computed; the analyzer only
# literal-evals the field tuples above).
PLANE_WIDTH = {name: len(fields) for name, fields in PLANE_FIELDS.items()}
MAP_REC_WIDTH = len(MAP_REC_FIELDS)
FILL_REC_WIDTH = len(FILL_REC_FIELDS)
MISC_WIDTH = len(MISC_FIELDS)
