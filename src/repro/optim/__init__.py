from .optimizer import (AdamState, FactorState, OptConfig, abstract_opt,
                        apply_opt, clip_by_global_norm, global_norm, init_opt,
                        opt_logical, schedule)
