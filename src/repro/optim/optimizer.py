"""Optimizers: AdamW, AdamW-8bit (block-quantized first moment), Adafactor.

All pure-functional: ``init(params) -> state``, ``update(grads, state, params,
step) -> (new_params, new_state)``.  The 8-bit variant is the
distributed-optimization trick that lets jamba-398B fit a single 256-chip pod
(see EXPERIMENTS.md §Dry-run): m is stored int8 with per-block scales
(block = 256), v in bfloat16.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"            # adamw | adamw8bit | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: PyTree, max_norm: float
                        ) -> Tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# int8 block quantization (for 8-bit moments)
#
# Blocks run along the LAST axis only, so quantized moments keep the param's
# leading dims and inherit its sharding (the whole point for 398B models).
# ---------------------------------------------------------------------------

QBLOCK = 256


def _q8_shapes(shape) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    last = shape[-1] if shape else 1
    padded = -(-last // QBLOCK) * QBLOCK
    return (tuple(shape[:-1]) + (padded,),
            tuple(shape[:-1]) + (padded // QBLOCK,))


def _q8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    shape = x.shape
    qshape, sshape = _q8_shapes(shape)
    pad = qshape[-1] - shape[-1]
    xp = jnp.pad(x.astype(jnp.float32), [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    blk = xp.reshape(sshape + (QBLOCK,))
    amax = jnp.max(jnp.abs(blk), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(blk / scale), -127, 127).astype(jnp.int8)
    return q.reshape(qshape), scale[..., 0].astype(jnp.float32)


def _dq8(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    sshape = scale.shape
    blk = q.reshape(sshape + (QBLOCK,)).astype(jnp.float32) * scale[..., None]
    return blk.reshape(sshape[:-1] + (-1,))[..., : shape[-1]]


# ---------------------------------------------------------------------------
# AdamW family
# ---------------------------------------------------------------------------

class AdamState(NamedTuple):
    m: PyTree
    v: PyTree
    m_scale: Optional[PyTree]   # None for fp32 m


def init_adam(params: PyTree, kind: str = "adamw") -> AdamState:
    if kind == "adamw8bit":
        def mk(p):
            q, s = _q8(jnp.zeros_like(p, jnp.float32))
            return q, s
        qs = jax.tree.map(mk, params)
        m = jax.tree.map(lambda t: t[0], qs, is_leaf=lambda x: isinstance(x, tuple))
        sc = jax.tree.map(lambda t: t[1], qs, is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
        return AdamState(m, v, sc)
    m = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    v = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamState(m, v, None)


def adam_update(cfg: OptConfig, grads: PyTree, state: AdamState,
                params: PyTree, step: jax.Array
                ) -> Tuple[PyTree, AdamState]:
    lr = schedule(cfg, step)
    t = step.astype(jnp.float32) + 1.0
    c1 = 1 - cfg.b1 ** t
    c2 = 1 - cfg.b2 ** t
    eight_bit = state.m_scale is not None

    def upd(p, g, m, v, ms=None):
        g32 = g.astype(jnp.float32)
        m32 = _dq8(m, ms, p.shape) if eight_bit else m
        v32 = v.astype(jnp.float32)
        m32 = cfg.b1 * m32 + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v32 + (1 - cfg.b2) * jnp.square(g32)
        mhat = m32 / c1
        vhat = v32 / c2
        upd_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            upd_ = upd_ + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd_).astype(p.dtype)
        if eight_bit:
            qm, qs = _q8(m32)
            return new_p, qm, qs, v32.astype(jnp.bfloat16)
        return new_p, m32, None, v32

    if eight_bit:
        out = jax.tree.map(upd, params, grads, state.m, state.v, state.m_scale)
        leaves = lambda i: jax.tree.map(lambda t: t[i], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
        return leaves(0), AdamState(leaves(1), leaves(3), leaves(2))
    out = jax.tree.map(upd, params, grads, state.m, state.v)
    leaves = lambda i: jax.tree.map(lambda t: t[i], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
    return leaves(0), AdamState(leaves(1), leaves(3), None)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment for >=2D params)
# ---------------------------------------------------------------------------

class FactorState(NamedTuple):
    vr: PyTree
    vc: PyTree
    v: PyTree      # unfactored fallback for <2D


def init_adafactor(params: PyTree) -> FactorState:
    def rows(p):
        return (jnp.zeros(p.shape[:-1], jnp.float32) if p.ndim >= 2
                else jnp.zeros((1,), jnp.float32))

    def cols(p):
        return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                if p.ndim >= 2 else jnp.zeros((1,), jnp.float32))

    def full(p):
        return (jnp.zeros((1,), jnp.float32) if p.ndim >= 2
                else jnp.zeros(p.shape, jnp.float32))
    return FactorState(jax.tree.map(rows, params), jax.tree.map(cols, params),
                       jax.tree.map(full, params))


def adafactor_update(cfg: OptConfig, grads: PyTree, state: FactorState,
                     params: PyTree, step: jax.Array
                     ) -> Tuple[PyTree, FactorState]:
    lr = schedule(cfg, step)
    t = step.astype(jnp.float32) + 1.0
    beta2 = 1.0 - t ** -0.8

    def upd(p, g, vr, vc, v):
        g32 = g.astype(jnp.float32)
        g2 = jnp.square(g32) + 1e-30
        if p.ndim >= 2:
            vr = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = (vr[..., None] * vc[..., None, :]
                     / jnp.maximum(jnp.mean(vr, axis=-1,
                                            keepdims=True)[..., None], 1e-30))
            u = g32 / jnp.sqrt(denom + 1e-30)
        else:
            v = beta2 * v + (1 - beta2) * g2
            u = g32 / jnp.sqrt(v + 1e-30)
        # update clipping (Shazeer & Stern)
        rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
        u = u / jnp.maximum(1.0, rms_u)
        if p.ndim >= 2:
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), vr, vc, v

    out = jax.tree.map(upd, params, grads, state.vr, state.vc, state.v)
    leaves = lambda i: jax.tree.map(lambda tup: tup[i], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
    return leaves(0), FactorState(leaves(1), leaves(2), leaves(3))


# ---------------------------------------------------------------------------
# unified front-end
# ---------------------------------------------------------------------------

def init_opt(cfg: OptConfig, params: PyTree):
    if cfg.kind in ("adamw", "adamw8bit"):
        return init_adam(params, cfg.kind)
    if cfg.kind == "adafactor":
        return init_adafactor(params)
    raise ValueError(cfg.kind)


def abstract_opt(cfg: OptConfig, abstract_params: PyTree):
    """ShapeDtypeStruct mirror of ``init_opt`` (dry-run: no allocation)."""
    sds = jax.ShapeDtypeStruct
    if cfg.kind == "adamw":
        m = jax.tree.map(lambda p: sds(p.shape, jnp.float32), abstract_params)
        v = jax.tree.map(lambda p: sds(p.shape, jnp.float32), abstract_params)
        return AdamState(m, v, None)
    if cfg.kind == "adamw8bit":
        m = jax.tree.map(lambda p: sds(_q8_shapes(p.shape)[0], jnp.int8),
                         abstract_params)
        sc = jax.tree.map(lambda p: sds(_q8_shapes(p.shape)[1], jnp.float32),
                          abstract_params)
        v = jax.tree.map(lambda p: sds(p.shape, jnp.bfloat16), abstract_params)
        return AdamState(m, v, sc)
    if cfg.kind == "adafactor":
        def rows(p):
            return (sds(p.shape[:-1], jnp.float32) if len(p.shape) >= 2
                    else sds((1,), jnp.float32))

        def cols(p):
            return (sds(p.shape[:-2] + p.shape[-1:], jnp.float32)
                    if len(p.shape) >= 2 else sds((1,), jnp.float32))

        def full(p):
            return (sds((1,), jnp.float32) if len(p.shape) >= 2
                    else sds(p.shape, jnp.float32))
        return FactorState(jax.tree.map(rows, abstract_params),
                           jax.tree.map(cols, abstract_params),
                           jax.tree.map(full, abstract_params))
    raise ValueError(cfg.kind)


def opt_logical(cfg: OptConfig, param_logical: PyTree):
    """Logical axes for the opt state (mirrors ``abstract_opt``).

    Moment tensors inherit the param's logical axes (same rank); factored /
    scale tensors inherit sliced axes; non-divisible dims fall back to
    replication inside ``logical_to_pspec``'s divisibility check.
    """
    is_leaf = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)
    ident = lambda lg: lg
    if cfg.kind == "adamw":
        m = jax.tree.map(ident, param_logical, is_leaf=is_leaf)
        return AdamState(m, m, None)
    if cfg.kind == "adamw8bit":
        m = jax.tree.map(ident, param_logical, is_leaf=is_leaf)
        return AdamState(m, m, m)
    if cfg.kind == "adafactor":
        rows = jax.tree.map(lambda lg: lg[:-1] or (None,), param_logical,
                            is_leaf=is_leaf)
        cols = jax.tree.map(lambda lg: (lg[:-2] + lg[-1:]) if len(lg) >= 2
                            else (None,), param_logical, is_leaf=is_leaf)
        full = jax.tree.map(lambda lg: (None,) if len(lg) >= 2 else lg,
                            param_logical, is_leaf=is_leaf)
        return FactorState(rows, cols, full)
    raise ValueError(cfg.kind)


def apply_opt(cfg: OptConfig, grads: PyTree, state, params: PyTree,
              step: jax.Array):
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    if cfg.kind in ("adamw", "adamw8bit"):
        new_p, new_s = adam_update(cfg, grads, state, params, step)
    else:
        new_p, new_s = adafactor_update(cfg, grads, state, params, step)
    return new_p, new_s, gnorm
