import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:

* ``.lower().compile()`` must succeed for the 16x16 single-pod mesh AND the
  2x16x16 multi-pod mesh for every runnable cell;
* ``compiled.memory_analysis()`` per-device bytes prove the cell fits a
  16GB v5e chip;
* ``compiled.cost_analysis()`` + HLO collective parsing feed §Roofline.

Usage:
    python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
    python -m repro.launch.dryrun --all --out results/dryrun
Every cell writes a JSON next to ``--out`` so the sweep is restartable.
"""
import argparse
import json
import re
import sys
import time
import traceback
from collections import Counter
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from ..configs import SHAPES, ShapeSpec, all_cells, cell_status, get_config
from ..distributed.sharding import act_pspec, dp_size, param_sharding
from ..models import Model, RunConfig
from ..models.config import ModelConfig
from ..models.model import (decode_state_logical, decode_state_shapes,
                            model_specs, padded_vocab)
from ..models.common import logical_tree, spec_shapes
from ..optim import OptConfig, abstract_opt, opt_logical
from ..train.train_step import (batch_logical_axes, make_batch_shapes,
                                make_serve_step, make_train_step)
from .hlo_analysis import analyze_hlo
from .mesh import make_production_mesh

# TPU v5e hardware constants (§Roofline)
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~3 links usable per chip)
HBM_PER_CHIP = 16e9


# ---------------------------------------------------------------------------
# per-cell execution policy (microbatching, optimizer, dtypes, rules)
# ---------------------------------------------------------------------------

def cell_runconfig(cfg: ModelConfig, shape: ShapeSpec, mesh,
                   rules: str = "default",
                   microbatches: Optional[int] = None,
                   overrides: Optional[Dict[str, Any]] = None) -> RunConfig:
    dp = dp_size(mesh)
    rc = RunConfig()
    kw: Dict[str, Any] = dict(rules=rules)
    if shape.kind == "train":
        # auto-microbatching: keep per-layer saved activations ~<=2GB/device
        b_loc = max(shape.global_batch // dp, 1)
        bytes_per_layer_carry = (b_loc * shape.seq_len * cfg.d_model * 2)
        saved = bytes_per_layer_carry * cfg.n_layers
        micro = 1
        while saved / micro > 2e9 and micro < b_loc:
            micro *= 2
        kw.update(microbatches=(microbatches or micro),
                  param_dtype="float32", compute_dtype="bfloat16")
        if cfg.param_count() > 1e11:      # jamba-398B: factored opt + bf16
            kw.update(optimizer="adafactor", param_dtype="bfloat16",
                      grad_dtype="bfloat16", scan_chunk=128)
        kw.update(attn_q_chunk=512, attn_kv_chunk=1024, scan_chunk=256)
    elif shape.kind == "prefill":
        kw.update(param_dtype="bfloat16", compute_dtype="bfloat16",
                  attn_q_chunk=1024, attn_kv_chunk=2048, scan_chunk=512,
                  remat="none")
        # chunked prefill when per-device activation transients get large
        b_loc = max(shape.global_batch // dp, 1)
        est = b_loc * shape.seq_len * cfg.d_model * 24
        chunks = 1
        while est / chunks > 4e9 and chunks < 8:
            chunks *= 2
        kw.update(prefill_seq_chunks=chunks)
    else:  # decode
        kw.update(param_dtype="bfloat16", compute_dtype="bfloat16",
                  remat="none")
        if shape.seq_len >= 100_000:
            kw.update(rules=rules if rules != "default" else "default")
    if overrides:
        kw.update(overrides)
    return rc.replace(**kw)


def act_rules_for(shape: ShapeSpec) -> str:
    if shape.kind == "decode":
        return "decode_long" if shape.seq_len >= 100_000 else "decode"
    return "default"


# ---------------------------------------------------------------------------
# analytic memory-traffic model (per chip per step, bytes)
#
# The CPU-compiled HLO's fusion granularity over-counts HBM traffic relative
# to TPU codegen (attention tiles that Pallas keeps VMEM-resident appear as
# HBM-touching fusions).  We therefore report three memory estimates:
#   * hlo_upper  — every compiled fusion/dot/copy touching memory (parsed)
#   * hlo_dot    — matmul operands/results only (unavoidable floor, parsed)
#   * analytic   — the model below (weights + activations + KV + optimizer)
# and use `analytic` for bottleneck identification.
# ---------------------------------------------------------------------------

ACT_TENSORS_PER_LAYER = 14      # d-sized tensor reads+writes per token, fwd
REMAT_FACTOR = 1.5              # full remat: fwd recompute in bwd


def _param_bytes_per_chip(cfg: ModelConfig, rc: RunConfig, n_chips: int) -> float:
    bs = {"float32": 4, "bfloat16": 2}[rc.param_dtype]
    return cfg.param_count() * bs / n_chips


def _opt_bytes_per_chip(cfg: ModelConfig, rc: RunConfig, n_chips: int) -> float:
    n = cfg.param_count() / n_chips
    return {"adamw": 8 * n, "adamw8bit": 3.02 * n,
            "adafactor": 0.02 * n}[rc.optimizer]


def analytic_memory_bytes(cfg: ModelConfig, shape: ShapeSpec, rc: RunConfig,
                          n_chips: int, dp: int) -> Dict[str, float]:
    W = _param_bytes_per_chip(cfg, rc, n_chips)
    ab = 2  # bf16 activations
    d = cfg.d_model
    L = cfg.n_layers
    L_attn = cfg.n_attn_layers
    if shape.kind == "train":
        micro = rc.microbatches
        tok_chip = shape.global_batch * shape.seq_len / dp   # per chip, step
        # weights: fwd + bwd + remat-recompute reads, per microbatch
        weights = (2 + REMAT_FACTOR) * W * micro
        acts = (tok_chip * L * d * ab * ACT_TENSORS_PER_LAYER
                * (1 + 1 + (REMAT_FACTOR - 1)))
        # flash attention: each q-block re-reads K,V (causal: half on avg)
        nq = max(shape.seq_len // rc.attn_q_chunk, 1)
        attn = (tok_chip / max(shape.seq_len, 1)) * nq * (shape.seq_len / 2) \
            * cfg.kv_dim * 2 * ab * L_attn * 2
        grads = 2.0 * 4 * cfg.param_count() / n_chips * micro  # fp32 accum r/w
        opt = 2 * W + 2 * _opt_bytes_per_chip(cfg, rc, n_chips)
        total = weights + acts + attn + grads + opt
        return dict(weights=weights, activations=acts, attention=attn,
                    grads=grads, optimizer=opt, total=total)
    if shape.kind == "prefill":
        tok_chip = shape.global_batch * shape.seq_len / dp
        weights = W
        acts = tok_chip * L * d * ab * ACT_TENSORS_PER_LAYER
        nq = max(shape.seq_len // rc.attn_q_chunk, 1)
        attn = (tok_chip / max(shape.seq_len, 1)) * nq * (shape.seq_len / 2) \
            * cfg.kv_dim * 2 * ab * L_attn
        cache_w = tok_chip * cfg.kv_dim * 2 * ab * L_attn
        total = weights + acts + attn + cache_w
        return dict(weights=weights, activations=acts, attention=attn,
                    cache_write=cache_w, total=total)
    # decode: weights + full KV-cache read + state r/w per token
    b_chip = max(shape.global_batch / dp, shape.global_batch / dp)
    kv_read = (b_chip * shape.seq_len * cfg.kv_dim * 2 * ab * L_attn
               / (n_chips / dp if False else 1))
    # kv head_dim is model-sharded: divide by the model-axis size
    model_par = n_chips // dp
    kv_read = kv_read / model_par
    ssm = 0.0
    if cfg.mamba is not None:
        d_in = cfg.mamba.expand * d
        n_mamba = L - L_attn
        ssm = 2 * b_chip * d_in * cfg.mamba.d_state * 4 * n_mamba / model_par
    if cfg.family == "xlstm":
        d_in = 2 * d
        dh = d_in // cfg.n_heads
        ssm = 2 * b_chip * cfg.n_heads * dh * dh * 4 * L / model_par
    acts = b_chip * L * d * ab * ACT_TENSORS_PER_LAYER
    total = W + kv_read + ssm + acts
    return dict(weights=W, kv_read=kv_read, state=ssm, activations=acts,
                total=total)


def _cpu_f32_mirror_bytes(hlo: str, args) -> int:
    """Bytes of f32 while-carry entries shape-matching bf16 input shards.

    These are CPU-backend upcast mirrors (no native bf16 matmul); a TPU
    build does not allocate them.  Conservative: only counts entries inside
    top-level while tuples of the entry computation.
    """
    from collections import Counter
    from .hlo_analysis import _SHAPE_RE, parse_hlo

    want: Counter = Counter()
    for leaf in jax.tree.leaves(args):
        if getattr(leaf, "dtype", None) == jnp.bfloat16:
            sh = getattr(leaf, "sharding", None)
            shard = (sh.shard_shape(leaf.shape) if sh is not None
                     else leaf.shape)
            want[tuple(int(d) for d in shard)] += 2   # appears in ≤2 loops
    comps = parse_hlo(hlo)
    if "__entry__" not in comps:
        return 0
    # while ops at every nesting level (microbatch loop bodies contain the
    # fwd/bwd layer scans)
    whiles = []
    seen_names = set()
    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        for op in comp.ops.values():
            if op.kind == "while" and op.name not in seen_names:
                seen_names.add(op.name)
                whiles.append(op)
    for op in comps["__entry__"].ops.values():
        if op.kind == "while" and op.name not in seen_names:
            seen_names.add(op.name)
            whiles.append(op)
    # bf16 loop-carried buffers (e.g. remat activation saves) also get f32
    # mirrors; only sizeable ones matter
    for op in whiles:
        for dt, dims in _SHAPE_RE.findall(op.result_sig):
            if dt != "bf16" or not dims:
                continue
            shp = tuple(int(d) for d in dims.split(",") if d)
            if int(np.prod(shp)) * 2 > 1e8:
                want[shp] += 1
    mirror = 0
    for op in whiles:
        for dt, dims in _SHAPE_RE.findall(op.result_sig):
            if dt != "f32" or not dims:
                continue
            shp = tuple(int(d) for d in dims.split(",") if d)
            if want.get(shp, 0) > 0:
                want[shp] -= 1
                mirror += int(np.prod(shp)) * 4
    return mirror


# ---------------------------------------------------------------------------
# collective parsing (§Roofline: collective bytes are NOT in cost_analysis)
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(bf16|f32|f16|s32|u32|s8|u8|pred|f8\w*|s64|u64)"
                       r"\[([\d,]*)\]")
_DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "s64": 8, "u64": 8}
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def parse_collectives(hlo: str) -> Dict[str, Dict[str, float]]:
    """Sum operand bytes of every collective op in the compiled HLO."""
    out: Dict[str, Dict[str, float]] = {
        k: {"count": 0, "bytes": 0.0} for k in _COLL_KINDS}
    for line in hlo.splitlines():
        ls = line.strip()
        m = re.match(r"^[%\w.\-]+\s*=\s*((?:\([^)]*\)|\S+))\s+"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)", ls)
        if not m:
            continue
        result_sig, kind = m.group(1), m.group(2)
        if "-start" in ls.split(kind)[1][:10]:
            pass
        nbytes = 0.0
        for dt, dims in _SHAPE_RE.findall(result_sig):
            n = 1
            if dims:
                for d in dims.split(","):
                    if d:
                        n *= int(d)
            nbytes += n * _DTYPE_BYTES.get(dt, 4)
        out[kind]["count"] += 1
        out[kind]["bytes"] += nbytes
    return out


# ---------------------------------------------------------------------------
# cell lowering
# ---------------------------------------------------------------------------

def build_cell(arch: str, shape_name: str, mesh, rules: str = "default",
               overrides: Optional[Dict[str, Any]] = None):
    """Returns (jitted_fn, example_args_abstract) for one cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    overrides = dict(overrides or {})
    ar = overrides.pop("act_rules", None) or act_rules_for(shape)
    rc = cell_runconfig(cfg, shape, mesh, rules=rules,
                        overrides=overrides or None)
    model = Model(cfg, rc, mesh=mesh, act_rules=ar)

    specs = model_specs(cfg, rc)
    p_logical = logical_tree(specs)
    p_shapes = spec_shapes(specs, dtype=rc.param_dtype)
    p_shard = param_sharding(p_logical, p_shapes, mesh, rc.rules)
    params_abs = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        p_shapes, p_shard)

    def in_shard(logical, shp):
        return NamedSharding(mesh, act_pspec(logical, mesh, ar, shp))

    if shape.kind == "train":
        oc = OptConfig(kind=rc.optimizer if rc.optimizer != "adamw8bit"
                       else "adamw8bit")
        oc = OptConfig(kind=rc.optimizer)
        opt_abs0 = abstract_opt(oc, p_shapes)
        opt_lg = opt_logical(oc, p_logical)
        opt_shard = param_sharding(opt_lg, opt_abs0, mesh, rc.rules)
        opt_abs = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            opt_abs0, opt_shard)
        batch_abs0 = make_batch_shapes(cfg, shape.global_batch, shape.seq_len)
        blg = batch_logical_axes(cfg)
        batch_abs = {k: jax.ShapeDtypeStruct(
            v.shape, v.dtype, sharding=in_shard(blg[k], v.shape))
            for k, v in batch_abs0.items()}
        step_abs = jax.ShapeDtypeStruct((), jnp.int32)
        fn = jax.jit(make_train_step(model, oc), donate_argnums=(0, 1))
        args = (params_abs, opt_abs, batch_abs, step_abs)
    elif shape.kind == "prefill":
        def prefill(params, batch):
            if cfg.family == "encoder":
                logits, aux = model.forward(
                    params, None, input_embeds=batch["input_embeds"])
                return logits
            if rc.prefill_seq_chunks > 1:
                return model.prefill_chunked(
                    params, batch["tokens"],
                    n_chunks=rc.prefill_seq_chunks,
                    patch_embeds=batch.get("patch_embeds"))
            logits, state = model.prefill(
                params, batch["tokens"],
                patch_embeds=batch.get("patch_embeds"))
            return logits, state
        batch_abs0 = make_batch_shapes(cfg, shape.global_batch, shape.seq_len)
        blg = batch_logical_axes(cfg)
        batch_abs = {k: jax.ShapeDtypeStruct(
            v.shape, v.dtype, sharding=in_shard(blg[k], v.shape))
            for k, v in batch_abs0.items()
            if k in ("tokens", "input_embeds", "patch_embeds")}
        # §Perf iteration 1: without explicit out_shardings XLA replicated
        # the returned decode states (38GB/dev KV caches on jamba prefill).
        logits_sh = in_shard(("batch", "seq", "vocab"),
                             (shape.global_batch, shape.seq_len,
                              padded_vocab(cfg)))
        if cfg.family == "encoder":
            out_sh = logits_sh
        else:
            state_lg = decode_state_logical(cfg)
            state_abs0 = decode_state_shapes(cfg, rc, shape.global_batch,
                                             shape.seq_len, jnp.bfloat16)
            state_sh = jax.tree.map(
                lambda lg, s: in_shard(tuple(lg), s.shape),
                state_lg, state_abs0,
                is_leaf=lambda x: isinstance(x, tuple) and all(
                    a is None or isinstance(a, str) for a in x))
            out_sh = (logits_sh, state_sh)
        fn = jax.jit(prefill, out_shardings=out_sh)
        args = (params_abs, batch_abs)
    else:  # decode
        state_abs0 = decode_state_shapes(cfg, rc, shape.global_batch,
                                         shape.seq_len, jnp.bfloat16)
        state_lg = decode_state_logical(cfg)
        state_abs = jax.tree.map(
            lambda s, lg: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=in_shard(("layers",) * 0 + tuple(lg), s.shape)),
            state_abs0, state_lg,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        tok_abs = jax.ShapeDtypeStruct(
            (shape.global_batch, 1), jnp.int32,
            sharding=in_shard(("batch", None), (shape.global_batch, 1)))
        len_abs = jax.ShapeDtypeStruct(
            (shape.global_batch,), jnp.int32,
            sharding=in_shard(("batch",), (shape.global_batch,)))
        fn = jax.jit(make_serve_step(model), donate_argnums=(1,))
        args = (params_abs, state_abs, tok_abs, len_abs)
    return cfg, rc, fn, args


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rules: str = "default",
             overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    skip = cell_status(arch, shape_name)
    if skip:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                "status": "SKIP", "reason": skip}
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg, rc, fn, args = build_cell(arch, shape_name, mesh, rules, overrides)
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ca = compiled.cost_analysis()
        ma = compiled.memory_analysis()
        hlo = compiled.as_text()
    stats = analyze_hlo(hlo)          # loop-aware: while bodies x trip count
    colls = stats.collectives
    n_chips = int(np.prod(list(mesh.shape.values())))
    shape = SHAPES[shape_name]

    xla_flops = float(ca.get("flops", 0.0))   # counts loop bodies ONCE
    # The compiled SPMD module is the PER-DEVICE program: parsed flops/bytes
    # are per-chip quantities already.
    flops = stats.flops
    bytes_acc = stats.traffic_bytes
    coll_bytes = stats.collective_bytes
    dp = dp_size(mesh)
    mem_model = analytic_memory_bytes(cfg, shape, rc, n_chips, dp)

    # roofline terms (seconds; whole-step, per chip)
    t_compute = flops / PEAK_FLOPS
    t_mem_upper = bytes_acc / HBM_BW
    t_mem_dot = stats.dot_bytes / HBM_BW
    t_memory = mem_model["total"] / HBM_BW
    t_coll = coll_bytes / ICI_BW

    # model flops (6ND for train; 2ND-style per-token for decode)
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train"
                                   else (shape.seq_len if shape.kind == "prefill" else 1))
    if shape.kind == "train":
        model_flops = 6.0 * n_active * tokens
    else:
        model_flops = 2.0 * n_active * tokens
        if shape.kind == "decode":
            # attention reads over the KV cache dominate decode
            kv = (2 * cfg.n_attn_layers * cfg.kv_dim * shape.seq_len
                  * shape.global_batch * 2)
            model_flops += 2.0 * kv

    per_dev = {
        "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
        "output_bytes": getattr(ma, "output_size_in_bytes", None),
        "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
        "alias_bytes": getattr(ma, "alias_size_in_bytes", None),
    }
    total_dev = sum(v or 0 for k, v in per_dev.items()
                    if k != "alias_bytes")
    # CPU XLA has no native bf16 matmul: it materializes persistent f32
    # MIRRORS of bf16 operands (KV caches in decode scans, bf16 params in
    # grad-accumulation loops) — verified in the HLO as f32 while-carry
    # entries whose shapes equal bf16 input shards.  TPUs do bf16 dots
    # natively, so we report the footprint with those mirrors removed too.
    mirror = _cpu_f32_mirror_bytes(hlo, args)
    total_adj = total_dev - mirror
    dom = max(("compute", t_compute), ("memory", t_memory),
              ("collective", t_coll), key=lambda kv: kv[1])

    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "status": "OK", "rules": rules,
        "chips": n_chips,
        "params": cfg.param_count(), "active_params": n_active,
        "runconfig": {"microbatches": rc.microbatches,
                      "optimizer": rc.optimizer,
                      "param_dtype": rc.param_dtype,
                      "rules": rc.rules},
        "time": {"lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1)},
        "memory": dict(per_dev, total_per_device=total_dev,
                       cpu_f32_mirror_bytes=mirror,
                       total_adjusted_tpu=total_adj,
                       fits_16gb=bool(total_adj < HBM_PER_CHIP)),
        "hlo_flops_per_chip": flops,
        "hlo_flops_global": flops * n_chips,
        "hlo_bytes_per_chip": bytes_acc,
        "hlo_dot_bytes_per_chip": stats.dot_bytes,
        "xla_cost_analysis_flops": xla_flops,   # loop bodies counted once
        "collectives": colls, "collective_bytes_per_chip": coll_bytes,
        "memory_model": mem_model,
        "roofline": {
            "compute_s": t_compute, "memory_s": t_memory,
            "memory_hlo_upper_s": t_mem_upper, "memory_dot_s": t_mem_dot,
            "collective_s": t_coll, "dominant": dom[0],
            "step_lower_bound_s": max(t_compute, t_memory, t_coll),
        },
        "model_flops": model_flops,
        "useful_flops_frac": (model_flops / (flops * n_chips))
        if flops else None,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--archs", help="comma-separated arch filter (all shapes)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="recompute cells even if the JSON exists")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--rules", default="default")
    ap.add_argument("--set", action="append", default=[],
                    help="RunConfig override key=value")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args(argv)

    overrides: Dict[str, Any] = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    meshes = []
    if args.single_pod or not args.multi_pod:
        meshes.append(False)
    if args.multi_pod or not args.single_pod:
        meshes.append(True)

    if args.arch and args.shape:
        cells = [(args.arch, args.shape)]
    elif args.archs:
        sel = set(args.archs.split(","))
        cells = [(a, s) for a, s, _ in all_cells() if a in sel]
    else:
        cells = [(a, s) for a, s, _ in all_cells()]
    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}"
            if args.rules != "default":
                tag += f"__{args.rules}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path) and not args.force:
                continue
            try:
                res = run_cell(arch, shape, mp, args.rules,
                               overrides or None)
            except Exception as e:
                res = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-3000:]}
                failures += 1
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            status = res["status"]
            extra = ""
            if status == "OK":
                r = res["roofline"]
                extra = (f" dom={r['dominant']} "
                         f"mem/dev={res['memory']['total_per_device']/1e9:.2f}GB "
                         f"compile={res['time']['compile_s']}s")
            elif status == "FAIL":
                extra = " " + res["error"][:160]
            print(f"[{status}] {tag}{extra}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
