"""Loop-aware analysis of compiled HLO text (feeds §Roofline).

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of
trip count, which under-counts a scanned-64-layer model by ~2 orders of
magnitude.  This module parses the compiled HLO text into its computation
graph, recovers trip counts from loop conditions (``compare(iv,
constant(N)), direction=LT``), and accumulates through the loop nest:

* ``flops``            — 2 x prod(out) x prod(contracted dims) per ``dot``
* ``traffic_bytes``    — Σ (operand + result bytes) over fusions/dots/
                         copies/scatters: an upper-bound HBM-traffic model of
                         the compiled graph
* ``collectives``      — per-kind counts and operand bytes, loop-multiplied

Everything is derived from ``compiled.as_text()`` — the only profile source
available in a CPU dry-run.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "all-gather-start", "all-reduce-start",
               "collective-permute-start", "reduce-scatter-start",
               "all-to-all-start")
_COUNTED_TRAFFIC = ("fusion", "dot", "copy", "dynamic-update-slice",
                    "dynamic-slice", "scatter", "gather", "convolution",
                    "reduce", "transpose", "broadcast", "concatenate",
                    "select-and-scatter", "sort", "reshape", "slice", "pad",
                    "iota", "convert", "add", "multiply", "subtract",
                    "divide", "exponential", "tanh", "select", "compare",
                    "maximum", "minimum", "rsqrt", "negate", "log", "custom-call")


def _shape_bytes(sig: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(sig):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(sig: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(sig):
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    result_sig: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: Dict[str, Op]
    order: List[str]


# header params may contain nested tuple types: match permissively
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
# result signature: either a tuple "(s32[], f32[2,4]{1,0}, /*index=5*/...)"
# (no nested parens, but may contain '=' inside /*index=N*/ comments) or a
# single "f32[16,64]{1,0}" token.
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\)|[^\s=]+))\s+"
    r"([\w\-]+)\((.*?)\)(.*)$")


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            m = _COMP_HDR.match(s)
            if m and s.endswith("{"):
                cur = Computation(m.group(1), {}, [])
                if s.startswith("ENTRY"):
                    entry_name = m.group(1)
            continue
        if s == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, sig, kind, operands, attrs = m.groups()
        ops = [o.strip().lstrip("%").split(" ")[-1].lstrip("%")
               for o in _split_operands(operands)]
        cur.ops[name] = Op(name, kind, sig, ops, attrs)
        cur.order.append(name)
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def _split_operands(s: str) -> List[str]:
    out, depth, cur = [], 0, []
    for ch in s:
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            if ch in "([{":
                depth += 1
            elif ch in ")]}":
                depth -= 1
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    # operand tokens look like "bf16[2,4]{1,0} %name" or "%name"
    names = []
    for tok in out:
        tok = tok.strip()
        m = re.search(r"%([\w.\-]+)\s*$", tok)
        names.append(m.group(1) if m else tok)
    return names


_TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*{\s*"n"\s*:\s*"(\d+)"')


def _trip_count_from_attrs(attrs: str) -> Optional[int]:
    m = _TRIP_RE.search(attrs)
    return int(m.group(1)) if m else None


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts: Dict[str, int] = {}
    for op in cond.ops.values():
        if op.kind == "constant":
            m = re.search(r"constant\((-?\d+)\)", f"constant({op.attrs}")
            m2 = re.search(r"\((-?\d+)\)", op.result_sig + op.attrs)
            val = None
            for mm in (m, m2):
                if mm:
                    val = int(mm.group(1))
                    break
            if val is None:
                # constant value printed as operand text
                pass
            else:
                consts[op.name] = val
    # also catch "s32[] constant(64)" form captured in operands string
    for op in cond.ops.values():
        if op.kind == "constant" and op.name not in consts:
            m = re.search(r"constant\((-?\d+)\)",
                          "constant(" + ",".join(op.operands) + ")")
            if m:
                consts[op.name] = int(m.group(1))
    for op in cond.ops.values():
        if op.kind == "compare":
            m = re.search(r"direction=(\w+)", op.attrs)
            if not m:
                continue
            direction = m.group(1)
            vals = [consts.get(o) for o in op.operands]
            bound = next((v for v in vals if v is not None), None)
            if bound is None:
                continue
            if direction in ("LT", "GT"):
                return max(int(bound), 1)
            if direction in ("LE", "GE"):
                return max(int(bound) + 1, 1)
    # compare may be hidden inside a wrapped fusion: fall back to the single
    # scalar s32 constant of the condition computation (the loop bound)
    if len(consts) == 1:
        return max(next(iter(consts.values())), 1)
    return 1


def _dot_flops(op: Op, comp: Computation) -> float:
    out_dims = _shape_dims(op.result_sig)
    out_n = 1
    for _, dims in out_dims:
        for d in dims:
            out_n *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    if not m:
        return 2.0 * out_n
    lhs_op = comp.ops.get(op.operands[0])
    lhs_dims: List[int] = []
    if lhs_op is not None:
        sd = _shape_dims(lhs_op.result_sig)
        if sd:
            lhs_dims = sd[0][1]
    else:
        return 2.0 * out_n
    k = 1
    for i in m.group(1).split(","):
        if i and int(i) < len(lhs_dims):
            k *= lhs_dims[int(i)]
    return 2.0 * out_n * k


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    dot_bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=lambda: defaultdict(lambda: {"count": 0.0,
                                                     "bytes": 0.0}))

    def add(self, other: "HloStats", mult: float = 1.0):
        self.flops += other.flops * mult
        self.traffic_bytes += other.traffic_bytes * mult
        self.dot_bytes += other.dot_bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.collectives.items():
            self.collectives[k]["count"] += v["count"] * mult
            self.collectives[k]["bytes"] += v["bytes"] * mult


def _operand_bytes(op: Op, comp: Computation) -> float:
    total = 0.0
    for o in op.operands:
        src = comp.ops.get(o)
        if src is not None:
            total += _shape_bytes(src.result_sig)
    return total


def analyze_computation(comps: Dict[str, Computation], name: str,
                        memo: Dict[str, HloStats]) -> HloStats:
    if name in memo:
        return memo[name]
    comp = comps[name]
    st = HloStats()
    memo[name] = st   # cycles impossible in HLO, safe
    for op_name in comp.order:
        op = comp.ops[op_name]
        kind = op.kind
        if kind == "while":
            body = re.search(r"body=%?([\w.\-]+)", op.attrs)
            cond = re.search(r"condition=%?([\w.\-]+)", op.attrs)
            trips = _trip_count_from_attrs(op.attrs)
            if trips is None:
                trips = _trip_count(comps, cond.group(1)) if cond else 1
            if body and body.group(1) in comps:
                st.add(analyze_computation(comps, body.group(1), memo),
                       mult=trips)
            if cond and cond.group(1) in comps:
                st.add(analyze_computation(comps, cond.group(1), memo),
                       mult=trips)
            continue
        if kind in ("call", "fusion", "conditional", "async-start"):
            for m in re.finditer(r"(?:to_apply|calls)=%?([\w.\-]+)",
                                 op.attrs):
                sub = m.group(1)
                if sub in comps and sub != name:
                    sub_st = analyze_computation(comps, sub, memo)
                    if kind == "fusion":
                        # fused interiors don't touch HBM: count flops only
                        st.flops += sub_st.flops
                    else:
                        st.add(sub_st)
                    break
        base_kind = kind.replace("-start", "")
        if base_kind in ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute"):
            b = _shape_bytes(op.result_sig)
            st.collectives[base_kind]["count"] += 1
            st.collectives[base_kind]["bytes"] += b
            st.collective_bytes += b
            continue
        if kind == "dot":
            st.flops += _dot_flops(op, comp)
            st.dot_bytes += (_shape_bytes(op.result_sig)
                             + _operand_bytes(op, comp))
        if kind == "convolution":
            # rough: 2 x out x (in_ch x kernel) — conservative
            st.flops += 2.0 * _shape_bytes(op.result_sig)
        if kind in ("fusion", "dot", "copy", "dynamic-update-slice",
                    "dynamic-slice", "scatter", "gather", "reduce", "sort",
                    "concatenate", "convolution", "custom-call"):
            st.traffic_bytes += (_shape_bytes(op.result_sig)
                                 + _operand_bytes(op, comp))
    return st


def analyze_hlo(text: str) -> HloStats:
    comps = parse_hlo(text)
    if "__entry__" not in comps:
        return HloStats()
    memo: Dict[str, HloStats] = {}
    st = HloStats()
    st.add(analyze_computation(comps, "__entry__", memo))
    st.collectives = {k: dict(v) for k, v in st.collectives.items()}
    return st
