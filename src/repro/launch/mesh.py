"""Production mesh construction.

A *function*, not a module-level constant: importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first init).

Production topology (TPU v5e target):
* single-pod: 16x16 = 256 chips, axes (data, model)
* multi-pod:  2x16x16 = 512 chips, axes (pod, data, model) — the "pod" axis
  crosses DCN; keeping model-parallel traffic intra-pod and only data-
  parallel (or pipeline) traffic on "pod" is the standard 1000+-node layout.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes)
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devs)}. "
            "Run under dryrun.py (it forces 512 host devices).")
    return jax.sharding.Mesh(np.array(devs[:n]).reshape(shape), axes)


def make_test_mesh(shape: Tuple[int, ...] = (2, 2),
                   axes: Tuple[str, ...] = ("data", "model")):
    """Small mesh over however many devices exist (CPU tests)."""
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        # replicate the single device — tests that only need mesh semantics
        raise RuntimeError(f"need {n} devices, have {len(devs)}")
    return jax.sharding.Mesh(np.array(devs[:n]).reshape(shape), axes)
