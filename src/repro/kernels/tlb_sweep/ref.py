"""Pure-JAX oracle for the TLB-sweep kernel.

A deliberately simple execution of the shared lane program: one vmapped
``lax.scan`` advancing every lane by ONE trace step per iteration (no time
blocking, no block plan), with a python loop over the epoch segments and
the shootdown pass between them — the PR-3 engine structure, now expressed
through :func:`repro.core.lane_program.step_access` /
:func:`~repro.core.lane_program.shoot_lane`.

Both real backends must match this bit-for-bit (and it in turn must match
the pure-python oracles ``run_method`` / ``run_method_dynamic`` — enforced
together in ``tests/test_backends.py``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.lane_program import (STEP_KEYS, shoot_lane, step_access,
                                  switch_lane)


def run_lanes_ref(lanes, stacks, st0, seg_bounds):
    """Step-at-a-time reference with the same packed-batch contract."""
    map_stack = jnp.asarray(stacks["maps"])
    fill_stack = jnp.asarray(stacks["fills"])
    clus_map = jnp.asarray(stacks["clus"])
    dirty_stack = jnp.asarray(stacks["dirty"])
    trace_stack = jnp.asarray(stacks["trace"])
    Pc = clus_map.shape[1]
    lanes = {k: jnp.asarray(v) for k, v in lanes.items()}
    st0 = {k: jnp.asarray(v) for k, v in st0.items()}

    def one_lane(lane, st):
        params = {k: lane[k] for k in STEP_KEYS}

        def make_step(seg):
            def step(st, t_idx):
                vpn = trace_stack[lane["trace_id"], t_idx]
                mrec = map_stack[lane["seg_map"][seg], vpn]
                frec = fill_stack[lane["seg_fill"][seg], vpn]
                bm = clus_map[lane["seg_clus"][seg],
                              jnp.clip(vpn, 0, Pc - 1)]
                active = t_idx < lane["t_real"]
                return step_access(params, st, vpn, mrec, frec, bm, active)
            return step

        outs = []
        for seg, (lo, hi) in enumerate(zip(seg_bounds, seg_bounds[1:])):
            if seg > 0:
                st = switch_lane(st, lane["seg_asid"][seg],
                                 lane["seg_switch"][seg],
                                 lane["seg_fall"][seg],
                                 lane["seg_fasid"][seg])
                st = shoot_lane(params, st,
                                dirty_stack[lane["seg_dirty"][seg]],
                                lane["seg_shoot"][seg])
            st, pp = jax.lax.scan(make_step(seg), st,
                                  jnp.arange(lo, hi, dtype=jnp.int32))
            outs.append(pp)
        return st, (outs[0] if len(outs) == 1 else jnp.concatenate(outs))

    stF, ppns = jax.jit(jax.vmap(one_lane))(lanes, st0)
    return jax.device_get(stF), jax.device_get(ppns)
