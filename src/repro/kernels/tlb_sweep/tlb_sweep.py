"""TLB-sweep Pallas kernel: one lane per grid row, state resident in scratch.

The XLA backend of :mod:`repro.core.sweep` carries the packed TLB state of
every lane through a ``lax.scan`` — on a real accelerator that means the
whole state round-trips through HBM every block.  This kernel removes that
round-trip: the grid is ``(lanes, blocks)``, each lane's L1/L1H/L2/RMM/CLUS
arrays live in **scratch (VMEM)** for the entire trace, and only the trace
blocks and per-segment records stream in.

The structure mirrors ``kernels/paged_attention``: scalar-prefetched
per-lane record ids drive the ``BlockSpec`` index maps, so every grid step
receives exactly the live epoch's map/fill/cluster/dirty records for its
lane — the analogue of the window-descriptor indirection there.  The
timeline is the shared :class:`~repro.core.lane_program.BlockPlan`: blocks
never straddle an epoch-segment boundary, and the first block of every
segment runs the shootdown pass (``@pl.when``-gated per lane) before its
accesses.

The per-access datapath is **the same function** the XLA backend unrolls —
:func:`repro.core.lane_program.step_access` /
:func:`~repro.core.lane_program.shoot_lane` — applied to a state dict read
from scratch at block entry and written back at block exit.  Bit-exactness
vs the pure-python oracles is enforced by ``tests/test_backends.py``.

Off-TPU the kernel runs with ``interpret=True`` (the repo-wide convention
for Pallas kernels); the grid iterates blocks innermost, so scratch state
carries correctly from block to block within a lane.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.lane_program import (CLUS_SETS, CLUS_WAYS, INVALID, KCLS, L1_SETS,
                                  L1_WAYS, L1H_SETS, L1H_WAYS, N_COUNTERS,
                                  N_COV_SAMPLES, PPN, RMM_ENTRIES, TAG,
                                  shoot_lane, step_access, switch_lane)
from ...core.plane_layout import (FILL_REC_WIDTH, MAP_REC_WIDTH, MISC_WIDTH,
                                  PLANE_WIDTH)

# params row layout (int32): one row per lane, packed by ops.pack_params
# from PARAM_KEYS — the F_* indices and PARAM_KEYS are the same ordering
# by construction (see the zip below), so a new lane scalar is added in
# exactly one place.
PARAM_KEYS = ("is_colt", "is_thp", "has_rmm", "has_cluster", "use_pred",
              "set_mask", "n_ways", "k_hat", "miss_chain", "pred0",
              "asid0", "t_real", "sample_every", "is_subr", "has_ctlb",
              "use_dead", "coh_hw")
(F_IS_COLT, F_IS_THP, F_HAS_RMM, F_HAS_CLUSTER, F_USE_PRED, F_SET_MASK,
 F_N_WAYS, F_K_HAT, F_MISS_CHAIN, F_PRED0, F_ASID0, F_T_REAL,
 F_SAMPLE_EVERY, F_IS_SUBR, F_HAS_CTLB, F_USE_DEAD, F_COH_HW,
 ) = range(len(PARAM_KEYS))
N_PARAM_FIELDS = len(PARAM_KEYS)


def _lane_dict(p, kvals):
    """Per-lane scalar dict consumed by step_access/shoot_lane."""
    return dict(
        is_colt=p[F_IS_COLT] == 1, is_thp=p[F_IS_THP] == 1,
        is_subr=p[F_IS_SUBR] == 1, has_ctlb=p[F_HAS_CTLB] == 1,
        use_dead=p[F_USE_DEAD] == 1, coh_hw=p[F_COH_HW] == 1,
        has_rmm=p[F_HAS_RMM] == 1, has_cluster=p[F_HAS_CLUSTER] == 1,
        use_pred=p[F_USE_PRED] == 1, set_mask=p[F_SET_MASK],
        n_ways=p[F_N_WAYS], k_hat=p[F_K_HAT], miss_chain=p[F_MISS_CHAIN],
        sample_every=p[F_SAMPLE_EVERY], kvals=kvals)


def _tlb_sweep_kernel(
        # scalar prefetch
        tid_ref, smap_ref, sfill_ref, sclus_ref, sdirty_ref,
        bseg_ref, bshoot_ref, bhi_ref,
        # tensor inputs
        params_ref, kvals_ref, sshoot_ref, sasid_ref, sswitch_ref,
        sfall_ref, sfasid_ref, trace_ref, tpos_ref,
        map_ref, fill_ref, clus_ref, dirty_ref,
        # outputs
        ppn_ref, cnt_ref, cov_ref,
        # scratch: the lane's entire TLB state, resident across blocks
        l1_ref, l1h_ref, l2_ref, rmm_ref, cl_ref, ctlb_ref, dp_ref,
        misc_ref,
        *, tb: int, with_switch: bool):
    b = pl.program_id(1)
    p = params_ref[0]
    lane = _lane_dict(p, kvals_ref[0])

    @pl.when(b == 0)
    def _init():
        """Fresh TLB state at the first block of every lane."""
        l1_ref[...] = jnp.zeros_like(l1_ref).at[..., 0].set(-1)
        l1h_ref[...] = jnp.zeros_like(l1h_ref).at[..., 0].set(-1)
        l2_ref[...] = (jnp.zeros_like(l2_ref)
                       .at[..., TAG].set(-1)
                       .at[..., KCLS].set(INVALID)
                       .at[..., PPN].set(-1))
        rmm_ref[...] = jnp.zeros_like(rmm_ref).at[..., 0].set(-1)
        cl_ref[...] = jnp.zeros_like(cl_ref).at[..., 0].set(-1)
        ctlb_ref[...] = jnp.zeros_like(ctlb_ref).at[..., 0].set(-1)
        dp_ref[...] = jnp.zeros_like(dp_ref)
        misc_ref[0] = jnp.int32(0)            # t (active steps processed)
        misc_ref[1] = p[F_PRED0]              # alignment predictor
        misc_ref[2] = p[F_ASID0]              # live ASID
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        cov_ref[...] = jnp.zeros_like(cov_ref)

    def read_state():
        return dict(t=misc_ref[0], pred=misc_ref[1], asid=misc_ref[2],
                    l1=l1_ref[...], l1h=l1h_ref[...], l2=l2_ref[...],
                    rmm=rmm_ref[...], clus=cl_ref[...], ctlb=ctlb_ref[...],
                    dp=dp_ref[...], counters=cnt_ref[0],
                    cov_samples=cov_ref[0])

    def write_state(st):
        misc_ref[0] = st["t"]
        misc_ref[1] = st["pred"]
        misc_ref[2] = st["asid"]
        l1_ref[...] = st["l1"]
        l1h_ref[...] = st["l1h"]
        l2_ref[...] = st["l2"]
        rmm_ref[...] = st["rmm"]
        cl_ref[...] = st["clus"]
        ctlb_ref[...] = st["ctlb"]
        dp_ref[...] = st["dp"]
        cnt_ref[0] = st["counters"]
        cov_ref[0] = st["cov_samples"]

    seg = bseg_ref[b]

    if with_switch:
        # multi-tenant batch: segment entry runs the context switch (ASID
        # update + policy flush, data-gated per lane) then the epoch-
        # turnover shootdown (ditto) — the oracle's order.  Both passes
        # are identity for lanes whose own schedule has no boundary here.
        @pl.when(bshoot_ref[b] == 1)
        def _entry():
            st = switch_lane(read_state(), sasid_ref[0, seg],
                             sswitch_ref[0, seg] == 1,
                             sfall_ref[0, seg] == 1,
                             sfasid_ref[0, seg] == 1)
            write_state(shoot_lane(lane, st, dirty_ref[0],
                                   sshoot_ref[0, seg] == 1))
    else:
        # no lane switches (static/dynamic-only batch, knowable at pack
        # time): compile only the shootdown, gated as before
        @pl.when((bshoot_ref[b] == 1) & (sshoot_ref[0, seg] == 1))
        def _shoot():
            write_state(shoot_lane(lane, read_state(), dirty_ref[0],
                                   jnp.bool_(True)))

    st = read_state()
    vpns = trace_ref[0]                       # [tb] this lane's trace block
    tts = tpos_ref[...]                       # [tb] original t per slot
    hi = bhi_ref[b]
    t_real = p[F_T_REAL]
    Pc = clus_ref.shape[1]
    outs = []
    for j in range(tb):                       # sequential dependency chain
        vpn = vpns[j]
        mrec = map_ref[0, vpn]
        frec = fill_ref[0, vpn]
        bm = clus_ref[0, jnp.clip(vpn, 0, Pc - 1)]
        active = (tts[j] < hi) & (tts[j] < t_real)
        st, o = step_access(lane, st, vpn, mrec, frec, bm, active)
        outs.append(o)
    write_state(st)
    ppn_ref[0] = jnp.stack(outs)


def make_tlb_sweep_call(sets: int, ways: int, ctlb_sets: int = 1,
                        ctlb_ways: int = 1, dp_n: int = 1):
    """Build the jitted pallas_call wrapper for one L2 geometry.

    The returned callable invokes the kernel over the ``(lanes, blocks)``
    grid and returns ``(ppn_pad [L, NB*tb], counters [L, N_COUNTERS],
    cov_samples [L, N_COV_SAMPLES])`` — padded-timeline outputs that
    :mod:`.ops` maps back to trace order via the block plan.  The L2
    geometry — and the cache-backed-tier / dead-entry-table geometry,
    degenerate ``1`` when the batch has no such lane — parameterizes the
    scratch allocation, so it is a closure argument rather than an array
    shape.
    """

    @functools.partial(jax.jit,
                       static_argnames=("tb", "n_blocks", "interpret",
                                        "with_switch"))
    def call(tid, smap, sfill, sclus, sdirty, bseg, bshoot, bhi,
             params, kvals, sshoot, sasid, sswitch, sfall, sfasid,
             trace_pad, tpos, maps, fills, clus, dirty,
             *, tb: int, n_blocks: int, interpret: bool,
             with_switch: bool):
        L, n_segs = smap.shape
        P = maps.shape[1]
        Pc = clus.shape[1]
        Pd = dirty.shape[1]
        maxk = kvals.shape[1]
        grid = (L, n_blocks)

        def by_lane(shape):
            return pl.BlockSpec(shape, lambda l, b, *s: (l,) + (0,) *
                                (len(shape) - 1))

        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=8,
            grid=grid,
            in_specs=[
                by_lane((1, N_PARAM_FIELDS)),                 # params
                by_lane((1, maxk)),                           # kvals
                by_lane((1, n_segs)),                         # seg_shoot
                by_lane((1, n_segs)),                         # seg_asid
                by_lane((1, n_segs)),                         # seg_switch
                by_lane((1, n_segs)),                         # seg_fall
                by_lane((1, n_segs)),                         # seg_fasid
                pl.BlockSpec((1, tb),                         # trace block
                             lambda l, b, tid, *s: (tid[l], b)),
                pl.BlockSpec((tb,), lambda l, b, *s: (b,)),   # tpos block
                pl.BlockSpec((1, P, MAP_REC_WIDTH),           # map record
                             lambda l, b, tid, smap, sf, sc, sd, bseg, *s:
                             (smap[l, bseg[b]], 0, 0)),
                pl.BlockSpec((1, P, FILL_REC_WIDTH),          # fill record
                             lambda l, b, tid, smap, sf, sc, sd, bseg, *s:
                             (sf[l, bseg[b]], 0, 0)),
                pl.BlockSpec((1, Pc),                         # cluster bitmap
                             lambda l, b, tid, smap, sf, sc, sd, bseg, *s:
                             (sc[l, bseg[b]], 0)),
                pl.BlockSpec((1, Pd),                         # dirty prefix
                             lambda l, b, tid, smap, sf, sc, sd, bseg, *s:
                             (sd[l, bseg[b]], 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, tb), lambda l, b, *s: (l, b)),   # ppn
                by_lane((1, N_COUNTERS)),                         # counters
                by_lane((1, N_COV_SAMPLES)),                      # cov
            ],
            scratch_shapes=[
                pltpu.VMEM((L1_SETS, L1_WAYS, PLANE_WIDTH["l1"]), jnp.int32),
                pltpu.VMEM((L1H_SETS, L1H_WAYS, PLANE_WIDTH["l1h"]),
                           jnp.int32),
                pltpu.VMEM((sets, ways, PLANE_WIDTH["l2"]), jnp.int32),
                pltpu.VMEM((RMM_ENTRIES, PLANE_WIDTH["rmm"]), jnp.int32),
                pltpu.VMEM((CLUS_SETS, CLUS_WAYS, PLANE_WIDTH["clus"]),
                           jnp.int32),
                pltpu.VMEM((ctlb_sets, ctlb_ways, PLANE_WIDTH["ctlb"]),
                           jnp.int32),
                pltpu.VMEM((dp_n,), jnp.int32),      # dead-entry counters
                pltpu.SMEM((MISC_WIDTH,), jnp.int32),  # t, predictor, asid
            ],
        )
        out_shapes = (
            jax.ShapeDtypeStruct((L, n_blocks * tb), jnp.int32),
            jax.ShapeDtypeStruct((L, N_COUNTERS), jnp.int32),
            jax.ShapeDtypeStruct((L, N_COV_SAMPLES), jnp.int32),
        )
        kernel = functools.partial(_tlb_sweep_kernel, tb=tb,
                                   with_switch=with_switch)
        return pl.pallas_call(
            kernel, grid_spec=grid_spec, out_shape=out_shapes,
            interpret=interpret,
        )(tid, smap, sfill, sclus, sdirty, bseg, bshoot, bhi,
          params, kvals, sshoot, sasid, sswitch, sfall, sfasid,
          trace_pad, tpos, maps, fills, clus, dirty)

    return call
