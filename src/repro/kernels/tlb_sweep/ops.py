"""Public op: run packed sweep lanes through the Pallas TLB-sweep kernel.

:func:`run_lanes_pallas` has the same contract as the XLA backend's
``_simulate_lanes`` path in :mod:`repro.core.sweep`: it takes the packed
``(lanes, stacks, st0, seg_bounds)`` produced by
:func:`repro.core.lane_program.pack_lanes` plus the block size, and returns
``(final_state, ppns)`` where ``final_state`` carries the per-lane
``counters`` and ``cov_samples`` and ``ppns`` is the ``[L, T]`` translated
PPN array in trace order.  Results are bit-exact vs the XLA backend and the
pure-python oracles for every block size (``tests/test_backends.py``).

Host-side work here mirrors what the serving scheduler does for
``paged_attention``: build the static block plan, pre-gather each trace
into its padded block timeline, and pack the per-lane scalars — the kernel
then only streams blocks and records.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np

from ...core.lane_program import build_block_plan, needs_switch_pass
from .tlb_sweep import N_PARAM_FIELDS, PARAM_KEYS, make_tlb_sweep_call

_CALL_CACHE: Dict[Tuple[int, ...], object] = {}

# The kernel unrolls the intra-block dependency chain in its body, so its
# compile time scales with the block size; beyond ~8 steps the bigger body
# buys nothing (the HBM round-trip is already gone — state lives in
# scratch).  Blocking is an execution detail (results are bit-exact for
# every size), so the kernel caps its own block rather than inheriting the
# XLA backend's larger default.
MAX_KERNEL_BLOCK = 8


def effective_block(tb: int) -> int:
    """The block size the kernel actually runs for a requested ``tb`` —
    the single place the capping rule lives (``run_sweep`` reports it in
    its stats)."""
    return min(tb, MAX_KERNEL_BLOCK)


def pack_params(lanes: Dict[str, np.ndarray]) -> np.ndarray:
    """[L, N_PARAM_FIELDS] int32 per-lane scalar block for the kernel."""
    cols = [np.asarray(lanes[k], np.int32) for k in PARAM_KEYS]
    params = np.stack(cols, axis=1)
    assert params.shape[1] == N_PARAM_FIELDS
    return params


def run_lanes_pallas(lanes, stacks, st0, seg_bounds, tb: int,
                     interpret: Optional[bool] = None):
    """Simulate one packed batch with the Pallas kernel.

    ``interpret`` defaults to True off-TPU (the repo-wide kernel
    convention); ``st0`` fixes the padded L2 geometry (state itself is
    initialized in-kernel, in scratch).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    tb = effective_block(tb)
    lanes = {k: np.asarray(v) for k, v in lanes.items()}
    stacks = {k: np.asarray(v) for k, v in stacks.items()}
    plan = build_block_plan(tuple(seg_bounds), tb)

    trace = stacks["trace"]
    T = trace.shape[1]
    # pre-gather each trace into the padded block timeline (blocks never
    # straddle an epoch segment; padded slots are masked in-kernel)
    trace_pad = np.ascontiguousarray(
        trace[:, np.clip(plan.tpos, 0, T - 1)], dtype=np.int32)

    sets, ways = np.asarray(st0["l2"]).shape[1:3]
    # cache-backed-tier / dead-entry-table geometry rides along from the
    # batched init (degenerate 1s when no lane uses them)
    ctlb_sets, ctlb_ways = np.asarray(st0["ctlb"]).shape[1:3]
    dp_n = np.asarray(st0["dp"]).shape[1]
    geo = (sets, ways, ctlb_sets, ctlb_ways, dp_n)
    call = _CALL_CACHE.get(geo)
    if call is None:
        call = _CALL_CACHE[geo] = make_tlb_sweep_call(*geo)

    i32 = lambda a: np.asarray(a, np.int32)  # noqa: E731
    ppn_pad, counters, cov = call(
        i32(lanes["trace_id"]), i32(lanes["seg_map"]),
        i32(lanes["seg_fill"]), i32(lanes["seg_clus"]),
        i32(lanes["seg_dirty"]), i32(plan.blk_seg), i32(plan.blk_shoot),
        i32(plan.blk_hi),
        pack_params(lanes), i32(lanes["kvals"]), i32(lanes["seg_shoot"]),
        i32(lanes["seg_asid"]), i32(lanes["seg_switch"]),
        i32(lanes["seg_fall"]), i32(lanes["seg_fasid"]),
        trace_pad, i32(plan.tpos),
        i32(stacks["maps"]), i32(stacks["fills"]), i32(stacks["clus"]),
        i32(stacks["dirty"]),
        tb=tb, n_blocks=plan.n_blocks, interpret=bool(interpret),
        with_switch=needs_switch_pass(lanes))

    ppns = np.asarray(jax.device_get(ppn_pad))[:, plan.slot_of_t]
    stF = dict(counters=np.asarray(jax.device_get(counters)),
               cov_samples=np.asarray(jax.device_get(cov)))
    return stF, ppns
