"""Pallas TLB-sweep backend: lanes → program instances, state in scratch.

The second execution backend of the batched sweep engine
(:mod:`repro.core.sweep`): the same per-lane program definition
(:mod:`repro.core.lane_program`) run as a Pallas kernel instead of an XLA
scan.  Select it with ``run_sweep(..., backend='pallas')``.
"""
from .ops import run_lanes_pallas  # noqa: F401
