"""Pure-jnp oracle for paged decode attention.

Dense math, no paging tricks: gather pages through the block table into a
contiguous [B, S, KVH, D] view, run masked decode attention in fp32.  The
Pallas kernels in ``paged_attention.py`` must match this to float tolerance
for every (shape, dtype, contiguity pattern) — see tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def gather_kv(pool: jax.Array, block_table: jax.Array, page_size: int
              ) -> jax.Array:
    """pool: [n_pages, T, KVH, D]; block_table: [B, max_pages] (-1 pad)
    → [B, max_pages*T, KVH, D]."""
    safe = jnp.maximum(block_table, 0)
    gathered = pool[safe]                    # [B, P, T, KVH, D]
    B, P, T, KVH, D = gathered.shape
    valid = (block_table >= 0)[..., None, None, None]
    gathered = jnp.where(valid, gathered, 0)
    return gathered.reshape(B, P * T, KVH, D)


def paged_attention_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        block_tables: jax.Array, kv_lens: jax.Array,
                        page_size: int, scale: float | None = None
                        ) -> jax.Array:
    """q: [B, H, D]; pools: [n_pages, T, KVH, D]; block_tables: [B, P];
    kv_lens: [B] → o: [B, H, D]."""
    B, H, D = q.shape
    KVH = k_pool.shape[2]
    G = H // KVH
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    k = gather_kv(k_pool, block_tables, page_size)   # [B, S, KVH, D]
    v = gather_kv(v_pool, block_tables, page_size)
    S = k.shape[1]
    qg = q.reshape(B, KVH, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32)) * scale
    mask = (jnp.arange(S)[None, :] < kv_lens[:, None])[:, None, None, :]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)
