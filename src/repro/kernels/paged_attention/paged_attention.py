"""Coalesced paged decode attention — Pallas TPU kernel (the paper on TPU).

One kernel, parameterized by ``pages_per_block = 2^k`` (the class).  The
baseline paged attention is the class-0 instance (one DMA per page, vLLM
style); the coalesced scheme runs one instance per k ∈ K over the windows
*assigned* to that class (Algorithm 1's rightward-compatible fill, computed
host-side in ``repro.kvcache.block_table``), then merges the per-class
partial softmax states exactly.

Why this is the paper's mechanism and not just inspiration:

* class-k window ↔ k-bit aligned PTE whose contiguity spans its window;
* the BlockSpec ``index_map`` consulting the scalar-prefetched window table
  ↔ the aligned TLB lookup (translation happens per 2^k pages, not per page);
* one grid step loads 2^k·page_size tokens in ONE contiguous DMA ↔ one TLB
  entry covering 2^k pages (translation-overhead reduction = DMA-descriptor
  reduction);
* uncovered windows fall to the class-0 pass ↔ regular entries.

VMEM budget: a class-k tile is (2^k·T, KVH, D) for K and V → e.g. k=4,
T=64, KVH=8, D=128 ⇒ 2·16·64·8·128·2B = 4MB, well under the ~128MB VMEM of
a v5e core; ``choose_kernel_classes`` caps k accordingly.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _class_kernel(win_idx_ref, cov_ref, len_ref,   # scalar prefetch
                  q_ref, k_ref, v_ref,             # VMEM blocks
                  o_ref, m_ref, l_ref,             # outputs (revisited)
                  *, tokens_per_win: int, scale: float, kvh: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(cov_ref[b, j] == 1)
    def _attend():
        W = tokens_per_win
        q = q_ref[0].astype(jnp.float32)             # [H, D]
        k = k_ref[0].astype(jnp.float32)             # [W, KVH, D]
        v = v_ref[0].astype(jnp.float32)
        H, D = q.shape
        G = H // kvh
        qg = q.reshape(kvh, G, D)
        s = jax.lax.dot_general(
            qg, k.transpose(1, 2, 0),                # [KVH, D, W]
            dimension_numbers=(((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * scale   # [KVH, G, W]
        pos = j * W + jax.lax.broadcasted_iota(jnp.int32, (1, 1, W), 2)
        mask = pos < len_ref[b]
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[0].astype(jnp.float32).reshape(kvh, G)
        l_prev = l_ref[0].astype(jnp.float32).reshape(kvh, G)
        o_prev = o_ref[0].astype(jnp.float32).reshape(kvh, G, D)

        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p, v.transpose(1, 0, 2),                 # [KVH, W, D]
            dimension_numbers=(((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)      # [KVH, G, D]
        o_new = o_prev * alpha[..., None] + pv

        o_ref[0] = o_new.reshape(H, D).astype(o_ref.dtype)
        m_ref[0] = m_new.reshape(H).astype(m_ref.dtype)
        l_ref[0] = l_new.reshape(H).astype(l_ref.dtype)


def paged_attention_class_pass(
        q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
        win_idx: jax.Array, covered: jax.Array, kv_lens: jax.Array,
        *, pages_per_block: int, page_size: int,
        scale: Optional[float] = None, interpret: bool = True
        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One class-k pass.

    q: [B, H, D]; pools: [n_pages, T, KVH, D]; win_idx/covered: [B, n_win]
    (physical window index / class-assignment mask); kv_lens: [B].
    Returns unnormalized (o [B,H,D] f32, m [B,H] f32, l [B,H] f32).
    """
    B, H, D = q.shape
    n_pages, T, KVH, _ = k_pool.shape
    P2 = pages_per_block
    assert T == page_size
    assert n_pages % P2 == 0, (n_pages, P2)
    W = P2 * T
    n_win = win_idx.shape[1]
    scale = scale if scale is not None else 1.0 / np.sqrt(D)

    kp = k_pool.reshape(n_pages // P2, W, KVH, D)
    vp = v_pool.reshape(n_pages // P2, W, KVH, D)

    grid = (B, n_win)
    kernel = functools.partial(_class_kernel, tokens_per_win=W, scale=scale,
                               kvh=KVH)
    out_shapes = (
        jax.ShapeDtypeStruct((B, H, D), jnp.float32),
        jax.ShapeDtypeStruct((B, H), jnp.float32),
        jax.ShapeDtypeStruct((B, H), jnp.float32),
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, H, D), lambda b, j, *s: (b, 0, 0)),
            pl.BlockSpec((1, W, KVH, D),
                         lambda b, j, win, cov, ln: (win[b, j], 0, 0, 0)),
            pl.BlockSpec((1, W, KVH, D),
                         lambda b, j, win, cov, ln: (win[b, j], 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, H, D), lambda b, j, *s: (b, 0, 0)),
            pl.BlockSpec((1, H), lambda b, j, *s: (b, 0)),
            pl.BlockSpec((1, H), lambda b, j, *s: (b, 0)),
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec, out_shape=out_shapes,
        interpret=interpret,
    )(win_idx.astype(jnp.int32), covered.astype(jnp.int32),
      kv_lens.astype(jnp.int32), q, kp, vp)


def merge_partials(parts) -> jax.Array:
    """Exact merge of per-class (o_unnorm, m, l) partial-softmax states."""
    ms = jnp.stack([p[1] for p in parts])            # [C, B, H]
    m_star = jnp.max(ms, axis=0)
    o = 0.0
    lsum = 0.0
    for o_k, m_k, l_k in parts:
        w = jnp.exp(m_k - m_star)
        o = o + o_k * w[..., None]
        lsum = lsum + l_k * w
    return o / jnp.maximum(lsum, 1e-30)[..., None]
