"""Public op: coalesced paged decode attention (jit'd wrapper).

``paged_attention`` = per-class Pallas passes + exact softmax-state merge.
``K_classes = ()`` gives the page-granular baseline (one DMA per page);
``K_classes = (k1, k2, ...)`` adds coalesced classes chosen by Algorithm 3
(``repro.kvcache.block_table.choose_kernel_classes``) from the allocator's
contiguity histogram.

Descriptor tables (window index + class assignment per 2^k window) are
host-side numpy (the serving scheduler computes them when block tables
change — the analogue of the OS filling aligned entries after a page walk).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...kvcache.block_table import descriptor_tables, dma_descriptor_count
from .paged_attention import merge_partials, paged_attention_class_pass


def build_descriptors(block_tables: np.ndarray, K_classes: Sequence[int]
                      ) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
    """Host-side: class-k window tables for the kernel (scheduler-time)."""
    return descriptor_tables(np.asarray(block_tables), K_classes)


@functools.partial(jax.jit, static_argnames=("page_size", "classes",
                                             "interpret"))
def _paged_attention_jit(q, k_pool, v_pool, kv_lens, desc_flat,
                         *, page_size: int, classes: Tuple[int, ...],
                         interpret: bool):
    parts = []
    for i, k in enumerate(classes):
        win_idx, covered = desc_flat[2 * i], desc_flat[2 * i + 1]
        parts.append(paged_attention_class_pass(
            q, k_pool, v_pool, win_idx, covered, kv_lens,
            pages_per_block=1 << k, page_size=page_size,
            interpret=interpret))
    return merge_partials(parts).astype(q.dtype)


def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    block_tables: np.ndarray, kv_lens: jax.Array,
                    *, page_size: int, K_classes: Sequence[int] = (),
                    interpret: bool = True,
                    descriptors: Optional[Dict] = None) -> jax.Array:
    """q: [B, H, D] → [B, H, D] decode attention over the paged KV pool."""
    classes = tuple(sorted(set(list(K_classes) + [0]), reverse=True))
    if descriptors is None:
        descriptors = build_descriptors(block_tables, classes)
    desc_flat = []
    for k in classes:
        wi, cov = descriptors[k]
        desc_flat += [jnp.asarray(wi), jnp.asarray(cov)]
    return _paged_attention_jit(q, k_pool, v_pool, jnp.asarray(kv_lens),
                                tuple(desc_flat), page_size=page_size,
                                classes=classes, interpret=interpret)


def dma_stats(block_tables: np.ndarray, K_classes: Sequence[int]
              ) -> Dict[str, float]:
    """Descriptor-count reduction (the paper's miss metric, TPU edition)."""
    return dma_descriptor_count(np.asarray(block_tables), K_classes)
