"""Pallas TPU kernels (validated with interpret=True on CPU vs ref.py oracles).

* ``paged_attention`` — the paper's technique as a kernel: per-class
  coalesced superblock DMA over the paged KV pool (ops.paged_attention).
* ``flash_attention`` — tiled causal online-softmax forward for
  prefill/serving (ops.flash_attention_gqa).
* ``tlb_sweep`` — the sweep engine's Pallas backend: one lane per grid
  row, all TLB state resident in scratch for the whole trace
  (ops.run_lanes_pallas; select with ``run_sweep(backend='pallas')``).
"""
