"""Pallas TPU kernels (validated with interpret=True on CPU vs ref.py oracles).

* ``paged_attention`` — the paper's technique as a kernel: per-class
  coalesced superblock DMA over the paged KV pool (ops.paged_attention).
* ``flash_attention`` — tiled causal online-softmax forward for
  prefill/serving (ops.flash_attention_gqa).
"""
