"""Tiled causal flash attention (forward) — Pallas TPU kernel.

Used by prefill/serving on TPU; training uses the jnp chunked-attention path
(same blocking, autodiff-able) with this kernel as the drop-in fast forward.
Grid (B·H, n_q_blocks, n_kv_blocks); online softmax in fp32 scratch;
causal tiles skip fully-masked kv blocks via the index structure.

BlockSpec tiling: q tile (Bq, D), kv tiles (Bk, D) — MXU-aligned when
Bq, Bk are multiples of 128 and D ∈ {64, 128}.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale: float, causal: bool, block_q: int, block_k: int,
                  seq_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    @pl.when((not causal) or (ki * block_k <= qi * block_q + block_q - 1))
    def _attend():
        q = q_ref[0].astype(jnp.float32)          # [Bq, D]
        k = k_ref[0].astype(jnp.float32)          # [Bk, D]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [Bq, Bk]
        mask = (k_pos < seq_len) & (q_pos < seq_len)
        if causal:
            mask &= q_pos >= k_pos
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc_scr[...]
        acc = acc * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new
        acc_scr[...] = acc

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        lsum = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / lsum).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q,k,v: [B, S, H, D] (H == KVH after GQA repeat) → [B, S, H, D]."""
    B, S, H, D = q.shape
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    block_q = min(block_q, max(S, 8))
    block_k = min(block_k, max(S, 8))
    pad_q = (-S) % block_q
    pad_k = (-S) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Sq, Sk = qp.shape[1], kp.shape[1]
    # [B, S, H, D] -> [B*H, S, D]
    def bh(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, x.shape[1], D)
    qb, kb, vb = bh(qp), bh(kp), bh(vp)
    grid = (B * H, Sq // block_q, Sk // block_k)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               block_q=block_q, block_k=block_k, seq_len=S)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, block_k, D), lambda h, qi, ki: (h, ki, 0)),
            pl.BlockSpec((1, block_k, D), lambda h, qi, ki: (h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qb, kb, vb)
    out = out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)[:, :S]
    return out
