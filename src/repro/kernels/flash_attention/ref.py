"""Pure-jnp oracle for flash attention: naive masked softmax attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal: bool = True, scale=None):
    """q,k,v: [B, S, H, D] → [B, S, H, D] (fp32 math)."""
    B, S, H, D = q.shape
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
