"""jit'd wrapper for the flash attention kernel (GQA-aware)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention_gqa(q, k, v, *, causal=True, block_q=128, block_k=128,
                        interpret=True):
    """q: [B, S, Hq, D]; k,v: [B, S, KVH, D] with Hq % KVH == 0."""
    Hq, KVH = q.shape[2], k.shape[2]
    if Hq != KVH:
        rep = Hq // KVH
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    return flash_attention(q, k, v, causal=causal, block_q=block_q,
                           block_k=block_k, interpret=interpret)
