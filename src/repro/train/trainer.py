"""Fault-tolerant training loop.

Fault-tolerance posture (designed for 1000+ nodes, exercised on CPU tests):

* **checkpoint/restart** — async atomic checkpoints every ``ckpt_every``
  steps (params, opt state, data-pipeline state, step); ``Trainer.run``
  auto-resumes from the newest complete checkpoint, so a killed process
  (node failure) loses at most ``ckpt_every`` steps.
* **elastic rescale**   — restore maps leaves onto the *current* mesh's
  shardings (see Checkpointer.restore), so the same checkpoint continues on
  a different device count after failures shrink the fleet.
* **straggler mitigation** — per-step wall times feed an EWMA watchdog; steps
  slower than ``straggler_factor``× the EWMA are logged and counted.  On real
  fleets this signal drives hot-spare swap-in; here it is surfaced in metrics
  and tested via injected delays.
* **failure injection**  — ``failure_hook(step)`` raising ``SimulatedFailure``
  exercises the crash/restore path in integration tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax

from ..checkpoint.checkpointer import Checkpointer
from ..data.pipeline import DataPipeline
from ..models.model import Model
from ..optim.optimizer import OptConfig, init_opt
from .train_step import make_train_step

PyTree = Any


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 2
    log_every: int = 10
    straggler_factor: float = 3.0


class Trainer:
    def __init__(self, model: Model, opt_cfg: OptConfig, tc: TrainerConfig,
                 pipeline: DataPipeline,
                 failure_hook: Optional[Callable[[int], None]] = None,
                 param_shardings: Optional[PyTree] = None):
        self.model = model
        self.opt_cfg = opt_cfg
        self.tc = tc
        self.pipeline = pipeline
        self.failure_hook = failure_hook
        self.param_shardings = param_shardings
        self.ckpt = Checkpointer(tc.ckpt_dir, keep=tc.keep)
        self.train_step = jax.jit(make_train_step(model, opt_cfg),
                                  donate_argnums=(0, 1))
        self.metrics_log: list = []
        self.straggler_steps: list = []

    # ------------------------------------------------------------------
    def init_state(self, seed: int = 0):
        params = self.model.init(seed)
        if self.param_shardings is not None:
            params = jax.tree.map(jax.device_put, params,
                                  self.param_shardings)
        opt_state = init_opt(self.opt_cfg, params)
        return params, opt_state, 0

    def try_restore(self):
        step = self.ckpt.latest_step()
        if step is None:
            return None
        params0 = self.model.init(0)   # structure donor
        opt0 = init_opt(self.opt_cfg, params0)
        tree, extras = self.ckpt.restore(
            step, target={"params": params0, "opt": opt0})
        self.pipeline.restore(extras["pipeline"])
        return tree["params"], tree["opt"], int(extras["step"])

    # ------------------------------------------------------------------
    def run(self, seed: int = 0) -> Dict[str, Any]:
        restored = self.try_restore()
        if restored is not None:
            params, opt_state, start = restored
        else:
            params, opt_state, start = self.init_state(seed)
            self.pipeline.restore({"step": start})

        ewma: Optional[float] = None
        executed = 0
        step = start
        for step in range(start, self.tc.total_steps):
            if self.failure_hook is not None:
                self.failure_hook(step)
            batch = next(self.pipeline)
            t0 = time.perf_counter()
            params, opt_state, metrics = self.train_step(
                params, opt_state, batch, jax.numpy.int32(step))
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            executed += 1
            if executed == 1:
                pass          # first step pays compile; never seeds the ewma
            elif ewma is None:
                ewma = dt
            else:
                if dt > self.tc.straggler_factor * ewma:
                    self.straggler_steps.append((step, dt, ewma))
                ewma = 0.9 * ewma + 0.1 * dt
            if step % self.tc.log_every == 0 or step == self.tc.total_steps - 1:
                self.metrics_log.append(dict(step=step, time=dt, **metrics))
            if (step + 1) % self.tc.ckpt_every == 0:
                self.ckpt.save(step + 1,
                               {"params": params, "opt": opt_state},
                               extras={"step": step + 1,
                                       "pipeline": self.pipeline.state()})
        self.ckpt.save(self.tc.total_steps,
                       {"params": params, "opt": opt_state},
                       extras={"step": self.tc.total_steps,
                               "pipeline": self.pipeline.state()},
                       blocking=True)
        return {"params": params, "opt": opt_state,
                "metrics": self.metrics_log,
                "stragglers": self.straggler_steps}
