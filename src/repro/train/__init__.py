from .train_step import (batch_logical_axes, loss_fn, make_batch_shapes,
                         make_prefill_step, make_serve_step, make_train_step)
from .trainer import SimulatedFailure, Trainer, TrainerConfig
