"""Train step: loss, gradient accumulation (microbatching), optimizer apply.

Microbatching reshapes the global batch [B, ...] into [n_micro, B/n_micro,
...] and accumulates grads with a ``lax.scan`` — the standard memory/compute
trade for big models (jamba-398B trains with n_micro >= 8).  Compute/comm
overlap comes for free: XLA overlaps the per-microbatch reduce-scatter of
grads with the next microbatch's compute when grads are sharded (ZeRO).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.model import Model
from ..optim.optimizer import OptConfig, apply_opt

PyTree = Any

MOE_AUX_COEF = 0.01


def make_batch_shapes(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    """Abstract input batch for this architecture (ShapeDtypeStructs)."""
    f32 = jnp.float32
    i32 = jnp.int32
    if cfg.family == "encoder":
        return {
            "input_embeds": jax.ShapeDtypeStruct((batch, seq, cfg.d_model), f32),
            "labels": jax.ShapeDtypeStruct((batch, seq), i32),
            "mask": jax.ShapeDtypeStruct((batch, seq), f32),
        }
    out = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), i32),
        "labels": jax.ShapeDtypeStruct((batch, seq), i32),
    }
    if cfg.family == "vlm":
        out["patch_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_patches, cfg.d_model), f32)
    return out


def batch_logical_axes(cfg: ModelConfig) -> Dict[str, Tuple]:
    if cfg.family == "encoder":
        return {"input_embeds": ("batch", "seq", None),
                "labels": ("batch", "seq"), "mask": ("batch", "seq")}
    out = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
    if cfg.family == "vlm":
        out["patch_embeds"] = ("batch", None, None)
    return out


def loss_fn(model: Model, params: PyTree, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    cfg = model.cfg
    if cfg.family == "encoder":
        loss, aux = model.loss(params, None, batch["labels"],
                               mask=batch.get("mask"),
                               input_embeds=batch["input_embeds"])
    else:
        loss, aux = model.loss(params, batch["tokens"], batch["labels"],
                               patch_embeds=batch.get("patch_embeds"))
    total = loss + MOE_AUX_COEF * aux
    return total, {"loss": loss, "aux": aux}


def _split_micro(batch: Dict[str, jax.Array], n: int) -> Dict[str, jax.Array]:
    def f(x):
        B = x.shape[0]
        assert B % n == 0, (B, n)
        return x.reshape((n, B // n) + x.shape[1:])
    return {k: f(v) for k, v in batch.items()}


def make_train_step(model: Model, opt_cfg: OptConfig):
    """Returns train_step(params, opt_state, batch, step) -> (params,
    opt_state, metrics)."""
    rc = model.rc

    def grads_of(params, batch):
        (total, metrics), grads = jax.value_and_grad(
            functools.partial(loss_fn, model), has_aux=True)(params, batch)
        return grads, metrics

    def train_step(params, opt_state, batch, step):
        n = rc.microbatches
        gdt = jnp.dtype(rc.grad_dtype)
        if n > 1:
            micro = _split_micro(batch, n)

            def acc(carry, mb):
                g_acc, m_acc = carry
                g, m = grads_of(params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(gdt),
                                     g_acc, g)
                m_acc = jax.tree.map(jnp.add, m_acc, m)
                return (g_acc, m_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, gdt), params)
            m0 = {"loss": jnp.float32(0), "aux": jnp.float32(0)}
            (grads, metrics), _ = jax.lax.scan(acc, (g0, m0), micro)
            grads = jax.tree.map(lambda g: g / n, grads)
            metrics = jax.tree.map(lambda m: m / n, metrics)
        else:
            grads, metrics = grads_of(params, batch)

        new_params, new_opt, gnorm = apply_opt(opt_cfg, grads, opt_state,
                                               params, step)
        metrics = dict(metrics, grad_norm=gnorm)
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        cfg = model.cfg
        if cfg.family == "encoder":
            logits, _ = model.forward(params, None,
                                      input_embeds=batch["input_embeds"])
            return logits
        logits, state = model.prefill(params, batch["tokens"],
                                      patch_embeds=batch.get("patch_embeds"))
        return logits, state
    return prefill_step


def make_serve_step(model: Model):
    """One decode step against a dense KV/SSM cache (dry-run `serve_step`)."""
    def serve_step(params, state, tokens, kv_len):
        return model.decode_step(params, state, tokens, kv_len)
    return serve_step
