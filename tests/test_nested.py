"""Nested guest→host translation worlds: unit + regression tests.

Three layers of confidence on top of the differential fuzzer:

* :class:`~repro.core.page_table.NestedMapping` semantics pinned by hand:
  the union segment grid (VM schedule ∪ host epochs ∪ every guest's
  epochs), composed-view correctness, and host-event dirty sets hitting
  guests that never ran an OS event of their own.
* A seed-corpus cache regression: :func:`repro.core.sweep.cell_key` must
  fold BOTH translation levels' epoch PPNs — two nested worlds differing
  only in a host-side remap (which guests never observe directly) map the
  same guest tables and traces, so a key reading only the guest level
  would silently serve one world's cached results for the other.
* A hand-checkable parity + coherence-cost check: oracle == step-ref ==
  XLA on a nested world under both ``coh_policy`` values, with
  ``hw-coherence`` dropping the identical entry set for strictly fewer
  stall cycles than ``shootdown``.
"""
import dataclasses

import numpy as np

from repro.core import demand_mapping
from repro.core.baselines import base_spec, kaligned_spec
from repro.core.page_table import (UNMAPPED, MappingEvent,
                                   build_dynamic_mapping,
                                   build_nested_mapping)
from repro.core.simulator import run_method_nested
from repro.core.sweep import SweepCell, cell_key, run_sweep

N = 512


def _world(host_dest_off=5, guest_dest=None, n=N):
    """A 2-guest nested world: g0 runs a guest remap at t=120, the host
    runs a remap at t=200 (g1 never runs any event of its own)."""
    g0_base = demand_mapping(n, seed=11)
    g1 = demand_mapping(n, seed=13, thp=True)
    fresh = guest_dest if guest_dest is not None else \
        int(g0_base.ppn.max()) + 2
    g0 = build_dynamic_mapping(
        g0_base.ppn, [(120, [MappingEvent("remap", 40, 30, ppn=fresh)])],
        name="g0")
    hmax = max(int(np.max(np.asarray(m.ppn))) for m in
               (g0.epochs[0], g0.epochs[1], g1)) + 40
    h0 = np.arange(hmax, dtype=np.int64)
    # the remap window straddles both guests' frame ranges (g0 low, g1
    # from 512 up) so one host event dirties composed views of both
    host = build_dynamic_mapping(
        h0, [(200, [MappingEvent("remap", 480, 96,
                                 ppn=hmax + host_dest_off)])],
        name="host")
    return build_nested_mapping(
        [g0, g1], host, [(0, 0, 0), (90, 1, 1), (180, 0, 0), (260, 1, 1)],
        name="nw")


def _trace(world, total=330, seed=5):
    rng = np.random.default_rng(seed)
    segs = world.plan_segments()
    bounds = [s.lo for s in segs] + [total]
    parts = []
    for s, seg in enumerate(segs):
        mv = np.flatnonzero(np.asarray(seg.mapping.ppn) >= 0)
        parts.append(mv[rng.integers(0, mv.size, bounds[s + 1] - bounds[s])])
    return np.concatenate(parts).astype(np.int64)


def test_union_segment_grid():
    """Segment boundaries are the union of the VM schedule (0/90/180/260),
    g0's guest epoch (120) and the host epoch (200) — including epochs of
    worlds not scheduled at that instant."""
    world = _world()
    segs = world.plan_segments()
    assert [s.lo for s in segs] == [0, 90, 120, 180, 200, 260]
    # t=120: g0's OWN epoch turns over while g0 is scheduled — no switch
    assert [s.guest_id for s in segs] == [0, 1, 1, 0, 0, 1]
    assert [s.switch for s in segs] == [False, True, False, True, False,
                                        True]
    # g0's remap at 120 lands while g1 is scheduled — the dirty set is
    # ASID-blind (g0's entries may still be cached under its ASID), so the
    # boundary carries g0's composed diff even though g1's view is clean
    d120 = segs[2].dirty
    assert d120 is not None
    before = np.asarray(world.composed(0, 0, 0).ppn)
    after = np.asarray(world.composed(0, 1, 0).ppn)
    np.testing.assert_array_equal(
        d120, (before != UNMAPPED) & (before != after))


def test_composed_view_is_host_of_guest():
    world = _world()
    g1 = world.guests[1].epochs[0].ppn
    for he, host_m in enumerate(world.host.epochs):
        c = np.asarray(world.composed(1, 0, he).ppn)
        h = np.asarray(host_m.ppn)
        g = np.asarray(g1)
        ok = (g != UNMAPPED) & (g < h.shape[0])
        np.testing.assert_array_equal(c[ok], h[g[ok]])
        assert (c[~ok] == UNMAPPED).all()


def test_host_event_dirties_untouched_guest():
    """The host remap at t=200 dirties composed translations of BOTH
    guests — including g1, which never ran a guest event."""
    world = _world()
    seg = next(s for s in world.plan_segments() if s.lo == 200)
    assert seg.dirty is not None and seg.dirty.any()
    # the dirty set is exactly the vpns whose composed translation moved,
    # for ANY guest, comparing the views live just before vs just after
    expect = np.zeros(world.n_pages, bool)
    for gid, ge in ((0, 1), (1, 0)):     # guest epochs live at t=200
        before = np.asarray(world.composed(gid, ge, 0).ppn)
        after = np.asarray(world.composed(gid, ge, 1).ppn)
        d = (before != UNMAPPED) & (before != after)
        expect[: d.shape[0]] |= d        # guest footprints differ in size
    np.testing.assert_array_equal(seg.dirty, expect)
    # g1 alone has moved translations: host coherence reaches guests that
    # never touched their own page tables
    b1 = np.asarray(world.composed(1, 0, 0).ppn)
    a1 = np.asarray(world.composed(1, 0, 1).ppn)
    assert ((b1 != UNMAPPED) & (b1 != a1)).any()


def test_cell_key_folds_both_translation_levels():
    """Seed corpus: the sweep cache key must distinguish nested worlds
    that differ ONLY in a host-side event (same guest tables, same trace)
    — and equally ones differing only in a guest-side event — or cached
    cells alias across host layouts."""
    spec = base_spec()
    base = _world()
    trace = _trace(base)
    k_base = cell_key(SweepCell(spec, base, trace))
    # same guests, same trace, different host remap destination
    k_host = cell_key(SweepCell(spec, _world(host_dest_off=200), trace))
    assert k_host != k_base
    # different guest remap destination
    k_guest = cell_key(SweepCell(spec, _world(guest_dest=2000), trace))
    assert k_guest != k_base and k_guest != k_host
    # deterministic rebuild of the identical world hits the same key
    assert cell_key(SweepCell(spec, _world(), trace)) == k_base


def test_nested_parity_and_coherence_cost():
    """oracle == XLA sweep on a nested world under both coh_policy values;
    both policies invalidate the identical entry set (walks/hits/
    shootdowns bit-equal) and hw-coherence pays strictly fewer cycles."""
    world = _world()
    trace = _trace(world)
    res = {}
    for coh in ("shootdown", "hw-coherence"):
        spec = dataclasses.replace(kaligned_spec([9, 6, 4]), coh_policy=coh)
        want = run_method_nested(spec, world, trace)
        got = run_sweep([SweepCell(spec, world, trace)], cache=False,
                        backend="xla", block_size=6).results[0]
        for f in ("accesses", "l1_hits", "l2_regular_hits",
                  "l2_coalesced_hits", "walks", "aligned_probes",
                  "pred_correct", "cycles", "coverage_mean", "shootdowns"):
            assert getattr(got, f) == getattr(want, f), (coh, f)
        np.testing.assert_array_equal(got.ppn, want.ppn)
        res[coh] = want
    sd, hw = res["shootdown"], res["hw-coherence"]
    assert hw.walks == sd.walks and hw.shootdowns == sd.shootdowns
    np.testing.assert_array_equal(hw.ppn, sd.ppn)
    assert hw.shootdowns > 0          # the world actually invalidates
    assert hw.cycles < sd.cycles      # ... and hw-coherence is cheaper
