"""Chaos-harness tests: every injected fault is either recovered exactly
or fails loudly — never a silent divergence.

Four layers:

* **tlb-parity worlds** — the paper-grounded soft-error fault: all four
  executors (pure-python oracle, step-at-a-time ref, time-blocked XLA,
  Pallas) stay bit-exact on :class:`ParityWorld` cells, ``par_policy="ecc"``
  is bit-identical to the fault-free run by construction, and
  detect-invalidate-rewalk recovery shows the coalescing blast radius.
* **sweep runtime** — injected backend failures recover via the
  pallas→xla fallback and batch bisection down to the oracle; corrupt
  cache entries are quarantined (surfaced in stats) and recomputed.
* **serving engine** — snapshot/restore is token-exact mid-serve;
  corrupted KV pages quarantine-and-recompute through the preemption
  path; the stalled metric and oversized-request rejection close the
  silent-loss holes.
* **allocator** — buddy snapshot/restore round-trips and bad-page
  retirement keeps the free pool consistent.
"""
import dataclasses
import os

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import demand_mapping, generate_trace
from repro.core.baselines import (base_spec, cluster_spec, colt_spec,
                                  kaligned_spec)
from repro.core.mappings import BuddyAllocator
from repro.core.page_table import (MappingEvent, ParityWorld,
                                   build_dynamic_mapping)
from repro.core.simulator import run_method_dynamic, run_method_parity
from repro.core.sweep import SweepCell, cell_key, run_sweep
from repro.robustness import (BackendFault, EngineCrash, FaultPlan,
                              KVCorruption, PageLoss, RecoveryError,
                              backend_fault_injection, corrupt_cache_entry,
                              make_parity_world, retry_with_backoff,
                              run_engine_with_recovery)

COUNTERS = ("accesses", "l1_hits", "l2_regular_hits", "l2_coalesced_hits",
            "walks", "aligned_probes", "pred_correct", "cycles",
            "coverage_mean", "shootdowns")

SPECS = [base_spec(), colt_spec(), cluster_spec(), kaligned_spec([6, 4, 2])]


def _assert_equal(got, want, ctx):
    for f in COUNTERS:
        assert getattr(got, f) == getattr(want, f), (ctx, f)
    np.testing.assert_array_equal(got.ppn, want.ppn, err_msg=str(ctx))


# ---------------------------------------------------------------------------
# ParityWorld: the tlb-parity fault model
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def parity_worlds():
    """ParityWorld over a static base and over a dynamic (remapping) base,
    fault vpns drawn from the trace so they are always live."""
    m = demand_mapping(1 << 10, seed=11)
    tr = generate_trace("multiscale", 0, 400, seed=4, mapping=m)
    pw_static = make_parity_world(m, tr, seed=5, n_faults=3)

    n = 1 << 10
    ppn0 = np.arange(n, dtype=np.int64) + 7
    ev1 = [MappingEvent("remap", 0, 128, ppn=100_000)]
    ev2 = [MappingEvent("unmap", 768, 32)]
    dyn = build_dynamic_mapping(ppn0, [(150, ev1), (300, ev2)], name="hot")
    rng = np.random.default_rng(3)
    dtr = rng.integers(0, 512, size=420).astype(np.int64)
    pw_dyn = make_parity_world(dyn, dtr, seed=6, n_faults=2)
    return (pw_static, tr), (pw_dyn, dtr)


def test_parity_world_validation():
    m = demand_mapping(1 << 9, seed=2)
    with pytest.raises(AssertionError):
        ParityWorld(base=m, faults=((5, 1), (5, 2)))      # duplicate step
    with pytest.raises(AssertionError):
        ParityWorld(base=m, faults=((0, 1),))             # step 0
    with pytest.raises(AssertionError):                    # no nesting
        ParityWorld(base=ParityWorld(base=m, faults=()), faults=())
    n = 1 << 9
    dyn = build_dynamic_mapping(
        np.arange(n, dtype=np.int64),
        [(100, [MappingEvent("remap", 0, 16, ppn=10_000)])])
    with pytest.raises(AssertionError):                    # boundary clash
        ParityWorld(base=dyn, faults=((100, 3),))


def test_parity_executor_matrix(parity_worlds):
    """Oracle == XLA (TB 1 and 8) == Pallas on every (spec, par_policy,
    world) parity cell — the four-executor bit-exactness the acceptance
    criteria demand, plus the ref leg below."""
    cells, wants = [], []
    for (pw, tr) in parity_worlds:
        for s in SPECS:
            for par in ("parity", "ecc"):
                sp = dataclasses.replace(s, par_policy=par)
                cells.append(SweepCell(sp, pw, tr))
                wants.append(run_method_parity(sp, pw, tr))
    for backend, tb in (("xla", 1), ("xla", 8), ("pallas", 4)):
        res = run_sweep(cells, cache=False, backend=backend, block_size=tb)
        for c, got, want in zip(cells, res, wants):
            _assert_equal(got, want,
                          (backend, tb, c.spec.name, c.spec.par_policy))


def test_parity_ref_backend(parity_worlds):
    from repro.core.lane_program import (C_COV, init_batched_state,
                                         pack_lanes)
    from repro.kernels.tlb_sweep.ref import run_lanes_ref
    (pw, tr), _ = parity_worlds
    cells = [SweepCell(s, pw, tr) for s in SPECS]
    wants = [run_method_parity(s, pw, tr) for s in SPECS]
    lanes, stacks, (L, sets, ways), seg_bounds = pack_lanes(cells)
    st0 = init_batched_state(
        L, sets, ways, lanes["pred0"], lanes["asid0"],
        with_ctlb=bool(np.asarray(lanes["has_ctlb"]).any()),
        with_dp=bool(np.asarray(lanes["use_dead"]).any()))
    stF, ppns = run_lanes_ref(lanes, stacks, st0, seg_bounds)
    counters = np.asarray(stF["counters"])
    cov = np.asarray(stF["cov_samples"])
    from repro.core.lane_program import (C_COAL, C_CYC, C_L1, C_PRED,
                                         C_PROBE, C_REG, C_SHOOT, C_WALK)
    fields = {C_L1: "l1_hits", C_REG: "l2_regular_hits",
              C_COAL: "l2_coalesced_hits", C_WALK: "walks",
              C_PROBE: "aligned_probes", C_PRED: "pred_correct",
              C_CYC: "cycles", C_SHOOT: "shootdowns"}
    assert C_COV not in fields
    for i, (spec, want) in enumerate(zip(SPECS, wants)):
        for c, f in fields.items():
            assert counters[i, c] == getattr(want, f), (spec.name, f)
        assert float(np.mean(cov[i])) == want.coverage_mean, spec.name
        np.testing.assert_array_equal(
            np.asarray(ppns)[i, : tr.shape[0]], want.ppn, err_msg=spec.name)


def test_ecc_is_fault_free(parity_worlds):
    """par_policy='ecc' corrects the flip in place: bit-identical to
    running the base world without the fault schedule."""
    for (pw, tr) in parity_worlds:
        for s in SPECS:
            ecc = run_method_parity(
                dataclasses.replace(s, par_policy="ecc"), pw, tr)
            free = run_method_dynamic(s, pw.base, tr)
            _assert_equal(ecc, free, ("ecc-vs-fault-free", s.name))


def test_parity_blast_radius(parity_worlds):
    """Detect-invalidate-rewalk recovery costs real invalidations: the
    parity run loses entries (and never fewer walks) vs ECC, and the
    cells keep completing — recovery, not corruption."""
    (pw, tr), _ = parity_worlds
    for s in SPECS:
        flip = run_method_parity(s, pw, tr)
        ecc = run_method_parity(
            dataclasses.replace(s, par_policy="ecc"), pw, tr)
        assert flip.shootdowns > ecc.shootdowns, s.name
        assert flip.walks >= ecc.walks, s.name
        assert flip.accesses == ecc.accesses == tr.shape[0]


def test_parity_fault_schedule_in_cache_key(parity_worlds):
    (pw, tr), _ = parity_worlds
    s = SPECS[0]
    k1 = cell_key(SweepCell(s, pw, tr))
    other = ParityWorld(base=pw.base, faults=pw.faults[:-1])
    k2 = cell_key(SweepCell(s, other, tr))
    k3 = cell_key(SweepCell(s, pw.base, tr))
    assert len({k1, k2, k3}) == 3


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_fuzz_parity_recovers_or_fails_loudly(seed):
    """The chaos property on the simulator: any (fault plan, world) either
    recovers exactly (ecc == fault-free; batched == oracle) or raises —
    the executors never silently diverge."""
    rng = np.random.default_rng(seed)
    m = demand_mapping(1 << 9, seed=seed % 97)
    tr = generate_trace("multiscale", 0, 256, seed=seed % 89, mapping=m)
    pw = make_parity_world(m, tr, seed=seed, n_faults=int(rng.integers(1, 4)))
    spec = SPECS[seed % len(SPECS)]
    tb = int(rng.choice([1, 4, 8]))
    want = run_method_parity(spec, pw, tr)
    got = run_sweep([SweepCell(spec, pw, tr)], cache=False, backend="xla",
                    block_size=tb)[0]
    _assert_equal(got, want, ("fuzz", seed, spec.name, tb))
    ecc = run_method_parity(
        dataclasses.replace(spec, par_policy="ecc"), pw, tr)
    _assert_equal(ecc, run_method_dynamic(spec, m, tr),
                  ("fuzz-ecc", seed, spec.name))


# ---------------------------------------------------------------------------
# Sweep runtime: backend fallback, bisection, cache quarantine
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sweep_cells():
    m = demand_mapping(1 << 9, seed=7)
    tr = generate_trace("multiscale", 0, 300, seed=9, mapping=m)
    cells = [SweepCell(s, m, tr) for s in SPECS]
    clean = run_sweep(cells, cache=False, backend="xla")
    return cells, clean


def test_backend_fallback_pallas_to_xla(sweep_cells):
    cells, clean = sweep_cells
    with backend_fault_injection(n_failures=1, backends=("pallas",)) as st_:
        res = run_sweep(cells, cache=False, backend="pallas")
    assert st_["injected"] == 1
    assert res.stats["backend_fallbacks"] == 1
    assert res.stats["oracle_fallbacks"] == 0
    for got, want in zip(res, clean):
        _assert_equal(got, want, "pallas-fallback")


def test_bisection_isolates_cursed_cell_to_oracle(sweep_cells):
    cells, clean = sweep_cells
    cursed = cells[2]
    with backend_fault_injection(
            n_failures=10_000, backends=("pallas", "xla"),
            predicate=lambda sub, bk: any(c is cursed for c in sub)):
        res = run_sweep(cells, cache=False, backend="xla")
    assert res.stats["bisections"] >= 1
    assert res.stats["oracle_fallbacks"] == 1
    for got, want in zip(res, clean):
        _assert_equal(got, want, "bisect-oracle")


def test_injected_fault_is_loud_without_recovery_path(sweep_cells):
    """The hook itself raises when recovery is exhausted-by-construction:
    a single-cell batch failing every backend lands on the oracle, so the
    ONLY loud path left is the oracle raising — simulate it by cursing the
    oracle dispatch with an invalid spec instead."""
    cells, _ = sweep_cells
    with backend_fault_injection(n_failures=1, backends=("pallas",)) as st_:
        with pytest.raises(BackendFault):
            from repro.core.sweep import _run_batch
            _run_batch(list(cells), "pallas", 8)
    assert st_["injected"] == 1


def test_cache_corruption_quarantined_and_recomputed(tmp_path, sweep_cells):
    """Satellite: truncated, garbage, and wrong-schema .npz entries each
    recompute correctly and increment the quarantine counter."""
    cells, clean = sweep_cells
    cdir = str(tmp_path / "sweep_cache")
    first = run_sweep(cells, cache=True, cache_dir=cdir, backend="xla")
    assert first.stats["simulated"] == len(cells)
    assert first.stats["cache_quarantined"] == 0
    entries = sorted(p for p in os.listdir(cdir) if p.endswith(".npz"))
    assert len(entries) == len(cells)
    for mode, entry in zip(("truncate", "garbage", "schema"), entries):
        corrupt_cache_entry(os.path.join(cdir, entry), mode)
    again = run_sweep(cells, cache=True, cache_dir=cdir, backend="xla")
    assert again.stats["cache_quarantined"] == 3
    assert again.stats["cache_hits"] == len(cells) - 3
    assert again.stats["simulated"] == 3
    # quarantined originals are kept inspectable, not deleted
    assert sum(p.endswith(".quarantined") for p in os.listdir(cdir)) == 3
    for got, want in zip(again, clean):
        _assert_equal(got, want, "cache-quarantine")
    third = run_sweep(cells, cache=True, cache_dir=cdir, backend="xla")
    assert third.stats["cache_hits"] == len(cells)
    assert third.stats["cache_quarantined"] == 0


def test_retry_with_backoff():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("transient")
        return 42

    slept = []
    assert retry_with_backoff(flaky, retries=3, base_delay=0.5,
                              retry_on=(OSError,),
                              sleep=slept.append) == 42
    assert len(calls) == 3 and slept == [0.5, 1.0]
    with pytest.raises(ValueError):
        retry_with_backoff(lambda: (_ for _ in ()).throw(ValueError()),
                           retries=1, retry_on=(ValueError,))


# ---------------------------------------------------------------------------
# Allocator robustness primitives
# ---------------------------------------------------------------------------

def test_buddy_snapshot_restore_roundtrip():
    b = BuddyAllocator(64, max_order=4)
    a0 = b.alloc(2)
    b.alloc(0)
    snap = b.snapshot()
    b2 = BuddyAllocator(64, max_order=4)
    b2.restore(snap)
    assert b2.snapshot() == snap
    b.free_block(a0, 2)
    assert b.snapshot() != snap


def test_buddy_retire():
    b = BuddyAllocator(32, max_order=5)
    assert b.retire(7)                       # free frame: retired
    free, _ = b.frag_stats()
    assert free == 31
    assert not b.retire(7)                   # already gone
    # the remaining 31 frames are all still allocatable
    got = sum(1 << 0 for _ in range(31) if b.alloc(0) is not None)
    assert got == 31 and b.alloc(0) is None


def test_kv_allocator_snapshot_owners_retire():
    from repro.kvcache.allocator import PagedKVAllocator
    al = PagedKVAllocator(64, alloc_policy="buddy_best")
    al.allocate(1, 5)
    al.allocate(2, 3)
    snap = al.snapshot_state()
    page = al.seqs[1].pages[0]
    assert al.owners_of([page]) == [1]
    assert al.retire_pages([page]) == []     # owned: not retirable
    al.free(1)
    assert al.retire_pages([page]) == [page]
    al2 = PagedKVAllocator(64, alloc_policy="buddy_best")
    al2.restore_state(snap)
    assert al2.seqs[1].pages == snap["seqs"]["1"]["pages"]
    assert al2.buddy.snapshot() == snap["free"]


def test_fault_plan_deterministic():
    a = FaultPlan.generate(3, kinds=("engine-crash", "kv-corruption",
                                     "page-loss"), max_step=6)
    b = FaultPlan.generate(3, kinds=("engine-crash", "kv-corruption",
                                     "page-loss"), max_step=6)
    assert a == b
    assert set(a.kinds()) <= {"engine-crash", "kv-corruption", "page-loss"}
    assert all(e.step >= 1 for e in a.events)


# ---------------------------------------------------------------------------
# Serving engine: crash-restart, quarantine, admission hardening
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_model():
    from repro.configs import get_config
    from repro.models import Model, RunConfig
    cfg = get_config("internlm2-1.8b", reduced=True)
    rc = RunConfig(attn_q_chunk=32, attn_kv_chunk=32, scan_chunk=16)
    model = Model(cfg, rc)
    params = model.init(0)
    return cfg, model, params


def _engine(tiny_model, **kw):
    from repro.serve import EngineConfig, ServingEngine
    cfg, model, params = tiny_model
    ec = EngineConfig(**{**dict(page_size=8, num_pages=256, max_batch=3,
                                max_seq=64, interpret=True), **kw})
    return ServingEngine(model, params, ec)


def _requests(cfg, n=4, max_new=5):
    rng = np.random.default_rng(2024)
    return [(list(rng.integers(0, cfg.vocab, size=12)), max_new)
            for _ in range(n)]


@pytest.fixture(scope="module")
def served_baseline(tiny_model, tmp_path_factory):
    cfg, _, _ = tiny_model
    reqs = _requests(cfg)
    ck = str(tmp_path_factory.mktemp("ck_base"))
    out, rep = run_engine_with_recovery(
        lambda: _engine(tiny_model), reqs, None, ck, max_steps=64)
    assert rep["steps"] >= 4 and rep["crashes"] == 0
    return reqs, out


def test_add_request_rejects_oversize(tiny_model):
    """Satellite: a request that can never fit (prompt + max_new_tokens
    beyond max_seq, or more pages than the pool) is rejected at the door
    instead of live-locking admission."""
    eng = _engine(tiny_model)
    with pytest.raises(ValueError, match="max_seq"):
        eng.add_request(list(range(60)), max_new_tokens=16)
    eng = _engine(tiny_model, num_pages=4)
    with pytest.raises(ValueError, match="pool"):
        eng.add_request(list(range(30)), max_new_tokens=20)
    assert not eng.waiting and not eng.requests


def test_stalled_metric_surfaces_exhaustion(tiny_model):
    """Satellite: run_to_completion with an exhausted step budget reports
    the stranded requests instead of silently truncating."""
    cfg, _, _ = tiny_model
    eng = _engine(tiny_model)
    for prompt, max_new in _requests(cfg, n=2):
        eng.add_request(prompt, max_new_tokens=max_new)
    m = eng.run_to_completion(max_steps=1)
    assert m["stalled"] == 2
    m = eng.run_to_completion()
    assert m["stalled"] == 0
    assert all(r.state == "done" for r in eng.requests.values())


def test_snapshot_restore_token_exact(tiny_model, served_baseline, tmp_path):
    """Crash-restart mid-serve: a FRESH engine restoring the checkpoint
    finishes with output token-identical to the uninterrupted run."""
    reqs, want = served_baseline
    eng = _engine(tiny_model)
    for prompt, max_new in reqs:
        eng.add_request(prompt, max_new_tokens=max_new)
    eng.step()
    eng.step()
    ck = str(tmp_path / "ck")
    eng.snapshot(ck)
    del eng                                   # the process dies here
    eng2 = _engine(tiny_model)
    eng2.restore(ck)
    m = eng2.run_to_completion()
    assert m["stalled"] == 0
    got = {rid: list(r.generated) for rid, r in eng2.requests.items()}
    assert got == want


def test_kv_quarantine_recompute_token_exact(tiny_model, served_baseline,
                                             tmp_path):
    """Corrupted KV pages: garbage the pool, quarantine-and-recompute, and
    the final output still matches the fault-free run (the recompute path
    keeps every generated token)."""
    reqs, want = served_baseline
    plan = FaultPlan(1908, (KVCorruption(step=2, n_pages=2),))
    out, rep = run_engine_with_recovery(
        lambda: _engine(tiny_model), reqs, plan, str(tmp_path),
        max_steps=64, snapshot_every=2)
    assert rep["kv_corrupted"] >= 1 and rep["preempted"] >= 1
    assert rep["metrics"]["kv_quarantined_pages"] >= 1
    assert out == want


def test_page_loss_transparent(tiny_model, served_baseline, tmp_path):
    reqs, want = served_baseline
    plan = FaultPlan(1908, (PageLoss(step=1, n_pages=3),))
    out, rep = run_engine_with_recovery(
        lambda: _engine(tiny_model), reqs, plan, str(tmp_path),
        max_steps=64, snapshot_every=2)
    assert rep["pages_lost"] >= 1
    assert out == want


@settings(max_examples=2, deadline=None)
@given(crash_step=st.integers(1, 5), every=st.integers(1, 3))
def test_fuzz_crash_restart_token_exact(tiny_model, served_baseline,
                                        tmp_path_factory, crash_step, every):
    """The crash-restart property: for ANY crash step and snapshot cadence
    the restarted engine replays to token-identical output (decode is
    deterministic, so checkpoint-resume is exact by construction)."""
    reqs, want = served_baseline
    plan = FaultPlan(7, (EngineCrash(step=crash_step),))
    ck = str(tmp_path_factory.mktemp("ck_fuzz"))
    out, rep = run_engine_with_recovery(
        lambda: _engine(tiny_model), reqs, plan, ck,
        max_steps=64, snapshot_every=every)
    assert out == want
    assert rep["crashes"] in (0, 1)           # may finish before the crash


def test_stall_fails_loudly(tiny_model, tmp_path):
    """A run that cannot finish raises RecoveryError instead of returning
    partial output."""
    cfg, _, _ = tiny_model
    reqs = _requests(cfg, n=2)
    with pytest.raises(RecoveryError, match="stalled"):
        run_engine_with_recovery(lambda: _engine(tiny_model), reqs, None,
                                 str(tmp_path), max_steps=1)
