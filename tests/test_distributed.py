"""Distribution substrate tests — run in subprocesses with 8 fake devices
(the main pytest process keeps the default 1 device for smoke tests)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_sharding_rules_resolve_and_divide():
    print(run_with_devices("""
        import jax, numpy as np
        from jax.sharding import Mesh
        from repro.distributed.sharding import logical_to_pspec, PARAM_RULES
        mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
        rules = PARAM_RULES["default"]
        # divisible: shard both dims
        ps = logical_to_pspec(("embed", "mlp"), mesh, rules, (8, 16))
        assert ps == jax.sharding.PartitionSpec(("data",), "model"), ps
        # non-divisible dim falls back to replication, not an error
        ps = logical_to_pspec(("embed", "mlp"), mesh, rules, (8, 6))
        assert ps[1] is None, ps
        # same mesh axis never used twice
        ps = logical_to_pspec(("q_heads", "q_heads"), mesh, rules, (8, 8))
        assert ps[1] is None, ps
        print("RULES-OK")
    """))


SPMD_LOSS_TMPL = """
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import Model, RunConfig
        from repro.optim import OptConfig, init_opt
        from repro.train import make_train_step
        from repro.distributed.sharding import param_sharding
        from repro.models.common import logical_tree, spec_shapes
        from repro.models.model import model_specs
        from repro.data.pipeline import _batch_at, PipelineConfig

        cfg = get_config("internlm2-1.8b", reduced=True)
        rc = RunConfig(attn_q_chunk=32, attn_kv_chunk=32, scan_chunk=16)
        oc = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        batch = {k: jnp.asarray(v) for k, v in _batch_at(
            cfg, PipelineConfig(batch=8, seq=32), 0).items()}

        losses = {}
        for meshspec in (None, %(meshes)s):
            model = Model(cfg, rc)
            params = model.init(0)
            opt = init_opt(oc, params)
            if meshspec is None:
                step = jax.jit(make_train_step(model, oc))
                _, _, m = step(params, opt, batch, jnp.int32(0))
            else:
                mesh = Mesh(np.array(jax.devices()).reshape(meshspec),
                            ("data", "model"))
                model = Model(cfg, rc, mesh=mesh)
                specs = model_specs(cfg, rc)
                shard = param_sharding(logical_tree(specs),
                                       spec_shapes(specs), mesh, "default")
                params = jax.tree.map(jax.device_put, params, shard)
                opt = init_opt(oc, params)
                bsh = NamedSharding(mesh, P("data"))
                b = {k: jax.device_put(v, bsh) for k, v in batch.items()}
                with mesh:
                    step = jax.jit(make_train_step(model, oc))
                    _, _, m = step(params, opt, b, jnp.int32(0))
            losses[str(meshspec)] = float(m["loss"])
        vals = list(losses.values())
        assert max(vals) - min(vals) < 2e-2, losses
        print("SPMD-LOSS-OK", losses)
    """


@pytest.mark.slow
def test_train_step_spmd_equals_single_device():
    """The sharded train step computes the same loss as 1-device execution,
    over every mesh factorization (full grid; CI `-m slow` lane)."""
    out = run_with_devices(SPMD_LOSS_TMPL % {
        "meshes": "(2, 4), (4, 2), (8, 1)"})
    assert "SPMD-LOSS-OK" in out


def test_train_step_spmd_small_mesh():
    """Default-tier coverage of the same property on one 2x4 mesh."""
    out = run_with_devices(SPMD_LOSS_TMPL % {"meshes": "(2, 4)"})
    assert "SPMD-LOSS-OK" in out


def test_checkpoint_reshard_across_meshes():
    """Save sharded on 2x4, restore onto 4x2 and onto 1 device (elastic)."""
    out = run_with_devices("""
        import tempfile, jax, numpy as np, jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.checkpoint import Checkpointer

        devs = np.array(jax.devices())
        mesh_a = Mesh(devs.reshape(2, 4), ("data", "model"))
        mesh_b = Mesh(devs.reshape(4, 2), ("data", "model"))
        x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        tree = {"w": jax.device_put(x, NamedSharding(mesh_a, P("data", "model")))}
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d)
            ck.save(1, tree, blocking=True)
            got_b, _ = ck.restore(target=tree, shardings={
                "w": NamedSharding(mesh_b, P("data", "model"))})
            np.testing.assert_array_equal(np.asarray(got_b["w"]), np.asarray(x))
            assert got_b["w"].sharding.mesh.shape["data"] == 4
            got_1, _ = ck.restore(target=tree, shardings={
                "w": jax.devices()[0]})
            np.testing.assert_array_equal(np.asarray(got_1["w"]), np.asarray(x))
        print("RESHARD-OK")
    """)
    assert "RESHARD-OK" in out


def test_grad_compression_on_mesh():
    out = run_with_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.distributed.grad_compress import (ef_allreduce,
                                                     init_residual_stacked)
        mesh = Mesh(np.array(jax.devices()).reshape(8,), ("data",))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal((8, 4, 33)), jnp.float32)
        gs = jax.device_put(g, NamedSharding(mesh, P("data")))
        resid = init_residual_stacked({"g": gs})
        out, new_r = ef_allreduce({"g": gs}, resid, mesh, "data")
        want = np.asarray(g).mean(axis=0)
        got = np.asarray(out["g"][0])
        err = np.abs(got - want).max()
        assert err < np.abs(np.asarray(g)).max() / 127 * 2 + 1e-5, err
        # all shards agree
        for i in range(8):
            np.testing.assert_allclose(np.asarray(out["g"][i]), got)
        print("EF-ALLREDUCE-OK", float(err))
    """)
    assert "EF-ALLREDUCE-OK" in out


def test_long_context_seq_sharded_decode():
    """decode with KV sequence sharded over data (long_500k rules) matches
    the replicated result."""
    out = run_with_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.models.layers import decode_attention
        mesh = Mesh(np.array(jax.devices()).reshape(8,), ("data",))
        rng = np.random.default_rng(0)
        B, S, H, KVH, D = 1, 64, 4, 2, 16
        q = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, S, KVH, D)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, S, KVH, D)), jnp.float32)
        lens = jnp.asarray([50], jnp.int32)
        ref = decode_attention(q, k, v, lens)
        ks = jax.device_put(k, NamedSharding(mesh, P(None, "data")))
        vs = jax.device_put(v, NamedSharding(mesh, P(None, "data")))
        with mesh:
            out = jax.jit(decode_attention, static_argnames=())(q, ks, vs, lens)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)
        print("SP-DECODE-OK")
    """)
    assert "SP-DECODE-OK" in out


def test_pipeline_parallel_matches_sequential():
    """GPipe over 4 stages == sequential layer application."""
    out = run_with_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.distributed.pipeline import pipeline_forward
        mesh = Mesh(np.array(jax.devices()[:4]).reshape(4,), ("pod",))
        rng = np.random.default_rng(0)
        n_stages, n_micro, Bm, d = 4, 8, 2, 16
        w = jnp.asarray(rng.standard_normal((n_stages, d, d)) * 0.3,
                        jnp.float32)
        x = jnp.asarray(rng.standard_normal((n_micro, Bm, d)), jnp.float32)

        def block(w_s, xb):
            return jnp.tanh(xb @ w_s)

        got = pipeline_forward(mesh, "pod", block, w, x)
        want = x
        for s in range(n_stages):
            want = jnp.tanh(want @ w[s])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)
        print("PIPELINE-OK")
    """, n=4)
    assert "PIPELINE-OK" in out
