"""Contract-checker tests: the live tree is clean, and each pass catches
its seeded violation.

Two fixture styles:

* **clones** — the executor/test/docs files the passes read are copied
  into a tmp tree and then mutated (the mutation tests from the PR
  acceptance: removing a kind from one executor's dispatch must turn the
  kind-dispatch pass red);
* **minimal trees** — tiny hand-written ``simulator.py``-shaped files
  for the latency and purity passes, which skip absent files.

Every seeded violation asserts on the *specific* finding message, so a
pass can neither go blind nor start flagging the wrong thing.
"""
from __future__ import annotations

import ast
import json
import os
import shutil
import subprocess
import sys
from pathlib import Path

import pytest

from repro import analysis
from repro.analysis import (framework, pass_cache_key, pass_kind_dispatch,
                            pass_latency, pass_plane_layout, pass_purity)
from repro.analysis.framework import Repo

REPO_ROOT = Path(__file__).resolve().parents[1]

CLONE_FILES = (
    "src/repro/core/simulator.py",
    "src/repro/core/lane_program.py",
    "src/repro/core/sweep.py",
    "src/repro/core/plane_layout.py",
    "src/repro/core/baselines.py",
    "src/repro/kernels/tlb_sweep/tlb_sweep.py",
    "src/repro/kernels/tlb_sweep/ops.py",
    "src/repro/kernels/tlb_sweep/ref.py",
    "tests/test_backends.py",
    "tests/test_fuzz_differential.py",
    "docs/methods.md",
)


@pytest.fixture
def clone(tmp_path):
    """The real tree's analyzable subset, copied so tests can mutate it."""
    for rel in CLONE_FILES:
        dst = tmp_path / rel
        dst.parent.mkdir(parents=True, exist_ok=True)
        shutil.copyfile(REPO_ROOT / rel, dst)
    gdir = tmp_path / "tests" / "goldens"
    gdir.mkdir(parents=True)
    for g in sorted((REPO_ROOT / "tests" / "goldens").glob("*.json")):
        shutil.copyfile(g, gdir / g.name)
    return tmp_path


def edit(root: Path, rel: str, old: str, new: str):
    p = root / rel
    text = p.read_text()
    assert old in text, f"mutation anchor {old!r} not found in {rel}"
    p.write_text(text.replace(old, new))


def write(root: Path, rel: str, text: str):
    p = root / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(text)


def errors(findings):
    return [f for f in findings if f.severity == "error"]


def messages(findings):
    return [f.message for f in findings]


# ---------------------------------------------------------------------------
# the live tree
# ---------------------------------------------------------------------------

def test_live_tree_is_clean():
    active, _ = analysis.run_passes(Repo(str(REPO_ROOT)),
                                    analysis.ALL_PASSES)
    assert not framework.has_errors(active), \
        "\n".join(f.render() for f in errors(active))


def test_cli_exits_zero_and_writes_step_summary(tmp_path):
    summary = tmp_path / "summary.md"
    env = dict(os.environ, GITHUB_STEP_SUMMARY=str(summary))
    r = subprocess.run(
        [sys.executable, str(REPO_ROOT / "scripts" / "check_contracts.py")],
        capture_output=True, text=True, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 error(s)" in r.stdout
    assert "## Contract checker" in summary.read_text()


def test_registered_kinds_match_runtime_registry():
    simulator = pytest.importorskip("repro.core.simulator")
    assert tuple(analysis.registered_kinds(Repo(str(REPO_ROOT)))) == \
        simulator.KINDS


# ---------------------------------------------------------------------------
# kind-dispatch: the mutation checks
# ---------------------------------------------------------------------------

def _mutate_literal_in_function(root: Path, rel: str, fname: str,
                                kind: str):
    """Rewrite every ``"<kind>"`` literal inside function ``fname`` so the
    executor no longer dispatches that kind there."""
    p = root / rel
    src = p.read_text()
    fn = next(n for n in ast.walk(ast.parse(src))
              if isinstance(n, ast.FunctionDef) and n.name == fname)
    lines = src.splitlines(keepends=True)
    target, changed = f'"{kind}"', False
    for i in range(fn.lineno - 1, fn.end_lineno):
        if target in lines[i]:
            lines[i] = lines[i].replace(target, f'"{kind}-off"')
            changed = True
    assert changed, f"{target} not found inside {fname}() of {rel}"
    p.write_text("".join(lines))


DISPATCHED = [(k, c) for k, c in pass_kind_dispatch.KIND_CONTRACTS.items()
              if c["lane"]]


@pytest.mark.parametrize("kind,contract", DISPATCHED,
                         ids=[k for k, _ in DISPATCHED])
def test_removing_lane_dispatch_turns_pass_red(clone, kind, contract):
    fname, literal = contract["lane"][0]
    _mutate_literal_in_function(clone, "src/repro/core/lane_program.py",
                                fname, literal)
    found = errors(pass_kind_dispatch.run(Repo(str(clone))))
    assert any(f"kind {kind!r}" in f.message
               and f"selector literal {literal!r}" in f.message
               and fname in f.message for f in found), messages(found)


def test_removing_oracle_dispatch_turns_pass_red(clone):
    _mutate_literal_in_function(clone, "src/repro/core/simulator.py",
                                "_run_segments", "thp")
    found = errors(pass_kind_dispatch.run(Repo(str(clone))))
    assert any("kind 'thp'" in f.message and "_run_segments" in f.message
               for f in found), messages(found)


def test_missing_golden_detected(clone):
    for g in (clone / "tests" / "goldens").glob("*.json"):
        if json.loads(g.read_text()).get("spec", {}).get("kind") == "colt":
            g.unlink()
    found = errors(pass_kind_dispatch.run(Repo(str(clone))))
    assert any("kind 'colt' has no golden trace" in f.message
               for f in found), messages(found)


def test_unregistered_factory_detected(clone):
    edit(clone, "tests/test_backends.py", "colt_spec(),", "")
    found = errors(pass_kind_dispatch.run(Repo(str(clone))))
    assert any("kind 'colt'" in f.message and "ALL_KINDS" in f.message
               for f in found), messages(found)


def test_undocumented_kind_detected(clone):
    edit(clone, "docs/methods.md", "`colt`", "`colt-renamed`")
    found = errors(pass_kind_dispatch.run(Repo(str(clone))))
    assert any("kind 'colt' is not documented" in f.message
               for f in found), messages(found)


def test_flag_dropped_from_step_keys_detected(clone):
    edit(clone, "src/repro/core/lane_program.py", '"is_colt", ', "")
    found = errors(pass_kind_dispatch.run(Repo(str(clone))))
    assert any("lane flag 'is_colt' missing from STEP_KEYS" in f.message
               for f in found), messages(found)


def test_new_kind_without_contract_entry_detected(clone):
    edit(clone, "src/repro/core/simulator.py",
         'KINDS = ("base", "thp", "colt", "cluster", "rmm", "anchor",',
         'KINDS = ("brandnew", "base", "thp", "colt", "cluster", "rmm", '
         '"anchor",')
    found = errors(pass_kind_dispatch.run(Repo(str(clone))))
    assert any("kind 'brandnew' has no entry in the dispatch contract"
               in f.message for f in found), messages(found)


# ---------------------------------------------------------------------------
# plane-layout
# ---------------------------------------------------------------------------

def test_hardcoded_plane_width_detected(clone):
    edit(clone, "src/repro/core/lane_program.py",
         'l1=packed((L, L1_SETS, L1_WAYS, PLANE_WIDTH["l1"]), -1),',
         "l1=packed((L, L1_SETS, L1_WAYS, 4), -1),")
    found = errors(pass_plane_layout.run(Repo(str(clone))))
    assert any("hardcoded plane/record width 4" in f.message
               and f.file == "src/repro/core/lane_program.py"
               for f in found), messages(found)


def test_asid_ordering_invariant_detected(clone):
    edit(clone, "src/repro/core/plane_layout.py",
         '"l1": ("tag", "ppn", "lru", "asid"),',
         '"l1": ("tag", "ppn", "asid", "lru"),')
    found = errors(pass_plane_layout.run(Repo(str(clone))))
    assert any("non-sidecar fields ['lru'] follow 'asid'" in f.message
               for f in found), messages(found)


def test_stack_arity_drift_detected(clone):
    edit(clone, "src/repro/core/plane_layout.py",
         '"l1": ("tag", "ppn", "lru", "asid"),',
         '"l1": ("tag", "ppn", "extra", "lru", "asid"),')
    found = errors(pass_plane_layout.run(Repo(str(clone))))
    assert any("l1_vec stacks 4 fields but plane 'l1' is 5 wide"
               in f.message for f in found), messages(found)


# ---------------------------------------------------------------------------
# latency-constants (minimal tree)
# ---------------------------------------------------------------------------

LATENCY_FIXTURE = """\
LAT_WALK = 50
LAT_HIT = 1


def miss_chain_cycles():
    return 50 + 1
"""


def test_latency_magic_number_detected(tmp_path):
    write(tmp_path, "src/repro/core/simulator.py", LATENCY_FIXTURE)
    found = pass_latency.run(Repo(str(tmp_path)))
    assert [f.message for f in errors(found)] == \
        ["magic number 50 duplicates LAT_WALK"]
    assert errors(found)[0].line == 6


def test_latency_definition_and_small_values_exempt(tmp_path):
    write(tmp_path, "src/repro/core/simulator.py",
          "LAT_WALK = 50\nLAT_HIT = 1\nX = 1\n")
    assert pass_latency.run(Repo(str(tmp_path))) == []


# ---------------------------------------------------------------------------
# traced-purity (minimal trees)
# ---------------------------------------------------------------------------

PURITY_FIXTURE = """\
import numpy as np


def step_access(state, x):
    if x > 0:
        state = float(x)
    state = state + np.random.rand()
    n = x.shape[0]
    if n > 2:
        state = state + 1
    for v in probe_order(x):
        state = state + v
    for v in x:
        state = state + v
    return state
"""


def test_purity_violations_detected(tmp_path):
    write(tmp_path, "src/repro/core/lane_program.py", PURITY_FIXTURE)
    msgs = messages(pass_purity.run(Repo(str(tmp_path))))
    assert "python branch on traced value" in msgs
    assert "float() concretizes a traced value" in msgs
    assert "host service call np.random.rand() in traced code" in msgs
    assert "python for over traced array" in msgs
    # sanitized branch (x.shape) and the probe-chain unroll (for over a
    # call result) are legal — exactly one branch and one for flagged
    assert msgs.count("python branch on traced value") == 1
    assert msgs.count("python for over traced array") == 1


STATIC_ARG_FIXTURE = """\
import functools

import jax


@functools.partial(jax.jit, static_argnums=(1,))
def run(x, n):
    if n:
        x = x + 1
    if x:
        x = x + 2
    return x
"""


def test_purity_respects_static_argnums(tmp_path):
    write(tmp_path, "src/repro/core/sweep.py", STATIC_ARG_FIXTURE)
    found = pass_purity.run(Repo(str(tmp_path)))
    assert len(found) == 1 and found[0].line == 10, messages(found)


# ---------------------------------------------------------------------------
# cache-key
# ---------------------------------------------------------------------------

def test_dropped_spec_repr_fold_detected(clone):
    edit(clone, "src/repro/core/sweep.py", "repr(cell.spec)", '"spec"')
    found = errors(pass_cache_key.run(Repo(str(clone))))
    assert any("no longer folds repr(cell.spec)" in f.message
               for f in found), messages(found)


def test_spec_field_opting_out_of_repr_detected(clone):
    edit(clone, "src/repro/core/simulator.py",
         "    kind: str                      # one of KINDS",
         "    kind: str                      # one of KINDS\n"
         "    leak: int = field(repr=False, default=0)")
    found = errors(pass_cache_key.run(Repo(str(clone))))
    assert any("MethodSpec.leak sets repr=False" in f.message
               for f in found), messages(found)


def test_new_run_sweep_knob_detected(clone):
    edit(clone, "src/repro/core/sweep.py",
         "block_size: Optional[int] = None) -> SweepResult:",
         "block_size: Optional[int] = None,\n"
         "              magic: int = 0) -> SweepResult:")
    found = errors(pass_cache_key.run(Repo(str(clone))))
    assert any("run_sweep parameter 'magic'" in f.message
               for f in found), messages(found)


def test_unclassified_worldplan_field_detected(clone):
    edit(clone, "src/repro/core/lane_program.py",
         "    dirty: Tuple[Optional[np.ndarray], ...]",
         "    dirty: Tuple[Optional[np.ndarray], ...]\n"
         "    shadow: int = 0")
    found = errors(pass_cache_key.run(Repo(str(clone))))
    assert any("_WorldPlan.shadow is not classified" in f.message
               for f in found), messages(found)


# ---------------------------------------------------------------------------
# pass isolation: each seeded violation fires exactly its pass
# ---------------------------------------------------------------------------

def test_clone_fixture_is_clean(clone):
    active, _ = analysis.run_passes(Repo(str(clone)), analysis.ALL_PASSES)
    assert not framework.has_errors(active), \
        "\n".join(f.render() for f in errors(active))


ISOLATION_SEEDS = [
    ("kind-dispatch", "src/repro/core/lane_program.py",
     'lanes["is_colt"][i] = s.kind == "colt"',
     'lanes["is_colt"][i] = s.kind == "colt-off"'),
    ("plane-layout", "src/repro/core/lane_program.py",
     'l1=packed((L, L1_SETS, L1_WAYS, PLANE_WIDTH["l1"]), -1),',
     "l1=packed((L, L1_SETS, L1_WAYS, 4), -1),"),
    ("cache-key", "src/repro/core/sweep.py",
     "repr(cell.spec)", '"spec"'),
]


@pytest.mark.parametrize("rule,rel,old,new", ISOLATION_SEEDS,
                         ids=[s[0] for s in ISOLATION_SEEDS])
def test_seeded_violation_fires_exactly_its_pass(clone, rule, rel, old,
                                                 new):
    edit(clone, rel, old, new)
    active, _ = analysis.run_passes(Repo(str(clone)), analysis.ALL_PASSES)
    fired = {f.rule for f in errors(active)}
    assert fired == {rule}, \
        "\n".join(f.render() for f in errors(active))


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def test_suppression_silences_matching_finding(tmp_path):
    write(tmp_path, "src/repro/core/simulator.py", LATENCY_FIXTURE)
    write(tmp_path, framework.SUPPRESSION_FILE,
          "latency-constants | src/repro/core/*.py | magic number 50 | "
          "seeded for the suppression test\n")
    active, suppressed = analysis.run_passes(Repo(str(tmp_path)),
                                             [pass_latency])
    assert active == []
    assert len(suppressed) == 1 and suppressed[0].rule == \
        "latency-constants"


def test_unused_suppression_warns(tmp_path):
    write(tmp_path, "src/repro/core/simulator.py",
          "LAT_WALK = 50\n")
    write(tmp_path, framework.SUPPRESSION_FILE,
          "latency-constants | nowhere/*.py | magic number 99 | stale\n")
    active, _ = analysis.run_passes(Repo(str(tmp_path)), [pass_latency])
    assert any(f.rule == "suppressions" and f.severity == "warning"
               and "matches no finding" in f.message for f in active)


def test_malformed_suppression_is_an_error(tmp_path):
    write(tmp_path, "src/repro/core/simulator.py", "LAT_WALK = 50\n")
    write(tmp_path, framework.SUPPRESSION_FILE, "only | three | fields\n")
    active, _ = analysis.run_passes(Repo(str(tmp_path)), [pass_latency])
    assert any(f.rule == "suppressions" and f.severity == "error"
               and "malformed" in f.message for f in active)
