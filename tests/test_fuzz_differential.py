"""Cross-backend differential fuzzer + named seed-corpus regressions.

The simulator has four implementations of one semantics: the pure-python
oracles (``run_method`` / ``run_method_dynamic`` /
``run_method_multitenant`` / ``run_method_nested``), the step-at-a-time
pure-JAX reference (``kernels/tlb_sweep/ref.py``), the time-blocked XLA
backend, and the Pallas kernel.  The fuzzer draws random ``(mapping
events, trace, method kind, ctx policy, coherence policy, block size,
tenant schedule)`` tuples — including nested worlds composing random
guest event streams over a random host event stream — and asserts all
four agree counter-for-counter and PPN-for-PPN — any divergence is a bug
in exactly one layer, which is what makes the redundancy worth its
maintenance cost.  ``test_nested_zero_stale_translation`` additionally
pins the coherence property itself: after any host remap, no structure
ever serves the old host PPN for an affected composed translation.

The bottom of the file pins the three bugs fixed en route in PRs 2–3 as
named seed-corpus regressions, each reproducing its original trigger:

* ``decode_step_paged`` scattering inactive batch slots' KV at page ``-1``
  (which wraps to the LAST pool page and corrupts whoever owns it);
* ``determine_k`` breaking on strict ``>`` where Algorithm 3 is inclusive
  at coverage == theta;
* recompute preemption dropping the victim's already-generated tokens.
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import demand_mapping
from repro.core.baselines import (anchor_spec, base_spec, cache_tlb_spec,
                                  cluster_spec, colt_spec, dead_protect_spec,
                                  kaligned_spec, rmm_spec, subregion_spec,
                                  thp_spec)
from repro.core.determine_k import determine_k
from repro.core.lane_program import init_batched_state, pack_lanes
from repro.core.page_table import (MappingEvent, build_dynamic_mapping,
                                   build_multitenant_mapping,
                                   build_nested_mapping, make_mapping)
from repro.core.simulator import (run_method_dynamic, run_method_multitenant,
                                  run_method_nested)
from repro.core.sweep import SweepCell, run_sweep

COUNTERS = ("accesses", "l1_hits", "l2_regular_hits", "l2_coalesced_hits",
            "walks", "aligned_probes", "pred_correct", "cycles",
            "coverage_mean", "shootdowns")

SPECS = [base_spec(), thp_spec(), colt_spec(), cluster_spec(), rmm_spec(),
         anchor_spec(6), kaligned_spec([9, 6, 4]),
         kaligned_spec([6, 4], use_predictor=False, name="ka-nopred"),
         subregion_spec(), cache_tlb_spec(), dead_protect_spec()]

WORLD_KINDS = ("static", "dynamic", "multitenant", "nested")


def _mapped_trace(m, n, rng):
    mv = np.flatnonzero(np.asarray(m.ppn) >= 0)
    if mv.size == 0:
        return None
    return mv[rng.integers(0, mv.size, n)].astype(np.int64)


def _gen_world(world_kind: str, seed: int):
    """Deterministic (world, trace) from one seed; None if degenerate."""
    rng = np.random.default_rng(seed)
    n = 512
    if world_kind == "static":
        m = demand_mapping(n, seed=seed % 997)
        trace = _mapped_trace(m, 260, rng)
        return (m, trace) if trace is not None else None

    if world_kind == "dynamic":
        m0 = demand_mapping(n, seed=seed % 991)
        fresh = int(m0.ppn.max()) + 2
        ppn = m0.ppn
        schedule = []
        for e in (1, 2):
            evs = []
            for _ in range(int(rng.integers(1, 3))):
                kind = str(rng.choice(["remap", "unmap", "map", "compact"]))
                start = int(rng.integers(0, n - 64))
                ln = int(rng.integers(1, 48))
                if kind == "unmap":
                    evs.append(MappingEvent("unmap", start, ln))
                else:
                    evs.append(MappingEvent(kind, start, ln, ppn=fresh))
                    fresh += ln + 1
            schedule.append((e * 90, evs))
        dyn = build_dynamic_mapping(m0.ppn, schedule, name=f"fz{seed}")
        parts = []
        bounds = list(dyn.boundaries) + [300]
        for e in range(dyn.n_epochs):
            p = _mapped_trace(dyn.epochs[e], bounds[e + 1] - bounds[e], rng)
            if p is None:
                return None
            parts.append(p)
        return dyn, np.concatenate(parts)

    if world_kind == "nested":
        # nested: 1-2 guests, each optionally with its own event stream,
        # composed over a host layer with its own random event stream; the
        # VM schedule draws ASIDs from a pool smaller than the guest count
        n_g = int(rng.integers(1, 3))
        guests, fresh = [], 0
        for i in range(n_g):
            g0 = demand_mapping(n, seed=(seed + 3 * i) % 971)
            fresh = max(fresh, int(g0.ppn.max()) + 2)
            if rng.integers(0, 2):
                evs = []
                for _ in range(int(rng.integers(1, 3))):
                    kind = str(rng.choice(["remap", "unmap", "map",
                                           "compact"]))
                    start = int(rng.integers(0, n - 64))
                    ln = int(rng.integers(1, 32))
                    if kind == "unmap":
                        evs.append(MappingEvent("unmap", start, ln))
                    else:
                        evs.append(MappingEvent(kind, start, ln, ppn=fresh))
                        fresh += ln + 1
                guests.append(build_dynamic_mapping(
                    g0.ppn, [(int(rng.integers(60, 200)), evs)],
                    name=f"fzg{seed}_{i}"))
            else:
                guests.append(g0)
        hsize = fresh + 8            # host covers every guest PPN
        h_evs, hfresh = [], hsize
        for _ in range(int(rng.integers(1, 3))):
            kind = str(rng.choice(["remap", "unmap", "compact"]))
            start = int(rng.integers(0, hsize - 64))
            ln = int(rng.integers(1, 64))
            if kind == "unmap":
                h_evs.append(MappingEvent("unmap", start, ln))
            else:
                h_evs.append(MappingEvent(kind, start, ln, ppn=hfresh))
                hfresh += ln + 1
        host = build_dynamic_mapping(
            np.arange(hsize, dtype=np.int64),
            [(int(rng.integers(80, 240)), h_evs)], name=f"fzh{seed}")
        sched, t = [], 0
        for _ in range(int(rng.integers(2, 5))):
            gid = int(rng.integers(0, n_g))
            if sched and sched[-1][1] == gid:
                asid = sched[-1][2]  # a resident VM keeps its vCPU ASID
            else:
                asid = int(rng.integers(0, max(n_g - 1, 1)))
            sched.append((t, gid, asid))
            t += 70
        world = build_nested_mapping(guests, host, sched, name=f"fzn{seed}")
        segs = world.plan_segments()
        total = max(sg.lo for sg in segs) + 90
        bounds = [sg.lo for sg in segs] + [total]
        parts = []
        for s, sg in enumerate(segs):
            p = _mapped_trace(sg.mapping, bounds[s + 1] - bounds[s], rng)
            if p is None:
                return None          # a host unmap emptied a composed view
            parts.append(p)
        return world, np.concatenate(parts)

    # multitenant: 2-3 tenants, 5-7 segments, ASIDs drawn from a pool
    # SMALLER than the tenant count so recycling happens organically
    n_ten = int(rng.integers(2, 4))
    tenants = []
    for i in range(n_ten):
        style = int(rng.integers(0, 3))
        if style == 0:
            tenants.append(demand_mapping(n, seed=(seed + i) % 983))
        elif style == 1:
            tenants.append(make_mapping(
                np.arange(n, dtype=np.int64) + int(rng.integers(1, 100)),
                name=f"contig{i}"))
        else:
            tenants.append(demand_mapping(n, seed=(seed + i) % 977,
                                          thp=True))
    n_seg = int(rng.integers(5, 8))
    q = 40
    schedule = []
    for s in range(n_seg):
        tid = int(rng.integers(0, n_ten))
        if schedule and schedule[-1][1] == tid:
            # a resident tenant keeps its ASID (constructor invariant)
            asid = schedule[-1][2]
        else:
            asid = int(rng.integers(0, max(n_ten - 1, 1)))
        schedule.append((s * q, tid, asid))
    mt = build_multitenant_mapping(tenants, schedule, name=f"fzmt{seed}")
    total = n_seg * q + 20
    bounds = list(mt.boundaries) + [total]
    parts = []
    for s in range(mt.n_segments):
        m = mt.tenants[mt.tenant_ids[s]]
        p = _mapped_trace(m, bounds[s + 1] - bounds[s], rng)
        if p is None:
            return None
        parts.append(p)
    return mt, np.concatenate(parts)


def _oracle(spec, world, trace):
    from repro.core.page_table import MultiTenantMapping, NestedMapping
    if isinstance(world, NestedMapping):
        return run_method_nested(spec, world, trace)
    if isinstance(world, MultiTenantMapping):
        return run_method_multitenant(spec, world, trace)
    return run_method_dynamic(spec, world, trace)   # handles static too


def _assert_same(got, want, ctx):
    for f in COUNTERS:
        assert getattr(got, f) == getattr(want, f), (ctx, f)
    np.testing.assert_array_equal(got.ppn, want.ppn, err_msg=str(ctx))


def _run_ref(cell):
    from repro.kernels.tlb_sweep.ref import run_lanes_ref
    from repro.core.lane_program import (C_COAL, C_CYC, C_L1, C_PRED,
                                         C_PROBE, C_REG, C_SHOOT, C_WALK)
    lanes, stacks, (L, sets, ways), seg_bounds = pack_lanes([cell])
    st0 = init_batched_state(
        L, sets, ways, lanes["pred0"], lanes["asid0"],
        with_ctlb=bool(np.asarray(lanes["has_ctlb"]).any()),
        with_dp=bool(np.asarray(lanes["use_dead"]).any()))
    stF, ppns = run_lanes_ref(lanes, stacks, st0, seg_bounds)
    counters = np.asarray(stF["counters"])[0]
    fields = {C_L1: "l1_hits", C_REG: "l2_regular_hits",
              C_COAL: "l2_coalesced_hits", C_WALK: "walks",
              C_PROBE: "aligned_probes", C_PRED: "pred_correct",
              C_CYC: "cycles", C_SHOOT: "shootdowns"}
    cov = float(np.mean(np.asarray(stF["cov_samples"])[0]))
    return ({f: int(counters[c]) for c, f in fields.items()},
            cov, np.asarray(ppns)[0, : cell.trace.shape[0]])


def _check_tuple(seed, spec_i, policy, tb, world_kind, with_pallas,
                 coh="shootdown"):
    gen = _gen_world(world_kind, seed)
    if gen is None:
        return                       # degenerate draw: nothing mapped
    world, trace = gen
    spec = dataclasses.replace(SPECS[spec_i], ctx_policy=policy,
                               coh_policy=coh)
    cell = SweepCell(spec, world, trace)
    want = _oracle(spec, world, trace)

    ref_counters, ref_cov, ref_ppn = _run_ref(cell)
    for f, v in ref_counters.items():
        assert v == getattr(want, f), (seed, world_kind, spec.name, "ref", f)
    assert ref_cov == want.coverage_mean
    np.testing.assert_array_equal(ref_ppn, want.ppn)

    got = run_sweep([cell], cache=False, backend="xla",
                    block_size=tb).results[0]
    _assert_same(got, want, (seed, world_kind, spec.name, "xla", tb))

    if with_pallas:
        got = run_sweep([cell], cache=False, backend="pallas",
                        block_size=tb).results[0]
        _assert_same(got, want, (seed, world_kind, spec.name, "pallas", tb))


@given(st.integers(0, 2**31 - 1), st.integers(0, len(SPECS) - 1),
       st.sampled_from(["flush", "tag"]), st.integers(1, 12),
       st.sampled_from(WORLD_KINDS),
       st.sampled_from(["shootdown", "hw-coherence"]))
@settings(max_examples=4, deadline=None)
def test_differential_oracle_ref_xla(seed, spec_i, policy, tb, world_kind,
                                     coh):
    """oracle == step-reference == time-blocked XLA for random tuples."""
    _check_tuple(seed, spec_i, policy, tb, world_kind, with_pallas=False,
                 coh=coh)


@given(st.integers(0, 2**31 - 1), st.integers(0, len(SPECS) - 1),
       st.sampled_from(["flush", "tag"]), st.integers(1, 8))
@settings(max_examples=2, deadline=None)
def test_differential_pallas_multitenant(seed, spec_i, policy, tb):
    """The full four-way diff including the Pallas kernel, on the
    multi-tenant world kind."""
    _check_tuple(seed, spec_i, policy, tb, "multitenant", with_pallas=True)


@given(st.integers(0, 2**31 - 1), st.integers(0, len(SPECS) - 1),
       st.sampled_from(["shootdown", "hw-coherence"]), st.integers(1, 8))
@settings(max_examples=2, deadline=None)
def test_differential_pallas_nested(seed, spec_i, coh, tb):
    """The full four-way diff including the Pallas kernel, on the newest
    (nested guest→host) world kind — the one most likely to regress."""
    _check_tuple(seed, spec_i, "tag", tb, "nested", with_pallas=True,
                 coh=coh)


@given(st.integers(0, 2**31 - 1), st.integers(0, len(SPECS) - 1))
@settings(max_examples=4, deadline=None)
def test_nested_zero_stale_translation(seed, spec_i):
    """Zero-stale property: after any host remap, NO structure ever serves
    the old host PPN for an affected composed translation — every returned
    PPN equals what the composed view live at that step says, oracle and
    step-reference alike."""
    gen = _gen_world("nested", seed)
    if gen is None:
        return
    world, trace = gen
    spec = SPECS[spec_i]
    res = run_method_nested(spec, world, trace)
    _, _, ref_ppn = _run_ref(SweepCell(spec, world, trace))
    segs = world.plan_segments()
    bounds = [sg.lo for sg in segs] + [trace.shape[0]]
    for s, sg in enumerate(segs):
        lo, hi = bounds[s], bounds[s + 1]
        live = np.asarray(sg.mapping.ppn)[trace[lo:hi]]
        np.testing.assert_array_equal(
            res.ppn[lo:hi], live,
            err_msg=f"oracle served a stale translation in segment {s}")
        np.testing.assert_array_equal(
            ref_ppn[lo:hi], live,
            err_msg=f"reference served a stale translation in segment {s}")


@pytest.mark.slow
@given(st.integers(0, 2**31 - 1), st.integers(0, len(SPECS) - 1),
       st.sampled_from(["flush", "tag"]), st.integers(1, 16),
       st.sampled_from(WORLD_KINDS),
       st.sampled_from(["shootdown", "hw-coherence"]))
@settings(max_examples=8, deadline=None)
def test_differential_full(seed, spec_i, policy, tb, world_kind, coh):
    """Slow lane: more examples, every world kind, all four engines."""
    _check_tuple(seed, spec_i, policy, tb, world_kind, with_pallas=True,
                 coh=coh)


# ---------------------------------------------------------------------------
# Seed corpus: the three bugs fixed en route in PRs 2-3, pinned by name
# ---------------------------------------------------------------------------


def test_seed_corpus_determine_k_inclusive_theta():
    """PR 3: Algorithm 3's stop test used strict ``>`` where the paper's
    "covers more than theta" is inclusive at the boundary.  A histogram
    whose best class covers EXACTLY theta must stop after that class;
    the strict version kept appending alignments."""
    # k=9 covers 512 of 1024 total contiguity == theta exactly
    assert determine_k({512: 1, 16: 32}, theta=0.5, psi=4) == [9]
    # and the epsilon guard keeps float rounding of total*theta from
    # pushing an exact boundary back over the line
    assert determine_k({16: 2, 32: 1}, theta=0.5, psi=4) == [6]


@pytest.fixture(scope="module")
def tiny_model():
    from repro.configs import get_config
    from repro.models import Model, RunConfig
    cfg = get_config("internlm2-1.8b", reduced=True)
    model = Model(cfg, RunConfig(attn_q_chunk=32, attn_kv_chunk=32,
                                 scan_chunk=16))
    return model, model.init(0)


def test_seed_corpus_inactive_slot_kv_scatter(tiny_model):
    """PR 3: ``decode_step_paged`` scattered inactive batch slots' KV at
    page ``-1``, which wraps to the LAST pool page — corrupting whichever
    live sequence owns it.  Run a 1-request engine with a 2-slot batch
    (slot 1 stays inactive every step) and pin that no decode step ever
    writes a pool page the allocator never handed out."""
    import jax.numpy as jnp
    from repro.serve import EngineConfig, ServingEngine
    model, params = tiny_model
    ec = EngineConfig(page_size=8, num_pages=64, max_batch=2, max_seq=64,
                      interpret=True)
    eng = ServingEngine(model, params, ec)
    rid = eng.add_request(list(range(7, 20)), max_new_tokens=4)
    eng.step()                                   # admit + prefill + decode
    owned = set(eng.allocator.seqs[rid].pages)
    probe = [p for p in range(ec.num_pages - 1, -1, -1) if p not in owned]
    assert probe, "allocator handed out every page; enlarge num_pages"
    victim_page = probe[0]                       # includes the wrap target
    snaps = {}
    for j in range(eng.period):
        st = eng.state.get(f"pos{j}")
        if st is not None and "pool_k" in st:
            snaps[j] = (np.asarray(jnp.copy(st["pool_k"][:, victim_page])),
                        np.asarray(jnp.copy(st["pool_v"][:, victim_page])))
    assert snaps, "no paged attention position found"
    while eng.step():
        pass
    assert len(eng.requests[rid].generated) >= 4
    for j, (k0, v0) in snaps.items():
        st = eng.state[f"pos{j}"]
        np.testing.assert_array_equal(
            np.asarray(st["pool_k"][:, victim_page]), k0,
            err_msg=f"pos{j}: unowned page {victim_page} was written "
                    "(inactive-slot scatter regressed)")
        np.testing.assert_array_equal(
            np.asarray(st["pool_v"][:, victim_page]), v0)


def test_seed_corpus_preemption_keeps_generated_tokens(tiny_model):
    """PR 3: recompute preemption folded the victim's generated tokens
    into the prompt and cleared the list, silently dropping them from the
    final output.  Force a preemption and pin that every token generated
    before it survives, as a prefix, to completion."""
    from repro.serve import EngineConfig, ServingEngine
    model, params = tiny_model
    # 16 pages x 8 tokens: two 45-token sequences fit, admitting the third
    # preempts the youngest — which by then holds its first generated token
    ec = EngineConfig(page_size=8, num_pages=16, max_batch=3, max_seq=64,
                      interpret=True)
    eng = ServingEngine(model, params, ec)
    rng = np.random.default_rng(2024)
    rids = [eng.add_request(list(rng.integers(0, model.cfg.vocab, size=45)),
                            max_new_tokens=3) for _ in range(3)]
    pre_preempt: dict = {}
    orig_tap = eng.sched.event_tap

    def tap(kind, rid):
        if kind == "preempt":
            pre_preempt[rid] = list(eng.requests[rid].generated)
        if orig_tap is not None:
            orig_tap(kind, rid)

    eng.sched.event_tap = tap
    eng.run_to_completion()
    assert eng.metrics["preemptions"] >= 1, \
        "pool pressure never forced a preemption; shrink num_pages"
    assert any(pre_preempt.values()), \
        "no victim had generated tokens at preemption time"
    for rid in rids:
        gen = eng.requests[rid].generated
        assert len(gen) == 3
        if rid in pre_preempt:
            k = len(pre_preempt[rid])
            assert gen[:k] == pre_preempt[rid], \
                "pre-preemption tokens were dropped on recompute"
