"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + property tests."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention_gqa
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.paged_attention.ops import (dma_stats,
                                               paged_attention)
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kvcache.allocator import PagedKVAllocator
from repro.kvcache.block_table import (assign_classes, choose_kernel_classes,
                                       window_coverage)

TOL = dict(atol=5e-5, rtol=5e-5)
TOL_BF16 = dict(atol=2e-2, rtol=2e-2)


# ---------------------------------------------------------------------------
# paged attention
# ---------------------------------------------------------------------------

def _random_pool_case(rng, B, H, KVH, D, T, n_pages, frag: float,
                      dtype=jnp.float32):
    """Build block tables with tunable fragmentation."""
    alloc = PagedKVAllocator(n_pages, max_order=5,
                             alloc_policy="page" if frag > 0.9
                             else "buddy_best")
    # churn
    for i in range(int(frag * 10)):
        alloc.allocate(1000 + i, int(rng.integers(1, 6)))
    for i in range(int(frag * 10)):
        if rng.random() < 0.5:
            alloc.free(1000 + i)
    lens, tables = [], []
    max_pages = n_pages // 2
    for b in range(B):
        L = int(rng.integers(T, T * max_pages // 2))
        alloc.allocate(b, -(-L // T))
        lens.append(L)
        tables.append(alloc.block_table(b, max_pages))
    bt = np.stack(tables)
    kp = jnp.asarray(rng.standard_normal((n_pages, T, KVH, D)), dtype)
    vp = jnp.asarray(rng.standard_normal((n_pages, T, KVH, D)), dtype)
    q = jnp.asarray(rng.standard_normal((B, H, D)), dtype)
    return q, kp, vp, bt, jnp.asarray(lens, jnp.int32)


@pytest.mark.parametrize("B,H,KVH,D,T", [
    (2, 4, 2, 64, 16),
    (3, 8, 8, 32, 8),     # MHA
    (1, 8, 1, 128, 16),   # MQA
])
@pytest.mark.parametrize("K_classes", [(), (2,), (3, 1)])
def test_paged_attention_shapes(rng, B, H, KVH, D, T, K_classes):
    q, kp, vp, bt, lens = _random_pool_case(rng, B, H, KVH, D, T, 128, 0.3)
    ref = paged_attention_ref(q, kp, vp, jnp.asarray(bt), lens, T)
    out = paged_attention(q, kp, vp, bt, lens, page_size=T,
                          K_classes=K_classes, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, TOL),
                                       (jnp.bfloat16, TOL_BF16)])
def test_paged_attention_dtypes(rng, dtype, tol):
    q, kp, vp, bt, lens = _random_pool_case(rng, 2, 4, 2, 64, 16, 128, 0.2,
                                            dtype)
    ref = paged_attention_ref(q, kp, vp, jnp.asarray(bt), lens, 16)
    out = paged_attention(q, kp, vp, bt, lens, page_size=16, K_classes=(2,),
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol)


@given(frag=st.floats(0.0, 1.0), seed=st.integers(0, 10_000),
       psi=st.integers(1, 4))
@settings(max_examples=12, deadline=None)
def test_paged_attention_any_fragmentation(frag, seed, psi):
    """Property: coalesced result is exact for ANY contiguity pattern and
    any K chosen by Algorithm 3."""
    rng = np.random.default_rng(seed)
    q, kp, vp, bt, lens = _random_pool_case(rng, 2, 4, 2, 32, 8, 64, frag)
    K = choose_kernel_classes(
        {int(s): 1 for s in np.diff(np.flatnonzero(
            np.diff(np.concatenate([[-9], bt[0][bt[0] >= 0]])) != 1))
         if s > 0} or {1: 1}, psi=psi)
    ref = paged_attention_ref(q, kp, vp, jnp.asarray(bt), lens, 8)
    out = paged_attention(q, kp, vp, bt, lens, page_size=8,
                          K_classes=tuple(K), interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_descriptor_partition_property(rng):
    """Class windows partition the mapped pages: every mapped page is read by
    exactly one class pass."""
    _, _, _, bt, _ = _random_pool_case(rng, 3, 4, 2, 32, 8, 128, 0.5)
    K = [3, 2, 1]
    for b in range(bt.shape[0]):
        asg = assign_classes(bt[b], K)
        covered = np.zeros(bt.shape[1], bool)
        for k, take in asg.items():
            w = 1 << k
            pages = np.repeat(take, w)[: bt.shape[1]] if k else take
            assert not (covered & pages).any(), "double-read"
            covered |= pages
        np.testing.assert_array_equal(covered, bt[b] >= 0)


def test_window_coverage_requires_alignment():
    # physically consecutive but misaligned start ⇒ not class-2 coverable
    bt = np.array([5, 6, 7, 8], np.int64)        # starts at 5 (not %4==0)
    assert not window_coverage(bt, 2)[0]
    bt = np.array([8, 9, 10, 11], np.int64)
    assert window_coverage(bt, 2)[0]


def test_dma_reduction_monotone(rng):
    """More contiguity ⇒ at least as few descriptors."""
    q, kp, vp, bt_frag, lens = _random_pool_case(rng, 2, 4, 2, 32, 8, 128, 1.0)
    q, kp, vp, bt_cont, lens = _random_pool_case(rng, 2, 4, 2, 32, 8, 128, 0.0)
    K = [3, 2, 1]
    frag = dma_stats(bt_frag, K)
    cont = dma_stats(bt_cont, K)
    assert cont["reduction"] >= frag["reduction"]
    assert cont["reduction"] > 0.4


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KVH,D,causal,bq,bk", [
    (2, 128, 4, 2, 64, True, 64, 64),
    (1, 200, 4, 4, 32, True, 64, 32),     # ragged block boundary
    (2, 96, 8, 2, 64, False, 32, 64),
    (1, 64, 2, 1, 128, True, 64, 64),     # MQA, D=128
])
def test_flash_attention(rng, B, S, H, KVH, D, causal, bq, bk):
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KVH, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KVH, D)), jnp.float32)
    out = flash_attention_gqa(q, k, v, causal=causal, block_q=bq, block_k=bk)
    kr = jnp.repeat(k, H // KVH, 2)
    vr = jnp.repeat(v, H // KVH, 2)
    ref = attention_ref(q, kr, vr, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


@pytest.mark.slow
@given(s=st.integers(8, 160), bq=st.sampled_from([8, 32, 64]),
       bk=st.sampled_from([8, 32, 64]), seed=st.integers(0, 999))
@settings(max_examples=10, deadline=None)
def test_flash_attention_block_shape_sweep(s, bq, bk, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((1, s, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, s, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, s, 2, 32)), jnp.float32)
    out = flash_attention_gqa(q, k, v, causal=True, block_q=bq, block_k=bk)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_flash_matches_chunked_jnp_path(rng):
    """The model's portable chunked attention and the Pallas kernel agree."""
    from repro.models.layers import chunked_attention
    q = jnp.asarray(rng.standard_normal((2, 96, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 96, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 96, 2, 32)), jnp.float32)
    a = chunked_attention(q, k, v, causal=True, q_chunk=32, kv_chunk=32)
    b = flash_attention_gqa(q, k, v, causal=True, block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                               rtol=1e-4)
