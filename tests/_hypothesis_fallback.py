"""Minimal stand-in for ``hypothesis`` when it is not installed.

The real hypothesis is declared as a test dependency in pyproject.toml and is
what CI runs.  Hermetic environments without it (e.g. the pinned benchmark
container) still need the suite to *collect and pass*, so ``conftest.py``
registers this module as ``hypothesis`` when the import fails.  It implements
just the API surface our tests use — ``@given``/``@settings`` with integers,
floats, booleans, lists, tuples and sampled_from strategies — drawing a fixed
number of deterministic pseudo-random examples (no shrinking, no database).
"""
from __future__ import annotations

import functools
import inspect
import itertools
import random
from typing import Any, Callable, List

DEFAULT_MAX_EXAMPLES = 40


class _Strategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def example_for(self, rng: random.Random) -> Any:
        return self._draw(rng)


class strategies:
    """Namespace mirroring ``hypothesis.strategies``."""

    @staticmethod
    def integers(min_value: int = -(2**63), max_value: int = 2**63 - 1):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float = 0.0, max_value: float = 1.0):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def sampled_from(seq):
        options = list(seq)
        return _Strategy(lambda rng: options[rng.randrange(len(options))])

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10):
        def draw(rng: random.Random) -> List[Any]:
            n = rng.randint(min_size, max_size)
            return [elements.example_for(rng) for _ in range(n)]
        return _Strategy(draw)

    @staticmethod
    def tuples(*parts: _Strategy):
        return _Strategy(
            lambda rng: tuple(p.example_for(rng) for p in parts))


class _HypothesisHandle:
    """Mimics hypothesis' handle: plugins reach for ``.inner_test``."""

    def __init__(self, inner_test):
        self.inner_test = inner_test


def given(*arg_strategies: _Strategy, **kw_strategies: _Strategy):
    """Run the test once per drawn example (deterministic seed)."""

    def decorate(fn):
        sig = inspect.signature(fn)
        names = list(sig.parameters)
        # hypothesis semantics: positional strategies fill the RIGHTMOST
        # parameters; kwargs strategies fill by name; anything left over is
        # a pytest fixture and must stay visible in the signature.
        pos_names = names[-len(arg_strategies):] if arg_strategies else []
        drawn_names = set(pos_names) | set(kw_strategies)
        fixture_params = [p for p in sig.parameters.values()
                          if p.name not in drawn_names]

        @functools.wraps(fn)
        def wrapper(**fixtures):
            n = getattr(fn, "_max_examples", DEFAULT_MAX_EXAMPLES)
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for i in itertools.islice(itertools.count(), n):
                drawn = {name: s.example_for(rng)
                         for name, s in zip(pos_names, arg_strategies)}
                drawn.update((k, s.example_for(rng))
                             for k, s in kw_strategies.items())
                try:
                    fn(**fixtures, **drawn)
                except Exception:
                    print(f"Falsifying example ({i + 1}/{n}): {drawn!r}")
                    raise

        # pytest must only see the fixture parameters (setting __signature__
        # also stops inspect from following __wrapped__ to the original)
        wrapper.__signature__ = inspect.Signature(fixture_params)
        wrapper.hypothesis = _HypothesisHandle(fn)
        return wrapper

    return decorate


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def decorate(fn):
        # runs before @given wraps (decorators apply bottom-up), so stash the
        # budget on the function for given() to read; after given, update the
        # wrapper's view too.
        fn._max_examples = max_examples
        inner = getattr(fn, "__wrapped__", None)
        if inner is not None:
            inner._max_examples = max_examples
        return fn

    return decorate
