"""Serving engine: end-to-end paged decode == dense decode, scheduling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model, RunConfig, init_decode_state
from repro.serve import EngineConfig, ServingEngine

RC = RunConfig(attn_q_chunk=32, attn_kv_chunk=32, scan_chunk=16)


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_config("internlm2-1.8b", reduced=True)
    model = Model(cfg, RC)
    return model, model.init(0)


def _dense_greedy(model, params, prompt, n_new):
    """Reference: dense-cache decode loop."""
    cfg = model.cfg
    B, S = 1, len(prompt)
    state = init_decode_state(cfg, RC, B, S + n_new + 1, jnp.float32)
    dec = jax.jit(model.decode_step)
    toks = list(prompt)
    for t in range(S):
        lg, state = dec(params, state, jnp.asarray([[toks[t]]]),
                        jnp.asarray([t], jnp.int32))
    out = []
    cur = int(jnp.argmax(lg[0, 0, : cfg.vocab]))
    out.append(cur)
    for i in range(n_new - 1):
        lg, state = dec(params, state, jnp.asarray([[cur]]),
                        jnp.asarray([S + i], jnp.int32))
        cur = int(jnp.argmax(lg[0, 0, : cfg.vocab]))
        out.append(cur)
    return out


def test_paged_equals_dense_decode(model_and_params, rng):
    """The engine's paged+coalesced generation must reproduce the dense
    decode path token for token (the kernel IS the memory system here)."""
    model, params = model_and_params
    cfg = model.cfg
    prompt = list(rng.integers(0, cfg.vocab, size=13))
    n_new = 5
    want = _dense_greedy(model, params, prompt, n_new)

    ec = EngineConfig(page_size=8, num_pages=64, max_batch=1, max_seq=64,
                      interpret=True)
    eng = ServingEngine(model, params, ec)
    eng.add_request(prompt, max_new_tokens=n_new)
    eng.run_to_completion()
    got = eng.requests[0].generated
    assert got == want, (got, want)


@pytest.mark.slow
def test_continuous_batching_and_reuse(model_and_params, rng):
    """Full-size batching churn (CI `-m slow` lane; the default tier keeps
    multi-request coverage via test_descriptor_reduction_positive)."""
    model, params = model_and_params
    cfg = model.cfg
    ec = EngineConfig(page_size=8, num_pages=96, max_batch=2, max_seq=64,
                      interpret=True)
    eng = ServingEngine(model, params, ec)
    for i in range(4):
        eng.add_request(list(rng.integers(0, cfg.vocab, size=10 + 3 * i)),
                        max_new_tokens=4)
    m = eng.run_to_completion()
    assert all(r.state == "done" for r in eng.requests.values())
    assert m["tokens"] >= 4 * 3   # n-1 decoded tokens per request, 4 reqs
    # pages are recycled: pool far smaller than total demand
    assert eng.allocator.utilization() < 1.0


def test_descriptor_reduction_positive(model_and_params, rng):
    model, params = model_and_params
    cfg = model.cfg
    ec = EngineConfig(page_size=8, num_pages=128, max_batch=2, max_seq=128,
                      interpret=True)
    eng = ServingEngine(model, params, ec)
    for i in range(3):
        eng.add_request(list(rng.integers(0, cfg.vocab, size=30)),
                        max_new_tokens=4)
    m = eng.run_to_completion()
    assert m["descriptor_reduction"] > 0.3
    assert m["K"], "Algorithm 3 selected at least one class"


def test_fragmented_pool_still_exact(model_and_params, rng):
    """Worst-case contiguity (page-granular allocation): results identical,
    reduction ~0 — the paper's Base configuration."""
    model, params = model_and_params
    cfg = model.cfg
    prompt = list(rng.integers(0, cfg.vocab, size=11))
    want = _dense_greedy(model, params, prompt, 3)
    ec = EngineConfig(page_size=8, num_pages=64, max_batch=1, max_seq=64,
                      interpret=True, alloc_policy="page")
    eng = ServingEngine(model, params, ec)
    eng.add_request(prompt, max_new_tokens=3)
    eng.run_to_completion()
    assert eng.requests[0].generated == want


def test_decode_growth_across_page_boundary(model_and_params, rng):
    """Generation crossing a page boundary keeps exact results (new pages
    appended through the allocator mid-decode path)."""
    model, params = model_and_params
    cfg = model.cfg
    prompt = list(rng.integers(0, cfg.vocab, size=7))   # page_size 8: crosses
    n_new = 4
    want = _dense_greedy(model, params, prompt, n_new)
    ec = EngineConfig(page_size=8, num_pages=64, max_batch=1, max_seq=64,
                      interpret=True)
    eng = ServingEngine(model, params, ec)
    eng.add_request(prompt, max_new_tokens=n_new)   # 7+4=11 tokens → 2 pages
    eng.run_to_completion()
    assert eng.requests[0].generated == want
    assert len(eng.allocator.seqs) == 0 or True


def test_preemption_under_pool_pressure(model_and_params):
    """A tiny pool forces preempt-and-requeue; results stay exact and no
    generated token is lost across recompute preemption.

    Uses a dedicated seeded generator rather than the shared session ``rng``:
    paged and dense attention differ in reduction order, so exact-argmax
    comparison needs prompts with comfortable logit gaps — the session
    stream shifts with test selection and can land on near-ties (this test
    used to fail when the file ran as a standalone subset).  The seed is
    pinned to one verified to decode identically on both paths.
    """
    model, params = model_and_params
    cfg = model.cfg
    rng = np.random.default_rng(2024)
    prompts = [list(rng.integers(0, cfg.vocab, size=45)) for _ in range(3)]
    wants = [_dense_greedy(model, params, p, 3) for p in prompts]
    # pool of 16 pages x 8 tokens: two 45+3-token seqs (6 pages each) fit,
    # admitting the third forces a preemption
    ec = EngineConfig(page_size=8, num_pages=16, max_batch=3, max_seq=64,
                      interpret=True)
    eng = ServingEngine(model, params, ec)
    for p in prompts:
        eng.add_request(p, max_new_tokens=3)
    m = eng.run_to_completion()
    assert m["preemptions"] >= 1, "pool pressure never forced a preemption"
    assert all(r.state == "done" for r in eng.requests.values())
    for rid, want in enumerate(wants):
        assert eng.requests[rid].generated == want, rid
