"""Dynamic mapping worlds: epoch-aware oracle parity, shootdown correctness
(no structure may ever translate a stale vpn -> old ppn pair), cache keys."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import demand_mapping, generate_trace
from repro.core.baselines import (anchor_spec, base_spec, cluster_spec,
                                  colt_spec, kaligned_spec, rmm_spec,
                                  thp_spec)
from repro.core.page_table import (DynamicMapping, MappingEvent, apply_event,
                                   build_dynamic_mapping,
                                   dynamic_from_snapshots, events_from_diff,
                                   make_mapping)
from repro.core.simulator import run_method, run_method_dynamic
from repro.core.sweep import SweepCell, cell_key, run_sweep
from repro.scenarios import clear_materialized_cache, get_scenario, \
    list_scenarios

COUNTERS = ("accesses", "l1_hits", "l2_regular_hits", "l2_coalesced_hits",
            "walks", "aligned_probes", "pred_correct", "cycles",
            "coverage_mean", "shootdowns")

ALL_KINDS = [base_spec(), thp_spec(), colt_spec(), cluster_spec(), rmm_spec(),
             anchor_spec(6), kaligned_spec([9, 6, 4]),
             kaligned_spec([6, 4], use_predictor=False, name="ka-nopred")]


def _epoch_bounds(world, trace_len):
    b = world.boundaries if isinstance(world, DynamicMapping) else (0,)
    return list(b) + [trace_len]


def _assert_no_stale(world, trace, result):
    """Every access must translate to the ppn of the epoch live at that
    step — the shootdown-correctness property."""
    epochs = world.epochs if isinstance(world, DynamicMapping) else (world,)
    bounds = _epoch_bounds(world, len(trace))
    for e, m in enumerate(epochs):
        lo, hi = bounds[e], bounds[e + 1]
        np.testing.assert_array_equal(
            result.ppn[lo:hi], np.asarray(m.ppn)[trace[lo:hi]],
            err_msg=f"stale translation in epoch {e}")


# ---------------------------------------------------------------------------
# Worlds
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def hot_world():
    """Remaps that overlap the working set: every structure must shoot."""
    n = 1 << 12
    ppn0 = np.arange(n, dtype=np.int64) + 7      # contiguous: huge runs
    ev1 = [MappingEvent("remap", 0, 512, ppn=100_000)]
    ev2 = [MappingEvent("split", 512, 256,
                        ppn=np.arange(200_000, 200_000 + 256 * 3, 3)),
           MappingEvent("unmap", 3072, 64)]
    dyn = build_dynamic_mapping(ppn0, [(700, ev1), (1400, ev2)], name="hot")
    rng = np.random.default_rng(3)
    trace = rng.integers(0, 1024, size=2100).astype(np.int64)
    return dyn, trace


@pytest.fixture(scope="module")
def churn_world():
    d = get_scenario("dyn-kv-churn").materialize(n_pages=1 << 12,
                                                 trace_len=1800, trace_seed=8)
    return d.dynamic, np.asarray(d.trace)


@pytest.fixture(scope="module")
def hot_sweep(hot_world, churn_world):
    """One batched run over BOTH dynamic worlds plus one static lane —
    heterogeneous epochs/boundaries share one compiled program."""
    dyn, trace = hot_world
    cdyn, ctrace = churn_world
    m_static = demand_mapping(1 << 11, seed=5)
    tr_static = generate_trace("zipf", 0, 1500, seed=9, mapping=m_static)
    cells = [SweepCell(s, dyn, trace) for s in ALL_KINDS]
    cells += [SweepCell(s, cdyn, ctrace) for s in ALL_KINDS]
    cells += [SweepCell(base_spec(), m_static, tr_static),
              SweepCell(kaligned_spec([8, 6, 4]), m_static, tr_static)]
    return cells, run_sweep(cells, cache=False)


@pytest.mark.parametrize("i", range(len(ALL_KINDS)),
                         ids=lambda i: ALL_KINDS[i].name)
def test_lane_matches_oracle_hot_world(hot_sweep, hot_world, i):
    """Bit-exact parity of the epoch-segmented lane vs the pure-python
    epoch-aware oracle, every counter including shootdowns."""
    dyn, trace = hot_world
    _, sweep = hot_sweep
    got = sweep.results[i]
    want = run_method_dynamic(ALL_KINDS[i], dyn, trace)
    for f in COUNTERS:
        assert getattr(got, f) == getattr(want, f), f
    np.testing.assert_array_equal(got.ppn, want.ppn)


@pytest.mark.parametrize("i", range(len(ALL_KINDS)),
                         ids=lambda i: ALL_KINDS[i].name)
def test_lane_matches_oracle_churn_world(hot_sweep, churn_world, i):
    """Same parity over a recorded serving-churn world (snapshot-diff
    events, uneven dirty sets)."""
    cdyn, ctrace = churn_world
    _, sweep = hot_sweep
    got = sweep.results[len(ALL_KINDS) + i]
    want = run_method_dynamic(ALL_KINDS[i], cdyn, ctrace)
    for f in COUNTERS:
        assert getattr(got, f) == getattr(want, f), f
    np.testing.assert_array_equal(got.ppn, want.ppn)


def test_no_stale_translations_all_methods(hot_sweep, hot_world, churn_world):
    """THE dynamic-correctness property: after shootdown, no method ever
    returns a dead translation, in either engine."""
    dyn, trace = hot_world
    cdyn, ctrace = churn_world
    cells, sweep = hot_sweep
    for i, spec in enumerate(ALL_KINDS):
        _assert_no_stale(dyn, trace, sweep.results[i])
        _assert_no_stale(cdyn, ctrace, sweep.results[len(ALL_KINDS) + i])


def test_shootdowns_fire_and_cost_cycles(hot_sweep):
    """Remaps overlapping the working set must invalidate entries in every
    method (the hot world touches L1, L2, THP, RMM and cluster reach)."""
    _, sweep = hot_sweep
    for i, spec in enumerate(ALL_KINDS):
        r = sweep.results[i]
        assert r.shootdowns > 0, spec.name
    # static lanes never shoot
    assert sweep.results[-1].shootdowns == 0
    assert sweep.results[-2].shootdowns == 0


def test_static_lane_in_mixed_sweep_matches_run_method(hot_sweep):
    """Static cells riding in a dynamic sweep stay bit-exact vs the static
    oracle (the 1-epoch path is the old engine)."""
    cells, sweep = hot_sweep
    for idx in (-2, -1):
        c = cells[idx]
        want = run_method(c.spec, c.mapping, c.trace)
        got = sweep.results[idx]
        for f in COUNTERS[:-1]:
            assert getattr(got, f) == getattr(want, f), f
        np.testing.assert_array_equal(got.ppn, want.ppn)


# ---------------------------------------------------------------------------
# Property test: random event streams never leak a stale pair
# ---------------------------------------------------------------------------


@given(st.integers(0, 2**31 - 1), st.integers(1, 3),
       st.sampled_from(["remap", "unmap", "promote", "split", "compact"]))
@settings(max_examples=5, deadline=None)
def test_random_event_streams_no_stale(seed, n_epochs, bias):
    """After ANY event stream, lane == oracle and no stale translation, for
    all seven method kinds (small world so the python oracle stays cheap)."""
    n = 1 << 10
    rng = np.random.default_rng(seed)
    m0 = demand_mapping(n, seed=seed % 1000)
    seg = 250
    schedule = []
    fresh = int(m0.ppn.max()) + 2
    for e in range(1, n_epochs + 1):
        evs = []
        for _ in range(int(rng.integers(1, 4))):
            kind = bias if rng.random() < 0.5 else \
                str(rng.choice(["remap", "unmap", "map"]))
            start = int(rng.integers(0, n - 64))
            ln = int(rng.integers(1, 64))
            if kind == "unmap":
                evs.append(MappingEvent("unmap", start, ln))
            else:
                evs.append(MappingEvent(kind, start, ln, ppn=fresh))
                fresh += ln + 1
        schedule.append((e * seg, evs))
    dyn = build_dynamic_mapping(m0.ppn, schedule, name=f"rand{seed}")
    parts = []
    bounds = list(dyn.boundaries) + [(n_epochs + 1) * seg]
    for e in range(dyn.n_epochs):
        mv = np.flatnonzero(dyn.epochs[e].ppn >= 0)
        if mv.size == 0:
            return          # degenerate: everything unmapped
        idx = rng.integers(0, mv.size, size=bounds[e + 1] - bounds[e])
        parts.append(mv[idx])
    trace = np.concatenate(parts).astype(np.int64)
    specs = [base_spec(), thp_spec(), colt_spec(), cluster_spec(),
             rmm_spec(), anchor_spec(4), kaligned_spec([6, 4])]
    sweep = run_sweep([SweepCell(s, dyn, trace) for s in specs], cache=False)
    for s, got in zip(specs, sweep.results):
        _assert_no_stale(dyn, trace, got)
        want = run_method_dynamic(s, dyn, trace)
        for f in COUNTERS:
            assert getattr(got, f) == getattr(want, f), (s.name, f)
        np.testing.assert_array_equal(got.ppn, want.ppn)


# ---------------------------------------------------------------------------
# Event / DynamicMapping plumbing
# ---------------------------------------------------------------------------


def test_events_from_diff_roundtrip():
    rng = np.random.default_rng(0)
    a = np.where(rng.random(512) < 0.8,
                 rng.integers(0, 10_000, 512), -1).astype(np.int64)
    b = a.copy()
    b[40:80] = np.arange(40) + 20_000       # remap
    b[100:110] = -1                         # unmap
    b[200:220] = np.arange(20) + 30_000     # part map / part remap
    evs = events_from_diff(a, b)
    cur = a
    for ev in evs:
        cur = apply_event(cur, ev)
    np.testing.assert_array_equal(cur, b)
    assert {e.kind for e in evs} <= {"map", "unmap", "remap"}


def test_dynamic_mapping_dirty_and_epoch_at():
    n = 256
    ppn0 = np.arange(n, dtype=np.int64)
    dyn = build_dynamic_mapping(
        ppn0, [(10, [MappingEvent("remap", 0, 8, ppn=1000)])])
    assert dyn.n_epochs == 2
    assert dyn.epoch_at(0) == 0 and dyn.epoch_at(9) == 0
    assert dyn.epoch_at(10) == 1 and dyn.epoch_at(99) == 1
    assert dyn.dirty_count(1) == 8
    # newly mapped pages are NOT dirty (no stale translation existed)
    ppn1 = np.full(n, -1, np.int64)
    ppn1[:8] = 5
    m1 = make_mapping(ppn1)
    ppn2 = ppn1.copy()
    ppn2[8:16] = 77                          # map fresh pages only
    dyn2 = dynamic_from_snapshots([m1, make_mapping(ppn2)], [0, 5])
    assert dyn2.dirty_count(1) == 0
    assert dyn2.events[1][0].kind == "map"


def test_dynamic_cell_key_sensitive_to_events():
    """The sweep cache key must fold in the event stream: same epoch-0
    mapping + same trace but different events/boundaries -> different key."""
    n = 1 << 10
    ppn0 = np.arange(n, dtype=np.int64)
    tr = np.arange(500, dtype=np.int64) % n
    ev = [MappingEvent("remap", 0, 32, ppn=5000)]
    d1 = build_dynamic_mapping(ppn0, [(100, ev)])
    d2 = build_dynamic_mapping(ppn0, [(200, ev)])                # when
    d3 = build_dynamic_mapping(ppn0, [(100, [MappingEvent(
        "remap", 0, 32, ppn=6000)])])                            # what
    m_static = make_mapping(ppn0)
    keys = {cell_key(SweepCell(base_spec(), w, tr))
            for w in (d1, d2, d3, m_static)}
    assert len(keys) == 4
    assert cell_key(SweepCell(base_spec(), d1, tr)) == \
        cell_key(SweepCell(base_spec(),
                           build_dynamic_mapping(ppn0, [(100, ev)]), tr))


def test_dynamic_cache_roundtrip(tmp_path, hot_world):
    dyn, trace = hot_world
    cells = [SweepCell(base_spec(), dyn, trace),
             SweepCell(kaligned_spec([6, 4]), dyn, trace)]
    cdir = str(tmp_path / "cache")
    first = run_sweep(cells, cache=True, cache_dir=cdir)
    assert first.stats["simulated"] == 2
    second = run_sweep(cells, cache=True, cache_dir=cdir)
    assert second.stats["cache_hits"] == 2
    for a, b in zip(first.results, second.results):
        for f in COUNTERS:
            assert getattr(a, f) == getattr(b, f), f
        np.testing.assert_array_equal(a.ppn, b.ppn)


# ---------------------------------------------------------------------------
# Dynamic scenarios
# ---------------------------------------------------------------------------


def test_dynamic_scenarios_registered():
    names = {sc.name for sc in list_scenarios("dynamic")}
    assert {"dyn-kv-churn", "dyn-compaction", "dyn-thp-split"} <= names


@pytest.mark.parametrize("name", [sc.name for sc in list_scenarios("dynamic")])
def test_dynamic_scenario_valid_per_epoch(name):
    """Every trace entry must be mapped in the epoch live at that step, and
    the static `mapping` is the epoch-0 snapshot."""
    d = get_scenario(name).materialize(n_pages=1 << 12, trace_len=2000,
                                       trace_seed=8)
    dyn = d.dynamic
    assert dyn is not None and d.world is dyn
    assert dyn.n_epochs >= 2, "dynamic scenario produced a static world"
    np.testing.assert_array_equal(d.mapping.ppn, dyn.epochs[0].ppn)
    bounds = _epoch_bounds(dyn, len(d.trace))
    for e in range(dyn.n_epochs):
        seg = d.trace[bounds[e]: bounds[e + 1]]
        assert (dyn.epochs[e].ppn[seg] >= 0).all(), f"epoch {e}"
    assert sum(dyn.dirty_count(e) for e in range(1, dyn.n_epochs)) > 0, \
        "no translation ever died: the world is effectively static"


@pytest.mark.parametrize("name", [sc.name for sc in list_scenarios("dynamic")])
def test_dynamic_scenario_deterministic(name):
    a = get_scenario(name).materialize(n_pages=1 << 12, trace_len=1500,
                                       map_seed=5)
    clear_materialized_cache()
    b = get_scenario(name).materialize(n_pages=1 << 12, trace_len=1500,
                                       map_seed=5)
    np.testing.assert_array_equal(a.trace, b.trace)
    assert a.dynamic.boundaries == b.dynamic.boundaries
    for ma, mb in zip(a.dynamic.epochs, b.dynamic.epochs):
        np.testing.assert_array_equal(ma.ppn, mb.ppn)


def test_dyn_kv_churn_tapped_real_scheduling():
    d = get_scenario("dyn-kv-churn").materialize(n_pages=1 << 12,
                                                 trace_len=1500, trace_seed=8)
    assert d.meta["sched_events"].get("admit", 0) > 0
    assert d.meta["events"], "no mapping events recorded"
    assert d.meta["preemptions"] > 0 or d.meta["completions"] > 0
