"""Optimizers, gradient compression, trainer fault tolerance, checkpointing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data import DataPipeline, PipelineConfig
from repro.models import Model, RunConfig
from repro.optim import OptConfig, apply_opt, init_opt
from repro.optim.optimizer import _dq8, _q8
from repro.train import SimulatedFailure, Trainer, TrainerConfig

RC = RunConfig(attn_q_chunk=32, attn_kv_chunk=32, scan_chunk=16)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["adamw", "adamw8bit", "adafactor"])
def test_optimizer_minimizes_quadratic(kind):
    oc = OptConfig(kind=kind, lr=0.1, warmup_steps=0, total_steps=200,
                   weight_decay=0.0, clip_norm=1e9)
    target = jnp.asarray(np.random.default_rng(0).standard_normal((8, 16)),
                         jnp.float32)
    params = {"w": jnp.zeros((8, 16), jnp.float32)}
    state = init_opt(oc, params)
    loss = lambda p: jnp.mean((p["w"] - target) ** 2)
    for step in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = apply_opt(oc, g, state, params, jnp.int32(step))
    assert float(loss(params)) < 0.01, kind


@given(st.integers(0, 1000), st.integers(1, 600))
@settings(max_examples=30, deadline=None)
def test_q8_roundtrip_bounded_error(seed, n):
    x = np.random.default_rng(seed).standard_normal(n).astype(np.float32) * 10
    q, s = _q8(jnp.asarray(x))
    back = np.asarray(_dq8(q, s, x.shape))
    # error bounded by scale/2 per block (127 levels)
    err = np.abs(back - x)
    assert err.max() <= (np.abs(x).max() / 127) * 1.01 + 1e-6


def test_q8_preserves_leading_dims():
    x = jnp.ones((3, 5, 300))
    q, s = _q8(x)
    assert q.shape[:2] == (3, 5) and s.shape[:2] == (3, 5)


def test_grad_compression_error_feedback():
    """EF property: mean of compressed updates converges to the true mean."""
    from repro.distributed.grad_compress import _dequant, _quant
    rng = np.random.default_rng(0)
    g = rng.standard_normal(1000).astype(np.float32)
    resid = np.zeros_like(g)
    acc = np.zeros_like(g)
    for t in range(50):
        x = jnp.asarray(g + resid)
        q, s = _quant(x)
        sent = np.asarray(_dequant(q, s, x.shape))
        resid = np.asarray(x) - sent
        acc += sent
    # accumulated transmitted mass ≈ accumulated true mass
    np.testing.assert_allclose(acc / 50, g, atol=np.abs(g).max() / 127 / 50
                               + 1e-3, rtol=0.01)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        tree = {"a": jnp.arange(10, dtype=jnp.bfloat16),
                "b": {"c": jnp.ones((3, 3), jnp.int8)}}
        for step in (1, 2, 3):
            ck.save(step, tree, extras={"step": step}, blocking=True)
        assert ck.latest_step() == 3
        got, extras = ck.restore(target=tree)
        assert extras["step"] == 3
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
        # keep=2: step 1 garbage-collected
        assert not os.path.exists(os.path.join(d, "step_00000001"))


def test_checkpoint_atomicity_tmp_cleanup():
    with tempfile.TemporaryDirectory() as d:
        os.makedirs(os.path.join(d, "step_00000009.tmp-deadbeef"))
        ck = Checkpointer(d)
        assert ck.latest_step() is None          # partial save invisible
        assert not any(".tmp-" in n for n in os.listdir(d))


# ---------------------------------------------------------------------------
# trainer fault tolerance
# ---------------------------------------------------------------------------

def _make_trainer(d, total, fail_at=None):
    cfg = get_config("internlm2-1.8b", reduced=True)
    model = Model(cfg, RC)
    oc = OptConfig(lr=1e-3, warmup_steps=1, total_steps=100)
    tc = TrainerConfig(total_steps=total, ckpt_every=3, ckpt_dir=d,
                       log_every=1)
    hook = None
    if fail_at is not None:
        def hook(step):
            if step == fail_at:
                raise SimulatedFailure(f"injected at {step}")
    pipe = DataPipeline(cfg, PipelineConfig(batch=2, seq=16))
    return Trainer(model, oc, tc, pipe, failure_hook=hook)


def test_crash_restart_smoke():
    """Default-tier resume coverage at the smallest useful size: one
    checkpoint cycle, crash, restart from it."""
    with tempfile.TemporaryDirectory() as d:
        t1 = _make_trainer(d, total=5, fail_at=4)
        with pytest.raises(SimulatedFailure):
            t1.run()
        t1.ckpt.wait()
        out = _make_trainer(d, total=5).run()
        steps = [m["step"] for m in out["metrics"]]
        assert steps[0] == 3 and steps[-1] == 4


@pytest.mark.slow
def test_crash_restart_resumes_training():
    with tempfile.TemporaryDirectory() as d:
        t1 = _make_trainer(d, total=9, fail_at=7)
        with pytest.raises(SimulatedFailure):
            t1.run()
        t1.ckpt.wait()
        # "node" restarts: fresh trainer picks up from last checkpoint (6)
        t2 = _make_trainer(d, total=9)
        out = t2.run()
        steps = [m["step"] for m in out["metrics"]]
        assert steps[0] == 6, "resumed from last checkpoint"
        assert steps[-1] == 8


@pytest.mark.slow
def test_restart_is_deterministic_continuation():
    """Run-through losses == crash+resume losses (same data, same steps)."""
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        full = _make_trainer(d1, total=6).run()
        t1 = _make_trainer(d2, total=6, fail_at=4)
        with pytest.raises(SimulatedFailure):
            t1.run()
        t1.ckpt.wait()
        resumed = _make_trainer(d2, total=6).run()
        a = {m["step"]: m["loss"] for m in full["metrics"]}
        b = {m["step"]: m["loss"] for m in resumed["metrics"]}
        for s in (4, 5):
            assert abs(a[s] - b[s]) < 1e-4, (s, a[s], b[s])


def test_straggler_watchdog():
    import time as _time
    with tempfile.TemporaryDirectory() as d:
        tr = _make_trainer(d, total=8)
        orig = tr.train_step

        calls = {"n": 0}

        def slow(*a, **k):
            calls["n"] += 1
            if calls["n"] == 6:
                _time.sleep(1.0)      # inject a straggler step
            return orig(*a, **k)
        tr.train_step = slow
        tr.run()
        assert len(tr.straggler_steps) >= 1
