"""Recurrent layers: chunked-parallel prefill == step-by-step decode."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import MambaConfig, ModelConfig
from repro.models.mamba import mamba_layer, mamba_specs
from repro.models.xlstm import (mlstm_layer, mlstm_specs, slstm_layer,
                                slstm_specs)
from repro.models.common import init_params


def _params(specs, seed=0):
    return init_params(specs, seed=seed, dtype="float32")


CFG = ModelConfig(name="t", family="hybrid", n_layers=1, d_model=32,
                  n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64, vocab=64,
                  mamba=MambaConfig(d_state=4, d_conv=4, expand=2))
XCFG = ModelConfig(name="x", family="xlstm", n_layers=1, d_model=32,
                   n_heads=4, n_kv_heads=4, head_dim=8, d_ff=0, vocab=64)


@pytest.mark.parametrize("chunk", [4, 16, 64])
def test_mamba_chunked_equals_sequential(rng, chunk):
    """Chunk size must not change the result (checkpoint boundaries only)."""
    p = _params(mamba_specs(CFG))
    x = jnp.asarray(rng.standard_normal((2, 48, 32)) * 0.3, jnp.float32)
    full = mamba_layer(CFG, p, x, scan_chunk=48)
    chunked = mamba_layer(CFG, p, x, scan_chunk=chunk)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               atol=1e-4, rtol=1e-4)


def test_mamba_prefill_equals_decode(rng):
    """Prefill final state == state after token-by-token decode; decode
    outputs match the parallel outputs."""
    p = _params(mamba_specs(CFG))
    x = jnp.asarray(rng.standard_normal((1, 12, 32)) * 0.3, jnp.float32)
    full, (conv_f, ssm_f) = mamba_layer(CFG, p, x, scan_chunk=4,
                                        return_state=True)
    state = None
    outs = []
    for t in range(12):
        o, state = mamba_layer(CFG, p, x[:, t:t + 1], state=state,
                               return_state=True)
        outs.append(o[:, 0])
    seq = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(full), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(state[1]), np.asarray(ssm_f),
                               atol=1e-4, rtol=1e-4)


def test_mlstm_prefill_equals_decode(rng):
    p = _params(mlstm_specs(XCFG))
    x = jnp.asarray(rng.standard_normal((1, 10, 32)) * 0.3, jnp.float32)
    full, st_f = mlstm_layer(XCFG, p, x, scan_chunk=5, return_state=True)
    state = None
    outs = []
    for t in range(10):
        o, state = mlstm_layer(XCFG, p, x[:, t:t + 1], state=state,
                               return_state=True)
        outs.append(o[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(state.C), np.asarray(st_f.C),
                               atol=1e-4, rtol=1e-4)


def test_slstm_prefill_equals_decode(rng):
    p = _params(slstm_specs(XCFG))
    x = jnp.asarray(rng.standard_normal((1, 9, 32)) * 0.3, jnp.float32)
    full, st_f = slstm_layer(XCFG, p, x, scan_chunk=3, return_state=True)
    state = None
    outs = []
    for t in range(9):
        o, state = slstm_layer(XCFG, p, x[:, t:t + 1], state=state,
                               return_state=True)
        outs.append(o[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(state.c), np.asarray(st_f.c),
                               atol=1e-4, rtol=1e-4)


def test_mamba_state_is_o1(rng):
    """Decode state size is independent of sequence length (the reason
    jamba/xlstm run long_500k)."""
    p = _params(mamba_specs(CFG))
    for S in (8, 64):
        x = jnp.asarray(rng.standard_normal((1, S, 32)), jnp.float32)
        _, (conv, ssm) = mamba_layer(CFG, p, x, return_state=True)
        assert ssm.shape == (1, 64, 4)
        assert conv.shape[1] == 3
