"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
shape + finite checks; decode-vs-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import Model, RunConfig, init_decode_state, padded_vocab
from repro.optim import OptConfig, init_opt
from repro.train import make_train_step
from repro.data import PipelineConfig

RC = RunConfig(attn_q_chunk=32, attn_kv_chunk=32, scan_chunk=16)

# the hybrid jamba stack dominates suite wall time even reduced (~100s
# across its four tests); it runs full-size in the CI `-m slow` lane while
# the default tier keeps every other arch
HEAVY_ARCHS = {"jamba-1.5-large-398b"}


def _arch_params(archs, extra_slow=()):
    return [pytest.param(a, marks=pytest.mark.slow)
            if (a in HEAVY_ARCHS or a in extra_slow) else a for a in archs]


def _batch(cfg, B, S, rng):
    if cfg.family == "encoder":
        return {"input_embeds": jnp.asarray(
                    rng.standard_normal((B, S, cfg.d_model)), jnp.float32),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))),
                "mask": jnp.ones((B, S), jnp.float32)}
    out = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))),
           "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))}
    if cfg.family == "vlm":
        out["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_patches, cfg.d_model)) * 0.02,
            jnp.float32)
    return out


@pytest.mark.parametrize("arch", _arch_params(ARCH_IDS))
def test_smoke_forward(arch, rng):
    cfg = get_config(arch, reduced=True)
    model = Model(cfg, RC)
    params = model.init(0)
    B, S = 2, 48
    b = _batch(cfg, B, S, rng)
    logits, aux = jax.jit(model.forward)(
        params, b.get("tokens"),
        patch_embeds=b.get("patch_embeds"),
        input_embeds=b.get("input_embeds"))
    assert logits.shape == (B, S, padded_vocab(cfg))
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", _arch_params(
    ARCH_IDS, extra_slow=("xlstm-350m",)))
def test_smoke_train_step(arch, rng):
    cfg = get_config(arch, reduced=True)
    model = Model(cfg, RC)
    params = model.init(0)
    oc = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = init_opt(oc, params)
    step = jax.jit(make_train_step(model, oc))
    b = _batch(cfg, 2, 32, rng)
    # step 1: step 0 of a 1-step warmup has lr == 0 (params must not move!)
    p2, o2, metrics = step(params, opt, b, jnp.int32(1))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = any(bool(jnp.any(a != b_)) for a, b_ in
                zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", _arch_params(
    [a for a in ARCH_IDS if a != "hubert-xlarge"]))
def test_decode_matches_forward(arch, rng):
    """Greedy decode over a prefix must equal teacher-forced forward argmax:
    the strongest cheap consistency check between cache and full paths."""
    cfg = get_config(arch, reduced=True)
    # f32 for tight tolerance; huge capacity factor so the MoE dispatch drops
    # nothing (forward dispatches per 24-token group, decode per 1 token —
    # capacity drops are the one legitimate forward/decode divergence).
    model = Model(cfg, RC.replace(compute_dtype="float32",
                                  capacity_factor=32.0))
    params = model.init(0)
    B, S = 1, 24
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
    pe = (jnp.asarray(rng.standard_normal((B, cfg.n_patches, cfg.d_model))
                      * 0.02, jnp.float32) if cfg.family == "vlm" else None)
    logits, _ = jax.jit(model.forward)(params, toks, patch_embeds=pe)

    # replay through decode_step one token at a time
    state = init_decode_state(cfg, RC, B, S + 4, jnp.float32)
    dec = jax.jit(model.decode_step)
    outs = []
    # feed the true tokens (teacher forcing) so positions match
    if cfg.family == "vlm":
        # decode path has no patch injection for the prefix; skip strict
        # equality, just run the steps for finiteness
        for t in range(4):
            lg, state = dec(params, state, toks[:, t:t + 1],
                            jnp.full((B,), t, jnp.int32))
            assert bool(jnp.isfinite(lg).all())
        return
    for t in range(S):
        lg, state = dec(params, state, toks[:, t:t + 1],
                        jnp.full((B,), t, jnp.int32))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(logits), atol=2e-3, rtol=2e-3)


def test_loss_decreases_dense(rng):
    cfg = get_config("internlm2-1.8b", reduced=True)
    model = Model(cfg, RC)
    oc = OptConfig(lr=3e-3, warmup_steps=1, total_steps=40)
    params = model.init(0)
    opt = init_opt(oc, params)
    step = jax.jit(make_train_step(model, oc))
    pc = PipelineConfig(batch=4, seq=32, seed=1)
    losses = []
    from repro.data.pipeline import _batch_at
    for i in range(12):
        b = {k: jnp.asarray(v) for k, v in _batch_at(cfg, pc, 0).items()}
        params, opt, m = step(params, opt, b, jnp.int32(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses


@pytest.mark.parametrize("arch", _arch_params(
    ["internlm2-1.8b", "jamba-1.5-large-398b"]))
def test_chunked_prefill_matches_full(arch, rng):
    """Sarathi-style chunked prefill == single-pass prefill (logits+state)."""
    cfg = get_config(arch, reduced=True)
    model = Model(cfg, RC.replace(compute_dtype="float32",
                                  capacity_factor=32.0))
    params = model.init(0)
    B, S = 2, 32
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
    lg_full, st_full = jax.jit(model.prefill)(params, toks)
    lg_c, st_c = jax.jit(lambda p, t: model.prefill_chunked(
        p, t, n_chunks=4))(params, toks)
    np.testing.assert_allclose(np.asarray(lg_c), np.asarray(lg_full),
                               atol=3e-3, rtol=3e-3)
    # decode states agree (caches compared over the filled prefix)
    for pos, st in st_full.items():
        for key, val in st.items():
            got = np.asarray(st_c[pos][key], np.float32)
            want = np.asarray(val, np.float32)
            if key in ("k", "v"):
                got, want = got[:, :, :S], want[:, :, :S]
            np.testing.assert_allclose(got, want, atol=3e-3, rtol=3e-3)
