"""Multi-tenant address spaces: ASID-tagged coalesced TLBs under
context-switch pressure.

The contract this file pins down:

* **Parity** — the switch-segmented sweep lanes are bit-exact
  (hit/miss/evict/shootdown counters AND every translated PPN) against the
  pure-python oracle :func:`repro.core.simulator.run_method_multitenant`
  for all 8 method kinds × both context-switch policies × both backends.
* **Isolation** — no access EVER translates through another tenant's
  entry: ``result.ppn[t] == tenant_at(t).ppn[trace[t]]`` for every method
  and policy (the multi-tenant analogue of the dynamic worlds' no-stale
  property).
* **ASID semantics** — a recycled ASID never serves the departed tenant's
  translations; tags beat flushes when resident working sets fit; the
  cache key distinguishes schedules and policies.

Heaviest variants (scenario-scale traces) are ``@pytest.mark.slow`` with
small fast stand-ins, per the repo convention.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import demand_mapping
from repro.core.baselines import (anchor_spec, base_spec, cluster_spec,
                                  colt_spec, kaligned_spec, rmm_spec,
                                  thp_spec)
from repro.core.page_table import (MultiTenantMapping,
                                   build_multitenant_mapping, make_mapping)
from repro.core.simulator import (LAT_CTX_SWITCH, run_method,
                                  run_method_multitenant)
from repro.core.sweep import SweepCell, cell_key, run_sweep
from repro.scenarios import clear_materialized_cache, get_scenario, \
    list_scenarios

COUNTERS = ("accesses", "l1_hits", "l2_regular_hits", "l2_coalesced_hits",
            "walks", "aligned_probes", "pred_correct", "cycles",
            "coverage_mean", "shootdowns")

ALL_KINDS = [base_spec(), thp_spec(), colt_spec(), cluster_spec(), rmm_spec(),
             anchor_spec(6), kaligned_spec([9, 6, 4]),
             kaligned_spec([6, 4], use_predictor=False, name="ka-nopred")]
POLICIES = ("flush", "tag")


def _with_policy(specs, policy):
    return [dataclasses.replace(s, ctx_policy=policy) for s in specs]


def _assert_equal(got, want, ctx):
    for f in COUNTERS:
        assert getattr(got, f) == getattr(want, f), (ctx, f)
    np.testing.assert_array_equal(got.ppn, want.ppn, err_msg=str(ctx))


def _assert_isolated(world: MultiTenantMapping, trace, result, ctx):
    """Every access translates in the tenant scheduled at that step."""
    bounds = list(world.boundaries) + [len(trace)]
    for s in range(world.n_segments):
        lo, hi = bounds[s], bounds[s + 1]
        m = world.tenants[world.tenant_ids[s]]
        np.testing.assert_array_equal(
            result.ppn[lo:hi], np.asarray(m.ppn)[trace[lo:hi]],
            err_msg=f"cross-tenant translation in segment {s} ({ctx})")


# ---------------------------------------------------------------------------
# Worlds
# ---------------------------------------------------------------------------


def _segment_trace(world: MultiTenantMapping, total: int, seed: int):
    """Random per-segment accesses, each mapped in its segment's tenant."""
    rng = np.random.default_rng(seed)
    bounds = list(world.boundaries) + [total]
    parts = []
    for s in range(world.n_segments):
        m = world.tenants[world.tenant_ids[s]]
        mv = np.flatnonzero(m.ppn >= 0)
        parts.append(mv[rng.integers(0, mv.size, bounds[s + 1] - bounds[s])])
    return np.concatenate(parts).astype(np.int64)


@pytest.fixture(scope="module")
def hand_world():
    """Three tenants with different contiguity (demand / fully contiguous /
    THP-ish), schedule with revisits AND an ASID recycle (tenant 2 takes
    tenant 0's ASID after it departs)."""
    ta = demand_mapping(1 << 10, seed=1)
    tb = make_mapping(np.arange(1 << 10, dtype=np.int64) + 3, name="contig")
    tc = demand_mapping(1 << 9, seed=7, thp=True)
    mt = build_multitenant_mapping(
        [ta, tb, tc],
        [(0, 0, 0), (60, 1, 1), (130, 0, 0), (200, 1, 1),
         (260, 2, 0), (330, 1, 1), (400, 2, 0)],
        name="mt-hand")
    assert sum(mt.recycled) >= 1      # the tenant-2 takeover of ASID 0
    trace = _segment_trace(mt, 470, seed=5)
    return mt, trace


@pytest.fixture(scope="module")
def hand_cells(hand_world):
    """8 kinds × both policies over the hand world — one 16-lane batch."""
    mt, trace = hand_world
    specs = _with_policy(ALL_KINDS, "flush") + _with_policy(ALL_KINDS, "tag")
    return specs, [SweepCell(s, mt, trace) for s in specs]


@pytest.fixture(scope="module")
def hand_oracle(hand_world, hand_cells):
    mt, trace = hand_world
    specs, _ = hand_cells
    return [run_method_multitenant(s, mt, trace) for s in specs]


@pytest.fixture(scope="module")
def hand_sweep_xla(hand_cells):
    _, cells = hand_cells
    return run_sweep(cells, cache=False, backend="xla")


# ---------------------------------------------------------------------------
# Parity: lanes == oracle, both policies, both backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("j", range(2 * len(ALL_KINDS)),
                         ids=lambda j: (POLICIES[j // len(ALL_KINDS)] + "-"
                                        + ALL_KINDS[j % len(ALL_KINDS)].name))
def test_lane_matches_oracle_xla(hand_cells, hand_oracle, hand_sweep_xla, j):
    specs, _ = hand_cells
    _assert_equal(hand_sweep_xla.results[j], hand_oracle[j],
                  (specs[j].name, specs[j].ctx_policy, "xla"))


def test_lane_matches_oracle_pallas(hand_cells, hand_oracle):
    """The Pallas kernel runs the same switch pass in-kernel (interpret
    mode on CPU): bit-exact for every kind × policy."""
    specs, cells = hand_cells
    sweep = run_sweep(cells, cache=False, backend="pallas", block_size=4)
    for j, s in enumerate(specs):
        _assert_equal(sweep.results[j], hand_oracle[j],
                      (s.name, s.ctx_policy, "pallas"))


@pytest.mark.parametrize("tb", [1, 8])
def test_block_size_invariance(hand_cells, hand_oracle, tb):
    """Block boundaries never straddle a switch; results are identical for
    any block size."""
    _, cells = hand_cells
    sweep = run_sweep(cells, cache=False, backend="xla", block_size=tb)
    for j, want in enumerate(hand_oracle):
        _assert_equal(sweep.results[j], want, ("tb", tb, j))


def test_isolation_no_cross_tenant_translation(hand_world, hand_cells,
                                               hand_sweep_xla, hand_oracle):
    """THE multi-tenant correctness property: under either policy no
    method ever returns another tenant's translation — from the oracle or
    from the engine."""
    mt, trace = hand_world
    specs, _ = hand_cells
    for j, s in enumerate(specs):
        _assert_isolated(mt, trace, hand_oracle[j],
                         (s.name, s.ctx_policy, "oracle"))
        _assert_isolated(mt, trace, hand_sweep_xla.results[j],
                         (s.name, s.ctx_policy, "xla"))


# ---------------------------------------------------------------------------
# ASID semantics
# ---------------------------------------------------------------------------


def test_recycled_asid_never_serves_dead_tenant():
    """Tenant C inherits tenant A's ASID; under the tag policy C's first
    access must WALK (A's entry for the same vpn is invalidated by the
    recycle), and must translate through C's page table."""
    ta = make_mapping(np.full(8, 100, np.int64) + np.arange(8), name="A")
    tc = make_mapping(np.full(8, 200, np.int64) + np.arange(8), name="C")
    mt = build_multitenant_mapping([ta, tc], [(0, 0, 0), (4, 1, 0)],
                                   name="recycle")
    assert mt.recycled == (False, True)
    trace = np.array([0, 1, 0, 1, 0, 1, 0, 1], np.int64)
    spec = dataclasses.replace(base_spec(), ctx_policy="tag")
    r = run_method_multitenant(spec, mt, trace)
    # A: walks at t=0,1 then L1 hits; C: must walk again at t=4,5
    assert r.walks == 4
    np.testing.assert_array_equal(
        r.ppn, np.array([100, 101, 100, 101, 200, 201, 200, 201]))
    # engine agrees
    sweep = run_sweep([SweepCell(spec, mt, trace)], cache=False,
                      backend="xla")
    _assert_equal(sweep.results[0], r, "recycle")


def test_tag_retains_resident_tenants_flush_refaults():
    """Two tiny tenants alternating: their working sets fit every
    structure, so ASID tags keep both resident (walks = cold misses only)
    while flush-on-switch refaults every quantum."""
    ta = make_mapping(np.arange(32, dtype=np.int64) * 3 + 50, name="A")
    tb = make_mapping(np.arange(32, dtype=np.int64) * 5 + 900, name="B")
    sched = [(i * 32, i % 2, i % 2) for i in range(8)]
    mt = build_multitenant_mapping([ta, tb], sched, name="pingpong")
    trace = np.tile(np.arange(32, dtype=np.int64), 8)
    flush = run_method_multitenant(
        dataclasses.replace(base_spec(), ctx_policy="flush"), mt, trace)
    tag = run_method_multitenant(
        dataclasses.replace(base_spec(), ctx_policy="tag"), mt, trace)
    assert tag.walks == 64            # cold misses only: 2 tenants x 32
    assert flush.walks == 256         # every quantum refaults its 32 pages
    assert tag.cycles < flush.cycles
    assert flush.shootdowns > 0 and tag.shootdowns == 0
    # both policies charge the same 7 x LAT_CTX_SWITCH, so the entire cycle
    # gap is the refault walks (base: 7-cycle miss chain + 50-cycle walk)
    assert flush.cycles - tag.cycles == (flush.walks - tag.walks) * (7 + 50)
    assert LAT_CTX_SWITCH > 0


def test_single_segment_multitenant_equals_static():
    """A one-tenant, one-segment MultiTenantMapping is just that tenant's
    static world."""
    m = demand_mapping(1 << 10, seed=3)
    mt = build_multitenant_mapping([m], [(0, 0, 0)], name="solo")
    mv = np.flatnonzero(m.ppn >= 0)
    trace = mv[np.random.default_rng(0).integers(0, mv.size, 300)]
    for spec in (base_spec(), kaligned_spec([6, 4])):
        want = run_method(spec, m, trace)
        got = run_method_multitenant(spec, mt, trace)
        for f in COUNTERS[:-1]:
            assert getattr(got, f) == getattr(want, f), f
        np.testing.assert_array_equal(got.ppn, want.ppn)


def test_mt_cell_key_sensitive_to_schedule_and_policy(hand_world):
    """Same tenants but a different schedule, different ASID assignment,
    or different ctx_policy must never collide in the sweep cache."""
    mt, trace = hand_world
    base = SweepCell(base_spec(), mt, trace)
    other_sched = build_multitenant_mapping(
        list(mt.tenants),
        [(0, 0, 0), (100, 1, 1), (200, 2, 2)], name="other")
    other_asids = MultiTenantMapping(
        mt.tenants, mt.boundaries, mt.tenant_ids,
        tuple((a + 1) % 3 for a in mt.asids), name="reasid")
    keys = {cell_key(base),
            cell_key(SweepCell(base_spec(), other_sched, trace)),
            cell_key(SweepCell(base_spec(), other_asids, trace)),
            cell_key(SweepCell(
                dataclasses.replace(base_spec(), ctx_policy="tag"),
                mt, trace)),
            cell_key(SweepCell(base_spec(), mt.tenants[0], trace))}
    assert len(keys) == 5
    # and it IS stable across rebuilds of an identical world
    rebuilt = build_multitenant_mapping(
        list(mt.tenants),
        [(b, t, a) for b, t, a in zip(mt.boundaries, mt.tenant_ids,
                                      mt.asids)], name="rebuilt")
    assert cell_key(SweepCell(base_spec(), rebuilt, trace)) == cell_key(base)


def test_mixed_batch_static_dynamic_multitenant(hand_world):
    """One run_sweep over static + multi-tenant cells: the partition keeps
    static lanes off the segmented timeline and results stay exact."""
    mt, trace = hand_world
    m = demand_mapping(1 << 10, seed=9)
    mv = np.flatnonzero(m.ppn >= 0)
    st_trace = mv[np.random.default_rng(2).integers(0, mv.size, 400)]
    cells = [SweepCell(base_spec(), m, st_trace),
             SweepCell(kaligned_spec([6, 4]), m, st_trace),
             SweepCell(dataclasses.replace(base_spec(), ctx_policy="tag"),
                       mt, trace)]
    sweep = run_sweep(cells, cache=False)
    assert sweep.stats["n_batches"] == 2
    for idx in (0, 1):
        want = run_method(cells[idx].spec, m, st_trace)
        for f in COUNTERS[:-1]:
            assert getattr(sweep.results[idx], f) == getattr(want, f), f
    want = run_method_multitenant(cells[2].spec, mt, trace)
    _assert_equal(sweep.results[2], want, "mt lane in mixed batch")


# ---------------------------------------------------------------------------
# Scenario plumbing
# ---------------------------------------------------------------------------

MT_SCENARIOS = ("mt-serve-mix", "mt-churn", "mt-flush-vs-tag")


def test_mt_scenarios_registered():
    names = {sc.name for sc in list_scenarios("multitenant")}
    assert set(MT_SCENARIOS) <= names


@pytest.mark.parametrize("name", MT_SCENARIOS)
def test_mt_scenario_valid_per_segment(name):
    """Every trace entry is mapped in the tenant scheduled at that step;
    the schedule actually switches; mt-churn actually recycles ASIDs."""
    d = get_scenario(name).materialize(n_pages=1 << 12, trace_len=2000,
                                       trace_seed=8)
    mt = d.multitenant
    assert mt is not None and d.world is mt
    assert mt.n_switches() > 0, "no context switch: world is single-tenant"
    bounds = list(mt.boundaries) + [len(d.trace)]
    for s in range(mt.n_segments):
        m = mt.tenants[mt.tenant_ids[s]]
        seg = d.trace[bounds[s]: bounds[s + 1]]
        assert (seg < m.n_pages).all() and (m.ppn[seg] >= 0).all(), \
            f"segment {s} accesses pages unmapped in its tenant"
    if name == "mt-churn":
        assert sum(mt.recycled) > 0, "mt-churn never recycled an ASID"
        assert d.meta["sched_events"].get("admit", 0) > 0


@pytest.mark.parametrize("name", MT_SCENARIOS)
def test_mt_scenario_deterministic(name):
    a = get_scenario(name).materialize(n_pages=1 << 12, trace_len=1500,
                                       map_seed=5)
    clear_materialized_cache()
    b = get_scenario(name).materialize(n_pages=1 << 12, trace_len=1500,
                                       map_seed=5)
    np.testing.assert_array_equal(a.trace, b.trace)
    assert a.multitenant.boundaries == b.multitenant.boundaries
    assert a.multitenant.asids == b.multitenant.asids
    for ma, mb in zip(a.multitenant.tenants, b.multitenant.tenants):
        np.testing.assert_array_equal(ma.ppn, mb.ppn)


def test_mt_scenario_parity_fast():
    """Scenario-world parity, fast tier: one scenario, a subset of kinds,
    both policies, xla backend."""
    d = get_scenario("mt-flush-vs-tag").materialize(
        n_pages=1 << 12, trace_len=900, trace_seed=8)
    mt, trace = d.multitenant, np.asarray(d.trace)
    kinds = [base_spec(), colt_spec(), kaligned_spec([6, 4])]
    specs = _with_policy(kinds, "flush") + _with_policy(kinds, "tag")
    sweep = run_sweep([SweepCell(s, mt, trace) for s in specs], cache=False)
    for s, got in zip(specs, sweep.results):
        want = run_method_multitenant(s, mt, trace)
        _assert_equal(got, want, (s.name, s.ctx_policy, "scenario-fast"))
        _assert_isolated(mt, trace, got, s.name)


@pytest.mark.slow
@pytest.mark.parametrize("name", MT_SCENARIOS)
def test_mt_scenario_parity_full(name):
    """Scenario-world parity, slow lane: every scenario, all 8 kinds,
    both policies, both backends."""
    d = get_scenario(name).materialize(n_pages=1 << 12, trace_len=2000,
                                       trace_seed=8)
    mt, trace = d.multitenant, np.asarray(d.trace)
    specs = _with_policy(ALL_KINDS, "flush") + _with_policy(ALL_KINDS, "tag")
    cells = [SweepCell(s, mt, trace) for s in specs]
    oracle = [run_method_multitenant(s, mt, trace) for s in specs]
    for backend in ("xla", "pallas"):
        sweep = run_sweep(cells, cache=False, backend=backend)
        for s, got, want in zip(specs, sweep.results, oracle):
            _assert_equal(got, want, (name, s.name, s.ctx_policy, backend))
            _assert_isolated(mt, trace, got, (name, s.name, backend))
