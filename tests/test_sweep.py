"""Batched sweep engine: bit-exact parity with run_method + cache behavior."""
import numpy as np
import pytest

from repro.core import (anchor_spec, base_spec, cluster_spec, colt_spec,
                        demand_mapping, generate_trace, kaligned_spec,
                        rmm_spec, run_method, thp_spec)
from repro.core.sweep import SweepCell, cell_key, run_sweep

COUNTERS = ("accesses", "l1_hits", "l2_regular_hits", "l2_coalesced_hits",
            "walks", "aligned_probes", "pred_correct", "cycles",
            "coverage_mean")

ALL_KINDS = [base_spec(), thp_spec(), colt_spec(), cluster_spec(), rmm_spec(),
             anchor_spec(6), kaligned_spec([8, 6, 4]),
             kaligned_spec([6, 4], use_predictor=False, name="ka-nopred")]


@pytest.fixture(scope="module")
def small_world():
    m = demand_mapping(1 << 12, seed=11)
    m2 = demand_mapping(1 << 11, seed=5)
    tr = generate_trace("multiscale", 0, 2500, seed=4, mapping=m)
    tr2 = generate_trace("zipf", 0, 1800, seed=9, mapping=m2)
    return m, m2, tr, tr2


@pytest.fixture(scope="module")
def sweep_and_oracle(small_world):
    m, m2, tr, tr2 = small_world
    # heterogeneous batch: two mappings of different sizes, two trace
    # lengths, all seven method kinds (plus a predictor-less kaligned) —
    # exercises every padding axis at once
    cells = [SweepCell(s, m, tr) for s in ALL_KINDS]
    cells += [SweepCell(s, m2, tr2) for s in ALL_KINDS]
    sweep = run_sweep(cells, cache=False)
    oracle = [run_method(c.spec, c.mapping, c.trace) for c in cells]
    return cells, sweep, oracle


@pytest.mark.parametrize("i", range(2 * len(ALL_KINDS)),
                         ids=lambda i: f"{ALL_KINDS[i % len(ALL_KINDS)].name}"
                                       f"/m{i // len(ALL_KINDS)}")
def test_sweep_matches_run_method_exactly(sweep_and_oracle, i):
    """Every counter and every translated PPN must match the per-call oracle
    bit-for-bit — the padded batched engine is the same machine."""
    _, sweep, oracle = sweep_and_oracle
    got, want = sweep.results[i], oracle[i]
    for f in COUNTERS:
        assert getattr(got, f) == getattr(want, f), f
    np.testing.assert_array_equal(got.ppn, want.ppn)


def test_sweep_stats(sweep_and_oracle):
    cells, sweep, _ = sweep_and_oracle
    assert sweep.stats["n_cells"] == len(cells)
    assert sweep.stats["simulated"] == len(cells)
    assert sweep.stats["cache_hits"] == 0


def test_cache_roundtrip(small_world, tmp_path):
    """Second run_sweep hits the on-disk cache and skips simulation."""
    m, _, tr, _ = small_world
    cells = [SweepCell(base_spec(), m, tr),
             SweepCell(kaligned_spec([6, 4]), m, tr)]
    cdir = str(tmp_path / "sweep_cache")
    first = run_sweep(cells, cache=True, cache_dir=cdir)
    assert first.stats["simulated"] == 2
    second = run_sweep(cells, cache=True, cache_dir=cdir)
    assert second.stats["simulated"] == 0
    assert second.stats["cache_hits"] == 2
    for a, b in zip(first.results, second.results):
        for f in COUNTERS:
            assert getattr(a, f) == getattr(b, f), f
        np.testing.assert_array_equal(a.ppn, b.ppn)


def test_cache_key_sensitivity(small_world):
    """The key must change when spec, mapping, or trace content changes."""
    m, m2, tr, tr2 = small_world
    base = cell_key(SweepCell(base_spec(), m, tr))
    assert cell_key(SweepCell(thp_spec(), m, tr)) != base
    assert cell_key(SweepCell(base_spec(), m2, tr)) != base
    assert cell_key(SweepCell(base_spec(), m, tr2)) != base
    assert cell_key(SweepCell(base_spec(), m, tr)) == base
