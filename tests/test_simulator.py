"""TLB simulator engine: translation exactness + oracle equivalence."""
import numpy as np
import pytest

from repro.core import (MethodSpec, anchor_spec, base_spec, cluster_spec,
                        colt_spec, generate_trace, kaligned_for_mapping,
                        kaligned_spec, rmm_spec, run_method, simulate_reference,
                        synthetic_mapping, thp_spec)


@pytest.fixture(scope="module")
def mapping():
    return synthetic_mapping("mixed", 1 << 14, seed=3)


@pytest.fixture(scope="module")
def trace(mapping):
    return generate_trace("multiscale", 0, 20_000, seed=4, mapping=mapping)


ALL_SPECS = [base_spec(), thp_spec(), colt_spec(), cluster_spec(), rmm_spec(),
             anchor_spec(6), kaligned_spec([8, 6, 4])]


@pytest.mark.parametrize("spec", ALL_SPECS, ids=lambda s: s.name)
def test_translation_exact(spec, mapping, trace):
    """Every method must translate every access to the true PPN."""
    r = run_method(spec, mapping, trace)
    np.testing.assert_array_equal(r.ppn, np.asarray(mapping.ppn)[trace])


@pytest.mark.parametrize("spec", ALL_SPECS, ids=lambda s: s.name)
def test_accounting_consistent(spec, mapping, trace):
    r = run_method(spec, mapping, trace)
    assert (r.l1_hits + r.l2_regular_hits + r.l2_coalesced_hits + r.walks
            == r.accesses)
    assert r.cycles >= 50 * r.walks


def test_engine_matches_reference_oracle(mapping):
    """Fully-associative engine == the pure-python ReferenceTLB, miss for
    miss (no L1: the oracle has none, so give the engine a 1-entry L1 set
    that never hits by using distinct pages)."""
    K = (6, 4)
    trace = generate_trace("multiscale", 0, 3_000, seed=7, mapping=mapping)
    ref = simulate_reference(mapping, trace, K=K, capacity=64)
    # engine: 1 set x 64 ways == fully associative, same capacity
    spec = MethodSpec(name="fa", kind="kaligned", K=K, l2_sets=1, l2_ways=64,
                      index_shift=max(K), use_predictor=True)
    r = run_method(spec, mapping, trace)
    # L1 absorbs some repeats the oracle counts as L2 hits, so compare walks
    # (page-table walks are L1-independent: L1 content ⊆ L2-resident pages
    # does not hold in general, so allow a small slack).
    assert abs(r.walks - ref["walks"]) <= 0.05 * max(ref["walks"], 1)


def test_kaligned_beats_base_on_contiguity():
    m = synthetic_mapping("large", 1 << 16, seed=5)
    tr = generate_trace("multiscale", 0, 50_000, seed=6, mapping=m)
    base = run_method(base_spec(), m, tr)
    ka = run_method(kaligned_for_mapping(m, psi=3), m, tr)
    assert ka.walks < 0.5 * base.walks


def test_predictor_high_accuracy_on_sequential():
    """§3.2/Table 6: spatial locality ⇒ ~9x% single-probe aligned hits."""
    m = synthetic_mapping("medium", 1 << 15, seed=8)
    tr = generate_trace("sequential", 0, 40_000, seed=9, mapping=m)
    r = run_method(kaligned_for_mapping(m, psi=3), m, tr)
    assert r.l2_coalesced_hits > 0
    assert r.predictor_accuracy > 0.85


def test_coverage_grows_with_coalescing(mapping, trace):
    """Table 5: coverage(K Aligned) > coverage(Base)."""
    base = run_method(base_spec(), mapping, trace)
    ka = run_method(kaligned_for_mapping(mapping, psi=3), mapping, trace)
    assert ka.coverage_mean > 1.5 * base.coverage_mean
