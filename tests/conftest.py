import sys

import numpy as np
import pytest

# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device;
# only launch/dryrun.py forces 512 host devices (in its own process).

try:  # real hypothesis (declared in pyproject [test]) when available
    import hypothesis  # noqa: F401
except ImportError:  # hermetic containers: register the minimal fallback
    import _hypothesis_fallback

    sys.modules["hypothesis"] = _hypothesis_fallback


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
