"""MoE dispatch invariants (property-based) + gradient flow."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models.config import ModelConfig, RunConfig
from repro.models.common import init_params
from repro.models.moe import _dispatch_indices, moe_ffn, moe_specs


def _cfg(E=8, k=2, shared=0):
    return ModelConfig(name="m", family="moe", n_layers=1, d_model=16,
                       n_heads=2, n_kv_heads=2, head_dim=8, d_ff=32,
                       vocab=64, n_experts=E, top_k=k,
                       n_shared_experts=shared)


@given(st.integers(0, 9999), st.integers(2, 16), st.integers(4, 64))
@settings(max_examples=40, deadline=None)
def test_dispatch_indices_invariants(seed, E, A):
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, E, A), jnp.int32)
    cap = max(int(np.ceil(A / E)), 2)
    slot, keep = jax.jit(lambda i: _dispatch_indices(i, E, cap))(ids)
    slot, keep = np.asarray(slot), np.asarray(keep)
    kept = slot[keep]
    assert len(np.unique(kept)) == len(kept), "slot collision"
    assert (kept // cap == np.asarray(ids)[keep]).all(), "wrong expert bucket"
    assert (slot[~keep] == E * cap).all(), "dropped must hit drop bucket"
    # per-expert kept count never exceeds capacity
    for e in range(E):
        assert ((kept // cap) == e).sum() <= cap


def test_high_capacity_drops_nothing(rng):
    cfg = _cfg(E=4, k=2)
    rc = RunConfig(capacity_factor=8.0)
    p = init_params(moe_specs(cfg, rc), dtype="float32")
    x = jnp.asarray(rng.standard_normal((2, 16, 16)), jnp.float32)
    y, aux = moe_ffn(cfg, rc, p, x)
    assert y.shape == x.shape
    # with huge capacity, output = dense mixture: no token is zeroed
    norms = jnp.linalg.norm(y.reshape(-1, 16), axis=-1)
    assert float(norms.min()) > 0


def test_zero_capacity_factor_drops_everything_gracefully(rng):
    cfg = _cfg(E=4, k=1)
    rc = RunConfig(capacity_factor=1e-9)   # capacity floor = 4
    p = init_params(moe_specs(cfg, rc), dtype="float32")
    x = jnp.asarray(rng.standard_normal((1, 8, 16)), jnp.float32)
    y, _ = moe_ffn(cfg, rc, p, x)
    assert bool(jnp.isfinite(y).all())


def test_moe_grads_flow_to_all_parts(rng):
    cfg = _cfg(E=4, k=2, shared=1)
    rc = RunConfig(capacity_factor=2.0)
    p = init_params(moe_specs(cfg, rc), dtype="float32")
    x = jnp.asarray(rng.standard_normal((2, 8, 16)), jnp.float32)

    def loss(p):
        y, aux = moe_ffn(cfg, rc, p, x)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    for path in ("router", "w_gate", "w_down"):
        assert float(jnp.abs(g[path]).sum()) > 0, path
    assert float(jnp.abs(g["shared"]["w_gate"]).sum()) > 0


def test_aux_loss_uniform_router_is_one():
    """Switch aux loss: uniform routing ⇒ E * Σ (1/E)(1/E) = 1."""
    cfg = _cfg(E=8, k=1)
    rc = RunConfig()
    p = init_params(moe_specs(cfg, rc), dtype="float32")
    p["router"] = jnp.zeros_like(p["router"])   # uniform probs
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 32, 16)),
                    jnp.float32)
    _, aux = moe_ffn(cfg, rc, p, x)
    assert abs(float(aux) - 1.0) < 0.05
