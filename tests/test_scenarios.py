"""Scenario registry: parity with direct calls, determinism, sweep cache."""
import zlib

import numpy as np
import pytest

from repro.core import demand_mapping, generate_trace, synthetic_mapping
from repro.core.mappings import mapped_vpns
from repro.core.sweep import SweepCell, run_sweep
from repro.core.traces import BENCHMARKS
from repro.core.baselines import base_spec, kaligned_for_mapping
from repro.kvcache.allocator import PagedKVAllocator
from repro.scenarios import (clear_materialized_cache, get_scenario,
                             list_scenarios)
from repro.serve.scheduler import KVScheduler

N = 1 << 12
L = 2000


# ---------------------------------------------------------------------------
# Registry basics
# ---------------------------------------------------------------------------


def test_registry_families_populated():
    names = {sc.name for sc in list_scenarios()}
    assert {"synth-mixed", "demand", "paper-mcf", "kv-churn", "kv-gather",
            "train-pipeline", "ckpt-shards", "adv-numa"} <= names
    assert len(list_scenarios("workload")) >= 5
    assert len(list_scenarios("adversarial")) >= 3
    with pytest.raises(KeyError):
        get_scenario("no-such-scenario")


@pytest.mark.parametrize("name", [sc.name for sc in list_scenarios()])
def test_every_scenario_materializes_valid_world(name):
    """Every registered scenario yields a simulator-ready world: an int64
    VPN trace that only touches mapped pages of its mapping (for dynamic
    scenarios: mapped in the epoch live at that step)."""
    d = get_scenario(name).materialize(n_pages=N, trace_len=L, trace_seed=8)
    assert d.trace.dtype == np.int64 and d.trace.ndim == 1
    assert 0 < d.trace.shape[0] <= L
    assert d.trace.min() >= 0
    if d.multitenant is not None:
        mt = d.multitenant
        assert d.trace.max() < mt.n_pages
        bounds = list(mt.boundaries) + [d.trace.shape[0]]
        for s in range(mt.n_segments):
            m = mt.tenants[mt.tenant_ids[s]]
            seg = d.trace[bounds[s]: bounds[s + 1]]
            assert (seg < m.n_pages).all() and (m.ppn[seg] >= 0).all(), \
                f"trace hit a vpn unmapped in its tenant (segment {s})"
    elif d.nested is not None:
        nw = d.nested
        assert d.trace.max() < nw.n_pages
        segs = nw.plan_segments()
        bounds = [sg.lo for sg in segs] + [d.trace.shape[0]]
        for s, sg in enumerate(segs):
            seg = d.trace[bounds[s]: bounds[s + 1]]
            m = sg.mapping
            assert (seg < m.n_pages).all() and (m.ppn[seg] >= 0).all(), \
                f"trace hit a vpn unmapped in its composed view (segment {s})"
    elif d.dynamic is not None:
        assert d.trace.max() < d.mapping.n_pages
        bounds = list(d.dynamic.boundaries) + [d.trace.shape[0]]
        for e, m in enumerate(d.dynamic.epochs):
            seg = d.trace[bounds[e]: bounds[e + 1]]
            assert (m.ppn[seg] >= 0).all(), \
                f"trace hit a vpn unmapped in epoch {e}"
    else:
        assert d.trace.max() < d.mapping.n_pages
        assert (d.mapping.ppn[d.trace] >= 0).all(), \
            "trace hit an unmapped vpn"
    assert mapped_vpns(d.mapping).shape[0] > 0


def test_materialization_is_memoized():
    a = get_scenario("synth-small").materialize(n_pages=N, trace_len=L)
    b = get_scenario("synth-small").materialize(n_pages=N, trace_len=L)
    assert a is b
    clear_materialized_cache()
    c = get_scenario("synth-small").materialize(n_pages=N, trace_len=L)
    assert c is not a
    np.testing.assert_array_equal(a.trace, c.trace)


# ---------------------------------------------------------------------------
# Parity: registry-wrapped synthetic scenarios == the old direct calls
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["small", "medium", "large", "mixed"])
def test_synth_scenario_matches_direct_calls(kind):
    """bench_synthetic's registry path must reproduce the exact arrays the
    pre-registry direct calls produced (same seeds → same cache keys)."""
    d = get_scenario(f"synth-{kind}").materialize(
        n_pages=N, trace_len=L, map_seed=1, trace_seed=2)
    m = synthetic_mapping(kind, N, seed=1)
    tr = generate_trace("multiscale", 0, L, seed=2, mapping=m)
    np.testing.assert_array_equal(d.mapping.ppn, m.ppn)
    np.testing.assert_array_equal(d.trace, tr)


@pytest.mark.parametrize("bench", ["mcf", "gups"])
def test_paper_scenario_matches_direct_calls(bench):
    """The paper-benchmark scenarios pin the crc32 per-bench mapping seed the
    old tlb_suite._mapping_for used."""
    pattern, footprint = BENCHMARKS[bench]
    cap = N
    d = get_scenario(f"paper-{bench}").materialize(
        n_pages=cap, trace_len=L, trace_seed=3)
    m = demand_mapping(min(footprint, cap),
                       seed=zlib.crc32(bench.encode()) % 1000)
    tr = generate_trace(pattern, 0, L, seed=3, mapping=m)
    np.testing.assert_array_equal(d.mapping.ppn, m.ppn)
    np.testing.assert_array_equal(d.trace, tr)


def test_demand_scenario_matches_direct_calls():
    d = get_scenario("demand").materialize(n_pages=N, trace_len=L,
                                           map_seed=7, trace_seed=9)
    m = demand_mapping(N, seed=7)
    tr = generate_trace("multiscale", 0, L, seed=9, mapping=m)
    np.testing.assert_array_equal(d.mapping.ppn, m.ppn)
    np.testing.assert_array_equal(d.trace, tr)


# ---------------------------------------------------------------------------
# Workload-derived scenarios: determinism + churn actually happened
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["kv-churn", "kv-gather", "train-pipeline",
                                  "ckpt-shards"])
def test_workload_scenarios_deterministic(name):
    """Same seeds → bit-identical mapping and trace across rebuilds (the
    property the sweep's content-hash cache rests on)."""
    a = get_scenario(name).materialize(n_pages=N, trace_len=L, map_seed=5)
    clear_materialized_cache()
    b = get_scenario(name).materialize(n_pages=N, trace_len=L, map_seed=5)
    np.testing.assert_array_equal(a.mapping.ppn, b.mapping.ppn)
    np.testing.assert_array_equal(a.trace, b.trace)


def _worlds_differ(a, b):
    return a.mapping.ppn.shape != b.mapping.ppn.shape or \
        not np.array_equal(a.mapping.ppn, b.mapping.ppn)


def test_kv_churn_seed_sensitivity():
    """Workload recordings are one system episode: map_seed and trace_seed
    jointly seed it, so varying either yields an independent episode."""
    a = get_scenario("kv-churn").materialize(n_pages=N, trace_len=L,
                                             map_seed=5)
    b = get_scenario("kv-churn").materialize(n_pages=N, trace_len=L,
                                             map_seed=6)
    c = get_scenario("kv-churn").materialize(n_pages=N, trace_len=L,
                                             map_seed=5, trace_seed=1)
    assert _worlds_differ(a, b)
    assert _worlds_differ(a, c)


def test_kv_churn_exercised_the_serving_stack():
    """The recorded world must come from real allocate/extend/preempt/free
    cycles with mixed contiguity, not a quiescent pool."""
    d = get_scenario("kv-churn").materialize(n_pages=1 << 13, trace_len=L,
                                             map_seed=0, trace_seed=8)
    assert d.meta["preemptions"] > 0
    assert d.meta["extends"] > 0
    assert d.meta["completions"] > 0
    assert d.meta["live_seqs"] > 0
    assert len(d.meta["contiguity_histogram"]) >= 3, "contiguity not mixed"


def test_kv_gather_orders_by_class():
    d = get_scenario("kv-gather").materialize(n_pages=1 << 13, trace_len=L,
                                              map_seed=0, trace_seed=8)
    assert d.meta["K"], "Algorithm 3 chose no classes"


# ---------------------------------------------------------------------------
# Scenarios through the sweep engine (content-hash cache must just work)
# ---------------------------------------------------------------------------


def test_scenario_lanes_through_run_sweep_cache(tmp_path):
    d = get_scenario("kv-churn").materialize(n_pages=1 << 12, trace_len=1500,
                                             trace_seed=8)
    cells = [SweepCell(base_spec(), d.mapping, d.trace),
             SweepCell(kaligned_for_mapping(d.mapping, psi=2),
                       d.mapping, d.trace)]
    cdir = str(tmp_path / "cache")
    first = run_sweep(cells, cache=True, cache_dir=cdir)
    assert first.stats["simulated"] == 2
    # rebuild the scenario from scratch: content hashing must still hit
    clear_materialized_cache()
    d2 = get_scenario("kv-churn").materialize(n_pages=1 << 12,
                                              trace_len=1500, trace_seed=8)
    cells2 = [SweepCell(base_spec(), d2.mapping, d2.trace),
              SweepCell(kaligned_for_mapping(d2.mapping, psi=2),
                        d2.mapping, d2.trace)]
    second = run_sweep(cells2, cache=True, cache_dir=cdir)
    assert second.stats["cache_hits"] == 2
    for a, b in zip(first.results, second.results):
        assert a.walks == b.walks and a.cycles == b.cycles


# ---------------------------------------------------------------------------
# KVScheduler core (the policy shared by ServingEngine and the recorder)
# ---------------------------------------------------------------------------


def _mk_sched(pool=64, max_batch=3):
    alloc = PagedKVAllocator(pool, max_order=4)
    return alloc, KVScheduler(alloc, max_batch)


def test_scheduler_fcfs_admission_and_slots():
    alloc, sched = _mk_sched()
    need = {0: 8, 1: 8, 2: 8, 3: 8}
    for rid in need:
        sched.enqueue(rid)
    admitted = sched.admit(need.__getitem__)
    assert admitted == [0, 1, 2]                  # FCFS, max_batch=3
    assert list(sched.waiting) == [3]
    assert sorted(sched.slots.values()) == [0, 1, 2]
    sched.release(1)
    assert sched.admit(need.__getitem__) == [3]
    assert sched.slot_of(3) == 1                  # recycled slot


def test_scheduler_preempts_youngest_and_requeues_front():
    alloc, sched = _mk_sched(pool=32, max_batch=3)
    seen = []
    for rid, n in ((0, 12), (1, 12)):
        sched.enqueue(rid)
    sched.admit({0: 12, 1: 12}.__getitem__)
    sched.enqueue(2)
    admitted = sched.admit(lambda rid: 12, on_preempt=seen.append)
    # pool of 32 can't hold three 12-page (16-frame rounded) seqs: the
    # youngest runner is preempted and lands at the front of the queue
    assert seen == [1]
    assert sched.preemptions == 1
    assert 2 in admitted and list(sched.waiting) == [1]
    assert 1 not in alloc.seqs                    # pages were freed


def test_scheduler_admit_terminates_under_thrash():
    """Ping-pong regression: admitting A by preempting B, then B by
    preempting A, must not loop forever."""
    alloc, sched = _mk_sched(pool=32, max_batch=2)
    sched.enqueue(0)
    sched.enqueue(1)
    sched.enqueue(2)
    sched.admit(lambda rid: 24)                   # each seq nearly fills pool
    assert len(sched.running) >= 1
    # a second pass over a saturated pool must return, not spin
    sched.admit(lambda rid: 24)
    assert sched.has_work


def test_allocator_failed_allocation_rolls_back_partial_blocks():
    """Regression: a mid-allocation failure must return partial buddy blocks
    to the pool instead of leaking them."""
    alloc = PagedKVAllocator(32, max_order=4)
    free_before, _ = alloc.buddy.frag_stats()
    assert alloc.allocate(0, 64) is None          # bigger than the pool
    free_after, _ = alloc.buddy.frag_stats()
    assert free_after == free_before, "partial blocks leaked"
