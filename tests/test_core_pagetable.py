"""Page-table / contiguity semantics against the paper's own worked examples."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (aligned_vpn, alignment_class, contiguity_chunks,
                        determine_k, f_alignment, fill_select, make_mapping,
                        stored_contiguity)
from repro.core.aligned import REGULAR, aligned_lookup

# The paper's Figure 4 page table: VPN -> PPN (K = {1, 2, 3}).
FIG4_PPN = [0x8, 0x9, 0x2, 0x0, 0x4, 0x5, 0x6, 0x3,
            0xA, 0xB, 0xC, 0xD, 0xE, 0xF, 0x1, 0x7]


@pytest.fixture(scope="module")
def fig4():
    return make_mapping(np.array(FIG4_PPN, dtype=np.int64), name="fig4")


class TestFig4:
    def test_chunks(self, fig4):
        # "three contiguity chunks occur ... their sizes are 2, 3 and 6"
        sizes = sorted(s for _, s in contiguity_chunks(fig4) if s > 1)
        assert sizes == [2, 3, 6]

    def test_chunk_positions(self, fig4):
        chunks = dict(contiguity_chunks(fig4))
        assert chunks[0] == 2      # VPN 0: chunk of 2
        assert chunks[4] == 3      # VPN 4: chunk of 3
        assert chunks[8] == 6      # VPN 8: chunk of 6

    def test_alignment_classes(self, fig4):
        # Rightward Compatible Rule (paper's examples)
        K = (3, 2, 1)
        assert alignment_class(8, K) == 3
        assert alignment_class(4, K) == 2
        assert alignment_class(6, K) == 1
        assert alignment_class(0, K) == 3
        assert alignment_class(5, K) == REGULAR

    def test_stored_contiguity(self, fig4):
        # Fig 4 annotations: VPN 0 (3-bit) -> 2; VPN 4 (2-bit) -> 3;
        # VPN 8 (3-bit) -> 6 "completely covering the chunk of size 6"
        assert stored_contiguity(fig4, 0, 3) == 2
        assert stored_contiguity(fig4, 4, 2) == 3
        assert stored_contiguity(fig4, 8, 3) == 6
        assert stored_contiguity(fig4, 10, 1) == 2

    def test_fig5_fill(self, fig4):
        # Fig 5: translating VPN 13 fills the 3-bit aligned entry at VPN 8
        # (contiguity 6 covers diff 5), preferred over the 2-bit at VPN 12.
        e = fill_select(fig4, 13, K=(3, 2, 1))
        assert (e.tag, e.kcls, e.contiguity) == (8, 3, 6)
        assert e.ppn + (13 - 8) == FIG4_PPN[13]

    def test_fig5_lookup(self, fig4):
        e = fill_select(fig4, 13, K=(3, 2, 1))
        ppn, probes, hit_k = aligned_lookup([e], 11, K=(3, 2, 1), first_k=3)
        assert ppn == FIG4_PPN[11] and probes == 1 and hit_k == 3
        # VPN 14 is NOT covered (chunk of 6 = VPNs 8..13)
        ppn, _, _ = aligned_lookup([e], 14, K=(3, 2, 1))
        assert ppn is None


class TestDetermineK:
    def test_size_range_table(self):
        # Table 1 boundaries
        for size, k in [(2, 4), (16, 4), (17, 6), (64, 6), (65, 7), (128, 7),
                        (129, 8), (256, 8), (257, 9), (512, 9), (513, 10),
                        (1024, 10), (1025, 11), (10**6, 11)]:
            assert f_alignment(size) == k, size
        assert f_alignment(1) == -1

    def test_paper_example(self):
        # §3.3: "if the memory mapping is filled with the contiguity chunks of
        # size 16 and 128 that cover more than 90% of contiguous pages,
        # K = {4, 7} will be returned"
        hist = {16: 100, 128: 100, 2: 1}
        assert sorted(determine_k(hist)) == [4, 7]

    def test_theta_stops(self):
        hist = {16: 1000, 64: 1}   # k=4 alone covers ~99.6%
        assert determine_k(hist, theta=0.9) == [4]

    def test_theta_exact_boundary_inclusive(self):
        """Algorithm 3 stops at coverage >= theta, not strictly greater: a
        histogram whose best class covers EXACTLY theta of the total must
        stop after that class (regression: the break used strict >)."""
        # k=4 covers 2*16=32, k=6 covers 32: exact half; coverage tie is
        # broken toward the larger k, which then meets theta=0.5 alone
        assert determine_k({16: 2, 32: 1}, theta=0.5, psi=4) == [6]
        # k=4 covers 18*16=288 of 320 == 0.9 exactly (float-representable
        # via the epsilon guard): must stop at [4], not append k=6
        assert determine_k({16: 18, 32: 1}, theta=0.9, psi=4) == [4]

    def test_psi_bound(self):
        hist = {2: 100, 32: 100, 100: 120, 200: 90, 400: 70, 600: 60}
        assert len(determine_k(hist, theta=1.0, psi=4)) <= 4


@given(st.lists(st.integers(1, 40), min_size=1, max_size=30),
       st.integers(0, 2**31 - 1))
@settings(max_examples=50, deadline=None)
def test_run_extraction_properties(sizes, seed):
    """compute_runs recovers exactly the chunks a random layout creates."""
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(sizes))
    ppn = []
    base = 0
    bases = {}
    for idx in order:
        bases[idx] = base
        base += sizes[idx] + 1          # +1 gap: chunks never merge
    for idx, s in enumerate(sizes):
        ppn.extend(range(bases[idx], bases[idx] + s))
    m = make_mapping(np.array(ppn, dtype=np.int64))
    assert sorted(s for _, s in contiguity_chunks(m)) == sorted(sizes)
    # contiguity field: within a chunk it counts down to 1
    for start, size in contiguity_chunks(m):
        got = m.contiguity(np.arange(start, start + size))
        assert list(got) == list(range(size, 0, -1))


@given(st.integers(0, 10**6), st.integers(1, 11))
@settings(max_examples=200, deadline=None)
def test_aligned_vpn_properties(vpn, k):
    vk = aligned_vpn(vpn, k)
    assert vk % (1 << k) == 0
    assert 0 <= vpn - vk < (1 << k)
