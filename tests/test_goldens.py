"""Golden-trace oracle suite: per-step pinning of tiny worlds.

Each file under ``tests/goldens/`` holds a <= 16-access world for one
method kind (plus one multi-tenant world per context-switch policy) with
the oracle's expected per-step ``(level, ppn, evict, probes, cycles)``
sequence and segment-entry events.  The tests replay the oracle and
compare STEP BY STEP — a parity failure names the first diverging step —
then run both sweep backends over the same world and hold them to the
golden's final counters and translated PPNs.

Regenerate after an intentional semantics change with
``PYTHONPATH=src python scripts/make_goldens.py`` and review the diff;
the generator's docstrings describe what each world is designed to prove.
"""
import glob
import json
import os

import numpy as np
import pytest

from repro.core.page_table import (DynamicMapping, MultiTenantMapping,
                                   NestedMapping, make_mapping)
from repro.core.simulator import (MethodSpec, run_method_dynamic,
                                  run_method_multitenant,
                                  run_method_nested)
from repro.core.sweep import SweepCell, run_sweep

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "goldens")
GOLDEN_FILES = sorted(glob.glob(os.path.join(GOLDEN_DIR, "*.json")))

STEP_FIELDS = ("t", "vpn", "asid", "level", "ppn", "walk", "evict",
               "probes", "cycles")


def _load(path):
    with open(path) as f:
        return json.load(f)


def _layer(d, name):
    return DynamicMapping(
        tuple(make_mapping(np.asarray(p, np.int64), name=f"{name}e{e}")
              for e, p in enumerate(d["epochs"])),
        tuple(d["boundaries"]), name=name)


def _rebuild(g):
    spec = MethodSpec(**{**g["spec"], "K": tuple(g["spec"]["K"])})
    w = g["world"]
    if w["kind"] == "nested":
        world = NestedMapping(
            tuple(_layer(d, f"g{i}") for i, d in enumerate(w["guests"])),
            _layer(w["host"], "host"), tuple(w["boundaries"]),
            tuple(w["guest_ids"]), tuple(w["asids"]), name=g["name"])
        runner = run_method_nested
    elif w["kind"] == "multitenant":
        world = MultiTenantMapping(
            tuple(make_mapping(np.asarray(p, np.int64), name=f"t{i}")
                  for i, p in enumerate(w["tenants"])),
            tuple(w["boundaries"]), tuple(w["tenant_ids"]),
            tuple(w["asids"]), name=g["name"])
        runner = run_method_multitenant
    else:
        world = make_mapping(np.asarray(w["ppn"], np.int64), name=g["name"])
        runner = run_method_dynamic
    return spec, world, runner, np.asarray(g["trace"], np.int64)


def test_goldens_exist_and_cover_every_kind():
    assert len(GOLDEN_FILES) >= 16
    gs = [_load(p) for p in GOLDEN_FILES]
    kinds = {g["spec"]["kind"] for g in gs}
    assert {"base", "thp", "colt", "cluster", "rmm", "anchor", "kaligned",
            "subregion", "cache-tlb", "dead-protect"} <= kinds
    # the kaligned pair covers predictor on AND off
    preds = {g["spec"]["use_predictor"] for g in gs
             if g["spec"]["kind"] == "kaligned"}
    assert preds == {True, False}
    # one multi-tenant golden per context-switch policy
    mt_pol = {g["spec"]["ctx_policy"] for g in gs
              if g["world"]["kind"] == "multitenant"}
    assert mt_pol == {"flush", "tag"}
    # one nested golden per translation-coherence policy
    coh = {g["spec"]["coh_policy"] for g in gs
           if g["world"]["kind"] == "nested"}
    assert coh == {"shootdown", "hw-coherence"}
    assert all(len(g["trace"]) <= 16 for g in gs)


def test_nested_coherence_pair_differs_only_in_cycles():
    """The nested coherence pair shares world and trace, so their diff IS
    the coh_policy cost model: identical walks/hits/shootdowns/events and
    a cycle gap of exactly LAT_SHOOTDOWN per dirty turnover."""
    from repro.core.simulator import LAT_SHOOTDOWN
    sd = _load(os.path.join(GOLDEN_DIR, "nested-host-remap.json"))
    hw = _load(os.path.join(GOLDEN_DIR,
                            "nested-coherence-vs-shootdown.json"))
    assert sd["world"] == hw["world"] and sd["trace"] == hw["trace"]
    assert sd["events"] == hw["events"]      # same entries die, same steps
    for f, v in sd["final"].items():
        if f != "cycles":
            assert hw["final"][f] == pytest.approx(v), f
    n_turnovers = sum(e["kind"] == "shootdown" for e in sd["events"])
    assert n_turnovers == 2                  # one guest + one host epoch
    assert sd["final"]["cycles"] - hw["final"]["cycles"] == \
        LAT_SHOOTDOWN * n_turnovers


@pytest.mark.parametrize("path", GOLDEN_FILES,
                         ids=[os.path.basename(p)[:-5]
                              for p in GOLDEN_FILES])
def test_oracle_matches_golden_step_by_step(path):
    """The oracle's per-step hit-level/ppn/evict/latency sequence and its
    segment-entry events reproduce the committed golden exactly; on
    divergence the assertion names the step."""
    g = _load(path)
    spec, world, runner, trace = _rebuild(g)
    steps, events = [], []
    r = runner(spec, world, trace, on_step=steps.append,
               on_event=events.append)
    assert len(steps) == len(g["steps"])
    for got, want in zip(steps, g["steps"]):
        for f in STEP_FIELDS:
            assert got[f] == want[f], (
                f"{g['name']}: step t={want['t']} field {f!r}: "
                f"got {got[f]!r}, golden {want[f]!r} "
                f"(golden level sequence: "
                f"{[s['level'] for s in g['steps']]})")
    assert events == g["events"], f"{g['name']}: segment-entry events"
    for f, v in g["final"].items():
        got = getattr(r, f)
        assert got == pytest.approx(v), (g["name"], f)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_backends_match_goldens(backend):
    """Both sweep backends reproduce every golden's final counters and
    per-step translations (one batch over all golden worlds)."""
    gs = [_load(p) for p in GOLDEN_FILES]
    cells = []
    for g in gs:
        spec, world, _, trace = _rebuild(g)
        cells.append(SweepCell(spec, world, trace))
    sweep = run_sweep(cells, cache=False, backend=backend, block_size=4)
    for g, got in zip(gs, sweep.results):
        for f, v in g["final"].items():
            assert getattr(got, f) == pytest.approx(v), \
                (g["name"], backend, f)
        np.testing.assert_array_equal(
            got.ppn, np.asarray([s["ppn"] for s in g["steps"]]),
            err_msg=f"{g['name']} ({backend})")
