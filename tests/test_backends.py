"""Backend parity matrix for the time-blocked sweep engine.

The per-lane program (:mod:`repro.core.lane_program`) has two execution
backends — the time-blocked XLA scan and the Pallas TLB-sweep kernel — and
one tunable execution detail, the block size.  None of them may change a
single counter: every combination of

    backend ∈ {xla (TB = 1, 3, 8), pallas (interpret)}
  × method kind ∈ all 11 (base/thp/colt/cluster/rmm/anchor/kaligned ±pred
                          + subregion/cache-tlb/dead-protect)
  × world ∈ {static demand mapping, dynamic remap world}

must be bit-exact — including shootdown counters and every translated
PPN — against the pure-python oracles ``run_method`` /
``run_method_dynamic``.  A hypothesis property test additionally drives
random block sizes (block boundaries are an execution detail), and the
trace-bucket tests pin that trace padding never leaks into results or
cache keys.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import demand_mapping, generate_trace
from repro.core.baselines import (anchor_spec, base_spec, cache_tlb_spec,
                                  cluster_spec, colt_spec, dead_protect_spec,
                                  kaligned_spec, rmm_spec, subregion_spec,
                                  thp_spec)
from repro.core.lane_program import TRACE_FLOOR, bucket_trace_len
from repro.core.page_table import MappingEvent, build_dynamic_mapping
from repro.core.simulator import run_method, run_method_dynamic
from repro.core.sweep import SweepCell, cell_key, run_sweep

COUNTERS = ("accesses", "l1_hits", "l2_regular_hits", "l2_coalesced_hits",
            "walks", "aligned_probes", "pred_correct", "cycles",
            "coverage_mean", "shootdowns")

ALL_KINDS = [base_spec(), thp_spec(), colt_spec(), cluster_spec(), rmm_spec(),
             anchor_spec(6), kaligned_spec([9, 6, 4]),
             kaligned_spec([6, 4], use_predictor=False, name="ka-nopred"),
             subregion_spec(), cache_tlb_spec(), dead_protect_spec()]


def _assert_equal(got, want, ctx):
    for f in COUNTERS:
        assert getattr(got, f) == getattr(want, f), (ctx, f)
    np.testing.assert_array_equal(got.ppn, want.ppn, err_msg=str(ctx))


@pytest.fixture(scope="module")
def worlds():
    """One static and one dynamic world, both small enough for the python
    oracles and the interpret-mode kernel."""
    m = demand_mapping(1 << 10, seed=11)
    tr = generate_trace("multiscale", 0, 400, seed=4, mapping=m)
    n = 1 << 10
    ppn0 = np.arange(n, dtype=np.int64) + 7          # contiguous: huge runs
    ev1 = [MappingEvent("remap", 0, 128, ppn=100_000)]
    ev2 = [MappingEvent("split", 128, 64,
                        ppn=np.arange(200_000, 200_000 + 64 * 3, 3)),
           MappingEvent("unmap", 768, 32)]
    dyn = build_dynamic_mapping(ppn0, [(150, ev1), (370, ev2)], name="hot")
    rng = np.random.default_rng(3)
    dtr = rng.integers(0, 512, size=520).astype(np.int64)
    return m, tr, dyn, dtr


@pytest.fixture(scope="module")
def cells(worlds):
    """Mixed batch: 8 static + 8 dynamic lanes (run_sweep partitions them
    into a static-only and a dynamic batch internally)."""
    m, tr, dyn, dtr = worlds
    return [SweepCell(s, m, tr) for s in ALL_KINDS] + \
           [SweepCell(s, dyn, dtr) for s in ALL_KINDS]


@pytest.fixture(scope="module")
def oracles(worlds):
    m, tr, dyn, dtr = worlds
    return ([run_method(s, m, tr) for s in ALL_KINDS],
            [run_method_dynamic(s, dyn, dtr) for s in ALL_KINDS])


@pytest.mark.parametrize("tb", [1, 3, 8])
def test_xla_blocked_parity(cells, oracles, tb):
    """The time-blocked XLA backend is bit-exact vs the pure-python oracles
    for several block sizes, including the degenerate TB=1 (whose timeline
    equals the step-at-a-time engine)."""
    static_want, dyn_want = oracles
    sweep = run_sweep(cells, cache=False, backend="xla", block_size=tb)
    assert sweep.stats["backend"] == "xla"
    assert sweep.stats["block"] == tb
    assert sweep.stats["n_batches"] == 2          # static-only + dynamic
    for i, spec in enumerate(ALL_KINDS):
        _assert_equal(sweep.results[i], static_want[i],
                      (spec.name, "static", tb))
        _assert_equal(sweep.results[len(ALL_KINDS) + i], dyn_want[i],
                      (spec.name, "dynamic", tb))


def test_pallas_parity(cells, oracles):
    """The Pallas TLB-sweep kernel (interpret mode on CPU) is bit-exact vs
    the same oracles — all 8 method kinds, static AND dynamic worlds,
    including the in-kernel shootdown pass."""
    static_want, dyn_want = oracles
    sweep = run_sweep(cells, cache=False, backend="pallas", block_size=4)
    assert sweep.stats["backend"] == "pallas"
    for i, spec in enumerate(ALL_KINDS):
        _assert_equal(sweep.results[i], static_want[i],
                      (spec.name, "static", "pallas"))
        _assert_equal(sweep.results[len(ALL_KINDS) + i], dyn_want[i],
                      (spec.name, "dynamic", "pallas"))


def test_backend_name_validated():
    with pytest.raises(ValueError):
        run_sweep([], backend="cuda")


def test_ref_backend_parity(worlds, oracles):
    """The step-at-a-time pure-JAX reference
    (``kernels/tlb_sweep/ref.py``) — the third leg of the parity matrix,
    with no time blocking at all — matches the oracles too."""
    from repro.core.lane_program import (C_COAL, C_COV, C_CYC, C_L1, C_PRED,
                                         C_PROBE, C_REG, C_SHOOT, C_WALK,
                                         init_batched_state, pack_lanes)
    from repro.kernels.tlb_sweep.ref import run_lanes_ref
    m, tr, dyn, dtr = worlds
    static_want, dyn_want = oracles
    fields = {C_L1: "l1_hits", C_REG: "l2_regular_hits",
              C_COAL: "l2_coalesced_hits", C_WALK: "walks",
              C_PROBE: "aligned_probes", C_PRED: "pred_correct",
              C_CYC: "cycles", C_SHOOT: "shootdowns"}
    assert C_COV not in fields          # sampled, compared via the mean
    for world, trace, wants in ((m, tr, static_want), (dyn, dtr, dyn_want)):
        cells = [SweepCell(s, world, trace) for s in ALL_KINDS]
        lanes, stacks, (L, sets, ways), seg_bounds = pack_lanes(cells)
        st0 = init_batched_state(
            L, sets, ways, lanes["pred0"],
            with_ctlb=bool(np.asarray(lanes["has_ctlb"]).any()),
            with_dp=bool(np.asarray(lanes["use_dead"]).any()))
        stF, ppns = run_lanes_ref(lanes, stacks, st0, seg_bounds)
        counters = np.asarray(stF["counters"])
        cov = np.asarray(stF["cov_samples"])
        for i, (spec, want) in enumerate(zip(ALL_KINDS, wants)):
            for c, f in fields.items():
                assert counters[i, c] == getattr(want, f), (spec.name, f)
            assert float(np.mean(cov[i])) == want.coverage_mean, spec.name
            np.testing.assert_array_equal(
                np.asarray(ppns)[i, : trace.shape[0]], want.ppn,
                err_msg=spec.name)


# ---------------------------------------------------------------------------
# Property: block boundaries are an execution detail
# ---------------------------------------------------------------------------


@given(st.integers(1, 50))
@settings(max_examples=4, deadline=None)
def test_block_boundaries_never_change_results(tb):
    """For ANY block size — aligned or not with the trace length or the
    epoch boundaries — the sweep returns the same counters and PPNs."""
    m = demand_mapping(1 << 9, seed=2)
    tr = generate_trace("zipf", 0, 333, seed=7, mapping=m)
    specs = [base_spec(), colt_spec(), kaligned_spec([6, 4])]
    sweep = run_sweep([SweepCell(s, m, tr) for s in specs],
                      cache=False, backend="xla", block_size=tb)
    for s, got in zip(specs, sweep.results):
        _assert_equal(got, run_method(s, m, tr), (s.name, tb))


# ---------------------------------------------------------------------------
# Trace buckets: padded length is invisible to results and cache keys
# ---------------------------------------------------------------------------


def test_trace_bucket_pow2_with_floor():
    assert bucket_trace_len(1) == TRACE_FLOOR
    assert bucket_trace_len(TRACE_FLOOR) == TRACE_FLOOR
    assert bucket_trace_len(TRACE_FLOOR + 1) == 2 * TRACE_FLOOR
    assert bucket_trace_len(4096) == 4096
    assert bucket_trace_len(5000) == 8192
    # long paper traces use linear 16k buckets, not pow2 (padding stays
    # under ~13%, where pow2 could double the scan)
    assert bucket_trace_len(150_000) == 163_840
    assert bucket_trace_len(1 << 17) == 1 << 17


def test_padded_length_changes_nothing(worlds):
    """The same cell simulated under different padded trace lengths (alone:
    the 256 floor bucket; next to a much longer trace: a 2048 bucket) keeps
    its cell_key AND produces identical results."""
    m, tr, _, _ = worlds
    spec = kaligned_spec([8, 6, 4])
    cell_alone = SweepCell(spec, m, tr)
    long_tr = generate_trace("zipf", 0, 1800, seed=9, mapping=m)
    alone = run_sweep([cell_alone], cache=False, backend="xla")
    cell_again = SweepCell(spec, m, tr)
    padded = run_sweep([cell_again, SweepCell(base_spec(), m, long_tr)],
                       cache=False, backend="xla")
    assert cell_key(cell_alone) == cell_key(cell_again)
    got, want = padded.results[0], alone.results[0]
    for f in COUNTERS:
        assert getattr(got, f) == getattr(want, f), f
    np.testing.assert_array_equal(got.ppn, want.ppn)
