"""Allocator + block-table invariants (property-based)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.mappings import BuddyAllocator
from repro.kvcache import PagedKVAllocator, assign_classes
from repro.kvcache.block_table import choose_kernel_classes


@given(st.lists(st.tuples(st.integers(0, 4), st.booleans()), min_size=1,
                max_size=60), st.integers(16, 256))
@settings(max_examples=40, deadline=None)
def test_buddy_invariants(ops, n_frames):
    """No double allocation; blocks order-aligned; free coalesces fully."""
    buddy = BuddyAllocator(n_frames, max_order=5)
    total = buddy.n_frames
    if total == 0:
        return
    live = {}
    for i, (order, do_free) in enumerate(ops):
        order = min(order, 5)
        base = buddy.alloc(order)
        if base is not None:
            assert base % (1 << order) == 0, "buddy blocks are order-aligned"
            rng = set(range(base, base + (1 << order)))
            for other in live.values():
                assert not (rng & other), "overlapping allocation"
            live[i] = rng
        if do_free and live:
            key = next(iter(live))
            blk = live.pop(key)
            b0 = min(blk)
            buddy.free_block(b0, int(np.log2(len(blk))))
    for key in list(live):
        blk = live.pop(key)
        buddy.free_block(min(blk), int(np.log2(len(blk))))
    free, largest = buddy.frag_stats()
    assert free == total, "all frames returned"
    assert largest == buddy.max_order, "full coalescing restores max block"


@given(st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_paged_allocator_roundtrip(seed):
    rng = np.random.default_rng(seed)
    alloc = PagedKVAllocator(256, max_order=6)
    live = []
    for i in range(30):
        if rng.random() < 0.6:
            if alloc.allocate(i, int(rng.integers(1, 20))) is not None:
                live.append(i)
        elif live:
            alloc.free(live.pop(int(rng.integers(0, len(live)))))
    # tables of live seqs never share pages
    seen = set()
    for rid in live:
        pages = alloc.seqs[rid].pages
        assert len(set(pages)) == len(pages)
        assert not (set(pages) & seen)
        seen |= set(pages)
    hist = alloc.contiguity_histogram()
    assert sum(s * f for s, f in hist.items()) >= len(seen) * 0 and all(
        s >= 1 for s in hist)


def test_buddy_policy_produces_more_contiguity():
    """Paper §2: scattered in-use pages inhibit large allocations.  After
    free-every-other churn, page-granular allocation lands on the isolated
    holes (runs of 1) while buddy_best still finds aligned blocks."""
    hists = {}
    for policy in ("buddy_best", "page"):
        alloc = PagedKVAllocator(512, max_order=6, alloc_policy=policy)
        # churn: 40 single-page allocations, free every other one → 20
        # isolated free pages whose buddies are in use (cannot coalesce)
        for i in range(40):
            alloc.allocate(1000 + i, 1)
        for i in range(0, 40, 2):
            alloc.free(1000 + i)
        alloc.allocate(1, 16)
        phys = np.asarray(alloc.seqs[1].pages, np.int64)
        from repro.core.page_table import compute_runs
        _, rl = compute_runs(phys)
        hists[policy] = int(rl.max())
    assert hists["buddy_best"] >= 8
    assert hists["page"] <= 2
    assert hists["buddy_best"] > hists["page"]


@given(st.integers(0, 99999), st.integers(1, 4))
@settings(max_examples=40, deadline=None)
def test_assign_classes_partition(seed, psi):
    """Every mapped page claimed by exactly one class; class-k windows are
    contiguous and aligned."""
    rng = np.random.default_rng(seed)
    n = 64
    bt = np.full(n, -1, np.int64)
    pos = 0
    phys = 0
    while pos < n and rng.random() < 0.95:
        run = int(rng.integers(1, 12))
        run = min(run, n - pos)
        align = 1 << min(int(np.log2(run)) if run > 1 else 0, 4)
        phys = -(-phys // align) * align
        bt[pos:pos + run] = np.arange(phys, phys + run)
        phys += run + int(rng.integers(0, 3))
        pos += run + int(rng.integers(0, 3))
    K = [3, 2, 1][:psi]
    asg = assign_classes(bt, K)
    claimed = np.zeros(n, int)
    for k, take in asg.items():
        w = 1 << k
        expanded = np.repeat(take, w)[:n] if k else take.astype(int)
        claimed += expanded.astype(int)
        if k > 0:
            for j in np.flatnonzero(take):
                seg = bt[j * w:(j + 1) * w]
                assert (np.diff(seg) == 1).all(), "class window not contiguous"
                assert seg[0] % w == 0, "class window not aligned"
    np.testing.assert_array_equal(claimed, (bt >= 0).astype(int))


def test_choose_kernel_classes_theta_psi():
    assert choose_kernel_classes({8: 100}, psi=3) == [3]
    assert choose_kernel_classes({8: 100, 2: 100, 32: 100}, psi=2,
                                 theta=1.0) == [5, 3]
    assert choose_kernel_classes({1: 50}) == []
    K = choose_kernel_classes({1024: 5}, max_class=6)
    assert K == [6], "classes capped for VMEM"
