"""Tests for the no-install CI gate scripts.

``scripts/check_docs_links.py`` and ``scripts/check_tier_budget.py`` are
loaded by file path (they are scripts, not package modules) and driven
against tmp-dir fixture trees: broken-link, undocumented-kind,
unarmed-host and over-budget cases, plus the GitHub step-summary output.
The tier-budget tests stub the pytest subprocess and the clock — they
test the gate logic, not the suite it times.
"""
from __future__ import annotations

import importlib.util
import json
import subprocess
import sys
from pathlib import Path

import pytest

REPO_ROOT = Path(__file__).resolve().parents[1]


def load_script(name):
    spec = importlib.util.spec_from_file_location(
        name, REPO_ROOT / "scripts" / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def docs_links():
    return load_script("check_docs_links")


@pytest.fixture
def tier_budget():
    # function-scoped: each test monkeypatches its module globals
    return load_script("check_tier_budget")


# ---------------------------------------------------------------------------
# check_docs_links
# ---------------------------------------------------------------------------

SIM_FIXTURE = """\
ACCEL_KINDS = ("subregion",)
KINDS = ("base", "thp") + ACCEL_KINDS
"""


def make_docs_tree(root: Path, *, methods: str, readme: str):
    (root / "src" / "repro" / "core").mkdir(parents=True)
    (root / "src" / "repro" / "core" / "simulator.py").write_text(
        SIM_FIXTURE)
    (root / "docs").mkdir()
    (root / "docs" / "methods.md").write_text(methods)
    (root / "README.md").write_text(readme)


def test_docs_clean_tree_passes(tmp_path, docs_links, capsys):
    make_docs_tree(tmp_path,
                   methods="`base` `thp` `subregion`\n",
                   readme="[methods](docs/methods.md)\n")
    assert docs_links.check(str(tmp_path)) == 0
    assert "0 broken" in capsys.readouterr().out


def test_docs_broken_link_fails(tmp_path, docs_links, capsys):
    make_docs_tree(tmp_path,
                   methods="`base` `thp` `subregion`\n",
                   readme="[gone](docs/nonexistent.md)\n")
    assert docs_links.check(str(tmp_path)) == 1
    assert "BROKEN" in capsys.readouterr().err


def test_docs_undocumented_kind_fails(tmp_path, docs_links, capsys):
    make_docs_tree(tmp_path,
                   methods="`base` `subregion`\n",  # thp missing
                   readme="[methods](docs/methods.md)\n")
    assert docs_links.check(str(tmp_path)) == 1
    err = capsys.readouterr().err
    assert "UNDOCUMENTED" in err and "`thp`" in err


def test_docs_kind_registry_uses_shared_parser(tmp_path, docs_links):
    make_docs_tree(tmp_path, methods="x\n", readme="x\n")
    assert docs_links.registered_kinds(str(tmp_path)) == \
        ["base", "thp", "subregion"]


# ---------------------------------------------------------------------------
# check_tier_budget
# ---------------------------------------------------------------------------

def arm_tier_budget(tier_budget, monkeypatch, tmp_path, *, wall_s: float,
                    baseline):
    """Point the script at a tmp repo, stub pytest + the clock."""
    bench = tmp_path / "BENCH_tier1.json"
    if baseline is not None:
        entry = {"git_sha": "seed", "host": tier_budget._host_sig(),
                 "wall_s": baseline, "pytest_args": []}
        bench.write_text(json.dumps([entry]) + "\n")
    monkeypatch.setattr(tier_budget, "REPO", str(tmp_path))
    monkeypatch.setattr(tier_budget, "BENCH_FILE", str(bench))

    real_run = subprocess.run

    def fake_run(cmd, **kw):
        if "pytest" in cmd:
            return subprocess.CompletedProcess(cmd, 0)
        return real_run(cmd, **kw)  # git calls: fail normally in tmp

    monkeypatch.setattr(tier_budget.subprocess, "run", fake_run)
    ticks = iter([0.0, wall_s])
    monkeypatch.setattr(tier_budget.time, "time", lambda: next(ticks))
    return bench


def test_unarmed_host_passes_with_ready_to_commit_entry(
        tier_budget, monkeypatch, tmp_path, capsys):
    bench = arm_tier_budget(tier_budget, monkeypatch, tmp_path,
                            wall_s=10.0, baseline=None)
    summary = tmp_path / "summary.md"
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
    assert tier_budget.main(["--check"]) == 0
    err = capsys.readouterr().err
    assert "budget gate did NOT run" in err
    assert '"wall_s": 10.0' in err  # the ready-to-commit entry
    text = summary.read_text()
    assert "not armed" in text and '"wall_s": 10.0' in text
    # the run was still appended so a later commit can arm the gate
    assert json.loads(bench.read_text())[0]["wall_s"] == 10.0


def test_over_budget_fails(tier_budget, monkeypatch, tmp_path, capsys):
    arm_tier_budget(tier_budget, monkeypatch, tmp_path,
                    wall_s=10.0, baseline=1.0)
    summary = tmp_path / "summary.md"
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
    assert tier_budget.main(["--check", "--no-append"]) == 1
    assert "BUDGET EXCEEDED" in capsys.readouterr().err
    assert "BUDGET EXCEEDED" in summary.read_text()


def test_within_budget_passes(tier_budget, monkeypatch, tmp_path, capsys):
    arm_tier_budget(tier_budget, monkeypatch, tmp_path,
                    wall_s=10.0, baseline=9.0)
    assert tier_budget.main(["--check", "--no-append"]) == 0
    assert "1.11x vs baseline" in capsys.readouterr().out


def test_baseline_ignores_other_host_and_args(tier_budget, monkeypatch,
                                              tmp_path, capsys):
    bench = arm_tier_budget(tier_budget, monkeypatch, tmp_path,
                            wall_s=10.0, baseline=None)
    entries = [
        {"git_sha": "x", "host": "other-host-1cpu", "wall_s": 0.1,
         "pytest_args": []},
        {"git_sha": "x", "host": tier_budget._host_sig(), "wall_s": 0.1,
         "pytest_args": ["--cov=repro.core"]},
    ]
    bench.write_text(json.dumps(entries) + "\n")
    monkeypatch.delenv("GITHUB_STEP_SUMMARY", raising=False)
    assert tier_budget.main(["--check", "--no-append"]) == 0
    assert "did NOT run" in capsys.readouterr().err
