"""End-to-end behaviour: the paper's headline claims on this implementation.

These are the integration tests that pin the reproduction: relative-miss
ordering across methods (Fig 1/Table 4 structure) and the serving stack's
descriptor reduction under mixed contiguity.
"""
import pytest

from repro.core import (anchor_static, base_spec, generate_trace,
                        kaligned_for_mapping, run_method, synthetic_mapping,
                        thp_spec)


@pytest.fixture(scope="module")
def mixed():
    m = synthetic_mapping("mixed", 1 << 17, seed=11)
    tr = generate_trace("multiscale", 0, 120_000, seed=12, mapping=m)
    return m, tr


def test_kaligned_beats_anchor_on_mixed(mixed):
    """The paper's central claim: on mixed contiguity, K Aligned reduces
    misses >= 27% relative to Anchor-Static (abstract; §4.2 shows more).
    psi=4 is the paper's strongest mode (Table 4 rightmost column)."""
    m, tr = mixed
    anchor = anchor_static(m, tr, grid=(4, 6, 8, 9, 10, 11))
    ka = run_method(kaligned_for_mapping(m, psi=4, theta=1.0), m, tr)
    assert ka.walks < 0.73 * anchor.walks, (ka.walks, anchor.walks)


def test_method_ordering_on_mixed(mixed):
    """Base > THP > K-Aligned (Fig 1 structure on mixed contiguity)."""
    m, tr = mixed
    base = run_method(base_spec(), m, tr).walks
    thp = run_method(thp_spec(), m, tr).walks
    ka = run_method(kaligned_for_mapping(m, psi=2), m, tr).walks
    assert ka < thp <= base


def test_psi_monotone(mixed):
    """Fig 9: more alignment types never hurt (theta=1 to expose |K|)."""
    m, tr = mixed
    walks = []
    for psi in (1, 2, 3):
        spec = kaligned_for_mapping(m, psi=psi, theta=1.0)
        walks.append(run_method(spec, m, tr).walks)
    assert walks[2] <= walks[1] <= walks[0] * 1.02
